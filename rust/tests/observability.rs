//! Observability suite (DESIGN.md §11): the contracts the obs layer
//! must keep under concurrency and across the serving path.
//!
//! * lock-free registry: multi-threaded counter/histogram increments
//!   end in a deterministic snapshot; snapshot merge is associative;
//! * cross-replica stats: `ServerStats::merge_from` folds latency
//!   rings + counters, and p999 is exposed end to end;
//! * tracing completeness: every admitted request closes exactly one
//!   span; rejected requests never open one;
//! * quantization health: boundary-bin (saturation) rates are exact on
//!   a synthetic clipped layer, and the live-vs-calibration sketch
//!   divergence moves when the input distribution shifts — the
//!   boundary-accumulation signal BS-KMQ recalibration would key off;
//! * exposition: the Prometheus page carries the request + per-qlayer
//!   health series, and `stats` JSON parses.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use bskmq::backend::BackendKind;
use bskmq::coordinator::pool::{
    ModelPool, ObsConfig, PoolConfig, ServerStats,
};
use bskmq::data::dataset::ModelData;
use bskmq::data::synth;
use bskmq::obs::quant_health::health_sketch;
use bskmq::obs::{
    Histogram, MetricsRegistry, PromWriter, QuantHealth, TraceSink,
};
use bskmq::quant::codebook::Codebook;
use bskmq::quant::{Method, QuantSpec};
use bskmq::util::json::Json;

fn fresh_dir(tag: &str, models: &[&str]) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bskmq_obs_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    for m in models {
        synth::write_model(&dir, m, 42).unwrap();
    }
    dir
}

fn obs_cfg(replicas: usize, queue_depth: usize, obs: ObsConfig) -> PoolConfig {
    PoolConfig {
        backend: BackendKind::Native,
        spec: Some(QuantSpec::new(Method::BsKmq, 3)),
        noise_std: 0.0,
        calib_batches: 2,
        replicas,
        queue_depth,
        batch_window: Duration::from_millis(1),
        obs,
        ..PoolConfig::default()
    }
}

/// 8 threads hammering one counter and one histogram: the final
/// snapshot must be exact, not approximately right.
#[test]
fn concurrent_registry_updates_have_deterministic_snapshot() {
    let reg = Arc::new(MetricsRegistry::new());
    let c = reg.counter("bskmq_test_total");
    let h = reg.histogram("bskmq_test_ms", &[1.0, 10.0, 100.0]);
    let threads = 8usize;
    let per = 10_000usize;
    std::thread::scope(|s| {
        for _ in 0..threads {
            let c = c.clone();
            let h = h.clone();
            s.spawn(move || {
                for i in 0..per {
                    c.inc();
                    // cycle the three buckets + overflow deterministically
                    h.observe([0.5, 5.0, 50.0, 500.0][i % 4]);
                }
            });
        }
    });
    let total = (threads * per) as u64;
    assert_eq!(c.get(), total);
    let snap = h.snapshot();
    assert_eq!(snap.count, total);
    assert_eq!(snap.counts, vec![total / 4; 4]);
    // fixed-point sum: (0.5 + 5 + 50 + 500) * 1000 per 4 observes
    let want_scaled = (threads * per / 4) as u64 * 555_500;
    assert_eq!(snap.sum_scaled, want_scaled);
}

#[test]
fn histogram_snapshot_merge_is_associative() {
    let bounds = [1.0, 2.0, 4.0];
    let mk = |vals: &[f64]| {
        let h = Histogram::new(&bounds);
        for &v in vals {
            h.observe(v);
        }
        h.snapshot()
    };
    let a = mk(&[0.5, 1.5, 8.0]);
    let b = mk(&[3.0, 3.5]);
    let c = mk(&[0.1, 0.2, 0.3, 9.0]);

    let mut left = a.clone();
    left.merge(&b).unwrap();
    left.merge(&c).unwrap();

    let mut bc = b.clone();
    bc.merge(&c).unwrap();
    let mut right = a.clone();
    right.merge(&bc).unwrap();

    assert_eq!(left.counts, right.counts);
    assert_eq!(left.count, right.count);
    assert_eq!(left.sum_scaled, right.sum_scaled);
    assert_eq!(left.count, 9);
    // mismatched bounds must refuse to merge, not silently mangle
    let other = Histogram::new(&[1.0]).snapshot();
    assert!(left.merge(&other).is_err());
}

/// merge_from folds counters and both latency rings; the merged stats
/// expose p999 (and the summary line prints it).
#[test]
fn server_stats_merge_and_p999() {
    let a = ServerStats::default();
    let b = ServerStats::default();
    for us in 1..=500u64 {
        a.record_batch(1, 4, us * 10);
        a.record_queue_wait(us);
    }
    for us in 501..=1000u64 {
        b.record_batch(1, 4, us * 10);
        b.record_queue_wait(us);
    }
    a.merge_from(&b);
    assert_eq!(a.requests.load(Ordering::SeqCst), 1000);
    let p = a.percentiles_ms(&[0.5, 0.999]);
    // 1000 samples of 10..=10000 us: p50 ~ 5ms, p999 ~ 10ms
    assert!((p[0] - 5.0).abs() < 0.1, "p50 {}", p[0]);
    assert!(p[1] > 9.9 && p[1] <= 10.0, "p999 {}", p[1]);
    let qw = a.queue_percentiles_ms(&[0.999]);
    assert!(qw[0] > 0.99 && qw[0] <= 1.0, "queue p999 {}", qw[0]);
    assert!(a.summary().contains("p999="), "{}", a.summary());
}

/// Every admitted request produces exactly one closed span, every span
/// is emitted (sampling 1:1 here), and span ids never repeat.
#[test]
fn every_admitted_request_closes_exactly_one_span() {
    let dir = fresh_dir("spans", &["resnet"]);
    let sink = TraceSink::memory();
    let cfg = obs_cfg(
        2,
        256,
        ObsConfig {
            trace_sample_every: 1,
            trace_sink: Some(sink.clone()),
            ..ObsConfig::default()
        },
    );
    let mut pool =
        ModelPool::start(dir.clone(), "resnet".to_string(), &cfg).unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let elems: usize = data.x_test.shape[1..].iter().product();
    let total = 48usize;
    std::thread::scope(|s| {
        for t in 0..6 {
            let client = pool.client();
            let x = data.x_test.data[..elems].to_vec();
            s.spawn(move || {
                for r in 0..total / 6 {
                    let mut xi = x.clone();
                    xi[0] += (t * 100 + r) as f32 * 1e-3;
                    client.infer(xi).unwrap();
                }
            });
        }
    });
    pool.shutdown();
    let tr = pool.tracer();
    assert_eq!(tr.opened(), total as u64, "span opened per admission");
    assert_eq!(tr.closed(), total as u64, "span closed per reply");
    assert_eq!(tr.emitted(), total as u64, "1:1 sampling emits all");
    let lines = sink.lines();
    assert_eq!(lines.len(), total);
    let mut ids = std::collections::HashSet::new();
    for line in &lines {
        let j = Json::parse(line).unwrap();
        assert!(ids.insert(j.get("id").unwrap().as_usize().unwrap()));
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "resnet");
        j.get("queue_us").unwrap().as_f64().unwrap();
        j.get("forward_us").unwrap().as_f64().unwrap();
    }
}

/// Rejected submissions roll their span back: opened == closed ==
/// admitted, and admitted + rejected == attempted.
#[test]
fn rejected_requests_open_no_spans() {
    let dir = fresh_dir("reject", &["resnet"]);
    let cfg = obs_cfg(1, 1, ObsConfig::default());
    let mut pool =
        ModelPool::start(dir.clone(), "resnet".to_string(), &cfg).unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let elems: usize = data.x_test.shape[1..].iter().product();
    let client = pool.client();
    let attempts = 512usize;
    let mut accepted = 0u64;
    let mut kept = Vec::new();
    for _ in 0..attempts {
        // receivers are kept so accepted requests are answered, not
        // dropped; rejected ones error immediately
        if let Ok(rx) = client.submit(data.x_test.data[..elems].to_vec()) {
            accepted += 1;
            kept.push(rx);
        }
    }
    for rx in &kept {
        let _ = rx.recv();
    }
    pool.shutdown();
    let rejected = pool.rejected();
    assert!(rejected > 0, "depth-1 queue under a 512 burst must reject");
    assert_eq!(accepted + rejected, attempts as u64);
    assert_eq!(pool.tracer().opened(), accepted);
    assert_eq!(pool.tracer().closed(), accepted);
}

/// Saturation rates on a layer driven into clipping: values pinned
/// outside the codebook range land in the boundary bins exactly.
#[test]
fn saturation_rate_is_exact_on_clipped_layer() {
    let book = Codebook::from_centers(&[0.0, 1.0, 2.0, 3.0]);
    let health = QuantHealth::new(
        &["clip".to_string()],
        std::slice::from_ref(&book),
        None,
        0,
    );
    // 8 under-range, 1 mid, 1 over-range
    let mut vals = vec![-10.0f32; 8];
    vals.push(1.0);
    vals.push(100.0);
    health.observe(0, &vals);
    let occ = health.occupancy(0);
    assert_eq!(occ, vec![8, 1, 0, 1]);
    let (low, high) = health.saturation(0);
    assert!((low - 0.8).abs() < 1e-12, "low {low}");
    assert!((high - 0.1).abs() < 1e-12, "high {high}");
    assert_eq!(health.observed(0), 10);
}

/// The live-vs-calibration sketch divergence must move when the serving
/// distribution shifts away from what Algorithm 1 calibrated on.
#[test]
fn sketch_divergence_moves_under_distribution_shift() {
    let book = Codebook::from_centers(&[0.0, 0.25, 0.5, 0.75, 1.0]);
    // calibration-time sketch over a [0, 1) ramp
    let mut calib = health_sketch();
    for i in 0..4096 {
        calib.insert((i % 1000) as f64 / 1000.0);
    }
    let names = ["act".to_string()];
    let mk = || {
        QuantHealth::new(
            &names,
            std::slice::from_ref(&book),
            Some(std::slice::from_ref(&calib)),
            1,
        )
    };

    // same distribution live: divergence stays near zero
    let same = mk();
    let live_same: Vec<f32> =
        (0..4096).map(|i| (i % 1000) as f32 / 1000.0).collect();
    same.observe(0, &live_same);
    let d_same = same.divergence(0).expect("calibrated layer diverges");

    // shifted distribution live: every decile moves by ~2 ranges
    let shifted = mk();
    let live_shift: Vec<f32> =
        (0..4096).map(|i| 2.0 + (i % 1000) as f32 / 1000.0).collect();
    shifted.observe(0, &live_shift);
    let d_shift = shifted.divergence(0).expect("calibrated layer diverges");

    assert!(d_same < 0.05, "matched distribution, divergence {d_same}");
    assert!(d_shift > 1.0, "shifted distribution, divergence {d_shift}");
    assert!(d_shift > 10.0 * d_same.max(1e-6));

    // uncalibrated health has nothing to diff against
    let bare = QuantHealth::new(
        &names,
        std::slice::from_ref(&book),
        None,
        1,
    );
    bare.observe(0, &live_same);
    assert!(bare.divergence(0).is_none());
}

/// End-to-end exposition: after serving traffic, the pool's Prometheus
/// page carries the request counters, latency histograms and per-qlayer
/// health series, and the `stats` JSON parses with matching counts.
#[test]
fn pool_prometheus_and_stats_json_expose_health_series() {
    let dir = fresh_dir("prom", &["resnet"]);
    let cfg = obs_cfg(1, 64, ObsConfig::default());
    let mut pool =
        ModelPool::start(dir.clone(), "resnet".to_string(), &cfg).unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let elems: usize = data.x_test.shape[1..].iter().product();
    let n = 12usize;
    for i in 0..n {
        let mut x = data.x_test.data[..elems].to_vec();
        x[0] += i as f32 * 1e-3;
        pool.infer(x).unwrap();
    }
    pool.shutdown();

    let health = pool.quant_health().expect("native backend has hooks");
    assert!(health.num_layers() > 0);
    assert!(health.observed(0) > 0, "serving traffic reached telemetry");

    let mut w = PromWriter::new();
    pool.render_prometheus(&mut w);
    let page = w.finish();
    for series in [
        "bskmq_requests_total{model=\"resnet\"}",
        "bskmq_rejected_total",
        "bskmq_latency_ms",
        "bskmq_forward_latency_ms_bucket",
        "bskmq_queue_wait_ms_bucket",
        "bskmq_level_occupancy_total",
        "bskmq_saturation_rate",
        "bskmq_activations_observed_total",
        "bskmq_spans_opened_total",
    ] {
        assert!(page.contains(series), "missing {series} in:\n{page}");
    }
    // every HELP/TYPE header appears exactly once per family
    let headers: Vec<&str> = page
        .lines()
        .filter(|l| l.starts_with("# TYPE "))
        .collect();
    let mut uniq = std::collections::HashSet::new();
    for h in &headers {
        assert!(uniq.insert(*h), "duplicate family header {h}");
    }

    let j = Json::parse(&pool.stats_json()).unwrap();
    assert_eq!(j.get("model").unwrap().as_str().unwrap(), "resnet");
    assert_eq!(j.get("requests").unwrap().as_usize().unwrap(), n);
    assert_eq!(
        j.get("spans").unwrap().get("opened").unwrap().as_usize().unwrap(),
        n
    );
    j.get("latency_ms").unwrap().get("p999").unwrap().as_f64().unwrap();
    j.get("queue_wait_ms").unwrap().get("p50").unwrap().as_f64().unwrap();
}
