//! Differential fuzz harness for the vectorized IMC hot path
//! (DESIGN.md §12): random tile shapes, ladders, sparsity and noise
//! settings, asserting the fused/vectorized kernels are **bit-identical**
//! to the frozen scalar reference kernels in `ops::reference` — under
//! both the forced-scalar fallback and the runtime-dispatched SIMD path.
//!
//! CI runs this suite at `BSKMQ_THREADS` 1 and 8, so the parity claim
//! also covers the deterministic row partitioning.

use std::sync::Mutex;

use bskmq::backend::native::exec_pool;
use bskmq::backend::native::ops::{
    self, bias_relu_convert_into, bias_relu_convert_into_with_lut,
    floor_adc, nl_convert_into, tiled_mac_into, tiled_mac_into_with_lut,
    AdcLut, ConvertSpec,
};
use bskmq::backend::native::simd;
use bskmq::quant::codebook::Codebook;
use bskmq::tensor::Tensor;

/// Serializes `force_scalar` toggles across this binary's test threads
/// (the flag is process-global; both settings produce identical bits,
/// so the lock only keeps each assertion's label honest).
static FORCE_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` twice — forced-scalar, then runtime-dispatched — and return
/// both results for bitwise comparison.
fn scalar_and_simd<R>(mut f: impl FnMut() -> R) -> (R, R) {
    let _g = FORCE_LOCK.lock().unwrap();
    simd::force_scalar(true);
    let a = f();
    simd::force_scalar(false);
    let b = f();
    (a, b)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// Tiny deterministic generator for fuzz inputs (the kernels' own RNG
/// stays reserved for conversion noise).
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 16
    }

    fn pick(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() % (hi - lo + 1) as u64) as usize
    }

    fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        let u = (self.next() % (1 << 24)) as f32 / (1u64 << 24) as f32;
        lo + (hi - lo) * u
    }
}

/// A random padded ladder: 2..=16 sorted centers (duplicates allowed —
/// k-means pads empty clusters that way), padded to a random capacity.
fn random_ladder(g: &mut Lcg) -> (Vec<f32>, Vec<f32>) {
    let levels = g.pick(2, 16);
    let mut centers = Vec::with_capacity(levels);
    let mut c = g.f32(-30.0, 0.0) as f64;
    for _ in 0..levels {
        centers.push(c);
        c += g.f32(0.0, 8.0) as f64; // 0-width steps = duplicates
    }
    let pad = levels + g.pick(0, 16);
    Codebook::from_centers(&centers).padded(pad)
}

fn random_x(g: &mut Lcg, len: usize) -> Vec<f32> {
    (0..len)
        .map(|_| {
            if g.pick(0, 9) < 3 {
                0.0 // exercise the `a != 0.0` skip
            } else {
                g.f32(-2.0, 2.0)
            }
        })
        .collect()
}

#[test]
fn fuzz_tiled_mac_bit_identical_to_reference() {
    let mut g = Lcg(0x5eed_0001);
    for iter in 0..40 {
        let m = g.pick(1, 9);
        let k = g.pick(1, 70);
        let n = g.pick(1, 40);
        let tile_k = [1, 3, 16, 256][g.pick(0, 3)];
        let x = random_x(&mut g, m * k);
        let w = Tensor::new(
            vec![k, n],
            (0..k * n).map(|_| g.f32(-1.0, 1.0)).collect(),
        )
        .unwrap();
        let (t_refs, t_centers) = random_ladder(&mut g);
        let sigma = if iter % 2 == 0 { 0.0 } else { g.f32(0.05, 0.8) };
        let spec = ConvertSpec {
            refs: &t_refs,
            centers: &t_centers,
            sigma,
            seed: g.next(),
        };
        for quant in [None, Some(&spec)] {
            let mut want = vec![0f32; m * n];
            let wmax = ops::reference::tiled_mac_into(
                &x, m, k, &w, tile_k, quant, &mut want,
            );
            let ((smax, sout), (vmax, vout)) = scalar_and_simd(|| {
                let mut out = vec![0f32; m * n];
                let mx = tiled_mac_into(&x, m, k, &w, tile_k, quant, &mut out);
                (mx, out)
            });
            let tag = format!(
                "iter {iter} m {m} k {k} n {n} tile {tile_k} quant {} \
                 sigma {sigma}",
                quant.is_some()
            );
            assert_eq!(bits(&sout), bits(&want), "scalar vs ref: {tag}");
            assert_eq!(bits(&vout), bits(&want), "simd vs ref: {tag}");
            assert_eq!(smax.to_bits(), wmax.to_bits(), "absmax scalar: {tag}");
            assert_eq!(vmax.to_bits(), wmax.to_bits(), "absmax simd: {tag}");
        }
    }
}

#[test]
fn fuzz_fused_epilogue_bit_identical_to_reference() {
    let mut g = Lcg(0x5eed_0002);
    for iter in 0..60 {
        let rows = g.pick(1, 24);
        let cols = g.pick(1, 50);
        let y0 = random_x(&mut g, rows * cols);
        let bias: Vec<f32> = (0..cols).map(|_| g.f32(-3.0, 3.0)).collect();
        let relu = iter % 2 == 0;
        let sigma = if iter % 3 == 0 { 0.0 } else { g.f32(0.05, 0.9) };
        let (refs, centers) = random_ladder(&mut g);
        let seed = g.next();
        let mut want = y0.clone();
        ops::reference::bias_relu_convert_into(
            &mut want, rows, cols, &bias, relu, &refs, &centers, sigma, seed,
        );
        let (sout, vout) = scalar_and_simd(|| {
            let mut out = y0.clone();
            bias_relu_convert_into(
                &mut out, rows, cols, &bias, relu, &refs, &centers, sigma,
                seed,
            );
            out
        });
        let tag = format!("iter {iter} rows {rows} cols {cols} relu {relu}");
        assert_eq!(bits(&sout), bits(&want), "scalar vs ref: {tag}");
        assert_eq!(bits(&vout), bits(&want), "simd vs ref: {tag}");
    }
}

#[test]
fn fuzz_nl_convert_bit_identical_to_reference() {
    let mut g = Lcg(0x5eed_0003);
    for iter in 0..60 {
        let rows = g.pick(1, 24);
        let cols = g.pick(1, 50);
        let y0 = random_x(&mut g, rows * cols);
        let sigma = if iter % 3 == 0 { 0.0 } else { g.f32(0.05, 0.9) };
        let (refs, centers) = random_ladder(&mut g);
        let seed = g.next();
        let mut want = y0.clone();
        ops::reference::nl_convert_into(
            &mut want, rows, cols, &refs, &centers, sigma, seed,
        );
        let (sout, vout) = scalar_and_simd(|| {
            let mut out = y0.clone();
            nl_convert_into(&mut out, rows, cols, &refs, &centers, sigma, seed);
            out
        });
        let tag = format!("iter {iter} rows {rows} cols {cols} sigma {sigma}");
        assert_eq!(bits(&sout), bits(&want), "scalar vs ref: {tag}");
        assert_eq!(bits(&vout), bits(&want), "simd vs ref: {tag}");
    }
}

/// Executor-pool extension of the fuzz harness (DESIGN.md §14): the
/// same random tiles run through the persistent pool and the per-op
/// scoped-spawn path, at thread budgets 1 and 8, stay bit-identical to
/// the frozen scalar reference — and the cached-`AdcLut` kernel forms
/// (`_with_lut`, the zero-alloc steady-state entry points) match their
/// allocating wrappers exactly.
#[test]
fn fuzz_pool_and_cached_lut_bit_identical_to_reference() {
    let _g_lock = FORCE_LOCK.lock().unwrap();
    let mut g = Lcg(0x5eed_0005);
    for iter in 0..20 {
        let m = g.pick(1, 24);
        let k = g.pick(1, 70);
        let n = g.pick(1, 40);
        let tile_k = [1, 3, 16, 256][g.pick(0, 3)];
        let x = random_x(&mut g, m * k);
        let w = Tensor::new(
            vec![k, n],
            (0..k * n).map(|_| g.f32(-1.0, 1.0)).collect(),
        )
        .unwrap();
        let (t_refs, t_centers) = random_ladder(&mut g);
        let sigma = if iter % 2 == 0 { 0.0 } else { g.f32(0.05, 0.8) };
        let spec = ConvertSpec {
            refs: &t_refs,
            centers: &t_centers,
            sigma,
            seed: g.next(),
        };
        let lut = AdcLut::new(&t_refs, &t_centers);
        let mut want = vec![0f32; m * n];
        let wmax = ops::reference::tiled_mac_into(
            &x, m, k, &w, tile_k, Some(&spec), &mut want,
        );

        for threads in [1usize, 8] {
            ops::set_thread_override(Some(threads));
            for spawn in [true, false] {
                exec_pool::force_spawn(spawn);
                let mut out = vec![0f32; m * n];
                let mx = tiled_mac_into_with_lut(
                    &x, m, k, &w, tile_k, Some(&spec), Some(&lut), &mut out,
                );
                let tag = format!(
                    "iter {iter} threads {threads} {}",
                    if spawn { "scoped spawn" } else { "executor pool" }
                );
                assert_eq!(bits(&out), bits(&want), "pool parity: {tag}");
                assert_eq!(mx.to_bits(), wmax.to_bits(), "absmax: {tag}");
            }
        }
        exec_pool::force_spawn(false);
        ops::set_thread_override(None);

        // cached-LUT epilogue vs its allocating wrapper on the mac output
        let bias: Vec<f32> = (0..n).map(|_| g.f32(-3.0, 3.0)).collect();
        let (e_refs, e_centers) = random_ladder(&mut g);
        let e_lut = AdcLut::new(&e_refs, &e_centers);
        let e_seed = g.next();
        let relu = iter % 2 == 0;
        let mut ew = want.clone();
        bias_relu_convert_into(
            &mut ew, m, n, &bias, relu, &e_refs, &e_centers, sigma, e_seed,
        );
        let mut eg = want.clone();
        bias_relu_convert_into_with_lut(
            &mut eg, m, n, &bias, relu, &e_lut, sigma, e_seed,
        );
        assert_eq!(
            bits(&eg),
            bits(&ew),
            "cached-LUT epilogue diverged from wrapper: iter {iter}"
        );
    }
}

#[test]
fn fuzz_adc_lut_exact_on_random_ladders() {
    let mut g = Lcg(0x5eed_0004);
    for iter in 0..200 {
        let (refs, centers) = random_ladder(&mut g);
        let adc = AdcLut::new(&refs, &centers);
        let mut probes: Vec<f32> =
            vec![f32::NEG_INFINITY, f32::NAN, -1e30, 1e30, 0.0, -0.0];
        for &r in refs.iter().filter(|r| r.is_finite()) {
            probes.push(r);
            probes.push(r - f32::EPSILON * r.abs().max(1.0));
            probes.push(r + f32::EPSILON * r.abs().max(1.0));
        }
        for _ in 0..50 {
            probes.push(g.f32(-60.0, 120.0));
        }
        for &p in &probes {
            let want = floor_adc(&refs, &centers, p);
            let got = adc.convert(p);
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "iter {iter} probe {p} refs {refs:?}"
            );
        }
    }
}
