//! Integration tests over the full runtime pipeline: backend ->
//! artifacts -> calibration -> PTQ -> server.  These require
//! `make artifacts` to have run (they are the rust half of the paper's
//! software evaluation) — they self-skip when artifacts are missing so
//! plain `cargo test` works in a fresh checkout.  The backend follows
//! `BSKMQ_BACKEND` (default auto: XLA when compiled in, native else);
//! `backend_native.rs` covers the native engine on synthetic artifacts
//! without any of this gating.

use bskmq::backend::{load, Backend, BackendKind};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::coordinator::ptq::PtqEvaluator;
use bskmq::coordinator::pool::InferenceServer;
use bskmq::data::dataset::ModelData;
use bskmq::quant::{Method, QuantSpec};

fn artifacts_ready() -> Option<std::path::PathBuf> {
    let dir = bskmq::artifacts_dir();
    if dir.join("resnet_manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: run `make artifacts` first");
        None
    }
}

fn backend_for(dir: &std::path::Path, model: &str) -> Box<dyn Backend> {
    load(BackendKind::from_env(), dir, model).unwrap()
}

#[test]
fn collect_graph_layout_matches_manifest() {
    let Some(dir) = artifacts_ready() else { return };
    let be = backend_for(&dir, "resnet");
    let data = ModelData::load(&dir, "resnet").unwrap();
    let m = be.manifest();
    let out = be
        .run_collect(ModelData::batch(&data.x_calib, 0, m.batch))
        .unwrap();
    assert_eq!(out.samples.len(), m.nq());
    assert_eq!(out.tile_max.len(), m.nq());
    assert_eq!(out.logits.len(), m.batch * m.num_classes);
    // ReLU'd layers must produce non-negative samples
    for (i, q) in m.qlayers.iter().enumerate() {
        if q.relu {
            assert!(
                out.samples[i].iter().all(|&v| v >= 0.0),
                "layer {} marked relu has negative activations",
                q.name
            );
        }
        assert!(out.tile_max[i] > 0.0, "tile max of {} is zero", q.name);
    }
}

#[test]
fn calibrate_then_ptq_beats_linear_at_3_bits() {
    let Some(dir) = artifacts_ready() else { return };
    let be = backend_for(&dir, "resnet");
    let data = ModelData::load(&dir, "resnet").unwrap();
    let ev = PtqEvaluator::new(be.as_ref());
    let bs = Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::BsKmq, 3))
        .calibrate(&data, 8)
        .unwrap();
    let lin = Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::Linear, 3))
        .calibrate(&data, 8)
        .unwrap();
    let acc_bs = ev
        .evaluate(&data, &bs.programmed, 0.0, 4, 1)
        .unwrap()
        .accuracy;
    let acc_lin = ev
        .evaluate(&data, &lin.programmed, 0.0, 4, 1)
        .unwrap()
        .accuracy;
    // the paper's headline: BS-KMQ dramatically beats linear at 3 bits
    assert!(
        acc_bs > acc_lin + 0.10,
        "bs_kmq {acc_bs} should beat linear {acc_lin} by >10 pts"
    );
    assert!(acc_bs > 0.8, "bs_kmq PTQ collapsed: {acc_bs}");
}

#[test]
fn noise_injection_degrades_gracefully() {
    let Some(dir) = artifacts_ready() else { return };
    let be = backend_for(&dir, "resnet");
    let data = ModelData::load(&dir, "resnet").unwrap();
    let ev = PtqEvaluator::new(be.as_ref());
    let bs = Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::BsKmq, 4))
        .calibrate(&data, 8)
        .unwrap();
    let clean = ev
        .evaluate(&data, &bs.programmed, 0.0, 4, 9)
        .unwrap()
        .accuracy;
    let noisy = ev
        .evaluate(&data, &bs.programmed, 0.11, 4, 9)
        .unwrap()
        .accuracy;
    let destroyed = ev
        .evaluate(&data, &bs.programmed, 8.0, 4, 9)
        .unwrap()
        .accuracy;
    assert!(noisy >= clean - 0.08, "TT noise too destructive: {clean} -> {noisy}");
    assert!(
        destroyed < clean - 0.2,
        "extreme noise should hurt: {clean} -> {destroyed}"
    );
}

#[test]
fn weight_quantization_small_loss_at_2bit() {
    let Some(dir) = artifacts_ready() else { return };
    let be = backend_for(&dir, "resnet");
    let data = ModelData::load(&dir, "resnet").unwrap();
    let bs = Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::BsKmq, 3))
        .calibrate(&data, 8)
        .unwrap();
    let ev = PtqEvaluator::new(be.as_ref());
    let base = ev
        .evaluate(&data, &bs.programmed, 0.0, 4, 2)
        .unwrap()
        .accuracy;
    // mini models have ~500x fewer params than the paper's ResNet-18, so
    // 4-bit is their iso-accuracy point of the paper's 2-bit (sweep in
    // EXPERIMENTS.md); lower precisions must degrade monotonically, not
    // catastrophically at 4b.
    for (bits, floor) in [(4u32, base - 0.05), (3, 0.45), (2, 0.15)] {
        let wq = ev.quantize_weights(bits).unwrap();
        // deployment order: calibrate ON the quantized-weight hardware
        let books = Calibrator::with_uniform(wq.as_ref(), QuantSpec::new(Method::BsKmq, 3))
            .calibrate(&data, 8)
            .unwrap();
        let evw = PtqEvaluator::new(wq.as_ref());
        let quant = evw
            .evaluate(&data, &books.programmed, 0.0, 4, 2)
            .unwrap()
            .accuracy;
        assert!(
            quant >= floor,
            "{bits}-bit weights too destructive: {base} -> {quant}"
        );
    }
}

#[test]
fn server_batches_and_answers() {
    let Some(dir) = artifacts_ready() else { return };
    let server = InferenceServer::start(
        dir.clone(),
        "resnet".into(),
        BackendKind::from_env(),
        Some(QuantSpec::new(Method::BsKmq, 3)),
        0.0,
        4,
    )
    .unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let in_elems: usize = data.x_test.shape[1..].iter().product();
    // fire a few requests and check logits shape + determinism of shape
    for i in 0..5 {
        let x = data.x_test.data[i * in_elems..(i + 1) * in_elems].to_vec();
        let logits = server.infer(x).unwrap();
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
    let stats = server.stats.summary();
    assert!(stats.contains("requests=5"), "{stats}");
    assert!(stats.contains("p50="), "{stats}");
}

#[test]
fn all_four_models_run_qfwd() {
    let Some(dir) = artifacts_ready() else { return };
    for model in ["resnet", "vgg", "inception", "distilbert"] {
        let be = backend_for(&dir, model);
        let data = ModelData::load(&dir, model).unwrap();
        let calib = Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::BsKmq, 4))
            .calibrate(&data, 2)
            .unwrap();
        let ev = PtqEvaluator::new(be.as_ref());
        let r = ev
            .evaluate(&data, &calib.programmed, 0.0, 1, 3)
            .unwrap();
        assert_eq!(r.samples, be.manifest().batch, "{model}");
        assert!(r.accuracy.is_finite());
    }
}

/// Acceptance: with real artifacts present, the native integer backend's
/// quantized forward agrees with the XLA engine's to within codebook
/// quantization tolerance (only meaningful with `--features xla`).
#[cfg(feature = "xla")]
#[test]
fn native_agrees_with_xla_qfwd() {
    let Some(dir) = artifacts_ready() else { return };
    let native = load(BackendKind::Native, &dir, "resnet").unwrap();
    let xla = match load(BackendKind::Xla, &dir, "resnet") {
        Ok(b) => b,
        Err(e) => {
            eprintln!("SKIP: xla backend unavailable ({e:#})");
            return;
        }
    };
    let data = ModelData::load(&dir, "resnet").unwrap();
    let calib = Calibrator::with_uniform(native.as_ref(), QuantSpec::new(Method::BsKmq, 3))
        .calibrate(&data, 8)
        .unwrap();
    let m = native.manifest();
    let xb = ModelData::batch(&data.x_test, 0, m.batch);
    let a = native.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap();
    let b = xla.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap();
    let row = bskmq::experiments::backends_agree::compare(
        "resnet",
        &a,
        &b,
        m.batch,
        m.num_classes,
    );
    // logits are themselves codebook centers; disagreements only arise
    // when float summation order crosses a floor-ADC reference
    assert!(
        row.exact >= 0.9,
        "only {:.1}% of logits identical (max|diff| {})",
        row.exact * 100.0,
        row.max_abs_diff
    );
    assert!(
        row.argmax_match >= 0.9,
        "argmax agreement {:.1}%",
        row.argmax_match * 100.0
    );
}
