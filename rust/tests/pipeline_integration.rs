//! Integration tests over the full runtime pipeline: PJRT engine ->
//! artifacts -> calibration -> PTQ -> server.  These require
//! `make artifacts` to have run (they are the rust half of the paper's
//! software evaluation) — they self-skip when artifacts are missing so
//! plain `cargo test` works in a fresh checkout.

use bskmq::coordinator::calibrate::Calibrator;
use bskmq::coordinator::ptq::PtqEvaluator;
use bskmq::coordinator::server::InferenceServer;
use bskmq::data::dataset::ModelData;
use bskmq::quant::Method;
use bskmq::runtime::engine::Engine;
use bskmq::runtime::model::ModelRuntime;

fn artifacts_ready() -> Option<std::path::PathBuf> {
    let dir = bskmq::artifacts_dir();
    if dir.join("resnet_manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: run `make artifacts` first");
        None
    }
}

#[test]
fn collect_graph_layout_matches_manifest() {
    let Some(dir) = artifacts_ready() else { return };
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(&engine, &dir, "resnet").unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let out = rt
        .run_collect(ModelData::batch(&data.x_calib, 0, rt.manifest.batch))
        .unwrap();
    assert_eq!(out.samples.len(), rt.manifest.nq());
    assert_eq!(out.tile_max.len(), rt.manifest.nq());
    assert_eq!(
        out.logits.len(),
        rt.manifest.batch * rt.manifest.num_classes
    );
    // ReLU'd layers must produce non-negative samples
    for (i, q) in rt.manifest.qlayers.iter().enumerate() {
        if q.relu {
            assert!(
                out.samples[i].iter().all(|&v| v >= 0.0),
                "layer {} marked relu has negative activations",
                q.name
            );
        }
        assert!(out.tile_max[i] > 0.0, "tile max of {} is zero", q.name);
    }
}

#[test]
fn calibrate_then_ptq_beats_linear_at_3_bits() {
    let Some(dir) = artifacts_ready() else { return };
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(&engine, &dir, "resnet").unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let ev = PtqEvaluator::new(&rt);
    let bs = Calibrator::new(&rt, Method::BsKmq, 3)
        .calibrate(&data, 8)
        .unwrap();
    let lin = Calibrator::new(&rt, Method::Linear, 3)
        .calibrate(&data, 8)
        .unwrap();
    let acc_bs = ev
        .evaluate(&data, &bs.programmed, 0.0, 4, 1)
        .unwrap()
        .accuracy;
    let acc_lin = ev
        .evaluate(&data, &lin.programmed, 0.0, 4, 1)
        .unwrap()
        .accuracy;
    // the paper's headline: BS-KMQ dramatically beats linear at 3 bits
    assert!(
        acc_bs > acc_lin + 0.10,
        "bs_kmq {acc_bs} should beat linear {acc_lin} by >10 pts"
    );
    assert!(acc_bs > 0.8, "bs_kmq PTQ collapsed: {acc_bs}");
}

#[test]
fn noise_injection_degrades_gracefully() {
    let Some(dir) = artifacts_ready() else { return };
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(&engine, &dir, "resnet").unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let ev = PtqEvaluator::new(&rt);
    let bs = Calibrator::new(&rt, Method::BsKmq, 4)
        .calibrate(&data, 8)
        .unwrap();
    let clean = ev
        .evaluate(&data, &bs.programmed, 0.0, 4, 9)
        .unwrap()
        .accuracy;
    let noisy = ev
        .evaluate(&data, &bs.programmed, 0.11, 4, 9)
        .unwrap()
        .accuracy;
    let destroyed = ev
        .evaluate(&data, &bs.programmed, 8.0, 4, 9)
        .unwrap()
        .accuracy;
    assert!(noisy >= clean - 0.08, "TT noise too destructive: {clean} -> {noisy}");
    assert!(
        destroyed < clean - 0.2,
        "extreme noise should hurt: {clean} -> {destroyed}"
    );
}

#[test]
fn weight_quantization_small_loss_at_2bit() {
    let Some(dir) = artifacts_ready() else { return };
    let engine = Engine::cpu().unwrap();
    let rt = ModelRuntime::load(&engine, &dir, "resnet").unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let bs = Calibrator::new(&rt, Method::BsKmq, 3)
        .calibrate(&data, 8)
        .unwrap();
    let ev = PtqEvaluator::new(&rt);
    let base = ev
        .evaluate(&data, &bs.programmed, 0.0, 4, 2)
        .unwrap()
        .accuracy;
    // mini models have ~500x fewer params than the paper's ResNet-18, so
    // 4-bit is their iso-accuracy point of the paper's 2-bit (sweep in
    // EXPERIMENTS.md); lower precisions must degrade monotonically, not
    // catastrophically at 4b.
    for (bits, floor) in [(4u32, base - 0.05), (3, 0.45), (2, 0.15)] {
        let wq = ev.quantize_weights(bits).unwrap();
        // deployment order: calibrate ON the quantized-weight hardware
        let books = Calibrator::new(&wq, Method::BsKmq, 3)
            .calibrate(&data, 8)
            .unwrap();
        let evw = PtqEvaluator::new(&wq);
        let quant = evw
            .evaluate(&data, &books.programmed, 0.0, 4, 2)
            .unwrap()
            .accuracy;
        assert!(
            quant >= floor,
            "{bits}-bit weights too destructive: {base} -> {quant}"
        );
    }
}

#[test]
fn server_batches_and_answers() {
    let Some(dir) = artifacts_ready() else { return };
    let server = InferenceServer::start(
        dir.clone(),
        "resnet".into(),
        Method::BsKmq,
        3,
        0.0,
        4,
    )
    .unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let in_elems: usize = data.x_test.shape[1..].iter().product();
    // fire a few requests and check logits shape + determinism of shape
    for i in 0..5 {
        let x = data.x_test.data[i * in_elems..(i + 1) * in_elems].to_vec();
        let logits = server.infer(x).unwrap();
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
    let stats = server.stats.summary();
    assert!(stats.contains("requests=5"), "{stats}");
}

#[test]
fn all_four_models_run_qfwd() {
    let Some(dir) = artifacts_ready() else { return };
    let engine = Engine::cpu().unwrap();
    for model in ["resnet", "vgg", "inception", "distilbert"] {
        let rt = ModelRuntime::load(&engine, &dir, model).unwrap();
        let data = ModelData::load(&dir, model).unwrap();
        let calib = Calibrator::new(&rt, Method::BsKmq, 4)
            .calibrate(&data, 2)
            .unwrap();
        let ev = PtqEvaluator::new(&rt);
        let r = ev
            .evaluate(&data, &calib.programmed, 0.0, 1, 3)
            .unwrap();
        assert_eq!(r.samples, rt.manifest.batch, "{model}");
        assert!(r.accuracy.is_finite());
    }
}
