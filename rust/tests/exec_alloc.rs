//! Steady-state allocation gate for the executor-pool + LayerPlan
//! tentpole (DESIGN.md §14): once the plan cache, the backend's arena
//! pool and the executor workers' thread-local scratch are warm, a
//! quantized forward performs no per-op heap allocation.  Rebuilding
//! the two `AdcLut`s per qlayer would cost ~6 Vec allocations per
//! layer per forward, and per-op scoped thread spawn hundreds (stack
//! and handle allocations per op) — either regression blows the budget
//! asserted here by an order of magnitude.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use bskmq::backend::{load, BackendKind};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::data::dataset::ModelData;
use bskmq::data::synth;
use bskmq::quant::{Method, QuantSpec};

/// Counts every allocation in the process (all threads, pool workers
/// included), so per-op churn on worker threads cannot hide.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_qfwd_allocates_a_small_constant() {
    let dir = std::env::temp_dir().join("bskmq_exec_alloc");
    let _ = std::fs::remove_dir_all(&dir);
    synth::write_model(&dir, "resnet", 42).unwrap();
    let be = load(BackendKind::Native, &dir, "resnet").unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let batch = be.manifest().batch;
    let calib =
        Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::BsKmq, 3))
            .calibrate(&data, 2)
            .unwrap();
    let xt = ModelData::batch(&data.x_test, 0, batch);

    // warm-up: builds and caches the LayerPlan, grows the arena, spawns
    // the pool workers and sizes their thread-local kernel scratch (any
    // worker may claim any row block, so several rounds are needed
    // before every worker has seen the largest block)
    for _ in 0..8 {
        be.run_qfwd(xt, &calib.programmed, 0.5, 9).unwrap();
    }

    const ITERS: u64 = 8;
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..ITERS {
        be.run_qfwd(xt, &calib.programmed, 0.5, 9).unwrap();
    }
    let per_fwd = (ALLOCS.load(Ordering::Relaxed) - before) / ITERS;

    // warm forwards allocate only the returned logits vector plus a
    // handful of bookkeeping vectors (multi-input gather lists); the
    // budget below is several times that, and far below any per-op
    // allocation pattern
    assert!(
        per_fwd <= 16,
        "steady-state forward allocates {per_fwd} times per run — per-op \
         allocation crept back into the hot path"
    );
}
