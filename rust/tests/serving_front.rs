//! TCP front suite over synthetic artifacts: the event front must be
//! byte-identical to the threaded oracle on the full protocol surface
//! (logits, routing errors, parse errors, admission errors), preserve
//! per-connection reply order under deep pipelining, and expose the
//! stats/metrics commands.
//!
//! The agreement test is the acceptance gate for DESIGN.md §13: both
//! fronts serve the *same* registry back to back, so any byte of
//! divergence is the front's fault, not the pools'.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use bskmq::backend::BackendKind;
use bskmq::coordinator::front::{FrontKind, ServeFront};
use bskmq::coordinator::pool::{ModelRegistry, PoolConfig};
use bskmq::data::dataset::ModelData;
use bskmq::data::synth;
use bskmq::quant::{Method, QuantSpec};

const UNIQUE_INPUTS: usize = 8;

fn fresh_dir(tag: &str, models: &[&str]) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bskmq_front_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    for m in models {
        synth::write_model(&dir, m, 42).unwrap();
    }
    dir
}

fn native_cfg(replicas: usize, queue_depth: usize) -> PoolConfig {
    PoolConfig {
        backend: BackendKind::Native,
        spec: Some(QuantSpec::new(Method::BsKmq, 3)),
        noise_std: 0.0,
        calib_batches: 2,
        replicas,
        queue_depth,
        batch_window: Duration::from_millis(1),
        ..PoolConfig::default()
    }
}

fn unique_inputs(dir: &std::path::Path, model: &str) -> Vec<Vec<f32>> {
    let data = ModelData::load(dir, model).unwrap();
    let elems: usize = data.x_test.shape[1..].iter().product();
    (0..UNIQUE_INPUTS)
        .map(|i| data.x_test.data[i * elems..(i + 1) * elems].to_vec())
        .collect()
}

fn spawn_front(registry: &Arc<ModelRegistry>, kind: FrontKind) -> ServeFront {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    ServeFront::spawn(registry.clone(), listener, kind).unwrap()
}

/// One protocol line per float vector (`f32::to_string` round-trips
/// exactly through the front's parser).
fn infer_line(x: &[f32]) -> String {
    let s: Vec<String> = x.iter().map(|v| v.to_string()).collect();
    s.join(",")
}

/// The front's logits formatting, duplicated here so the pipelining
/// test can predict exact reply bytes.
fn logits_line(logits: &[f32]) -> String {
    let s: Vec<String> = logits.iter().map(|v| format!("{v:.6}")).collect();
    format!("{}\n", s.join(","))
}

/// Write every line pipelined, then read exactly `replies` reply lines.
fn run_script(addr: SocketAddr, lines: &[String], replies: usize) -> Vec<String> {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let mut out = stream.try_clone().unwrap();
    let mut payload = String::new();
    for l in lines {
        payload.push_str(l);
        payload.push('\n');
    }
    out.write_all(payload.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream);
    let mut got = Vec::with_capacity(replies);
    for i in 0..replies {
        let mut s = String::new();
        reader
            .read_line(&mut s)
            .unwrap_or_else(|e| panic!("reply {i} never arrived: {e}"));
        assert!(!s.is_empty(), "connection closed before reply {i}");
        got.push(s);
    }
    got
}

/// Acceptance: the event front's replies are byte-identical to the
/// threaded oracle across the whole protocol surface — logits, empty
/// lines, model routing, unknown-model errors, float parse errors, and
/// admission (wrong size) errors — served by the *same* registry.
#[test]
fn event_and_threaded_fronts_agree_bytewise() {
    let dir = fresh_dir("agree", &["resnet", "vgg"]);
    let models = vec!["resnet".to_string(), "vgg".to_string()];
    let registry = Arc::new(
        ModelRegistry::start(&dir, &models, &native_cfg(2, 1024)).unwrap(),
    );
    let resnet = unique_inputs(&dir, "resnet");
    let vgg = unique_inputs(&dir, "vgg");

    let script: Vec<String> = vec![
        infer_line(&resnet[0]),
        String::new(), // empty line: no reply
        format!("vgg:{}", infer_line(&vgg[1])),
        format!("resnet:{}", infer_line(&resnet[2])),
        "nosuch:1,2,3".to_string(),
        "1,2,not_a_float".to_string(),
        "1,2".to_string(), // wrong size: refused at submit
    ];
    let replies = script.len() - 1; // the empty line answers nothing

    let mut threaded = spawn_front(&registry, FrontKind::Threaded);
    let a = run_script(threaded.addr(), &script, replies);
    threaded.stop();

    // sanity on the oracle itself before pinning the event front to it
    assert_eq!(a[0].trim().split(',').count(), synth::CLASSES);
    assert!(a[3].starts_with("error: unknown model 'nosuch'"), "{}", a[3]);
    assert!(a[3].contains("resnet,vgg"), "{}", a[3]);
    assert!(a[4].starts_with("error: parsing input floats:"), "{}", a[4]);
    assert!(a[5].starts_with("error:"), "{}", a[5]);
    assert!(a[5].contains("elements"), "{}", a[5]);

    if !cfg!(target_os = "linux") {
        return; // no epoll, nothing to compare
    }
    let mut event = spawn_front(&registry, FrontKind::Event);
    let b = run_script(event.addr(), &script, replies);
    event.stop();
    assert_eq!(a, b, "event front diverged from the threaded oracle");
}

/// The event front is pipelined: a client may write many requests
/// before reading anything, and replies must come back in request
/// order — including error replies interleaved mid-stream, which the
/// front answers out of the pool's band.
#[test]
fn event_front_preserves_pipelined_reply_order() {
    if !cfg!(target_os = "linux") {
        return;
    }
    let dir = fresh_dir("pipeline", &["resnet"]);
    let models = vec!["resnet".to_string()];
    let registry = Arc::new(
        ModelRegistry::start(&dir, &models, &native_cfg(2, 4096)).unwrap(),
    );
    let inputs = unique_inputs(&dir, "resnet");

    // expected logits per unique input, via the in-process client
    let client = registry.default_pool().client();
    let expected_logits: Vec<String> = inputs
        .iter()
        .map(|x| logits_line(&client.infer(x.clone()).unwrap()))
        .collect();

    let mut script: Vec<String> = Vec::new();
    let mut expected: Vec<String> = Vec::new();
    for i in 0..60 {
        if i % 10 == 9 {
            script.push("nosuch:1".to_string());
            expected.push(
                "error: unknown model 'nosuch' (serving: resnet)\n"
                    .to_string(),
            );
        } else {
            let idx = (i * 5 + 3) % UNIQUE_INPUTS;
            script.push(infer_line(&inputs[idx]));
            expected.push(expected_logits[idx].clone());
        }
    }

    let mut front = spawn_front(&registry, FrontKind::Event);
    let got = run_script(front.addr(), &script, expected.len());
    front.stop();
    for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
        assert_eq!(g, e, "reply {i} out of order or wrong");
    }
}

/// The stats / stats --text / metrics commands answer over TCP; the
/// metrics page carries both pool series (shed counter) and the
/// front's own connection telemetry.
#[test]
fn stats_and_metrics_commands_answer_over_tcp() {
    let dir = fresh_dir("metrics", &["resnet"]);
    let models = vec!["resnet".to_string()];
    let registry = Arc::new(
        ModelRegistry::start(&dir, &models, &native_cfg(1, 256)).unwrap(),
    );
    let inputs = unique_inputs(&dir, "resnet");
    let mut front =
        spawn_front(&registry, FrontKind::default_for_platform());

    let stream = TcpStream::connect(front.addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    let mut out = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();

    out.write_all(format!("{}\n", infer_line(&inputs[0])).as_bytes())
        .unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(!line.starts_with("error:"), "{line}");

    line.clear();
    out.write_all(b"stats\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with('{'), "{line}");
    assert!(line.contains("resnet"), "{line}");

    line.clear();
    out.write_all(b"stats --text\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("backend"), "{line}");

    out.write_all(b"metrics\n").unwrap();
    let mut page = String::new();
    loop {
        line.clear();
        reader.read_line(&mut line).unwrap();
        if line == "\n" || line.is_empty() {
            break; // blank line terminates the page
        }
        page.push_str(&line);
    }
    assert!(page.contains("bskmq_requests_total"), "{page}");
    assert!(page.contains("bskmq_shed_total"), "{page}");
    assert!(page.contains("bskmq_connections"), "{page}");

    drop(out);
    drop(reader);
    front.stop();
}
