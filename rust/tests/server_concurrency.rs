//! Concurrency / agreement soak suite for the replica-pool server, on
//! synthetic artifacts (no Python, no HLO).  Pins the serving-layer
//! contract:
//!
//! * every accepted request gets exactly one reply, and the stats
//!   counters account for every one of them;
//! * logits are bit-identical regardless of replica count and thread
//!   interleaving (zero conversion noise makes the quantized forward a
//!   deterministic per-sample function);
//! * a full bounded queue rejects with an error — requests are never
//!   silently dropped and clients never hang;
//! * dropping the server while client handles are still alive shuts the
//!   pool down instead of hanging the serve loop (regression for the
//!   old mpsc-hangup Drop);
//! * past-deadline requests are shed with an explicit overload reply
//!   and counted (stats, summary, Prometheus) — including requests
//!   still queued when the pool shuts down mid-overload;
//! * queue-depth autoscaling grows and shrinks the live replica set
//!   without ever changing a single logit bit.
//!
//! CI runs this suite with `BSKMQ_THREADS` at 1 and 8 to catch
//! thread-count-dependent results.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Duration;

use bskmq::backend::BackendKind;
use bskmq::coordinator::pool::{
    AdmissionError, InferenceServer, ModelPool, ModelRegistry, PoolConfig,
};
use bskmq::data::dataset::ModelData;
use bskmq::data::synth;
use bskmq::obs::prometheus::PromWriter;
use bskmq::quant::{Method, QuantSpec};

const CLIENT_THREADS: usize = 16;
const REQS_PER_THREAD: usize = 8;
const UNIQUE_INPUTS: usize = 8;

fn fresh_dir(tag: &str, models: &[&str]) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bskmq_conc_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    for m in models {
        synth::write_model(&dir, m, 42).unwrap();
    }
    dir
}

fn native_cfg(replicas: usize, queue_depth: usize) -> PoolConfig {
    PoolConfig {
        backend: BackendKind::Native,
        spec: Some(QuantSpec::new(Method::BsKmq, 3)),
        noise_std: 0.0,
        calib_batches: 2,
        replicas,
        queue_depth,
        batch_window: Duration::from_millis(1),
        ..PoolConfig::default()
    }
}

/// Pull `UNIQUE_INPUTS` distinct test inputs out of the synthetic split.
fn unique_inputs(dir: &std::path::Path, model: &str) -> Vec<Vec<f32>> {
    let data = ModelData::load(dir, model).unwrap();
    let elems: usize = data.x_test.shape[1..].iter().product();
    (0..UNIQUE_INPUTS)
        .map(|i| data.x_test.data[i * elems..(i + 1) * elems].to_vec())
        .collect()
}

/// Soak one pool with `CLIENT_THREADS` threads and return the logits per
/// unique input, after asserting the exactly-one-reply and accounting
/// invariants.
fn soak_pool(pool: &ModelPool, inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let total = CLIENT_THREADS * REQS_PER_THREAD;
    let replies: Mutex<Vec<(usize, Vec<f32>)>> =
        Mutex::new(Vec::with_capacity(total));
    std::thread::scope(|s| {
        for t in 0..CLIENT_THREADS {
            let client = pool.client();
            let replies = &replies;
            s.spawn(move || {
                for r in 0..REQS_PER_THREAD {
                    let idx = (t * 7 + r * 3) % UNIQUE_INPUTS;
                    let rx = client
                        .submit(inputs[idx].clone())
                        .expect("queue sized for the whole soak");
                    let reply = rx
                        .recv_timeout(Duration::from_secs(120))
                        .expect("accepted request must be answered");
                    let logits =
                        reply.expect("soak request failed server-side");
                    assert_eq!(logits.len(), synth::CLASSES);
                    assert!(logits.iter().all(|v| v.is_finite()));
                    // exactly one reply: the worker dropped its sender
                    // after answering, so a second receive disconnects
                    assert!(
                        rx.recv_timeout(Duration::from_millis(200)).is_err(),
                        "request answered more than once"
                    );
                    replies.lock().unwrap().push((idx, logits));
                }
            });
        }
    });
    let replies = replies.into_inner().unwrap();
    assert_eq!(replies.len(), total, "a request went unanswered");

    // stats account for every reply, globally and per replica
    let stats_requests =
        pool.stats.requests.load(std::sync::atomic::Ordering::SeqCst);
    assert_eq!(stats_requests, total as u64, "stats lost requests");
    let per_replica: u64 = pool
        .replica_stats
        .iter()
        .map(|s| s.requests.load(std::sync::atomic::Ordering::SeqCst))
        .sum();
    assert_eq!(per_replica, total as u64, "replica stats don't add up");
    assert_eq!(pool.rejected(), 0, "sized queue must not reject");

    // bit-identical logits per input across every interleaving
    let mut by_input: HashMap<usize, Vec<f32>> = HashMap::new();
    for (idx, logits) in replies {
        match by_input.entry(idx) {
            Entry::Occupied(e) => assert_eq!(
                e.get(),
                &logits,
                "input {idx}: logits depended on batch interleaving"
            ),
            Entry::Vacant(v) => {
                v.insert(logits);
            }
        }
    }
    (0..UNIQUE_INPUTS)
        .map(|i| by_input.remove(&i).expect("every input was exercised"))
        .collect()
}

/// The headline soak: 16 client threads against replica counts 1 and 4;
/// every request answered exactly once, logits bit-identical between the
/// two pool shapes.
#[test]
fn soak_replica_counts_agree_bitwise() {
    let dir = fresh_dir("soak", &["resnet"]);
    let inputs = unique_inputs(&dir, "resnet");

    let pool1 = ModelPool::start(
        dir.clone(),
        "resnet".into(),
        &native_cfg(1, 4096),
    )
    .unwrap();
    assert_eq!(pool1.replicas(), 1);
    let logits1 = soak_pool(&pool1, &inputs);
    drop(pool1);

    let pool4 = ModelPool::start(
        dir.clone(),
        "resnet".into(),
        &native_cfg(4, 4096),
    )
    .unwrap();
    assert_eq!(pool4.replicas(), 4);
    // with >1 replica, more than one worker must have actually served
    let logits4 = soak_pool(&pool4, &inputs);
    let active = pool4
        .replica_stats
        .iter()
        .filter(|s| s.requests.load(std::sync::atomic::Ordering::SeqCst) > 0)
        .count();
    assert!(
        active >= 2,
        "only {active} of 4 replicas served any request"
    );
    drop(pool4);

    for (i, (a, b)) in logits1.iter().zip(&logits4).enumerate() {
        assert_eq!(
            a, b,
            "input {i}: replica count changed the logits bitwise"
        );
    }
}

/// Admission control: a depth-1 queue flooded from one thread must
/// reject (as immediate errors, attributable to the queue) — and every
/// *accepted* request must still be answered.  No hangs, no silent
/// drops.
#[test]
fn queue_full_rejections_surface_as_errors() {
    let dir = fresh_dir("reject", &["resnet"]);
    let inputs = unique_inputs(&dir, "resnet");
    let pool = ModelPool::start(
        dir.clone(),
        "resnet".into(),
        &native_cfg(1, 1),
    )
    .unwrap();
    let client = pool.client();

    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for i in 0..200 {
        match client.submit(inputs[i % UNIQUE_INPUTS].clone()) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                let adm = e
                    .downcast_ref::<AdmissionError>()
                    .expect("rejection must be an AdmissionError");
                assert_eq!(adm, &AdmissionError::Full { depth: 1 });
                assert!(e.to_string().contains("queue full"), "{e}");
                rejected += 1;
            }
        }
    }
    assert!(rejected > 0, "depth-1 queue never rejected a 200-burst");
    assert!(!accepted.is_empty(), "admission let nothing through");
    for rx in accepted.iter() {
        let reply = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("accepted request must be answered, not dropped");
        assert!(reply.is_ok(), "accepted request failed: {reply:?}");
    }
    assert_eq!(pool.rejected(), rejected, "rejection counter drifted");
    let served =
        pool.stats.requests.load(std::sync::atomic::Ordering::SeqCst);
    assert_eq!(served, accepted.len() as u64);
}

/// Regression (old `InferenceServer::Drop`): senders cloned via
/// `client()` used to keep the serve loop alive, hanging the join.  The
/// explicit shutdown signal must win even with live client handles.
#[test]
fn drop_with_live_clients_does_not_hang() {
    let dir = fresh_dir("drop", &["resnet"]);
    let inputs = unique_inputs(&dir, "resnet");
    let server = InferenceServer::start(
        dir.clone(),
        "resnet".into(),
        BackendKind::Native,
        Some(QuantSpec::new(Method::BsKmq, 3)),
        0.0,
        2,
    )
    .unwrap();
    let logits = server.infer(inputs[0].clone()).unwrap();
    assert_eq!(logits.len(), synth::CLASSES);

    // two live client handles on another thread outlive the server
    let c1 = server.client();
    let c2 = server.client();
    let (done_tx, done_rx) = mpsc::channel::<()>();
    std::thread::spawn(move || {
        drop(server);
        let _ = done_tx.send(());
    });
    assert!(
        done_rx.recv_timeout(Duration::from_secs(60)).is_ok(),
        "dropping the server hung while client handles were alive"
    );
    // the survivors get clean rejections, not hangs
    for c in [c1, c2] {
        let err = c.submit(inputs[0].clone()).unwrap_err();
        assert_eq!(
            err.downcast_ref::<AdmissionError>(),
            Some(&AdmissionError::Closed),
            "{err}"
        );
    }
}

/// Oversized/undersized inputs are refused at submit time with an error,
/// never enqueued.
#[test]
fn wrong_sized_input_is_an_immediate_error() {
    let dir = fresh_dir("badsize", &["resnet"]);
    let pool = ModelPool::start(
        dir.clone(),
        "resnet".into(),
        &native_cfg(1, 8),
    )
    .unwrap();
    let client = pool.client();
    let err = client.submit(vec![0.0; 3]).unwrap_err();
    assert!(err.to_string().contains("elements"), "{err}");
    assert_eq!(
        pool.stats.requests.load(std::sync::atomic::Ordering::SeqCst),
        0
    );
}

/// Acceptance: one registry serving two models with two replicas each,
/// under concurrent clients on both, with correct per-pool accounting
/// and name routing.
#[test]
fn registry_serves_two_models_with_two_replicas() {
    let dir = fresh_dir("registry", &["resnet", "vgg"]);
    let models = vec!["resnet".to_string(), "vgg".to_string()];
    let registry =
        ModelRegistry::start(&dir, &models, &native_cfg(2, 1024)).unwrap();
    assert_eq!(registry.models(), vec!["resnet", "vgg"]);
    assert!(registry.get("inception").is_none());
    assert_eq!(registry.default_pool().model, "resnet");

    let per_model = 4 * REQS_PER_THREAD;
    std::thread::scope(|s| {
        for model in ["resnet", "vgg"] {
            let inputs = unique_inputs(&dir, model);
            let pool = registry.get(model).unwrap();
            for t in 0..4 {
                let client = pool.client();
                let inputs = inputs.clone();
                s.spawn(move || {
                    for r in 0..REQS_PER_THREAD {
                        let idx = (t * 5 + r) % UNIQUE_INPUTS;
                        let logits =
                            client.infer(inputs[idx].clone()).unwrap();
                        assert_eq!(logits.len(), synth::CLASSES);
                    }
                });
            }
        }
    });
    for model in ["resnet", "vgg"] {
        let pool = registry.get(model).unwrap();
        assert_eq!(pool.engine(), "native");
        assert_eq!(pool.replicas(), 2);
        assert_eq!(
            pool.stats.requests.load(std::sync::atomic::Ordering::SeqCst),
            per_model as u64,
            "{model} lost requests"
        );
        let summary = pool.summary();
        assert!(summary.contains("r0:"), "{summary}");
        assert!(summary.contains("r1:"), "{summary}");
    }
}

/// Deadline shedding: with a zero deadline every admitted request is
/// past-due at batch assembly, so *all* of them must come back as
/// explicit overload replies — no hangs, no silent drops — and the shed
/// count must agree across `pool.shed()`, the summary line, and the
/// Prometheus page.  A pool shut down mid-overload still drains its
/// queue and answers everything before the workers exit.
#[test]
fn overload_sheds_with_explicit_replies_and_counters() {
    let dir = fresh_dir("overload", &["resnet"]);
    let inputs = unique_inputs(&dir, "resnet");
    let cfg = PoolConfig {
        request_deadline: Duration::ZERO,
        ..native_cfg(1, 4096)
    };
    let mut pool =
        ModelPool::start(dir.clone(), "resnet".into(), &cfg).unwrap();
    let client = pool.client();

    let burst = 64usize;
    let rxs: Vec<_> = (0..burst)
        .map(|i| {
            client
                .submit(inputs[i % UNIQUE_INPUTS].clone())
                .expect("queue sized for the burst")
        })
        .collect();
    for rx in rxs {
        let reply = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("shed request must still be answered");
        let err = reply.expect_err("a zero deadline cannot be met");
        assert!(err.is_overload(), "{err}");
        let msg = err.to_string();
        assert!(msg.contains("overload"), "{msg}");
        assert!(msg.contains("deadline"), "{msg}");
    }
    assert_eq!(pool.shed(), burst as u64, "shed counter drifted");
    // sheds are not served requests
    assert_eq!(
        pool.stats.requests.load(std::sync::atomic::Ordering::SeqCst),
        0,
        "shed requests leaked into the served counter"
    );

    // second burst, then shut down while it is still queued: workers
    // observe close only after the queue is drained, so every request
    // still gets its overload reply
    let rxs: Vec<_> = (0..burst)
        .map(|i| {
            client
                .submit(inputs[i % UNIQUE_INPUTS].clone())
                .expect("queue sized for the burst")
        })
        .collect();
    pool.shutdown();
    for rx in rxs {
        let reply = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("shutdown dropped a queued request");
        assert!(reply.expect_err("still past-due").is_overload());
    }
    let err = client.submit(inputs[0].clone()).unwrap_err();
    assert_eq!(
        err.downcast_ref::<AdmissionError>(),
        Some(&AdmissionError::Closed),
        "{err}"
    );

    assert_eq!(pool.shed(), 2 * burst as u64);
    let summary = pool.summary();
    assert!(summary.contains("shed=128"), "{summary}");
    let prom = {
        let mut w = PromWriter::new();
        pool.render_prometheus(&mut w);
        w.finish()
    };
    assert!(
        prom.contains("bskmq_shed_total{model=\"resnet\"} 128"),
        "{prom}"
    );
}

/// Queue-depth autoscaling between 1 and 3 replicas: sustained backlog
/// must grow the live set past one replica, every reply must be
/// bit-identical to the pre-scaling single-replica logits, and an idle
/// pool must fall back to its floor.
#[test]
fn autoscale_scales_up_and_back_down() {
    let dir = fresh_dir("autoscale", &["resnet"]);
    let inputs = unique_inputs(&dir, "resnet");
    let cfg = PoolConfig {
        max_replicas: 3,
        scale_check: Duration::from_millis(5),
        scale_up_depth: 1,
        scale_down_idle: 10,
        ..native_cfg(1, 4096)
    };
    let pool = ModelPool::start(dir.clone(), "resnet".into(), &cfg).unwrap();
    assert_eq!(pool.replicas(), 1);
    assert_eq!(pool.live_replicas(), 1);

    // reference logits before any scaling happens
    let refs: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| pool.infer(x.clone()).unwrap())
        .collect();
    let client = pool.client();

    // submit async bursts and sample liveness while each backlog
    // drains; keep the pressure up until a scale-up is observed
    let mut peak = pool.live_replicas();
    let mut served = refs.len() as u64;
    for _round in 0..50 {
        let rxs: Vec<_> = (0..128)
            .map(|i| {
                let idx = i % UNIQUE_INPUTS;
                let rx = client
                    .submit(inputs[idx].clone())
                    .expect("queue sized for the burst");
                (idx, rx)
            })
            .collect();
        for _ in 0..20 {
            peak = peak.max(pool.live_replicas());
            std::thread::sleep(Duration::from_millis(1));
        }
        for (idx, rx) in rxs {
            let reply = rx
                .recv_timeout(Duration::from_secs(120))
                .expect("request lost during scaling");
            let logits = reply.expect("request failed during scaling");
            assert_eq!(
                logits, refs[idx],
                "input {idx}: autoscaling changed the logits bitwise"
            );
            served += 1;
        }
        peak = peak.max(pool.live_replicas());
        if peak >= 2 {
            break;
        }
    }
    assert!(
        peak >= 2,
        "50 rounds of 128-deep backlog never scaled past one replica"
    );
    assert_eq!(
        pool.stats.requests.load(std::sync::atomic::Ordering::SeqCst),
        served,
        "requests lost across scale events"
    );

    // idle: the supervisor must walk the target back down to the floor
    let t0 = std::time::Instant::now();
    while pool.live_replicas() > 1 {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "pool never scaled back down to 1 (live {})",
            pool.live_replicas()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(pool.live_replicas(), 1);
}
