//! Property suite for the per-layer QuantSpec plumbing and the
//! streaming mergeable estimator contract (`quant::estimator`):
//!
//! * `merge` is order-insensitive and shard-count-invariant — 1 vs 4 vs
//!   16 shards over the same batch stream produce **bit-identical**
//!   codebooks for all five methods;
//! * streaming (chunked) observation equals buffered (one-shot)
//!   observation, and for the linear/CDF/Lloyd-Max baselines equals the
//!   legacy buffer-everything fitters exactly;
//! * specs parse/serialize through the manifest and are validated at
//!   graph compile time;
//! * the paper's 6/2/3b mixed-precision system point runs end-to-end
//!   (calibrate → PTQ → serve) on the synthetic resnet artifact, with
//!   4-shard parallel calibration bit-identical to serial.

use std::time::Duration;

use bskmq::backend::native::graph::GraphProgram;
use bskmq::backend::{load, Backend, BackendKind};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::coordinator::ptq::PtqEvaluator;
use bskmq::coordinator::pool::{ModelPool, PoolConfig};
use bskmq::data::dataset::ModelData;
use bskmq::data::synth::{self, mixture_samples};
use bskmq::io::manifest::Manifest;
use bskmq::quant::codebook::Codebook;
use bskmq::quant::{
    estimator_for, fit_cdf, fit_linear, fit_lloyd_max, Method,
    QuantEstimator, QuantSpec,
};
use bskmq::util::rng::Rng;

fn book_bits(b: &Codebook) -> (Vec<u64>, Vec<u64>) {
    (
        b.centers.iter().map(|c| c.to_bits()).collect(),
        b.refs.iter().map(|r| r.to_bits()).collect(),
    )
}

/// Stream `batches` through `shards` estimators (contiguous slices,
/// seeked to their global offsets), merge, finish.
fn shard_fit(
    spec: &QuantSpec,
    batches: &[Vec<f64>],
    shards: usize,
    bits: u32,
) -> Codebook {
    assert_eq!(batches.len() % shards, 0, "test uses even splits");
    let per = batches.len() / shards;
    let mut parts: Vec<Box<dyn QuantEstimator>> = (0..shards)
        .map(|s| {
            let mut est = estimator_for(spec);
            est.seek((s * per) as u64);
            for b in &batches[s * per..(s + 1) * per] {
                est.observe(b);
            }
            est
        })
        .collect();
    let mut root = parts.remove(0);
    for p in parts {
        root.merge(p.as_ref()).unwrap();
    }
    root.finish(bits).unwrap()
}

/// 1 vs 4 vs 16 shards -> bit-identical codebooks, all five methods.
#[test]
fn shard_count_invariance_all_methods() {
    let mut rng = Rng::new(41);
    let batches: Vec<Vec<f64>> =
        (0..16).map(|_| mixture_samples(&mut rng, 2_000)).collect();
    for method in Method::ALL {
        for bits in [2u32, 4] {
            let spec = QuantSpec::new(method, bits);
            let serial = shard_fit(&spec, &batches, 1, bits);
            for shards in [4usize, 16] {
                let sharded = shard_fit(&spec, &batches, shards, bits);
                assert_eq!(
                    book_bits(&sharded),
                    book_bits(&serial),
                    "{} @{bits}b: {shards} shards diverged from serial",
                    method.name()
                );
            }
        }
    }
}

/// Merge order must not matter: folding the shard states in scrambled
/// orders (and into different roots) gives identical codebooks.
#[test]
fn merge_is_order_insensitive() {
    let mut rng = Rng::new(43);
    let batches: Vec<Vec<f64>> =
        (0..8).map(|_| mixture_samples(&mut rng, 1_500)).collect();
    for method in Method::ALL {
        let spec = QuantSpec::new(method, 3);
        let mk_parts = || -> Vec<Box<dyn QuantEstimator>> {
            (0..4)
                .map(|s| {
                    let mut est = estimator_for(&spec);
                    est.seek((s * 2) as u64);
                    for b in &batches[s * 2..(s + 1) * 2] {
                        est.observe(b);
                    }
                    est
                })
                .collect()
        };

        // order A: fold 1,2,3 into 0
        let mut a = mk_parts();
        let mut root_a = a.remove(0);
        for p in a {
            root_a.merge(p.as_ref()).unwrap();
        }
        // order B: fold 3,0,1 into 2
        let mut b = mk_parts();
        let root2 = b.remove(2);
        let mut root_b = root2;
        for idx in [2usize, 0, 0] {
            let p = b.remove(idx.min(b.len() - 1));
            root_b.merge(p.as_ref()).unwrap();
        }
        assert_eq!(
            book_bits(&root_a.finish(3).unwrap()),
            book_bits(&root_b.finish(3).unwrap()),
            "{}: merge order changed the codebook",
            method.name()
        );
    }
}

/// Streaming (chunked observes) equals buffered (single observe) for
/// the order-free estimators, and equals the legacy pool-everything
/// fitters exactly for linear / CDF / Lloyd-Max.
#[test]
fn streaming_equals_buffered_baselines() {
    let mut rng = Rng::new(47);
    for trial in 0..5 {
        let xs = mixture_samples(&mut rng, 12_000);
        let bits = 2 + (trial % 4) as u32;
        for method in [Method::Linear, Method::Cdf, Method::LloydMax, Method::KMeans] {
            let spec = QuantSpec::new(method, bits);
            let mut chunked = estimator_for(&spec);
            for c in xs.chunks(997) {
                chunked.observe(c);
            }
            let mut oneshot = estimator_for(&spec);
            oneshot.observe(&xs);
            let a = chunked.finish(bits).unwrap();
            let b = oneshot.finish(bits).unwrap();
            assert_eq!(
                book_bits(&a),
                book_bits(&b),
                "{} @{bits}b: chunking changed the codebook",
                method.name()
            );
            // legacy buffer-everything fitters (k-means excluded: its
            // reservoir subsample is order-dependent by construction,
            // which is exactly what the canonicalizing sketch fixes)
            let legacy = match method {
                Method::Linear => Some(fit_linear(&xs, bits)),
                Method::Cdf => Some(fit_cdf(&xs, bits)),
                Method::LloydMax => Some(fit_lloyd_max(&xs, bits)),
                _ => None,
            };
            if let Some(centers) = legacy {
                assert_eq!(
                    book_bits(&a),
                    book_bits(&Codebook::from_centers(&centers)),
                    "{} @{bits}b: streaming estimator diverged from the \
                     legacy buffered fitter",
                    method.name()
                );
            }
        }
    }
}

/// BS-KMQ: identical *batch sequences* produce identical codebooks
/// regardless of how the batches are distributed over shards (its
/// Algorithm 1 is defined per batch, so the batch structure is input).
#[test]
fn bs_kmq_shard_invariance_over_batches() {
    let mut rng = Rng::new(53);
    let batches: Vec<Vec<f64>> =
        (0..16).map(|_| mixture_samples(&mut rng, 3_000)).collect();
    let spec = QuantSpec::new(Method::BsKmq, 3);
    let serial = shard_fit(&spec, &batches, 1, 3);
    for shards in [2usize, 4, 8, 16] {
        let sharded = shard_fit(&spec, &batches, shards, 3);
        assert_eq!(
            book_bits(&sharded),
            book_bits(&serial),
            "bs_kmq: {shards} shards diverged"
        );
    }
}

/// Cross-method and cross-seed merges must fail loudly.
#[test]
fn merge_rejects_incompatible_states() {
    let mut a = estimator_for(&QuantSpec::new(Method::Cdf, 3));
    a.observe(&[1.0, 2.0]);
    let mut b = estimator_for(&QuantSpec::new(Method::KMeans, 3));
    b.observe(&[1.0, 2.0]);
    assert!(a.merge(b.as_ref()).is_err(), "cdf <- kmeans must fail");

    let s0 = QuantSpec::new(Method::BsKmq, 3);
    let s9 = QuantSpec {
        seed: 9,
        ..QuantSpec::new(Method::BsKmq, 3)
    };
    let mut e0 = estimator_for(&s0);
    e0.observe(&[1.0, 2.0]);
    let mut e9 = estimator_for(&s9);
    e9.seek(1);
    e9.observe(&[3.0, 4.0]);
    assert!(e0.merge(e9.as_ref()).is_err(), "seed mismatch must fail");
}

/// Manifest round trip: specs written by synth parse back; a spec the
/// hardware cannot program is rejected at graph compile time.
#[test]
fn manifest_specs_roundtrip_and_validate() {
    let dir = std::env::temp_dir().join("bskmq_spec_roundtrip");
    let _ = std::fs::remove_dir_all(&dir);
    synth::write_model(&dir, "inception", 7).unwrap();
    let m = Manifest::load(dir.join("inception_manifest.json")).unwrap();
    let specs = m.layer_specs();
    assert_eq!(specs.len(), m.nq());
    for (i, s) in specs.iter().enumerate() {
        assert_eq!(s.method, Method::BsKmq);
        assert_eq!(s.act_bits, synth::paper_act_bits("inception"));
        assert_eq!(s.tile_bits, 7);
        assert_eq!(s.seed, i as u64, "per-layer seed must be the index");
    }

    // sabotage one spec beyond the manifest's level capacity
    let src =
        std::fs::read_to_string(dir.join("inception_manifest.json")).unwrap();
    let bad_src = src.replacen(r#""max_levels": 128"#, r#""max_levels": 8"#, 1);
    let bad = Manifest::from_json_str(&bad_src).unwrap();
    let err = GraphProgram::compile(&bad).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("quant spec") && msg.contains("max_levels"),
        "compile error must name the spec violation, got: {msg}"
    );
}

/// Acceptance: the paper's 6/2/3b (tile/weight/act) ResNet config runs
/// end-to-end — calibrate (4-shard ≡ serial, bitwise) → per-layer
/// weight quantization → PTQ → replica-pool serving.
#[test]
fn paper_6_2_3_config_end_to_end() {
    let dir = std::env::temp_dir().join("bskmq_spec_e2e");
    let _ = std::fs::remove_dir_all(&dir);
    synth::write_model(&dir, "resnet", 42).unwrap();
    let be = load(BackendKind::Native, &dir, "resnet").unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();

    let spec = QuantSpec::parse("6/2/3", &QuantSpec::default()).unwrap();
    assert_eq!((spec.tile_bits, spec.weight_bits, spec.act_bits), (6, Some(2), 3));

    // 1-shard vs 4-shard calibration: programmed codebooks bit-identical
    let calib = Calibrator::with_uniform(be.as_ref(), spec);
    let serial = calib.calibrate_sharded(&data, 8, 1).unwrap();
    let sharded = calib.calibrate_sharded(&data, 8, 4).unwrap();
    assert_eq!(serial.shards, 1);
    assert_eq!(sharded.shards, 4);
    assert_eq!(serial.samples_seen, sharded.samples_seen);
    for i in 0..be.manifest().nq() {
        assert_eq!(
            book_bits(&serial.nl_books[i]),
            book_bits(&sharded.nl_books[i]),
            "layer {i}: sharded NL codebook diverged"
        );
        assert_eq!(
            book_bits(&serial.tile_books[i]),
            book_bits(&sharded.tile_books[i]),
            "layer {i}: sharded tile codebook diverged"
        );
        assert_eq!(serial.nl_books[i].levels(), 8, "3-bit NL codebook");
        assert_eq!(serial.tile_books[i].levels(), 64, "6-bit tile codebook");
    }

    // per-layer weight quantization + deployment-order recalibration
    let specs = serial.specs.clone();
    let deployed = PtqEvaluator::new(be.as_ref())
        .quantize_weights_spec(&specs)
        .unwrap();
    // 2-bit columns: every weight is ternary per column scale
    for (&wi, w0) in deployed
        .qweight_indices()
        .iter()
        .zip(be.weights().iter().step_by(2))
    {
        let wq = &deployed.weights()[wi];
        assert_eq!(wq.shape, w0.shape);
        let n = wq.shape[1];
        for col in 0..n {
            let col_vals: Vec<f32> = (0..wq.shape[0])
                .map(|r| wq.data[r * n + col])
                .collect();
            let mut distinct: Vec<u32> =
                col_vals.iter().map(|v| v.to_bits()).collect();
            distinct.sort_unstable();
            distinct.dedup();
            assert!(
                distinct.len() <= 3,
                "2-bit column has {} distinct levels",
                distinct.len()
            );
        }
    }
    let books = Calibrator::with_specs(deployed.as_ref(), specs)
        .calibrate_sharded(&data, 8, 4)
        .unwrap();
    let r = PtqEvaluator::new(deployed.as_ref())
        .evaluate(&data, &books.programmed, 0.0, 2, 3)
        .unwrap();
    assert!(r.accuracy.is_finite());
    assert_eq!(r.samples, 2 * be.manifest().batch);

    // serve the same spec through a replica pool (weights quantized and
    // codebooks calibrated inside pool_setup, 2 shards)
    let pool = ModelPool::start(
        dir.clone(),
        "resnet".into(),
        &PoolConfig {
            backend: BackendKind::Native,
            spec: Some(spec),
            calib_batches: 4,
            calib_shards: 2,
            replicas: 2,
            queue_depth: 64,
            batch_window: Duration::from_millis(1),
            ..PoolConfig::default()
        },
    )
    .unwrap();
    let elems: usize = data.x_test.shape[1..].iter().product();
    for i in 0..3 {
        let x = data.x_test.data[i * elems..(i + 1) * elems].to_vec();
        let logits = pool.infer(x).unwrap();
        assert_eq!(logits.len(), synth::CLASSES);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
}

/// Manifests without per-layer specs resolve to defaults equal to the
/// synth-emitted resnet specs (which encode the historical behavior).
#[test]
fn specless_manifest_defaults_match_emitted_resnet() {
    let dir = std::env::temp_dir().join("bskmq_spec_defaults");
    let _ = std::fs::remove_dir_all(&dir);
    synth::write_model(&dir, "resnet", 42).unwrap();
    let m = Manifest::load(dir.join("resnet_manifest.json")).unwrap();
    let mut stripped = m.clone();
    for q in &mut stripped.qlayers {
        q.spec = None;
    }
    assert_eq!(
        stripped.layer_specs(),
        m.layer_specs(),
        "resnet's emitted specs must equal the backward-compat defaults"
    );
    for (i, s) in stripped.layer_specs().iter().enumerate() {
        assert_eq!(*s, QuantSpec::default_for_layer(i));
    }
}
