//! Native-backend end-to-end tests on *synthetic* artifacts: a
//! resnet-topology manifest + random weights + data splits are written
//! from Rust (no Python, no HLO lowering), then the full pipeline —
//! collect, Algorithm 1 calibration, quantized forward, weight
//! quantization, inference server — runs through the NativeBackend.
//! These tests always run; nothing here touches the XLA artifacts path.

use bskmq::backend::{load, Backend, BackendKind};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::coordinator::ptq::PtqEvaluator;
use bskmq::coordinator::server::InferenceServer;
use bskmq::data::dataset::ModelData;
use bskmq::io::weights::save_tensors;
use bskmq::quant::Method;
use bskmq::tensor::Tensor;
use bskmq::util::rng::Rng;

const BATCH: usize = 4;
const CLASSES: usize = 10;
const SPL: usize = 4096;
/// resnet qlayer table: (name, k, n, relu)
const QLAYERS: [(&str, usize, usize, bool); 7] = [
    ("conv0", 27, 16, true),
    ("b1c1", 144, 16, true),
    ("b1c2", 144, 16, false),
    ("b2c1", 144, 32, true),
    ("b2c2", 288, 32, false),
    ("b2sc", 16, 32, false),
    ("fc", 32, CLASSES, false),
];

/// Write a self-consistent synthetic resnet artifact set into `dir`.
fn synth_artifacts(dir: &std::path::Path) {
    std::fs::create_dir_all(dir).unwrap();
    let mut rng = Rng::new(42);

    // --- weights container (he-init mats, zero biases)
    let mut tensors: Vec<(String, Tensor)> = Vec::new();
    let mut weight_args = String::new();
    for (i, (name, k, n, _relu)) in QLAYERS.iter().enumerate() {
        let scale = (2.0 / *k as f64).sqrt();
        let w: Vec<f32> = (0..k * n)
            .map(|_| (rng.gaussian() * scale) as f32)
            .collect();
        let b: Vec<f32> = (0..*n).map(|_| (rng.gaussian() * 0.05) as f32).collect();
        let wname = format!("q{i:02}_{name}_w");
        let bname = format!("q{i:02}_{name}_b");
        if i > 0 {
            weight_args.push(',');
        }
        weight_args.push_str(&format!(
            r#"{{"name": "{wname}", "shape": [{k}, {n}]}},
               {{"name": "{bname}", "shape": [{n}]}}"#
        ));
        tensors.push((wname, Tensor::new(vec![*k, *n], w).unwrap()));
        tensors.push((bname, Tensor::new(vec![*n], b).unwrap()));
    }
    let refs: Vec<(&str, &Tensor)> =
        tensors.iter().map(|(n, t)| (n.as_str(), t)).collect();
    save_tensors(dir.join("resnet_weights.bin"), &refs).unwrap();

    // --- manifest
    let nq = QLAYERS.len();
    let logits_len = BATCH * CLASSES;
    let qlayers_json: Vec<String> = QLAYERS
        .iter()
        .map(|(name, k, n, relu)| {
            format!(r#"{{"name": "{name}", "k": {k}, "n": {n}, "relu": {relu}}}"#)
        })
        .collect();
    let manifest = format!(
        r#"{{
  "model": "resnet",
  "batch": {BATCH},
  "input_shape": [16, 16, 3],
  "input_dtype": "f32",
  "num_classes": {CLASSES},
  "max_levels": 128,
  "qlayers": [{}],
  "weight_args": [{weight_args}],
  "collect": {{
    "out_len": {},
    "logits_len": {logits_len},
    "samples_per_layer": {SPL},
    "tilemax_offset": {}
  }},
  "artifacts": {{
    "collect": "resnet_collect.hlo.txt",
    "qfwd": "resnet_qfwd.hlo.txt"
  }}
}}"#,
        qlayers_json.join(","),
        logits_len + nq * SPL + nq,
        logits_len + nq * SPL,
    );
    std::fs::write(dir.join("resnet_manifest.json"), manifest).unwrap();

    // --- data splits (smooth-ish random images)
    let elems = 16 * 16 * 3;
    let n_calib = 4 * BATCH;
    let n_test = 2 * BATCH;
    let gen_imgs = |rng: &mut Rng, n: usize| -> Vec<f32> {
        (0..n * elems).map(|_| (rng.gaussian() * 0.6) as f32).collect()
    };
    let x_calib =
        Tensor::new(vec![n_calib, 16, 16, 3], gen_imgs(&mut rng, n_calib))
            .unwrap();
    let x_test =
        Tensor::new(vec![n_test, 16, 16, 3], gen_imgs(&mut rng, n_test))
            .unwrap();
    let y_test: Vec<f32> =
        (0..n_test).map(|_| (rng.below(CLASSES)) as f32).collect();
    let y_test = Tensor::new(vec![n_test], y_test).unwrap();
    save_tensors(
        dir.join("resnet_data.bin"),
        &[
            ("x_calib", &x_calib),
            ("x_test", &x_test),
            ("y_test", &y_test),
        ],
    )
    .unwrap();
}

fn fresh_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bskmq_native_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    synth_artifacts(&dir);
    dir
}

#[test]
fn collect_layout_relu_and_tilemax() {
    let dir = fresh_dir("collect");
    let be = load(BackendKind::Native, &dir, "resnet").unwrap();
    assert_eq!(be.name(), "native");
    let m = be.manifest();
    assert_eq!(m.nq(), QLAYERS.len());
    let data = ModelData::load(&dir, "resnet").unwrap();
    let out = be
        .run_collect(ModelData::batch(&data.x_calib, 0, m.batch))
        .unwrap();
    assert_eq!(out.logits.len(), m.batch * m.num_classes);
    assert_eq!(out.samples.len(), m.nq());
    assert_eq!(out.tile_max.len(), m.nq());
    for (i, q) in m.qlayers.iter().enumerate() {
        assert_eq!(out.samples[i].len(), SPL, "layer {}", q.name);
        if q.relu {
            assert!(
                out.samples[i].iter().all(|&v| v >= 0.0),
                "relu layer {} has negative samples",
                q.name
            );
        }
        assert!(out.tile_max[i] > 0.0, "tile max of {} is zero", q.name);
        assert!(out.samples[i].iter().all(|v| v.is_finite()));
    }
}

#[test]
fn qfwd_batches_determinism_and_noise() {
    let dir = fresh_dir("qfwd");
    let be = load(BackendKind::Native, &dir, "resnet").unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let calib = Calibrator::new(be.as_ref(), Method::BsKmq, 3)
        .calibrate(&data, 3)
        .unwrap();
    let m = be.manifest();
    let elems = m.input_elems();
    let xb = ModelData::batch(&data.x_test, 0, m.batch);

    // the native backend accepts any batch size, exactly
    for n in [1usize, 3, m.batch] {
        assert!(be.supports_batch(n));
        let logits = be
            .run_qfwd(&xb[..n * elems], &calib.programmed, 0.0, 7)
            .unwrap();
        assert_eq!(logits.len(), n * m.num_classes, "batch {n}");
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    // deterministic given (input, books, seed)...
    let a = be.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap();
    let b = be.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap();
    assert_eq!(a, b);
    // ...and batch-1 logits equal the first row of the batch run (no
    // cross-sample coupling in the dataflow)
    let one = be
        .run_qfwd(&xb[..elems], &calib.programmed, 0.0, 7)
        .unwrap();
    assert_eq!(one, a[..m.num_classes].to_vec());
    // heavy conversion noise must perturb the quantized logits
    let noisy = be.run_qfwd(xb, &calib.programmed, 2.0, 7).unwrap();
    assert_ne!(a, noisy, "2-LSB conversion noise changed nothing");

    // weight quantization path (with_weights + qweight_indices)
    let ev = PtqEvaluator::new(be.as_ref());
    let wq = ev.quantize_weights(4).unwrap();
    assert_eq!(wq.name(), "native");
    let books = Calibrator::new(wq.as_ref(), Method::BsKmq, 3)
        .calibrate(&data, 3)
        .unwrap();
    let r = PtqEvaluator::new(wq.as_ref())
        .evaluate(&data, &books.programmed, 0.0, 2, 3)
        .unwrap();
    assert_eq!(r.samples, 2 * m.batch);
    assert!(r.accuracy.is_finite());
}

/// The integer/codebook-domain forward at the ADC's maximum resolution
/// (7-bit NL + 7-bit tile codebooks) must track the float forward within
/// accumulated codebook quantization tolerance.
#[test]
fn high_resolution_qfwd_tracks_float_forward() {
    let dir = fresh_dir("agree");
    let be = load(BackendKind::Native, &dir, "resnet").unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let m = be.manifest();
    // calibrate on the same batch we evaluate: tile ranges then cover the
    // evaluated partial sums exactly
    let calib = Calibrator::new(be.as_ref(), Method::Linear, 7)
        .calibrate(&data, 3)
        .unwrap();
    let xb = ModelData::batch(&data.x_calib, 0, m.batch);
    let float_logits = be.run_collect(xb).unwrap().logits;
    let q_logits = be.run_qfwd(xb, &calib.programmed, 0.0, 1).unwrap();
    assert_eq!(float_logits.len(), q_logits.len());
    let absmax = float_logits
        .iter()
        .fold(0f32, |acc, v| acc.max(v.abs()));
    let tol = 0.15 * (1.0 + absmax);
    let mut worst = 0f32;
    for (q, f) in q_logits.iter().zip(&float_logits) {
        worst = worst.max((q - f).abs());
    }
    assert!(
        worst <= tol,
        "7-bit quantized forward drifted from float: max|diff| {worst} > {tol}"
    );
}

/// Acceptance: the inference server starts and serves with the native
/// backend in a directory that contains NO HLO artifacts at all.
#[test]
fn server_serves_natively_without_hlo_artifacts() {
    let dir = fresh_dir("server");
    assert!(
        !dir.join("resnet_qfwd.hlo.txt").exists(),
        "test dir must not contain lowered graphs"
    );
    let server = InferenceServer::start(
        dir.clone(),
        "resnet".into(),
        BackendKind::Native,
        Method::BsKmq,
        3,
        0.0,
        2,
    )
    .unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let elems: usize = data.x_test.shape[1..].iter().product();
    for i in 0..3 {
        let x = data.x_test.data[i * elems..(i + 1) * elems].to_vec();
        let logits = server.infer(x).unwrap();
        assert_eq!(logits.len(), CLASSES);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
    let stats = server.stats.summary();
    assert!(stats.contains("requests=3"), "{stats}");
    assert!(stats.contains("p50="), "{stats}");
}
