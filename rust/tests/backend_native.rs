//! Native-backend end-to-end tests on *synthetic* artifacts: the
//! library's own artifact writer (`bskmq::data::synth`) emits a
//! manifest + random weights + data splits from Rust (no Python, no HLO
//! lowering), then the full pipeline — collect, Algorithm 1 calibration,
//! quantized forward, weight quantization, inference server — runs
//! through the NativeBackend.  These tests always run; nothing here
//! touches the XLA artifacts path.

use bskmq::backend::{load, Backend, BackendKind};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::coordinator::ptq::{argmax, PtqEvaluator};
use bskmq::coordinator::pool::InferenceServer;
use bskmq::data::dataset::ModelData;
use bskmq::data::synth;
use bskmq::quant::{Method, QuantSpec};
use bskmq::util::rng::Rng;

fn fresh_dir(tag: &str, model: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bskmq_native_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    synth::write_model(&dir, model, 42).unwrap();
    dir
}

#[test]
fn collect_layout_relu_and_tilemax() {
    let dir = fresh_dir("collect", "resnet");
    let be = load(BackendKind::Native, &dir, "resnet").unwrap();
    assert_eq!(be.name(), "native");
    let m = be.manifest();
    assert_eq!(m.nq(), 7);
    let data = ModelData::load(&dir, "resnet").unwrap();
    let out = be
        .run_collect(ModelData::batch(&data.x_calib, 0, m.batch))
        .unwrap();
    assert_eq!(out.logits.len(), m.batch * m.num_classes);
    assert_eq!(out.samples.len(), m.nq());
    assert_eq!(out.tile_max.len(), m.nq());
    for (i, q) in m.qlayers.iter().enumerate() {
        assert_eq!(out.samples[i].len(), synth::SPL, "layer {}", q.name);
        if q.relu {
            assert!(
                out.samples[i].iter().all(|&v| v >= 0.0),
                "relu layer {} has negative samples",
                q.name
            );
        }
        assert!(out.tile_max[i] > 0.0, "tile max of {} is zero", q.name);
        assert!(out.samples[i].iter().all(|v| v.is_finite()));
    }
}

#[test]
fn qfwd_batches_determinism_and_noise() {
    let dir = fresh_dir("qfwd", "resnet");
    let be = load(BackendKind::Native, &dir, "resnet").unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let calib = Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::BsKmq, 3))
        .calibrate(&data, 3)
        .unwrap();
    let m = be.manifest();
    let elems = m.input_elems();
    let xb = ModelData::batch(&data.x_test, 0, m.batch);

    // the native backend accepts any batch size, exactly
    for n in [1usize, 3, m.batch] {
        assert!(be.supports_batch(n));
        let logits = be
            .run_qfwd(&xb[..n * elems], &calib.programmed, 0.0, 7)
            .unwrap();
        assert_eq!(logits.len(), n * m.num_classes, "batch {n}");
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    // deterministic given (input, books, seed)...
    let a = be.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap();
    let b = be.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap();
    assert_eq!(a, b);
    // ...and batch-1 logits equal the first row of the batch run (no
    // cross-sample coupling in the dataflow)
    let one = be
        .run_qfwd(&xb[..elems], &calib.programmed, 0.0, 7)
        .unwrap();
    assert_eq!(one, a[..m.num_classes].to_vec());
    // heavy conversion noise must perturb the quantized logits
    let noisy = be.run_qfwd(xb, &calib.programmed, 2.0, 7).unwrap();
    assert_ne!(a, noisy, "2-LSB conversion noise changed nothing");

    // weight quantization path (with_weights + qweight_indices)
    let ev = PtqEvaluator::new(be.as_ref());
    let wq = ev.quantize_weights(4).unwrap();
    assert_eq!(wq.name(), "native");
    let books = Calibrator::with_uniform(wq.as_ref(), QuantSpec::new(Method::BsKmq, 3))
        .calibrate(&data, 3)
        .unwrap();
    let r = PtqEvaluator::new(wq.as_ref())
        .evaluate(&data, &books.programmed, 0.0, 2, 3)
        .unwrap();
    assert_eq!(r.samples, 2 * m.batch);
    assert!(r.accuracy.is_finite());
}

/// `Backend::replicate` hands out instances that share the weight set:
/// same manifest, same weight tensors (bitwise), identical qfwd logits.
#[test]
fn replicate_shares_weights_and_agrees() {
    let dir = fresh_dir("replicate", "resnet");
    let be = load(BackendKind::Native, &dir, "resnet").unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let calib = Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::BsKmq, 3))
        .calibrate(&data, 3)
        .unwrap();
    let rep = be.replicate().unwrap();
    assert_eq!(rep.name(), "native");
    assert_eq!(rep.manifest().nq(), be.manifest().nq());
    assert_eq!(rep.weights().len(), be.weights().len());
    for (a, b) in be.weights().iter().zip(rep.weights()) {
        assert_eq!(a.shape, b.shape);
        assert_eq!(a.data, b.data);
    }
    let m = be.manifest();
    let xb = ModelData::batch(&data.x_test, 0, m.batch);
    let la = be.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap();
    let lb = rep.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap();
    assert_eq!(la, lb, "replica diverged from its source backend");
}

/// The integer/codebook-domain forward at the ADC's maximum resolution
/// (7-bit NL + 7-bit tile codebooks) must track the float forward within
/// accumulated codebook quantization tolerance.
#[test]
fn high_resolution_qfwd_tracks_float_forward() {
    let dir = fresh_dir("agree", "resnet");
    let be = load(BackendKind::Native, &dir, "resnet").unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let m = be.manifest();
    // calibrate on the same batch we evaluate: tile ranges then cover the
    // evaluated partial sums exactly
    let calib = Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::Linear, 7))
        .calibrate(&data, 3)
        .unwrap();
    let xb = ModelData::batch(&data.x_calib, 0, m.batch);
    let float_logits = be.run_collect(xb).unwrap().logits;
    let q_logits = be.run_qfwd(xb, &calib.programmed, 0.0, 1).unwrap();
    assert_eq!(float_logits.len(), q_logits.len());
    let absmax = float_logits
        .iter()
        .fold(0f32, |acc, v| acc.max(v.abs()));
    let tol = 0.15 * (1.0 + absmax);
    let mut worst = 0f32;
    for (q, f) in q_logits.iter().zip(&float_logits) {
        worst = worst.max((q - f).abs());
    }
    assert!(
        worst <= tol,
        "7-bit quantized forward drifted from float: max|diff| {worst} > {tol}"
    );
}

/// Fuzz agreement across every native topology: seeded random-input
/// families from the mixture generator (the same one the quantizer
/// property tests use) through the integer path at max ADC resolution
/// and zero conversion noise must reproduce the float path's argmax on
/// every confidently-classified sample (float top-2 margin beyond the
/// observed quantization drift) — and such samples must actually occur.
#[test]
fn fuzz_argmax_agreement_all_topologies() {
    for (mi, model) in synth::MODELS.iter().enumerate() {
        let dir = fresh_dir(&format!("fuzz_{model}"), model);
        let be = load(BackendKind::Native, &dir, model).unwrap();
        let data = ModelData::load(&dir, model).unwrap();
        let m = be.manifest();
        let classes = m.num_classes;
        let elems = m.input_elems();
        let calib = Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::Linear, 7))
            .calibrate(&data, 8)
            .unwrap();
        let mut rng = Rng::new(900 + mi as u64);
        let mut total = 0usize;
        let mut checked = 0usize;
        for family in 0..4 {
            let raw = synth::mixture_samples(&mut rng, m.batch * elems);
            let x: Vec<f32> = if *model == "distilbert" {
                // sequence model: map the mixture onto token ids
                raw.iter()
                    .map(|v| {
                        ((v.abs() * 7.0) as usize % synth::BERT_VOCAB) as f32
                    })
                    .collect()
            } else {
                // image models: normalize each sample into the calibrated
                // activation range so tile clipping stays physical
                let mut x = Vec::with_capacity(raw.len());
                for chunk in raw.chunks(elems) {
                    let absmax = chunk
                        .iter()
                        .fold(0f64, |acc, v| acc.max(v.abs()));
                    let scale = if absmax > 2.0 { 2.0 / absmax } else { 1.0 };
                    x.extend(chunk.iter().map(|v| (v * scale) as f32));
                }
                x
            };
            let f_logits = be.run_collect(&x).unwrap().logits;
            let q_logits =
                be.run_qfwd(&x, &calib.programmed, 0.0, 1).unwrap();
            assert_eq!(f_logits.len(), q_logits.len());
            for s in 0..m.batch {
                total += 1;
                let fl = &f_logits[s * classes..(s + 1) * classes];
                let ql = &q_logits[s * classes..(s + 1) * classes];
                let top = argmax(fl);
                let margin = fl
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| *i != top)
                    .fold(f32::NEG_INFINITY, |acc, (_, v)| acc.max(*v));
                let margin = fl[top] - margin;
                let drift = fl
                    .iter()
                    .zip(ql)
                    .fold(0f32, |acc, (f, q)| acc.max((f - q).abs()));
                if margin > 2.0 * drift + 1e-6 {
                    checked += 1;
                    assert_eq!(
                        argmax(ql),
                        top,
                        "{model} family {family} sample {s}: integer path \
                         flipped a confident argmax (margin {margin}, \
                         drift {drift})"
                    );
                }
            }
        }
        assert!(
            checked * 4 >= total,
            "{model}: only {checked}/{total} samples were confidently \
             separated — agreement check has no teeth"
        );
    }
}

/// Acceptance: the inference server starts and serves with the native
/// backend in a directory that contains NO HLO artifacts at all.
#[test]
fn server_serves_natively_without_hlo_artifacts() {
    let dir = fresh_dir("server", "resnet");
    assert!(
        !dir.join("resnet_qfwd.hlo.txt").exists(),
        "test dir must not contain lowered graphs"
    );
    let server = InferenceServer::start(
        dir.clone(),
        "resnet".into(),
        BackendKind::Native,
        Some(QuantSpec::new(Method::BsKmq, 3)),
        0.0,
        2,
    )
    .unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let elems: usize = data.x_test.shape[1..].iter().product();
    for i in 0..3 {
        let x = data.x_test.data[i * elems..(i + 1) * elems].to_vec();
        let logits = server.infer(x).unwrap();
        assert_eq!(logits.len(), synth::CLASSES);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
    let stats = server.stats.summary();
    assert!(stats.contains("requests=3"), "{stats}");
    assert!(stats.contains("p50="), "{stats}");
}
