//! Layer-graph IR acceptance tests: every class of malformed graph must
//! fail at *load* time (GraphProgram::compile) with an error naming the
//! offending op/edge — never panic mid-inference — and a topology that
//! was never hardcoded in Rust (the MLP-Mixer-style `mixer`) must run
//! the full pipeline from its manifest alone.  Also pins `bskmq synth`
//! seed reproducibility: same seed -> byte-identical artifacts.

use bskmq::backend::native::graph::GraphProgram;
use bskmq::backend::native::NativeBackend;
use bskmq::backend::{load, Backend, BackendKind};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::coordinator::ptq::PtqEvaluator;
use bskmq::coordinator::pool::InferenceServer;
use bskmq::data::dataset::ModelData;
use bskmq::data::synth;
use bskmq::io::manifest::Manifest;
use bskmq::quant::{Method, QuantSpec};

/// A minimal two-dense-layer manifest with a caller-supplied `ops`
/// array (the shared fixture of the failure tests).
fn manifest_with_ops(ops_json: &str) -> Manifest {
    let src = format!(
        r#"{{
  "model": "fixture",
  "batch": 2,
  "input_shape": [4],
  "input_dtype": "f32",
  "num_classes": 3,
  "max_levels": 128,
  "qlayers": [
    {{"name": "d0", "k": 4, "n": 5, "relu": true}},
    {{"name": "d1", "k": 5, "n": 3, "relu": false}}
  ],
  "weight_args": [
    {{"name": "q00_d0_w", "shape": [4, 5]}},
    {{"name": "q00_d0_b", "shape": [5]}},
    {{"name": "q01_d1_w", "shape": [5, 3]}},
    {{"name": "q01_d1_b", "shape": [3]}}
  ],
  "collect": {{
    "out_len": 0, "logits_len": 6,
    "samples_per_layer": 8, "tilemax_offset": 0
  }},
  "artifacts": {{"collect": "none", "qfwd": "none"}},
  "graph": {{
    "input": "x",
    "output": "logits",
    "ops": [{ops_json}]
  }}
}}"#
    );
    Manifest::from_json_str(&src).unwrap()
}

fn compile_err(ops_json: &str) -> String {
    let m = manifest_with_ops(ops_json);
    let err = GraphProgram::compile(&m)
        .expect_err("malformed graph must fail at load");
    format!("{err:#}")
}

#[test]
fn cyclic_graph_fails_at_load_naming_op_and_edge() {
    // d0 consumes d1's output while d1 consumes d0's: a 2-cycle.  The
    // topological-order contract makes this a forward reference.
    let e = compile_err(
        r#"{"op": "dense", "name": "d0", "in": ["loop"], "out": "h",
            "qlayer": "d0"},
           {"op": "dense", "name": "d1", "in": ["h"], "out": "loop",
            "qlayer": "d1"}"#,
    );
    assert!(e.contains("d0"), "error must name the op: {e}");
    assert!(e.contains("loop"), "error must name the edge: {e}");
    assert!(e.contains("cyclic"), "error must diagnose the cycle: {e}");
}

#[test]
fn unknown_op_kind_fails_at_load() {
    let e = compile_err(
        r#"{"op": "convolution", "name": "c0", "in": ["x"],
            "out": "logits", "qlayer": "d0"}"#,
    );
    assert!(e.contains("unknown op kind"), "{e}");
    assert!(e.contains("convolution"), "{e}");
    assert!(e.contains("c0"), "error must name the op: {e}");
}

#[test]
fn edge_consumer_shape_mismatch_fails_at_load() {
    // d1 (k = 5) applied straight to the 4-feature input edge
    let e = compile_err(
        r#"{"op": "dense", "name": "bad", "in": ["x"], "out": "logits",
            "qlayer": "d1"}"#,
    );
    assert!(e.contains("bad"), "error must name the op: {e}");
    assert!(e.contains("4 features"), "{e}");
    assert!(e.contains("k = 5"), "{e}");
}

#[test]
fn unreferenced_qlayer_fails_at_load() {
    // a graph that is complete and shape-consistent (d0 straight to a
    // 5-class output) but leaves q-layer d1 with no consumer — its
    // calibration stream would silently never be fed
    let src = r#"{
  "model": "fixture",
  "batch": 2,
  "input_shape": [4],
  "input_dtype": "f32",
  "num_classes": 5,
  "max_levels": 128,
  "qlayers": [
    {"name": "d0", "k": 4, "n": 5, "relu": true},
    {"name": "d1", "k": 5, "n": 3, "relu": false}
  ],
  "weight_args": [
    {"name": "q00_d0_w", "shape": [4, 5]},
    {"name": "q00_d0_b", "shape": [5]},
    {"name": "q01_d1_w", "shape": [5, 3]},
    {"name": "q01_d1_b", "shape": [3]}
  ],
  "collect": {
    "out_len": 0, "logits_len": 10,
    "samples_per_layer": 8, "tilemax_offset": 0
  },
  "artifacts": {"collect": "none", "qfwd": "none"},
  "graph": {
    "input": "x",
    "output": "logits",
    "ops": [
      {"op": "dense", "name": "d0", "in": ["x"], "out": "logits",
       "qlayer": "d0"}
    ]
  }
}"#;
    let m = Manifest::from_json_str(src).unwrap();
    let e = format!(
        "{:#}",
        GraphProgram::compile(&m).expect_err("unused q-layer must fail")
    );
    assert!(e.contains("d1"), "error must name the q-layer: {e}");
    assert!(e.contains("referenced by no graph op"), "{e}");
}

#[test]
fn dangling_edge_fails_at_load() {
    // a fully-wired chain plus one relu whose output nothing consumes
    let e = compile_err(
        r#"{"op": "dense", "name": "d0", "in": ["x"], "out": "h",
            "qlayer": "d0"},
           {"op": "relu", "name": "orphan", "in": ["h"], "out": "dead"},
           {"op": "dense", "name": "d1", "in": ["h"], "out": "logits",
            "qlayer": "d1"}"#,
    );
    assert!(e.contains("dead"), "error must name the edge: {e}");
    assert!(e.contains("never consumed"), "{e}");
    assert!(e.contains("orphan"), "error must name the producer: {e}");
}

#[test]
fn double_consumed_qlayer_fails_at_load() {
    let e = compile_err(
        r#"{"op": "dense", "name": "first", "in": ["x"], "out": "h",
            "qlayer": "d0"},
           {"op": "dense", "name": "second", "in": ["x"], "out": "h2",
            "qlayer": "d0"},
           {"op": "add", "name": "merge", "in": ["h", "h2"],
            "out": "logits"}"#,
    );
    assert!(e.contains("second"), "error must name the op: {e}");
    assert!(e.contains("already consumed"), "{e}");
    assert!(e.contains("first"), "error must name the first user: {e}");
}

#[test]
fn graphless_manifest_fails_at_load_not_inference() {
    let mut m = manifest_with_ops(
        r#"{"op": "dense", "name": "d0", "in": ["x"], "out": "h",
            "qlayer": "d0"},
           {"op": "dense", "name": "d1", "in": ["h"], "out": "logits",
            "qlayer": "d1"}"#,
    );
    m.graph = None;
    let e = format!(
        "{:#}",
        GraphProgram::compile(&m).expect_err("graphless must fail")
    );
    assert!(e.contains("no `graph` section"), "{e}");
    // and the backend constructor surfaces it at build time
    let e2 = NativeBackend::from_parts(m, Vec::new())
        .err()
        .map(|e| format!("{e:#}"))
        .expect("from_parts must fail without a graph");
    assert!(e2.contains("no `graph` section"), "{e2}");
}

#[test]
fn valid_fixture_compiles_and_reports_arena() {
    let m = manifest_with_ops(
        r#"{"op": "dense", "name": "d0", "in": ["x"], "out": "h",
            "qlayer": "d0"},
           {"op": "dense", "name": "d1", "in": ["h"], "out": "logits",
            "qlayer": "d1"}"#,
    );
    let p = GraphProgram::compile(&m).unwrap();
    assert_eq!(p.n_ops(), 2);
    assert_eq!(p.n_values(), 3);
    assert!(p.n_slots() <= 2, "liveness planner failed to reuse slots");
}

/// Acceptance: the fifth topology — never hardcoded anywhere in Rust —
/// runs collect -> Algorithm 1 -> qfwd -> PTQ -> serving purely from its
/// manifest.
#[test]
fn mixer_runs_end_to_end_from_manifest_alone() {
    let dir = std::env::temp_dir().join("bskmq_graph_mixer");
    let _ = std::fs::remove_dir_all(&dir);
    synth::write_model(&dir, "mixer", 42).unwrap();

    let be = load(BackendKind::Native, &dir, "mixer").unwrap();
    let m = be.manifest();
    assert_eq!(m.nq(), 4);
    let data = ModelData::load(&dir, "mixer").unwrap();

    // collect layout + relu discipline
    let out = be
        .run_collect(ModelData::batch(&data.x_calib, 0, m.batch))
        .unwrap();
    assert_eq!(out.logits.len(), m.batch * m.num_classes);
    assert_eq!(out.samples.len(), 4);
    for (i, q) in m.qlayers.iter().enumerate() {
        assert_eq!(out.samples[i].len(), synth::SPL, "layer {}", q.name);
        if q.relu {
            assert!(out.samples[i].iter().all(|&v| v >= 0.0), "{}", q.name);
        }
        assert!(out.tile_max[i] > 0.0, "layer {}", q.name);
    }

    // Algorithm 1 -> deployed quantized forward -> PTQ accuracy
    let calib = Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::BsKmq, 3))
        .calibrate(&data, 3)
        .unwrap();
    let xb = ModelData::batch(&data.x_test, 0, m.batch);
    let a = be.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap();
    let b = be.run_qfwd(xb, &calib.programmed, 0.0, 7).unwrap();
    assert_eq!(a, b, "mixer qfwd must be deterministic");
    assert!(a.iter().all(|v| v.is_finite()));
    let r = PtqEvaluator::new(be.as_ref())
        .evaluate(&data, &calib.programmed, 0.0, 2, 3)
        .unwrap();
    assert_eq!(r.samples, 2 * m.batch);
    assert!(r.accuracy.is_finite());

    // and the serving stack hosts it like any paper topology
    let server = InferenceServer::start(
        dir.clone(),
        "mixer".into(),
        BackendKind::Native,
        Some(QuantSpec::new(Method::BsKmq, 3)),
        0.0,
        2,
    )
    .unwrap();
    let elems: usize = data.x_test.shape[1..].iter().product();
    for i in 0..3 {
        let x = data.x_test.data[i * elems..(i + 1) * elems].to_vec();
        let logits = server.infer(x).unwrap();
        assert_eq!(logits.len(), synth::CLASSES);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
}

/// `bskmq synth --seed`: same seed -> byte-identical artifacts; a
/// different seed actually changes them.
#[test]
fn synth_seed_reproducibility() {
    let base = std::env::temp_dir().join("bskmq_graph_seed");
    let (a, b, c) = (base.join("a"), base.join("b"), base.join("c"));
    for d in [&a, &b, &c] {
        let _ = std::fs::remove_dir_all(d);
    }
    synth::write_model(&a, "resnet", 1234).unwrap();
    synth::write_model(&b, "resnet", 1234).unwrap();
    synth::write_model(&c, "resnet", 99).unwrap();
    for f in [
        "resnet_manifest.json",
        "resnet_weights.bin",
        "resnet_data.bin",
    ] {
        let fa = std::fs::read(a.join(f)).unwrap();
        let fb = std::fs::read(b.join(f)).unwrap();
        assert_eq!(fa, fb, "{f}: same seed must be byte-identical");
    }
    assert_ne!(
        std::fs::read(a.join("resnet_weights.bin")).unwrap(),
        std::fs::read(c.join("resnet_weights.bin")).unwrap(),
        "different seeds must produce different weights"
    );
}
