//! Golden-equivalence tests for the layer-graph executor.
//!
//! The `oracle` module below is the **pre-refactor hardcoded forward**,
//! captured verbatim from `backend/native/models.rs` before that file
//! was deleted (PR "manifest-driven layer-graph IR").  It consumes the
//! same public `ops` kernels and the same per-(layer, salt) noise
//! seeding, so it reproduces the old per-model `forward_infer` paths
//! bit-for-bit — and every test here asserts that the generic graph
//! executor's logits (and, in collect mode, activation subsamples and
//! tile absmax) are **bit-identical** to it, in both execution modes,
//! with and without conversion noise, across all four paper topologies.

use bskmq::backend::native::graph::{layer_seed, NL_SEED_SALT};
use bskmq::backend::native::ops::{
    add_bias_relu, add_mat, add_relu, attention, avg_pool3_same,
    collect_subsample, concat_c, global_avg_pool, im2col, layer_norm,
    max_pool2, mean_over_seq, min_ref_step, nl_convert, tiled_mac,
    ConvertSpec, Feat, Mat,
};
use bskmq::backend::native::simd;
use bskmq::backend::native::NativeBackend;
use bskmq::backend::{load, Backend, BackendKind, ProgrammedCodebooks};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::data::dataset::ModelData;
use bskmq::data::synth;
use bskmq::io::manifest::Manifest;
use bskmq::macro_model::ROWS;
use bskmq::quant::codebook::Codebook;
use bskmq::quant::{Method, QuantSpec};
use bskmq::tensor::Tensor;

/// The four pre-refactor hand-written forwards, preserved as the golden
/// reference.  Do not "modernize" this code: its value is that it is the
/// exact computation the deleted `models.rs` performed.
mod oracle {
    use super::*;

    /// Transformer head count of the mini DistilBERT (export-side
    /// constant of the old native backend).
    const BERT_HEADS: usize = 4;

    pub enum Mode<'a> {
        Collect {
            samples: Vec<Vec<f64>>,
            tile_max: Vec<f64>,
        },
        Quant {
            books: &'a ProgrammedCodebooks,
            noise_std: f32,
            seed: u32,
        },
    }

    pub struct ForwardCtx<'a> {
        pub manifest: &'a Manifest,
        pub weights: &'a [Tensor],
        pub mode: Mode<'a>,
        qi: usize,
    }

    impl<'a> ForwardCtx<'a> {
        pub fn new(
            manifest: &'a Manifest,
            weights: &'a [Tensor],
            mode: Mode<'a>,
        ) -> ForwardCtx<'a> {
            ForwardCtx {
                manifest,
                weights,
                mode,
                qi: 0,
            }
        }

        fn digital(&self, name: &str) -> &'a Tensor {
            let idx = self
                .manifest
                .weight_args
                .iter()
                .position(|wa| wa.name == name)
                .unwrap_or_else(|| panic!("digital param '{name}' missing"));
            &self.weights[idx]
        }

        fn qmatmul(&mut self, x: &Mat, relu: bool) -> Mat {
            let wi = self.qi;
            self.qi += 1;
            let w = &self.weights[2 * wi];
            let bias = &self.weights[2 * wi + 1];
            assert_eq!(
                self.manifest.qlayers[wi].relu, relu,
                "oracle relu flag out of sync at layer {wi}"
            );
            match &mut self.mode {
                Mode::Collect { samples, tile_max } => {
                    let (mut y, absmax) = tiled_mac(x, w, ROWS, None);
                    add_bias_relu(&mut y, &bias.data, relu);
                    tile_max.push(absmax);
                    samples.push(collect_subsample(
                        &y.data,
                        self.manifest.samples_per_layer,
                    ));
                    y
                }
                Mode::Quant {
                    books,
                    noise_std,
                    seed,
                } => {
                    let (n_refs, n_centers, t_refs, t_centers) =
                        books.layer_rows(wi);
                    let spec = ConvertSpec {
                        refs: t_refs,
                        centers: t_centers,
                        sigma: *noise_std * min_ref_step(t_refs),
                        seed: layer_seed(*seed, wi, 0),
                    };
                    let (mut y, _) = tiled_mac(x, w, ROWS, Some(&spec));
                    add_bias_relu(&mut y, &bias.data, relu);
                    nl_convert(
                        &mut y,
                        n_refs,
                        n_centers,
                        *noise_std * min_ref_step(n_refs),
                        layer_seed(*seed, wi, NL_SEED_SALT),
                    );
                    y
                }
            }
        }

        fn qconv(
            &mut self,
            x: &Feat,
            k: usize,
            stride: usize,
            relu: bool,
        ) -> Feat {
            let (x2d, oh, ow) = im2col(x, k, k, stride, true);
            let y = self.qmatmul(&x2d, relu);
            Feat::from_mat(y, x.b, oh, ow)
        }
    }

    pub fn forward(
        model: &str,
        ctx: &mut ForwardCtx,
        x: &[f32],
        batch: usize,
    ) -> Mat {
        let logits = if model == "distilbert" {
            distilbert(ctx, x, batch)
        } else {
            let m = ctx.manifest;
            let (h, w, c) =
                (m.input_shape[0], m.input_shape[1], m.input_shape[2]);
            let feat = Feat::new(batch, h, w, c, x.to_vec());
            match model {
                "resnet" => resnet(ctx, feat),
                "vgg" => vgg(ctx, feat),
                "inception" => inception(ctx, feat),
                other => panic!("oracle has no forward for '{other}'"),
            }
        };
        assert_eq!(ctx.qi, ctx.manifest.nq(), "oracle q-layer count");
        logits
    }

    fn resnet(ctx: &mut ForwardCtx, x: Feat) -> Mat {
        let y = ctx.qconv(&x, 3, 1, true); // conv0
        let h = ctx.qconv(&y, 3, 1, true); // b1c1
        let h = ctx.qconv(&h, 3, 1, false); // b1c2
        let y = add_relu(&y, &h);
        let h = ctx.qconv(&y, 3, 2, true); // b2c1
        let h = ctx.qconv(&h, 3, 1, false); // b2c2
        let sc = ctx.qconv(&y, 1, 2, false); // b2sc
        let y = add_relu(&h, &sc);
        let p = global_avg_pool(&y);
        ctx.qmatmul(&p, false) // fc
    }

    fn vgg(ctx: &mut ForwardCtx, x: Feat) -> Mat {
        const POOL_AFTER: [bool; 5] = [false, true, false, true, true];
        let mut y = x;
        for pool in POOL_AFTER {
            y = ctx.qconv(&y, 3, 1, true);
            if pool {
                y = max_pool2(&y);
            }
        }
        let m = y.flatten();
        let m = ctx.qmatmul(&m, true); // fc1
        ctx.qmatmul(&m, false) // fc2
    }

    fn inception(ctx: &mut ForwardCtx, x: Feat) -> Mat {
        let mut y = max_pool2(&ctx.qconv(&x, 3, 1, true)); // stem
        for _ in 0..2 {
            let br0 = ctx.qconv(&y, 1, 1, true); // b0
            let t = ctx.qconv(&y, 1, 1, true); // b1a
            let br1 = ctx.qconv(&t, 3, 1, true); // b1b
            let pooled = avg_pool3_same(&y);
            let br2 = ctx.qconv(&pooled, 1, 1, true); // pp
            y = concat_c(&[&br0, &br1, &br2]);
        }
        let p = global_avg_pool(&y);
        ctx.qmatmul(&p, false) // fc
    }

    fn distilbert(ctx: &mut ForwardCtx, x: &[f32], batch: usize) -> Mat {
        let manifest = ctx.manifest;
        let t = manifest.input_shape[0];
        let d = manifest.qlayers[0].n;
        let embed = ctx.digital("d_embed");
        let pos = ctx.digital("d_pos");
        let vocab = embed.shape[0];

        let mut h = Mat::zeros(batch * t, d);
        for bi in 0..batch {
            for ti in 0..t {
                let tok =
                    (x[bi * t + ti].max(0.0) as usize).min(vocab - 1);
                let erow = &embed.data[tok * d..(tok + 1) * d];
                let prow = &pos.data[ti * d..(ti + 1) * d];
                let orow = &mut h.data
                    [(bi * t + ti) * d..(bi * t + ti + 1) * d];
                for dd in 0..d {
                    orow[dd] = erow[dd] + prow[dd];
                }
            }
        }

        let n_layers = (manifest.nq() - 1) / 6;
        for l in 0..n_layers {
            let q = ctx.qmatmul(&h, false);
            let k = ctx.qmatmul(&h, false);
            let v = ctx.qmatmul(&h, false);
            let a = attention(&q, &k, &v, batch, t, BERT_HEADS);
            let o = ctx.qmatmul(&a, false);
            let ln1g = ctx.digital(&format!("d_l{l}_ln1_gamma"));
            let ln1b = ctx.digital(&format!("d_l{l}_ln1_beta"));
            h = layer_norm(&add_mat(&h, &o), &ln1g.data, &ln1b.data);
            let f = ctx.qmatmul(&h, true); // ff1
            let f = ctx.qmatmul(&f, false); // ff2
            let ln2g = ctx.digital(&format!("d_l{l}_ln2_gamma"));
            let ln2b = ctx.digital(&format!("d_l{l}_ln2_beta"));
            h = layer_norm(&add_mat(&h, &f), &ln2g.data, &ln2b.data);
        }
        let pooled = mean_over_seq(&h, batch, t);
        ctx.qmatmul(&pooled, false) // cls
    }
}

/// The four paper topologies the old backend hardcoded.
const GOLDEN_MODELS: [&str; 4] = ["resnet", "vgg", "inception", "distilbert"];

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

fn fresh_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bskmq_golden_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// collect mode: logits, per-layer subsamples and tile absmax all
/// bit/value-identical to the pre-refactor forward.
#[test]
fn graph_collect_matches_hardcoded_forwards_bitwise() {
    for model in GOLDEN_MODELS {
        let dir = fresh_dir(&format!("collect_{model}"));
        synth::write_model(&dir, model, 42).unwrap();
        let be = load(BackendKind::Native, &dir, model).unwrap();
        let data = ModelData::load(&dir, model).unwrap();
        let m = be.manifest();
        let xb = ModelData::batch(&data.x_calib, 0, m.batch);

        let got = be.run_collect(xb).unwrap();

        let mut ctx = oracle::ForwardCtx::new(
            m,
            be.weights(),
            oracle::Mode::Collect {
                samples: Vec::new(),
                tile_max: Vec::new(),
            },
        );
        let want = oracle::forward(model, &mut ctx, xb, m.batch);
        assert_eq!(
            bits(&got.logits),
            bits(&want.data),
            "{model}: collect logits diverged from the pre-refactor forward"
        );
        let oracle::Mode::Collect { samples, tile_max } = ctx.mode else {
            unreachable!()
        };
        assert_eq!(got.samples, samples, "{model}: collect subsamples");
        assert_eq!(got.tile_max, tile_max, "{model}: collect tile absmax");
    }
}

/// quant mode: calibrated qfwd logits bit-identical, with zero noise and
/// with conversion noise (same per-(layer, row) seeding).
#[test]
fn graph_qfwd_matches_hardcoded_forwards_bitwise() {
    for model in GOLDEN_MODELS {
        let dir = fresh_dir(&format!("qfwd_{model}"));
        synth::write_model(&dir, model, 42).unwrap();
        let be = load(BackendKind::Native, &dir, model).unwrap();
        let data = ModelData::load(&dir, model).unwrap();
        let m = be.manifest();
        let calib = Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::BsKmq, 3))
            .calibrate(&data, 3)
            .unwrap();
        let xt = ModelData::batch(&data.x_test, 0, m.batch);

        for (noise_std, seed) in [(0.0f32, 7u32), (0.5, 9)] {
            let got = be
                .run_qfwd(xt, &calib.programmed, noise_std, seed)
                .unwrap();
            let mut ctx = oracle::ForwardCtx::new(
                m,
                be.weights(),
                oracle::Mode::Quant {
                    books: &calib.programmed,
                    noise_std,
                    seed,
                },
            );
            let want = oracle::forward(model, &mut ctx, xt, m.batch);
            assert_eq!(
                bits(&got),
                bits(&want.data),
                "{model} (noise {noise_std}, seed {seed}): qfwd logits \
                 diverged from the pre-refactor forward"
            );
        }
    }
}

/// The **pre-refactor calibration pipeline**, captured verbatim from
/// `coordinator/calibrate.rs` + `quant/bs_kmq.rs` before the streaming
/// mergeable `QuantEstimator` redesign: the sequential EMA-range BS-KMQ
/// calibrator (incremental `observe`, capped buffer with a live
/// reservoir RNG) and the old `Calibrator::calibrate` BS-KMQ path with
/// its crate-wide `TILE_BITS = 7`.  Do not "modernize" this code: its
/// value is that it is the exact computation the old API performed.
mod oracle_calib {
    use super::*;
    use bskmq::quant::kmeans_1d;
    use bskmq::util::rng::Rng;
    use bskmq::util::stats::quantile_sorted;

    const EMA_KEEP: f64 = 0.9;
    const EMA_NEW: f64 = 0.1;
    const TILE_BITS: u32 = 7;

    pub struct OldBsKmq {
        alpha: f64,
        g_min: Option<f64>,
        g_max: Option<f64>,
        buffer: Vec<f64>,
        max_buffer: usize,
        rng: Rng,
    }

    impl OldBsKmq {
        fn new(alpha: f64, max_buffer: usize, seed: u64) -> OldBsKmq {
            OldBsKmq {
                alpha,
                g_min: None,
                g_max: None,
                buffer: Vec::new(),
                max_buffer,
                rng: Rng::new(seed),
            }
        }

        fn observe(&mut self, batch: &[f64]) {
            if batch.is_empty() {
                return;
            }
            let mut sorted = batch.to_vec();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let p_low = quantile_sorted(&sorted, self.alpha);
            let p_high = quantile_sorted(&sorted, 1.0 - self.alpha);
            let mut cent: Vec<f64> = batch
                .iter()
                .copied()
                .filter(|&a| a >= p_low && a <= p_high)
                .collect();
            if cent.is_empty() {
                cent = batch.to_vec();
            }
            let b_min = cent.iter().copied().fold(f64::INFINITY, f64::min);
            let b_max =
                cent.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            match (self.g_min, self.g_max) {
                (None, _) | (_, None) => {
                    self.g_min = Some(b_min);
                    self.g_max = Some(b_max);
                }
                (Some(gmin), Some(gmax)) => {
                    self.g_min = Some(EMA_KEEP * gmin + EMA_NEW * b_min);
                    self.g_max = Some(EMA_KEEP * gmax + EMA_NEW * b_max);
                }
            }
            if self.buffer.len() + cent.len() > self.max_buffer {
                let keep = self.max_buffer.saturating_sub(self.buffer.len());
                if keep == 0 {
                    return;
                }
                cent = self.rng.sample(&cent, keep);
            }
            self.buffer.extend_from_slice(&cent);
        }

        fn finish(&self, bits: u32, seed: u64) -> Vec<f64> {
            let (g_min, g_max) = (self.g_min.unwrap(), self.g_max.unwrap());
            let g_max = if g_max > g_min { g_max } else { g_min + 1e-8 };
            let k_interior = (1usize << bits) - 2;
            if k_interior == 0 {
                return vec![g_min, g_max];
            }
            let interior: Vec<f64> = self
                .buffer
                .iter()
                .map(|&s| s.clamp(g_min, g_max))
                .filter(|&s| s > g_min && s < g_max)
                .collect();
            let mut cq = if interior.len() < k_interior {
                even_interior(g_min, g_max, k_interior)
            } else {
                let mut c = kmeans_1d(&interior, k_interior, 50, seed);
                if c.len() < k_interior {
                    let pad =
                        even_interior(g_min, g_max, k_interior - c.len());
                    c.extend(pad);
                    c.sort_by(|a, b| a.partial_cmp(b).unwrap());
                }
                c
            };
            let mut centers = Vec::with_capacity(k_interior + 2);
            centers.push(g_min);
            centers.append(&mut cq);
            centers.push(g_max);
            centers.sort_by(|a, b| a.partial_cmp(b).unwrap());
            centers
        }
    }

    fn even_interior(lo: f64, hi: f64, k: usize) -> Vec<f64> {
        let step = (hi - lo) / (k + 1) as f64;
        (1..=k).map(|i| lo + step * i as f64).collect()
    }

    /// The old `Calibrator::new(backend, Method::BsKmq, bits)
    /// .calibrate(data, n_batches)` — per-layer NL + 7-bit tile books.
    pub fn calibrate(
        backend: &dyn Backend,
        data: &ModelData,
        n_batches: usize,
        bits: u32,
    ) -> (Vec<Codebook>, Vec<Codebook>) {
        let m = backend.manifest();
        let nq = m.nq();
        let mut calibs: Vec<OldBsKmq> = (0..nq)
            .map(|i| OldBsKmq::new(0.005, 200_000, i as u64))
            .collect();
        let mut tile_max = vec![0f64; nq];
        for b in 0..n_batches {
            let xb = ModelData::batch(&data.x_calib, b, m.batch);
            let out = backend.run_collect(xb).unwrap();
            for i in 0..nq {
                calibs[i].observe(&out.samples[i]);
                tile_max[i] = tile_max[i].max(out.tile_max[i]);
            }
        }
        let mut nl = Vec::with_capacity(nq);
        let mut tile = Vec::with_capacity(nq);
        for i in 0..nq {
            let centers = calibs[i].finish(bits, i as u64);
            nl.push(
                Codebook::from_centers(&centers).project_to_hardware(bits),
            );
            let r = tile_max[i].max(1e-6);
            tile.push(Codebook::linear(-r, r, TILE_BITS));
        }
        (nl, tile)
    }
}

/// Tentpole parity gate for the vectorized hot path (DESIGN.md §12):
/// across **all five** synthetic topologies, in both execution modes,
/// with and without conversion noise, the runtime-dispatched SIMD path
/// must be bit-identical to the forced-scalar fallback — logits,
/// activation subsamples and tile absmax alike.
#[test]
fn simd_and_scalar_paths_bit_identical_across_topologies() {
    for model in synth::MODELS {
        let dir = fresh_dir(&format!("simd_{model}"));
        synth::write_model(&dir, model, 42).unwrap();
        let be = load(BackendKind::Native, &dir, model).unwrap();
        let data = ModelData::load(&dir, model).unwrap();
        let m = be.manifest();
        let calib =
            Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::BsKmq, 3))
                .calibrate(&data, 3)
                .unwrap();
        let xb = ModelData::batch(&data.x_calib, 0, m.batch);
        let xt = ModelData::batch(&data.x_test, 0, m.batch);

        let run = || {
            let collect = be.run_collect(xb).unwrap();
            let quant: Vec<Vec<f32>> = [(0.0f32, 7u32), (0.5, 9)]
                .iter()
                .map(|&(noise_std, seed)| {
                    be.run_qfwd(xt, &calib.programmed, noise_std, seed)
                        .unwrap()
                })
                .collect();
            (collect, quant)
        };

        simd::force_scalar(true);
        let (sc, sq) = run();
        simd::force_scalar(false);
        let (vc, vq) = run();

        assert_eq!(
            bits(&sc.logits),
            bits(&vc.logits),
            "{model}: collect logits diverged between scalar and SIMD"
        );
        assert_eq!(sc.samples, vc.samples, "{model}: collect subsamples");
        assert_eq!(sc.tile_max, vc.tile_max, "{model}: collect tile absmax");
        for (i, (s, v)) in sq.iter().zip(&vq).enumerate() {
            assert_eq!(
                bits(s),
                bits(v),
                "{model}: qfwd noise variant {i} diverged between scalar \
                 and SIMD"
            );
        }
    }
}

/// Tentpole parity gate for the persistent executor pool (DESIGN.md
/// §14): across **all five** synthetic topologies, in both execution
/// modes, with and without conversion noise, and under thread budgets
/// 1/4/8, the pool path must be bit-identical to the per-op
/// scoped-spawn path — logits, activation subsamples and tile absmax
/// alike.  The deterministic static row partitioning is seeded per row,
/// so neither the thread count nor the dispatch mechanism may move a
/// single bit.
#[test]
fn executor_pool_and_scoped_spawn_bit_identical_across_topologies() {
    use bskmq::backend::native::{exec_pool, ops};
    for model in synth::MODELS {
        let dir = fresh_dir(&format!("pool_{model}"));
        synth::write_model(&dir, model, 42).unwrap();
        let be = load(BackendKind::Native, &dir, model).unwrap();
        let data = ModelData::load(&dir, model).unwrap();
        let m = be.manifest();
        let calib =
            Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::BsKmq, 3))
                .calibrate(&data, 3)
                .unwrap();
        let xb = ModelData::batch(&data.x_calib, 0, m.batch);
        let xt = ModelData::batch(&data.x_test, 0, m.batch);

        let run = || {
            let collect = be.run_collect(xb).unwrap();
            let quant: Vec<Vec<f32>> = [(0.0f32, 7u32), (0.5, 9)]
                .iter()
                .map(|&(noise_std, seed)| {
                    be.run_qfwd(xt, &calib.programmed, noise_std, seed)
                        .unwrap()
                })
                .collect();
            (collect, quant)
        };

        // reference: single-threaded scoped spawn (degenerates to the
        // inline serial path)
        ops::set_thread_override(Some(1));
        exec_pool::force_spawn(true);
        let (rc, rq) = run();

        for threads in [1usize, 4, 8] {
            ops::set_thread_override(Some(threads));
            for spawn in [true, false] {
                exec_pool::force_spawn(spawn);
                let (c, q) = run();
                let tag = format!(
                    "{model} ({threads} threads, {})",
                    if spawn { "scoped spawn" } else { "executor pool" }
                );
                assert_eq!(
                    bits(&rc.logits),
                    bits(&c.logits),
                    "{tag}: collect logits diverged"
                );
                assert_eq!(rc.samples, c.samples, "{tag}: collect subsamples");
                assert_eq!(rc.tile_max, c.tile_max, "{tag}: collect tile absmax");
                for (i, (r, g)) in rq.iter().zip(&q).enumerate() {
                    assert_eq!(
                        bits(r),
                        bits(g),
                        "{tag}: qfwd noise variant {i} diverged"
                    );
                }
            }
        }
        exec_pool::force_spawn(false);
        ops::set_thread_override(None);
    }
}

/// Four replicas of one program hammering the shared executor pool
/// concurrently must each produce the exact logits of an undisturbed
/// single run: per-job weighted leasing divides the budget but cannot
/// change the deterministic per-row partitioning.
#[test]
fn concurrent_replicas_on_shared_pool_stay_bit_identical() {
    use bskmq::backend::native::{exec_pool, ops};
    let dir = fresh_dir("pool_replicas");
    synth::write_model(&dir, "resnet", 42).unwrap();
    let be = load(BackendKind::Native, &dir, "resnet").unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let m = be.manifest();
    let calib =
        Calibrator::with_uniform(be.as_ref(), QuantSpec::new(Method::BsKmq, 3))
            .calibrate(&data, 3)
            .unwrap();
    let xt = ModelData::batch(&data.x_test, 0, m.batch);

    ops::set_thread_override(Some(4));
    exec_pool::force_spawn(false);
    let want = be.run_qfwd(xt, &calib.programmed, 0.5, 9).unwrap();
    let replicas: Vec<_> = (0..4)
        .map(|_| be.replicate().expect("native backends replicate"))
        .collect();
    std::thread::scope(|scope| {
        for (ri, r) in replicas.into_iter().enumerate() {
            let want = &want;
            let calib = &calib;
            scope.spawn(move || {
                for iter in 0..4 {
                    let got =
                        r.run_qfwd(xt, &calib.programmed, 0.5, 9).unwrap();
                    assert_eq!(
                        bits(&got),
                        bits(want),
                        "replica {ri} iter {iter}: logits diverged under \
                         concurrent pool sharing"
                    );
                }
            });
        }
    });
    ops::set_thread_override(None);
}

/// Backward-compat shim: a manifest **without** per-layer quant specs
/// (the pre-QuantSpec schema) must resolve to defaults that reproduce
/// the old uniform BS-KMQ/3-bit calibration *bit for bit* — codebooks
/// and end-to-end logits both — against the pre-refactor pipeline
/// captured in `oracle_calib`.
#[test]
fn default_spec_calibration_matches_pre_refactor_pipeline() {
    let dir = fresh_dir("compat");
    synth::write_model(&dir, "resnet", 42).unwrap();
    let be = load(BackendKind::Native, &dir, "resnet").unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();

    // strip the emitted per-layer specs: this is what a pre-refactor
    // manifest looks like to the new loader
    let mut stripped_manifest = be.manifest().clone();
    for q in &mut stripped_manifest.qlayers {
        q.spec = None;
    }
    let stripped =
        NativeBackend::from_parts(stripped_manifest, be.weights().to_vec())
            .unwrap();

    let calib = Calibrator::from_manifest(&stripped)
        .calibrate(&data, 3)
        .unwrap();
    let (nl_want, tile_want) = oracle_calib::calibrate(&stripped, &data, 3, 3);

    let book_bits = |b: &Codebook| -> (Vec<u64>, Vec<u64>) {
        (
            b.centers.iter().map(|c| c.to_bits()).collect(),
            b.refs.iter().map(|r| r.to_bits()).collect(),
        )
    };
    for i in 0..stripped.manifest().nq() {
        assert_eq!(
            book_bits(&calib.nl_books[i]),
            book_bits(&nl_want[i]),
            "layer {i}: default-spec NL codebook diverged from the \
             pre-refactor calibrator"
        );
        assert_eq!(
            book_bits(&calib.tile_books[i]),
            book_bits(&tile_want[i]),
            "layer {i}: default-spec tile codebook diverged"
        );
    }

    // end-to-end: logits through both book sets are bit-identical
    let m = stripped.manifest();
    let xt = ModelData::batch(&data.x_test, 0, m.batch);
    let got = stripped.run_qfwd(xt, &calib.programmed, 0.0, 7).unwrap();
    let want_books =
        ProgrammedCodebooks::stack(&nl_want, &tile_want, m.max_levels)
            .unwrap();
    let want = stripped.run_qfwd(xt, &want_books, 0.0, 7).unwrap();
    assert_eq!(
        bits(&got),
        bits(&want),
        "default-spec logits diverged from the pre-refactor artifact"
    );

    // the synth-emitted resnet specs ARE the historical defaults, so the
    // unstripped manifest must produce the same books
    let emitted = Calibrator::from_manifest(be.as_ref())
        .calibrate(&data, 3)
        .unwrap();
    for i in 0..m.nq() {
        assert_eq!(
            book_bits(&emitted.nl_books[i]),
            book_bits(&calib.nl_books[i]),
            "layer {i}: emitted resnet specs differ from defaults"
        );
    }
}
