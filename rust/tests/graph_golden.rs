//! Golden-equivalence tests for the layer-graph executor.
//!
//! The `oracle` module below is the **pre-refactor hardcoded forward**,
//! captured verbatim from `backend/native/models.rs` before that file
//! was deleted (PR "manifest-driven layer-graph IR").  It consumes the
//! same public `ops` kernels and the same per-(layer, salt) noise
//! seeding, so it reproduces the old per-model `forward_infer` paths
//! bit-for-bit — and every test here asserts that the generic graph
//! executor's logits (and, in collect mode, activation subsamples and
//! tile absmax) are **bit-identical** to it, in both execution modes,
//! with and without conversion noise, across all four paper topologies.

use bskmq::backend::native::graph::{layer_seed, NL_SEED_SALT};
use bskmq::backend::native::ops::{
    add_bias_relu, add_mat, add_relu, attention, avg_pool3_same,
    collect_subsample, concat_c, global_avg_pool, im2col, layer_norm,
    max_pool2, mean_over_seq, min_ref_step, nl_convert, tiled_mac, Feat, Mat,
    QuantSpec,
};
use bskmq::backend::{load, Backend, BackendKind, ProgrammedCodebooks};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::data::dataset::ModelData;
use bskmq::data::synth;
use bskmq::io::manifest::Manifest;
use bskmq::macro_model::ROWS;
use bskmq::quant::Method;
use bskmq::tensor::Tensor;

/// The four pre-refactor hand-written forwards, preserved as the golden
/// reference.  Do not "modernize" this code: its value is that it is the
/// exact computation the deleted `models.rs` performed.
mod oracle {
    use super::*;

    /// Transformer head count of the mini DistilBERT (export-side
    /// constant of the old native backend).
    const BERT_HEADS: usize = 4;

    pub enum Mode<'a> {
        Collect {
            samples: Vec<Vec<f64>>,
            tile_max: Vec<f64>,
        },
        Quant {
            books: &'a ProgrammedCodebooks,
            noise_std: f32,
            seed: u32,
        },
    }

    pub struct ForwardCtx<'a> {
        pub manifest: &'a Manifest,
        pub weights: &'a [Tensor],
        pub mode: Mode<'a>,
        qi: usize,
    }

    impl<'a> ForwardCtx<'a> {
        pub fn new(
            manifest: &'a Manifest,
            weights: &'a [Tensor],
            mode: Mode<'a>,
        ) -> ForwardCtx<'a> {
            ForwardCtx {
                manifest,
                weights,
                mode,
                qi: 0,
            }
        }

        fn digital(&self, name: &str) -> &'a Tensor {
            let idx = self
                .manifest
                .weight_args
                .iter()
                .position(|wa| wa.name == name)
                .unwrap_or_else(|| panic!("digital param '{name}' missing"));
            &self.weights[idx]
        }

        fn qmatmul(&mut self, x: &Mat, relu: bool) -> Mat {
            let wi = self.qi;
            self.qi += 1;
            let w = &self.weights[2 * wi];
            let bias = &self.weights[2 * wi + 1];
            assert_eq!(
                self.manifest.qlayers[wi].relu, relu,
                "oracle relu flag out of sync at layer {wi}"
            );
            match &mut self.mode {
                Mode::Collect { samples, tile_max } => {
                    let (mut y, absmax) = tiled_mac(x, w, ROWS, None);
                    add_bias_relu(&mut y, &bias.data, relu);
                    tile_max.push(absmax);
                    samples.push(collect_subsample(
                        &y.data,
                        self.manifest.samples_per_layer,
                    ));
                    y
                }
                Mode::Quant {
                    books,
                    noise_std,
                    seed,
                } => {
                    let (n_refs, n_centers, t_refs, t_centers) =
                        books.layer_rows(wi);
                    let spec = QuantSpec {
                        refs: t_refs,
                        centers: t_centers,
                        sigma: *noise_std * min_ref_step(t_refs),
                        seed: layer_seed(*seed, wi, 0),
                    };
                    let (mut y, _) = tiled_mac(x, w, ROWS, Some(&spec));
                    add_bias_relu(&mut y, &bias.data, relu);
                    nl_convert(
                        &mut y,
                        n_refs,
                        n_centers,
                        *noise_std * min_ref_step(n_refs),
                        layer_seed(*seed, wi, NL_SEED_SALT),
                    );
                    y
                }
            }
        }

        fn qconv(
            &mut self,
            x: &Feat,
            k: usize,
            stride: usize,
            relu: bool,
        ) -> Feat {
            let (x2d, oh, ow) = im2col(x, k, k, stride, true);
            let y = self.qmatmul(&x2d, relu);
            Feat::from_mat(y, x.b, oh, ow)
        }
    }

    pub fn forward(
        model: &str,
        ctx: &mut ForwardCtx,
        x: &[f32],
        batch: usize,
    ) -> Mat {
        let logits = if model == "distilbert" {
            distilbert(ctx, x, batch)
        } else {
            let m = ctx.manifest;
            let (h, w, c) =
                (m.input_shape[0], m.input_shape[1], m.input_shape[2]);
            let feat = Feat::new(batch, h, w, c, x.to_vec());
            match model {
                "resnet" => resnet(ctx, feat),
                "vgg" => vgg(ctx, feat),
                "inception" => inception(ctx, feat),
                other => panic!("oracle has no forward for '{other}'"),
            }
        };
        assert_eq!(ctx.qi, ctx.manifest.nq(), "oracle q-layer count");
        logits
    }

    fn resnet(ctx: &mut ForwardCtx, x: Feat) -> Mat {
        let y = ctx.qconv(&x, 3, 1, true); // conv0
        let h = ctx.qconv(&y, 3, 1, true); // b1c1
        let h = ctx.qconv(&h, 3, 1, false); // b1c2
        let y = add_relu(&y, &h);
        let h = ctx.qconv(&y, 3, 2, true); // b2c1
        let h = ctx.qconv(&h, 3, 1, false); // b2c2
        let sc = ctx.qconv(&y, 1, 2, false); // b2sc
        let y = add_relu(&h, &sc);
        let p = global_avg_pool(&y);
        ctx.qmatmul(&p, false) // fc
    }

    fn vgg(ctx: &mut ForwardCtx, x: Feat) -> Mat {
        const POOL_AFTER: [bool; 5] = [false, true, false, true, true];
        let mut y = x;
        for pool in POOL_AFTER {
            y = ctx.qconv(&y, 3, 1, true);
            if pool {
                y = max_pool2(&y);
            }
        }
        let m = y.flatten();
        let m = ctx.qmatmul(&m, true); // fc1
        ctx.qmatmul(&m, false) // fc2
    }

    fn inception(ctx: &mut ForwardCtx, x: Feat) -> Mat {
        let mut y = max_pool2(&ctx.qconv(&x, 3, 1, true)); // stem
        for _ in 0..2 {
            let br0 = ctx.qconv(&y, 1, 1, true); // b0
            let t = ctx.qconv(&y, 1, 1, true); // b1a
            let br1 = ctx.qconv(&t, 3, 1, true); // b1b
            let pooled = avg_pool3_same(&y);
            let br2 = ctx.qconv(&pooled, 1, 1, true); // pp
            y = concat_c(&[&br0, &br1, &br2]);
        }
        let p = global_avg_pool(&y);
        ctx.qmatmul(&p, false) // fc
    }

    fn distilbert(ctx: &mut ForwardCtx, x: &[f32], batch: usize) -> Mat {
        let manifest = ctx.manifest;
        let t = manifest.input_shape[0];
        let d = manifest.qlayers[0].n;
        let embed = ctx.digital("d_embed");
        let pos = ctx.digital("d_pos");
        let vocab = embed.shape[0];

        let mut h = Mat::zeros(batch * t, d);
        for bi in 0..batch {
            for ti in 0..t {
                let tok =
                    (x[bi * t + ti].max(0.0) as usize).min(vocab - 1);
                let erow = &embed.data[tok * d..(tok + 1) * d];
                let prow = &pos.data[ti * d..(ti + 1) * d];
                let orow = &mut h.data
                    [(bi * t + ti) * d..(bi * t + ti + 1) * d];
                for dd in 0..d {
                    orow[dd] = erow[dd] + prow[dd];
                }
            }
        }

        let n_layers = (manifest.nq() - 1) / 6;
        for l in 0..n_layers {
            let q = ctx.qmatmul(&h, false);
            let k = ctx.qmatmul(&h, false);
            let v = ctx.qmatmul(&h, false);
            let a = attention(&q, &k, &v, batch, t, BERT_HEADS);
            let o = ctx.qmatmul(&a, false);
            let ln1g = ctx.digital(&format!("d_l{l}_ln1_gamma"));
            let ln1b = ctx.digital(&format!("d_l{l}_ln1_beta"));
            h = layer_norm(&add_mat(&h, &o), &ln1g.data, &ln1b.data);
            let f = ctx.qmatmul(&h, true); // ff1
            let f = ctx.qmatmul(&f, false); // ff2
            let ln2g = ctx.digital(&format!("d_l{l}_ln2_gamma"));
            let ln2b = ctx.digital(&format!("d_l{l}_ln2_beta"));
            h = layer_norm(&add_mat(&h, &f), &ln2g.data, &ln2b.data);
        }
        let pooled = mean_over_seq(&h, batch, t);
        ctx.qmatmul(&pooled, false) // cls
    }
}

/// The four paper topologies the old backend hardcoded.
const GOLDEN_MODELS: [&str; 4] = ["resnet", "vgg", "inception", "distilbert"];

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

fn fresh_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bskmq_golden_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// collect mode: logits, per-layer subsamples and tile absmax all
/// bit/value-identical to the pre-refactor forward.
#[test]
fn graph_collect_matches_hardcoded_forwards_bitwise() {
    for model in GOLDEN_MODELS {
        let dir = fresh_dir(&format!("collect_{model}"));
        synth::write_model(&dir, model, 42).unwrap();
        let be = load(BackendKind::Native, &dir, model).unwrap();
        let data = ModelData::load(&dir, model).unwrap();
        let m = be.manifest();
        let xb = ModelData::batch(&data.x_calib, 0, m.batch);

        let got = be.run_collect(xb).unwrap();

        let mut ctx = oracle::ForwardCtx::new(
            m,
            be.weights(),
            oracle::Mode::Collect {
                samples: Vec::new(),
                tile_max: Vec::new(),
            },
        );
        let want = oracle::forward(model, &mut ctx, xb, m.batch);
        assert_eq!(
            bits(&got.logits),
            bits(&want.data),
            "{model}: collect logits diverged from the pre-refactor forward"
        );
        let oracle::Mode::Collect { samples, tile_max } = ctx.mode else {
            unreachable!()
        };
        assert_eq!(got.samples, samples, "{model}: collect subsamples");
        assert_eq!(got.tile_max, tile_max, "{model}: collect tile absmax");
    }
}

/// quant mode: calibrated qfwd logits bit-identical, with zero noise and
/// with conversion noise (same per-(layer, row) seeding).
#[test]
fn graph_qfwd_matches_hardcoded_forwards_bitwise() {
    for model in GOLDEN_MODELS {
        let dir = fresh_dir(&format!("qfwd_{model}"));
        synth::write_model(&dir, model, 42).unwrap();
        let be = load(BackendKind::Native, &dir, model).unwrap();
        let data = ModelData::load(&dir, model).unwrap();
        let m = be.manifest();
        let calib = Calibrator::new(be.as_ref(), Method::BsKmq, 3)
            .calibrate(&data, 3)
            .unwrap();
        let xt = ModelData::batch(&data.x_test, 0, m.batch);

        for (noise_std, seed) in [(0.0f32, 7u32), (0.5, 9)] {
            let got = be
                .run_qfwd(xt, &calib.programmed, noise_std, seed)
                .unwrap();
            let mut ctx = oracle::ForwardCtx::new(
                m,
                be.weights(),
                oracle::Mode::Quant {
                    books: &calib.programmed,
                    noise_std,
                    seed,
                },
            );
            let want = oracle::forward(model, &mut ctx, xt, m.batch);
            assert_eq!(
                bits(&got),
                bits(&want.data),
                "{model} (noise {noise_std}, seed {seed}): qfwd logits \
                 diverged from the pre-refactor forward"
            );
        }
    }
}
