//! Online shadow recalibration: hot-swap atomicity and the end-to-end
//! drift -> refit -> swap loop (DESIGN.md §15), on synthetic artifacts.
//!
//! Pins the recalibration contract:
//!
//! * a codebook hot-swap under concurrent clients is atomic — every
//!   reply is bit-identical to ONE of the two generations, never a mix,
//!   and nothing is dropped, shed, or errored because of the swap;
//! * with the controller live, a sustained distribution shift drives
//!   sketch drift past the threshold, a shadow-window refit fires, the
//!   new generation is published with zero client-visible disruption,
//!   and post-swap drift (measured against the refit baseline) settles
//!   back below the threshold;
//! * the swap counters agree across the `stats` JSON and the
//!   Prometheus page;
//! * a pool asked to recalibrate without quant-health telemetry fails
//!   fast at startup instead of serving silently degraded.
//!
//! CI runs this suite with `BSKMQ_THREADS` at 1 and 8 (the `recalib`
//! job) to catch thread-count-dependent behavior.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use bskmq::backend::{Backend, BackendKind, ProgrammedCodebooks};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::coordinator::loadgen::{closed_loop, scaled_inputs};
use bskmq::coordinator::pool::{ModelPool, ObsConfig, PoolConfig};
use bskmq::coordinator::recalib::RecalibConfig;
use bskmq::data::dataset::ModelData;
use bskmq::data::synth;
use bskmq::obs::prometheus::PromWriter;
use bskmq::quant::codebook::Codebook;
use bskmq::util::json::Json;

const UNIQUE_INPUTS: usize = 6;
const CLIENT_THREADS: usize = 8;
const REQS_PER_THREAD: usize = 32;

fn fresh_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bskmq_recalib_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    synth::write_model(&dir, "resnet", 42).unwrap();
    dir
}

fn base_cfg(replicas: usize) -> PoolConfig {
    PoolConfig {
        backend: BackendKind::Native,
        noise_std: 0.0,
        calib_batches: 2,
        replicas,
        queue_depth: 4096,
        batch_window: Duration::from_millis(1),
        ..PoolConfig::default()
    }
}

fn unique_inputs(dir: &std::path::Path) -> Vec<Vec<f32>> {
    let data = ModelData::load(dir, "resnet").unwrap();
    let elems: usize = data.x_test.shape[1..].iter().product();
    (0..UNIQUE_INPUTS)
        .map(|i| data.x_test.data[i * elems..(i + 1) * elems].to_vec())
        .collect()
}

/// Expected logits for one input under one programmed generation: with
/// zero conversion noise the quantized forward is deterministic per
/// sample, so a direct backend run reproduces the pool bit-for-bit.
fn expected_logits(
    be: &dyn Backend,
    books: &ProgrammedCodebooks,
    input: &[f32],
) -> Vec<f32> {
    let m = be.manifest();
    let mut x = Vec::with_capacity(m.batch * input.len());
    for _ in 0..m.batch {
        x.extend_from_slice(input);
    }
    let logits = be.run_qfwd(&x, books, 0.0, 7).unwrap();
    logits[..m.num_classes].to_vec()
}

/// Swap atomicity under concurrent clients (the soak half of satellite
/// 3).  Reference logits for generation A (the pool's own calibration,
/// reproduced bit-identically offline) and generation B (NL centers
/// scaled 5%) are computed up front; a [`ModelPool::hot_swap`] lands
/// mid-soak, and every concurrent reply must be bitwise equal to
/// exactly one of the two — no drops, no errors, no mixed-generation
/// replies.
#[test]
fn hot_swap_is_atomic_under_concurrent_clients() {
    let dir = fresh_dir("atomic");
    let inputs = unique_inputs(&dir);

    // reproduce the pool's generation-A books offline: same specs, same
    // batch count, serial shards (base_cfg) -> bit-identical codebooks
    let be = bskmq::backend::load(BackendKind::Native, &dir, "resnet").unwrap();
    let data = ModelData::load(&dir, "resnet").unwrap();
    let calib =
        Calibrator::with_specs(be.as_ref(), be.manifest().layer_specs())
            .calibrate_sharded(&data, 2, 1)
            .unwrap();
    let max_levels = be.manifest().max_levels;

    // generation B: every NL center scaled 5% — a valid ladder that
    // provably changes the computation
    let nl_b: Vec<Codebook> = calib
        .nl_books
        .iter()
        .map(|cb| {
            let centers: Vec<f64> =
                cb.centers.iter().map(|c| c * 1.05).collect();
            Codebook::from_centers(&centers)
        })
        .collect();
    let books_b =
        ProgrammedCodebooks::stack(&nl_b, &calib.tile_books, max_levels)
            .unwrap();

    let expect_a: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| expected_logits(be.as_ref(), &calib.programmed, x))
        .collect();
    let expect_b: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| expected_logits(be.as_ref(), &books_b, x))
        .collect();
    assert!(
        expect_a.iter().zip(&expect_b).any(|(a, b)| a != b),
        "scaled codebooks must change at least one input's logits"
    );
    drop(be);

    let pool =
        ModelPool::start(dir.clone(), "resnet".into(), &base_cfg(2)).unwrap();
    assert_eq!(pool.codebook_generation(), 1);
    // without recalib configured the stats block still reports the
    // generation and an explicit enabled=false
    let j = Json::parse(&pool.stats_json()).unwrap();
    let rj = j.get("recalib").unwrap();
    assert!(!rj.get("enabled").unwrap().as_bool().unwrap());
    assert_eq!(rj.get("generation").unwrap().as_usize().unwrap(), 1);

    // pre-swap: the pool serves generation A bit-for-bit
    for (i, x) in inputs.iter().enumerate() {
        assert_eq!(
            pool.infer(x.clone()).unwrap(),
            expect_a[i],
            "input {i} diverged from the offline generation-A forward"
        );
    }

    // soak with the hot-swap landing mid-flight
    let answered = AtomicU64::new(0);
    std::thread::scope(|s| {
        for t in 0..CLIENT_THREADS {
            let client = pool.client();
            let (inputs, expect_a, expect_b) = (&inputs, &expect_a, &expect_b);
            let answered = &answered;
            s.spawn(move || {
                for r in 0..REQS_PER_THREAD {
                    let idx = (t * 7 + r * 3) % UNIQUE_INPUTS;
                    let rx = client
                        .submit(inputs[idx].clone())
                        .expect("queue sized for the whole soak");
                    let logits = rx
                        .recv_timeout(Duration::from_secs(120))
                        .expect("accepted request must be answered")
                        .expect("request failed during the swap soak");
                    assert!(
                        logits == expect_a[idx] || logits == expect_b[idx],
                        "input {idx}: reply matches neither generation \
                         (a mixed-codebook batch?)"
                    );
                    answered.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        // swap while the soak is in flight; in-flight batches finish
        // under the generation they snapshotted
        std::thread::sleep(Duration::from_millis(10));
        let generation = pool
            .hot_swap(&nl_b, &calib.tile_books, None)
            .expect("hot swap failed");
        assert_eq!(generation, 2);
    });
    let total = (CLIENT_THREADS * REQS_PER_THREAD) as u64;
    assert_eq!(answered.load(Ordering::SeqCst), total, "replies went missing");
    assert_eq!(pool.shed(), 0, "the swap shed requests");
    assert_eq!(pool.rejected(), 0, "the swap rejected requests");
    assert_eq!(pool.codebook_generation(), 2);

    // post-swap: everything serves generation B bit-for-bit
    for (i, x) in inputs.iter().enumerate() {
        assert_eq!(
            pool.infer(x.clone()).unwrap(),
            expect_b[i],
            "input {i}: post-swap reply is not the generation-B forward"
        );
    }
    let j = Json::parse(&pool.stats_json()).unwrap();
    assert_eq!(
        j.get("recalib")
            .unwrap()
            .get("generation")
            .unwrap()
            .as_usize()
            .unwrap(),
        2
    );
}

/// A pool asked to recalibrate without quant-health telemetry must fail
/// at startup — the drift signal is the controller's trigger, so
/// starting without it would serve silently degraded.
#[test]
fn recalib_without_quant_health_fails_fast() {
    let dir = fresh_dir("nohealth");
    let mut cfg = base_cfg(1);
    cfg.obs.quant_health = false;
    cfg.recalib = Some(RecalibConfig::default());
    let err = ModelPool::start(dir, "resnet".into(), &cfg).unwrap_err();
    assert!(err.to_string().contains("quant-health"), "{err:#}");
}

/// Acceptance: drift detected -> shadow refit -> zero-downtime hot-swap
/// -> post-swap drift back below threshold, with the swap counters
/// agreeing between the `stats` JSON and the Prometheus page.
#[test]
fn drift_triggers_refit_and_zero_downtime_swap() {
    let dir = fresh_dir("e2e");
    let inputs = unique_inputs(&dir);
    let threshold = 0.3;
    let mut cfg = base_cfg(2);
    cfg.obs = ObsConfig {
        sketch_sample_every: 1,
        ..ObsConfig::default()
    };
    cfg.recalib = Some(RecalibConfig {
        sample_every: 1,
        drift_threshold: threshold,
        hysteresis: 0.5,
        min_observations: 32,
        trigger_checks: 2,
        check_interval: Duration::from_millis(5),
    });
    let pool = ModelPool::start(dir.clone(), "resnet".into(), &cfg).unwrap();
    let client = pool.client();
    let stats = pool.recalib().expect("recalib was configured").stats.clone();
    let deadline = Duration::from_secs(10);

    // matched traffic: live deciles agree with the calibration sketch,
    // so the detector must hold
    let p = closed_loop(&client, &inputs, "resnet", "base", 4, 256, deadline);
    assert_eq!(p.completed, 256, "{p:?}");
    assert_eq!(p.shed + p.rejected + p.errors, 0, "{p:?}");
    std::thread::sleep(Duration::from_millis(40)); // several supervisor ticks
    assert_eq!(
        stats.swaps.load(Ordering::SeqCst),
        0,
        "matched traffic must not trigger a refit (drift {})",
        stats.drift()
    );

    // sustained 4x-scaled traffic: every activation decile moves, drift
    // crosses the threshold, and the controller refits + swaps — with
    // zero dropped/shed/errored replies attributable to the swap
    let hot = scaled_inputs(&inputs, 4.0);
    let t0 = Instant::now();
    while stats.swaps.load(Ordering::SeqCst) == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "no hot-swap after 60s of shifted traffic (drift {}, {} shadow \
             batches, {} sampled)",
            stats.drift(),
            stats.shadow_batches.load(Ordering::SeqCst),
            stats.sampled.load(Ordering::SeqCst),
        );
        let p = closed_loop(&client, &hot, "resnet", "shift", 4, 64, deadline);
        assert_eq!(
            p.shed + p.rejected + p.errors,
            0,
            "the swap disrupted serving: {p:?}"
        );
    }
    assert!(pool.codebook_generation() >= 2, "swap without a generation bump");
    assert!(pool.quant_health().unwrap().rebaselines() >= 1);
    assert!(stats.refits.load(Ordering::SeqCst) >= 1);
    assert_eq!(stats.refit_errors.load(Ordering::SeqCst), 0);
    assert!(stats.last_refit_ns.load(Ordering::SeqCst) > 0);

    // post-swap: the SAME shifted traffic, now measured against the
    // refit baseline, must settle below the threshold (every layer's
    // live sketch repopulated, max divergence under the trigger)
    let t0 = Instant::now();
    loop {
        let p = closed_loop(&client, &hot, "resnet", "post", 4, 64, deadline);
        assert_eq!(p.shed + p.rejected + p.errors, 0, "{p:?}");
        let h = pool.quant_health().unwrap();
        let ds: Vec<Option<f64>> =
            (0..h.num_layers()).map(|q| h.divergence(q)).collect();
        if ds.iter().all(|d| d.is_some()) {
            let max = ds.iter().map(|d| d.unwrap()).fold(0.0, f64::max);
            if max < threshold {
                break;
            }
        }
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "post-swap drift never settled below the threshold: {ds:?}"
        );
    }

    // the swap counters agree across stats JSON and the Prometheus page
    let swaps = stats.swaps.load(Ordering::SeqCst);
    let generation = pool.codebook_generation();
    let j = Json::parse(&pool.stats_json()).unwrap();
    let rj = j.get("recalib").unwrap();
    assert!(rj.get("enabled").unwrap().as_bool().unwrap());
    assert_eq!(rj.get("swaps").unwrap().as_usize().unwrap() as u64, swaps);
    assert_eq!(
        rj.get("generation").unwrap().as_usize().unwrap() as u64,
        generation
    );
    assert!(rj.get("refits").unwrap().as_usize().unwrap() >= 1);
    let prom = {
        let mut w = PromWriter::new();
        pool.render_prometheus(&mut w);
        w.finish()
    };
    assert!(
        prom.contains(&format!(
            "bskmq_recalib_swaps_total{{model=\"resnet\"}} {swaps}"
        )),
        "{prom}"
    );
    assert!(
        prom.contains(&format!(
            "bskmq_codebook_generation{{model=\"resnet\"}} {generation}"
        )),
        "{prom}"
    );
}
