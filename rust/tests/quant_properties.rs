//! Property-based tests on the quantizer/codebook invariants (proptest is
//! not vendored offline; properties are checked over seeded random input
//! families via the library's own PRNG — same spirit, deterministic).
//! The mixture input family lives in `bskmq::data::synth` and is shared
//! with the cross-backend fuzz agreement tests.

use bskmq::data::synth::mixture_samples as random_samples;
use bskmq::quant::codebook::Codebook;
use bskmq::quant::Method;
use bskmq::util::rng::Rng;

/// Quantized output is always one of the codebook centers.
#[test]
fn prop_output_is_a_center() {
    let mut rng = Rng::new(101);
    for trial in 0..30 {
        let xs = random_samples(&mut rng, 2_000);
        let bits = 1 + (trial % 5) as u32;
        for m in Method::ALL {
            let cb = m.fit_hw(&xs, bits, 0);
            for &x in xs.iter().step_by(37) {
                let q = cb.quantize(x);
                assert!(
                    cb.centers.iter().any(|&c| (c - q).abs() < 1e-12),
                    "{}: q={q} not a center",
                    m.name()
                );
            }
        }
    }
}

/// Quantization is monotone: x <= y implies q(x) <= q(y).
#[test]
fn prop_quantize_monotone() {
    let mut rng = Rng::new(202);
    for _ in 0..20 {
        let xs = random_samples(&mut rng, 3_000);
        let cb = Method::BsKmq.fit_hw(&xs, 4, 0);
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev = f64::NEG_INFINITY;
        for &x in sorted.iter().step_by(11) {
            let q = cb.quantize(x);
            assert!(q >= prev, "monotonicity violated at {x}");
            prev = q;
        }
    }
}

/// Eq. 2 round trip: references derived from centers reproduce
/// nearest-center assignment for interior points.
#[test]
fn prop_refs_emulate_nearest_center() {
    let mut rng = Rng::new(303);
    for _ in 0..50 {
        let k = 2 + rng.below(30);
        let mut centers: Vec<f64> =
            (0..k).map(|_| rng.range(-10.0, 10.0)).collect();
        centers.sort_by(|a, b| a.partial_cmp(b).unwrap());
        centers.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        if centers.len() < 2 {
            continue;
        }
        let cb = Codebook::from_centers(&centers);
        for _ in 0..200 {
            let x = rng.range(centers[0], *centers.last().unwrap());
            let q = cb.quantize(x);
            // brute-force nearest center
            let nearest = cb
                .centers
                .iter()
                .copied()
                .min_by(|a, b| {
                    (x - a).abs().partial_cmp(&(x - b).abs()).unwrap()
                })
                .unwrap();
            assert!(
                (q - nearest).abs() < 1e-9
                    || ((x - q).abs() - (x - nearest).abs()).abs() < 1e-9,
                "x={x} q={q} nearest={nearest}"
            );
        }
    }
}

/// MSE never increases with more bits (same method, same data).  Checked
/// on the *ideal* codebooks; the hardware projection re-grids the ladder
/// per resolution so only a loose bound holds there.
#[test]
fn prop_mse_monotone_in_bits() {
    let mut rng = Rng::new(404);
    for _ in 0..10 {
        let xs = random_samples(&mut rng, 5_000);
        // NOTE: Linear min-max is deliberately excluded — on zero-spiked
        // data its MSE is NOT monotone in bits (whether the uniform grid
        // happens to align with the spike dominates), which is precisely
        // the weakness Fig. 1 exploits.
        for m in [Method::Cdf, Method::BsKmq] {
            let mut prev = f64::INFINITY;
            for bits in [2u32, 3, 4, 5, 6] {
                let mse = Codebook::from_centers(&m.fit(&xs, bits, 0)).mse(&xs);
                assert!(
                    mse <= prev * 1.10 + 1e-9,
                    "{} ideal mse grew {prev} -> {mse} at {bits}b",
                    m.name()
                );
                prev = prev.min(mse);
                // projected form: loose sanity bound only
                let hw = m.fit_hw(&xs, bits, 0).mse(&xs);
                assert!(hw.is_finite() && hw >= 0.0);
            }
        }
    }
}

/// BS-KMQ codebook always spans [g_min, g_max] with sorted centers.
#[test]
fn prop_bs_kmq_spans_range() {
    let mut rng = Rng::new(505);
    for _ in 0..30 {
        let xs = random_samples(&mut rng, 4_000);
        let centers = Method::BsKmq.fit(&xs, 3, 0);
        assert_eq!(centers.len(), 8);
        assert!(centers.windows(2).all(|w| w[0] <= w[1]));
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(centers[0] >= lo - 1e-9 && centers[7] <= hi + 1e-9);
    }
}

/// Hardware projection keeps every step at least one cell and never
/// exceeds the cell budget.
#[test]
fn prop_hw_projection_budget() {
    let mut rng = Rng::new(606);
    for trial in 0..40 {
        let xs = random_samples(&mut rng, 3_000);
        let bits = 2 + (trial % 4) as u32;
        let cb = Method::KMeans.fit_hw(&xs, bits, 0);
        let budget = Codebook::cell_budget(bits).unwrap();
        let dv = cb.min_step();
        if dv <= 0.0 {
            continue;
        }
        let total_cells: f64 = cb
            .refs
            .windows(2)
            .map(|w| (w[1] - w[0]) / dv)
            .sum::<f64>()
            .round();
        assert!(
            total_cells <= budget as f64 + 0.5,
            "projected ladder uses {total_cells} cells > budget {budget}"
        );
    }
}
