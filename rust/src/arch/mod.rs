//! System-level accelerator simulation (NeuroSim-style, §3.2 / Table 1):
//! maps a network's MAC layers onto a pool of 256x128 macros, adds the
//! peripheral costs NeuroSim estimates (buffers, interconnect,
//! accumulation), and produces TOPS / TOPS/W / accuracy-loss rows that
//! regenerate Table 1 — including the normalized comparison against the
//! three published IMC designs.

pub mod accelerator;
pub mod baselines;
pub mod mapping;

pub use accelerator::{Accelerator, SystemConfig, SystemReport};
pub use baselines::{baseline_designs, BaselineDesign};
pub use mapping::{LayerMapping, map_network};
