//! Layer-to-crossbar mapping: every MAC layer is tiled into 256-row x
//! (weight-column) crossbar allocations; multi-bit weights consume
//! parallel bitcells per §3.2, so the effective columns per macro shrink
//! with weight precision.

use crate::macro_model::weights::weight_columns;
use crate::macro_model::ROWS;
use crate::nn::zoo::{Layer, Network};

/// How one layer lands on the macro pool.
#[derive(Clone, Debug)]
pub struct LayerMapping {
    pub name: String,
    /// crossbar tiles along the contraction dimension (ceil(K/256))
    pub k_tiles: usize,
    /// crossbar tiles along the output dimension
    pub n_tiles: usize,
    /// macro passes needed per inference (tiles x output positions)
    pub passes: f64,
    /// digital partial-sum accumulations per inference
    pub accumulations: f64,
    /// activations written to / read from buffers per inference
    pub buffer_accesses: f64,
}

/// Map a whole network at a weight precision.
pub fn map_network(net: &Network, w_bits: u32) -> Vec<LayerMapping> {
    let wcols = weight_columns(w_bits);
    net.layers
        .iter()
        .map(|l| map_layer(l, wcols))
        .collect()
}

fn map_layer(l: &Layer, wcols: usize) -> LayerMapping {
    let k_tiles = l.k.div_ceil(ROWS);
    let n_tiles = l.n.div_ceil(wcols);
    let tiles = (k_tiles * n_tiles) as f64;
    let passes = tiles * l.positions as f64;
    // each k-tile beyond the first needs a digital accumulate per output
    let accumulations =
        ((k_tiles - 1) * l.n) as f64 * l.positions as f64;
    // write each output activation once, read it K-fan-in times next layer
    let buffer_accesses = 2.0 * (l.n * l.positions) as f64;
    LayerMapping {
        name: l.name.clone(),
        k_tiles,
        n_tiles,
        passes,
        accumulations,
        buffer_accesses,
    }
}

/// Total macros required to hold all weights resident (weight-stationary).
pub fn macros_for_weights(net: &Network, w_bits: u32) -> usize {
    let wcols = weight_columns(w_bits);
    net.layers
        .iter()
        .map(|l| l.k.div_ceil(ROWS) * l.n.div_ceil(wcols))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo::resnet18_cifar;

    #[test]
    fn small_layer_fits_one_tile() {
        let l = Layer::conv("c", 3, 64, 3, 32, 32); // K=27, N=64
        let m = map_layer(&l, 128);
        assert_eq!(m.k_tiles, 1);
        assert_eq!(m.n_tiles, 1);
        assert_eq!(m.passes, 1024.0);
        assert_eq!(m.accumulations, 0.0);
    }

    #[test]
    fn big_layer_tiles_both_ways() {
        let l = Layer::conv("c", 512, 512, 3, 4, 4); // K=4608, N=512
        let m = map_layer(&l, 128);
        assert_eq!(m.k_tiles, 18);
        assert_eq!(m.n_tiles, 4);
        assert_eq!(m.passes, (18 * 4 * 16) as f64);
        assert!(m.accumulations > 0.0);
    }

    #[test]
    fn weight_bits_grow_the_footprint() {
        let net = resnet18_cifar();
        let m2 = macros_for_weights(&net, 2);
        let m4 = macros_for_weights(&net, 4);
        assert!(m4 > 3 * m2, "m2={m2} m4={m4}");
    }
}
