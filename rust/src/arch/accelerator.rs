//! The end-to-end accelerator model: a pool of macros executing a mapped
//! network plus NeuroSim-style peripheral costs (65 nm, matching the
//! paper's methodology: crossbar + IM NL-ADC costs from the circuit
//! model, "interconnect, buffers, and accumulation units" estimated
//! analytically).

use crate::arch::mapping::{self, LayerMapping};
use crate::macro_model::{EnergyBreakdown, MacroConfig, MacroEnergy};
use crate::nn::zoo::Network;

// --- NeuroSim-flavoured peripheral constants (65 nm) ----------------------
// At system level the periphery dominates (the paper's own numbers imply
// it: the macro alone does 246 TOPS/W but the ResNet-18 system reaches
// 31.5 TOPS/W — a ~6x gap that buffers/interconnect must absorb, exactly
// what NeuroSim reports for 65 nm IMC systems).
/// energy per activation buffer access (global SRAM read or write), pJ
const E_BUFFER_PJ: f64 = 1.4;
/// energy per digital partial-sum accumulation, pJ
const E_ACCUM_PJ: f64 = 0.12;
/// energy per activation hop over the H-tree interconnect, pJ
const E_HTREE_PJ: f64 = 1.2;
/// per-pass input fetch: each macro pass streams ROWS activations from
/// the global buffer over the H-tree (pJ per activation)
const E_INPUT_FETCH_PJ: f64 = 3.6;
/// fraction of macro-pass latency added by periphery (pipelined)
const PERIPHERY_LATENCY_OVERHEAD: f64 = 0.18;

#[derive(Clone, Copy, Debug)]
pub struct SystemConfig {
    pub macro_cfg: MacroConfig,
    /// macros operating in parallel
    pub num_macros: usize,
    /// average utilization of the macro pool (mapping imbalance)
    pub utilization: f64,
}

impl SystemConfig {
    /// The paper's Table 1 system: ResNet-18 at 6/2/3-bit, sized to hit
    /// the reported 2 TOPS with realistic (77 %) pool utilization.
    pub fn paper_system() -> SystemConfig {
        SystemConfig {
            macro_cfg: MacroConfig::paper_system(),
            num_macros: 36,
            utilization: 0.85,
        }
    }
}

#[derive(Clone, Debug)]
pub struct SystemReport {
    pub network: String,
    pub inferences_per_sec: f64,
    pub latency_ms: f64,
    pub tops: f64,
    pub tops_per_watt: f64,
    pub macro_energy_uj: f64,
    pub periphery_energy_uj: f64,
    pub total_energy_uj: f64,
    pub total_passes: f64,
}

pub struct Accelerator {
    pub cfg: SystemConfig,
}

impl Accelerator {
    pub fn new(cfg: SystemConfig) -> Self {
        Accelerator { cfg }
    }

    /// Simulate one network end-to-end (batch 1, weight-stationary).
    pub fn simulate(&self, net: &Network) -> SystemReport {
        let mc = self.cfg.macro_cfg;
        let mappings = mapping::map_network(net, mc.w_bits);
        let pass_e: EnergyBreakdown = MacroEnergy::per_pass(mc);
        let pass_pj = pass_e.total_pj();
        let pass_s = MacroEnergy::pass_seconds(mc);

        let total_passes: f64 = mappings.iter().map(|m| m.passes).sum();
        let total_accum: f64 =
            mappings.iter().map(|m| m.accumulations).sum();
        let total_buf: f64 =
            mappings.iter().map(|m| m.buffer_accesses).sum();

        // energy: macros + periphery (input fetch dominates — every pass
        // streams 256 activations from the global buffer over the H-tree)
        let macro_pj = total_passes * pass_pj;
        let input_fetch_pj =
            total_passes * crate::macro_model::ROWS as f64 * E_INPUT_FETCH_PJ;
        let periph_pj = input_fetch_pj
            + total_buf * E_BUFFER_PJ
            + total_accum * E_ACCUM_PJ
            + total_buf * 0.5 * E_HTREE_PJ;

        // latency: passes spread over the pool, layers pipelined
        let pool = self.cfg.num_macros as f64 * self.cfg.utilization;
        let latency_s =
            total_passes / pool * pass_s * (1.0 + PERIPHERY_LATENCY_OVERHEAD);

        let ops = net.total_ops();
        let total_j = (macro_pj + periph_pj) * 1e-12;
        SystemReport {
            network: net.name.clone(),
            inferences_per_sec: 1.0 / latency_s,
            latency_ms: latency_s * 1e3,
            tops: ops / latency_s / 1e12,
            tops_per_watt: ops / total_j / 1e12,
            macro_energy_uj: macro_pj * 1e-6,
            periphery_energy_uj: periph_pj * 1e-6,
            total_energy_uj: (macro_pj + periph_pj) * 1e-6,
            total_passes,
        }
    }

    /// Layer mappings (diagnostics for the e2e example).
    pub fn mappings(&self, net: &Network) -> Vec<LayerMapping> {
        mapping::map_network(net, self.cfg.macro_cfg.w_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo::resnet18_cifar;

    #[test]
    fn paper_system_hits_2tops_31p5_topsw() {
        let acc = Accelerator::new(SystemConfig::paper_system());
        let r = acc.simulate(&resnet18_cifar());
        assert!((r.tops - 2.0).abs() < 0.5, "TOPS {} vs paper 2.0", r.tops);
        assert!(
            (r.tops_per_watt - 31.5).abs() < 8.0,
            "TOPS/W {} vs paper 31.5",
            r.tops_per_watt
        );
    }

    #[test]
    fn more_macros_cut_latency_not_energy() {
        let base = Accelerator::new(SystemConfig::paper_system());
        let big = Accelerator::new(SystemConfig {
            num_macros: 144,
            ..SystemConfig::paper_system()
        });
        let net = resnet18_cifar();
        let rb = base.simulate(&net);
        let rg = big.simulate(&net);
        assert!(rg.latency_ms < rb.latency_ms / 1.8);
        assert!((rg.total_energy_uj - rb.total_energy_uj).abs() < 1e-9);
    }

    #[test]
    fn lower_adc_bits_boost_efficiency() {
        let sys4 = SystemConfig {
            macro_cfg: MacroConfig {
                out_bits: 4,
                ..MacroConfig::paper_system()
            },
            ..SystemConfig::paper_system()
        };
        let net = resnet18_cifar();
        let r3 = Accelerator::new(SystemConfig::paper_system()).simulate(&net);
        let r4 = Accelerator::new(sys4).simulate(&net);
        assert!(r3.tops_per_watt > r4.tops_per_watt);
    }
}
