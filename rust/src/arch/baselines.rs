//! Table 1 baseline designs, parameterized from their published metrics
//! (the paper compares against reported numbers, not re-measured silicon)
//! plus the paper's normalization: footnote (b),
//! `TOPS/W = reported x (tech / 65 nm) x (supply / 1.1 V)^2`.

/// One published IMC design row of Table 1.
#[derive(Clone, Debug)]
pub struct BaselineDesign {
    pub label: &'static str,
    pub venue: &'static str,
    pub tech_nm: f64,
    pub supply_v: f64,
    pub freq_mhz: (f64, f64),
    pub bitcell: &'static str,
    pub adc_type: &'static str,
    pub reconfigurable: bool,
    pub network: &'static str,
    pub dataset: &'static str,
    pub acc_loss_pct: f64,
    /// reported peak throughput (TOPS); None if unreported
    pub tops: Option<f64>,
    /// reported TOPS/W range
    pub tops_per_watt: (f64, f64),
}

impl BaselineDesign {
    /// Footnote (b): normalize reported TOPS/W to 65 nm / 1.1 V.
    pub fn normalized_tops_per_watt(&self) -> (f64, f64) {
        let f = (self.tech_nm / 65.0) * (self.supply_v / 1.1).powi(2);
        (self.tops_per_watt.0 * f, self.tops_per_watt.1 * f)
    }
}

/// The three comparison designs of Table 1.
pub fn baseline_designs() -> Vec<BaselineDesign> {
    vec![
        BaselineDesign {
            label: "TCASI'24 [8]",
            venue: "TCASI 2024",
            tech_nm: 28.0,
            supply_v: 0.925, // 0.9-0.95 midpoint
            freq_mhz: (160.0, 340.0),
            bitcell: "9T1C",
            adc_type: "Linear",
            reconfigurable: false,
            network: "ResNet-18",
            dataset: "CIFAR-10",
            acc_loss_pct: 3.22,
            tops: Some(0.52),
            tops_per_watt: (5.45, 21.82),
        },
        BaselineDesign {
            label: "VLSI'23 [12]",
            venue: "VLSI 2023",
            tech_nm: 28.0,
            supply_v: 0.75,
            freq_mhz: (50.0, 200.0),
            bitcell: "RRAM",
            adc_type: "NL",
            reconfigurable: false,
            network: "ResNet-20",
            dataset: "CIFAR-100",
            acc_loss_pct: 0.45,
            tops: Some(0.34),
            tops_per_watt: (0.52, 1.29),
        },
        BaselineDesign {
            label: "SSCL'24 [16]",
            venue: "SSCL 2024",
            tech_nm: 180.0,
            supply_v: 1.8,
            freq_mhz: (12.0, 12.0),
            bitcell: "FCA",
            adc_type: "NL",
            reconfigurable: false,
            network: "ResNet-18",
            dataset: "CIFAR-10",
            acc_loss_pct: 1.7,
            tops: None,
            tops_per_watt: (13.27, 34.6),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization_formula() {
        // 28 nm @ 0.925 V: factor = (28/65)*(0.925/1.1)^2 ~ 0.3046
        let d = &baseline_designs()[0];
        let (lo, hi) = d.normalized_tops_per_watt();
        assert!((lo - 5.45 * 0.3046).abs() < 0.05, "lo {lo}");
        assert!(hi < d.tops_per_watt.1, "normalize must shrink 28nm values");
    }

    #[test]
    fn headline_ratios_hold() {
        // paper: ours = 2 TOPS / 31.5 TOPS/W; up to 4x speedup and 24x
        // energy-efficiency over these baselines (after normalization)
        let ours_tops = 2.0;
        let ours_tpw = 31.5;
        let designs = baseline_designs();
        let max_speedup = designs
            .iter()
            .filter_map(|d| d.tops.map(|t| ours_tops / t))
            .fold(0.0f64, f64::max);
        assert!((3.5..6.0).contains(&max_speedup), "speedup {max_speedup}");
        // the 24x claim compares against VLSI'23's reported 1.29 TOPS/W
        let max_eff = designs
            .iter()
            .map(|d| ours_tpw / d.tops_per_watt.1)
            .fold(0.0f64, f64::max);
        assert!((20.0..28.0).contains(&max_eff), "eff {max_eff}");
    }

    #[test]
    fn old_node_normalizes_up() {
        // 180 nm 1.8 V normalizes *up* (factor > 1)
        let d = &baseline_designs()[2];
        let (lo, _) = d.normalized_tops_per_watt();
        assert!(lo > d.tops_per_watt.0);
    }
}
