//! Minimal dense f32 tensor: shape + row-major data.
//!
//! The heavy math runs inside the AOT-compiled XLA executables; this type
//! only carries data between the weights container, the codebook builders
//! and the PJRT literals, so it stays deliberately small.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} needs {} elements, got {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.ndim(), 2, "row() needs a 2-D tensor");
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// Leading-dimension slice: element block `i` of the first axis.
    pub fn slice0(&self, i: usize) -> &[f32] {
        assert!(!self.shape.is_empty());
        let stride: usize = self.shape[1..].iter().product();
        &self.data[i * stride..(i + 1) * stride]
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }

    pub fn to_f64(&self) -> Vec<f64> {
        self.data.iter().map(|&x| x as f64).collect()
    }

    /// Stack equal-shape tensors along a new leading axis.
    pub fn stack(ts: &[Tensor]) -> Result<Tensor> {
        if ts.is_empty() {
            bail!("stack of zero tensors");
        }
        let shape = &ts[0].shape;
        let mut data = Vec::with_capacity(ts.len() * ts[0].len());
        for t in ts {
            if &t.shape != shape {
                bail!("stack shape mismatch {:?} vs {:?}", t.shape, shape);
            }
            data.extend_from_slice(&t.data);
        }
        let mut out_shape = vec![ts.len()];
        out_shape.extend_from_slice(shape);
        Ok(Tensor {
            shape: out_shape,
            data,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn rows_and_slices() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect())
            .unwrap();
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(t.slice0(0), &[0.0, 1.0, 2.0]);
    }

    #[test]
    fn stack_tensors() {
        let a = Tensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(vec![2], vec![3.0, 4.0]).unwrap();
        let s = Tensor::stack(&[a, b]).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
