//! Pluggable execution backends (DESIGN.md §8).
//!
//! A [`Backend`] executes the two per-model entry points the coordinator
//! needs — `collect` (float forward emitting calibration activations) and
//! `qfwd` (the deployed quantized forward) — behind a trait object, so the
//! calibration pipeline, the PTQ evaluator, the inference server and the
//! experiment harnesses are all engine-agnostic:
//!
//! * [`native::NativeBackend`] — executes the quantized network entirely
//!   in Rust: integer-domain MACs tiled onto the 256-row macro geometry,
//!   partial sums digitized through the NL-ADC codebook ladder, ReLU/clamp
//!   folded into the codebook exactly as the hardware does.  No PJRT, no
//!   `xla` crate, no HLO artifacts on the request path.
//! * [`xla::XlaBackend`] (feature `xla`) — adapter over the PJRT engine +
//!   the AOT HLO artifacts lowered by `python/compile/aot.py`.
//!
//! Select with [`BackendKind`] (CLI `--backend`, env `BSKMQ_BACKEND`).

pub mod native;
#[cfg(feature = "xla")]
pub mod xla;

use std::path::Path;
use std::sync::{Arc, RwLock};

use anyhow::{ensure, Result};

use crate::io::manifest::Manifest;
use crate::obs::quant_health::QuantHealth;
use crate::quant::codebook::Codebook;
use crate::tensor::Tensor;

pub use native::graph::OpTiming;

/// Output of one `collect` batch, sliced per the manifest layout.
pub struct CollectOut {
    pub logits: Vec<f32>,
    /// per-quantized-layer activation subsamples
    pub samples: Vec<Vec<f64>>,
    /// per-layer crossbar-tile partial-sum absmax
    pub tile_max: Vec<f64>,
}

/// Per-layer codebook pairs programmed into the deployed forward: the
/// low-bit NL-ADC codebooks plus the 7-bit linear per-tile codebooks,
/// stacked/padded to the fixed `[nq, max_levels]` shape both backends
/// consume (the XLA graphs take them as literals, the native backend
/// reads the rows directly).
pub struct ProgrammedCodebooks {
    /// stacked padded NL refs/centers, shape [nq, levels] each
    pub nl_refs: Tensor,
    pub nl_centers: Tensor,
    /// stacked per-tile (7-bit linear) refs/centers
    pub tile_refs: Tensor,
    pub tile_centers: Tensor,
    /// process-unique id minted by [`ProgrammedCodebooks::stack`]; the
    /// compiled-graph layer-plan cache keys on it, so a codebook
    /// hot-swap (new `stack` → new uid) can never serve stale LUTs.
    /// Mutating the pub tensor fields of an existing instance bypasses
    /// this key and is unsupported on the quantized forward path.
    uid: u64,
}

impl ProgrammedCodebooks {
    /// Stack per-layer codebooks into the `[nq, levels]` tensors.
    pub fn stack(
        nl: &[Codebook],
        tile: &[Codebook],
        levels: usize,
    ) -> Result<ProgrammedCodebooks> {
        ensure!(nl.len() == tile.len(), "nl/tile layer count mismatch");
        // a 0/1-level ladder cannot convert anything: floor_adc would
        // index an empty centers row and min_ref_step would silently
        // fall back to 1.0, mis-scaling conversion noise
        for (i, cb) in nl.iter().enumerate() {
            ensure!(
                cb.levels() >= 2,
                "q-layer {i}: degenerate NL codebook ({} level(s); \
                 conversion needs at least 2)",
                cb.levels()
            );
        }
        for (i, cb) in tile.iter().enumerate() {
            ensure!(
                cb.levels() >= 2,
                "q-layer {i}: degenerate tile codebook ({} level(s); \
                 conversion needs at least 2)",
                cb.levels()
            );
        }
        let nq = nl.len();
        let mut buf = [
            Vec::with_capacity(nq * levels),
            Vec::with_capacity(nq * levels),
            Vec::with_capacity(nq * levels),
            Vec::with_capacity(nq * levels),
        ];
        for i in 0..nq {
            let (r, c) = nl[i].padded(levels);
            buf[0].extend(r);
            buf[1].extend(c);
            let (r, c) = tile[i].padded(levels);
            buf[2].extend(r);
            buf[3].extend(c);
        }
        let shape = vec![nq, levels];
        let mut it = buf.into_iter();
        static NEXT_UID: std::sync::atomic::AtomicU64 =
            std::sync::atomic::AtomicU64::new(1);
        Ok(ProgrammedCodebooks {
            nl_refs: Tensor::new(shape.clone(), it.next().unwrap())?,
            nl_centers: Tensor::new(shape.clone(), it.next().unwrap())?,
            tile_refs: Tensor::new(shape.clone(), it.next().unwrap())?,
            tile_centers: Tensor::new(shape, it.next().unwrap())?,
            uid: NEXT_UID.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
        })
    }

    /// Process-unique identity of this programmed codebook set (layer-plan
    /// cache key; see the field doc for the mutation caveat).
    pub fn uid(&self) -> u64 {
        self.uid
    }

    /// Number of levels per stacked row.
    pub fn levels(&self) -> usize {
        self.nl_refs.shape[1]
    }

    /// Layer `i`'s four padded rows: (nl_refs, nl_centers, tile_refs,
    /// tile_centers).
    pub fn layer_rows(&self, i: usize) -> (&[f32], &[f32], &[f32], &[f32]) {
        (
            self.nl_refs.row(i),
            self.nl_centers.row(i),
            self.tile_refs.row(i),
            self.tile_centers.row(i),
        )
    }
}

/// One immutable codebook generation: the programmed books plus a
/// 1-based monotonic generation number (1 = the calibration-time books
/// a pool started serving with).  Held behind an `Arc` so the pair can
/// never be observed half-swapped.
pub struct CodebookGeneration {
    pub books: ProgrammedCodebooks,
    pub generation: u64,
}

/// The `Backend::with_codebooks`-style replacement point shared by every
/// replica of one pool (DESIGN.md §15).  Workers grab
/// [`CodebookCell::current`] once per batch and run the whole batch —
/// digitization, noise, replies — against that snapshot, so every reply
/// is produced entirely under a single codebook generation; a concurrent
/// [`CodebookCell::swap`] only takes effect at the next batch boundary.
/// Because `swap` installs a freshly [`ProgrammedCodebooks::stack`]ed
/// set (new uid), the compiled-graph layer-plan cache rebuilds its LUTs
/// instead of serving stale ones.
pub struct CodebookCell {
    inner: RwLock<Arc<CodebookGeneration>>,
}

impl CodebookCell {
    /// Wrap the calibration-time books as generation 1.
    pub fn new(books: ProgrammedCodebooks) -> CodebookCell {
        CodebookCell {
            inner: RwLock::new(Arc::new(CodebookGeneration {
                books,
                generation: 1,
            })),
        }
    }

    /// Snapshot the live generation (cheap: one read lock + Arc clone).
    pub fn current(&self) -> Arc<CodebookGeneration> {
        self.inner.read().unwrap().clone()
    }

    /// The live generation number.
    pub fn generation(&self) -> u64 {
        self.inner.read().unwrap().generation
    }

    /// Atomically publish `books` as the next generation and return its
    /// number.  In-flight batches keep the snapshot they grabbed; no
    /// request is dropped, reordered, or mixed across generations.
    pub fn swap(&self, books: ProgrammedCodebooks) -> u64 {
        let mut g = self.inner.write().unwrap();
        let next = g.generation + 1;
        *g = Arc::new(CodebookGeneration {
            books,
            generation: next,
        });
        next
    }
}

/// An execution engine for one loaded model.
///
/// Implementations are created per model via [`load`]; the trait is
/// deliberately object-safe so the coordinator layers hold a
/// `Box<dyn Backend>` / `&dyn Backend` and never name an engine.
pub trait Backend {
    /// Short engine identifier ("native", "xla").
    fn name(&self) -> &'static str;

    /// The model's AOT manifest (layer table, shapes, batch).
    fn manifest(&self) -> &Manifest;

    /// Capability probe: can `run_qfwd` execute a batch of exactly `n`
    /// samples?  The native backend accepts any `n >= 1`; the XLA backend
    /// only the compiled batch sizes.
    fn supports_batch(&self, n: usize) -> bool;

    /// Run one calibration batch (`manifest().batch` samples) through the
    /// float forward, recording per-layer activation subsamples and
    /// crossbar-tile partial-sum absmax.
    fn run_collect(&self, x: &[f32]) -> Result<CollectOut>;

    /// Run the quantized forward; the batch is inferred from
    /// `x.len() / manifest().input_elems()` and must satisfy
    /// [`Backend::supports_batch`].  Returns flat `[batch * classes]`
    /// logits.
    fn run_qfwd(
        &self,
        x: &[f32],
        books: &ProgrammedCodebooks,
        noise_std: f32,
        seed: u32,
    ) -> Result<Vec<f32>>;

    /// Like [`Backend::run_qfwd`] but also returns a per-op wall-time
    /// breakdown.  Engines without instrumentation fall back to an
    /// unprofiled run with an empty breakdown, so callers can always
    /// request a profile and simply get no rows.
    fn run_qfwd_profiled(
        &self,
        x: &[f32],
        books: &ProgrammedCodebooks,
        noise_std: f32,
        seed: u32,
    ) -> Result<(Vec<f32>, Vec<OpTiming>)> {
        Ok((self.run_qfwd(x, books, noise_std, seed)?, Vec::new()))
    }

    /// Attach quantization-health telemetry; subsequent quantized
    /// forwards feed it per-layer pre-conversion activations.  Returns
    /// `false` for engines without digitization hooks (telemetry is then
    /// silently absent, never an error).
    fn attach_quant_health(&mut self, _health: Arc<QuantHealth>) -> bool {
        false
    }

    /// The telemetry attached via [`Backend::attach_quant_health`].
    fn quant_health(&self) -> Option<Arc<QuantHealth>> {
        None
    }

    /// Weight tensors in graph argument order.
    fn weights(&self) -> &[Tensor];

    /// A backend clone with a replaced weight set (Fig. 6 weight
    /// quantization).
    fn with_weights(&self, weights: Vec<Tensor>) -> Result<Box<dyn Backend>>;

    /// A cheap additional instance of this engine for a worker replica —
    /// the software analogue of programming the same weights into another
    /// crossbar bank.  Replicas share immutable state (the native backend
    /// hands out `Arc` clones of its weight/manifest set) and must be
    /// `Send` so the replica pool can move them onto worker threads.
    ///
    /// Engines that cannot replicate (the PJRT client's handles are
    /// thread-bound) return an error; a pool configured with one replica
    /// never calls this, so such engines still serve at `--replicas 1`.
    fn replicate(&self) -> Result<Box<dyn Backend + Send>> {
        anyhow::bail!(
            "{} backend does not support replication; serve with --replicas 1",
            self.name()
        )
    }

    /// Indices of the q-layer weight matrices within `weights()` (the
    /// tensors Fig. 6 quantizes — biases and digital params stay float).
    fn qweight_indices(&self) -> Vec<usize> {
        self.manifest()
            .weight_args
            .iter()
            .enumerate()
            .filter(|(_, wa)| wa.name.starts_with('q') && wa.name.ends_with("_w"))
            .map(|(i, _)| i)
            .collect()
    }
}

/// Backend selector, settable per invocation (CLI) or process (env).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// XLA when compiled in and loadable, native otherwise.
    Auto,
    /// Pure-Rust integer IMC execution (always available).
    Native,
    /// PJRT/XLA engine over the AOT HLO artifacts (feature `xla`).
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "auto" => Ok(BackendKind::Auto),
            "native" => Ok(BackendKind::Native),
            "xla" => Ok(BackendKind::Xla),
            other => anyhow::bail!("unknown backend '{other}' (auto|native|xla)"),
        }
    }

    /// `BSKMQ_BACKEND` env override, defaulting to `Auto`.  An invalid
    /// value is loudly ignored rather than silently re-routed.
    pub fn from_env() -> BackendKind {
        match std::env::var("BSKMQ_BACKEND") {
            Ok(v) => match BackendKind::parse(&v) {
                Ok(k) => k,
                Err(e) => {
                    eprintln!("warning: ignoring BSKMQ_BACKEND: {e}");
                    BackendKind::Auto
                }
            },
            Err(_) => BackendKind::Auto,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }
}

/// Load a backend for `model` from the artifacts directory.
///
/// `Auto` prefers the XLA engine when the crate is built with the `xla`
/// feature and the HLO artifacts load, and falls back to the native
/// backend otherwise (the native path only needs the manifest + weights
/// container, not the lowered graphs).
pub fn load(
    kind: BackendKind,
    artifacts: &Path,
    model: &str,
) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Native => {
            Ok(Box::new(native::NativeBackend::load(artifacts, model)?))
        }
        BackendKind::Xla => {
            #[cfg(feature = "xla")]
            {
                Ok(Box::new(xla::XlaBackend::load(artifacts, model)?))
            }
            #[cfg(not(feature = "xla"))]
            {
                anyhow::bail!(
                    "backend 'xla' requested but this build has no `xla` \
                     feature; rebuild with `--features xla` or use --backend native"
                )
            }
        }
        BackendKind::Auto => {
            #[cfg(feature = "xla")]
            {
                match xla::XlaBackend::load(artifacts, model) {
                    Ok(b) => return Ok(Box::new(b)),
                    Err(e) => {
                        eprintln!(
                            "auto backend: xla unavailable ({e:#}); \
                             falling back to native"
                        );
                    }
                }
            }
            Ok(Box::new(native::NativeBackend::load(artifacts, model)?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("xla").unwrap(), BackendKind::Xla);
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
        assert!(BackendKind::parse("tpu").is_err());
    }

    #[test]
    fn stacked_codebooks_expose_rows() {
        let nl = vec![
            Codebook::from_centers(&[0.0, 1.0]),
            Codebook::from_centers(&[-1.0, 2.0]),
        ];
        let tile = vec![
            Codebook::linear(-4.0, 4.0, 2),
            Codebook::linear(-8.0, 8.0, 2),
        ];
        let pb = ProgrammedCodebooks::stack(&nl, &tile, 8).unwrap();
        assert_eq!(pb.levels(), 8);
        let (nr, nc, tr, tc) = pb.layer_rows(1);
        assert_eq!(nr[0], -1.0);
        assert_eq!(nc[1], 2.0);
        assert_eq!(tr.len(), 8);
        assert_eq!(tc[0], -8.0);
        // padding refs are +inf, never selected
        assert!(nr[7].is_infinite());
    }

    #[test]
    fn stack_rejects_degenerate_ladders() {
        let ok = vec![Codebook::from_centers(&[0.0, 1.0])];
        let tile = vec![Codebook::linear(-4.0, 4.0, 2)];
        // single-level NL book
        let single = vec![Codebook::from_centers(&[1.0])];
        let err = ProgrammedCodebooks::stack(&single, &tile, 8).unwrap_err();
        assert!(err.to_string().contains("degenerate NL codebook"), "{err}");
        assert!(err.to_string().contains("q-layer 0"), "{err}");
        // empty tile book (constructed directly: from_centers rejects
        // empty input by panicking on c[0])
        let empty = vec![Codebook {
            centers: Vec::new(),
            refs: Vec::new(),
        }];
        let err = ProgrammedCodebooks::stack(&ok, &empty, 8).unwrap_err();
        assert!(err.to_string().contains("degenerate tile codebook"), "{err}");
    }

    #[test]
    fn codebook_cell_swaps_generations_atomically() {
        let mk = |c0: f64| {
            let nl = vec![Codebook::from_centers(&[c0, c0 + 1.0])];
            let tile = vec![Codebook::linear(-4.0, 4.0, 2)];
            ProgrammedCodebooks::stack(&nl, &tile, 4).unwrap()
        };
        let cell = CodebookCell::new(mk(0.0));
        assert_eq!(cell.generation(), 1);
        let a = cell.current();
        assert_eq!(a.generation, 1);
        let uid_a = a.books.uid();
        // a swap bumps the generation and mints a new uid (layer-plan
        // cache key), while the old snapshot stays intact for in-flight
        // batches
        assert_eq!(cell.swap(mk(5.0)), 2);
        let b = cell.current();
        assert_eq!(b.generation, 2);
        assert_ne!(b.books.uid(), uid_a);
        assert_eq!(a.generation, 1);
        assert_eq!(a.books.uid(), uid_a);
        assert_eq!(cell.swap(mk(9.0)), 3);
        assert_eq!(cell.generation(), 3);
    }
}
