//! The layer-graph IR executor: load-time validation + a generic
//! interpreter that replaces the four hand-written per-model forwards.
//!
//! [`GraphProgram::compile`] turns a manifest's declarative `graph`
//! section into an executable program, rejecting every malformed graph
//! *before* any inference runs: unknown op kinds, out-of-order / cyclic
//! edges, dangling values, shape mismatches between an edge and its
//! consumer, q-layer/weight-table inconsistencies — each error names the
//! offending op and edge.  [`GraphProgram::execute`] then interprets the
//! validated op list in both pipeline modes (`collect` float statistics
//! and the deployed quantized forward) through the `ops` kernels.
//!
//! Hot-path memory: value edges are mapped onto a small set of reusable
//! arena slots at compile time (liveness-based — an edge's buffer is
//! recycled after its last consumer), and one [`ExecBuffers`] arena is
//! reused across forwards, so steady-state inference performs no per-op
//! tensor allocations.  Optional per-op timings feed the
//! `cargo bench --bench backends` breakdown and `bskmq graph`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::ops::{
    add_bias_relu_into, add_into, attention_into, avg_pool3_same_into,
    bias_relu_convert_into_with_lut, collect_subsample, concat_c_into,
    conv_dims, global_avg_pool_into, im2col_into, layer_norm_into,
    max_pool2_into, mean_over_seq_into, min_ref_step,
    nl_convert_into_with_lut, tiled_mac_into, tiled_mac_into_with_lut,
    AdcLut, ConvertSpec,
};
use crate::backend::ProgrammedCodebooks;
use crate::io::manifest::Manifest;
use crate::macro_model::ROWS;
use crate::obs::quant_health::QuantHealth;
use crate::tensor::Tensor;

/// Per-sample shape of a value edge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VShape {
    /// NHWC feature map: `h*w*c` elements per sample.
    Feat { h: usize, w: usize, c: usize },
    /// Row matrix: `rows*cols` elements per sample (`rows` = tokens for
    /// sequence values, 1 for pooled/classifier values).
    Mat { rows: usize, cols: usize },
}

impl VShape {
    /// Elements per sample.
    pub fn elems(&self) -> usize {
        match *self {
            VShape::Feat { h, w, c } => h * w * c,
            VShape::Mat { rows, cols } => rows * cols,
        }
    }
}

impl std::fmt::Display for VShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            VShape::Feat { h, w, c } => write!(f, "feat[{h}, {w}, {c}]"),
            VShape::Mat { rows, cols } => write!(f, "mat[{rows}, {cols}]"),
        }
    }
}

/// A validated, resolved op (q-layer / weight-arg names are indices).
#[derive(Clone, Copy, Debug)]
enum OpKind {
    Conv {
        q: usize,
        kernel: usize,
        stride: usize,
        same: bool,
    },
    Dense {
        q: usize,
    },
    MaxPool2,
    AvgPool3,
    GlobalAvgPool,
    Flatten,
    Tokens,
    Concat,
    Add {
        relu: bool,
    },
    Relu,
    LayerNorm {
        gamma: usize,
        beta: usize,
    },
    Attention {
        heads: usize,
    },
    Embed {
        table: usize,
        pos: usize,
    },
    MeanOverSeq,
}

impl OpKind {
    fn name(&self) -> &'static str {
        match self {
            OpKind::Conv { .. } => "conv",
            OpKind::Dense { .. } => "dense",
            OpKind::MaxPool2 => "maxpool2",
            OpKind::AvgPool3 => "avgpool3",
            OpKind::GlobalAvgPool => "gap",
            OpKind::Flatten => "flatten",
            OpKind::Tokens => "tokens",
            OpKind::Concat => "concat",
            OpKind::Add { .. } => "add",
            OpKind::Relu => "relu",
            OpKind::LayerNorm { .. } => "layernorm",
            OpKind::Attention { .. } => "attention",
            OpKind::Embed { .. } => "embed",
            OpKind::MeanOverSeq => "meanseq",
        }
    }

    fn qlayer(&self) -> Option<usize> {
        match *self {
            OpKind::Conv { q, .. } | OpKind::Dense { q } => Some(q),
            _ => None,
        }
    }
}

const KNOWN_OPS: &str = "conv, dense, maxpool2, avgpool3, gap, flatten, \
                         tokens, concat, add, relu, layernorm, attention, \
                         embed, meanseq";

#[derive(Clone, Debug)]
struct ValueInfo {
    name: String,
    shape: VShape,
    /// arena slot carrying this edge at runtime
    slot: usize,
}

#[derive(Clone, Debug)]
struct Node {
    name: String,
    kind: OpKind,
    /// value ids consumed
    inputs: Vec<usize>,
    /// value id produced
    output: usize,
}

/// One row of the per-op dump (`bskmq graph`, bench breakdowns).
#[derive(Clone, Debug)]
pub struct OpSummary {
    pub name: String,
    pub kind: &'static str,
    pub inputs: Vec<String>,
    pub output: String,
    pub out_shape: String,
    pub qlayer: Option<String>,
}

/// Wall-clock of one executed op (profiled runs only).
#[derive(Clone, Debug)]
pub struct OpTiming {
    pub name: String,
    pub kind: &'static str,
    /// output elements written (whole batch)
    pub out_elems: usize,
    pub nanos: u128,
}

/// Reusable execution arena: value-edge slots plus the im2col patch and
/// attention score scratch buffers.  Buffers only ever grow; a backend
/// keeps a pool of these so steady-state forwards allocate nothing per
/// op.
#[derive(Default)]
pub struct ExecBuffers {
    slots: Vec<Vec<f32>>,
    patch: Vec<f32>,
}

/// Execution mode of one forward pass.
#[derive(Clone, Copy)]
pub enum ExecMode<'a> {
    /// Float forward recording calibration statistics.
    Collect,
    /// Deployed quantized forward with programmed codebooks.
    Quant {
        books: &'a ProgrammedCodebooks,
        noise_std: f32,
        seed: u32,
    },
}

/// Output of one interpreted forward.
pub struct ExecOut {
    /// flat `[batch, num_classes]` logits
    pub logits: Vec<f32>,
    /// per-q-layer activation subsamples (collect mode; else empty)
    pub samples: Vec<Vec<f64>>,
    /// per-q-layer crossbar-tile absmax (collect mode; else empty)
    pub tile_max: Vec<f64>,
}

/// Noise-seed salt of the layer-output NL-ADC conversion (the per-tile
/// conversion uses salt 0) — fixed since the first native backend so
/// calibrated deployments reproduce bit-identically.
pub const NL_SEED_SALT: u64 = 0x5851_F42D_4C95_7F2D;

/// Per-(layer, salt) RNG seed of the quantized forward's conversion
/// noise; `wi` is the q-layer index in manifest order.
pub fn layer_seed(seed: u32, wi: usize, salt: u64) -> u64 {
    (seed as u64)
        .wrapping_mul(0xA076_1D64_78BD_642F)
        .wrapping_add((wi as u64).wrapping_mul(0xE703_7ED1_A0B4_28DB))
        ^ salt
}

/// Everything the quantized forward derives from one programmed
/// codebook set, built once per (program, codebooks) pairing and reused
/// by every forward on every replica: the per-q-layer tile/NL
/// [`AdcLut`]s (previously rebuilt on every single op) and the
/// pre-resolved noise LSB units.  The weight matrices themselves need
/// no repacking — the `[k, n]` row-major layout already *is* tile-major
/// (crossbar tiles are contiguous `tile_k`-row bands), so the plan
/// stores derived tables only and never duplicates weight bytes.
#[derive(Debug)]
pub struct LayerPlan {
    /// [`ProgrammedCodebooks::uid`] this plan was compiled from
    books_uid: u64,
    layers: Vec<PlanLayer>,
}

/// One q-layer's slice of a [`LayerPlan`].
#[derive(Debug)]
struct PlanLayer {
    tile_lut: AdcLut,
    nl_lut: AdcLut,
    /// `min_ref_step(tile_refs)` — the tile-ADC LSB the conversion
    /// noise sigma scales by
    tile_sigma_unit: f32,
    /// `min_ref_step(nl_refs)` — the NL-ADC LSB
    nl_sigma_unit: f32,
}

/// A compiled, validated layer graph, ready to interpret.
#[derive(Debug)]
pub struct GraphProgram {
    nodes: Vec<Node>,
    values: Vec<ValueInfo>,
    input_vid: usize,
    output_vid: usize,
    n_slots: usize,
    nq: usize,
    /// Cached [`LayerPlan`] for the most recently seen codebook set.
    /// Keyed on [`ProgrammedCodebooks::uid`], so a hot-swapped codebook
    /// stack (always a fresh `stack()` → fresh uid) rebuilds the plan on
    /// first use and every replica sharing this program (via `Arc`)
    /// picks it up atomically; `with_weights`-style backend swaps
    /// recompile the program and start from an empty cache.
    plan: Mutex<Option<Arc<LayerPlan>>>,
}

impl Clone for GraphProgram {
    fn clone(&self) -> GraphProgram {
        GraphProgram {
            nodes: self.nodes.clone(),
            values: self.values.clone(),
            input_vid: self.input_vid,
            output_vid: self.output_vid,
            n_slots: self.n_slots,
            nq: self.nq,
            // carry the cached plan: it is pure derived data keyed by
            // codebook uid, so sharing the Arc is always valid
            plan: Mutex::new(self.plan.lock().unwrap().clone()),
        }
    }
}

fn pop_or_new(free: &mut Vec<usize>, n_slots: &mut usize) -> usize {
    free.pop().unwrap_or_else(|| {
        let s = *n_slots;
        *n_slots += 1;
        s
    })
}

impl GraphProgram {
    /// Validate the manifest's `graph` section and resolve it into an
    /// executable program.  Every structural error — unknown op kind,
    /// out-of-order (cyclic) or dangling edge, shape mismatch, q-layer /
    /// weight-table inconsistency — is reported here, naming the
    /// offending op and edge, so nothing panics mid-inference.
    pub fn compile(m: &Manifest) -> Result<GraphProgram> {
        let g = m.graph.as_ref().ok_or_else(|| {
            anyhow!(
                "manifest for model '{}' has no `graph` section; the native \
                 backend executes only graph-bearing manifests",
                m.model
            )
        })?;
        ensure!(!g.ops.is_empty(), "graph has no ops");

        // weight-arg and q-layer name resolution tables
        let warg_idx: HashMap<&str, usize> = m
            .weight_args
            .iter()
            .enumerate()
            .map(|(i, wa)| (wa.name.as_str(), i))
            .collect();
        let q_idx: HashMap<&str, usize> = m
            .qlayers
            .iter()
            .enumerate()
            .map(|(i, q)| (q.name.as_str(), i))
            .collect();

        // the MAC weight indexing scheme: weight_args[2i], [2i+1] are
        // q-layer i's matrix and bias
        ensure!(
            m.weight_args.len() >= 2 * m.nq(),
            "weight table has {} args, too short for {} q-layer (w, b) pairs",
            m.weight_args.len(),
            m.nq()
        );
        for (i, ql) in m.qlayers.iter().enumerate() {
            ensure!(
                ql.k >= 1 && ql.n >= 1,
                "q-layer '{}' has zero width (k = {}, n = {})",
                ql.name,
                ql.k,
                ql.n
            );
            let w = &m.weight_args[2 * i];
            let b = &m.weight_args[2 * i + 1];
            ensure!(
                w.shape == vec![ql.k, ql.n],
                "q-layer '{}': weight arg '{}' has shape {:?}, want [{}, {}]",
                ql.name,
                w.name,
                w.shape,
                ql.k,
                ql.n
            );
            ensure!(
                b.shape == vec![ql.n],
                "q-layer '{}': bias arg '{}' has shape {:?}, want [{}]",
                ql.name,
                b.name,
                b.shape,
                ql.n
            );
            // per-layer QuantSpec vs the manifest's codebook capacity:
            // an unprogrammable precision must fail at load, not after
            // calibration has already burned the compute
            if let Some(spec) = &ql.spec {
                spec.validate(m.max_levels).with_context(|| {
                    format!("q-layer '{}': invalid quant spec", ql.name)
                })?;
            }
        }

        ensure!(
            m.input_shape.iter().all(|&d| d >= 1),
            "input shape {:?} has a zero dimension",
            m.input_shape
        );
        let in_shape = match m.input_shape.len() {
            3 => VShape::Feat {
                h: m.input_shape[0],
                w: m.input_shape[1],
                c: m.input_shape[2],
            },
            1 => VShape::Mat {
                rows: 1,
                cols: m.input_shape[0],
            },
            _ => bail!(
                "unsupported input shape {:?} (want [h, w, c] or [t])",
                m.input_shape
            ),
        };

        let mut values = vec![ValueInfo {
            name: g.input.clone(),
            shape: in_shape,
            slot: usize::MAX,
        }];
        let mut by_name: HashMap<String, usize> = HashMap::new();
        by_name.insert(g.input.clone(), 0);
        // which op consumed each q-layer (exactly-once bookkeeping)
        let mut q_used: Vec<Option<String>> = vec![None; m.nq()];
        let mut nodes: Vec<Node> = Vec::new();

        for def in &g.ops {
            let op_name = def.name.as_str();
            // edge resolution: every input must already exist — the op
            // list is required to be topologically ordered, so a forward
            // reference is a cycle or a dangling name either way
            let mut input_ids = Vec::with_capacity(def.inputs.len());
            let mut in_shapes = Vec::with_capacity(def.inputs.len());
            for e in &def.inputs {
                let vid = *by_name.get(e.as_str()).ok_or_else(|| {
                    anyhow!(
                        "op '{op_name}': input edge '{e}' is not produced by \
                         any earlier op or the graph input (cyclic or \
                         dangling reference)"
                    )
                })?;
                input_ids.push(vid);
                in_shapes.push(values[vid].shape);
            }

            let arity = |want: usize| -> Result<()> {
                ensure!(
                    def.inputs.len() == want,
                    "op '{op_name}' ({}): takes {want} input(s), got {}",
                    def.op,
                    def.inputs.len()
                );
                Ok(())
            };
            // resolve the q-layer of a MAC op and enforce exactly-once use
            let mut resolve_q = |qname: &Option<String>| -> Result<usize> {
                let qname = qname.as_deref().ok_or_else(|| {
                    anyhow!("op '{op_name}' ({}): missing `qlayer`", def.op)
                })?;
                let q = *q_idx.get(qname).ok_or_else(|| {
                    anyhow!(
                        "op '{op_name}': q-layer '{qname}' is not in the \
                         manifest qlayers table"
                    )
                })?;
                if let Some(prev) = &q_used[q] {
                    bail!(
                        "op '{op_name}': q-layer '{qname}' already consumed \
                         by op '{prev}' (each q-layer maps to one crossbar \
                         programming and must be used exactly once)"
                    );
                }
                if let Some(r) = def.relu {
                    ensure!(
                        r == m.qlayers[q].relu,
                        "op '{op_name}': relu attribute {r} contradicts \
                         q-layer '{qname}' (relu = {})",
                        m.qlayers[q].relu
                    );
                }
                q_used[q] = Some(op_name.to_string());
                Ok(q)
            };
            let resolve_warg = |attr: &str, name: &Option<String>| -> Result<usize> {
                let name = name.as_deref().ok_or_else(|| {
                    anyhow!("op '{op_name}' ({}): missing `{attr}`", def.op)
                })?;
                warg_idx.get(name).copied().ok_or_else(|| {
                    anyhow!(
                        "op '{op_name}': {attr} weight arg '{name}' is not \
                         in the manifest weight_args table"
                    )
                })
            };
            let feat_input = |i: usize| -> Result<(usize, usize, usize)> {
                match in_shapes[i] {
                    VShape::Feat { h, w, c } => Ok((h, w, c)),
                    s => bail!(
                        "op '{op_name}' ({}): input edge '{}' has shape {s}, \
                         want an NHWC feature map",
                        def.op,
                        def.inputs[i]
                    ),
                }
            };
            let mat_input = |i: usize| -> Result<(usize, usize)> {
                match in_shapes[i] {
                    VShape::Mat { rows, cols } => Ok((rows, cols)),
                    s => bail!(
                        "op '{op_name}' ({}): input edge '{}' has shape {s}, \
                         want a row matrix",
                        def.op,
                        def.inputs[i]
                    ),
                }
            };

            let (kind, out_shape) = match def.op.as_str() {
                "conv" => {
                    arity(1)?;
                    let (h, w, c) = feat_input(0)?;
                    let q = resolve_q(&def.qlayer)?;
                    let kernel = def.kernel.ok_or_else(|| {
                        anyhow!("op '{op_name}' (conv): missing `kernel`")
                    })?;
                    ensure!(
                        kernel >= 1,
                        "op '{op_name}' (conv): kernel must be >= 1"
                    );
                    let stride = def.stride.unwrap_or(1);
                    ensure!(
                        stride >= 1,
                        "op '{op_name}' (conv): stride must be >= 1"
                    );
                    let same = match def.pad.as_deref().unwrap_or("same") {
                        "same" => true,
                        "valid" => false,
                        p => bail!(
                            "op '{op_name}' (conv): pad '{p}' is neither \
                             'same' nor 'valid'"
                        ),
                    };
                    let ql = &m.qlayers[q];
                    ensure!(
                        ql.k == kernel * kernel * c,
                        "op '{op_name}': input edge '{}' has {c} channels, \
                         so a {kernel}x{kernel} conv contracts over {} — \
                         but q-layer '{}' declares k = {}",
                        def.inputs[0],
                        kernel * kernel * c,
                        ql.name,
                        ql.k
                    );
                    if !same {
                        ensure!(
                            h >= kernel && w >= kernel,
                            "op '{op_name}' (conv): {kernel}x{kernel} VALID \
                             kernel exceeds the {h}x{w} input map of edge \
                             '{}'",
                            def.inputs[0]
                        );
                    }
                    let (oh, ow, _, _) =
                        conv_dims(h, w, kernel, kernel, stride, same);
                    (
                        OpKind::Conv {
                            q,
                            kernel,
                            stride,
                            same,
                        },
                        VShape::Feat {
                            h: oh,
                            w: ow,
                            c: ql.n,
                        },
                    )
                }
                "dense" => {
                    arity(1)?;
                    let (rows, cols) = mat_input(0)?;
                    let q = resolve_q(&def.qlayer)?;
                    let ql = &m.qlayers[q];
                    ensure!(
                        ql.k == cols,
                        "op '{op_name}': input edge '{}' has {cols} \
                         features, but q-layer '{}' declares k = {}",
                        def.inputs[0],
                        ql.name,
                        ql.k
                    );
                    (OpKind::Dense { q }, VShape::Mat { rows, cols: ql.n })
                }
                "maxpool2" => {
                    arity(1)?;
                    let (h, w, c) = feat_input(0)?;
                    ensure!(
                        h % 2 == 0 && w % 2 == 0 && h >= 2 && w >= 2,
                        "op '{op_name}' (maxpool2): input edge '{}' is \
                         {h}x{w}, want even spatial dims >= 2",
                        def.inputs[0]
                    );
                    (
                        OpKind::MaxPool2,
                        VShape::Feat {
                            h: h / 2,
                            w: w / 2,
                            c,
                        },
                    )
                }
                "avgpool3" => {
                    arity(1)?;
                    let (h, w, c) = feat_input(0)?;
                    (OpKind::AvgPool3, VShape::Feat { h, w, c })
                }
                "gap" => {
                    arity(1)?;
                    let (_, _, c) = feat_input(0)?;
                    (OpKind::GlobalAvgPool, VShape::Mat { rows: 1, cols: c })
                }
                "flatten" => {
                    arity(1)?;
                    let (h, w, c) = feat_input(0)?;
                    (
                        OpKind::Flatten,
                        VShape::Mat {
                            rows: 1,
                            cols: h * w * c,
                        },
                    )
                }
                "tokens" => {
                    arity(1)?;
                    let (h, w, c) = feat_input(0)?;
                    (
                        OpKind::Tokens,
                        VShape::Mat {
                            rows: h * w,
                            cols: c,
                        },
                    )
                }
                "concat" => {
                    ensure!(
                        def.inputs.len() >= 2,
                        "op '{op_name}' (concat): takes >= 2 inputs, got {}",
                        def.inputs.len()
                    );
                    let (h, w, mut c) = feat_input(0)?;
                    for i in 1..def.inputs.len() {
                        let (hi, wi, ci) = feat_input(i)?;
                        ensure!(
                            (hi, wi) == (h, w),
                            "op '{op_name}' (concat): input edge '{}' is \
                             {hi}x{wi}, but edge '{}' is {h}x{w}",
                            def.inputs[i],
                            def.inputs[0]
                        );
                        c += ci;
                    }
                    (OpKind::Concat, VShape::Feat { h, w, c })
                }
                "add" => {
                    arity(2)?;
                    ensure!(
                        in_shapes[0] == in_shapes[1],
                        "op '{op_name}' (add): input edge '{}' has shape \
                         {}, but edge '{}' has shape {}",
                        def.inputs[0],
                        in_shapes[0],
                        def.inputs[1],
                        in_shapes[1]
                    );
                    (
                        OpKind::Add {
                            relu: def.relu.unwrap_or(false),
                        },
                        in_shapes[0],
                    )
                }
                "relu" => {
                    arity(1)?;
                    (OpKind::Relu, in_shapes[0])
                }
                "layernorm" => {
                    arity(1)?;
                    let (rows, cols) = mat_input(0)?;
                    let gamma = resolve_warg("gamma", &def.gamma)?;
                    let beta = resolve_warg("beta", &def.beta)?;
                    for (attr, wi) in [("gamma", gamma), ("beta", beta)] {
                        let wa = &m.weight_args[wi];
                        ensure!(
                            wa.shape == vec![cols],
                            "op '{op_name}': {attr} arg '{}' has shape \
                             {:?}, want [{cols}] to match edge '{}'",
                            wa.name,
                            wa.shape,
                            def.inputs[0]
                        );
                    }
                    (
                        OpKind::LayerNorm { gamma, beta },
                        VShape::Mat { rows, cols },
                    )
                }
                "attention" => {
                    arity(3)?;
                    let (t, d) = mat_input(0)?;
                    for i in 1..3 {
                        ensure!(
                            in_shapes[i] == in_shapes[0],
                            "op '{op_name}' (attention): input edge '{}' \
                             has shape {}, but edge '{}' has shape {}",
                            def.inputs[i],
                            in_shapes[i],
                            def.inputs[0],
                            in_shapes[0]
                        );
                    }
                    let heads = def.heads.ok_or_else(|| {
                        anyhow!("op '{op_name}' (attention): missing `heads`")
                    })?;
                    ensure!(
                        heads >= 1 && d % heads == 0,
                        "op '{op_name}' (attention): d_model {d} is not \
                         divisible by {heads} heads"
                    );
                    (
                        OpKind::Attention { heads },
                        VShape::Mat { rows: t, cols: d },
                    )
                }
                "embed" => {
                    arity(1)?;
                    let (rows, t) = mat_input(0)?;
                    ensure!(
                        rows == 1,
                        "op '{op_name}' (embed): input edge '{}' has shape \
                         {}, want a [1, t] token-id row",
                        def.inputs[0],
                        in_shapes[0]
                    );
                    let table = resolve_warg("table", &def.table)?;
                    let pos = resolve_warg("pos", &def.pos)?;
                    let ts = &m.weight_args[table];
                    ensure!(
                        ts.shape.len() == 2 && ts.shape[0] >= 1,
                        "op '{op_name}': table arg '{}' has shape {:?}, \
                         want [vocab, d]",
                        ts.name,
                        ts.shape
                    );
                    let d = ts.shape[1];
                    let ps = &m.weight_args[pos];
                    ensure!(
                        ps.shape == vec![t, d],
                        "op '{op_name}': pos arg '{}' has shape {:?}, want \
                         [{t}, {d}]",
                        ps.name,
                        ps.shape
                    );
                    (
                        OpKind::Embed { table, pos },
                        VShape::Mat { rows: t, cols: d },
                    )
                }
                "meanseq" => {
                    arity(1)?;
                    let (t, d) = mat_input(0)?;
                    ensure!(
                        t >= 1,
                        "op '{op_name}' (meanseq): empty sequence input"
                    );
                    (OpKind::MeanOverSeq, VShape::Mat { rows: 1, cols: d })
                }
                other => bail!(
                    "op '{op_name}': unknown op kind '{other}' \
                     (known: {KNOWN_OPS})"
                ),
            };

            ensure!(
                !by_name.contains_key(&def.output),
                "op '{op_name}': output edge '{}' is already defined",
                def.output
            );
            let vid = values.len();
            values.push(ValueInfo {
                name: def.output.clone(),
                shape: out_shape,
                slot: usize::MAX,
            });
            by_name.insert(def.output.clone(), vid);
            nodes.push(Node {
                name: def.name.clone(),
                kind,
                inputs: input_ids,
                output: vid,
            });
        }

        let output_vid = *by_name.get(&g.output).ok_or_else(|| {
            anyhow!("graph output edge '{}' is produced by no op", g.output)
        })?;
        match values[output_vid].shape {
            VShape::Mat { rows: 1, cols } if cols == m.num_classes => {}
            s => bail!(
                "graph output edge '{}' has per-sample shape {s}, want \
                 [1, {}] logits",
                g.output,
                m.num_classes
            ),
        }
        for (i, used) in q_used.iter().enumerate() {
            ensure!(
                used.is_some(),
                "q-layer '{}' (index {i}) is referenced by no graph op — \
                 its calibration stream would never be fed",
                m.qlayers[i].name
            );
        }
        // dangling-edge check: every produced value must be consumed
        // (the logits edge is consumed by the caller)
        let mut consumed = vec![false; values.len()];
        for node in &nodes {
            for &v in &node.inputs {
                consumed[v] = true;
            }
        }
        consumed[output_vid] = true;
        for (vid, v) in values.iter().enumerate() {
            if !consumed[vid] {
                let producer = nodes
                    .iter()
                    .find(|n| n.output == vid)
                    .map(|n| n.name.clone())
                    .unwrap_or_else(|| "the graph input".to_string());
                bail!(
                    "value edge '{}' (produced by {producer}) is never \
                     consumed (dangling edge)",
                    v.name
                );
            }
        }

        // arena slot planning: liveness-based reuse — an edge's slot is
        // recycled once its last consumer has run
        let mut last_use = vec![0usize; values.len()];
        for (i, node) in nodes.iter().enumerate() {
            for &v in &node.inputs {
                last_use[v] = i;
            }
        }
        last_use[output_vid] = nodes.len(); // logits outlive the walk
        let mut free: Vec<usize> = Vec::new();
        let mut n_slots = 0usize;
        values[0].slot = pop_or_new(&mut free, &mut n_slots);
        for (i, node) in nodes.iter().enumerate() {
            // flatten/tokens are NHWC reinterprets (identical bytes):
            // when their input dies here, the output edge simply renames
            // the input's buffer — no slot, no copy on the hot path
            if matches!(node.kind, OpKind::Flatten | OpKind::Tokens)
                && last_use[node.inputs[0]] == i
            {
                let s = values[node.inputs[0]].slot;
                values[node.output].slot = s;
                continue;
            }
            // allocate the output first: the inputs are still being read
            let slot = pop_or_new(&mut free, &mut n_slots);
            values[node.output].slot = slot;
            for (j, &v) in node.inputs.iter().enumerate() {
                if last_use[v] == i && !node.inputs[..j].contains(&v) {
                    free.push(values[v].slot);
                }
            }
        }

        Ok(GraphProgram {
            nodes,
            values,
            input_vid: 0,
            output_vid,
            n_slots,
            nq: m.nq(),
            plan: Mutex::new(None),
        })
    }

    /// The cached [`LayerPlan`] for `books`, compiling it on first use
    /// (or after a codebook hot-swap changed the uid).  Cheap on the
    /// steady-state path: one mutex lock + one u64 compare + one `Arc`
    /// clone per forward.
    pub fn plan_for(&self, books: &ProgrammedCodebooks) -> Arc<LayerPlan> {
        let mut g = self.plan.lock().unwrap();
        if let Some(p) = g.as_ref() {
            if p.books_uid == books.uid() {
                return Arc::clone(p);
            }
        }
        let layers = (0..self.nq)
            .map(|q| {
                let (n_refs, n_centers, t_refs, t_centers) =
                    books.layer_rows(q);
                PlanLayer {
                    tile_lut: AdcLut::new(t_refs, t_centers),
                    nl_lut: AdcLut::new(n_refs, n_centers),
                    tile_sigma_unit: min_ref_step(t_refs),
                    nl_sigma_unit: min_ref_step(n_refs),
                }
            })
            .collect();
        let p = Arc::new(LayerPlan {
            books_uid: books.uid(),
            layers,
        });
        *g = Some(Arc::clone(&p));
        p
    }

    /// True when a [`LayerPlan`] for `books` is already cached (test /
    /// introspection hook for the invalidation contract).
    pub fn plan_cached_for(&self, books: &ProgrammedCodebooks) -> bool {
        self.plan
            .lock()
            .unwrap()
            .as_ref()
            .is_some_and(|p| p.books_uid == books.uid())
    }

    /// Ops in execution order, with names resolved for display.
    pub fn summary(&self, m: &Manifest) -> Vec<OpSummary> {
        self.nodes
            .iter()
            .map(|n| OpSummary {
                name: n.name.clone(),
                kind: n.kind.name(),
                inputs: n
                    .inputs
                    .iter()
                    .map(|&v| self.values[v].name.clone())
                    .collect(),
                output: self.values[n.output].name.clone(),
                out_shape: self.values[n.output].shape.to_string(),
                qlayer: n.kind.qlayer().map(|q| m.qlayers[q].name.clone()),
            })
            .collect()
    }

    pub fn n_ops(&self) -> usize {
        self.nodes.len()
    }

    pub fn n_values(&self) -> usize {
        self.values.len()
    }

    /// Arena slots the liveness planner mapped the value edges onto.
    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    /// Interpret the graph over a `batch`-sample input.  `buf` is the
    /// reusable arena (grown on first use, then allocation-free);
    /// `profile` collects per-op wall-clock when provided; `taps`, when
    /// attached, observes each q-layer's pre-conversion activations
    /// (quant mode only).
    #[allow(clippy::too_many_arguments)]
    pub fn execute(
        &self,
        m: &Manifest,
        weights: &[Tensor],
        x: &[f32],
        batch: usize,
        mode: ExecMode,
        buf: &mut ExecBuffers,
        mut profile: Option<&mut Vec<OpTiming>>,
        taps: Option<&QuantHealth>,
    ) -> Result<ExecOut> {
        ensure!(batch >= 1, "empty batch");
        let in_elems = self.values[self.input_vid].shape.elems();
        ensure!(
            x.len() == batch * in_elems,
            "input len {} != batch {batch} x {in_elems}",
            x.len()
        );
        buf.slots.resize_with(self.n_slots, Vec::new);
        {
            let s = &mut buf.slots[self.values[self.input_vid].slot];
            s.clear();
            s.extend_from_slice(x);
        }

        let (mut samples, mut tile_max) = match mode {
            ExecMode::Collect => {
                (vec![Vec::new(); self.nq], vec![0f64; self.nq])
            }
            ExecMode::Quant { .. } => (Vec::new(), Vec::new()),
        };
        // resolve the cached layer plan once per forward; every qmac in
        // the op loop then runs without LUT construction or ladder scans
        let plan = match mode {
            ExecMode::Quant { books, .. } => Some(self.plan_for(books)),
            ExecMode::Collect => None,
        };

        for node in &self.nodes {
            let t0 = profile.as_ref().map(|_| Instant::now());
            let out_elems =
                batch * self.values[node.output].shape.elems();
            let out_slot = self.values[node.output].slot;
            // renamed reinterprets share their input's slot: the bytes
            // are already in place, nothing to execute
            if matches!(node.kind, OpKind::Flatten | OpKind::Tokens)
                && self.values[node.inputs[0]].slot == out_slot
            {
                if let (Some(p), Some(t0)) = (profile.as_mut(), t0) {
                    p.push(OpTiming {
                        name: node.name.clone(),
                        kind: node.kind.name(),
                        out_elems,
                        nanos: t0.elapsed().as_nanos(),
                    });
                }
                continue;
            }
            let mut out = std::mem::take(&mut buf.slots[out_slot]);
            out.clear();
            out.resize(out_elems, 0.0);

            // shorthand for an input's (slice, per-sample shape)
            macro_rules! input {
                ($i:expr) => {{
                    let v = &self.values[node.inputs[$i]];
                    (
                        &buf.slots[v.slot][..batch * v.shape.elems()],
                        v.shape,
                    )
                }};
            }

            match node.kind {
                OpKind::Conv {
                    q,
                    kernel,
                    stride,
                    same,
                } => {
                    let (xdat, shape) = input!(0);
                    let VShape::Feat { h, w, c } = shape else {
                        unreachable!("validated at compile")
                    };
                    let (oh, ow, _, _) =
                        conv_dims(h, w, kernel, kernel, stride, same);
                    let rows = batch * oh * ow;
                    let cols = kernel * kernel * c;
                    let need = rows * cols;
                    if buf.patch.len() < need {
                        buf.patch.resize(need, 0.0);
                    }
                    im2col_into(
                        xdat,
                        batch,
                        h,
                        w,
                        c,
                        kernel,
                        kernel,
                        stride,
                        same,
                        &mut buf.patch[..need],
                    );
                    qmac(
                        m,
                        weights,
                        q,
                        &buf.patch[..need],
                        rows,
                        cols,
                        mode,
                        plan.as_deref(),
                        &mut samples,
                        &mut tile_max,
                        &mut out,
                        taps,
                    );
                }
                OpKind::Dense { q } => {
                    let (xdat, shape) = input!(0);
                    let VShape::Mat { rows, cols } = shape else {
                        unreachable!("validated at compile")
                    };
                    qmac(
                        m,
                        weights,
                        q,
                        xdat,
                        batch * rows,
                        cols,
                        mode,
                        plan.as_deref(),
                        &mut samples,
                        &mut tile_max,
                        &mut out,
                        taps,
                    );
                }
                OpKind::MaxPool2 => {
                    let (xdat, shape) = input!(0);
                    let VShape::Feat { h, w, c } = shape else {
                        unreachable!()
                    };
                    max_pool2_into(xdat, batch, h, w, c, &mut out);
                }
                OpKind::AvgPool3 => {
                    let (xdat, shape) = input!(0);
                    let VShape::Feat { h, w, c } = shape else {
                        unreachable!()
                    };
                    avg_pool3_same_into(xdat, batch, h, w, c, &mut out);
                }
                OpKind::GlobalAvgPool => {
                    let (xdat, shape) = input!(0);
                    let VShape::Feat { h, w, c } = shape else {
                        unreachable!()
                    };
                    global_avg_pool_into(xdat, batch, h, w, c, &mut out);
                }
                OpKind::Flatten | OpKind::Tokens => {
                    // NHWC row-major reinterpretation: same bytes
                    let (xdat, _) = input!(0);
                    out.copy_from_slice(xdat);
                }
                OpKind::Concat => {
                    let mut parts: Vec<(&[f32], usize)> =
                        Vec::with_capacity(node.inputs.len());
                    let mut pixels = 0;
                    for &vi in &node.inputs {
                        let v = &self.values[vi];
                        let VShape::Feat { h, w, c } = v.shape else {
                            unreachable!()
                        };
                        pixels = batch * h * w;
                        parts.push((
                            &buf.slots[v.slot][..batch * v.shape.elems()],
                            c,
                        ));
                    }
                    concat_c_into(&parts, pixels, &mut out);
                }
                OpKind::Add { relu } => {
                    let (a, _) = input!(0);
                    let (b, _) = input!(1);
                    add_into(a, b, relu, &mut out);
                }
                OpKind::Relu => {
                    let (xdat, _) = input!(0);
                    for (o, &v) in out.iter_mut().zip(xdat) {
                        *o = v.max(0.0);
                    }
                }
                OpKind::LayerNorm { gamma, beta } => {
                    let (xdat, shape) = input!(0);
                    let VShape::Mat { cols, .. } = shape else {
                        unreachable!()
                    };
                    layer_norm_into(
                        xdat,
                        cols,
                        &weights[gamma].data,
                        &weights[beta].data,
                        &mut out,
                    );
                }
                OpKind::Attention { heads } => {
                    let (q, shape) = input!(0);
                    let (k, _) = input!(1);
                    let (v, _) = input!(2);
                    let VShape::Mat { rows: t, cols: d } = shape else {
                        unreachable!()
                    };
                    attention_into(q, k, v, batch, t, d, heads, &mut out);
                }
                OpKind::Embed { table, pos } => {
                    let (xdat, shape) = input!(0);
                    let VShape::Mat { cols: t, .. } = shape else {
                        unreachable!()
                    };
                    let tbl = &weights[table];
                    let pose = &weights[pos];
                    let (vocab, d) = (tbl.shape[0], tbl.shape[1]);
                    for bi in 0..batch {
                        for ti in 0..t {
                            let tok = (xdat[bi * t + ti].max(0.0) as usize)
                                .min(vocab - 1);
                            let erow = &tbl.data[tok * d..(tok + 1) * d];
                            let prow = &pose.data[ti * d..(ti + 1) * d];
                            let orow = &mut out
                                [(bi * t + ti) * d..(bi * t + ti + 1) * d];
                            for dd in 0..d {
                                orow[dd] = erow[dd] + prow[dd];
                            }
                        }
                    }
                }
                OpKind::MeanOverSeq => {
                    let (xdat, shape) = input!(0);
                    let VShape::Mat { rows: t, cols: d } = shape else {
                        unreachable!()
                    };
                    mean_over_seq_into(xdat, batch, t, d, &mut out);
                }
            }

            buf.slots[out_slot] = out;
            if let (Some(p), Some(t0)) = (profile.as_mut(), t0) {
                p.push(OpTiming {
                    name: node.name.clone(),
                    kind: node.kind.name(),
                    out_elems,
                    nanos: t0.elapsed().as_nanos(),
                });
            }
        }

        let out_slot = self.values[self.output_vid].slot;
        Ok(ExecOut {
            logits: buf.slots[out_slot].clone(),
            samples,
            tile_max,
        })
    }
}

/// One quantized MAC layer on a 2-D `[rows, k]` operand: the shared
/// conv/dense path of both modes — exactly the `qmatmul` the per-model
/// forwards used, with `q` the q-layer index in manifest order.
#[allow(clippy::too_many_arguments)]
fn qmac(
    m: &Manifest,
    weights: &[Tensor],
    q: usize,
    x2d: &[f32],
    rows: usize,
    k: usize,
    mode: ExecMode,
    plan: Option<&LayerPlan>,
    samples: &mut [Vec<f64>],
    tile_max: &mut [f64],
    out: &mut [f32],
    taps: Option<&QuantHealth>,
) {
    let w = &weights[2 * q];
    let bias = &weights[2 * q + 1];
    let ql = &m.qlayers[q];
    match mode {
        ExecMode::Collect => {
            let absmax = tiled_mac_into(x2d, rows, k, w, ROWS, None, out);
            add_bias_relu_into(out, ql.n, &bias.data, ql.relu);
            tile_max[q] = absmax;
            samples[q] = collect_subsample(out, m.samples_per_layer);
        }
        ExecMode::Quant {
            books,
            noise_std,
            seed,
        } => {
            let pl = &plan.expect("quant mode runs with a layer plan").layers
                [q];
            let (_, _, t_refs, t_centers) = books.layer_rows(q);
            let spec = ConvertSpec {
                refs: t_refs,
                centers: t_centers,
                sigma: noise_std * pl.tile_sigma_unit,
                seed: layer_seed(seed, q, 0),
            };
            tiled_mac_into_with_lut(
                x2d,
                rows,
                k,
                w,
                ROWS,
                Some(&spec),
                Some(&pl.tile_lut),
                out,
            );
            let nl_sigma = noise_std * pl.nl_sigma_unit;
            let nl_seed = layer_seed(seed, q, NL_SEED_SALT);
            match taps {
                // health telemetry sees exactly what the NL-ADC is
                // about to digitize: post-bias/ReLU, pre-conversion —
                // the tap needs the whole buffer in one piece, so this
                // path keeps the unfused epilogue (bit-identical to the
                // fused one; `fused_epilogue_matches_unfused_pair` and
                // the simd_parity suite pin that)
                Some(h) => {
                    add_bias_relu_into(out, ql.n, &bias.data, ql.relu);
                    h.observe(q, out);
                    nl_convert_into_with_lut(
                        out, rows, ql.n, &pl.nl_lut, nl_sigma, nl_seed,
                    );
                }
                None => bias_relu_convert_into_with_lut(
                    out, rows, ql.n, &bias.data, ql.relu, &pl.nl_lut,
                    nl_sigma, nl_seed,
                ),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::manifest::Manifest;
    use crate::quant::codebook::Codebook;

    /// A 2-dense-layer chain manifest with an inline graph.
    fn chain_manifest() -> Manifest {
        Manifest::from_json_str(
            r#"{
  "model": "chain",
  "batch": 2,
  "input_shape": [4],
  "input_dtype": "f32",
  "num_classes": 3,
  "max_levels": 128,
  "qlayers": [
    {"name": "d0", "k": 4, "n": 5, "relu": true},
    {"name": "d1", "k": 5, "n": 3, "relu": false}
  ],
  "weight_args": [
    {"name": "q00_d0_w", "shape": [4, 5]},
    {"name": "q00_d0_b", "shape": [5]},
    {"name": "q01_d1_w", "shape": [5, 3]},
    {"name": "q01_d1_b", "shape": [3]}
  ],
  "collect": {
    "out_len": 0, "logits_len": 6,
    "samples_per_layer": 8, "tilemax_offset": 0
  },
  "artifacts": {"collect": "none", "qfwd": "none"},
  "graph": {
    "input": "x",
    "output": "logits",
    "ops": [
      {"op": "dense", "name": "d0", "in": ["x"], "out": "h",
       "qlayer": "d0"},
      {"op": "dense", "name": "d1", "in": ["h"], "out": "logits",
       "qlayer": "d1"}
    ]
  }
}"#,
        )
        .unwrap()
    }

    fn chain_weights() -> Vec<Tensor> {
        vec![
            Tensor::new(vec![4, 5], (0..20).map(|v| v as f32 * 0.1).collect())
                .unwrap(),
            Tensor::new(vec![5], vec![0.1; 5]).unwrap(),
            Tensor::new(vec![5, 3], (0..15).map(|v| v as f32 * 0.05).collect())
                .unwrap(),
            Tensor::new(vec![3], vec![0.0; 3]).unwrap(),
        ]
    }

    #[test]
    fn chain_compiles_and_reuses_slots() {
        let m = chain_manifest();
        let p = GraphProgram::compile(&m).unwrap();
        assert_eq!(p.n_ops(), 2);
        assert_eq!(p.n_values(), 3);
        // x's slot is recycled for the logits after d0 consumes it
        assert_eq!(p.n_slots(), 2);
        let s = p.summary(&m);
        assert_eq!(s[0].kind, "dense");
        assert_eq!(s[0].qlayer.as_deref(), Some("d0"));
        assert_eq!(s[1].out_shape, "mat[1, 3]");
    }

    #[test]
    fn chain_executes_both_modes() {
        let m = chain_manifest();
        let p = GraphProgram::compile(&m).unwrap();
        let weights = chain_weights();
        let x = vec![0.5f32; 2 * 4];
        let mut buf = ExecBuffers::default();
        let out = p
            .execute(&m, &weights, &x, 2, ExecMode::Collect, &mut buf, None, None)
            .unwrap();
        assert_eq!(out.logits.len(), 2 * 3);
        assert_eq!(out.samples.len(), 2);
        assert_eq!(out.samples[0].len(), m.samples_per_layer);
        assert!(out.tile_max.iter().all(|&t| t > 0.0));
        // relu'd first layer -> non-negative samples
        assert!(out.samples[0].iter().all(|&v| v >= 0.0));

        let nl = vec![
            Codebook::linear(0.0, 8.0, 7),
            Codebook::linear(-8.0, 8.0, 7),
        ];
        let tile = vec![
            Codebook::linear(-8.0, 8.0, 7),
            Codebook::linear(-8.0, 8.0, 7),
        ];
        let books = ProgrammedCodebooks::stack(&nl, &tile, 128).unwrap();
        let mode = ExecMode::Quant {
            books: &books,
            noise_std: 0.0,
            seed: 7,
        };
        let mut timings = Vec::new();
        let q1 = p
            .execute(&m, &weights, &x, 2, mode, &mut buf, Some(&mut timings), None)
            .unwrap();
        assert_eq!(q1.logits.len(), 2 * 3);
        assert!(q1.samples.is_empty());
        assert_eq!(timings.len(), 2);
        assert_eq!(timings[0].name, "d0");
        // arena reuse across calls is bit-stable
        let q2 = p
            .execute(&m, &weights, &x, 2, mode, &mut buf, None, None)
            .unwrap();
        assert_eq!(q1.logits, q2.logits);
    }

    #[test]
    fn batch_one_matches_batch_row() {
        let m = chain_manifest();
        let p = GraphProgram::compile(&m).unwrap();
        let weights = chain_weights();
        let x: Vec<f32> = (0..8).map(|v| v as f32 * 0.25 - 1.0).collect();
        let nl = vec![
            Codebook::linear(0.0, 8.0, 7),
            Codebook::linear(-8.0, 8.0, 7),
        ];
        let tile = nl.clone();
        let books = ProgrammedCodebooks::stack(&nl, &tile, 128).unwrap();
        let mode = ExecMode::Quant {
            books: &books,
            noise_std: 0.0,
            seed: 3,
        };
        let mut buf = ExecBuffers::default();
        let full = p
            .execute(&m, &weights, &x, 2, mode, &mut buf, None, None)
            .unwrap();
        let one = p
            .execute(&m, &weights, &x[..4], 1, mode, &mut buf, None, None)
            .unwrap();
        assert_eq!(one.logits, full.logits[..3].to_vec());
    }

    #[test]
    fn qfwd_rejects_degenerate_programmed_ladder() {
        use crate::backend::Backend;
        let be = crate::backend::native::NativeBackend::from_parts(
            chain_manifest(),
            chain_weights(),
        )
        .unwrap();
        let nl = vec![
            Codebook::linear(0.0, 8.0, 7),
            Codebook::linear(-8.0, 8.0, 7),
        ];
        let tile = nl.clone();
        let mut books = ProgrammedCodebooks::stack(&nl, &tile, 128).unwrap();
        // collapse layer d1's NL row to a single finite reference — the
        // shape is still valid, so only the per-row check can catch it
        let levels = books.levels();
        for v in books.nl_refs.data[levels + 1..2 * levels].iter_mut() {
            *v = f32::INFINITY;
        }
        let x = vec![0.5f32; 2 * 4];
        let err = be.run_qfwd(&x, &books, 0.0, 7).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("q-layer 'd1'"), "{msg}");
        assert!(msg.contains("degenerate NL-ADC ladder"), "{msg}");
    }
}
