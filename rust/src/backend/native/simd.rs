//! Runtime-dispatched SIMD primitives for the native IMC hot path
//! (DESIGN.md §12).
//!
//! Strategy: vectorize only loops whose scalar per-element operation
//! sequence is preserved lane-for-lane, so the vector path is
//! **bit-identical** to the scalar path by construction:
//!
//! * no FMA — multiply and add stay separate instructions, exactly like
//!   the scalar `s + a * w` (a fused `mul_add` rounds once, not twice,
//!   and would change low bits);
//! * no reassociation of accumulation order — SIMD runs across the
//!   *output* dimension, where elements are independent, never across a
//!   reduction;
//! * vector `max` only where the reduction is order-free, with operand
//!   order chosen so NaN semantics match `f64::max` (NaN ignored).
//!
//! The contract is enforced bit-for-bit by `rust/tests/simd_parity.rs`
//! (kernel-level fuzz against the retained `ops::reference` scalar
//! kernels) and by the whole-model SIMD-vs-scalar assertions in
//! `rust/tests/graph_golden.rs`.
//!
//! Dispatch is decided once per process: AVX2 when detected on x86_64,
//! the scalar fallback otherwise or when `BSKMQ_NO_SIMD` is set (any
//! value but `0`).  [`force_scalar`] overrides at runtime so one test
//! process can exercise and compare both paths.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};

const MODE_UNINIT: u8 = 0;
const MODE_SCALAR: u8 = 1;
const MODE_VECTOR: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(MODE_UNINIT);
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Force the scalar fallback for every subsequent kernel call (parity
/// tests flip this to diff both paths in one process).  Safe to toggle
/// from any thread at any time: both paths produce bit-identical
/// results, so a racing caller only ever changes speed, never output.
pub fn force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::SeqCst);
}

/// Whether [`force_scalar`] is currently set.
pub fn scalar_forced() -> bool {
    FORCE_SCALAR.load(Ordering::SeqCst)
}

fn detect() -> u8 {
    let off = std::env::var("BSKMQ_NO_SIMD")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    if off {
        return MODE_SCALAR;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            return MODE_VECTOR;
        }
    }
    MODE_SCALAR
}

/// True when the vector path is active (AVX2 detected, not forced off).
#[inline]
pub fn vector_enabled() -> bool {
    if FORCE_SCALAR.load(Ordering::Relaxed) {
        return false;
    }
    match MODE.load(Ordering::Relaxed) {
        MODE_VECTOR => true,
        MODE_SCALAR => false,
        _ => {
            let m = detect();
            MODE.store(m, Ordering::Relaxed);
            m == MODE_VECTOR
        }
    }
}

/// `acc[j] += a * x[j]` over the paired prefix — the MAC tile inner
/// loop.  Scalar reference; the dispatched form is [`axpy`].
#[inline]
pub fn axpy_scalar(acc: &mut [f32], x: &[f32], a: f32) {
    for (s, &w) in acc.iter_mut().zip(x) {
        *s += a * w;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(acc: &mut [f32], x: &[f32], a: f32) {
    use std::arch::x86_64::*;
    let n = acc.len().min(x.len());
    let ap = acc.as_mut_ptr();
    let xp = x.as_ptr();
    let av = _mm256_set1_ps(a);
    let mut j = 0usize;
    while j + 8 <= n {
        // multiply and add kept separate (never vfmadd): per lane this
        // is exactly the scalar `s + (a * w)`, so bits match
        let prod = _mm256_mul_ps(av, _mm256_loadu_ps(xp.add(j)));
        let sum = _mm256_add_ps(_mm256_loadu_ps(ap.add(j)), prod);
        _mm256_storeu_ps(ap.add(j), sum);
        j += 8;
    }
    while j < n {
        *ap.add(j) += a * *xp.add(j);
        j += 1;
    }
}

/// Runtime-dispatched [`axpy_scalar`].
#[inline]
pub fn axpy(acc: &mut [f32], x: &[f32], a: f32) {
    #[cfg(target_arch = "x86_64")]
    if vector_enabled() {
        // SAFETY: vector_enabled() implies AVX2 was detected
        unsafe { axpy_avx2(acc, x, a) };
        return;
    }
    axpy_scalar(acc, x, a);
}

/// Float-mode tile fold: `out[j] += s[j]` over the paired prefix,
/// returning `max(|s[j]|)` as f64.  Scalar reference; the dispatched
/// form is [`accum_absmax`].  The max reduction is order-free, so the
/// vector path may fold lanes in any order.
#[inline]
pub fn accum_absmax_scalar(out: &mut [f32], s: &[f32]) -> f64 {
    let mut m = 0f64;
    for (o, &v) in out.iter_mut().zip(s) {
        m = m.max(v.abs() as f64);
        *o += v;
    }
    m
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn accum_absmax_avx2(out: &mut [f32], s: &[f32]) -> f64 {
    use std::arch::x86_64::*;
    let n = out.len().min(s.len());
    let op = out.as_mut_ptr();
    let sp = s.as_ptr();
    let sign = _mm256_set1_ps(-0.0);
    let mut mv = _mm256_setzero_ps();
    let mut j = 0usize;
    while j + 8 <= n {
        let v = _mm256_loadu_ps(sp.add(j));
        // andnot clears the sign bit: |v| without branches; vmaxps
        // returns its SECOND operand on NaN, so passing the accumulator
        // second ignores NaN exactly like `f64::max`
        let av = _mm256_andnot_ps(sign, v);
        mv = _mm256_max_ps(av, mv);
        let acc = _mm256_add_ps(_mm256_loadu_ps(op.add(j)), v);
        _mm256_storeu_ps(op.add(j), acc);
        j += 8;
    }
    let mut lanes = [0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), mv);
    let mut m = 0f64;
    for &l in &lanes {
        m = m.max(l as f64);
    }
    while j < n {
        let v = *sp.add(j);
        m = m.max(v.abs() as f64);
        *op.add(j) += v;
        j += 1;
    }
    m
}

/// Runtime-dispatched [`accum_absmax_scalar`].
#[inline]
pub fn accum_absmax(out: &mut [f32], s: &[f32]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if vector_enabled() {
        // SAFETY: vector_enabled() implies AVX2 was detected
        return unsafe { accum_absmax_avx2(out, s) };
    }
    accum_absmax_scalar(out, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn with_forced<R>(on: bool, f: impl FnOnce() -> R) -> R {
        force_scalar(on);
        let r = f();
        force_scalar(false);
        r
    }

    #[test]
    fn axpy_paths_bit_identical() {
        // 19 elements: two full AVX lanes + a 3-wide scalar tail
        let x: Vec<f32> = (0..19).map(|v| (v as f32) * 0.37 - 2.1).collect();
        let base: Vec<f32> = (0..19).map(|v| (v as f32) * -0.11).collect();
        for a in [0.0f32, -1.5, 3.25e-3, 7.0] {
            let mut want = base.clone();
            axpy_scalar(&mut want, &x, a);
            let mut sc = base.clone();
            with_forced(true, || axpy(&mut sc, &x, a));
            let mut vec = base.clone();
            with_forced(false, || axpy(&mut vec, &x, a));
            let bits = |v: &[f32]| -> Vec<u32> {
                v.iter().map(|f| f.to_bits()).collect()
            };
            assert_eq!(bits(&sc), bits(&want), "forced-scalar a={a}");
            assert_eq!(bits(&vec), bits(&want), "dispatched a={a}");
        }
    }

    #[test]
    fn accum_absmax_paths_agree() {
        let s: Vec<f32> = (0..21).map(|v| (10 - v) as f32 * 1.3).collect();
        let base: Vec<f32> = (0..21).map(|v| v as f32).collect();
        let mut want = base.clone();
        let mw = accum_absmax_scalar(&mut want, &s);
        let mut got = base.clone();
        let mg = accum_absmax(&mut got, &s);
        assert_eq!(mw.to_bits(), mg.to_bits());
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(mw, 13.0);
    }

    #[test]
    fn force_scalar_toggles() {
        force_scalar(true);
        assert!(scalar_forced());
        assert!(!vector_enabled());
        force_scalar(false);
        assert!(!scalar_forced());
    }
}
