//! Numeric kernels of the native IMC backend: im2col, the 256-row-tiled
//! integer MAC with per-tile NL-ADC digitization, pooling, layernorm and
//! attention — pure Rust, data-parallel across output rows via scoped
//! threads (this build environment vendors no rayon; the row partition is
//! deterministic and noise RNG is seeded per row, so results do not
//! depend on the thread count).
//!
//! Every kernel comes in a `_into` form writing into a caller-provided
//! slice — the graph executor (`super::graph`) routes all hot-path
//! tensors through a reusable scratch arena, so no kernel allocates per
//! op.  The [`Mat`]/[`Feat`] wrappers remain for unit tests and oracles.

use std::sync::Mutex;

use super::simd;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Dense row-major 2-D activation matrix.
#[derive(Clone, Debug)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(rows * cols, data.len(), "Mat shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// NHWC feature map.
#[derive(Clone, Debug)]
pub struct Feat {
    pub b: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl Feat {
    pub fn new(b: usize, h: usize, w: usize, c: usize, data: Vec<f32>) -> Feat {
        assert_eq!(b * h * w * c, data.len(), "Feat shape/data mismatch");
        Feat { b, h, w, c, data }
    }

    /// Reinterpret a `[b*oh*ow, c]` matmul output as NHWC.
    pub fn from_mat(m: Mat, b: usize, h: usize, w: usize) -> Feat {
        assert_eq!(m.rows, b * h * w, "Feat::from_mat row mismatch");
        Feat::new(b, h, w, m.cols, m.data)
    }

    /// `[b, h*w*c]` view (row-major NHWC flatten, the VGG head layout).
    pub fn flatten(self) -> Mat {
        let cols = self.h * self.w * self.c;
        Mat::new(self.b, cols, self.data)
    }
}

/// Worker thread count: the [`set_thread_override`] hook when armed,
/// else env `BSKMQ_THREADS` / host parallelism, resolved **once** per
/// process (the old implementation re-read the environment on every
/// `par_row_blocks` call — a syscall-shaped tax on every op).
pub fn num_threads() -> usize {
    let o = THREAD_OVERRIDE.load(std::sync::atomic::Ordering::Relaxed);
    if o != 0 {
        return o;
    }
    *BASE_THREADS.get_or_init(|| {
        if let Ok(v) = std::env::var("BSKMQ_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

static BASE_THREADS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
static THREAD_OVERRIDE: std::sync::atomic::AtomicUsize =
    std::sync::atomic::AtomicUsize::new(0);

/// Test-only override of [`num_threads`] (`None` restores the cached
/// process default).  Lets one test process sweep the 1/4/8-thread
/// partitioning matrix without respawning; results are bit-identical at
/// any thread count by the per-row seeding contract, so a racing
/// override never changes another test's output, only its partition.
pub fn set_thread_override(n: Option<usize>) {
    THREAD_OVERRIDE.store(
        n.map(|v| v.max(1)).unwrap_or(0),
        std::sync::atomic::Ordering::SeqCst,
    );
}

/// Run `f(first_row, block)` over row blocks of `out` — through the
/// persistent executor pool ([`super::exec_pool`]) by default, on
/// freshly scoped threads when the pool is disabled (`BSKMQ_NO_POOL`,
/// [`super::exec_pool::force_spawn`]).  Both paths use the identical
/// static partition (`chunk_rows = rows.div_ceil(threads)`, block
/// `ti` starting at row `ti * chunk_rows`), so they are bit-identical
/// for any kernel whose per-row work is deterministic — the contract
/// every caller in this module upholds via per-row RNG seeding.
pub fn par_row_blocks<F>(rows: usize, cols: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), rows * cols, "par_row_blocks shape mismatch");
    if rows == 0 || cols == 0 {
        return;
    }
    let threads = num_threads().min(rows);
    if threads <= 1 {
        f(0, out);
        return;
    }
    let chunk_rows = rows.div_ceil(threads);
    let f = &f;
    if super::exec_pool::pool_enabled() {
        let n_tasks = rows.div_ceil(chunk_rows);
        let base = out.as_mut_ptr() as usize;
        let total = out.len();
        super::exec_pool::global().run(n_tasks, &move |ti| {
            let start = ti * chunk_rows * cols;
            let end = (start + chunk_rows * cols).min(total);
            // SAFETY: tasks receive disjoint [start, end) sub-slices of
            // `out`, which outlives the (blocking) pool call
            let block = unsafe {
                std::slice::from_raw_parts_mut(
                    (base as *mut f32).add(start),
                    end - start,
                )
            };
            f(ti * chunk_rows, block);
        });
        return;
    }
    std::thread::scope(|s| {
        for (ti, block) in out.chunks_mut(chunk_rows * cols).enumerate() {
            s.spawn(move || f(ti * chunk_rows, block));
        }
    });
}

thread_local! {
    /// Per-thread kernel scratch, reused across ops and forwards: pool
    /// workers are long-lived, so after warmup the hot path performs
    /// zero per-op heap allocation (the scoped-spawn fallback's threads
    /// die per call and keep paying it — one more reason the pool wins).
    static KERNEL_SCRATCH: std::cell::RefCell<Vec<f32>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Run `f` with this thread's reusable zero-filled scratch of `len`
/// floats (grown, never shrunk).
fn with_scratch<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    KERNEL_SCRATCH.with(|c| {
        let mut buf = c.borrow_mut();
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        f(&mut buf[..len])
    })
}

/// Floor-ADC conversion against a padded reference ladder: the index of
/// the largest reference `<= v` (padding slots are `+inf`, never taken),
/// mapped to its digital center — `ref.ref_nl_quantize` semantics.
#[inline]
pub fn floor_adc(refs: &[f32], centers: &[f32], v: f32) -> f32 {
    let cnt = refs.partition_point(|&r| r <= v);
    centers[cnt.saturating_sub(1).min(centers.len() - 1)]
}

/// Smallest positive finite reference step — the ADC LSB (noise unit).
pub fn min_ref_step(refs: &[f32]) -> f32 {
    let mut m = f32::INFINITY;
    for w in refs.windows(2) {
        let d = w[1] - w[0];
        if d.is_finite() && d > 0.0 && d < m {
            m = d;
        }
    }
    if m.is_finite() {
        m
    } else {
        1.0
    }
}

/// Dense-grid accelerator for [`floor_adc`]: a per-ladder lookup table
/// mapping a probe value to a *starting guess* for the ladder index,
/// refined by at most a couple of exact comparison steps.  The LUT is
/// purely a performance hint — `convert` enforces the `partition_point`
/// contract with two bounded scans, so it is bit-identical to
/// [`floor_adc`] for every finite, NaN and -inf input (+inf lands on
/// the same center *value* through the padding convention: padding
/// centers repeat the last real center).
/// Owned (no ladder borrow) so compiled [`LayerPlan`]s can cache one
/// per quantized layer across forwards — rebuilding these per op was
/// the single largest steady-state allocation before PR 9.
///
/// [`LayerPlan`]: super::graph::LayerPlan
#[derive(Clone, Debug)]
pub struct AdcLut {
    refs: Vec<f32>,
    centers: Vec<f32>,
    /// finite ladder prefix length (the rest is `+inf` padding)
    n_finite: usize,
    base: f32,
    scale: f32,
    lut: Vec<u32>,
}

impl AdcLut {
    pub fn new(refs: &[f32], centers: &[f32]) -> AdcLut {
        assert!(!centers.is_empty(), "AdcLut: empty centers");
        let n_finite = refs.iter().take_while(|r| r.is_finite()).count();
        let base = refs.first().copied().unwrap_or(0.0);
        let span = if n_finite > 0 {
            refs[n_finite - 1] - base
        } else {
            0.0
        };
        // ~4 cells per ladder step keeps the refine scans at <=1 step
        let cells = (n_finite.max(1) * 4).next_power_of_two().min(4096);
        let scale = if span > 0.0 { cells as f32 / span } else { 0.0 };
        let mut lut = vec![0u32; cells + 1];
        if scale > 0.0 {
            for (g, slot) in lut.iter_mut().enumerate().skip(1) {
                // one cell back: a conservative cut that absorbs the
                // float rounding of the probe->cell map; convert()'s
                // scans walk the remaining steps exactly
                let probe = base + (g as f32 - 1.0) / scale;
                *slot =
                    refs[..n_finite].partition_point(|&r| r <= probe) as u32;
            }
        }
        AdcLut {
            refs: refs.to_vec(),
            centers: centers.to_vec(),
            n_finite,
            base,
            scale,
            lut,
        }
    }

    /// The padded reference ladder this table was built from.
    pub fn refs(&self) -> &[f32] {
        &self.refs
    }

    /// The digital centers this table was built from.
    pub fn centers(&self) -> &[f32] {
        &self.centers
    }

    /// Branch-light [`floor_adc`]: same center for every input (see the
    /// type-level doc for the one +inf caveat, equal-value by padding).
    #[inline]
    pub fn convert(&self, v: f32) -> f32 {
        // float->usize casts saturate: NaN and negatives land on 0
        let cell =
            (((v - self.base) * self.scale) as usize).min(self.lut.len() - 1);
        let mut c = self.lut[cell] as usize;
        while c > 0 && self.refs[c - 1] > v {
            c -= 1;
        }
        while c < self.n_finite && self.refs[c] <= v {
            c += 1;
        }
        self.centers[c.saturating_sub(1).min(self.centers.len() - 1)]
    }
}

/// Per-tile conversion programmed into the MAC loop (quant mode).
pub struct ConvertSpec<'a> {
    pub refs: &'a [f32],
    pub centers: &'a [f32],
    /// pre-scaled conversion noise sigma in MAC units (noise_std * LSB)
    pub sigma: f32,
    /// per-layer noise seed (row index is mixed in per output row)
    pub seed: u64,
}

const ROW_SEED_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// Output rows digitized per streamed weight tile: the `tile_k x n`
/// weight block stays hot in cache across the row block, cutting weight
/// traffic by ~`ROW_BLOCK`x.  Bit-safe: every output row keeps its own
/// RNG, created per row and consumed in (tile, then column) order
/// exactly like the single-row loop.
const ROW_BLOCK: usize = 8;

/// The crossbar dataflow of Fig. 2: the contraction dimension is split
/// into `tile_k`-row tiles (one analog accumulation each); every tile's
/// partial sum is digitized — through the per-tile codebook in quant mode
/// — and digitally accumulated into `out` (`[m, n]`, fully overwritten).
///
/// Returns `absmax`, the largest |partial| observed across tiles (float
/// mode only; 0.0 in quant mode).
pub fn tiled_mac_into(
    x: &[f32],
    m: usize,
    k: usize,
    w: &Tensor,
    tile_k: usize,
    quant: Option<&ConvertSpec>,
    out: &mut [f32],
) -> f64 {
    let lut = quant.map(|q| AdcLut::new(q.refs, q.centers));
    tiled_mac_into_with_lut(x, m, k, w, tile_k, quant, lut.as_ref(), out)
}

/// [`tiled_mac_into`] with a caller-supplied [`AdcLut`] (built from the
/// same ladder as `quant`, normally cached in a compiled layer plan) so
/// the steady-state forward skips per-op LUT construction.
#[allow(clippy::too_many_arguments)]
pub fn tiled_mac_into_with_lut(
    x: &[f32],
    m: usize,
    k: usize,
    w: &Tensor,
    tile_k: usize,
    quant: Option<&ConvertSpec>,
    lut: Option<&AdcLut>,
    out: &mut [f32],
) -> f64 {
    assert_eq!(w.shape.len(), 2, "weight matrix must be 2-D");
    assert_eq!(w.shape[0], k, "contraction mismatch {} vs {}", w.shape[0], k);
    let n = w.shape[1];
    assert_eq!(x.len(), m * k, "tiled_mac input shape mismatch");
    assert_eq!(out.len(), m * n, "tiled_mac output shape mismatch");
    assert_eq!(
        quant.is_some(),
        lut.is_some(),
        "quant spec and AdcLut must be supplied together"
    );
    let kt = k.div_ceil(tile_k).max(1);
    out.fill(0.0);
    let absmax = Mutex::new(0f64);
    par_row_blocks(m, n, out, |row0, block| {
        let rows_here = block.len() / n;
        with_scratch(ROW_BLOCK.min(rows_here) * n, |scratch| {
            let mut rngs: [Rng; ROW_BLOCK] =
                std::array::from_fn(|_| Rng::new(0));
            let mut local_max = 0f64;
            for (bi, sub) in block.chunks_mut(ROW_BLOCK * n).enumerate() {
                let r0 = row0 + bi * ROW_BLOCK;
                let rb = sub.len() / n;
                if let Some(q) = quant {
                    for (ri, r) in (r0..r0 + rb).enumerate() {
                        rngs[ri] = Rng::new(
                            q.seed ^ (r as u64).wrapping_mul(ROW_SEED_MIX),
                        );
                    }
                }
                for t in 0..kt {
                    let lo = t * tile_k;
                    let hi = ((t + 1) * tile_k).min(k);
                    scratch[..rb * n].fill(0.0);
                    // all rb rows stream the same weight tile while it is
                    // hot in cache; the `a != 0.0` skip is part of the
                    // bit-exactness contract (-0.0 + 0.0 flips sign bits),
                    // so it stays in every path
                    for ri in 0..rb {
                        let xrow = &x[(r0 + ri) * k..(r0 + ri) * k + k];
                        let srow = &mut scratch[ri * n..ri * n + n];
                        for (kk, &a) in
                            xrow.iter().enumerate().take(hi).skip(lo)
                        {
                            if a != 0.0 {
                                let wrow = &w.data[kk * n..kk * n + n];
                                simd::axpy(srow, wrow, a);
                            }
                        }
                    }
                    if let (Some(q), Some(adc)) = (quant, lut) {
                        for ri in 0..rb {
                            let rng = &mut rngs[ri];
                            let orow = &mut sub[ri * n..ri * n + n];
                            let srow = &scratch[ri * n..ri * n + n];
                            if q.sigma != 0.0 {
                                for (oj, &v) in orow.iter_mut().zip(srow) {
                                    let p =
                                        v + q.sigma * rng.gaussian() as f32;
                                    *oj += adc.convert(p);
                                }
                            } else {
                                for (oj, &v) in orow.iter_mut().zip(srow) {
                                    *oj += adc.convert(v);
                                }
                            }
                        }
                    } else {
                        for ri in 0..rb {
                            let orow = &mut sub[ri * n..ri * n + n];
                            let srow = &scratch[ri * n..ri * n + n];
                            let mx = simd::accum_absmax(orow, srow);
                            if mx > local_max {
                                local_max = mx;
                            }
                        }
                    }
                }
            }
            if quant.is_none() {
                let mut g = absmax.lock().unwrap();
                if local_max > *g {
                    *g = local_max;
                }
            }
        });
    });
    absmax.into_inner().unwrap()
}

/// [`tiled_mac_into`] on [`Mat`] operands, allocating the output.
pub fn tiled_mac(
    x: &Mat,
    w: &Tensor,
    tile_k: usize,
    quant: Option<&ConvertSpec>,
) -> (Mat, f64) {
    let n = w.shape[1];
    let mut out = vec![0f32; x.rows * n];
    let absmax =
        tiled_mac_into(&x.data, x.rows, x.cols, w, tile_k, quant, &mut out);
    (Mat::new(x.rows, n, out), absmax)
}

/// `y += bias` (broadcast over `cols`-wide rows), then optional ReLU.
pub fn add_bias_relu_into(y: &mut [f32], cols: usize, bias: &[f32], relu: bool) {
    assert_eq!(bias.len(), cols, "bias length mismatch");
    for row in y.chunks_mut(cols) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
            if relu && *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

/// [`add_bias_relu_into`] on a [`Mat`].
pub fn add_bias_relu(y: &mut Mat, bias: &[f32], relu: bool) {
    add_bias_relu_into(&mut y.data, y.cols, bias, relu);
}

/// Fused quant-layer epilogue: bias add, optional ReLU and NL-ADC
/// conversion in one parallel pass, so each output element is loaded
/// and stored once instead of three times.  Bit-identical to
/// [`add_bias_relu_into`] followed by [`nl_convert_into`] — same
/// per-row RNG stream, same ladder semantics — which the unfused pair
/// remains for paths that must observe the pre-conversion activations
/// (the quant-health tap).
#[allow(clippy::too_many_arguments)]
pub fn bias_relu_convert_into(
    y: &mut [f32],
    rows: usize,
    cols: usize,
    bias: &[f32],
    relu: bool,
    refs: &[f32],
    centers: &[f32],
    sigma: f32,
    seed: u64,
) {
    let adc = AdcLut::new(refs, centers);
    bias_relu_convert_into_with_lut(
        y, rows, cols, bias, relu, &adc, sigma, seed,
    );
}

/// [`bias_relu_convert_into`] against a cached [`AdcLut`] (satellite of
/// the layer-plan work: the plan owns the LUT, the op just converts).
#[allow(clippy::too_many_arguments)]
pub fn bias_relu_convert_into_with_lut(
    y: &mut [f32],
    rows: usize,
    cols: usize,
    bias: &[f32],
    relu: bool,
    adc: &AdcLut,
    sigma: f32,
    seed: u64,
) {
    assert_eq!(bias.len(), cols, "bias length mismatch");
    par_row_blocks(rows, cols, y, |row0, block| {
        for (ri, row) in block.chunks_mut(cols).enumerate() {
            let r = row0 + ri;
            let mut rng =
                Rng::new(seed ^ (r as u64).wrapping_mul(ROW_SEED_MIX).rotate_left(17));
            for (v, &b) in row.iter_mut().zip(bias) {
                let mut p = *v + b;
                if relu && p < 0.0 {
                    p = 0.0;
                }
                if sigma != 0.0 {
                    p += sigma * rng.gaussian() as f32;
                }
                *v = adc.convert(p);
            }
        }
    });
}

/// Layer-output NL-ADC conversion (optionally with conversion noise).
pub fn nl_convert_into(
    y: &mut [f32],
    rows: usize,
    cols: usize,
    refs: &[f32],
    centers: &[f32],
    sigma: f32,
    seed: u64,
) {
    let adc = AdcLut::new(refs, centers);
    nl_convert_into_with_lut(y, rows, cols, &adc, sigma, seed);
}

/// [`nl_convert_into`] against a cached [`AdcLut`].
pub fn nl_convert_into_with_lut(
    y: &mut [f32],
    rows: usize,
    cols: usize,
    adc: &AdcLut,
    sigma: f32,
    seed: u64,
) {
    par_row_blocks(rows, cols, y, |row0, block| {
        for (ri, row) in block.chunks_mut(cols).enumerate() {
            let r = row0 + ri;
            if sigma != 0.0 {
                let mut rng = Rng::new(
                    seed ^ (r as u64).wrapping_mul(ROW_SEED_MIX).rotate_left(17),
                );
                for v in row.iter_mut() {
                    let p = *v + sigma * rng.gaussian() as f32;
                    *v = adc.convert(p);
                }
            } else {
                for v in row.iter_mut() {
                    *v = adc.convert(*v);
                }
            }
        }
    });
}

/// [`nl_convert_into`] on a [`Mat`].
pub fn nl_convert(y: &mut Mat, refs: &[f32], centers: &[f32], sigma: f32, seed: u64) {
    nl_convert_into(&mut y.data, y.rows, y.cols, refs, centers, sigma, seed);
}

/// Convolution output geometry: `(oh, ow, pad_top, pad_left)` for a
/// `kh x kw` kernel at `stride` over an `h x w` map.  `same` pads like
/// XLA SAME (low pad = total/2); otherwise VALID.
pub fn conv_dims(
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    same: bool,
) -> (usize, usize, usize, usize) {
    if same {
        let oh = h.div_ceil(stride);
        let ow = w.div_ceil(stride);
        let ph = ((oh - 1) * stride + kh).saturating_sub(h);
        let pw = ((ow - 1) * stride + kw).saturating_sub(w);
        (oh, ow, ph / 2, pw / 2)
    } else {
        ((h - kh) / stride + 1, (w - kw) / stride + 1, 0, 0)
    }
}

/// im2col with `(kh, kw, cin)` feature ordering — matches the export-time
/// `w.reshape(kh*kw*cin, cout)` of HWIO conv weights.  `out` must hold
/// `b*oh*ow * kh*kw*c` elements; it is fully overwritten (padding zeros
/// included).
#[allow(clippy::too_many_arguments)]
pub fn im2col_into(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    same: bool,
    out: &mut [f32],
) -> (usize, usize) {
    let (oh, ow, pt, pl) = conv_dims(h, w, kh, kw, stride, same);
    let cols = kh * kw * c;
    assert_eq!(x.len(), b * h * w * c, "im2col input shape mismatch");
    assert_eq!(out.len(), b * oh * ow * cols, "im2col output shape mismatch");
    // one patch row per output pixel: rows are written independently, so
    // the parallel partition cannot change any byte of the result
    par_row_blocks(b * oh * ow, cols, out, |row0, block| {
        block.fill(0.0);
        for (ri, row) in block.chunks_mut(cols).enumerate() {
            let r = row0 + ri;
            let (bi, oy, ox) = (r / (oh * ow), r / ow % oh, r % ow);
            for i in 0..kh {
                let iy = (oy * stride + i) as isize - pt as isize;
                if iy < 0 || iy >= h as isize {
                    continue; // zero padding rows add nothing
                }
                for j in 0..kw {
                    let ix = (ox * stride + j) as isize - pl as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                    let dst = (i * kw + j) * c;
                    row[dst..dst + c].copy_from_slice(&x[src..src + c]);
                }
            }
        }
    });
    (oh, ow)
}

/// [`im2col_into`] on a [`Feat`], allocating the patch matrix.
pub fn im2col(
    x: &Feat,
    kh: usize,
    kw: usize,
    stride: usize,
    same: bool,
) -> (Mat, usize, usize) {
    let (oh, ow, _, _) = conv_dims(x.h, x.w, kh, kw, stride, same);
    let cols = kh * kw * x.c;
    let mut out = vec![0f32; x.b * oh * ow * cols];
    im2col_into(&x.data, x.b, x.h, x.w, x.c, kh, kw, stride, same, &mut out);
    (Mat::new(x.b * oh * ow, cols, out), oh, ow)
}

/// 2x2 stride-2 VALID max pool into `out` (`b * (h/2) * (w/2) * c`).
pub fn max_pool2_into(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    out: &mut [f32],
) {
    let (oh, ow) = (h / 2, w / 2);
    assert_eq!(x.len(), b * h * w * c, "max_pool2 input shape mismatch");
    assert_eq!(out.len(), b * oh * ow * c, "max_pool2 output shape mismatch");
    par_row_blocks(b * oh * ow, c, out, |row0, block| {
        for (ri, row) in block.chunks_mut(c).enumerate() {
            let r = row0 + ri;
            let (bi, oy, ox) = (r / (oh * ow), r / ow % oh, r % ow);
            for (ci, o) in row.iter_mut().enumerate() {
                let mut m = f32::NEG_INFINITY;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let src =
                            ((bi * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ci;
                        m = m.max(x[src]);
                    }
                }
                *o = m;
            }
        }
    });
}

/// [`max_pool2_into`] on a [`Feat`].
pub fn max_pool2(x: &Feat) -> Feat {
    let (oh, ow) = (x.h / 2, x.w / 2);
    let mut out = vec![0f32; x.b * oh * ow * x.c];
    max_pool2_into(&x.data, x.b, x.h, x.w, x.c, &mut out);
    Feat::new(x.b, oh, ow, x.c, out)
}

/// 3x3 stride-1 SAME average pool with a fixed /9 divisor (the inception
/// pool branch: `reduce_window` sum over SAME padding, then / 9), into
/// `out` (same length as `x`).
pub fn avg_pool3_same_into(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    out: &mut [f32],
) {
    assert_eq!(x.len(), b * h * w * c, "avg_pool3 input shape mismatch");
    assert_eq!(out.len(), x.len(), "avg_pool3 output shape mismatch");
    par_row_blocks(b * h * w, c, out, |row0, block| {
        for (ri, row) in block.chunks_mut(c).enumerate() {
            let r = row0 + ri;
            let (bi, oy, ox) = (r / (h * w), r / w % h, r % w);
            for (ci, o) in row.iter_mut().enumerate() {
                let mut s = 0f32;
                for dy in -1isize..=1 {
                    let iy = oy as isize + dy;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for dx in -1isize..=1 {
                        let ix = ox as isize + dx;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        s += x[((bi * h + iy as usize) * w + ix as usize) * c
                            + ci];
                    }
                }
                *o = s / 9.0;
            }
        }
    });
}

/// [`avg_pool3_same_into`] on a [`Feat`].
pub fn avg_pool3_same(x: &Feat) -> Feat {
    let mut out = vec![0f32; x.data.len()];
    avg_pool3_same_into(&x.data, x.b, x.h, x.w, x.c, &mut out);
    Feat::new(x.b, x.h, x.w, x.c, out)
}

/// Global average pool into `out` (`[b, c]`; fully overwritten).
pub fn global_avg_pool_into(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    out: &mut [f32],
) {
    let hw = (h * w) as f32;
    assert_eq!(x.len(), b * h * w * c, "gap input shape mismatch");
    assert_eq!(out.len(), b * c, "gap output shape mismatch");
    par_row_blocks(b, c, out, |row0, block| {
        block.fill(0.0);
        for (ri, orow) in block.chunks_mut(c).enumerate() {
            let bi = row0 + ri;
            for p in 0..h * w {
                let src = (bi * h * w + p) * c;
                for (ci, o) in orow.iter_mut().enumerate() {
                    *o += x[src + ci];
                }
            }
            for o in orow.iter_mut() {
                *o /= hw;
            }
        }
    });
}

/// [`global_avg_pool_into`] on a [`Feat`], to `[b, c]`.
pub fn global_avg_pool(x: &Feat) -> Mat {
    let mut out = vec![0f32; x.b * x.c];
    global_avg_pool_into(&x.data, x.b, x.h, x.w, x.c, &mut out);
    Mat::new(x.b, x.c, out)
}

/// Digital residual connection: `a + b` elementwise, optionally ReLU'd.
pub fn add_into(a: &[f32], b: &[f32], relu: bool, out: &mut [f32]) {
    assert_eq!(a.len(), b.len(), "add shape mismatch");
    assert_eq!(out.len(), a.len(), "add output shape mismatch");
    if relu {
        for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
            *o = (x + y).max(0.0);
        }
    } else {
        for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
            *o = x + y;
        }
    }
}

/// `relu(a + b)` on [`Feat`] operands.
pub fn add_relu(a: &Feat, b: &Feat) -> Feat {
    let mut out = vec![0f32; a.data.len()];
    add_into(&a.data, &b.data, true, &mut out);
    Feat::new(a.b, a.h, a.w, a.c, out)
}

/// Channel concatenation of equal-spatial maps: each part is its flat
/// data plus channel count; `pixels` is the shared `b*h*w`.
pub fn concat_c_into(
    parts: &[(&[f32], usize)],
    pixels: usize,
    out: &mut [f32],
) {
    let c: usize = parts.iter().map(|&(_, pc)| pc).sum();
    assert_eq!(out.len(), pixels * c, "concat output shape mismatch");
    for &(data, pc) in parts {
        assert_eq!(data.len(), pixels * pc, "concat part shape mismatch");
    }
    for p_idx in 0..pixels {
        let mut off = p_idx * c;
        for &(data, pc) in parts {
            let src = p_idx * pc;
            out[off..off + pc].copy_from_slice(&data[src..src + pc]);
            off += pc;
        }
    }
}

/// [`concat_c_into`] on [`Feat`] parts.
pub fn concat_c(parts: &[&Feat]) -> Feat {
    let (b, h, w) = (parts[0].b, parts[0].h, parts[0].w);
    for p in parts {
        assert_eq!((p.b, p.h, p.w), (b, h, w), "concat spatial mismatch");
    }
    let c: usize = parts.iter().map(|p| p.c).sum();
    let mut out = vec![0f32; b * h * w * c];
    let flat: Vec<(&[f32], usize)> =
        parts.iter().map(|p| (p.data.as_slice(), p.c)).collect();
    concat_c_into(&flat, b * h * w, &mut out);
    Feat::new(b, h, w, c, out)
}

/// Row-wise layer norm over `cols`-wide rows (eps matches the
/// export-side 1e-6), into `out` (same length as `x`).
pub fn layer_norm_into(
    x: &[f32],
    cols: usize,
    gamma: &[f32],
    beta: &[f32],
    out: &mut [f32],
) {
    assert_eq!(gamma.len(), cols, "layernorm gamma mismatch");
    assert_eq!(beta.len(), cols, "layernorm beta mismatch");
    assert_eq!(out.len(), x.len(), "layernorm output shape mismatch");
    let rows = x.len() / cols;
    par_row_blocks(rows, cols, out, |row0, block| {
        for (ri, orow) in block.chunks_mut(cols).enumerate() {
            let row = &x[(row0 + ri) * cols..(row0 + ri + 1) * cols];
            let mu = row.iter().sum::<f32>() / cols as f32;
            let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>()
                / cols as f32;
            let inv = 1.0 / (var + 1e-6).sqrt();
            for j in 0..cols {
                orow[j] = (row[j] - mu) * inv * gamma[j] + beta[j];
            }
        }
    });
}

/// [`layer_norm_into`] on a [`Mat`].
pub fn layer_norm(y: &Mat, gamma: &[f32], beta: &[f32]) -> Mat {
    let mut out = vec![0f32; y.data.len()];
    layer_norm_into(&y.data, y.cols, gamma, beta, &mut out);
    Mat::new(y.rows, y.cols, out)
}

/// Elementwise sum of equal-shape matrices.
pub fn add_mat(a: &Mat, b: &Mat) -> Mat {
    let mut out = vec![0f32; a.data.len()];
    add_into(&a.data, &b.data, false, &mut out);
    Mat::new(a.rows, a.cols, out)
}

fn softmax_inplace(row: &mut [f32]) {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut s = 0f32;
    for v in row.iter_mut() {
        *v = (*v - m).exp();
        s += *v;
    }
    for v in row.iter_mut() {
        *v /= s;
    }
}

/// Digital-domain multi-head attention over quantized Q/K/V `[b*t, d]`
/// row matrices (the transformer's non-MAC stage), parallel over the
/// batch: each batch element's `t*d` output block is written by one
/// task, with the score matrix living in that thread's reusable
/// scratch (no caller-provided buffer, no per-op allocation).  `out`
/// must be zeroed on entry (partials accumulate per head).
#[allow(clippy::too_many_arguments)]
pub fn attention_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    t: usize,
    d: usize,
    heads: usize,
    out: &mut [f32],
) {
    assert_eq!(d % heads, 0, "d_model not divisible by heads");
    assert_eq!(q.len(), b * t * d, "attention q shape mismatch");
    assert_eq!(k.len(), q.len(), "attention k shape mismatch");
    assert_eq!(v.len(), q.len(), "attention v shape mismatch");
    assert_eq!(out.len(), q.len(), "attention output shape mismatch");
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    par_row_blocks(b, t * d, out, |b0, block| {
        with_scratch(t * t, |scores| {
            for (bi_off, bout) in block.chunks_mut(t * d).enumerate() {
                let bi = b0 + bi_off;
                for h in 0..heads {
                    let off = h * hd;
                    for t1 in 0..t {
                        let qrow = &q[(bi * t + t1) * d + off..][..hd];
                        for t2 in 0..t {
                            let krow = &k[(bi * t + t2) * d + off..][..hd];
                            let mut s = 0f32;
                            for dd in 0..hd {
                                s += qrow[dd] * krow[dd];
                            }
                            scores[t1 * t + t2] = s * scale;
                        }
                    }
                    for t1 in 0..t {
                        softmax_inplace(&mut scores[t1 * t..(t1 + 1) * t]);
                    }
                    for t1 in 0..t {
                        let orow = &mut bout[t1 * d + off..][..hd];
                        for t2 in 0..t {
                            let a = scores[t1 * t + t2];
                            let vrow = &v[(bi * t + t2) * d + off..][..hd];
                            for dd in 0..hd {
                                orow[dd] += a * vrow[dd];
                            }
                        }
                    }
                }
            }
        });
    });
}

/// [`attention_into`] on [`Mat`] operands, allocating the output.
pub fn attention(q: &Mat, k: &Mat, v: &Mat, b: usize, t: usize, heads: usize) -> Mat {
    let d = q.cols;
    let mut out = vec![0f32; b * t * d];
    attention_into(&q.data, &k.data, &v.data, b, t, d, heads, &mut out);
    Mat::new(b * t, d, out)
}

/// Mean over the sequence axis: `[b*t, d]` -> `[b, d]` into `out`
/// (fully overwritten).
pub fn mean_over_seq_into(
    x: &[f32],
    b: usize,
    t: usize,
    d: usize,
    out: &mut [f32],
) {
    assert_eq!(x.len(), b * t * d, "mean_over_seq input shape mismatch");
    assert_eq!(out.len(), b * d, "mean_over_seq output shape mismatch");
    par_row_blocks(b, d, out, |row0, block| {
        block.fill(0.0);
        for (ri, orow) in block.chunks_mut(d).enumerate() {
            let bi = row0 + ri;
            for ti in 0..t {
                let src = (bi * t + ti) * d;
                for (dd, o) in orow.iter_mut().enumerate() {
                    *o += x[src + dd];
                }
            }
            for o in orow.iter_mut() {
                *o /= t as f32;
            }
        }
    });
}

/// [`mean_over_seq_into`] on a [`Mat`].
pub fn mean_over_seq(h: &Mat, b: usize, t: usize) -> Mat {
    let mut out = vec![0f32; b * h.cols];
    mean_over_seq_into(&h.data, b, t, h.cols, &mut out);
    Mat::new(b, h.cols, out)
}

/// Deterministic evenly-spaced activation subsample — mirrors the
/// collect graph's `_collect_subsample` (index `i -> i*len/want`).
///
/// Indices cover the whole activation including the tail; the previous
/// truncated-stride decimation (`stride = len/want`) read only the
/// first `stride*want` elements, so e.g. `len=599, want=300` sampled
/// indices 0..=299 and calibration sketches never saw the upper half.
/// Tiny layers (`len < want`) repeat elements through the same formula.
pub fn collect_subsample(flat: &[f32], want: usize) -> Vec<f64> {
    assert!(!flat.is_empty(), "subsample of empty activation");
    (0..want)
        .map(|i| flat[i * flat.len() / want] as f64)
        .collect()
}

/// Frozen pre-SIMD scalar kernels, kept verbatim as the bit-exactness
/// oracle for the dispatched hot path (`rust/tests/simd_parity.rs`
/// fuzzes the fused/vectorized kernels against these).  Do not
/// optimize or "modernize": the whole point is that this module never
/// changes while the hot path does.
pub mod reference {
    use std::sync::Mutex;

    use super::{
        add_bias_relu_into, floor_adc, par_row_blocks, ConvertSpec,
        ROW_SEED_MIX,
    };
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    /// Scalar [`super::tiled_mac_into`]: single-row loop, per-element
    /// `partition_point` ladder search.
    pub fn tiled_mac_into(
        x: &[f32],
        m: usize,
        k: usize,
        w: &Tensor,
        tile_k: usize,
        quant: Option<&ConvertSpec>,
        out: &mut [f32],
    ) -> f64 {
        assert_eq!(w.shape.len(), 2, "weight matrix must be 2-D");
        assert_eq!(w.shape[0], k, "contraction mismatch {} vs {}", w.shape[0], k);
        let n = w.shape[1];
        assert_eq!(x.len(), m * k, "tiled_mac input shape mismatch");
        assert_eq!(out.len(), m * n, "tiled_mac output shape mismatch");
        let kt = k.div_ceil(tile_k).max(1);
        out.fill(0.0);
        let absmax = Mutex::new(0f64);
        par_row_blocks(m, n, out, |row0, block| {
            let mut scratch = vec![0f32; n];
            let mut local_max = 0f64;
            for (ri, orow) in block.chunks_mut(n).enumerate() {
                let r = row0 + ri;
                let xrow = &x[r * k..(r + 1) * k];
                let mut rng = quant.map(|q| {
                    Rng::new(q.seed ^ (r as u64).wrapping_mul(ROW_SEED_MIX))
                });
                for t in 0..kt {
                    let lo = t * tile_k;
                    let hi = ((t + 1) * tile_k).min(k);
                    scratch.fill(0.0);
                    for (kk, &a) in xrow.iter().enumerate().take(hi).skip(lo) {
                        if a != 0.0 {
                            let wrow = &w.data[kk * n..kk * n + n];
                            for (sj, &wj) in scratch.iter_mut().zip(wrow) {
                                *sj += a * wj;
                            }
                        }
                    }
                    match quant {
                        None => {
                            for (oj, &v) in orow.iter_mut().zip(scratch.iter()) {
                                local_max = local_max.max(v.abs() as f64);
                                *oj += v;
                            }
                        }
                        Some(q) => {
                            let rng = rng.as_mut().unwrap();
                            for (oj, &v) in orow.iter_mut().zip(scratch.iter()) {
                                let mut p = v;
                                if q.sigma != 0.0 {
                                    p += q.sigma * rng.gaussian() as f32;
                                }
                                *oj += floor_adc(q.refs, q.centers, p);
                            }
                        }
                    }
                }
            }
            if quant.is_none() {
                let mut g = absmax.lock().unwrap();
                if local_max > *g {
                    *g = local_max;
                }
            }
        });
        absmax.into_inner().unwrap()
    }

    /// Scalar [`super::nl_convert_into`]: per-element ladder search.
    pub fn nl_convert_into(
        y: &mut [f32],
        rows: usize,
        cols: usize,
        refs: &[f32],
        centers: &[f32],
        sigma: f32,
        seed: u64,
    ) {
        par_row_blocks(rows, cols, y, |row0, block| {
            for (ri, row) in block.chunks_mut(cols).enumerate() {
                let r = row0 + ri;
                let mut rng =
                    Rng::new(seed ^ (r as u64).wrapping_mul(ROW_SEED_MIX).rotate_left(17));
                for v in row.iter_mut() {
                    let mut p = *v;
                    if sigma != 0.0 {
                        p += sigma * rng.gaussian() as f32;
                    }
                    *v = floor_adc(refs, centers, p);
                }
            }
        });
    }

    /// Unfused quant-layer epilogue: bias/ReLU pass, then a separate
    /// conversion pass — what [`super::bias_relu_convert_into`] fuses.
    #[allow(clippy::too_many_arguments)]
    pub fn bias_relu_convert_into(
        y: &mut [f32],
        rows: usize,
        cols: usize,
        bias: &[f32],
        relu: bool,
        refs: &[f32],
        centers: &[f32],
        sigma: f32,
        seed: u64,
    ) {
        add_bias_relu_into(y, cols, bias, relu);
        nl_convert_into(y, rows, cols, refs, centers, sigma, seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floor_adc_matches_padded_semantics() {
        let refs = [0.0f32, 0.5, 1.5, f32::INFINITY];
        let centers = [0.0f32, 1.0, 2.0, 2.0];
        assert_eq!(floor_adc(&refs, &centers, -3.0), 0.0); // below base
        assert_eq!(floor_adc(&refs, &centers, 0.49), 0.0);
        assert_eq!(floor_adc(&refs, &centers, 0.5), 1.0); // boundary: >=
        assert_eq!(floor_adc(&refs, &centers, 99.0), 2.0); // pad never hit
        assert!((min_ref_step(&refs) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn tiled_mac_matches_plain_matmul_in_float_mode() {
        // k = 5 with tile_k = 2 exercises ragged tiling
        let x = Mat::new(2, 5, (0..10).map(|v| v as f32).collect());
        let w = Tensor::new(
            vec![5, 3],
            (0..15).map(|v| (v as f32) * 0.1 - 0.7).collect(),
        )
        .unwrap();
        let (acc, absmax) = tiled_mac(&x, &w, 2, None);
        for r in 0..2 {
            for j in 0..3 {
                let mut want = 0f32;
                for kk in 0..5 {
                    want += x.data[r * 5 + kk] * w.data[kk * 3 + j];
                }
                let got = acc.data[r * 3 + j];
                assert!((got - want).abs() < 1e-4, "r={r} j={j}: {got} vs {want}");
            }
        }
        assert!(absmax > 0.0);
    }

    #[test]
    fn tiled_mac_quant_digitizes_each_tile() {
        // identity-ish: wide linear codebook ~ no quantization
        let x = Mat::new(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::new(vec![4, 1], vec![1.0; 4]).unwrap();
        let cb = crate::quant::codebook::Codebook::linear(-128.0, 128.0, 7);
        let (refs, centers) = cb.padded(128);
        let spec = ConvertSpec {
            refs: &refs,
            centers: &centers,
            sigma: 0.0,
            seed: 1,
        };
        let (acc, _) = tiled_mac(&x, &w, 2, Some(&spec));
        // two tiles: q(1+2) + q(3+4) with ~2-unit steps
        assert!((acc.data[0] - 10.0).abs() <= 2.0 * cb.min_step() as f32 + 1e-3);
    }

    #[test]
    fn im2col_same_identity_kernel() {
        // 1x1 kernel stride 1: im2col is just a reshape
        let x = Feat::new(1, 2, 2, 3, (0..12).map(|v| v as f32).collect());
        let (m, oh, ow) = im2col(&x, 1, 1, 1, true);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(m.rows, 4);
        assert_eq!(m.cols, 3);
        assert_eq!(m.data, x.data);
    }

    #[test]
    fn im2col_same_pads_borders_with_zeros() {
        let x = Feat::new(1, 2, 2, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let (m, oh, ow) = im2col(&x, 3, 3, 1, true);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(m.cols, 9);
        // output (0,0): 3x3 patch centered at (0,0) — corners padded
        let r = m.row(0);
        assert_eq!(r[0], 0.0); // (-1,-1)
        assert_eq!(r[4], 1.0); // center
        assert_eq!(r[5], 2.0); // (0, 1)
        assert_eq!(r[8], 4.0); // (1, 1)
    }

    #[test]
    fn im2col_strided_downsamples() {
        let x = Feat::new(1, 4, 4, 1, (0..16).map(|v| v as f32).collect());
        let (m, oh, ow) = im2col(&x, 1, 1, 2, true);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(m.data, vec![0.0, 2.0, 8.0, 10.0]);
    }

    #[test]
    fn pools_and_gap() {
        let x = Feat::new(1, 2, 2, 1, vec![1.0, 5.0, 3.0, 2.0]);
        let p = max_pool2(&x);
        assert_eq!((p.h, p.w), (1, 1));
        assert_eq!(p.data, vec![5.0]);
        let g = global_avg_pool(&x);
        assert_eq!(g.data, vec![11.0 / 4.0]);
        // 3x3 SAME avg on a 1x1 map: single element / 9
        let tiny = Feat::new(1, 1, 1, 1, vec![9.0]);
        assert_eq!(avg_pool3_same(&tiny).data, vec![1.0]);
    }

    #[test]
    fn layer_norm_normalizes_rows() {
        let y = Mat::new(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let ln = layer_norm(&y, &g, &b);
        let mu: f32 = ln.data.iter().sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-5);
        assert!(ln.data[3] > ln.data[0]);
    }

    #[test]
    fn attention_uniform_value_passthrough() {
        // all V rows identical -> attention output equals that row
        let b = 1;
        let t = 3;
        let d = 4;
        let q = Mat::zeros(b * t, d);
        let k = Mat::zeros(b * t, d);
        let v = Mat::new(b * t, d, [1.0f32, 2.0, 3.0, 4.0].repeat(t));
        let o = attention(&q, &k, &v, b, t, 2);
        for ti in 0..t {
            for dd in 0..d {
                assert!((o.data[ti * d + dd] - (dd as f32 + 1.0)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn subsample_even_spacing_and_tiny_wrap() {
        let xs: Vec<f32> = (0..100).map(|v| v as f32).collect();
        let s = collect_subsample(&xs, 10);
        assert_eq!(s.len(), 10);
        assert_eq!(s[1], 10.0); // i*len/want = 10
        assert_eq!(s[9], 90.0);
        // tiny layers repeat through the same even-index formula
        let tiny = collect_subsample(&[7.0, 8.0], 5);
        assert_eq!(tiny, vec![7.0, 7.0, 7.0, 8.0, 8.0]);
    }

    #[test]
    fn subsample_covers_the_activation_tail() {
        // the old truncated-stride decimation (stride = len/want = 1)
        // read only indices 0..=299 of a 599-long activation; pin that
        // the fix actually reaches the tail
        let xs: Vec<f32> = (0..599).map(|v| v as f32).collect();
        let old: Vec<f64> = {
            let stride = (xs.len() / 300).max(1);
            xs.iter().step_by(stride).take(300).map(|&v| v as f64).collect()
        };
        assert_eq!(old[299], 299.0); // bias: tail never sampled
        let s = collect_subsample(&xs, 300);
        assert_eq!(s.len(), 300);
        assert_eq!(s[299], (299 * 599 / 300) as f64); // 597: tail covered
        assert!(s[299] > 590.0);
        for w in s.windows(2) {
            assert!(w[1] >= w[0], "monotone index walk");
        }
    }

    #[test]
    fn adc_lut_matches_floor_adc_everywhere() {
        let cb = crate::quant::codebook::Codebook::linear(-3.0, 5.0, 3);
        let (refs, centers) = cb.padded(16);
        let adc = AdcLut::new(&refs, &centers);
        let mut probes: Vec<f32> = vec![
            f32::NEG_INFINITY,
            -1e30,
            -3.0,
            0.0,
            -0.0,
            4.999,
            5.0,
            1e30,
            f32::NAN,
        ];
        // every reference exactly, and a hair to either side
        for &r in refs.iter().filter(|r| r.is_finite()) {
            probes.push(r);
            probes.push(r - 1e-4);
            probes.push(r + 1e-4);
            probes.push(r - f32::EPSILON * r.abs().max(1.0));
            probes.push(r + f32::EPSILON * r.abs().max(1.0));
        }
        let mut x = 0.1f32;
        for _ in 0..500 {
            x = (x * 1.7 + 0.37) % 11.0 - 5.5; // deterministic sweep
            probes.push(x);
        }
        for &p in &probes {
            let want = floor_adc(&refs, &centers, p);
            let got = adc.convert(p);
            assert_eq!(got.to_bits(), want.to_bits(), "probe {p}");
        }
    }

    #[test]
    fn blocked_mac_matches_reference_kernel() {
        // odd shapes: partial last row block, ragged tiles, SIMD tail
        let (m, k, n) = (11, 29, 13);
        let x: Vec<f32> = (0..m * k)
            .map(|v| if v % 7 == 0 { 0.0 } else { (v as f32) * 0.03 - 1.1 })
            .collect();
        let w = Tensor::new(
            vec![k, n],
            (0..k * n).map(|v| (v as f32) * 0.011 - 0.8).collect(),
        )
        .unwrap();
        let cb = crate::quant::codebook::Codebook::linear(-40.0, 40.0, 5);
        let (refs, centers) = cb.padded(64);
        for sigma in [0.0f32, 0.4] {
            let spec = ConvertSpec {
                refs: &refs,
                centers: &centers,
                sigma,
                seed: 99,
            };
            for quant in [None, Some(&spec)] {
                let mut want = vec![0f32; m * n];
                let wmax =
                    reference::tiled_mac_into(&x, m, k, &w, 8, quant, &mut want);
                let mut got = vec![0f32; m * n];
                let gmax = tiled_mac_into(&x, m, k, &w, 8, quant, &mut got);
                assert_eq!(wmax.to_bits(), gmax.to_bits());
                for (a, b) in want.iter().zip(&got) {
                    assert_eq!(a.to_bits(), b.to_bits(), "sigma {sigma}");
                }
            }
        }
    }

    #[test]
    fn fused_epilogue_matches_unfused_pair() {
        let (rows, cols) = (9, 17);
        let y0: Vec<f32> = (0..rows * cols)
            .map(|v| (v as f32) * 0.21 - 14.0)
            .collect();
        let bias: Vec<f32> = (0..cols).map(|v| (v as f32) * 0.5 - 4.0).collect();
        let cb = crate::quant::codebook::Codebook::linear(0.0, 20.0, 4);
        let (refs, centers) = cb.padded(32);
        for relu in [false, true] {
            for sigma in [0.0f32, 0.7] {
                let mut want = y0.clone();
                reference::bias_relu_convert_into(
                    &mut want, rows, cols, &bias, relu, &refs, &centers, sigma,
                    1234,
                );
                let mut got = y0.clone();
                bias_relu_convert_into(
                    &mut got, rows, cols, &bias, relu, &refs, &centers, sigma,
                    1234,
                );
                for (a, b) in want.iter().zip(&got) {
                    assert_eq!(a.to_bits(), b.to_bits(), "relu {relu} s {sigma}");
                }
            }
        }
    }

    #[test]
    fn parallel_partition_is_deterministic() {
        let rows = 37;
        let cols = 5;
        let mut a = vec![0f32; rows * cols];
        par_row_blocks(rows, cols, &mut a, |row0, block| {
            for (ri, row) in block.chunks_mut(cols).enumerate() {
                for (j, v) in row.iter_mut().enumerate() {
                    *v = ((row0 + ri) * cols + j) as f32;
                }
            }
        });
        for (i, &v) in a.iter().enumerate() {
            assert_eq!(v, i as f32);
        }
    }
}
