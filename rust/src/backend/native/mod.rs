//! Native integer IMC execution backend: runs the quantized network
//! entirely in Rust — no PJRT client, no HLO artifacts, no Python.
//!
//! The forward pass is executed the way the silicon does it (Fig. 2/3):
//! every MAC layer is im2col'd and tiled onto the 256-row macro geometry,
//! each tile's partial sum is digitized through the programmed per-tile
//! codebook ladder, partials accumulate digitally, and the layer output
//! goes through the layer's NL-ADC codebook with ReLU folded in.  Only
//! the manifest + weights container (+ data splits) are needed on disk.

pub mod models;
pub mod ops;

use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::backend::{Backend, CollectOut, ProgrammedCodebooks};
use crate::io::manifest::Manifest;
use crate::io::weights::load_tensors;
use crate::tensor::Tensor;

pub use models::ModelKind;

/// Immutable model state is behind `Arc`s, so [`Backend::replicate`]
/// hands the replica pool additional instances that share one weight set
/// instead of re-reading the container per worker.
#[derive(Clone)]
pub struct NativeBackend {
    manifest: Arc<Manifest>,
    /// weight tensors in graph argument order
    weights: Arc<Vec<Tensor>>,
    kind: ModelKind,
}

impl NativeBackend {
    /// Load manifest + weights container from the artifacts directory
    /// (the HLO graphs are not touched).
    pub fn load(artifacts: &Path, model: &str) -> Result<NativeBackend> {
        let manifest = Manifest::load(
            artifacts.join(format!("{model}_manifest.json")),
        )?;
        let tm = load_tensors(artifacts.join(format!("{model}_weights.bin")))
            .context("loading weights container")?;
        let weights = manifest
            .weight_args
            .iter()
            .map(|wa| {
                let t = tm.get(&wa.name)?.clone();
                ensure!(
                    t.shape == wa.shape,
                    "weight '{}' shape {:?} != manifest {:?}",
                    wa.name,
                    t.shape,
                    wa.shape
                );
                Ok(t)
            })
            .collect::<Result<Vec<_>>>()?;
        Self::from_parts(manifest, weights)
    }

    /// Build from an in-memory manifest + weight set (tests, weight
    /// quantization clones).
    pub fn from_parts(
        manifest: Manifest,
        weights: Vec<Tensor>,
    ) -> Result<NativeBackend> {
        let kind = ModelKind::from_name(&manifest.model)?;
        kind.check_manifest(&manifest)?;
        ensure!(
            weights.len() == manifest.weight_args.len(),
            "weight count {} != manifest {}",
            weights.len(),
            manifest.weight_args.len()
        );
        ensure!(
            weights.len() >= 2 * manifest.nq(),
            "weight table too short for {} q-layers",
            manifest.nq()
        );
        Ok(NativeBackend {
            manifest: Arc::new(manifest),
            weights: Arc::new(weights),
            kind,
        })
    }

    fn check_books(&self, books: &ProgrammedCodebooks) -> Result<()> {
        ensure!(
            books.nl_refs.shape.len() == 2
                && books.nl_refs.shape[0] == self.manifest.nq(),
            "codebook stack shape {:?} != [{}, levels]",
            books.nl_refs.shape,
            self.manifest.nq()
        );
        Ok(())
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        self.manifest.as_ref()
    }

    fn supports_batch(&self, n: usize) -> bool {
        n >= 1
    }

    fn run_collect(&self, x: &[f32]) -> Result<CollectOut> {
        let m: &Manifest = &self.manifest;
        ensure!(
            x.len() == m.batch * m.input_elems(),
            "collect input len {} != batch {} x {:?}",
            x.len(),
            m.batch,
            m.input_shape
        );
        let mut ctx = models::ForwardCtx::new(
            m,
            self.weights.as_slice(),
            models::Mode::Collect {
                samples: Vec::with_capacity(m.nq()),
                tile_max: Vec::with_capacity(m.nq()),
            },
        );
        let logits = models::forward(self.kind, &mut ctx, x, m.batch)?;
        match ctx.mode {
            models::Mode::Collect { samples, tile_max } => Ok(CollectOut {
                logits: logits.data,
                samples,
                tile_max,
            }),
            _ => unreachable!("collect mode preserved across forward"),
        }
    }

    fn run_qfwd(
        &self,
        x: &[f32],
        books: &ProgrammedCodebooks,
        noise_std: f32,
        seed: u32,
    ) -> Result<Vec<f32>> {
        let m: &Manifest = &self.manifest;
        self.check_books(books)?;
        let elems = m.input_elems();
        ensure!(
            !x.is_empty() && x.len() % elems == 0,
            "qfwd input len {} not a multiple of {:?}",
            x.len(),
            m.input_shape
        );
        let batch = x.len() / elems;
        let mut ctx = models::ForwardCtx::new(
            m,
            self.weights.as_slice(),
            models::Mode::Quant {
                books,
                noise_std,
                seed,
            },
        );
        let logits = models::forward(self.kind, &mut ctx, x, batch)?;
        Ok(logits.data)
    }

    fn weights(&self) -> &[Tensor] {
        self.weights.as_slice()
    }

    fn with_weights(&self, weights: Vec<Tensor>) -> Result<Box<dyn Backend>> {
        Ok(Box::new(Self::from_parts(
            (*self.manifest).clone(),
            weights,
        )?))
    }

    fn replicate(&self) -> Result<Box<dyn Backend + Send>> {
        // `Arc` clones of the shared weight/manifest set: O(1), no disk
        Ok(Box::new(self.clone()))
    }
}
