//! Native integer IMC execution backend: runs the quantized network
//! entirely in Rust — no PJRT client, no HLO artifacts, no Python.
//!
//! The forward pass is executed the way the silicon does it (Fig. 2/3):
//! every MAC layer is im2col'd and tiled onto the 256-row macro geometry,
//! each tile's partial sum is digitized through the programmed per-tile
//! codebook ladder, partials accumulate digitally, and the layer output
//! goes through the layer's NL-ADC codebook with ReLU folded in.  Only
//! the manifest + weights container (+ data splits) are needed on disk.
//!
//! There are no per-model forwards: the topology is data.  The manifest
//! carries a layer-graph IR (`graph` section) that [`graph::GraphProgram`]
//! validates at load time and interprets over a reusable scratch-buffer
//! arena — serving a new workload means writing a manifest, not Rust.

pub mod exec_pool;
pub mod graph;
pub mod ops;
pub mod simd;

use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Context, Result};

use crate::backend::{Backend, CollectOut, ProgrammedCodebooks};
use crate::io::manifest::Manifest;
use crate::io::weights::load_tensors;
use crate::obs::quant_health::QuantHealth;
use crate::tensor::Tensor;

use graph::{ExecBuffers, ExecMode, GraphProgram, OpTiming};

/// Cap on pooled execution arenas (concurrent callers beyond this build
/// a fresh arena and drop it afterwards).
const SCRATCH_POOL_CAP: usize = 8;

/// Immutable model state is behind `Arc`s, so [`Backend::replicate`]
/// hands the replica pool additional instances that share one weight set
/// and one compiled graph instead of re-reading/re-validating per
/// worker.  Each instance keeps its own pool of execution arenas.
pub struct NativeBackend {
    manifest: Arc<Manifest>,
    /// weight tensors in graph argument order
    weights: Arc<Vec<Tensor>>,
    program: Arc<GraphProgram>,
    /// reusable [`ExecBuffers`] arenas — steady-state forwards allocate
    /// no per-op tensors
    scratch: Mutex<Vec<ExecBuffers>>,
    /// optional quantization-health telemetry; shared across replica
    /// clones, so occupancy aggregates pool-wide
    health: Option<Arc<QuantHealth>>,
}

impl Clone for NativeBackend {
    fn clone(&self) -> NativeBackend {
        NativeBackend {
            manifest: Arc::clone(&self.manifest),
            weights: Arc::clone(&self.weights),
            program: Arc::clone(&self.program),
            // arenas are working state, not model state
            scratch: Mutex::new(Vec::new()),
            health: self.health.clone(),
        }
    }
}

impl NativeBackend {
    /// Load manifest + weights container from the artifacts directory
    /// (the HLO graphs are not touched).
    pub fn load(artifacts: &Path, model: &str) -> Result<NativeBackend> {
        let manifest = Manifest::load(
            artifacts.join(format!("{model}_manifest.json")),
        )?;
        let tm = load_tensors(artifacts.join(format!("{model}_weights.bin")))
            .context("loading weights container")?;
        let weights = manifest
            .weight_args
            .iter()
            .map(|wa| {
                let t = tm.get(&wa.name)?.clone();
                ensure!(
                    t.shape == wa.shape,
                    "weight '{}' shape {:?} != manifest {:?}",
                    wa.name,
                    t.shape,
                    wa.shape
                );
                Ok(t)
            })
            .collect::<Result<Vec<_>>>()?;
        Self::from_parts(manifest, weights)
    }

    /// Build from an in-memory manifest + weight set (tests, weight
    /// quantization clones).  This is where the layer graph is compiled:
    /// malformed graphs fail here, not mid-inference.
    pub fn from_parts(
        manifest: Manifest,
        weights: Vec<Tensor>,
    ) -> Result<NativeBackend> {
        let program = GraphProgram::compile(&manifest).with_context(|| {
            format!("validating layer graph of model '{}'", manifest.model)
        })?;
        ensure!(
            weights.len() == manifest.weight_args.len(),
            "weight count {} != manifest {}",
            weights.len(),
            manifest.weight_args.len()
        );
        Ok(NativeBackend {
            manifest: Arc::new(manifest),
            weights: Arc::new(weights),
            program: Arc::new(program),
            scratch: Mutex::new(Vec::new()),
            health: None,
        })
    }

    /// The compiled layer graph (op dump, arena stats).
    pub fn program(&self) -> &GraphProgram {
        &self.program
    }

    /// Run `f` with a pooled execution arena (created on first use).
    fn with_buffers<R>(&self, f: impl FnOnce(&mut ExecBuffers) -> R) -> R {
        let mut buf = self
            .scratch
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_default();
        let r = f(&mut buf);
        let mut pool = self.scratch.lock().unwrap();
        if pool.len() < SCRATCH_POOL_CAP {
            pool.push(buf);
        }
        r
    }

    fn qfwd_batch(&self, x: &[f32]) -> Result<usize> {
        let elems = self.manifest.input_elems();
        ensure!(
            !x.is_empty() && x.len() % elems == 0,
            "qfwd input len {} not a multiple of {:?}",
            x.len(),
            self.manifest.input_shape
        );
        Ok(x.len() / elems)
    }

    fn check_books(&self, books: &ProgrammedCodebooks) -> Result<()> {
        ensure!(
            books.nl_refs.shape.len() == 2
                && books.nl_refs.shape[0] == self.manifest.nq(),
            "codebook stack shape {:?} != [{}, levels]",
            books.nl_refs.shape,
            self.manifest.nq()
        );
        // degenerate ladders (empty / single-level) would panic inside
        // the conversion kernels and mis-scale noise via min_ref_step's
        // 1.0 fallback; reject them here, naming the offending qlayer
        for (i, ql) in self.manifest.qlayers.iter().enumerate() {
            for (stack, what) in [
                (&books.nl_refs, "NL-ADC"),
                (&books.tile_refs, "tile-ADC"),
            ] {
                let finite = stack
                    .row(i)
                    .iter()
                    .filter(|r| r.is_finite())
                    .count();
                ensure!(
                    finite >= 2,
                    "q-layer '{}': degenerate {} ladder ({} finite \
                     reference(s); conversion needs at least 2)",
                    ql.name,
                    what,
                    finite
                );
            }
        }
        Ok(())
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        self.manifest.as_ref()
    }

    fn supports_batch(&self, n: usize) -> bool {
        n >= 1
    }

    fn run_collect(&self, x: &[f32]) -> Result<CollectOut> {
        let m: &Manifest = &self.manifest;
        ensure!(
            x.len() == m.batch * m.input_elems(),
            "collect input len {} != batch {} x {:?}",
            x.len(),
            m.batch,
            m.input_shape
        );
        let out = self.with_buffers(|buf| {
            self.program.execute(
                m,
                self.weights.as_slice(),
                x,
                m.batch,
                ExecMode::Collect,
                buf,
                None,
                None,
            )
        })?;
        Ok(CollectOut {
            logits: out.logits,
            samples: out.samples,
            tile_max: out.tile_max,
        })
    }

    fn run_qfwd(
        &self,
        x: &[f32],
        books: &ProgrammedCodebooks,
        noise_std: f32,
        seed: u32,
    ) -> Result<Vec<f32>> {
        let batch = self.qfwd_batch(x)?;
        self.check_books(books)?;
        let out = self.with_buffers(|buf| {
            self.program.execute(
                &self.manifest,
                self.weights.as_slice(),
                x,
                batch,
                ExecMode::Quant {
                    books,
                    noise_std,
                    seed,
                },
                buf,
                None,
                self.health.as_deref(),
            )
        })?;
        Ok(out.logits)
    }

    /// [`Backend::run_qfwd`] with a per-op wall-clock breakdown (the
    /// bench harness, `bskmq graph` and the serving path's sampled
    /// profiling use this; plain `run_qfwd` skips the timestamping).
    fn run_qfwd_profiled(
        &self,
        x: &[f32],
        books: &ProgrammedCodebooks,
        noise_std: f32,
        seed: u32,
    ) -> Result<(Vec<f32>, Vec<OpTiming>)> {
        let batch = self.qfwd_batch(x)?;
        self.check_books(books)?;
        let mut timings = Vec::with_capacity(self.program.n_ops());
        let out = self.with_buffers(|buf| {
            self.program.execute(
                &self.manifest,
                self.weights.as_slice(),
                x,
                batch,
                ExecMode::Quant {
                    books,
                    noise_std,
                    seed,
                },
                buf,
                Some(&mut timings),
                self.health.as_deref(),
            )
        })?;
        Ok((out.logits, timings))
    }

    fn attach_quant_health(&mut self, health: Arc<QuantHealth>) -> bool {
        self.health = Some(health);
        true
    }

    fn quant_health(&self) -> Option<Arc<QuantHealth>> {
        self.health.clone()
    }

    fn weights(&self) -> &[Tensor] {
        self.weights.as_slice()
    }

    fn with_weights(&self, weights: Vec<Tensor>) -> Result<Box<dyn Backend>> {
        Ok(Box::new(Self::from_parts(
            (*self.manifest).clone(),
            weights,
        )?))
    }

    fn replicate(&self) -> Result<Box<dyn Backend + Send>> {
        // `Arc` clones of the shared weight/manifest/program set: O(1),
        // no disk, no re-validation
        Ok(Box::new(self.clone()))
    }
}
