//! Native forward passes for the four mini models — the Rust counterparts
//! of `python/compile/models/*` `forward_infer`, consuming quantized MAC
//! layers in the exact manifest order so the same calibrated codebooks
//! drive either backend.
//!
//! Each MAC layer runs through [`ForwardCtx::qmatmul`]: in collect mode a
//! float matmul that records the activation subsample + crossbar-tile
//! absmax; in quant mode the tiled integer MAC with per-tile ADC
//! digitization and the layer's NL-ADC output codebook (ReLU folded in
//! before the conversion, exactly as the hardware's non-negative
//! codebooks realize it).

use anyhow::{bail, ensure, Context, Result};

use super::ops::{
    add_bias_relu, add_mat, add_relu, attention, avg_pool3_same, collect_subsample,
    concat_c, global_avg_pool, im2col, layer_norm, max_pool2, mean_over_seq,
    min_ref_step, nl_convert, tiled_mac, Feat, Mat, QuantSpec,
};
use crate::backend::ProgrammedCodebooks;
use crate::io::manifest::Manifest;
use crate::macro_model::ROWS;
use crate::tensor::Tensor;

/// Transformer head count of the mini DistilBERT (export-side constant).
const BERT_HEADS: usize = 4;

/// The model topologies the native backend can execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Resnet,
    Vgg,
    Inception,
    Distilbert,
}

impl ModelKind {
    pub fn from_name(name: &str) -> Result<ModelKind> {
        match name {
            "resnet" => Ok(ModelKind::Resnet),
            "vgg" => Ok(ModelKind::Vgg),
            "inception" => Ok(ModelKind::Inception),
            "distilbert" => Ok(ModelKind::Distilbert),
            other => bail!(
                "native backend has no forward for model '{other}' \
                 (supported: resnet, vgg, inception, distilbert)"
            ),
        }
    }

    /// Reject manifests whose q-layer count cannot match this topology —
    /// the forward consumes a fixed layer sequence, and an undersized
    /// table would otherwise panic mid-inference instead of erroring at
    /// load time.
    pub fn check_manifest(&self, manifest: &Manifest) -> Result<()> {
        let nq = manifest.nq();
        let ok = match self {
            ModelKind::Resnet | ModelKind::Vgg => nq == 7,
            ModelKind::Inception => nq == 10,
            // per encoder layer: q, k, v, o, ff1, ff2; plus the classifier
            ModelKind::Distilbert => nq >= 7 && (nq - 1) % 6 == 0,
        };
        ensure!(
            ok,
            "manifest declares {nq} q-layers, incompatible with the \
             {self:?} topology"
        );
        Ok(())
    }
}

/// Execution mode of one forward pass.
pub(crate) enum Mode<'a> {
    /// Float forward recording calibration statistics.
    Collect {
        samples: Vec<Vec<f64>>,
        tile_max: Vec<f64>,
    },
    /// Deployed quantized forward with programmed codebooks.
    Quant {
        books: &'a ProgrammedCodebooks,
        noise_std: f32,
        seed: u32,
    },
}

/// Per-forward state: weight table + running quantized-layer index.
pub(crate) struct ForwardCtx<'a> {
    pub manifest: &'a Manifest,
    pub weights: &'a [Tensor],
    pub mode: Mode<'a>,
    qi: usize,
}

fn layer_seed(seed: u32, wi: usize, salt: u64) -> u64 {
    (seed as u64)
        .wrapping_mul(0xA076_1D64_78BD_642F)
        .wrapping_add((wi as u64).wrapping_mul(0xE703_7ED1_A0B4_28DB))
        ^ salt
}

impl<'a> ForwardCtx<'a> {
    pub fn new(
        manifest: &'a Manifest,
        weights: &'a [Tensor],
        mode: Mode<'a>,
    ) -> ForwardCtx<'a> {
        ForwardCtx {
            manifest,
            weights,
            mode,
            qi: 0,
        }
    }

    /// Digital (non-MAC) parameter by manifest argument name.
    fn digital(&self, name: &str) -> Result<&'a Tensor> {
        let idx = self
            .manifest
            .weight_args
            .iter()
            .position(|wa| wa.name == name)
            .with_context(|| format!("digital param '{name}' not in manifest"))?;
        Ok(&self.weights[idx])
    }

    /// One quantized MAC layer on 2-D operands (consumes the next qlayer).
    fn qmatmul(&mut self, x: &Mat, relu: bool) -> Mat {
        let wi = self.qi;
        self.qi += 1;
        let w = &self.weights[2 * wi];
        let bias = &self.weights[2 * wi + 1];
        debug_assert_eq!(
            self.manifest.qlayers[wi].relu, relu,
            "topology relu flag out of sync with manifest at layer {wi}"
        );
        match &mut self.mode {
            Mode::Collect { samples, tile_max } => {
                let (mut y, absmax) = tiled_mac(x, w, ROWS, None);
                add_bias_relu(&mut y, &bias.data, relu);
                tile_max.push(absmax);
                samples.push(collect_subsample(
                    &y.data,
                    self.manifest.samples_per_layer,
                ));
                y
            }
            Mode::Quant {
                books,
                noise_std,
                seed,
            } => {
                let (n_refs, n_centers, t_refs, t_centers) = books.layer_rows(wi);
                let spec = QuantSpec {
                    refs: t_refs,
                    centers: t_centers,
                    sigma: *noise_std * min_ref_step(t_refs),
                    seed: layer_seed(*seed, wi, 0),
                };
                let (mut y, _) = tiled_mac(x, w, ROWS, Some(&spec));
                add_bias_relu(&mut y, &bias.data, relu);
                nl_convert(
                    &mut y,
                    n_refs,
                    n_centers,
                    *noise_std * min_ref_step(n_refs),
                    layer_seed(*seed, wi, 0x5851_F42D_4C95_7F2D),
                );
                y
            }
        }
    }

    /// Quantized convolution = im2col + [`Self::qmatmul`] (the IMC mapping).
    fn qconv(&mut self, x: &Feat, k: usize, stride: usize, relu: bool) -> Feat {
        let (x2d, oh, ow) = im2col(x, k, k, stride, true);
        let y = self.qmatmul(&x2d, relu);
        Feat::from_mat(y, x.b, oh, ow)
    }
}

/// Run one forward pass; returns `[batch, num_classes]` logits.
pub(crate) fn forward(
    kind: ModelKind,
    ctx: &mut ForwardCtx,
    x: &[f32],
    batch: usize,
) -> Result<Mat> {
    let logits = if kind == ModelKind::Distilbert {
        distilbert(ctx, x, batch)?
    } else {
        let feat = image_input(ctx.manifest, x, batch)?;
        match kind {
            ModelKind::Resnet => resnet(ctx, feat),
            ModelKind::Vgg => vgg(ctx, feat),
            ModelKind::Inception => inception(ctx, feat),
            ModelKind::Distilbert => unreachable!(),
        }
    };
    ensure!(
        ctx.qi == ctx.manifest.nq(),
        "forward consumed {} q-layers, manifest has {}",
        ctx.qi,
        ctx.manifest.nq()
    );
    ensure!(
        logits.cols == ctx.manifest.num_classes,
        "logit width {} != num_classes {}",
        logits.cols,
        ctx.manifest.num_classes
    );
    Ok(logits)
}

fn image_input(manifest: &Manifest, x: &[f32], batch: usize) -> Result<Feat> {
    ensure!(
        manifest.input_shape.len() == 3,
        "image model expects [h, w, c] input shape, got {:?}",
        manifest.input_shape
    );
    let (h, w, c) = (
        manifest.input_shape[0],
        manifest.input_shape[1],
        manifest.input_shape[2],
    );
    ensure!(
        x.len() == batch * h * w * c,
        "input len {} != batch {batch} x {:?}",
        x.len(),
        manifest.input_shape
    );
    Ok(Feat::new(batch, h, w, c, x.to_vec()))
}

/// Mini ResNet: stem, one identity block, one strided projection block,
/// GAP, linear classifier.  Residual adds + ReLUs are digital.
fn resnet(ctx: &mut ForwardCtx, x: Feat) -> Mat {
    let y = ctx.qconv(&x, 3, 1, true); // conv0
    let h = ctx.qconv(&y, 3, 1, true); // b1c1
    let h = ctx.qconv(&h, 3, 1, false); // b1c2
    let y = add_relu(&y, &h);
    let h = ctx.qconv(&y, 3, 2, true); // b2c1
    let h = ctx.qconv(&h, 3, 1, false); // b2c2
    let sc = ctx.qconv(&y, 1, 2, false); // b2sc
    let y = add_relu(&h, &sc);
    let p = global_avg_pool(&y);
    ctx.qmatmul(&p, false) // fc
}

/// Mini VGG: five Conv-ReLU layers with max-pool downsampling after
/// conv2/conv4/conv5, then the two-layer classifier head.
fn vgg(ctx: &mut ForwardCtx, x: Feat) -> Mat {
    const POOL_AFTER: [bool; 5] = [false, true, false, true, true];
    let mut y = x;
    for pool in POOL_AFTER {
        y = ctx.qconv(&y, 3, 1, true);
        if pool {
            y = max_pool2(&y);
        }
    }
    let m = y.flatten();
    let m = ctx.qmatmul(&m, true); // fc1
    ctx.qmatmul(&m, false) // fc2
}

/// Mini Inception: stem + max-pool, two blocks of three parallel branches
/// (1x1, 1x1->3x3, avg-pool->1x1) concatenated along channels, GAP, fc.
fn inception(ctx: &mut ForwardCtx, x: Feat) -> Mat {
    let mut y = max_pool2(&ctx.qconv(&x, 3, 1, true)); // stem
    for _ in 0..2 {
        let br0 = ctx.qconv(&y, 1, 1, true); // b0
        let t = ctx.qconv(&y, 1, 1, true); // b1a
        let br1 = ctx.qconv(&t, 3, 1, true); // b1b
        let pooled = avg_pool3_same(&y);
        let br2 = ctx.qconv(&pooled, 1, 1, true); // pp
        y = concat_c(&[&br0, &br1, &br2]);
    }
    let p = global_avg_pool(&y);
    ctx.qmatmul(&p, false) // fc
}

/// Mini DistilBERT: embedding + position add, N post-LN encoder layers
/// (quantized Q/K/V/O/FF projections, digital attention + layernorm),
/// mean pooling, classifier.
fn distilbert(ctx: &mut ForwardCtx, x: &[f32], batch: usize) -> Result<Mat> {
    let manifest = ctx.manifest;
    ensure!(
        manifest.input_shape.len() == 1,
        "sequence model expects [t] input shape, got {:?}",
        manifest.input_shape
    );
    let t = manifest.input_shape[0];
    ensure!(
        x.len() == batch * t,
        "input len {} != batch {batch} x seq {t}",
        x.len()
    );
    let d = manifest.qlayers[0].n;
    let embed = ctx.digital("d_embed")?;
    let pos = ctx.digital("d_pos")?;
    ensure!(
        embed.shape.len() == 2 && embed.shape[1] == d,
        "embedding shape {:?} inconsistent with d_model {d}",
        embed.shape
    );
    ensure!(
        pos.shape == vec![t, d],
        "positional shape {:?} != [{t}, {d}]",
        pos.shape
    );
    let vocab = embed.shape[0];

    let mut h = Mat::zeros(batch * t, d);
    for bi in 0..batch {
        for ti in 0..t {
            let tok = (x[bi * t + ti].max(0.0) as usize).min(vocab - 1);
            let erow = &embed.data[tok * d..(tok + 1) * d];
            let prow = &pos.data[ti * d..(ti + 1) * d];
            let orow = &mut h.data[(bi * t + ti) * d..(bi * t + ti + 1) * d];
            for dd in 0..d {
                orow[dd] = erow[dd] + prow[dd];
            }
        }
    }

    let n_layers = (manifest.nq() - 1) / 6;
    for l in 0..n_layers {
        let q = ctx.qmatmul(&h, false);
        let k = ctx.qmatmul(&h, false);
        let v = ctx.qmatmul(&h, false);
        let a = attention(&q, &k, &v, batch, t, BERT_HEADS);
        let o = ctx.qmatmul(&a, false);
        let ln1g = ctx.digital(&format!("d_l{l}_ln1_gamma"))?;
        let ln1b = ctx.digital(&format!("d_l{l}_ln1_beta"))?;
        h = layer_norm(&add_mat(&h, &o), &ln1g.data, &ln1b.data);
        let f = ctx.qmatmul(&h, true); // ff1: GeLU -> ReLU substitution
        let f = ctx.qmatmul(&f, false); // ff2
        let ln2g = ctx.digital(&format!("d_l{l}_ln2_gamma"))?;
        let ln2b = ctx.digital(&format!("d_l{l}_ln2_beta"))?;
        h = layer_norm(&add_mat(&h, &f), &ln2g.data, &ln2b.data);
    }
    let pooled = mean_over_seq(&h, batch, t);
    Ok(ctx.qmatmul(&pooled, false)) // cls
}
