//! Persistent executor pool for the row-parallel kernels (DESIGN.md
//! §14).
//!
//! Before this module every `par_row_blocks` call spawned and joined
//! fresh OS threads — microseconds of kernel time per *op*, paid dozens
//! of times per forward — and every autoscaled replica independently
//! claimed `available_parallelism()` threads, so an R-replica pool on C
//! cores ran R×C compute threads.  The pool replaces both: one
//! process-wide set of long-lived workers, parked on a condvar when
//! idle, sized once from the `BSKMQ_THREADS` budget and **shared by
//! every replica** through weighted slot leasing (with J concurrent
//! jobs each job may occupy at most `ceil(budget / J)` workers, so no
//! replica starves the others and the pool never grows).
//!
//! Determinism contract: the pool executes *tasks*, and a task is one
//! statically partitioned row block — the identical
//! `chunk_rows = rows.div_ceil(threads)` split the scoped-spawn path
//! uses.  Tasks write disjoint output blocks and carry per-row RNG
//! seeding, so which worker runs which task (or whether the submitter
//! runs them all) cannot move a single bit.  The scoped-spawn path is
//! retained verbatim behind `BSKMQ_NO_POOL=1` / [`force_spawn`] as the
//! escape hatch and differential baseline, exactly like
//! `BSKMQ_NO_SIMD` / `simd::force_scalar` for the vector kernels.
//!
//! Submitters always participate in their own job (the pool holds
//! `budget - 1` workers), so a job makes progress even with a budget of
//! one or with every worker leased elsewhere, and `run` never returns
//! before all of its tasks have finished — which is what makes lending
//! stack-borrowed closures to the workers sound.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

/// Type-erased task body: run task `i` of the job.  The submitter
/// blocks in [`ExecPool::run`] until every task has finished, so the
/// borrowed closure outlives all worker accesses.
type TaskFn = dyn Fn(usize) + Sync;

struct Job {
    /// lifetime-erased pointer to the submitter's closure
    body: *const TaskFn,
    n_tasks: usize,
    /// next unclaimed task index
    next: usize,
    /// tasks claimed but not yet finished
    running: usize,
    /// tasks not yet finished (claimed or not)
    pending: usize,
    /// a task panicked; the submitter re-raises on return
    panicked: bool,
    id: u64,
}

// SAFETY: the raw closure pointer is only dereferenced while the
// submitting thread is blocked inside `run`, which keeps the referent
// alive; the closure itself is `Sync`.
unsafe impl Send for Job {}

#[derive(Default)]
struct PoolState {
    jobs: Vec<Job>,
    next_id: u64,
}

/// The process-wide executor: `budget - 1` parked workers plus every
/// submitting thread working on its own job.
pub struct ExecPool {
    state: Mutex<PoolState>,
    /// wakes parked workers when tasks become claimable
    work_cv: Condvar,
    /// wakes submitters waiting for their last straggler task
    done_cv: Condvar,
    budget: usize,
    workers: usize,
}

impl ExecPool {
    fn new(budget: usize) -> ExecPool {
        let budget = budget.max(1);
        ExecPool {
            state: Mutex::new(PoolState::default()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            budget,
            workers: budget - 1,
        }
    }

    fn spawn_workers(&'static self) {
        for i in 0..self.workers {
            std::thread::Builder::new()
                .name(format!("bskmq-exec-{i}"))
                .spawn(move || self.worker_loop())
                .expect("spawning executor pool worker");
        }
    }

    /// Configured process-wide thread budget (`BSKMQ_THREADS` or the
    /// host parallelism at first use).
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Pool-owned worker threads (`budget - 1`; submitters supply the
    /// remaining slot themselves).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Jobs currently in flight (a gauge snapshot, racy by nature).
    pub fn active_jobs(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    /// Worker slots a single job may occupy under the current load —
    /// the weighted lease `ceil(budget / active_jobs)` (whole budget
    /// when idle).
    pub fn lease_slots(&self) -> usize {
        let jobs = self.active_jobs().max(1);
        self.budget.div_ceil(jobs)
    }

    /// Per-job worker cap given `jobs` concurrent jobs.
    fn lease(&self, jobs: usize) -> usize {
        self.budget.div_ceil(jobs.max(1))
    }

    /// Claim one task a pool worker may run: the first job (FIFO) with
    /// unclaimed tasks still under its lease.
    fn claim_any(&self, st: &mut PoolState) -> Option<(*const TaskFn, u64, usize)> {
        let live = st.jobs.iter().filter(|j| j.pending > 0).count();
        let lease = self.lease(live);
        for job in st.jobs.iter_mut() {
            if job.next < job.n_tasks && job.running < lease {
                let idx = job.next;
                job.next += 1;
                job.running += 1;
                return Some((job.body, job.id, idx));
            }
        }
        None
    }

    /// Mark one task of job `id` finished and wake the submitter when
    /// it was the last one.  The job record itself is retired by its
    /// submitter (so the panic flag is always observed before removal).
    fn finish(&self, id: u64, panicked: bool) {
        let mut st = self.state.lock().unwrap();
        let job = st
            .jobs
            .iter_mut()
            .find(|j| j.id == id)
            .expect("finished task of unknown job");
        job.running -= 1;
        job.pending -= 1;
        job.panicked |= panicked;
        let job_done = job.pending == 0;
        drop(st);
        if job_done {
            self.done_cv.notify_all();
            // a completed job frees lease slots for the others
            self.work_cv.notify_all();
        }
    }

    fn run_task(&self, body: *const TaskFn, id: u64, idx: usize) {
        // SAFETY: the submitter of job `id` is blocked in `run` until
        // `pending == 0`, so `body` is alive for the whole call.
        let r = catch_unwind(AssertUnwindSafe(|| unsafe { (*body)(idx) }));
        self.finish(id, r.is_err());
    }

    fn worker_loop(&self) {
        let mut st = self.state.lock().unwrap();
        loop {
            match self.claim_any(&mut st) {
                Some((body, id, idx)) => {
                    drop(st);
                    self.run_task(body, id, idx);
                    st = self.state.lock().unwrap();
                }
                None => {
                    // park until a submitter enqueues or a lease frees
                    st = self.work_cv.wait(st).unwrap();
                }
            }
        }
    }

    /// Execute `body(0..n_tasks)` across the pool and the calling
    /// thread, returning once every task has finished.  Tasks must
    /// touch disjoint data; the call propagates a panic from any task.
    /// (The parameter is spelled out rather than using [`TaskFn`]: the
    /// alias carries the defaulted `'static` object bound, while here
    /// the closure only needs to outlive the call.)
    pub fn run(&self, n_tasks: usize, body: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        if n_tasks == 1 || self.workers == 0 {
            for i in 0..n_tasks {
                body(i);
            }
            return;
        }
        // SAFETY (lifetime erasure): `run` does not return until
        // `pending == 0`, so the erased borrow never dangles.
        let body_ptr: *const TaskFn = unsafe {
            std::mem::transmute::<*const (dyn Fn(usize) + Sync + '_), *const TaskFn>(
                body as *const _,
            )
        };
        let id = {
            let mut st = self.state.lock().unwrap();
            let id = st.next_id;
            st.next_id = st.next_id.wrapping_add(1);
            st.jobs.push(Job {
                body: body_ptr,
                n_tasks,
                next: 0,
                running: 0,
                pending: n_tasks,
                panicked: false,
                id,
            });
            id
        };
        self.work_cv.notify_all();

        // the submitter works its own job, lease-exempt: progress is
        // guaranteed even if every worker is leased to other jobs
        loop {
            let mut st = self.state.lock().unwrap();
            let job = st
                .jobs
                .iter_mut()
                .find(|j| j.id == id)
                .expect("submitter lost its own job record");
            if job.next < job.n_tasks {
                let idx = job.next;
                job.next += 1;
                job.running += 1;
                drop(st);
                self.run_task(body_ptr, id, idx);
                continue;
            }
            // all tasks claimed; wait for stragglers on other workers,
            // then retire the job record ourselves (only the submitter
            // removes it, so the panic flag is never lost)
            loop {
                let pos = st
                    .jobs
                    .iter()
                    .position(|j| j.id == id)
                    .expect("submitter lost its own job record");
                if st.jobs[pos].pending == 0 {
                    let job = st.jobs.remove(pos);
                    drop(st);
                    if job.panicked {
                        panic!("executor pool task panicked");
                    }
                    return;
                }
                st = self.done_cv.wait(st).unwrap();
            }
        }
    }
}

static POOL: OnceLock<&'static ExecPool> = OnceLock::new();
static FORCE_SPAWN: AtomicBool = AtomicBool::new(false);
static NO_POOL_ENV: OnceLock<bool> = OnceLock::new();

/// The process-wide pool, spawned on first use with the thread budget
/// [`super::ops::num_threads`] reports at that moment.
pub fn global() -> &'static ExecPool {
    POOL.get_or_init(|| {
        let pool: &'static ExecPool =
            Box::leak(Box::new(ExecPool::new(super::ops::num_threads())));
        pool.spawn_workers();
        pool
    })
}

/// Force the scoped-spawn fallback for subsequent row-parallel kernels
/// (benches and the determinism suite flip this to diff both paths in
/// one process).  Safe to toggle at any time: both paths produce
/// bit-identical results, so a racing caller only changes speed.
pub fn force_spawn(on: bool) {
    FORCE_SPAWN.store(on, Ordering::SeqCst);
}

/// Whether [`force_spawn`] is currently set.
pub fn spawn_forced() -> bool {
    FORCE_SPAWN.load(Ordering::SeqCst)
}

/// Telemetry snapshot of the executor configuration and load:
/// `(thread_budget, pool_workers, active_jobs, lease_slots)`.  Never
/// instantiates the pool — before first use (or with the pool disabled)
/// workers/jobs read 0 and the lease equals the full budget, while the
/// budget itself always reflects [`super::ops::num_threads`].
pub fn snapshot() -> (usize, usize, usize, usize) {
    let budget = super::ops::num_threads();
    match POOL.get() {
        Some(p) => (p.budget(), p.workers(), p.active_jobs(), p.lease_slots()),
        None => (budget, 0, 0, budget),
    }
}

/// True when row-parallel kernels should dispatch through the
/// persistent pool: not forced off at runtime and not disabled by
/// `BSKMQ_NO_POOL` (any value but `0`), the escape hatch mirroring
/// `BSKMQ_NO_SIMD`.
#[inline]
pub fn pool_enabled() -> bool {
    if FORCE_SPAWN.load(Ordering::Relaxed) {
        return false;
    }
    !*NO_POOL_ENV.get_or_init(|| {
        std::env::var("BSKMQ_NO_POOL")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = global();
        for n in [1usize, 2, 3, 7, 64, 257] {
            let hits: Vec<AtomicUsize> =
                (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "task {i} of {n}");
            }
        }
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        let pool = global();
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..50 {
                        pool.run(8, &|_| {
                            total.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 4 * 50 * 8);
    }

    #[test]
    fn lease_divides_budget_across_jobs() {
        let pool = global();
        assert_eq!(pool.lease(1), pool.budget());
        assert_eq!(pool.lease(0), pool.budget());
        assert!(pool.lease(4) >= 1);
        assert!(pool.lease(4) <= pool.budget().div_ceil(4).max(1));
    }

    #[test]
    fn task_panic_propagates_to_submitter() {
        let pool = global();
        let r = std::panic::catch_unwind(|| {
            pool.run(4, &|i| {
                if i == 2 {
                    panic!("boom");
                }
            });
        });
        assert!(r.is_err(), "panic must cross the pool boundary");
        // the pool survives and keeps executing afterwards
        let n = AtomicUsize::new(0);
        pool.run(4, &|_| {
            n.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(n.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn force_spawn_toggles() {
        force_spawn(true);
        assert!(spawn_forced());
        assert!(!pool_enabled());
        force_spawn(false);
        assert!(!spawn_forced());
    }
}
