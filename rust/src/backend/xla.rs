//! XLA execution backend: adapter wrapping the PJRT engine and the
//! per-model [`ModelRuntime`] behind the [`Backend`] trait.  Only built
//! with `--features xla`; the artifacts directory must hold the AOT HLO
//! graphs lowered by `python/compile/aot.py`.

use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::backend::{Backend, CollectOut, ProgrammedCodebooks};
use crate::io::manifest::Manifest;
use crate::runtime::engine::Engine;
use crate::runtime::model::ModelRuntime;
use crate::tensor::Tensor;

pub struct XlaBackend {
    /// shared PJRT client (executables cache inside it)
    engine: Arc<Engine>,
    runtime: ModelRuntime,
}

thread_local! {
    /// One PJRT client per thread: PJRT handles never cross threads, and
    /// every backend loaded on a thread (e.g. the `exp all` sweep over
    /// four models) shares the same client + executable cache instead of
    /// spinning up a fresh runtime each.
    static THREAD_ENGINE: std::cell::RefCell<Option<Arc<Engine>>> =
        const { std::cell::RefCell::new(None) };
}

fn shared_engine() -> Result<Arc<Engine>> {
    THREAD_ENGINE.with(|cell| {
        let mut slot = cell.borrow_mut();
        if let Some(e) = slot.as_ref() {
            return Ok(e.clone());
        }
        let e = Arc::new(Engine::cpu()?);
        *slot = Some(e.clone());
        Ok(e)
    })
}

impl XlaBackend {
    pub fn load(artifacts: &Path, model: &str) -> Result<XlaBackend> {
        let engine = shared_engine()?;
        let runtime = ModelRuntime::load(&engine, artifacts, model)?;
        Ok(XlaBackend { engine, runtime })
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn manifest(&self) -> &Manifest {
        &self.runtime.manifest
    }

    fn supports_batch(&self, n: usize) -> bool {
        n == self.runtime.manifest.batch || (n == 1 && self.runtime.has_b1())
    }

    fn run_collect(&self, x: &[f32]) -> Result<CollectOut> {
        self.runtime.run_collect(x)
    }

    fn run_qfwd(
        &self,
        x: &[f32],
        books: &ProgrammedCodebooks,
        noise_std: f32,
        seed: u32,
    ) -> Result<Vec<f32>> {
        let m = &self.runtime.manifest;
        let elems = m.input_elems();
        ensure!(
            !x.is_empty() && x.len() % elems == 0,
            "qfwd input len {} not a multiple of {:?}",
            x.len(),
            m.input_shape
        );
        let batch = x.len() / elems;
        if batch == m.batch {
            self.runtime.run_qfwd(x, books, noise_std, seed)
        } else if batch == 1 && self.runtime.has_b1() {
            self.runtime.run_qfwd_b1(x, books, noise_std, seed)
        } else {
            anyhow::bail!(
                "xla backend compiled for batch {} (and 1: {}); got {batch}",
                m.batch,
                self.runtime.has_b1()
            )
        }
    }

    fn weights(&self) -> &[Tensor] {
        self.runtime.weights()
    }

    fn with_weights(&self, weights: Vec<Tensor>) -> Result<Box<dyn Backend>> {
        Ok(Box::new(XlaBackend {
            engine: self.engine.clone(),
            runtime: self.runtime.with_weights(weights)?,
        }))
    }
}
