//! BS-KMQ: In-Memory ADC-Based Nonlinear Activation Quantization —
//! full-system reproduction (L3 coordinator + hardware substrates).
//!
//! Layer map (DESIGN.md):
//! * [`backend`] — pluggable execution engines behind the [`backend::Backend`]
//!   trait: the pure-Rust native integer IMC backend (always available) and
//!   the PJRT/XLA adapter (feature `xla`).
//! * [`runtime`] — PJRT CPU client loading the AOT HLO artifacts produced
//!   by `python/compile/aot.py` (feature `xla`; Python never runs on the
//!   request path).
//! * [`quant`] — the BS-KMQ quantizer (paper Algorithm 1) plus the four
//!   baselines (linear, Lloyd-Max, CDF, standard k-means) and the
//!   floor-ADC codebook machinery (Eq. 2) with hardware projection (§2.3).
//! * [`circuit`] / [`adc`] — behavioral simulation of the dual-9T SRAM
//!   macro and the reconfigurable in-memory NL-ADC across process corners
//!   (Fig. 7).
//! * [`macro_model`] — energy/area/latency model of the 256x128 macro
//!   (Fig. 8, 246 TOPS/W anchor).
//! * [`arch`] — NeuroSim-style system-level accelerator simulator and the
//!   Table 1 comparison against prior IMC designs.
//! * [`coordinator`] — calibration orchestration (streaming Algorithm 1
//!   over the `collect` graphs), PTQ evaluation, noise injection, and a
//!   multi-model replica-pool inference server with admission control.
//! * [`obs`] — observability: metrics registry, request-lifecycle
//!   tracing, quantization-health telemetry, Prometheus exposition and
//!   the committed BENCH_*.json perf trajectory.
//! * [`experiments`] — one harness per paper table/figure.

pub mod adc;
pub mod arch;
pub mod backend;
pub mod circuit;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod io;
pub mod macro_model;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Repo-root-relative artifacts directory (override with `BSKMQ_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("BSKMQ_ARTIFACTS") {
        return p.into();
    }
    // Walk up from cwd until an `artifacts/` directory is found.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
