//! Replica-pool inference serving: the request path of the deployed
//! system.
//!
//! One process hosts a [`ModelRegistry`] of independently calibrated
//! models.  Each model is served by a [`ModelPool`]: a shared **bounded**
//! intake queue with admission control (a full queue rejects the request
//! with an error instead of buffering without bound) feeding N worker
//! replicas.  Batching is *continuous*: every worker steals whatever is
//! pending from the one shared per-model queue, so a batch forms from
//! work across all clients rather than one replica's private window.
//! Every worker owns its own [`Backend`] instance — replicas come from
//! [`Backend::replicate`], which for the native engine is an `Arc` clone
//! of the shared weight set, the software analogue of programming the
//! same weights into another crossbar bank.
//!
//! Overload is handled in two layers (DESIGN.md §13): admission control
//! rejects when the bounded queue is full, and **deadline shedding**
//! answers requests that have already missed their per-request deadline
//! with an explicit [`ServeError::Overload`] reply at batch-assembly
//! time, so a saturated pool degrades by shedding rather than by letting
//! queue waits grow without bound.  Pools may also **autoscale**: when
//! `max_replicas > replicas` a supervisor grows/shrinks the live worker
//! set between those bounds, driven by queue depth.
//!
//! Shutdown is an explicit signal on the queue, not a channel-hangup
//! side effect: dropping a pool closes the queue, which wakes and drains
//! every worker even while [`PoolClient`] handles are still alive in
//! other threads (the bug the old mpsc-based server had).
//!
//! With zero conversion noise the quantized forward is a deterministic
//! per-sample function (per-(layer, row) noise seeding, no cross-sample
//! coupling), so logits are bit-identical regardless of replica count,
//! batch composition, thread interleaving, or live autoscaling — the
//! property the concurrency suite (`rust/tests/server_concurrency.rs`)
//! pins.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::backend::native::exec_pool;
use crate::backend::{Backend, BackendKind, CodebookCell, ProgrammedCodebooks};
use crate::coordinator::calibrate::{CalibrationResult, Calibrator};
use crate::coordinator::ptq::PtqEvaluator;
use crate::coordinator::recalib::{
    RecalibConfig, RecalibController, RecalibShared, RecalibStats, ShadowTap,
};
use crate::data::dataset::ModelData;
use crate::obs::prometheus::{escape_label, PromWriter};
use crate::obs::quant_health::QuantHealth;
use crate::obs::registry::{Gauge, Histogram, MetricsRegistry};
use crate::obs::trace::{escape_json, RequestTracer, Span, TraceSink};
use crate::quant::codebook::Codebook;
use crate::quant::sketch::ValueSketch;
use crate::quant::QuantSpec;

/// How a request can fail *after* admission.  Typed (unlike the old
/// `String` payload) so fronts and load generators can distinguish
/// deliberate overload shedding from genuine execution failures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// shed by deadline-based graceful degradation: the request had
    /// already missed its admission deadline when a worker assembled
    /// its batch, so it was answered immediately instead of queued on
    Overload { queued_ms: u64, deadline_ms: u64 },
    /// the backend failed the batch this request rode in
    Failed(String),
}

impl ServeError {
    /// Was this the deliberate shedding path (retry later), as opposed
    /// to an execution failure?
    pub fn is_overload(&self) -> bool {
        matches!(self, ServeError::Overload { .. })
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overload {
                queued_ms,
                deadline_ms,
            } => write!(
                f,
                "overload: shed after {queued_ms} ms in queue \
                 (deadline {deadline_ms} ms)"
            ),
            ServeError::Failed(msg) => write!(f, "inference failed: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Outcome of one request: logits, or a typed serving-side error.
pub type Reply = std::result::Result<Vec<f32>, ServeError>;

/// Completion queue for non-blocking fronts: workers push `(token,
/// reply)` pairs and fire the waker, the event loop drains on its next
/// iteration.  The waker only fires on the empty→non-empty transition,
/// so a batch of replies costs one wake.
pub(crate) struct CompletionQueue {
    done: Mutex<Vec<(u64, Reply)>>,
    waker: Box<dyn Fn() + Send + Sync>,
}

impl CompletionQueue {
    pub(crate) fn new(waker: Box<dyn Fn() + Send + Sync>) -> Arc<CompletionQueue> {
        Arc::new(CompletionQueue {
            done: Mutex::new(Vec::new()),
            waker,
        })
    }

    pub(crate) fn push(&self, token: u64, r: Reply) {
        let mut d = self.done.lock().unwrap();
        let was_empty = d.is_empty();
        d.push((token, r));
        drop(d);
        if was_empty {
            (self.waker)();
        }
    }

    pub(crate) fn drain(&self) -> Vec<(u64, Reply)> {
        std::mem::take(&mut *self.done.lock().unwrap())
    }
}

/// Where a worker delivers the reply: a blocking client's channel, or an
/// event front's completion queue (the token routes back to the
/// connection + in-flight request the reply belongs to).
pub(crate) enum ReplyTo {
    Channel(mpsc::Sender<Reply>),
    Completion { cq: Arc<CompletionQueue>, token: u64 },
}

impl ReplyTo {
    fn send(&self, r: Reply) {
        match self {
            ReplyTo::Channel(tx) => {
                let _ = tx.send(r);
            }
            ReplyTo::Completion { cq, token } => cq.push(*token, r),
        }
    }
}

/// One queued inference request.  Internal: the only producers are
/// [`PoolClient::submit`]-family methods, which have already validated
/// the input size.
struct Request {
    /// span id handed out by the pool's tracer at admission
    id: u64,
    /// when admission accepted the request (queue-wait clock)
    submitted: Instant,
    /// shed horizon: a worker assembling a batch at or past this instant
    /// answers the request with [`ServeError::Overload`] instead
    deadline: Instant,
    x: Vec<f32>,
    reply: ReplyTo,
}

/// Upper bound on retained latency samples (~8 MB worst case).
pub const MAX_LATENCY_SAMPLES: usize = 1 << 20;

/// Latency sample store: a ring over the most recent `capacity` service
/// times, so percentiles keep tracking a long-running server instead of
/// freezing on the warm-up era.
#[derive(Clone)]
struct LatencyRing {
    samples: Vec<u64>,
    capacity: usize,
    /// next overwrite position once the ring is full
    head: usize,
}

impl Default for LatencyRing {
    fn default() -> Self {
        LatencyRing::with_capacity(MAX_LATENCY_SAMPLES)
    }
}

impl LatencyRing {
    fn with_capacity(capacity: usize) -> LatencyRing {
        LatencyRing {
            samples: Vec::new(),
            capacity: capacity.max(1),
            head: 0,
        }
    }

    fn push(&mut self, us: u64) {
        if self.samples.len() < self.capacity {
            self.samples.push(us);
        } else {
            self.samples[self.head] = us;
            self.head = (self.head + 1) % self.capacity;
        }
    }

    /// Append another ring's retained samples, oldest first, as if they
    /// had been pushed here (cross-replica aggregation).  `head` is 0
    /// until a ring fills, so `(head + i) % len` is oldest-first in both
    /// regimes.
    fn merge(&mut self, other: &LatencyRing) {
        let n = other.samples.len();
        for i in 0..n {
            self.push(other.samples[(other.head + i) % n]);
        }
    }
}

#[derive(Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub full_batches: AtomicU64,
    pub singles: AtomicU64,
    pub busy_us: AtomicU64,
    /// requests refused by admission control (bounded queue full)
    pub rejected: AtomicU64,
    /// requests shed past their deadline at batch assembly
    pub shed: AtomicU64,
    /// per-request service latency samples (us)
    lat_us: Mutex<LatencyRing>,
    /// per-request queue-wait samples (us), recorded at batch assembly
    queue_us: Mutex<LatencyRing>,
}

/// One lock (copy only) + one sort outside the lock, so the serving
/// threads never stall on a reader.
fn ring_percentiles_ms(ring: &Mutex<LatencyRing>, qs: &[f64]) -> Vec<f64> {
    let raw = ring.lock().unwrap().samples.clone(); // memcpy only
    let mut sorted: Vec<f64> = raw.into_iter().map(|u| u as f64).collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    qs.iter()
        .map(|&q| {
            if sorted.is_empty() {
                0.0
            } else {
                crate::util::stats::quantile_sorted(&sorted, q) / 1e3
            }
        })
        .collect()
}

impl ServerStats {
    /// Record the service latency of a batch covering `n` requests.
    pub fn record_latency(&self, us: u64, n: usize) {
        let mut lat = self.lat_us.lock().unwrap();
        for _ in 0..n {
            lat.push(us);
        }
    }

    /// Record one executed batch of `n` requests against the model's
    /// compiled batch size.
    pub fn record_batch(&self, n: usize, full_batch: usize, us: u64) {
        self.requests.fetch_add(n as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        if n == full_batch {
            self.full_batches.fetch_add(1, Ordering::Relaxed);
        } else if n == 1 {
            self.singles.fetch_add(1, Ordering::Relaxed);
        }
        self.busy_us.fetch_add(us, Ordering::Relaxed);
        self.record_latency(us, n);
    }

    /// Record how long one request sat queued before batch assembly.
    pub fn record_queue_wait(&self, us: u64) {
        self.queue_us.lock().unwrap().push(us);
    }

    /// Latency percentiles in milliseconds, one per requested quantile
    /// (all 0.0 when no samples yet).
    pub fn percentiles_ms(&self, qs: &[f64]) -> Vec<f64> {
        ring_percentiles_ms(&self.lat_us, qs)
    }

    /// Queue-wait percentiles in milliseconds.
    pub fn queue_percentiles_ms(&self, qs: &[f64]) -> Vec<f64> {
        ring_percentiles_ms(&self.queue_us, qs)
    }

    /// Fold another stats instance into this one: counters add, latency
    /// rings append oldest-first — the cross-replica aggregation path
    /// (`other` must not be `self`).
    pub fn merge_from(&self, other: &ServerStats) {
        for (a, b) in [
            (&self.requests, &other.requests),
            (&self.batches, &other.batches),
            (&self.full_batches, &other.full_batches),
            (&self.singles, &other.singles),
            (&self.busy_us, &other.busy_us),
            (&self.rejected, &other.rejected),
            (&self.shed, &other.shed),
        ] {
            a.fetch_add(b.load(Ordering::SeqCst), Ordering::Relaxed);
        }
        let theirs = other.lat_us.lock().unwrap().clone();
        self.lat_us.lock().unwrap().merge(&theirs);
        let theirs = other.queue_us.lock().unwrap().clone();
        self.queue_us.lock().unwrap().merge(&theirs);
    }

    /// Latency percentile in milliseconds (0.0 when no samples yet).
    pub fn percentile_ms(&self, q: f64) -> f64 {
        self.percentiles_ms(&[q])[0]
    }

    pub fn summary(&self) -> String {
        let p = self.percentiles_ms(&[0.50, 0.95, 0.99, 0.999]);
        format!(
            "requests={} batches={} full={} singles={} rejected={} shed={} \
             busy={:.1}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms p999={:.2}ms",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.full_batches.load(Ordering::Relaxed),
            self.singles.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.busy_us.load(Ordering::Relaxed) as f64 / 1e3,
            p[0],
            p[1],
            p[2],
            p[3],
        )
    }
}

/// Why intake refused a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionError {
    /// bounded queue at capacity — back off and retry
    Full { depth: usize },
    /// pool shut down
    Closed,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::Full { depth } => write!(
                f,
                "queue full (depth {depth}): request rejected by admission \
                 control"
            ),
            AdmissionError::Closed => write!(f, "server shut down"),
        }
    }
}

impl std::error::Error for AdmissionError {}

struct QueueInner {
    jobs: VecDeque<Request>,
    closed: bool,
    /// desired live worker count (autoscaling); a worker whose slot id
    /// is >= target retires on its next wakeup
    target: usize,
    /// retire acknowledgements, one flag per worker slot; set by the
    /// retiring worker under this mutex, collected by `resize_target`
    retired: Vec<bool>,
}

/// What one call to [`JobQueue::pop_batch`] yields.
enum Popped {
    /// at least one request (deadline shedding happens at assembly)
    Batch(Vec<Request>),
    /// queue closed and fully drained
    Shutdown,
    /// this worker's slot was scaled away; exit without draining
    Retire,
}

/// Shared bounded work queue: the single intake point of a pool and the
/// continuous-batching source every replica steals from.  `push` applies
/// admission control; `close` is the explicit shutdown signal workers
/// observe even while client handles stay alive; `target`/`retired`
/// carry the autoscaling protocol (workers retire themselves when their
/// slot falls past the target, the supervisor collects and respawns).
struct JobQueue {
    inner: Mutex<QueueInner>,
    ready: Condvar,
    depth: usize,
    /// live queue-depth gauge (`bskmq_queue_depth`), updated on every
    /// push/pop under the queue lock
    depth_gauge: Option<Arc<Gauge>>,
}

impl JobQueue {
    fn new(
        depth: usize,
        target: usize,
        slots: usize,
        depth_gauge: Option<Arc<Gauge>>,
    ) -> JobQueue {
        JobQueue {
            inner: Mutex::new(QueueInner {
                jobs: VecDeque::new(),
                closed: false,
                target: target.max(1),
                retired: vec![false; slots.max(1)],
            }),
            ready: Condvar::new(),
            depth: depth.max(1),
            depth_gauge,
        }
    }

    /// Enqueue or reject immediately — never blocks, never buffers past
    /// the configured depth.
    fn push(&self, r: Request) -> std::result::Result<(), AdmissionError> {
        let mut q = self.inner.lock().unwrap();
        if q.closed {
            return Err(AdmissionError::Closed);
        }
        if q.jobs.len() >= self.depth {
            return Err(AdmissionError::Full { depth: self.depth });
        }
        q.jobs.push_back(r);
        if let Some(g) = &self.depth_gauge {
            g.set(q.jobs.len() as f64);
        }
        drop(q);
        self.ready.notify_one();
        Ok(())
    }

    /// Blocking batched pop for worker `slot`: waits for at least one
    /// job, drains up to `max`, then tops a partial batch up for at most
    /// `window`.  A **full batch dispatches immediately** — the top-up
    /// wait only ever runs while the batch is short.  Returns
    /// [`Popped::Shutdown`] only on close with the queue fully drained,
    /// and [`Popped::Retire`] when autoscaling moved the target below
    /// this slot (handing any wakeup it may have consumed to a live
    /// worker first).
    fn pop_batch(&self, slot: usize, max: usize, window: Duration) -> Popped {
        let mut q = self.inner.lock().unwrap();
        loop {
            if slot >= q.target {
                if let Some(r) = q.retired.get_mut(slot) {
                    *r = true;
                }
                drop(q);
                // a push's notify_one may have woken us; pass it on so
                // the job is not stranded with live workers asleep
                self.ready.notify_one();
                return Popped::Retire;
            }
            if !q.jobs.is_empty() {
                break;
            }
            if q.closed {
                return Popped::Shutdown;
            }
            q = self.ready.wait(q).unwrap();
        }
        let mut out = Vec::with_capacity(max.min(q.jobs.len()));
        while out.len() < max {
            match q.jobs.pop_front() {
                Some(j) => out.push(j),
                None => break,
            }
        }
        if out.len() < max && !window.is_zero() {
            let deadline = Instant::now() + window;
            while out.len() < max && !q.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) =
                    self.ready.wait_timeout(q, deadline - now).unwrap();
                q = guard;
                while out.len() < max {
                    match q.jobs.pop_front() {
                        Some(j) => out.push(j),
                        None => break,
                    }
                }
                if timeout.timed_out() {
                    break;
                }
            }
        }
        if let Some(g) = &self.depth_gauge {
            g.set(q.jobs.len() as f64);
        }
        Popped::Batch(out)
    }

    /// Set the autoscaling target and collect the slots below it whose
    /// workers have retired (each reported exactly once — the supervisor
    /// must join and respawn them).
    fn resize_target(&self, target: usize) -> Vec<usize> {
        let mut q = self.inner.lock().unwrap();
        q.target = target.max(1);
        let t = q.target;
        let mut respawn = Vec::new();
        for (i, r) in q.retired.iter_mut().enumerate() {
            if i < t && *r {
                *r = false;
                respawn.push(i);
            }
        }
        drop(q);
        // wake everyone: sleeping workers past the target retire, the
        // rest re-check and keep serving
        self.ready.notify_all();
        respawn
    }

    fn target(&self) -> usize {
        self.inner.lock().unwrap().target
    }

    fn len(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }

    fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    fn close(&self) {
        let mut q = self.inner.lock().unwrap();
        q.closed = true;
        drop(q);
        self.ready.notify_all();
    }
}

/// Observability knobs for one pool (DESIGN.md §11).  All sampling
/// rates use `0 = off` so the defaults cost nothing on the hot path.
#[derive(Clone)]
pub struct ObsConfig {
    /// run every Nth batch through `run_qfwd_profiled` for a per-op
    /// wall-time breakdown (0 = never; steady state stays allocation
    /// free because unprofiled batches collect no rows)
    pub profile_every: u64,
    /// emit every Nth request span to the trace sink (0 = never; span
    /// open/close accounting runs regardless)
    pub trace_sample_every: u64,
    /// JSONL span sink on disk (ignored when `trace_sink` is set)
    pub trace_path: Option<PathBuf>,
    /// explicit span sink (tests hand in memory sinks)
    pub trace_sink: Option<Arc<TraceSink>>,
    /// attach quantization-health telemetry to the backend's
    /// digitization step (engines without hooks silently skip it)
    pub quant_health: bool,
    /// live-sketch stride: every Nth observed activation feeds the
    /// per-layer bottom-k sketch (0 disables live sketching)
    pub sketch_sample_every: u64,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            profile_every: 0,
            trace_sample_every: 0,
            trace_path: None,
            trace_sink: None,
            quant_health: true,
            sketch_sample_every: 31,
        }
    }
}

impl std::fmt::Debug for ObsConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ObsConfig")
            .field("profile_every", &self.profile_every)
            .field("trace_sample_every", &self.trace_sample_every)
            .field("trace_path", &self.trace_path)
            .field("trace_sink", &self.trace_sink.is_some())
            .field("quant_health", &self.quant_health)
            .field("sketch_sample_every", &self.sketch_sample_every)
            .finish()
    }
}

/// Per-pool serving configuration.  `replicas`, `max_replicas` and
/// `queue_depth` are the scaling knobs; the rest mirrors the calibration
/// pipeline.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    pub backend: BackendKind,
    /// uniform calibration-spec override; `None` serves the manifest's
    /// per-layer specs (the mixed-precision deployment default)
    pub spec: Option<QuantSpec>,
    pub noise_std: f32,
    pub calib_batches: usize,
    /// parallel calibration shards (merged codebooks are bit-identical
    /// to serial, so this is purely a startup-latency knob)
    pub calib_shards: usize,
    /// minimum (and initial) worker replicas, each owning its own
    /// `Backend` instance
    pub replicas: usize,
    /// autoscaling ceiling; 0 (default) pins the pool at `replicas` and
    /// keeps engines without `replicate` support serveable
    pub max_replicas: usize,
    /// bounded intake queue depth (admission control threshold)
    pub queue_depth: usize,
    /// how long a worker waits to top up a partial batch
    pub batch_window: Duration,
    /// per-request deadline: a request still unassembled this long after
    /// admission is shed with an explicit overload reply (clients may
    /// override per request via `submit_deadline`)
    pub request_deadline: Duration,
    /// autoscaling supervisor tick
    pub scale_check: Duration,
    /// queue depth that triggers a scale-up; 0 = the model's batch size
    pub scale_up_depth: usize,
    /// consecutive idle supervisor ticks before one replica scales down
    pub scale_down_idle: u32,
    /// observability: tracing, profiling, quantization health
    pub obs: ObsConfig,
    /// online shadow recalibration (DESIGN.md §15): `Some` runs a
    /// controller that samples live traffic, watches sketch drift, and
    /// hot-swaps refit codebooks; requires `obs.quant_health` and a
    /// replicable backend
    pub recalib: Option<RecalibConfig>,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            backend: BackendKind::Auto,
            spec: None,
            noise_std: 0.0,
            calib_batches: 8,
            calib_shards: 1,
            replicas: 1,
            max_replicas: 0,
            queue_depth: 256,
            batch_window: Duration::from_millis(2),
            request_deadline: Duration::from_secs(10),
            scale_check: Duration::from_millis(20),
            scale_up_depth: 0,
            scale_down_idle: 50,
            obs: ObsConfig::default(),
            recalib: None,
        }
    }
}

/// Extra time a blocking client waits for its reply beyond the request
/// deadline: sheds happen at batch assembly, so an answered request can
/// arrive after the deadline by up to one batch's service time.  With
/// the default 10 s deadline this reproduces the old fixed 120 s recv
/// timeout.
pub const REPLY_GRACE: Duration = Duration::from_secs(110);

/// Cloneable intake handle: validates the input size, then submits
/// through the pool's admission-controlled queue.  Holding one does NOT
/// keep the pool alive — shutdown closes the queue underneath it and
/// later submissions fail with [`AdmissionError::Closed`].
#[derive(Clone)]
pub struct PoolClient {
    queue: Arc<JobQueue>,
    stats: Arc<ServerStats>,
    tracer: Arc<RequestTracer>,
    in_elems: usize,
    num_classes: usize,
    /// default per-request deadline (the pool's `request_deadline`)
    deadline: Duration,
}

impl PoolClient {
    /// Non-blocking submit under admission control with the pool's
    /// default deadline; on acceptance the receiver yields exactly one
    /// [`Reply`].  Rejections (queue full, shutdown, wrong input size)
    /// surface as immediate errors — a request is never silently
    /// dropped.
    pub fn submit(&self, x: Vec<f32>) -> Result<mpsc::Receiver<Reply>> {
        self.submit_deadline(x, self.deadline)
    }

    /// Live replica target of the pool behind this client (autoscaling
    /// moves it at runtime) — recorded on load-harness points so BENCH
    /// numbers carry their replica config.
    pub fn live_replicas(&self) -> usize {
        self.queue.target()
    }

    /// [`PoolClient::submit`] with an explicit per-request deadline.
    pub fn submit_deadline(
        &self,
        x: Vec<f32>,
        deadline: Duration,
    ) -> Result<mpsc::Receiver<Reply>> {
        let (tx, rx) = mpsc::channel();
        self.submit_to(x, deadline, ReplyTo::Channel(tx))?;
        Ok(rx)
    }

    /// Core submission path shared by blocking clients and the event
    /// front: validate, open a span, push under admission control.
    pub(crate) fn submit_to(
        &self,
        x: Vec<f32>,
        deadline: Duration,
        reply: ReplyTo,
    ) -> Result<()> {
        ensure!(
            x.len() == self.in_elems,
            "input has {} elements, model wants {}",
            x.len(),
            self.in_elems
        );
        // span opens at admission; a refused push rolls it back so
        // rejected requests never count as open spans
        let id = self.tracer.open();
        let now = Instant::now();
        let req = Request {
            id,
            submitted: now,
            deadline: now + deadline,
            x,
            reply,
        };
        match self.queue.push(req) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.tracer.cancel(id);
                if matches!(e, AdmissionError::Full { .. }) {
                    self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                }
                Err(anyhow::Error::new(e))
            }
        }
    }

    /// Blocking request: submit, then wait for the logits.  Overload
    /// sheds and execution failures surface as errors (the error string
    /// of a shed contains "overload").
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        let rx = self.submit(x)?;
        match rx.recv_timeout(self.deadline + REPLY_GRACE) {
            Ok(Ok(logits)) => Ok(logits),
            Ok(Err(e)) => bail!("{e}"),
            Err(_) => bail!("request dropped or timed out"),
        }
    }

    /// Logit vector length of the served model.
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Per-sample input element count of the served model.
    pub fn in_elems(&self) -> usize {
        self.in_elems
    }

    /// This client's default request deadline.
    pub fn deadline(&self) -> Duration {
        self.deadline
    }
}

/// What the coordinator thread reports back once serving can start.
struct PoolReady {
    engine: String,
    in_elems: usize,
    num_classes: usize,
    batch: usize,
    max_levels: usize,
    health: Option<Arc<QuantHealth>>,
    /// the swap cell every worker snapshots per batch
    cell: Arc<CodebookCell>,
    /// shadow-recalibration handle (None unless `cfg.recalib`)
    recalib: Option<Arc<RecalibShared>>,
}

/// One model's serving pool: worker replicas stealing from one bounded
/// queue, optionally autoscaled between `replicas` and `max_replicas`.
pub struct ModelPool {
    pub model: String,
    queue: Arc<JobQueue>,
    /// pool-wide aggregate (every worker records here too)
    pub stats: Arc<ServerStats>,
    /// per-slot counters, index = worker slot id (sized to the
    /// autoscaling ceiling; slots never spawned stay zero)
    pub replica_stats: Vec<Arc<ServerStats>>,
    engine: String,
    in_elems: usize,
    num_classes: usize,
    batch: usize,
    min_replicas: usize,
    request_deadline: Duration,
    /// request-lifecycle tracer (span accounting + sampled JSONL)
    tracer: Arc<RequestTracer>,
    /// pool-local metrics registry (latency/queue-wait/deadline
    /// histograms, queue-depth and live-replica gauges)
    metrics: Arc<MetricsRegistry>,
    /// quantization-health telemetry, when the engine supports hooks
    health: Option<Arc<QuantHealth>>,
    /// manifest ladder capacity, needed to restack swapped codebooks
    max_levels: usize,
    /// the generation cell the workers snapshot per batch
    cell: Arc<CodebookCell>,
    /// shadow-recalibration handle (None unless configured)
    recalib: Option<Arc<RecalibShared>>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
}

/// Move a replicated engine onto its own worker thread.
fn spawn_worker(
    rep: Box<dyn Backend + Send>,
    shared: &Arc<WorkerShared>,
    queue: &Arc<JobQueue>,
    slot: usize,
    mine: &Arc<ServerStats>,
    global: &Arc<ServerStats>,
) -> std::thread::JoinHandle<()> {
    let shared = shared.clone();
    let queue = queue.clone();
    let mine = mine.clone();
    let global = global.clone();
    std::thread::spawn(move || {
        worker_loop(rep.as_ref(), &shared, &queue, slot, &mine, &global);
    })
}

impl ModelPool {
    /// Start the pool: a coordinator thread loads the backend, calibrates
    /// the per-layer spec'd codebooks on `cfg.calib_batches` batches, then
    /// serves until the pool is dropped.  With `max_replicas` at its
    /// default the coordinator itself runs worker slot 0 (engines whose
    /// handles cannot cross threads still serve at `--replicas 1`); with
    /// `max_replicas > replicas` every slot runs on its own thread over a
    /// [`Backend::replicate`] clone and the coordinator becomes the
    /// autoscaling supervisor.
    pub fn start(
        artifacts: std::path::PathBuf,
        model: String,
        cfg: &PoolConfig,
    ) -> Result<ModelPool> {
        let cfg = cfg.clone();
        ensure!(cfg.replicas >= 1, "pool needs at least one replica");
        let max = if cfg.max_replicas == 0 {
            cfg.replicas
        } else {
            cfg.max_replicas
        };
        ensure!(
            max >= cfg.replicas,
            "max_replicas {} below replicas {}",
            max,
            cfg.replicas
        );
        let autoscaled = max > cfg.replicas;
        let stats = Arc::new(ServerStats::default());
        let replica_stats: Vec<Arc<ServerStats>> = (0..max)
            .map(|_| Arc::new(ServerStats::default()))
            .collect();
        let sink = match (&cfg.obs.trace_sink, &cfg.obs.trace_path) {
            (Some(s), _) => Some(s.clone()),
            (None, Some(p)) => Some(TraceSink::file(p)?),
            (None, None) => None,
        };
        let tracer =
            RequestTracer::new(&model, cfg.obs.trace_sample_every, sink);
        let metrics = Arc::new(MetricsRegistry::new());
        // pool-level instruments carry the model label in their
        // registered name so the registry renders them route-scoped
        let ml = escape_label(&model);
        let forward_hist = metrics.histogram(
            &format!("bskmq_forward_latency_ms{{model=\"{ml}\"}}"),
            &Histogram::latency_ms_bounds(),
        );
        let queue_hist = metrics.histogram(
            &format!("bskmq_queue_wait_ms{{model=\"{ml}\"}}"),
            &Histogram::latency_ms_bounds(),
        );
        let deadline_hist = metrics.histogram(
            &format!("bskmq_deadline_headroom_ms{{model=\"{ml}\"}}"),
            &Histogram::latency_ms_bounds(),
        );
        let depth_gauge =
            metrics.gauge(&format!("bskmq_queue_depth{{model=\"{ml}\"}}"));
        let live_gauge =
            metrics.gauge(&format!("bskmq_replicas_live{{model=\"{ml}\"}}"));
        live_gauge.set(cfg.replicas as f64);
        let queue = Arc::new(JobQueue::new(
            cfg.queue_depth,
            cfg.replicas,
            max,
            Some(depth_gauge),
        ));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<PoolReady>>();

        let m_name = model.clone();
        let q = queue.clone();
        let st = stats.clone();
        let rst = replica_stats.clone();
        let tracer_w = tracer.clone();
        let handle = std::thread::spawn(move || -> Result<()> {
            // setup: load + calibrate, reporting failure instead of
            // leaving the caller blocked
            let (be, calib, health) =
                match pool_setup(&cfg, &artifacts, &m_name) {
                    Ok(v) => v,
                    Err(e) => {
                        let _ = ready_tx.send(Err(anyhow::anyhow!("{e:#}")));
                        return Err(e);
                    }
                };
            // manifest facts are hoisted before `calib.programmed` moves
            // into the swap cell (a whole-struct borrow would be illegal
            // after the partial move)
            let (engine_name, batch, max_levels, in_elems, num_classes) = {
                let m = be.manifest();
                (
                    be.name().to_string(),
                    m.batch,
                    m.max_levels,
                    m.input_elems(),
                    m.num_classes,
                )
            };
            let specs = calib.specs.clone();
            let cell = Arc::new(CodebookCell::new(calib.programmed));
            // shadow recalibration (DESIGN.md §15): a supervisor thread
            // feeding tap samples into fresh estimators and hot-swapping
            // refit codebooks through the cell
            let mut recalib_shared: Option<Arc<RecalibShared>> = None;
            let mut _recalib_ctl: Option<RecalibController> = None;
            if let Some(rc) = cfg.recalib.clone() {
                match recalib_setup(rc, be.as_ref(), specs, &health, &cell, &q)
                {
                    Ok((sh, ctl)) => {
                        recalib_shared = Some(sh);
                        // held for the life of this closure: Drop stops
                        // the controller after the workers join below
                        _recalib_ctl = Some(ctl);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(anyhow::anyhow!("{e:#}")));
                        q.close();
                        return Err(e);
                    }
                }
            }
            let shared = Arc::new(WorkerShared {
                cell: cell.clone(),
                tap: recalib_shared.as_ref().map(|r| r.tap.clone()),
                noise_std: cfg.noise_std,
                window: cfg.batch_window,
                profile_every: cfg.obs.profile_every,
                tracer: tracer_w,
                forward_hist,
                queue_hist,
                deadline_hist,
            });
            let ready = PoolReady {
                engine: engine_name,
                in_elems,
                num_classes,
                batch,
                max_levels,
                health,
                cell,
                recalib: recalib_shared,
            };
            if autoscaled {
                // autoscaled pool: every slot runs on its own thread
                // over a replicate() clone; the loaded engine stays here
                // as the replication prototype, and this thread becomes
                // the scaling supervisor
                let mut slots: Vec<Option<std::thread::JoinHandle<()>>> =
                    (0..max).map(|_| None).collect();
                for (slot, mine) in
                    rst.iter().enumerate().take(cfg.replicas)
                {
                    let rep = match be.replicate() {
                        Ok(b) => b,
                        Err(e) => {
                            let e = e.context(format!(
                                "cannot autoscale '{m_name}': every worker \
                                 needs a replicate() clone"
                            ));
                            let _ =
                                ready_tx.send(Err(anyhow::anyhow!("{e:#}")));
                            q.close();
                            for h in slots.into_iter().flatten() {
                                let _ = h.join();
                            }
                            return Err(e);
                        }
                    };
                    slots[slot] =
                        Some(spawn_worker(rep, &shared, &q, slot, mine, &st));
                }
                let _ = ready_tx.send(Ok(ready));
                // queue-depth-driven autoscaling between cfg.replicas
                // and max (DESIGN.md §13): scale up one replica when the
                // backlog reaches a batch, scale down after a sustained
                // idle streak.  The supervisor sleeps rather than waits
                // on the queue condvar so it can never consume a
                // notify_one meant for a worker.
                let up_at = if cfg.scale_up_depth == 0 {
                    batch.max(1)
                } else {
                    cfg.scale_up_depth
                };
                let mut hard_max = max;
                let mut target = cfg.replicas;
                let mut idle_ticks: u32 = 0;
                loop {
                    std::thread::sleep(cfg.scale_check);
                    if q.is_closed() {
                        break;
                    }
                    let depth = q.len();
                    if depth >= up_at && target < hard_max {
                        target += 1;
                        for slot in q.resize_target(target) {
                            if let Some(h) = slots[slot].take() {
                                let _ = h.join();
                            }
                        }
                        let mut ok = true;
                        for (slot, s) in
                            slots.iter_mut().enumerate().take(target)
                        {
                            if s.is_some() {
                                continue;
                            }
                            match be.replicate() {
                                Ok(rep) => {
                                    *s = Some(spawn_worker(
                                        rep, &shared, &q, slot, &rst[slot],
                                        &st,
                                    ));
                                }
                                Err(e) => {
                                    eprintln!(
                                        "pool '{m_name}': scale-up to \
                                         {target} failed: {e:#}"
                                    );
                                    ok = false;
                                    break;
                                }
                            }
                        }
                        if !ok {
                            // pin the ceiling at what we actually have
                            target -= 1;
                            hard_max = target.max(cfg.replicas);
                            q.resize_target(target);
                        }
                        live_gauge.set(target as f64);
                        idle_ticks = 0;
                    } else if depth == 0 && target > cfg.replicas {
                        idle_ticks += 1;
                        if idle_ticks >= cfg.scale_down_idle {
                            target -= 1;
                            q.resize_target(target);
                            live_gauge.set(target as f64);
                            idle_ticks = 0;
                        }
                    } else {
                        idle_ticks = 0;
                    }
                }
                for h in slots.into_iter().flatten() {
                    let _ = h.join();
                }
            } else {
                // fixed-size pool: replicas 1..N each own a cheap clone
                // of the engine; worker slot 0 serves on the coordinator
                // thread (PJRT handles never cross threads; the native
                // replicas simply live where their work is)
                let mut workers = Vec::new();
                for (i, mine) in rst.iter().enumerate().skip(1) {
                    let rep = match be.replicate() {
                        Ok(b) => b,
                        Err(e) => {
                            let e = e.context(format!(
                                "cannot serve '{m_name}' with {} replicas",
                                cfg.replicas
                            ));
                            let _ =
                                ready_tx.send(Err(anyhow::anyhow!("{e:#}")));
                            q.close();
                            for w in workers {
                                let _ = w.join();
                            }
                            return Err(e);
                        }
                    };
                    workers.push(spawn_worker(rep, &shared, &q, i, mine, &st));
                }
                let _ = ready_tx.send(Ok(ready));
                worker_loop(be.as_ref(), &shared, &q, 0, &rst[0], &st);
                for w in workers {
                    let _ = w.join();
                }
            }
            Ok(())
        });

        let ready = match ready_rx.recv() {
            Ok(Ok(r)) => r,
            Ok(Err(e)) => {
                let _ = handle.join();
                return Err(e);
            }
            Err(_) => {
                let _ = handle.join();
                bail!("pool coordinator died during setup");
            }
        };
        Ok(ModelPool {
            model,
            queue,
            stats,
            replica_stats,
            engine: ready.engine,
            in_elems: ready.in_elems,
            num_classes: ready.num_classes,
            batch: ready.batch,
            min_replicas: cfg.replicas,
            request_deadline: cfg.request_deadline,
            tracer,
            metrics,
            health: ready.health,
            max_levels: ready.max_levels,
            cell: ready.cell,
            recalib: ready.recalib,
            handle: Some(handle),
        })
    }

    /// Clone-able intake handle for client threads.
    pub fn client(&self) -> PoolClient {
        PoolClient {
            queue: self.queue.clone(),
            stats: self.stats.clone(),
            tracer: self.tracer.clone(),
            in_elems: self.in_elems,
            num_classes: self.num_classes,
            deadline: self.request_deadline,
        }
    }

    /// Blocking request against this pool.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.client().infer(x)
    }

    /// Execution engine serving this pool ("native", "xla").
    pub fn engine(&self) -> &str {
        &self.engine
    }

    /// Worker slot count (the autoscaling ceiling; equals the configured
    /// replica count for fixed pools).
    pub fn replicas(&self) -> usize {
        self.replica_stats.len()
    }

    /// Current autoscaling target: how many worker slots are live.
    pub fn live_replicas(&self) -> usize {
        self.queue.target()
    }

    /// Configured minimum replica count.
    pub fn min_replicas(&self) -> usize {
        self.min_replicas
    }

    /// Compiled batch size of the served model.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Per-request deadline this pool sheds against.
    pub fn request_deadline(&self) -> Duration {
        self.request_deadline
    }

    /// Requests refused by admission control so far.
    pub fn rejected(&self) -> u64 {
        self.stats.rejected.load(Ordering::Relaxed)
    }

    /// Requests shed past their deadline so far.
    pub fn shed(&self) -> u64 {
        self.stats.shed.load(Ordering::Relaxed)
    }

    /// Explicit shutdown: close the queue (rejecting new requests), wake
    /// and drain every worker, join them.  Idempotent; also runs on Drop.
    /// Live [`PoolClient`] handles cannot keep the pool alive.
    pub fn shutdown(&mut self) {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Request-lifecycle tracer (span accounting, sampled JSONL sink).
    pub fn tracer(&self) -> &Arc<RequestTracer> {
        &self.tracer
    }

    /// Pool-local metrics registry.
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// Quantization-health telemetry (None when the engine has no
    /// digitization hooks or `obs.quant_health` is off).
    pub fn quant_health(&self) -> Option<&Arc<QuantHealth>> {
        self.health.as_ref()
    }

    /// Codebook generation currently being served (1 = the offline
    /// calibration books; each hot-swap increments).
    pub fn codebook_generation(&self) -> u64 {
        self.cell.generation()
    }

    /// Shadow-recalibration handle (None unless the pool was started
    /// with `cfg.recalib`).
    pub fn recalib(&self) -> Option<&Arc<RecalibShared>> {
        self.recalib.as_ref()
    }

    /// Atomically publish externally fitted codebooks: stack them for
    /// the deployed forward, swap the generation cell, and rebaseline
    /// health telemetry.  Batches already assembled finish under the
    /// generation they snapshotted; every later batch serves the new
    /// one — no reply ever mixes generations.  Returns the new
    /// generation number.
    pub fn hot_swap(
        &self,
        nl_books: &[Codebook],
        tile_books: &[Codebook],
        baseline: Option<&[ValueSketch]>,
    ) -> Result<u64> {
        let programmed =
            ProgrammedCodebooks::stack(nl_books, tile_books, self.max_levels)?;
        let generation = self.cell.swap(programmed);
        if let Some(h) = &self.health {
            h.rebaseline(nl_books, baseline);
        }
        Ok(generation)
    }

    /// Machine-readable pool stats (the `stats` protocol command).
    pub fn stats_json(&self) -> String {
        let lat = self.stats.percentiles_ms(&[0.5, 0.95, 0.99, 0.999]);
        let qw = self.stats.queue_percentiles_ms(&[0.5, 0.99]);
        let (exec_threads, pool_workers, active_jobs, lease_slots) =
            exec_pool::snapshot();
        let recalib = match &self.recalib {
            Some(r) => format!(
                "{{\"enabled\":true,\"generation\":{},\"swaps\":{},\
                 \"refits\":{},\"refit_errors\":{},\"last_refit_ns\":{},\
                 \"refit_ns_total\":{},\"drift\":{:.6},\
                 \"drift_threshold\":{},\"sampled\":{},\"dropped\":{},\
                 \"shadow_batches\":{},\"inflight_at_swap\":{}}}",
                self.cell.generation(),
                r.stats.swaps.load(Ordering::SeqCst),
                r.stats.refits.load(Ordering::SeqCst),
                r.stats.refit_errors.load(Ordering::SeqCst),
                r.stats.last_refit_ns.load(Ordering::SeqCst),
                r.stats.refit_ns_total.load(Ordering::SeqCst),
                r.stats.drift(),
                r.cfg.drift_threshold,
                r.stats.sampled.load(Ordering::SeqCst),
                r.stats.dropped.load(Ordering::SeqCst),
                r.stats.shadow_batches.load(Ordering::SeqCst),
                r.stats.inflight_at_swap.load(Ordering::SeqCst),
            ),
            None => format!(
                "{{\"enabled\":false,\"generation\":{}}}",
                self.cell.generation()
            ),
        };
        let mut s = format!(
            "{{\"model\":\"{}\",\"engine\":\"{}\",\"replicas\":{},\
             \"replicas_live\":{},\
             \"exec\":{{\"threads\":{exec_threads},\
             \"pool_workers\":{pool_workers},\
             \"active_jobs\":{active_jobs},\
             \"lease_slots\":{lease_slots},\"pool_enabled\":{}}},\
             \"queue_depth\":{},\"deadline_ms\":{},\"requests\":{},\
             \"batches\":{},\
             \"full_batches\":{},\"singles\":{},\"rejected\":{},\
             \"shed\":{},\
             \"busy_ms\":{:.3},\
             \"latency_ms\":{{\"p50\":{:.3},\"p95\":{:.3},\"p99\":{:.3},\
             \"p999\":{:.3}}},\
             \"queue_wait_ms\":{{\"p50\":{:.3},\"p99\":{:.3}}},\
             \"spans\":{{\"opened\":{},\"closed\":{},\"emitted\":{}}},\
             \"recalib\":{recalib},\
             \"per_replica_requests\":[",
            escape_json(&self.model),
            escape_json(&self.engine),
            self.replicas(),
            self.live_replicas(),
            exec_pool::pool_enabled(),
            self.queue.depth,
            self.request_deadline.as_millis(),
            self.stats.requests.load(Ordering::SeqCst),
            self.stats.batches.load(Ordering::SeqCst),
            self.stats.full_batches.load(Ordering::SeqCst),
            self.stats.singles.load(Ordering::SeqCst),
            self.stats.rejected.load(Ordering::SeqCst),
            self.stats.shed.load(Ordering::SeqCst),
            self.stats.busy_us.load(Ordering::SeqCst) as f64 / 1e3,
            lat[0],
            lat[1],
            lat[2],
            lat[3],
            qw[0],
            qw[1],
            self.tracer.opened(),
            self.tracer.closed(),
            self.tracer.emitted(),
        );
        for (i, r) in self.replica_stats.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&r.requests.load(Ordering::SeqCst).to_string());
        }
        s.push_str("]}");
        s
    }

    /// Render this pool's Prometheus series into `w` (the `metrics`
    /// protocol command aggregates every pool through one writer).
    pub fn render_prometheus(&self, w: &mut PromWriter) {
        let l = format!("model=\"{}\"", escape_label(&self.model));
        w.family("bskmq_requests_total", "counter", "requests served");
        w.raw_sample(
            "bskmq_requests_total",
            &l,
            self.stats.requests.load(Ordering::SeqCst) as f64,
        );
        w.family(
            "bskmq_rejected_total",
            "counter",
            "requests refused by admission control",
        );
        w.raw_sample(
            "bskmq_rejected_total",
            &l,
            self.stats.rejected.load(Ordering::SeqCst) as f64,
        );
        w.family(
            "bskmq_shed_total",
            "counter",
            "requests shed past their deadline",
        );
        w.raw_sample(
            "bskmq_shed_total",
            &l,
            self.stats.shed.load(Ordering::SeqCst) as f64,
        );
        w.family("bskmq_batches_total", "counter", "executed batches");
        w.raw_sample(
            "bskmq_batches_total",
            &l,
            self.stats.batches.load(Ordering::SeqCst) as f64,
        );
        let qs = [0.5, 0.95, 0.99, 0.999];
        let lat = self.stats.percentiles_ms(&qs);
        let qw = self.stats.queue_percentiles_ms(&qs);
        w.family(
            "bskmq_latency_ms",
            "gauge",
            "service latency quantiles (ms)",
        );
        w.family(
            "bskmq_queue_wait_quantile_ms",
            "gauge",
            "queue-wait quantiles (ms)",
        );
        for (i, q) in qs.iter().enumerate() {
            w.raw_sample(
                "bskmq_latency_ms",
                &format!("{l},quantile=\"{q}\""),
                lat[i],
            );
            w.raw_sample(
                "bskmq_queue_wait_quantile_ms",
                &format!("{l},quantile=\"{q}\""),
                qw[i],
            );
        }
        w.family(
            "bskmq_replica_requests_total",
            "counter",
            "requests per replica",
        );
        for (i, r) in self.replica_stats.iter().enumerate() {
            w.raw_sample(
                "bskmq_replica_requests_total",
                &format!("{l},replica=\"{i}\""),
                r.requests.load(Ordering::SeqCst) as f64,
            );
        }
        // executor-thread leasing per replica slot: live slots share the
        // one process-wide pool, each entitled to the current weighted
        // lease; retired slots hold no lease.  Together with
        // bskmq_exec_threads this makes serving BENCH points comparable
        // across machines (the old pages never recorded thread config).
        let lease = exec_pool::snapshot().3;
        let live = self.live_replicas();
        w.family(
            "bskmq_replica_lease_slots",
            "gauge",
            "executor-pool worker slots leasable per replica",
        );
        for i in 0..self.replica_stats.len() {
            w.raw_sample(
                "bskmq_replica_lease_slots",
                &format!("{l},replica=\"{i}\""),
                if i < live { lease as f64 } else { 0.0 },
            );
        }
        w.family(
            "bskmq_spans_opened_total",
            "counter",
            "request spans opened at admission",
        );
        w.raw_sample("bskmq_spans_opened_total", &l, self.tracer.opened() as f64);
        w.family(
            "bskmq_spans_closed_total",
            "counter",
            "request spans closed after reply",
        );
        w.raw_sample("bskmq_spans_closed_total", &l, self.tracer.closed() as f64);
        w.family(
            "bskmq_codebook_generation",
            "gauge",
            "codebook generation currently being served",
        );
        w.raw_sample(
            "bskmq_codebook_generation",
            &l,
            self.cell.generation() as f64,
        );
        if let Some(r) = &self.recalib {
            let st = &r.stats;
            w.family(
                "bskmq_recalib_swaps_total",
                "counter",
                "zero-downtime codebook hot-swaps completed",
            );
            w.raw_sample(
                "bskmq_recalib_swaps_total",
                &l,
                st.swaps.load(Ordering::SeqCst) as f64,
            );
            w.family(
                "bskmq_recalib_refits_total",
                "counter",
                "shadow refit attempts",
            );
            w.raw_sample(
                "bskmq_recalib_refits_total",
                &l,
                st.refits.load(Ordering::SeqCst) as f64,
            );
            w.family(
                "bskmq_recalib_refit_errors_total",
                "counter",
                "refits that failed (old generation kept serving)",
            );
            w.raw_sample(
                "bskmq_recalib_refit_errors_total",
                &l,
                st.refit_errors.load(Ordering::SeqCst) as f64,
            );
            w.family(
                "bskmq_recalib_drift",
                "gauge",
                "max-over-layers live-vs-baseline sketch drift at the \
                 last supervisor tick",
            );
            w.raw_sample("bskmq_recalib_drift", &l, st.drift());
            w.family(
                "bskmq_recalib_refit_ns",
                "gauge",
                "wall nanos of the last refit + swap",
            );
            w.raw_sample(
                "bskmq_recalib_refit_ns",
                &l,
                st.last_refit_ns.load(Ordering::SeqCst) as f64,
            );
            w.family(
                "bskmq_recalib_sampled_total",
                "counter",
                "request inputs diverted into the shadow buffer",
            );
            w.raw_sample(
                "bskmq_recalib_sampled_total",
                &l,
                st.sampled.load(Ordering::SeqCst) as f64,
            );
            w.family(
                "bskmq_recalib_dropped_total",
                "counter",
                "shadow samples dropped at a full buffer",
            );
            w.raw_sample(
                "bskmq_recalib_dropped_total",
                &l,
                st.dropped.load(Ordering::SeqCst) as f64,
            );
            w.family(
                "bskmq_recalib_inflight_at_swap",
                "gauge",
                "pool queue depth observed at the last swap instant",
            );
            w.raw_sample(
                "bskmq_recalib_inflight_at_swap",
                &l,
                st.inflight_at_swap.load(Ordering::SeqCst) as f64,
            );
        }
        self.metrics.render(w);
        if let Some(h) = &self.health {
            h.render(w, &self.model);
        }
    }

    /// Pool summary: aggregate line plus one line per replica.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} [{} backend, {} replica(s), {} live, queue depth {}]\n  \
             all: {}",
            self.model,
            self.engine,
            self.replicas(),
            self.live_replicas(),
            self.queue.depth,
            self.stats.summary()
        );
        for (i, r) in self.replica_stats.iter().enumerate() {
            s.push_str(&format!("\n  r{i}:  {}", r.summary()));
        }
        s
    }
}

impl Drop for ModelPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Load + calibrate one model for a pool (runs on the coordinator
/// thread so PJRT-style engines never cross threads).  Per-layer specs
/// come from the manifest unless `cfg.spec` overrides them uniformly;
/// specs carrying `weight_bits` quantize the weights *first* and then
/// calibrate on the quantized-weight backend (Algorithm 1 runs on the
/// deployed macro, not a float simulator).
fn pool_setup(
    cfg: &PoolConfig,
    artifacts: &std::path::Path,
    model: &str,
) -> Result<(Box<dyn Backend>, CalibrationResult, Option<Arc<QuantHealth>>)> {
    let be = crate::backend::load(cfg.backend, artifacts, model)?;
    let data = ModelData::load(artifacts, model)?;
    let specs = match cfg.spec {
        Some(s) => s.per_layer(be.manifest().nq()),
        None => be.manifest().layer_specs(),
    };
    let mut be: Box<dyn Backend> =
        if specs.iter().any(|s| s.weight_bits.is_some()) {
            PtqEvaluator::new(be.as_ref()).quantize_weights_spec(&specs)?
        } else {
            be
        };
    let calib = Calibrator::with_specs(be.as_ref(), specs)
        .calibrate_sharded(&data, cfg.calib_batches, cfg.calib_shards)?;
    // attach quant-health BEFORE replicate(): replicas clone the engine
    // and share the telemetry Arc, so the pool aggregates one view
    let health = if cfg.obs.quant_health {
        let names: Vec<String> = be
            .manifest()
            .qlayers
            .iter()
            .map(|ql| ql.name.clone())
            .collect();
        let h = Arc::new(QuantHealth::new(
            &names,
            &calib.nl_books,
            Some(&calib.sketches),
            cfg.obs.sketch_sample_every,
        ));
        if be.attach_quant_health(h.clone()) {
            Some(h)
        } else {
            None
        }
    } else {
        None
    };
    Ok((be, calib, health))
}

/// Build the shadow-recalibration plumbing for one pool: validate the
/// config, replicate a shadow backend for collect-mode refit passes, and
/// spawn the supervisor thread (DESIGN.md §15).  Fails fast — a pool
/// asked to recalibrate but unable to must not start silently degraded.
fn recalib_setup(
    rc: RecalibConfig,
    be: &dyn Backend,
    specs: Vec<QuantSpec>,
    health: &Option<Arc<QuantHealth>>,
    cell: &Arc<CodebookCell>,
    queue: &Arc<JobQueue>,
) -> Result<(Arc<RecalibShared>, RecalibController)> {
    rc.validate()?;
    let health = match health {
        Some(h) => h.clone(),
        None => bail!(
            "recalibration needs quant-health telemetry: enable \
             obs.quant_health and use an engine with activation hooks"
        ),
    };
    let shadow = be.replicate().context(
        "recalibration needs a replicable backend for its shadow \
         collect passes",
    )?;
    let m = be.manifest();
    let layer_names: Vec<String> =
        m.qlayers.iter().map(|q| q.name.clone()).collect();
    let stats = Arc::new(RecalibStats::default());
    // tap capacity: a few batches of headroom so sampling survives
    // bursts without the controller having drained yet
    let tap = Arc::new(ShadowTap::new(
        rc.sample_every,
        (m.batch * 8).max(64),
        stats.clone(),
    ));
    let shared = Arc::new(RecalibShared {
        cfg: rc,
        stats,
        tap,
        cell: cell.clone(),
    });
    let qp = queue.clone();
    let ctl = RecalibController::spawn(
        shared.clone(),
        shadow,
        specs,
        layer_names,
        health,
        Box::new(move || qp.len() as u64),
    );
    Ok((shared, ctl))
}

/// State every worker replica shares: the codebook swap cell (snapshot
/// once per batch, so every reply is computed under exactly one
/// generation) plus the pool's observability handles.
struct WorkerShared {
    cell: Arc<CodebookCell>,
    /// shadow-recalibration tap: workers offer each request's input for
    /// sampling before executing it (None when recalib is off)
    tap: Option<Arc<ShadowTap>>,
    noise_std: f32,
    window: Duration,
    /// profile every Nth batch through `run_qfwd_profiled` (0 = never)
    profile_every: u64,
    tracer: Arc<RequestTracer>,
    forward_hist: Arc<Histogram>,
    queue_hist: Arc<Histogram>,
    /// deadline headroom at batch assembly (0 for shed requests)
    deadline_hist: Arc<Histogram>,
}

/// One worker replica: pop a batch, shed what already missed its
/// deadline, execute the rest, reply, repeat until the queue closes and
/// drains (or autoscaling retires the slot).  Backend failures answer
/// the affected batch with errors and keep the worker alive.
fn worker_loop(
    backend: &dyn Backend,
    sh: &WorkerShared,
    queue: &JobQueue,
    slot: usize,
    mine: &ServerStats,
    global: &ServerStats,
) {
    let m = backend.manifest();
    let batch = m.batch;
    let classes = m.num_classes;
    let in_elems = m.input_elems();
    let replica = slot as u32;
    let mut seed = replica.wrapping_mul(0x9E37);
    let mut batches_done: u64 = 0;
    loop {
        let popped = match queue.pop_batch(slot, batch, sh.window) {
            Popped::Batch(v) => v,
            // shutdown observed with the queue drained, or this slot
            // scaled away — either way this thread is done
            Popped::Shutdown | Popped::Retire => return,
        };
        let t0 = Instant::now();
        seed = seed.wrapping_add(1);
        // queue wait is measured at batch assembly, per request; the
        // same instant decides shedding, so a shed request's wait is
        // still visible in the queue-wait percentiles
        let mut pending: Vec<Request> = Vec::with_capacity(popped.len());
        let mut queue_waits: Vec<u64> = Vec::with_capacity(popped.len());
        for r in popped {
            let us = r.submitted.elapsed().as_micros() as u64;
            sh.queue_hist.observe(us as f64 / 1e3);
            mine.record_queue_wait(us);
            global.record_queue_wait(us);
            if t0 >= r.deadline {
                // deadline shed: answer immediately with an explicit
                // overload reply instead of spending batch capacity on
                // an answer the client has given up on
                mine.shed.fetch_add(1, Ordering::Relaxed);
                global.shed.fetch_add(1, Ordering::Relaxed);
                sh.deadline_hist.observe(0.0);
                let deadline_ms = r
                    .deadline
                    .saturating_duration_since(r.submitted)
                    .as_millis() as u64;
                r.reply.send(Err(ServeError::Overload {
                    queued_ms: us / 1000,
                    deadline_ms,
                }));
                sh.tracer.close(r.id, || Span {
                    id: 0,
                    model: String::new(),
                    replica,
                    batch_n: 0,
                    queue_us: us,
                    forward_us: 0,
                    reply_us: 0,
                    ops: Vec::new(),
                });
                continue;
            }
            let headroom =
                r.deadline.saturating_duration_since(t0).as_secs_f64() * 1e3;
            sh.deadline_hist.observe(headroom);
            queue_waits.push(us);
            pending.push(r);
        }
        if pending.is_empty() {
            continue; // the whole pop was shed
        }
        // one generation snapshot per batch: a concurrent hot-swap lands
        // on the NEXT batch, never mid-reply (DESIGN.md §15)
        let generation = sh.cell.current();
        if let Some(tap) = &sh.tap {
            for r in &pending {
                tap.maybe_sample(&r.x);
            }
        }
        let n = pending.len();
        // exact-size execution when the backend can (native: always;
        // xla: full batch or the batch-1 graph); otherwise pad up to the
        // compiled batch
        let run_n = if backend.supports_batch(n) { n } else { batch };
        let mut x = Vec::with_capacity(run_n * in_elems);
        for r in &pending {
            x.extend_from_slice(&r.x);
        }
        for _ in n..run_n {
            x.extend_from_slice(&pending[0].x);
        }
        batches_done += 1;
        // sampled per-op profiling: unprofiled batches collect no rows,
        // so the steady state allocates nothing for tracing
        let profiled =
            sh.profile_every > 0 && batches_done % sh.profile_every == 0;
        let (result, ops) = if profiled {
            match backend.run_qfwd_profiled(
                &x,
                &generation.books,
                sh.noise_std,
                seed,
            ) {
                Ok((logits, timings)) => (
                    Ok(logits),
                    timings
                        .into_iter()
                        .map(|t| (t.name, t.nanos as u64))
                        .collect::<Vec<(String, u64)>>(),
                ),
                Err(e) => (Err(e), Vec::new()),
            }
        } else {
            (
                backend.run_qfwd(&x, &generation.books, sh.noise_std, seed),
                Vec::new(),
            )
        };
        // record BEFORE replying: a client that just received its answer
        // must already see itself in the counters
        let forward_us = t0.elapsed().as_micros() as u64;
        mine.record_batch(n, batch, forward_us);
        global.record_batch(n, batch, forward_us);
        sh.forward_hist.observe(forward_us as f64 / 1e3);
        match result {
            Ok(logits) => {
                for (i, r) in pending.iter().enumerate() {
                    r.reply
                        .send(Ok(logits[i * classes..(i + 1) * classes].to_vec()));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                eprintln!("worker r{replica}: batch of {n} failed: {msg}");
                for r in &pending {
                    r.reply.send(Err(ServeError::Failed(msg.clone())));
                }
            }
        }
        // close spans AFTER the replies: reply_us covers the send
        let reply_us =
            (t0.elapsed().as_micros() as u64).saturating_sub(forward_us);
        for (i, r) in pending.iter().enumerate() {
            sh.tracer.close(r.id, || Span {
                id: 0,
                model: String::new(),
                replica,
                batch_n: n,
                queue_us: queue_waits[i],
                forward_us,
                reply_us,
                ops: ops.clone(),
            });
        }
    }
}

/// Several models served from one process, each behind its own
/// [`ModelPool`].  Routing is by model name; the first model is the
/// default route.
pub struct ModelRegistry {
    pools: Vec<ModelPool>,
}

impl ModelRegistry {
    /// Load + calibrate every model sequentially; any failure aborts the
    /// whole registry (fail fast beats serving a partial fleet silently).
    pub fn start(
        artifacts: &std::path::Path,
        models: &[String],
        cfg: &PoolConfig,
    ) -> Result<ModelRegistry> {
        ensure!(!models.is_empty(), "registry needs at least one model");
        let mut pools: Vec<ModelPool> = Vec::with_capacity(models.len());
        for name in models {
            ensure!(
                pools.iter().all(|p| &p.model != name),
                "model '{name}' listed twice"
            );
            pools.push(ModelPool::start(
                artifacts.to_path_buf(),
                name.clone(),
                cfg,
            )?);
        }
        Ok(ModelRegistry { pools })
    }

    /// Pool by model name.
    pub fn get(&self, model: &str) -> Option<&ModelPool> {
        self.pools.iter().find(|p| p.model == model)
    }

    /// The default route (first model listed).
    pub fn default_pool(&self) -> &ModelPool {
        &self.pools[0]
    }

    pub fn pools(&self) -> &[ModelPool] {
        &self.pools
    }

    pub fn models(&self) -> Vec<&str> {
        self.pools.iter().map(|p| p.model.as_str()).collect()
    }

    /// Multi-line summary: per-pool aggregate + per-replica stats.
    pub fn summary(&self) -> String {
        let lines: Vec<String> =
            self.pools.iter().map(|p| p.summary()).collect();
        lines.join("\n")
    }

    /// Machine-readable stats over every pool (the `stats` command).
    pub fn stats_json(&self) -> String {
        let items: Vec<String> =
            self.pools.iter().map(|p| p.stats_json()).collect();
        format!("{{\"pools\":[{}]}}", items.join(","))
    }

    /// Prometheus text exposition over every pool (the `metrics`
    /// command).
    pub fn prometheus(&self) -> String {
        let mut w = PromWriter::new();
        // process-global executor gauges, emitted once (all pools share
        // the one thread budget — the point of the persistent pool)
        let (threads, workers, jobs, lease) = exec_pool::snapshot();
        w.family(
            "bskmq_exec_threads",
            "gauge",
            "process-wide executor thread budget (BSKMQ_THREADS)",
        );
        w.raw_sample("bskmq_exec_threads", "", threads as f64);
        w.family(
            "bskmq_exec_pool_workers",
            "gauge",
            "persistent executor-pool worker threads",
        );
        w.raw_sample("bskmq_exec_pool_workers", "", workers as f64);
        w.family(
            "bskmq_exec_active_jobs",
            "gauge",
            "row-parallel jobs in flight across all replicas",
        );
        w.raw_sample("bskmq_exec_active_jobs", "", jobs as f64);
        w.family(
            "bskmq_exec_lease_slots",
            "gauge",
            "worker slots one job may lease under current load",
        );
        w.raw_sample("bskmq_exec_lease_slots", "", lease as f64);
        for p in &self.pools {
            p.render_prometheus(&mut w);
        }
        w.finish()
    }
}

/// Single-model compatibility front over [`ModelPool`] (the pre-pool
/// API).  `start` keeps its historical signature; replica count and
/// queue depth come from [`PoolConfig::default`] unless the pool API is
/// used directly.
pub struct InferenceServer {
    pool: ModelPool,
    pub stats: Arc<ServerStats>,
}

impl InferenceServer {
    /// Start a one-model, default-config pool: load the selected
    /// backend, calibrate on `calib_batches` batches — with `spec` as a
    /// uniform per-layer override, or the manifest's specs when `None` —
    /// then serve until dropped.
    pub fn start(
        artifacts: std::path::PathBuf,
        model: String,
        backend: BackendKind,
        spec: Option<QuantSpec>,
        noise_std: f32,
        calib_batches: usize,
    ) -> Result<InferenceServer> {
        let cfg = PoolConfig {
            backend,
            spec,
            noise_std,
            calib_batches,
            ..PoolConfig::default()
        };
        let pool = ModelPool::start(artifacts, model, &cfg)?;
        eprintln!("inference server ready ({} backend)", pool.engine());
        let stats = pool.stats.clone();
        Ok(InferenceServer { pool, stats })
    }

    /// Blocking request: returns the logits for one input.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.pool.infer(x)
    }

    /// Clone-able intake handle for concurrent client threads.
    pub fn client(&self) -> PoolClient {
        self.pool.client()
    }

    /// The underlying pool (replica stats, admission counters).
    pub fn pool(&self) -> &ModelPool {
        &self.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_request(tx: mpsc::Sender<Reply>) -> Request {
        let now = Instant::now();
        Request {
            id: 0,
            submitted: now,
            deadline: now + Duration::from_secs(10),
            x: vec![0.0],
            reply: ReplyTo::Channel(tx),
        }
    }

    #[test]
    fn stats_percentiles() {
        let st = ServerStats::default();
        assert_eq!(st.percentile_ms(0.5), 0.0);
        for us in [1000u64, 2000, 3000, 4000] {
            st.record_latency(us, 1);
        }
        assert!((st.percentile_ms(0.5) - 2.5).abs() < 1e-9);
        assert!(st.percentile_ms(0.99) <= 4.0);
        let s = st.summary();
        assert!(s.contains("p50="), "{s}");
        assert!(s.contains("p99="), "{s}");
        assert!(s.contains("rejected=0"), "{s}");
        assert!(s.contains("shed=0"), "{s}");
    }

    /// Empty ring: every percentile is 0.0, for any quantile list.
    #[test]
    fn empty_ring_percentiles_are_zero() {
        let st = ServerStats::default();
        assert_eq!(
            st.percentiles_ms(&[0.0, 0.25, 0.5, 0.95, 1.0]),
            vec![0.0; 5]
        );
        assert_eq!(st.percentiles_ms(&[]), Vec::<f64>::new());
    }

    /// Small-capacity ring against a naive keep-the-last-K reference:
    /// wraparound must retain exactly the most recent `capacity` samples.
    #[test]
    fn ring_wraparound_matches_naive_reference() {
        let cap = 8;
        let mut ring = LatencyRing::with_capacity(cap);
        let feed: Vec<u64> = (0..31).map(|i| (i * 37 + 5) % 97).collect();
        for &v in &feed {
            ring.push(v);
        }
        assert_eq!(ring.samples.len(), cap, "ring exceeded its capacity");
        let mut got = ring.samples.clone();
        got.sort_unstable();
        let mut want: Vec<u64> = feed[feed.len() - cap..].to_vec();
        want.sort_unstable();
        assert_eq!(got, want, "ring lost or kept the wrong samples");
    }

    /// Full-size ring: push past MAX_LATENCY_SAMPLES and check the
    /// percentiles against a sort-everything reference over the retained
    /// window (the last MAX samples).
    #[test]
    fn ring_wraps_past_max_and_percentiles_track_recent_window() {
        let st = ServerStats::default();
        let extra = 1234usize;
        let total = MAX_LATENCY_SAMPLES + extra;
        for i in 0..total {
            st.record_latency(i as u64, 1);
        }
        assert_eq!(
            st.lat_us.lock().unwrap().samples.len(),
            MAX_LATENCY_SAMPLES,
            "ring grew past its bound"
        );
        // retained window = values extra..total (the most recent MAX)
        let window: Vec<f64> =
            (extra..total).map(|v| v as f64).collect(); // already sorted
        let qs = [0.0, 0.01, 0.5, 0.95, 1.0];
        let got = st.percentiles_ms(&qs); // one sort for all quantiles
        for (q, got) in qs.iter().zip(got) {
            let want =
                crate::util::stats::quantile_sorted(&window, *q) / 1e3;
            assert!(
                (got - want).abs() < 1e-6,
                "q={q}: got {got} want {want}"
            );
        }
    }

    /// Bounded queue semantics: admission rejection at depth, explicit
    /// close rejects producers and releases consumers.
    #[test]
    fn job_queue_admission_and_close() {
        let q = JobQueue::new(2, 1, 1, None);
        let mk = || {
            let (tx, rx) = mpsc::channel();
            (mk_request(tx), rx)
        };
        let (r1, _k1) = mk();
        let (r2, _k2) = mk();
        let (r3, _k3) = mk();
        assert!(q.push(r1).is_ok());
        assert!(q.push(r2).is_ok());
        assert_eq!(
            q.push(r3).unwrap_err(),
            AdmissionError::Full { depth: 2 }
        );
        match q.pop_batch(0, 8, Duration::ZERO) {
            Popped::Batch(got) => {
                assert_eq!(got.len(), 2, "drain returns everything queued");
            }
            _ => panic!("expected a batch"),
        }
        q.close();
        let (r4, _k4) = mk();
        assert_eq!(q.push(r4).unwrap_err(), AdmissionError::Closed);
        assert!(
            matches!(
                q.pop_batch(0, 8, Duration::from_millis(50)),
                Popped::Shutdown
            ),
            "closed+empty queue must release consumers immediately"
        );
    }

    /// A full batch dispatches the moment it is full: the top-up window
    /// must never add latency once `len == max` (the old per-replica
    /// batching bug class this module's rewrite retires structurally).
    #[test]
    fn full_batch_dispatches_without_waiting_for_window() {
        let q = JobQueue::new(8, 1, 1, None);
        for _ in 0..4 {
            let (tx, _rx) = mpsc::channel();
            q.push(mk_request(tx)).unwrap();
        }
        let t0 = Instant::now();
        match q.pop_batch(0, 4, Duration::from_secs(5)) {
            Popped::Batch(b) => assert_eq!(b.len(), 4),
            _ => panic!("expected a batch"),
        }
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "full batch must dispatch immediately, not wait out the window"
        );
    }

    /// The autoscaling protocol on the queue: slots past the target
    /// retire (handing queued work to live workers), and a later resize
    /// reports each retired slot for respawn exactly once.
    #[test]
    fn scale_target_retires_and_respawns_slots() {
        let q = Arc::new(JobQueue::new(8, 2, 4, None));
        match q.pop_batch(3, 4, Duration::ZERO) {
            Popped::Retire => {}
            _ => panic!("slot past the target must retire"),
        }
        assert_eq!(q.resize_target(4), vec![3]);
        assert_eq!(q.resize_target(4), Vec::<usize>::new());
        assert_eq!(q.target(), 4);
        // with work queued, a retiring slot must hand the wakeup on so
        // the job reaches a live worker
        q.resize_target(1);
        let (tx, _rx) = mpsc::channel();
        q.push(mk_request(tx)).unwrap();
        let q2 = q.clone();
        let h =
            std::thread::spawn(move || q2.pop_batch(1, 4, Duration::ZERO));
        match q.pop_batch(0, 4, Duration::ZERO) {
            Popped::Batch(b) => assert_eq!(b.len(), 1),
            _ => panic!("slot 0 must receive the handed-off job"),
        }
        match h.join().unwrap() {
            Popped::Retire => {}
            _ => panic!("slot 1 must retire after the resize"),
        }
    }

    #[test]
    fn serve_error_display_and_overload_flag() {
        let o = ServeError::Overload {
            queued_ms: 7,
            deadline_ms: 5,
        };
        assert!(o.is_overload());
        let s = o.to_string();
        assert!(s.contains("overload"), "{s}");
        assert!(s.contains('7'), "{s}");
        let f = ServeError::Failed("boom".into());
        assert!(!f.is_overload());
        assert_eq!(f.to_string(), "inference failed: boom");
    }
}
