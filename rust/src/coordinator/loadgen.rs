//! Closed-loop load generation against a [`PoolClient`].
//!
//! `concurrency` client threads each keep exactly one request in flight
//! (submit → wait → repeat), drawing request indices from one shared
//! counter until `total` have been issued — offered load is the number
//! of closed-loop clients, the knob the serving BENCH section sweeps.
//! Every issued request is accounted for exactly once: completed (with
//! its latency sample), shed
//! ([`crate::coordinator::pool::ServeError::Overload`]), rejected
//! (admission control refused the submit), or errored.  The returned
//! [`ServingPoint`] carries latency percentiles over *completed*
//! requests — under overload the interesting claim is that admitted
//! requests stay fast while the rest are shed, not that averages
//! degrade gracefully.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::coordinator::pool::{AdmissionError, PoolClient, REPLY_GRACE};
use crate::obs::bench_report::ServingPoint;

/// Per-thread tally merged after the run.
#[derive(Default)]
struct Tally {
    latencies_ms: Vec<f64>,
    shed: u64,
    rejected: u64,
    errors: u64,
}

/// Drive `total` requests through `client` from `concurrency` closed-loop
/// threads, cycling over `inputs`.  `model` and `phase` label the
/// resulting [`ServingPoint`]; `deadline` is the per-request deadline
/// (also recorded in the point).
pub fn closed_loop(
    client: &PoolClient,
    inputs: &[Vec<f32>],
    model: &str,
    phase: &str,
    concurrency: usize,
    total: u64,
    deadline: Duration,
) -> ServingPoint {
    assert!(!inputs.is_empty(), "closed_loop needs at least one input");
    let issued = AtomicU64::new(0);
    let t0 = Instant::now();
    let tallies: Vec<Tally> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..concurrency.max(1))
            .map(|_| {
                let issued = &issued;
                scope.spawn(move || {
                    let mut t = Tally::default();
                    loop {
                        let k = issued.fetch_add(1, Ordering::SeqCst);
                        if k >= total {
                            break;
                        }
                        let x = inputs[(k as usize) % inputs.len()].clone();
                        let sent = Instant::now();
                        match client.submit_deadline(x, deadline) {
                            Ok(rx) => {
                                match rx.recv_timeout(deadline + REPLY_GRACE) {
                                    Ok(Ok(_logits)) => {
                                        let ms = sent.elapsed().as_secs_f64()
                                            * 1e3;
                                        t.latencies_ms.push(ms);
                                    }
                                    Ok(Err(e)) if e.is_overload() => {
                                        t.shed += 1
                                    }
                                    Ok(Err(_)) => t.errors += 1,
                                    Err(_) => t.errors += 1,
                                }
                            }
                            Err(e) => {
                                let full = matches!(
                                    e.downcast_ref::<AdmissionError>(),
                                    Some(AdmissionError::Full { .. })
                                );
                                if full {
                                    t.rejected += 1;
                                } else {
                                    t.errors += 1;
                                }
                            }
                        }
                    }
                    t
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("load thread panicked"))
            .collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let mut lat: Vec<f64> = Vec::new();
    let (mut shed, mut rejected, mut errors) = (0u64, 0u64, 0u64);
    for mut t in tallies {
        lat.append(&mut t.latencies_ms);
        shed += t.shed;
        rejected += t.rejected;
        errors += t.errors;
    }
    lat.sort_by(|a, b| a.total_cmp(b));
    let completed = lat.len() as u64;
    debug_assert_eq!(
        completed + shed + rejected + errors,
        total,
        "every issued request must end exactly one way"
    );

    ServingPoint {
        phase: phase.to_string(),
        model: model.to_string(),
        offered: concurrency.max(1),
        requests: total,
        completed,
        shed,
        rejected,
        errors,
        wall_s,
        throughput_rps: if wall_s > 0.0 {
            completed as f64 / wall_s
        } else {
            0.0
        },
        p50_ms: pct(&lat, 0.50),
        p99_ms: pct(&lat, 0.99),
        p999_ms: pct(&lat, 0.999),
        deadline_ms: deadline.as_secs_f64() * 1e3,
        replicas: client.live_replicas(),
        exec_threads: crate::backend::native::ops::num_threads(),
    }
}

/// Nearest-rank percentile over an ascending-sorted slice (0.0 on empty).
fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pct_nearest_rank() {
        assert_eq!(pct(&[], 0.5), 0.0);
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(pct(&v, 0.0), 1.0);
        assert_eq!(pct(&v, 0.5), 51.0);
        assert_eq!(pct(&v, 0.99), 99.0);
        assert_eq!(pct(&v, 1.0), 100.0);
    }
}
