//! Closed-loop load generation against a [`PoolClient`].
//!
//! `concurrency` client threads each keep exactly one request in flight
//! (submit → wait → repeat), drawing request indices from one shared
//! counter until `total` have been issued — offered load is the number
//! of closed-loop clients, the knob the serving BENCH section sweeps.
//! Every issued request is accounted for exactly once: completed (with
//! its latency sample), shed
//! ([`crate::coordinator::pool::ServeError::Overload`]), rejected
//! (admission control refused the submit), or errored.  The returned
//! [`ServingPoint`] carries latency percentiles over *completed*
//! requests — under overload the interesting claim is that admitted
//! requests stay fast while the rest are shed, not that averages
//! degrade gracefully.
//!
//! [`closed_loop_phased`] drives **nonstationary** traffic: an ordered
//! list of [`TrafficPhase`]s, each contributing its own input set for a
//! span of the issued-request sequence.  Request `k` draws from the
//! phase owning `k`, so the offered distribution shifts mid-run without
//! tearing down the clients — the traffic shape the shadow
//! recalibration controller (DESIGN.md §15) exists to chase, and what
//! the swap-under-load BENCH point drives.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::coordinator::pool::{AdmissionError, PoolClient, REPLY_GRACE};
use crate::obs::bench_report::ServingPoint;

/// Per-thread tally merged after the run.
#[derive(Default)]
struct Tally {
    latencies_ms: Vec<f64>,
    shed: u64,
    rejected: u64,
    errors: u64,
}

/// Drive `total` requests through `client` from `concurrency` closed-loop
/// threads, cycling over `inputs`.  `model` and `phase` label the
/// resulting [`ServingPoint`]; `deadline` is the per-request deadline
/// (also recorded in the point).
pub fn closed_loop(
    client: &PoolClient,
    inputs: &[Vec<f32>],
    model: &str,
    phase: &str,
    concurrency: usize,
    total: u64,
    deadline: Duration,
) -> ServingPoint {
    assert!(!inputs.is_empty(), "closed_loop needs at least one input");
    let pick = |k: u64| inputs[(k as usize) % inputs.len()].clone();
    run_closed_loop(client, &pick, model, phase, concurrency, total, deadline)
}

/// One span of a nonstationary traffic program: `requests` issued
/// requests drawn (round-robin) from `inputs`.
pub struct TrafficPhase {
    pub inputs: Vec<Vec<f32>>,
    pub requests: u64,
}

/// Copies of `inputs` with every element scaled by `gain` — the
/// simplest controlled distribution shift (it moves every activation
/// decile), used by the recalibration tests and the swap-under-load
/// BENCH phase.
pub fn scaled_inputs(inputs: &[Vec<f32>], gain: f32) -> Vec<Vec<f32>> {
    inputs
        .iter()
        .map(|x| x.iter().map(|v| v * gain).collect())
        .collect()
}

/// Closed-loop run over a nonstationary traffic program: request index
/// `k` draws from the [`TrafficPhase`] owning `k` in issue order, so
/// the offered distribution shifts mid-run while the client threads
/// stay up.  Accounting spans the whole program (one [`ServingPoint`]).
pub fn closed_loop_phased(
    client: &PoolClient,
    phases: &[TrafficPhase],
    model: &str,
    phase: &str,
    concurrency: usize,
    deadline: Duration,
) -> ServingPoint {
    assert!(!phases.is_empty(), "phased run needs at least one phase");
    for p in phases {
        assert!(
            !p.inputs.is_empty() && p.requests >= 1,
            "every traffic phase needs inputs and a request budget"
        );
    }
    let total: u64 = phases.iter().map(|p| p.requests).sum();
    let pick = |k: u64| {
        let mut k = k;
        for p in phases {
            if k < p.requests {
                return p.inputs[(k as usize) % p.inputs.len()].clone();
            }
            k -= p.requests;
        }
        // issued indices are < total by construction
        unreachable!("request index past the traffic program")
    };
    run_closed_loop(client, &pick, model, phase, concurrency, total, deadline)
}

/// The shared driver: `pick` maps an issued-request index to its input.
fn run_closed_loop(
    client: &PoolClient,
    pick: &(dyn Fn(u64) -> Vec<f32> + Sync),
    model: &str,
    phase: &str,
    concurrency: usize,
    total: u64,
    deadline: Duration,
) -> ServingPoint {
    let issued = AtomicU64::new(0);
    let t0 = Instant::now();
    let tallies: Vec<Tally> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..concurrency.max(1))
            .map(|_| {
                let issued = &issued;
                scope.spawn(move || {
                    let mut t = Tally::default();
                    loop {
                        let k = issued.fetch_add(1, Ordering::SeqCst);
                        if k >= total {
                            break;
                        }
                        let x = pick(k);
                        let sent = Instant::now();
                        match client.submit_deadline(x, deadline) {
                            Ok(rx) => {
                                match rx.recv_timeout(deadline + REPLY_GRACE) {
                                    Ok(Ok(_logits)) => {
                                        let ms = sent.elapsed().as_secs_f64()
                                            * 1e3;
                                        t.latencies_ms.push(ms);
                                    }
                                    Ok(Err(e)) if e.is_overload() => {
                                        t.shed += 1
                                    }
                                    Ok(Err(_)) => t.errors += 1,
                                    Err(_) => t.errors += 1,
                                }
                            }
                            Err(e) => {
                                let full = matches!(
                                    e.downcast_ref::<AdmissionError>(),
                                    Some(AdmissionError::Full { .. })
                                );
                                if full {
                                    t.rejected += 1;
                                } else {
                                    t.errors += 1;
                                }
                            }
                        }
                    }
                    t
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("load thread panicked"))
            .collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let mut lat: Vec<f64> = Vec::new();
    let (mut shed, mut rejected, mut errors) = (0u64, 0u64, 0u64);
    for mut t in tallies {
        lat.append(&mut t.latencies_ms);
        shed += t.shed;
        rejected += t.rejected;
        errors += t.errors;
    }
    lat.sort_by(|a, b| a.total_cmp(b));
    let completed = lat.len() as u64;
    debug_assert_eq!(
        completed + shed + rejected + errors,
        total,
        "every issued request must end exactly one way"
    );

    ServingPoint {
        phase: phase.to_string(),
        model: model.to_string(),
        offered: concurrency.max(1),
        requests: total,
        completed,
        shed,
        rejected,
        errors,
        wall_s,
        throughput_rps: if wall_s > 0.0 {
            completed as f64 / wall_s
        } else {
            0.0
        },
        p50_ms: pct(&lat, 0.50),
        p99_ms: pct(&lat, 0.99),
        p999_ms: pct(&lat, 0.999),
        deadline_ms: deadline.as_secs_f64() * 1e3,
        replicas: client.live_replicas(),
        exec_threads: crate::backend::native::ops::num_threads(),
        // filled in by the caller when the run exercised a hot-swap
        swaps: 0,
        swap_ns: 0,
        inflight_at_swap: 0,
    }
}

/// Nearest-rank percentile over an ascending-sorted slice (0.0 on empty).
fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_inputs_scale_elementwise() {
        let base = vec![vec![1.0f32, -2.0], vec![0.5, 0.0]];
        let hot = scaled_inputs(&base, 4.0);
        assert_eq!(hot, vec![vec![4.0, -8.0], vec![2.0, 0.0]]);
        // the originals are untouched (the phases own copies)
        assert_eq!(base[0], vec![1.0, -2.0]);
    }

    #[test]
    fn pct_nearest_rank() {
        assert_eq!(pct(&[], 0.5), 0.0);
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(pct(&v, 0.0), 1.0);
        assert_eq!(pct(&v, 0.5), 51.0);
        assert_eq!(pct(&v, 0.99), 99.0);
        assert_eq!(pct(&v, 1.0), 100.0);
    }
}
