//! Back-compat shim: the serving layer now lives in
//! [`crate::coordinator::pool`] (replica pools, continuous batching,
//! deadline shedding, autoscaling) and [`crate::coordinator::front`]
//! (the TCP fronts).  Everything that used to be defined here is
//! re-exported so `coordinator::server::*` paths keep working.

pub use crate::coordinator::pool::*;
