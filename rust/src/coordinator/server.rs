//! Batched inference server: the request path of the deployed system.
//!
//! A dedicated inference thread owns the PJRT engine and the calibrated
//! model (the xla handles never cross threads); intake happens over an
//! mpsc channel from any number of client threads (or the TCP front in
//! `main.rs`).  A dynamic batcher groups queued requests: full batches go
//! through the batch-32 graph, stragglers through the batch-1 graph when
//! the model has one (padding otherwise) — the vLLM-style policy scaled
//! to this testbed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::calibrate::Calibrator;
use crate::data::dataset::ModelData;
use crate::quant::Method;
use crate::runtime::engine::Engine;
use crate::runtime::model::ModelRuntime;

pub struct Request {
    pub x: Vec<f32>,
    pub reply: mpsc::Sender<Vec<f32>>,
}

#[derive(Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub full_batches: AtomicU64,
    pub singles: AtomicU64,
    pub busy_us: AtomicU64,
}

impl ServerStats {
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} full={} singles={} busy={:.1}ms",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.full_batches.load(Ordering::Relaxed),
            self.singles.load(Ordering::Relaxed),
            self.busy_us.load(Ordering::Relaxed) as f64 / 1e3
        )
    }
}

pub struct InferenceServer {
    tx: mpsc::Sender<Request>,
    pub stats: Arc<ServerStats>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
}

impl InferenceServer {
    /// Start the inference thread: load artifacts, calibrate `bits`-bit
    /// BS-KMQ codebooks on `calib_batches`, then serve until dropped.
    pub fn start(
        artifacts: std::path::PathBuf,
        model: String,
        method: Method,
        bits: u32,
        noise_std: f32,
        calib_batches: usize,
    ) -> Result<InferenceServer> {
        let (tx, rx) = mpsc::channel::<Request>();
        let stats = Arc::new(ServerStats::default());
        let st = stats.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::spawn(move || -> Result<()> {
            let setup = (|| -> Result<(Engine, ModelRuntime, ModelData)> {
                let engine = Engine::cpu()?;
                let runtime = ModelRuntime::load(&engine, &artifacts, &model)?;
                let data = ModelData::load(&artifacts, &model)?;
                Ok((engine, runtime, data))
            })();
            let (_engine, runtime, data) = match setup {
                Ok(v) => v,
                Err(e) => {
                    let _ = ready_tx.send(Err(anyhow::anyhow!("{e}")));
                    return Err(e);
                }
            };
            let calib = Calibrator::new(&runtime, method, bits)
                .calibrate(&data, calib_batches)?;
            let _ = ready_tx.send(Ok(()));
            serve_loop(&runtime, &calib.programmed, noise_std, rx, &st)
        });
        ready_rx
            .recv()
            .context("inference thread died during setup")??;
        Ok(InferenceServer {
            tx,
            stats,
            handle: Some(handle),
        })
    }

    /// Blocking request: returns the logits for one input.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request { x, reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        reply_rx
            .recv_timeout(Duration::from_secs(120))
            .context("inference timed out")
    }

    /// Clone the intake handle for concurrent client threads.
    pub fn client(&self) -> mpsc::Sender<Request> {
        self.tx.clone()
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        // closing the channel ends the serve loop
        let (tx, _) = mpsc::channel();
        let _ = std::mem::replace(&mut self.tx, tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_loop(
    runtime: &ModelRuntime,
    books: &crate::runtime::model::ProgrammedCodebooks,
    noise_std: f32,
    rx: mpsc::Receiver<Request>,
    stats: &ServerStats,
) -> Result<()> {
    let batch = runtime.manifest.batch;
    let classes = runtime.manifest.num_classes;
    let in_elems = runtime.manifest.input_elems();
    let mut seed = 1u32;
    loop {
        // block for the first request, then drain up to a full batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return Ok(()), // all senders dropped
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + Duration::from_millis(2);
        while pending.len() < batch {
            match rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }
        let t0 = Instant::now();
        seed = seed.wrapping_add(1);
        if pending.len() == 1 && runtime.has_b1() {
            let r = &pending[0];
            let logits = runtime.run_qfwd_b1(&r.x, books, noise_std, seed)?;
            let _ = r.reply.send(logits);
            stats.singles.fetch_add(1, Ordering::Relaxed);
        } else {
            // pad to the compiled batch with the first request's input
            let mut x = Vec::with_capacity(batch * in_elems);
            for r in &pending {
                anyhow::ensure!(r.x.len() == in_elems, "bad input size");
                x.extend_from_slice(&r.x);
            }
            for _ in pending.len()..batch {
                x.extend_from_slice(&pending[0].x);
            }
            let logits = runtime.run_qfwd(&x, books, noise_std, seed)?;
            for (i, r) in pending.iter().enumerate() {
                let _ =
                    r.reply.send(logits[i * classes..(i + 1) * classes].to_vec());
            }
            if pending.len() == batch {
                stats.full_batches.fetch_add(1, Ordering::Relaxed);
            }
        }
        stats.requests.fetch_add(pending.len() as u64, Ordering::Relaxed);
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats
            .busy_us
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
    }
}
