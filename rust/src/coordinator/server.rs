//! Replica-pool inference serving: the request path of the deployed
//! system.
//!
//! One process hosts a [`ModelRegistry`] of independently calibrated
//! models.  Each model is served by a [`ModelPool`]: a shared **bounded**
//! intake queue with admission control (a full queue rejects the request
//! with an error instead of buffering without bound) feeding N worker
//! replicas.  Every worker owns its own [`Backend`] instance — replicas
//! come from [`Backend::replicate`], which for the native engine is an
//! `Arc` clone of the shared weight set, the software analogue of
//! programming the same weights into another crossbar bank — and batches
//! greedily: pop everything queued up to the model batch size, top a
//! partial batch up for a short window, execute, reply.  The vLLM-style
//! dynamic batching of the single-thread server, scaled across replicas.
//!
//! Shutdown is an explicit signal on the queue, not a channel-hangup
//! side effect: dropping a pool closes the queue, which wakes and drains
//! every worker even while [`PoolClient`] handles are still alive in
//! other threads (the bug the old mpsc-based server had).
//!
//! With zero conversion noise the quantized forward is a deterministic
//! per-sample function (per-(layer, row) noise seeding, no cross-sample
//! coupling), so logits are bit-identical regardless of replica count,
//! batch composition, or thread interleaving — the property the
//! concurrency suite (`rust/tests/server_concurrency.rs`) pins.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::backend::{Backend, BackendKind, ProgrammedCodebooks};
use crate::coordinator::calibrate::{CalibrationResult, Calibrator};
use crate::coordinator::ptq::PtqEvaluator;
use crate::data::dataset::ModelData;
use crate::obs::prometheus::{escape_label, PromWriter};
use crate::obs::quant_health::QuantHealth;
use crate::obs::registry::{Histogram, MetricsRegistry};
use crate::obs::trace::{escape_json, RequestTracer, Span, TraceSink};
use crate::quant::QuantSpec;

/// Outcome of one request: logits, or a serving-side error message.
pub type Reply = std::result::Result<Vec<f32>, String>;

/// One queued inference request.  Internal: the only producer is
/// [`PoolClient::submit`], which has already validated the input size.
struct Request {
    /// span id handed out by the pool's tracer at admission
    id: u64,
    /// when admission accepted the request (queue-wait clock)
    submitted: Instant,
    x: Vec<f32>,
    reply: mpsc::Sender<Reply>,
}

/// Upper bound on retained latency samples (~8 MB worst case).
pub const MAX_LATENCY_SAMPLES: usize = 1 << 20;

/// Latency sample store: a ring over the most recent `capacity` service
/// times, so percentiles keep tracking a long-running server instead of
/// freezing on the warm-up era.
#[derive(Clone)]
struct LatencyRing {
    samples: Vec<u64>,
    capacity: usize,
    /// next overwrite position once the ring is full
    head: usize,
}

impl Default for LatencyRing {
    fn default() -> Self {
        LatencyRing::with_capacity(MAX_LATENCY_SAMPLES)
    }
}

impl LatencyRing {
    fn with_capacity(capacity: usize) -> LatencyRing {
        LatencyRing {
            samples: Vec::new(),
            capacity: capacity.max(1),
            head: 0,
        }
    }

    fn push(&mut self, us: u64) {
        if self.samples.len() < self.capacity {
            self.samples.push(us);
        } else {
            self.samples[self.head] = us;
            self.head = (self.head + 1) % self.capacity;
        }
    }

    /// Append another ring's retained samples, oldest first, as if they
    /// had been pushed here (cross-replica aggregation).  `head` is 0
    /// until a ring fills, so `(head + i) % len` is oldest-first in both
    /// regimes.
    fn merge(&mut self, other: &LatencyRing) {
        let n = other.samples.len();
        for i in 0..n {
            self.push(other.samples[(other.head + i) % n]);
        }
    }
}

#[derive(Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub full_batches: AtomicU64,
    pub singles: AtomicU64,
    pub busy_us: AtomicU64,
    /// requests refused by admission control (bounded queue full)
    pub rejected: AtomicU64,
    /// per-request service latency samples (us)
    lat_us: Mutex<LatencyRing>,
    /// per-request queue-wait samples (us), recorded at batch assembly
    queue_us: Mutex<LatencyRing>,
}

/// One lock (copy only) + one sort outside the lock, so the serving
/// threads never stall on a reader.
fn ring_percentiles_ms(ring: &Mutex<LatencyRing>, qs: &[f64]) -> Vec<f64> {
    let raw = ring.lock().unwrap().samples.clone(); // memcpy only
    let mut sorted: Vec<f64> = raw.into_iter().map(|u| u as f64).collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    qs.iter()
        .map(|&q| {
            if sorted.is_empty() {
                0.0
            } else {
                crate::util::stats::quantile_sorted(&sorted, q) / 1e3
            }
        })
        .collect()
}

impl ServerStats {
    /// Record the service latency of a batch covering `n` requests.
    pub fn record_latency(&self, us: u64, n: usize) {
        let mut lat = self.lat_us.lock().unwrap();
        for _ in 0..n {
            lat.push(us);
        }
    }

    /// Record one executed batch of `n` requests against the model's
    /// compiled batch size.
    pub fn record_batch(&self, n: usize, full_batch: usize, us: u64) {
        self.requests.fetch_add(n as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        if n == full_batch {
            self.full_batches.fetch_add(1, Ordering::Relaxed);
        } else if n == 1 {
            self.singles.fetch_add(1, Ordering::Relaxed);
        }
        self.busy_us.fetch_add(us, Ordering::Relaxed);
        self.record_latency(us, n);
    }

    /// Record how long one request sat queued before batch assembly.
    pub fn record_queue_wait(&self, us: u64) {
        self.queue_us.lock().unwrap().push(us);
    }

    /// Latency percentiles in milliseconds, one per requested quantile
    /// (all 0.0 when no samples yet).
    pub fn percentiles_ms(&self, qs: &[f64]) -> Vec<f64> {
        ring_percentiles_ms(&self.lat_us, qs)
    }

    /// Queue-wait percentiles in milliseconds.
    pub fn queue_percentiles_ms(&self, qs: &[f64]) -> Vec<f64> {
        ring_percentiles_ms(&self.queue_us, qs)
    }

    /// Fold another stats instance into this one: counters add, latency
    /// rings append oldest-first — the cross-replica aggregation path
    /// (`other` must not be `self`).
    pub fn merge_from(&self, other: &ServerStats) {
        for (a, b) in [
            (&self.requests, &other.requests),
            (&self.batches, &other.batches),
            (&self.full_batches, &other.full_batches),
            (&self.singles, &other.singles),
            (&self.busy_us, &other.busy_us),
            (&self.rejected, &other.rejected),
        ] {
            a.fetch_add(b.load(Ordering::SeqCst), Ordering::Relaxed);
        }
        let theirs = other.lat_us.lock().unwrap().clone();
        self.lat_us.lock().unwrap().merge(&theirs);
        let theirs = other.queue_us.lock().unwrap().clone();
        self.queue_us.lock().unwrap().merge(&theirs);
    }

    /// Latency percentile in milliseconds (0.0 when no samples yet).
    pub fn percentile_ms(&self, q: f64) -> f64 {
        self.percentiles_ms(&[q])[0]
    }

    pub fn summary(&self) -> String {
        let p = self.percentiles_ms(&[0.50, 0.95, 0.99, 0.999]);
        format!(
            "requests={} batches={} full={} singles={} rejected={} \
             busy={:.1}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms p999={:.2}ms",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.full_batches.load(Ordering::Relaxed),
            self.singles.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.busy_us.load(Ordering::Relaxed) as f64 / 1e3,
            p[0],
            p[1],
            p[2],
            p[3],
        )
    }
}

/// Why intake refused a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionError {
    /// bounded queue at capacity — back off and retry
    Full { depth: usize },
    /// pool shut down
    Closed,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::Full { depth } => write!(
                f,
                "queue full (depth {depth}): request rejected by admission \
                 control"
            ),
            AdmissionError::Closed => write!(f, "server shut down"),
        }
    }
}

impl std::error::Error for AdmissionError {}

struct QueueInner {
    jobs: VecDeque<Request>,
    closed: bool,
}

/// Shared bounded work queue: the single intake point of a pool.
/// `push` applies admission control; `close` is the explicit shutdown
/// signal workers observe even while client handles stay alive.
struct JobQueue {
    inner: Mutex<QueueInner>,
    ready: Condvar,
    depth: usize,
}

impl JobQueue {
    fn with_depth(depth: usize) -> JobQueue {
        JobQueue {
            inner: Mutex::new(QueueInner {
                jobs: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
            depth: depth.max(1),
        }
    }

    /// Enqueue or reject immediately — never blocks, never buffers past
    /// the configured depth.
    fn push(&self, r: Request) -> std::result::Result<(), AdmissionError> {
        let mut q = self.inner.lock().unwrap();
        if q.closed {
            return Err(AdmissionError::Closed);
        }
        if q.jobs.len() >= self.depth {
            return Err(AdmissionError::Full { depth: self.depth });
        }
        q.jobs.push_back(r);
        drop(q);
        self.ready.notify_one();
        Ok(())
    }

    /// Blocking batched pop: waits for at least one job, drains up to
    /// `max`, then tops a partial batch up for at most `window`.  Returns
    /// an empty vec only on shutdown with the queue fully drained.
    fn pop_batch(&self, max: usize, window: Duration) -> Vec<Request> {
        let mut q = self.inner.lock().unwrap();
        loop {
            if !q.jobs.is_empty() {
                break;
            }
            if q.closed {
                return Vec::new();
            }
            q = self.ready.wait(q).unwrap();
        }
        let mut out = Vec::with_capacity(max.min(q.jobs.len()));
        while out.len() < max {
            match q.jobs.pop_front() {
                Some(j) => out.push(j),
                None => break,
            }
        }
        if out.len() < max && !window.is_zero() {
            let deadline = Instant::now() + window;
            while out.len() < max && !q.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) =
                    self.ready.wait_timeout(q, deadline - now).unwrap();
                q = guard;
                while out.len() < max {
                    match q.jobs.pop_front() {
                        Some(j) => out.push(j),
                        None => break,
                    }
                }
                if timeout.timed_out() {
                    break;
                }
            }
        }
        out
    }

    fn close(&self) {
        let mut q = self.inner.lock().unwrap();
        q.closed = true;
        drop(q);
        self.ready.notify_all();
    }
}

/// Observability knobs for one pool (DESIGN.md §11).  All sampling
/// rates use `0 = off` so the defaults cost nothing on the hot path.
#[derive(Clone)]
pub struct ObsConfig {
    /// run every Nth batch through `run_qfwd_profiled` for a per-op
    /// wall-time breakdown (0 = never; steady state stays allocation
    /// free because unprofiled batches collect no rows)
    pub profile_every: u64,
    /// emit every Nth request span to the trace sink (0 = never; span
    /// open/close accounting runs regardless)
    pub trace_sample_every: u64,
    /// JSONL span sink on disk (ignored when `trace_sink` is set)
    pub trace_path: Option<PathBuf>,
    /// explicit span sink (tests hand in memory sinks)
    pub trace_sink: Option<Arc<TraceSink>>,
    /// attach quantization-health telemetry to the backend's
    /// digitization step (engines without hooks silently skip it)
    pub quant_health: bool,
    /// live-sketch stride: every Nth observed activation feeds the
    /// per-layer bottom-k sketch (0 disables live sketching)
    pub sketch_sample_every: u64,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            profile_every: 0,
            trace_sample_every: 0,
            trace_path: None,
            trace_sink: None,
            quant_health: true,
            sketch_sample_every: 31,
        }
    }
}

impl std::fmt::Debug for ObsConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ObsConfig")
            .field("profile_every", &self.profile_every)
            .field("trace_sample_every", &self.trace_sample_every)
            .field("trace_path", &self.trace_path)
            .field("trace_sink", &self.trace_sink.is_some())
            .field("quant_health", &self.quant_health)
            .field("sketch_sample_every", &self.sketch_sample_every)
            .finish()
    }
}

/// Per-pool serving configuration.  `replicas` and `queue_depth` are the
/// scaling knobs; the rest mirrors the calibration pipeline.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    pub backend: BackendKind,
    /// uniform calibration-spec override; `None` serves the manifest's
    /// per-layer specs (the mixed-precision deployment default)
    pub spec: Option<QuantSpec>,
    pub noise_std: f32,
    pub calib_batches: usize,
    /// parallel calibration shards (merged codebooks are bit-identical
    /// to serial, so this is purely a startup-latency knob)
    pub calib_shards: usize,
    /// worker replicas, each owning its own `Backend` instance
    pub replicas: usize,
    /// bounded intake queue depth (admission control threshold)
    pub queue_depth: usize,
    /// how long a worker waits to top up a partial batch
    pub batch_window: Duration,
    /// observability: tracing, profiling, quantization health
    pub obs: ObsConfig,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            backend: BackendKind::Auto,
            spec: None,
            noise_std: 0.0,
            calib_batches: 8,
            calib_shards: 1,
            replicas: 1,
            queue_depth: 256,
            batch_window: Duration::from_millis(2),
            obs: ObsConfig::default(),
        }
    }
}

/// Cloneable intake handle: validates the input size, then submits
/// through the pool's admission-controlled queue.  Holding one does NOT
/// keep the pool alive — shutdown closes the queue underneath it and
/// later submissions fail with [`AdmissionError::Closed`].
#[derive(Clone)]
pub struct PoolClient {
    queue: Arc<JobQueue>,
    stats: Arc<ServerStats>,
    tracer: Arc<RequestTracer>,
    in_elems: usize,
    num_classes: usize,
}

impl PoolClient {
    /// Non-blocking submit under admission control; on acceptance the
    /// receiver yields exactly one [`Reply`].  Rejections (queue full,
    /// shutdown, wrong input size) surface as immediate errors — a
    /// request is never silently dropped.
    pub fn submit(&self, x: Vec<f32>) -> Result<mpsc::Receiver<Reply>> {
        ensure!(
            x.len() == self.in_elems,
            "input has {} elements, model wants {}",
            x.len(),
            self.in_elems
        );
        let (tx, rx) = mpsc::channel();
        // span opens at admission; a refused push rolls it back so
        // rejected requests never count as open spans
        let id = self.tracer.open();
        let req = Request {
            id,
            submitted: Instant::now(),
            x,
            reply: tx,
        };
        match self.queue.push(req) {
            Ok(()) => Ok(rx),
            Err(e) => {
                self.tracer.cancel(id);
                if matches!(e, AdmissionError::Full { .. }) {
                    self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                }
                Err(anyhow::Error::new(e))
            }
        }
    }

    /// Blocking request: submit, then wait for the logits.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        let rx = self.submit(x)?;
        match rx.recv_timeout(Duration::from_secs(120)) {
            Ok(Ok(logits)) => Ok(logits),
            Ok(Err(msg)) => bail!("inference failed: {msg}"),
            Err(_) => bail!("request dropped or timed out"),
        }
    }

    /// Logit vector length of the served model.
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Per-sample input element count of the served model.
    pub fn in_elems(&self) -> usize {
        self.in_elems
    }
}

/// What the coordinator thread reports back once serving can start.
struct PoolReady {
    engine: String,
    in_elems: usize,
    num_classes: usize,
    batch: usize,
    health: Option<Arc<QuantHealth>>,
}

/// One model's serving pool: N replica workers behind a bounded queue.
pub struct ModelPool {
    pub model: String,
    queue: Arc<JobQueue>,
    /// pool-wide aggregate (every worker records here too)
    pub stats: Arc<ServerStats>,
    /// per-replica counters, index = replica id
    pub replica_stats: Vec<Arc<ServerStats>>,
    engine: String,
    in_elems: usize,
    num_classes: usize,
    batch: usize,
    /// request-lifecycle tracer (span accounting + sampled JSONL)
    tracer: Arc<RequestTracer>,
    /// pool-local metrics registry (latency/queue-wait histograms)
    metrics: Arc<MetricsRegistry>,
    /// quantization-health telemetry, when the engine supports hooks
    health: Option<Arc<QuantHealth>>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
}

impl ModelPool {
    /// Start the pool: a coordinator thread loads the backend, calibrates
    /// the per-layer spec'd codebooks on `cfg.calib_batches` batches, spawns
    /// `cfg.replicas - 1` additional workers over [`Backend::replicate`]
    /// clones, then serves as worker 0 until the pool is dropped.
    pub fn start(
        artifacts: std::path::PathBuf,
        model: String,
        cfg: &PoolConfig,
    ) -> Result<ModelPool> {
        let cfg = cfg.clone();
        ensure!(cfg.replicas >= 1, "pool needs at least one replica");
        let queue = Arc::new(JobQueue::with_depth(cfg.queue_depth));
        let stats = Arc::new(ServerStats::default());
        let replica_stats: Vec<Arc<ServerStats>> = (0..cfg.replicas)
            .map(|_| Arc::new(ServerStats::default()))
            .collect();
        let sink = match (&cfg.obs.trace_sink, &cfg.obs.trace_path) {
            (Some(s), _) => Some(s.clone()),
            (None, Some(p)) => Some(TraceSink::file(p)?),
            (None, None) => None,
        };
        let tracer =
            RequestTracer::new(&model, cfg.obs.trace_sample_every, sink);
        let metrics = Arc::new(MetricsRegistry::new());
        // pool-level histograms carry the model label in their
        // registered name so the registry renders them route-scoped
        let ml = escape_label(&model);
        let forward_hist = metrics.histogram(
            &format!("bskmq_forward_latency_ms{{model=\"{ml}\"}}"),
            &Histogram::latency_ms_bounds(),
        );
        let queue_hist = metrics.histogram(
            &format!("bskmq_queue_wait_ms{{model=\"{ml}\"}}"),
            &Histogram::latency_ms_bounds(),
        );
        let (ready_tx, ready_rx) = mpsc::channel::<Result<PoolReady>>();

        let m_name = model.clone();
        let q = queue.clone();
        let st = stats.clone();
        let rst = replica_stats.clone();
        let tracer_w = tracer.clone();
        let handle = std::thread::spawn(move || -> Result<()> {
            // setup: load + calibrate, reporting failure instead of
            // leaving the caller blocked
            let (be, calib, health) =
                match pool_setup(&cfg, &artifacts, &m_name) {
                    Ok(v) => v,
                    Err(e) => {
                        let _ = ready_tx.send(Err(anyhow::anyhow!("{e:#}")));
                        return Err(e);
                    }
                };
            let shared = Arc::new(WorkerShared {
                books: calib.programmed,
                noise_std: cfg.noise_std,
                window: cfg.batch_window,
                profile_every: cfg.obs.profile_every,
                tracer: tracer_w,
                forward_hist,
                queue_hist,
            });
            // replicas 1..N each own a cheap clone of the engine
            let mut workers = Vec::new();
            for (i, mine) in rst.iter().enumerate().skip(1) {
                let rep = match be.replicate() {
                    Ok(b) => b,
                    Err(e) => {
                        let e = e.context(format!(
                            "cannot serve '{m_name}' with {} replicas",
                            cfg.replicas
                        ));
                        let _ = ready_tx.send(Err(anyhow::anyhow!("{e:#}")));
                        q.close();
                        for w in workers {
                            let _ = w.join();
                        }
                        return Err(e);
                    }
                };
                let q = q.clone();
                let st = st.clone();
                let mine = mine.clone();
                let shared = shared.clone();
                workers.push(std::thread::spawn(move || {
                    worker_loop(rep.as_ref(), &shared, &q, i as u32, &mine, &st);
                }));
            }
            let m = be.manifest();
            let _ = ready_tx.send(Ok(PoolReady {
                engine: be.name().to_string(),
                in_elems: m.input_elems(),
                num_classes: m.num_classes,
                batch: m.batch,
                health,
            }));
            // worker 0 serves on the coordinator thread (PJRT handles
            // never cross threads; the native replicas simply live where
            // their work is)
            worker_loop(be.as_ref(), &shared, &q, 0, &rst[0], &st);
            for w in workers {
                let _ = w.join();
            }
            Ok(())
        });

        let ready = match ready_rx.recv() {
            Ok(Ok(r)) => r,
            Ok(Err(e)) => {
                let _ = handle.join();
                return Err(e);
            }
            Err(_) => {
                let _ = handle.join();
                bail!("pool coordinator died during setup");
            }
        };
        Ok(ModelPool {
            model,
            queue,
            stats,
            replica_stats,
            engine: ready.engine,
            in_elems: ready.in_elems,
            num_classes: ready.num_classes,
            batch: ready.batch,
            tracer,
            metrics,
            health: ready.health,
            handle: Some(handle),
        })
    }

    /// Clone-able intake handle for client threads.
    pub fn client(&self) -> PoolClient {
        PoolClient {
            queue: self.queue.clone(),
            stats: self.stats.clone(),
            tracer: self.tracer.clone(),
            in_elems: self.in_elems,
            num_classes: self.num_classes,
        }
    }

    /// Blocking request against this pool.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.client().infer(x)
    }

    /// Execution engine serving this pool ("native", "xla").
    pub fn engine(&self) -> &str {
        &self.engine
    }

    /// Replica count.
    pub fn replicas(&self) -> usize {
        self.replica_stats.len()
    }

    /// Compiled batch size of the served model.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Requests refused by admission control so far.
    pub fn rejected(&self) -> u64 {
        self.stats.rejected.load(Ordering::Relaxed)
    }

    /// Explicit shutdown: close the queue (rejecting new requests), wake
    /// and drain every worker, join them.  Idempotent; also runs on Drop.
    /// Live [`PoolClient`] handles cannot keep the pool alive.
    pub fn shutdown(&mut self) {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Request-lifecycle tracer (span accounting, sampled JSONL sink).
    pub fn tracer(&self) -> &Arc<RequestTracer> {
        &self.tracer
    }

    /// Pool-local metrics registry.
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// Quantization-health telemetry (None when the engine has no
    /// digitization hooks or `obs.quant_health` is off).
    pub fn quant_health(&self) -> Option<&Arc<QuantHealth>> {
        self.health.as_ref()
    }

    /// Machine-readable pool stats (the `stats` protocol command).
    pub fn stats_json(&self) -> String {
        let lat = self.stats.percentiles_ms(&[0.5, 0.95, 0.99, 0.999]);
        let qw = self.stats.queue_percentiles_ms(&[0.5, 0.99]);
        let mut s = format!(
            "{{\"model\":\"{}\",\"engine\":\"{}\",\"replicas\":{},\
             \"queue_depth\":{},\"requests\":{},\"batches\":{},\
             \"full_batches\":{},\"singles\":{},\"rejected\":{},\
             \"busy_ms\":{:.3},\
             \"latency_ms\":{{\"p50\":{:.3},\"p95\":{:.3},\"p99\":{:.3},\
             \"p999\":{:.3}}},\
             \"queue_wait_ms\":{{\"p50\":{:.3},\"p99\":{:.3}}},\
             \"spans\":{{\"opened\":{},\"closed\":{},\"emitted\":{}}},\
             \"per_replica_requests\":[",
            escape_json(&self.model),
            escape_json(&self.engine),
            self.replicas(),
            self.queue.depth,
            self.stats.requests.load(Ordering::SeqCst),
            self.stats.batches.load(Ordering::SeqCst),
            self.stats.full_batches.load(Ordering::SeqCst),
            self.stats.singles.load(Ordering::SeqCst),
            self.stats.rejected.load(Ordering::SeqCst),
            self.stats.busy_us.load(Ordering::SeqCst) as f64 / 1e3,
            lat[0],
            lat[1],
            lat[2],
            lat[3],
            qw[0],
            qw[1],
            self.tracer.opened(),
            self.tracer.closed(),
            self.tracer.emitted(),
        );
        for (i, r) in self.replica_stats.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&r.requests.load(Ordering::SeqCst).to_string());
        }
        s.push_str("]}");
        s
    }

    /// Render this pool's Prometheus series into `w` (the `metrics`
    /// protocol command aggregates every pool through one writer).
    pub fn render_prometheus(&self, w: &mut PromWriter) {
        let l = format!("model=\"{}\"", escape_label(&self.model));
        w.family("bskmq_requests_total", "counter", "requests served");
        w.raw_sample(
            "bskmq_requests_total",
            &l,
            self.stats.requests.load(Ordering::SeqCst) as f64,
        );
        w.family(
            "bskmq_rejected_total",
            "counter",
            "requests refused by admission control",
        );
        w.raw_sample(
            "bskmq_rejected_total",
            &l,
            self.stats.rejected.load(Ordering::SeqCst) as f64,
        );
        w.family("bskmq_batches_total", "counter", "executed batches");
        w.raw_sample(
            "bskmq_batches_total",
            &l,
            self.stats.batches.load(Ordering::SeqCst) as f64,
        );
        let qs = [0.5, 0.95, 0.99, 0.999];
        let lat = self.stats.percentiles_ms(&qs);
        let qw = self.stats.queue_percentiles_ms(&qs);
        w.family(
            "bskmq_latency_ms",
            "gauge",
            "service latency quantiles (ms)",
        );
        w.family(
            "bskmq_queue_wait_quantile_ms",
            "gauge",
            "queue-wait quantiles (ms)",
        );
        for (i, q) in qs.iter().enumerate() {
            w.raw_sample(
                "bskmq_latency_ms",
                &format!("{l},quantile=\"{q}\""),
                lat[i],
            );
            w.raw_sample(
                "bskmq_queue_wait_quantile_ms",
                &format!("{l},quantile=\"{q}\""),
                qw[i],
            );
        }
        w.family(
            "bskmq_replica_requests_total",
            "counter",
            "requests per replica",
        );
        for (i, r) in self.replica_stats.iter().enumerate() {
            w.raw_sample(
                "bskmq_replica_requests_total",
                &format!("{l},replica=\"{i}\""),
                r.requests.load(Ordering::SeqCst) as f64,
            );
        }
        w.family(
            "bskmq_spans_opened_total",
            "counter",
            "request spans opened at admission",
        );
        w.raw_sample("bskmq_spans_opened_total", &l, self.tracer.opened() as f64);
        w.family(
            "bskmq_spans_closed_total",
            "counter",
            "request spans closed after reply",
        );
        w.raw_sample("bskmq_spans_closed_total", &l, self.tracer.closed() as f64);
        self.metrics.render(w);
        if let Some(h) = &self.health {
            h.render(w, &self.model);
        }
    }

    /// Pool summary: aggregate line plus one line per replica.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} [{} backend, {} replica(s), queue depth {}]\n  all: {}",
            self.model,
            self.engine,
            self.replicas(),
            self.queue.depth,
            self.stats.summary()
        );
        for (i, r) in self.replica_stats.iter().enumerate() {
            s.push_str(&format!("\n  r{i}:  {}", r.summary()));
        }
        s
    }
}

impl Drop for ModelPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Load + calibrate one model for a pool (runs on the coordinator
/// thread so PJRT-style engines never cross threads).  Per-layer specs
/// come from the manifest unless `cfg.spec` overrides them uniformly;
/// specs carrying `weight_bits` quantize the weights *first* and then
/// calibrate on the quantized-weight backend (Algorithm 1 runs on the
/// deployed macro, not a float simulator).
fn pool_setup(
    cfg: &PoolConfig,
    artifacts: &std::path::Path,
    model: &str,
) -> Result<(Box<dyn Backend>, CalibrationResult, Option<Arc<QuantHealth>>)> {
    let be = crate::backend::load(cfg.backend, artifacts, model)?;
    let data = ModelData::load(artifacts, model)?;
    let specs = match cfg.spec {
        Some(s) => s.per_layer(be.manifest().nq()),
        None => be.manifest().layer_specs(),
    };
    let mut be: Box<dyn Backend> =
        if specs.iter().any(|s| s.weight_bits.is_some()) {
            PtqEvaluator::new(be.as_ref()).quantize_weights_spec(&specs)?
        } else {
            be
        };
    let calib = Calibrator::with_specs(be.as_ref(), specs)
        .calibrate_sharded(&data, cfg.calib_batches, cfg.calib_shards)?;
    // attach quant-health BEFORE replicate(): replicas clone the engine
    // and share the telemetry Arc, so the pool aggregates one view
    let health = if cfg.obs.quant_health {
        let names: Vec<String> = be
            .manifest()
            .qlayers
            .iter()
            .map(|ql| ql.name.clone())
            .collect();
        let h = Arc::new(QuantHealth::new(
            &names,
            &calib.nl_books,
            Some(&calib.sketches),
            cfg.obs.sketch_sample_every,
        ));
        if be.attach_quant_health(h.clone()) {
            Some(h)
        } else {
            None
        }
    } else {
        None
    };
    Ok((be, calib, health))
}

/// Immutable state every worker replica shares: the programmed
/// codebooks plus the pool's observability handles.
struct WorkerShared {
    books: ProgrammedCodebooks,
    noise_std: f32,
    window: Duration,
    /// profile every Nth batch through `run_qfwd_profiled` (0 = never)
    profile_every: u64,
    tracer: Arc<RequestTracer>,
    forward_hist: Arc<Histogram>,
    queue_hist: Arc<Histogram>,
}

/// One worker replica: pop a batch, execute, reply, repeat until the
/// queue closes and drains.  Backend failures answer the affected batch
/// with errors and keep the worker alive.
fn worker_loop(
    backend: &dyn Backend,
    sh: &WorkerShared,
    queue: &JobQueue,
    replica: u32,
    mine: &ServerStats,
    global: &ServerStats,
) {
    let m = backend.manifest();
    let batch = m.batch;
    let classes = m.num_classes;
    let in_elems = m.input_elems();
    let mut seed = replica.wrapping_mul(0x9E37);
    let mut batches_done: u64 = 0;
    loop {
        let pending = queue.pop_batch(batch, sh.window);
        if pending.is_empty() {
            return; // shutdown signal observed, queue drained
        }
        let t0 = Instant::now();
        seed = seed.wrapping_add(1);
        let n = pending.len();
        // queue wait is measured at batch assembly, per request
        let mut queue_waits: Vec<u64> = Vec::with_capacity(n);
        for r in &pending {
            let us = r.submitted.elapsed().as_micros() as u64;
            sh.queue_hist.observe(us as f64 / 1e3);
            mine.record_queue_wait(us);
            global.record_queue_wait(us);
            queue_waits.push(us);
        }
        // exact-size execution when the backend can (native: always;
        // xla: full batch or the batch-1 graph); otherwise pad up to the
        // compiled batch
        let run_n = if backend.supports_batch(n) { n } else { batch };
        let mut x = Vec::with_capacity(run_n * in_elems);
        for r in &pending {
            x.extend_from_slice(&r.x);
        }
        for _ in n..run_n {
            x.extend_from_slice(&pending[0].x);
        }
        batches_done += 1;
        // sampled per-op profiling: unprofiled batches collect no rows,
        // so the steady state allocates nothing for tracing
        let profiled =
            sh.profile_every > 0 && batches_done % sh.profile_every == 0;
        let (result, ops) = if profiled {
            match backend.run_qfwd_profiled(&x, &sh.books, sh.noise_std, seed)
            {
                Ok((logits, timings)) => (
                    Ok(logits),
                    timings
                        .into_iter()
                        .map(|t| (t.name, t.nanos as u64))
                        .collect::<Vec<(String, u64)>>(),
                ),
                Err(e) => (Err(e), Vec::new()),
            }
        } else {
            (
                backend.run_qfwd(&x, &sh.books, sh.noise_std, seed),
                Vec::new(),
            )
        };
        // record BEFORE replying: a client that just received its answer
        // must already see itself in the counters
        let forward_us = t0.elapsed().as_micros() as u64;
        mine.record_batch(n, batch, forward_us);
        global.record_batch(n, batch, forward_us);
        sh.forward_hist.observe(forward_us as f64 / 1e3);
        match result {
            Ok(logits) => {
                for (i, r) in pending.iter().enumerate() {
                    let _ = r
                        .reply
                        .send(Ok(logits[i * classes..(i + 1) * classes].to_vec()));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                eprintln!("worker r{replica}: batch of {n} failed: {msg}");
                for r in &pending {
                    let _ = r.reply.send(Err(msg.clone()));
                }
            }
        }
        // close spans AFTER the replies: reply_us covers the send
        let reply_us =
            (t0.elapsed().as_micros() as u64).saturating_sub(forward_us);
        for (i, r) in pending.iter().enumerate() {
            sh.tracer.close(r.id, || Span {
                id: 0,
                model: String::new(),
                replica,
                batch_n: n,
                queue_us: queue_waits[i],
                forward_us,
                reply_us,
                ops: ops.clone(),
            });
        }
    }
}

/// Several models served from one process, each behind its own
/// [`ModelPool`].  Routing is by model name; the first model is the
/// default route.
pub struct ModelRegistry {
    pools: Vec<ModelPool>,
}

impl ModelRegistry {
    /// Load + calibrate every model sequentially; any failure aborts the
    /// whole registry (fail fast beats serving a partial fleet silently).
    pub fn start(
        artifacts: &std::path::Path,
        models: &[String],
        cfg: &PoolConfig,
    ) -> Result<ModelRegistry> {
        ensure!(!models.is_empty(), "registry needs at least one model");
        let mut pools: Vec<ModelPool> = Vec::with_capacity(models.len());
        for name in models {
            ensure!(
                pools.iter().all(|p| &p.model != name),
                "model '{name}' listed twice"
            );
            pools.push(ModelPool::start(
                artifacts.to_path_buf(),
                name.clone(),
                cfg,
            )?);
        }
        Ok(ModelRegistry { pools })
    }

    /// Pool by model name.
    pub fn get(&self, model: &str) -> Option<&ModelPool> {
        self.pools.iter().find(|p| p.model == model)
    }

    /// The default route (first model listed).
    pub fn default_pool(&self) -> &ModelPool {
        &self.pools[0]
    }

    pub fn pools(&self) -> &[ModelPool] {
        &self.pools
    }

    pub fn models(&self) -> Vec<&str> {
        self.pools.iter().map(|p| p.model.as_str()).collect()
    }

    /// Multi-line summary: per-pool aggregate + per-replica stats.
    pub fn summary(&self) -> String {
        let lines: Vec<String> =
            self.pools.iter().map(|p| p.summary()).collect();
        lines.join("\n")
    }

    /// Machine-readable stats over every pool (the `stats` command).
    pub fn stats_json(&self) -> String {
        let items: Vec<String> =
            self.pools.iter().map(|p| p.stats_json()).collect();
        format!("{{\"pools\":[{}]}}", items.join(","))
    }

    /// Prometheus text exposition over every pool (the `metrics`
    /// command).
    pub fn prometheus(&self) -> String {
        let mut w = PromWriter::new();
        for p in &self.pools {
            p.render_prometheus(&mut w);
        }
        w.finish()
    }
}

/// Single-model compatibility front over [`ModelPool`] (the pre-pool
/// API).  `start` keeps its historical signature; replica count and
/// queue depth come from [`PoolConfig::default`] unless the pool API is
/// used directly.
pub struct InferenceServer {
    pool: ModelPool,
    pub stats: Arc<ServerStats>,
}

impl InferenceServer {
    /// Start a one-model, default-config pool: load the selected
    /// backend, calibrate on `calib_batches` batches — with `spec` as a
    /// uniform per-layer override, or the manifest's specs when `None` —
    /// then serve until dropped.
    pub fn start(
        artifacts: std::path::PathBuf,
        model: String,
        backend: BackendKind,
        spec: Option<QuantSpec>,
        noise_std: f32,
        calib_batches: usize,
    ) -> Result<InferenceServer> {
        let cfg = PoolConfig {
            backend,
            spec,
            noise_std,
            calib_batches,
            ..PoolConfig::default()
        };
        let pool = ModelPool::start(artifacts, model, &cfg)?;
        eprintln!("inference server ready ({} backend)", pool.engine());
        let stats = pool.stats.clone();
        Ok(InferenceServer { pool, stats })
    }

    /// Blocking request: returns the logits for one input.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.pool.infer(x)
    }

    /// Clone-able intake handle for concurrent client threads.
    pub fn client(&self) -> PoolClient {
        self.pool.client()
    }

    /// The underlying pool (replica stats, admission counters).
    pub fn pool(&self) -> &ModelPool {
        &self.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let st = ServerStats::default();
        assert_eq!(st.percentile_ms(0.5), 0.0);
        for us in [1000u64, 2000, 3000, 4000] {
            st.record_latency(us, 1);
        }
        assert!((st.percentile_ms(0.5) - 2.5).abs() < 1e-9);
        assert!(st.percentile_ms(0.99) <= 4.0);
        let s = st.summary();
        assert!(s.contains("p50="), "{s}");
        assert!(s.contains("p99="), "{s}");
        assert!(s.contains("rejected=0"), "{s}");
    }

    /// Empty ring: every percentile is 0.0, for any quantile list.
    #[test]
    fn empty_ring_percentiles_are_zero() {
        let st = ServerStats::default();
        assert_eq!(
            st.percentiles_ms(&[0.0, 0.25, 0.5, 0.95, 1.0]),
            vec![0.0; 5]
        );
        assert_eq!(st.percentiles_ms(&[]), Vec::<f64>::new());
    }

    /// Small-capacity ring against a naive keep-the-last-K reference:
    /// wraparound must retain exactly the most recent `capacity` samples.
    #[test]
    fn ring_wraparound_matches_naive_reference() {
        let cap = 8;
        let mut ring = LatencyRing::with_capacity(cap);
        let feed: Vec<u64> = (0..31).map(|i| (i * 37 + 5) % 97).collect();
        for &v in &feed {
            ring.push(v);
        }
        assert_eq!(ring.samples.len(), cap, "ring exceeded its capacity");
        let mut got = ring.samples.clone();
        got.sort_unstable();
        let mut want: Vec<u64> = feed[feed.len() - cap..].to_vec();
        want.sort_unstable();
        assert_eq!(got, want, "ring lost or kept the wrong samples");
    }

    /// Full-size ring: push past MAX_LATENCY_SAMPLES and check the
    /// percentiles against a sort-everything reference over the retained
    /// window (the last MAX samples).
    #[test]
    fn ring_wraps_past_max_and_percentiles_track_recent_window() {
        let st = ServerStats::default();
        let extra = 1234usize;
        let total = MAX_LATENCY_SAMPLES + extra;
        for i in 0..total {
            st.record_latency(i as u64, 1);
        }
        assert_eq!(
            st.lat_us.lock().unwrap().samples.len(),
            MAX_LATENCY_SAMPLES,
            "ring grew past its bound"
        );
        // retained window = values extra..total (the most recent MAX)
        let window: Vec<f64> =
            (extra..total).map(|v| v as f64).collect(); // already sorted
        let qs = [0.0, 0.01, 0.5, 0.95, 1.0];
        let got = st.percentiles_ms(&qs); // one sort for all quantiles
        for (q, got) in qs.iter().zip(got) {
            let want =
                crate::util::stats::quantile_sorted(&window, *q) / 1e3;
            assert!(
                (got - want).abs() < 1e-6,
                "q={q}: got {got} want {want}"
            );
        }
    }

    /// Bounded queue semantics: admission rejection at depth, explicit
    /// close rejects producers and releases consumers.
    #[test]
    fn job_queue_admission_and_close() {
        let q = JobQueue::with_depth(2);
        let mk = || {
            let (tx, rx) = mpsc::channel();
            (
                Request {
                    id: 0,
                    submitted: Instant::now(),
                    x: vec![0.0],
                    reply: tx,
                },
                rx,
            )
        };
        let (r1, _k1) = mk();
        let (r2, _k2) = mk();
        let (r3, _k3) = mk();
        assert!(q.push(r1).is_ok());
        assert!(q.push(r2).is_ok());
        assert_eq!(
            q.push(r3).unwrap_err(),
            AdmissionError::Full { depth: 2 }
        );
        let got = q.pop_batch(8, Duration::ZERO);
        assert_eq!(got.len(), 2, "drain returns everything queued");
        q.close();
        let (r4, _k4) = mk();
        assert_eq!(q.push(r4).unwrap_err(), AdmissionError::Closed);
        assert!(
            q.pop_batch(8, Duration::from_millis(50)).is_empty(),
            "closed+empty queue must release consumers immediately"
        );
    }
}
