//! Batched inference server: the request path of the deployed system.
//!
//! A dedicated inference thread owns the execution backend and the
//! calibrated model (PJRT handles never cross threads; the native backend
//! simply lives where its work is); intake happens over an mpsc channel
//! from any number of client threads (or the TCP front in `main.rs`).  A
//! dynamic batcher groups queued requests: full batches go through the
//! batch-32 path, stragglers through whatever smaller batch the backend
//! supports (the native backend runs any size exactly; the XLA backend
//! falls back to its batch-1 graph or padding) — the vLLM-style policy
//! scaled to this testbed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::backend::{Backend, BackendKind};
use crate::coordinator::calibrate::Calibrator;
use crate::data::dataset::ModelData;
use crate::quant::Method;

pub struct Request {
    pub x: Vec<f32>,
    pub reply: mpsc::Sender<Vec<f32>>,
}

/// Upper bound on retained latency samples (~8 MB worst case).
const MAX_LATENCY_SAMPLES: usize = 1 << 20;

/// Latency sample store: a ring over the most recent
/// [`MAX_LATENCY_SAMPLES`] service times, so percentiles keep tracking a
/// long-running server instead of freezing on the warm-up era.
#[derive(Default)]
struct LatencyRing {
    samples: Vec<u64>,
    /// next overwrite position once the ring is full
    head: usize,
}

impl LatencyRing {
    fn push(&mut self, us: u64) {
        if self.samples.len() < MAX_LATENCY_SAMPLES {
            self.samples.push(us);
        } else {
            self.samples[self.head] = us;
            self.head = (self.head + 1) % MAX_LATENCY_SAMPLES;
        }
    }
}

#[derive(Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub full_batches: AtomicU64,
    pub singles: AtomicU64,
    pub busy_us: AtomicU64,
    /// per-request service latency samples (us)
    lat_us: Mutex<LatencyRing>,
}

impl ServerStats {
    /// Record the service latency of a batch covering `n` requests.
    pub fn record_latency(&self, us: u64, n: usize) {
        let mut lat = self.lat_us.lock().unwrap();
        for _ in 0..n {
            lat.push(us);
        }
    }

    /// Latency percentiles in milliseconds, one per requested quantile
    /// (all 0.0 when no samples yet).  One lock (copy only) + one sort
    /// outside the lock, so the serving thread never stalls on a reader.
    pub fn percentiles_ms(&self, qs: &[f64]) -> Vec<f64> {
        let raw = self.lat_us.lock().unwrap().samples.clone(); // memcpy only
        let mut sorted: Vec<f64> = raw.into_iter().map(|u| u as f64).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        qs.iter()
            .map(|&q| {
                if sorted.is_empty() {
                    0.0
                } else {
                    crate::util::stats::quantile_sorted(&sorted, q) / 1e3
                }
            })
            .collect()
    }

    /// Latency percentile in milliseconds (0.0 when no samples yet).
    pub fn percentile_ms(&self, q: f64) -> f64 {
        self.percentiles_ms(&[q])[0]
    }

    pub fn summary(&self) -> String {
        let p = self.percentiles_ms(&[0.50, 0.95, 0.99]);
        format!(
            "requests={} batches={} full={} singles={} busy={:.1}ms \
             p50={:.2}ms p95={:.2}ms p99={:.2}ms",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.full_batches.load(Ordering::Relaxed),
            self.singles.load(Ordering::Relaxed),
            self.busy_us.load(Ordering::Relaxed) as f64 / 1e3,
            p[0],
            p[1],
            p[2],
        )
    }
}

pub struct InferenceServer {
    tx: mpsc::Sender<Request>,
    pub stats: Arc<ServerStats>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
}

impl InferenceServer {
    /// Start the inference thread: load the selected backend, calibrate
    /// `bits`-bit codebooks on `calib_batches`, then serve until dropped.
    pub fn start(
        artifacts: std::path::PathBuf,
        model: String,
        backend: BackendKind,
        method: Method,
        bits: u32,
        noise_std: f32,
        calib_batches: usize,
    ) -> Result<InferenceServer> {
        let (tx, rx) = mpsc::channel::<Request>();
        let stats = Arc::new(ServerStats::default());
        let st = stats.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<String>>();
        let handle = std::thread::spawn(move || -> Result<()> {
            let setup = (|| -> Result<(Box<dyn Backend>, ModelData)> {
                let be = crate::backend::load(backend, &artifacts, &model)?;
                let data = ModelData::load(&artifacts, &model)?;
                Ok((be, data))
            })();
            let (be, data) = match setup {
                Ok(v) => v,
                Err(e) => {
                    let _ = ready_tx.send(Err(anyhow::anyhow!("{e}")));
                    return Err(e);
                }
            };
            let calib = match Calibrator::new(be.as_ref(), method, bits)
                .calibrate(&data, calib_batches)
            {
                Ok(c) => c,
                Err(e) => {
                    let _ = ready_tx.send(Err(anyhow::anyhow!("{e}")));
                    return Err(e);
                }
            };
            let _ = ready_tx.send(Ok(be.name().to_string()));
            serve_loop(be.as_ref(), &calib.programmed, noise_std, rx, &st)
        });
        let engine = ready_rx
            .recv()
            .context("inference thread died during setup")??;
        eprintln!("inference server ready ({engine} backend)");
        Ok(InferenceServer {
            tx,
            stats,
            handle: Some(handle),
        })
    }

    /// Blocking request: returns the logits for one input.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request { x, reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        reply_rx
            .recv_timeout(Duration::from_secs(120))
            .context("request dropped (bad input size?) or timed out")
    }

    /// Clone the intake handle for concurrent client threads.
    pub fn client(&self) -> mpsc::Sender<Request> {
        self.tx.clone()
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        // closing the channel ends the serve loop
        let (tx, _) = mpsc::channel();
        let _ = std::mem::replace(&mut self.tx, tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_loop(
    backend: &dyn Backend,
    books: &crate::backend::ProgrammedCodebooks,
    noise_std: f32,
    rx: mpsc::Receiver<Request>,
    stats: &ServerStats,
) -> Result<()> {
    let batch = backend.manifest().batch;
    let classes = backend.manifest().num_classes;
    let in_elems = backend.manifest().input_elems();
    let mut seed = 1u32;
    loop {
        // block for the first request, then drain up to a full batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return Ok(()), // all senders dropped
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + Duration::from_millis(2);
        while pending.len() < batch {
            match rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }
        // drop wrong-sized requests (their reply sender drops, so the
        // client sees an immediate error) instead of killing the server
        pending.retain(|r| {
            let ok = r.x.len() == in_elems;
            if !ok {
                eprintln!(
                    "dropping request with {} elements (model wants {in_elems})",
                    r.x.len()
                );
            }
            ok
        });
        if pending.is_empty() {
            continue;
        }
        let t0 = Instant::now();
        seed = seed.wrapping_add(1);
        let n = pending.len();
        // exact-size execution when the backend can (native: always;
        // xla: full batch or the batch-1 graph); otherwise pad up to the
        // compiled batch
        let run_n = if backend.supports_batch(n) { n } else { batch };
        let mut x = Vec::with_capacity(run_n * in_elems);
        for r in &pending {
            x.extend_from_slice(&r.x);
        }
        for _ in n..run_n {
            x.extend_from_slice(&pending[0].x);
        }
        let logits = backend.run_qfwd(&x, books, noise_std, seed)?;
        for (i, r) in pending.iter().enumerate() {
            let _ = r.reply.send(logits[i * classes..(i + 1) * classes].to_vec());
        }
        if n == batch {
            stats.full_batches.fetch_add(1, Ordering::Relaxed);
        } else if n == 1 {
            stats.singles.fetch_add(1, Ordering::Relaxed);
        }
        let elapsed_us = t0.elapsed().as_micros() as u64;
        stats.requests.fetch_add(n as u64, Ordering::Relaxed);
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.busy_us.fetch_add(elapsed_us, Ordering::Relaxed);
        stats.record_latency(elapsed_us, n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let st = ServerStats::default();
        assert_eq!(st.percentile_ms(0.5), 0.0);
        for us in [1000u64, 2000, 3000, 4000] {
            st.record_latency(us, 1);
        }
        assert!((st.percentile_ms(0.5) - 2.5).abs() < 1e-9);
        assert!(st.percentile_ms(0.99) <= 4.0);
        let s = st.summary();
        assert!(s.contains("p50="), "{s}");
        assert!(s.contains("p99="), "{s}");
    }
}
