//! Online shadow recalibration with zero-downtime codebook hot-swap
//! (DESIGN.md §15; retires ROADMAP item 3).
//!
//! The paper's Algorithm 1 is a one-shot offline fit, but its premise —
//! ReLU/clamping piling activation mass onto boundary values — holds
//! for *live* traffic too, and live traffic drifts.  This module turns
//! the offline fit into a production capability, reusing the two halves
//! built for it: the mergeable streaming [`QuantEstimator`]s (PR 5) and
//! the per-qlayer [`QuantHealth`] sketch-divergence signal (PR 6).
//!
//! Per served pool, three pieces cooperate:
//!
//! * a [`ShadowTap`] on the worker batch path clones every
//!   `sample_every`-th admitted request's input into a bounded buffer —
//!   a full buffer drops the sample, never slowing a reply;
//! * a controller thread drains the tap, runs full batches through its
//!   **own** [`Backend::replicate`] clone in collect mode (so the float
//!   forward feeding the estimators never touches the serving replicas
//!   or pollutes live telemetry), and accumulates fresh per-layer
//!   estimator state plus a [`ValueSketch`] of the window;
//! * a [`DriftDetector`] watches the max-over-layers
//!   [`QuantHealth::divergence`] each tick.  Past the threshold for
//!   `trigger_checks` consecutive ticks it restarts the shadow window
//!   (the refit must fit *post*-drift traffic, not a straddling
//!   mixture); once the window passes the min-observations gate it
//!   refits via [`finish_codebooks`] — the exact spec-driven path the
//!   deployed books came from — and publishes through
//!   [`CodebookCell::swap`].  Workers snapshot the cell once per batch,
//!   so every reply is produced entirely under one codebook generation:
//!   no drops, no reordering, no mixing.  After a swap the detector
//!   holds in cooldown until drift falls below the hysteresis low
//!   watermark, preventing refit storms while the fresh baseline
//!   settles.
//!
//! Physically this models reprogramming the NL-ADC reference ladder at
//! runtime — reconfigurable reference programming is exactly what the
//! IMC ADC literature (PIM-QAT, approximate-ADC IMC) says the hardware
//! supports.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::backend::{Backend, CodebookCell};
use crate::coordinator::calibrate::finish_codebooks;
use crate::obs::quant_health::{health_sketch, QuantHealth};
use crate::quant::estimator::{estimator_for, QuantEstimator};
use crate::quant::sketch::ValueSketch;
use crate::quant::QuantSpec;

/// Knobs for one pool's shadow recalibration controller
/// (`bskmq serve --recalib [--recalib-sample N] [--drift-threshold X]`).
#[derive(Clone, Debug)]
pub struct RecalibConfig {
    /// Shadow-sample every Nth executed request's input (>= 1).
    pub sample_every: u64,
    /// Max-over-layers normalized decile drift that arms a refit.
    pub drift_threshold: f64,
    /// Low-watermark factor in `(0, 1]`: the detector re-arms (and a
    /// collecting window is abandoned as a false alarm) only once drift
    /// falls below `drift_threshold * hysteresis`.
    pub hysteresis: f64,
    /// Minimum samples every layer's shadow estimator must hold before
    /// a refit fires (the min-observations gate).
    pub min_observations: u64,
    /// Consecutive over-threshold supervisor ticks required to trigger
    /// collection (debounces a single noisy divergence read).
    pub trigger_checks: u32,
    /// Supervisor tick interval.
    pub check_interval: Duration,
}

impl Default for RecalibConfig {
    fn default() -> RecalibConfig {
        RecalibConfig {
            sample_every: 16,
            drift_threshold: 0.25,
            hysteresis: 0.5,
            min_observations: 256,
            trigger_checks: 2,
            check_interval: Duration::from_millis(50),
        }
    }
}

impl RecalibConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.sample_every >= 1, "--recalib-sample must be >= 1");
        ensure!(
            self.drift_threshold.is_finite() && self.drift_threshold > 0.0,
            "--drift-threshold must be a positive finite number"
        );
        ensure!(
            self.hysteresis > 0.0 && self.hysteresis <= 1.0,
            "recalib hysteresis must be in (0, 1]"
        );
        ensure!(self.trigger_checks >= 1, "recalib trigger_checks must be >= 1");
        ensure!(
            self.check_interval > Duration::ZERO,
            "recalib check_interval must be positive"
        );
        Ok(())
    }
}

/// Counters the controller maintains; exposed through `stats` JSON and
/// the Prometheus page (`bskmq_recalib_*`).
#[derive(Default)]
pub struct RecalibStats {
    /// Completed hot-swaps.
    pub swaps: AtomicU64,
    /// Refit attempts (successes + failures).
    pub refits: AtomicU64,
    /// Refits that failed (the old generation kept serving).
    pub refit_errors: AtomicU64,
    /// Wall nanos of the last successful refit + swap.
    pub last_refit_ns: AtomicU64,
    /// Cumulative refit + swap nanos.
    pub refit_ns_total: AtomicU64,
    /// Request inputs diverted into the shadow buffer.
    pub sampled: AtomicU64,
    /// Sampled inputs dropped because the shadow buffer was full.
    pub dropped: AtomicU64,
    /// Full collect batches the shadow replica has run.
    pub shadow_batches: AtomicU64,
    /// Queue depth observed at the instant of the last swap.
    pub inflight_at_swap: AtomicU64,
    /// Last max-over-layers drift the supervisor read (f64 bits).
    drift_bits: AtomicU64,
}

impl RecalibStats {
    pub fn set_drift(&self, d: f64) {
        self.drift_bits.store(d.to_bits(), Ordering::Relaxed);
    }

    pub fn drift(&self) -> f64 {
        f64::from_bits(self.drift_bits.load(Ordering::Relaxed))
    }
}

/// Worker-side sampling tap: every `sample_every`-th executed request's
/// input is cloned into a bounded buffer the controller drains.  The
/// serving path only ever pays a clone + push; when the buffer is full
/// the sample is dropped and counted, never blocking a reply.
pub struct ShadowTap {
    sample_every: u64,
    counter: AtomicU64,
    cap: usize,
    buf: Mutex<VecDeque<Vec<f32>>>,
    stats: Arc<RecalibStats>,
}

impl ShadowTap {
    pub fn new(sample_every: u64, cap: usize, stats: Arc<RecalibStats>) -> ShadowTap {
        ShadowTap {
            sample_every: sample_every.max(1),
            counter: AtomicU64::new(0),
            cap: cap.max(1),
            buf: Mutex::new(VecDeque::new()),
            stats,
        }
    }

    /// Called by workers once per executed (non-shed) request.
    pub fn maybe_sample(&self, x: &[f32]) {
        let k = self.counter.fetch_add(1, Ordering::Relaxed);
        if k % self.sample_every != 0 {
            return;
        }
        let mut b = self.buf.lock().unwrap();
        if b.len() >= self.cap {
            self.stats.dropped.fetch_add(1, Ordering::Relaxed);
        } else {
            b.push_back(x.to_vec());
            self.stats.sampled.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Take everything buffered (controller side).
    pub fn drain(&self) -> Vec<Vec<f32>> {
        self.buf.lock().unwrap().drain(..).collect()
    }
}

/// Detector lifecycle (see [`DriftDetector::observe`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftState {
    /// Watching for sustained over-threshold drift.
    Armed,
    /// Drift confirmed; accumulating a post-drift shadow window.
    Collecting,
    /// Swap done; waiting for drift to fall below the low watermark.
    Cooldown,
}

/// What the controller should do after one supervisor tick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftAction {
    /// Nothing this tick.
    Hold,
    /// Threshold crossed for `trigger_checks` consecutive ticks: restart
    /// the shadow window so the refit sees post-drift traffic only.
    StartCollecting,
    /// The window passed the min-observations gate: refit + swap now.
    Refit,
    /// Drift subsided before the window filled (false alarm): discard
    /// the window and re-arm.
    Abandon,
}

/// Hysteresis state machine over the drift signal.  Pure and
/// synchronous — the controller owns one and feeds it a
/// `(drift, window_met)` pair per tick — so the trigger/cooldown
/// semantics are unit-testable without a pool.
pub struct DriftDetector {
    threshold: f64,
    low_watermark: f64,
    trigger_checks: u32,
    over: u32,
    state: DriftState,
}

impl DriftDetector {
    pub fn new(cfg: &RecalibConfig) -> DriftDetector {
        DriftDetector {
            threshold: cfg.drift_threshold,
            low_watermark: cfg.drift_threshold * cfg.hysteresis,
            trigger_checks: cfg.trigger_checks.max(1),
            over: 0,
            state: DriftState::Armed,
        }
    }

    pub fn state(&self) -> DriftState {
        self.state
    }

    /// One supervisor tick: `drift` is the current max-over-layers
    /// divergence, `window_met` whether the shadow window satisfies the
    /// min-observations gate.
    pub fn observe(&mut self, drift: f64, window_met: bool) -> DriftAction {
        match self.state {
            DriftState::Armed => {
                if drift >= self.threshold {
                    self.over += 1;
                    if self.over >= self.trigger_checks {
                        self.over = 0;
                        self.state = DriftState::Collecting;
                        return DriftAction::StartCollecting;
                    }
                } else {
                    // consecutive means consecutive: any sub-threshold
                    // tick restarts the debounce count
                    self.over = 0;
                }
                DriftAction::Hold
            }
            DriftState::Collecting => {
                if drift < self.low_watermark {
                    self.state = DriftState::Armed;
                    return DriftAction::Abandon;
                }
                if window_met {
                    DriftAction::Refit
                } else {
                    DriftAction::Hold
                }
            }
            DriftState::Cooldown => {
                // re-arm only below the LOW watermark, not the trigger
                // threshold — drift hovering between the two must not
                // bounce the detector straight back into a refit
                if drift < self.low_watermark {
                    self.state = DriftState::Armed;
                }
                DriftAction::Hold
            }
        }
    }

    /// A refit + swap completed: hold in cooldown until the post-swap
    /// drift (now measured against the fresh baseline) subsides.
    pub fn swapped(&mut self) {
        self.state = DriftState::Cooldown;
        self.over = 0;
    }
}

/// The per-pool recalibration handle: configuration plus the pieces the
/// pool, the workers, and the controller all share.
pub struct RecalibShared {
    pub cfg: RecalibConfig,
    pub stats: Arc<RecalibStats>,
    pub tap: Arc<ShadowTap>,
    pub cell: Arc<CodebookCell>,
}

/// One shadow window: fresh estimator state accumulated since the last
/// (re)start, plus the sketches the next baseline will diff against.
struct ShadowWindow {
    estimators: Vec<Box<dyn QuantEstimator>>,
    tile_max: Vec<f64>,
    sketches: Vec<ValueSketch>,
    batches: u64,
}

impl ShadowWindow {
    fn new(specs: &[QuantSpec]) -> ShadowWindow {
        let nq = specs.len();
        ShadowWindow {
            estimators: specs.iter().map(estimator_for).collect(),
            tile_max: vec![0.0; nq],
            sketches: (0..nq).map(|_| health_sketch()).collect(),
            batches: 0,
        }
    }

    /// The min-observations gate: the *least*-fed layer's sample count.
    fn min_observed(&self) -> u64 {
        self.estimators
            .iter()
            .map(|e| e.n_observed() as u64)
            .min()
            .unwrap_or(0)
    }
}

/// Handle to one pool's controller thread; stops and joins on
/// [`RecalibController::stop`] or drop (worst-case latency one
/// `check_interval` tick).
pub struct RecalibController {
    handle: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
}

impl RecalibController {
    /// Spawn the controller.  `shadow` is the controller's private
    /// replica; `depth_probe` reports the pool queue depth (recorded at
    /// each swap instant for the BENCH swap-under-load point).
    pub fn spawn(
        shared: Arc<RecalibShared>,
        shadow: Box<dyn Backend + Send>,
        specs: Vec<QuantSpec>,
        layer_names: Vec<String>,
        health: Arc<QuantHealth>,
        depth_probe: Box<dyn Fn() -> u64 + Send>,
    ) -> RecalibController {
        let stop = Arc::new(AtomicBool::new(false));
        let st = stop.clone();
        let handle = std::thread::spawn(move || {
            controller_loop(
                &shared,
                shadow.as_ref(),
                &specs,
                &layer_names,
                &health,
                depth_probe.as_ref(),
                &st,
            );
        });
        RecalibController {
            handle: Some(handle),
            stop,
        }
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RecalibController {
    fn drop(&mut self) {
        self.stop();
    }
}

fn controller_loop(
    sh: &RecalibShared,
    shadow: &dyn Backend,
    specs: &[QuantSpec],
    layer_names: &[String],
    health: &QuantHealth,
    depth_probe: &dyn Fn() -> u64,
    stop: &AtomicBool,
) {
    let m = shadow.manifest();
    let batch = m.batch;
    let in_elems = m.input_elems();
    let max_levels = m.max_levels;
    let nq = m.nq();
    let mut detector = DriftDetector::new(&sh.cfg);
    let mut window = ShadowWindow::new(specs);
    let mut pending: VecDeque<Vec<f32>> = VecDeque::new();
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(sh.cfg.check_interval);

        // ingest sampled inputs and run every full batch through the
        // shadow replica's float collect forward
        pending.extend(sh.tap.drain());
        while pending.len() >= batch {
            let mut x = Vec::with_capacity(batch * in_elems);
            for _ in 0..batch {
                x.extend_from_slice(&pending.pop_front().unwrap());
            }
            match shadow.run_collect(&x) {
                Ok(out) => {
                    for i in 0..nq {
                        window.estimators[i].observe(&out.samples[i]);
                        window.tile_max[i] =
                            window.tile_max[i].max(out.tile_max[i]);
                        for &v in &out.samples[i] {
                            window.sketches[i].insert(v);
                        }
                    }
                    window.batches += 1;
                    sh.stats.shadow_batches.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => {
                    eprintln!("recalib: shadow collect failed: {e:#}");
                }
            }
        }

        // drift signal: the worst layer's live-vs-baseline divergence
        let drift = (0..health.num_layers())
            .filter_map(|q| health.divergence(q))
            .fold(0.0f64, f64::max);
        sh.stats.set_drift(drift);
        let window_met = window.batches >= 1
            && window.min_observed() >= sh.cfg.min_observations;

        match detector.observe(drift, window_met) {
            DriftAction::Hold => {}
            DriftAction::StartCollecting | DriftAction::Abandon => {
                // either way the accumulated window is unusable: it
                // straddles the shift (or described a false alarm)
                window = ShadowWindow::new(specs);
            }
            DriftAction::Refit => {
                sh.stats.refits.fetch_add(1, Ordering::Relaxed);
                let t0 = Instant::now();
                match finish_codebooks(
                    specs,
                    &window.estimators,
                    &window.tile_max,
                    layer_names,
                    max_levels,
                ) {
                    Ok((nl, _tile, programmed)) => {
                        sh.stats
                            .inflight_at_swap
                            .store(depth_probe(), Ordering::Relaxed);
                        let generation = sh.cell.swap(programmed);
                        // the new baseline is the sketch the new books
                        // were fitted on; live sketches restart so
                        // post-swap drift reflects fresh traffic only
                        health.rebaseline(&nl, Some(&window.sketches));
                        let ns = t0.elapsed().as_nanos() as u64;
                        sh.stats.last_refit_ns.store(ns, Ordering::Relaxed);
                        sh.stats
                            .refit_ns_total
                            .fetch_add(ns, Ordering::Relaxed);
                        sh.stats.swaps.fetch_add(1, Ordering::SeqCst);
                        detector.swapped();
                        window = ShadowWindow::new(specs);
                        eprintln!(
                            "recalib: hot-swapped codebook generation \
                             {generation} ({ns} ns refit+swap)"
                        );
                    }
                    Err(e) => {
                        // the old generation keeps serving; a fresh
                        // window retries once it refills
                        sh.stats.refit_errors.fetch_add(1, Ordering::Relaxed);
                        window = ShadowWindow::new(specs);
                        eprintln!(
                            "recalib: refit failed (old codebooks stay \
                             live): {e:#}"
                        );
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(threshold: f64, hysteresis: f64, checks: u32) -> RecalibConfig {
        RecalibConfig {
            drift_threshold: threshold,
            hysteresis,
            trigger_checks: checks,
            ..RecalibConfig::default()
        }
    }

    #[test]
    fn config_validation_bounds() {
        assert!(RecalibConfig::default().validate().is_ok());
        assert!(cfg(0.0, 0.5, 2).validate().is_err());
        assert!(cfg(f64::NAN, 0.5, 2).validate().is_err());
        assert!(cfg(0.3, 0.0, 2).validate().is_err());
        assert!(cfg(0.3, 1.5, 2).validate().is_err());
        assert!(cfg(0.3, 0.5, 0).validate().is_err());
        let c = RecalibConfig {
            sample_every: 0,
            ..RecalibConfig::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn detector_holds_below_threshold() {
        let mut d = DriftDetector::new(&cfg(0.3, 0.5, 2));
        for _ in 0..100 {
            assert_eq!(d.observe(0.29, true), DriftAction::Hold);
        }
        assert_eq!(d.state(), DriftState::Armed);
    }

    #[test]
    fn detector_debounces_consecutive_checks() {
        let mut d = DriftDetector::new(&cfg(0.3, 0.5, 3));
        // two over-threshold ticks, then a dip: the count restarts
        assert_eq!(d.observe(0.5, false), DriftAction::Hold);
        assert_eq!(d.observe(0.5, false), DriftAction::Hold);
        assert_eq!(d.observe(0.1, false), DriftAction::Hold);
        assert_eq!(d.observe(0.5, false), DriftAction::Hold);
        assert_eq!(d.observe(0.5, false), DriftAction::Hold);
        assert_eq!(d.observe(0.5, false), DriftAction::StartCollecting);
        assert_eq!(d.state(), DriftState::Collecting);
    }

    #[test]
    fn detector_gates_refit_on_window_and_abandons_false_alarms() {
        let mut d = DriftDetector::new(&cfg(0.3, 0.5, 1));
        assert_eq!(d.observe(0.4, false), DriftAction::StartCollecting);
        // window not yet filled: hold, even though drift persists
        assert_eq!(d.observe(0.4, false), DriftAction::Hold);
        // drift still above the LOW watermark (0.15): keep collecting
        assert_eq!(d.observe(0.2, false), DriftAction::Hold);
        assert_eq!(d.state(), DriftState::Collecting);
        // window met while drift persists: refit fires (and keeps
        // firing until the controller acts — observe is pure)
        assert_eq!(d.observe(0.4, true), DriftAction::Refit);
        // drift collapses below the low watermark before a swap: the
        // window described a transient, abandon it
        assert_eq!(d.observe(0.1, true), DriftAction::Abandon);
        assert_eq!(d.state(), DriftState::Armed);
    }

    #[test]
    fn detector_hysteresis_blocks_retrigger_until_low_watermark() {
        let mut d = DriftDetector::new(&cfg(0.3, 0.5, 1));
        assert_eq!(d.observe(0.9, false), DriftAction::StartCollecting);
        assert_eq!(d.observe(0.9, true), DriftAction::Refit);
        d.swapped();
        assert_eq!(d.state(), DriftState::Cooldown);
        // post-swap drift hovering between the low watermark (0.15) and
        // the threshold — and even above the threshold — must NOT
        // restart collection while cooling down
        for drift in [0.2, 0.29, 0.4, 0.2] {
            assert_eq!(d.observe(drift, true), DriftAction::Hold);
            assert_eq!(d.state(), DriftState::Cooldown);
        }
        // below the low watermark: re-armed, and a fresh excursion
        // triggers again
        assert_eq!(d.observe(0.1, true), DriftAction::Hold);
        assert_eq!(d.state(), DriftState::Armed);
        assert_eq!(d.observe(0.5, false), DriftAction::StartCollecting);
    }

    #[test]
    fn shadow_tap_samples_strided_and_bounds_buffer() {
        let stats = Arc::new(RecalibStats::default());
        let tap = ShadowTap::new(4, 2, stats.clone());
        for i in 0..16 {
            tap.maybe_sample(&[i as f32]);
        }
        // requests 0,4,8,12 selected; capacity 2 holds the first two,
        // the rest are counted as dropped
        assert_eq!(stats.sampled.load(Ordering::SeqCst), 2);
        assert_eq!(stats.dropped.load(Ordering::SeqCst), 2);
        let drained = tap.drain();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0], vec![0.0]);
        assert_eq!(drained[1], vec![4.0]);
        // draining frees capacity
        tap.maybe_sample(&[16.0]);
        assert_eq!(stats.sampled.load(Ordering::SeqCst), 3);
        assert_eq!(tap.drain().len(), 1);
    }
}
