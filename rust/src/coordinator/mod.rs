//! L3 coordinator: the deployment pipeline of the paper — stream
//! calibration batches through the `collect` graph, run Algorithm 1 per
//! layer, program the NL-ADC codebooks, evaluate PTQ accuracy through the
//! `qfwd` graph (optionally with circuit-derived conversion noise and
//! quantized weights), and serve inference from a multi-model,
//! multi-replica pool (continuous batching, deadline shedding, replica
//! autoscaling) behind pluggable TCP fronts (epoll event loop or
//! thread-per-connection).

pub mod calibrate;
pub mod front;
pub mod loadgen;
pub mod pool;
pub mod ptq;
pub mod recalib;

pub use calibrate::{CalibrationResult, Calibrator};
pub use front::{FrontKind, ServeFront};
pub use loadgen::{closed_loop, closed_loop_phased, scaled_inputs, TrafficPhase};
pub use pool::{
    AdmissionError, InferenceServer, ModelPool, ModelRegistry, ObsConfig,
    PoolClient, PoolConfig, Reply, ServeError, ServerStats, REPLY_GRACE,
};
pub use ptq::{PtqEvaluator, PtqResult};
pub use recalib::{RecalibConfig, RecalibShared, RecalibStats};
