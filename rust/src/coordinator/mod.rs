//! L3 coordinator: the deployment pipeline of the paper — stream
//! calibration batches through the `collect` graph, run Algorithm 1 per
//! layer, program the NL-ADC codebooks, evaluate PTQ accuracy through the
//! `qfwd` graph (optionally with circuit-derived conversion noise and
//! quantized weights), and serve inference from a multi-model,
//! multi-replica pool with admission control.

pub mod calibrate;
pub mod ptq;
pub mod server;

pub use calibrate::{CalibrationResult, Calibrator};
pub use ptq::{PtqEvaluator, PtqResult};
pub use server::{
    AdmissionError, InferenceServer, ModelPool, ModelRegistry, ObsConfig,
    PoolClient, PoolConfig, ServerStats,
};
