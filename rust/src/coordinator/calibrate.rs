//! Calibration orchestration: streams batches through the model's
//! `collect` entry point (any [`Backend`]), feeds every quantized layer's
//! activation subsample into its own streaming [`QuantEstimator`] (the
//! fitter its per-layer [`QuantSpec`] names), and programs the resulting
//! codebooks — the per-layer, data-dependent, *mixed-precision*
//! quantization the prior NL-ADC hardware (fixed profiles) could not do.
//!
//! Because the estimators are mergeable, calibration shards: with
//! `shards > 1`, [`Calibrator::calibrate_sharded`] spawns one scoped
//! thread per shard, each streaming a contiguous slice of the
//! calibration batches through its own [`Backend::replicate`] clone and
//! estimator set, then merges the shard states associatively.  The
//! merged codebooks are **bit-identical** to the serial run — pinned by
//! `rust/tests/quant_spec.rs` — so sharding is purely a wall-clock knob.

use std::ops::Range;

use anyhow::{ensure, Context, Result};

use crate::backend::{Backend, ProgrammedCodebooks};
use crate::data::dataset::ModelData;
use crate::obs::quant_health::health_sketch;
use crate::quant::codebook::Codebook;
use crate::quant::estimator::{estimator_for, QuantEstimator};
use crate::quant::sketch::ValueSketch;
use crate::quant::QuantSpec;

pub struct CalibrationResult {
    /// per-layer NL codebooks (hardware-projected)
    pub nl_books: Vec<Codebook>,
    /// per-layer linear tile codebooks (each layer's `tile_bits`)
    pub tile_books: Vec<Codebook>,
    /// stacked tensors ready for the deployed forward
    pub programmed: ProgrammedCodebooks,
    /// calibration batches consumed
    pub batches: usize,
    /// shards the batches were streamed over
    pub shards: usize,
    /// per-layer sample counts observed
    pub samples_seen: Vec<usize>,
    /// the per-layer specs this calibration ran with
    pub specs: Vec<QuantSpec>,
    /// per-layer bounded sketches of the calibration activations — the
    /// baseline the obs layer diffs live traffic against
    pub sketches: Vec<ValueSketch>,
}

/// Fit + hardware-project every layer's codebook pair from accumulated
/// estimator state — the single fitting path shared by offline
/// calibration and the online shadow-recalibration controller
/// ([`crate::coordinator::recalib`]), so refit codebooks go through
/// exactly the spec-driven pipeline the deployed books came from.
pub fn finish_codebooks(
    specs: &[QuantSpec],
    estimators: &[Box<dyn QuantEstimator>],
    tile_max: &[f64],
    layer_names: &[String],
    max_levels: usize,
) -> Result<(Vec<Codebook>, Vec<Codebook>, ProgrammedCodebooks)> {
    let nq = specs.len();
    ensure!(
        estimators.len() == nq
            && tile_max.len() == nq
            && layer_names.len() == nq,
        "finish_codebooks: mismatched per-layer lengths \
         ({} specs, {} estimators, {} tile maxima, {} names)",
        nq,
        estimators.len(),
        tile_max.len(),
        layer_names.len()
    );
    let mut nl_books = Vec::with_capacity(nq);
    let mut tile_books = Vec::with_capacity(nq);
    for i in 0..nq {
        let spec = &specs[i];
        let ideal = estimators[i].finish(spec.act_bits).with_context(|| {
            format!(
                "fitting the {} codebook of q-layer '{}'",
                spec.method.name(),
                layer_names[i]
            )
        })?;
        let hw = ideal.project_to_hardware(spec.act_bits);
        // a degenerate ladder would panic inside the conversion
        // kernels and mis-scale noise (min_ref_step falls back to
        // 1.0); fail the fit here, naming the layer
        ensure!(
            hw.levels() >= 2,
            "q-layer '{}': calibration produced a degenerate \
             {}-level NL codebook (conversion needs at least 2 levels)",
            layer_names[i],
            hw.levels()
        );
        nl_books.push(hw);
        // per-tile linear conversion over the observed partial range
        let r = tile_max[i].max(1e-6);
        tile_books.push(Codebook::linear(-r, r, spec.tile_bits));
    }
    let programmed =
        ProgrammedCodebooks::stack(&nl_books, &tile_books, max_levels)?;
    Ok((nl_books, tile_books, programmed))
}

/// Per-shard accumulation state: one estimator per q-layer plus the
/// exactly-associative side statistics.
struct ShardState {
    estimators: Vec<Box<dyn QuantEstimator>>,
    tile_max: Vec<f64>,
    samples_seen: Vec<usize>,
    sketches: Vec<ValueSketch>,
}

impl ShardState {
    fn absorb(&mut self, other: ShardState) -> Result<()> {
        for (mine, theirs) in
            self.estimators.iter_mut().zip(&other.estimators)
        {
            mine.merge(theirs.as_ref())?;
        }
        for (a, b) in self.tile_max.iter_mut().zip(&other.tile_max) {
            if *b > *a {
                *a = *b;
            }
        }
        for (a, b) in self.samples_seen.iter_mut().zip(&other.samples_seen) {
            *a += *b;
        }
        for (a, b) in self.sketches.iter_mut().zip(&other.sketches) {
            a.merge(b)?;
        }
        Ok(())
    }
}

/// Stream one contiguous batch range through a backend into a fresh
/// estimator set (the per-shard worker body).
fn run_shard(
    backend: &dyn Backend,
    specs: &[QuantSpec],
    data: &ModelData,
    range: Range<usize>,
) -> Result<ShardState> {
    let m = backend.manifest();
    let nq = m.nq();
    let mut estimators: Vec<Box<dyn QuantEstimator>> =
        specs.iter().map(estimator_for).collect();
    for e in &mut estimators {
        e.seek(range.start as u64);
    }
    let mut tile_max = vec![0f64; nq];
    let mut samples_seen = vec![0usize; nq];
    let mut sketches: Vec<ValueSketch> =
        (0..nq).map(|_| health_sketch()).collect();
    for b in range {
        let xb = ModelData::batch(&data.x_calib, b, m.batch);
        let out = backend.run_collect(xb)?;
        for i in 0..nq {
            samples_seen[i] += out.samples[i].len();
            estimators[i].observe(&out.samples[i]);
            tile_max[i] = tile_max[i].max(out.tile_max[i]);
            for &v in &out.samples[i] {
                sketches[i].insert(v);
            }
        }
    }
    Ok(ShardState {
        estimators,
        tile_max,
        samples_seen,
        sketches,
    })
}

/// Split `n` batches into `shards` contiguous, near-even ranges.
fn split_ranges(n: usize, shards: usize) -> Vec<Range<usize>> {
    let per = n / shards;
    let rem = n % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0;
    for s in 0..shards {
        let len = per + usize::from(s < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

pub struct Calibrator<'a> {
    backend: &'a dyn Backend,
    specs: Vec<QuantSpec>,
}

impl<'a> Calibrator<'a> {
    /// Calibrate with the manifest's per-layer specs (absent entries
    /// resolve to the historical defaults) — the deployment path.
    pub fn from_manifest(backend: &'a dyn Backend) -> Calibrator<'a> {
        let specs = backend.manifest().layer_specs();
        Calibrator { backend, specs }
    }

    /// One spec applied uniformly, re-seeded per layer
    /// ([`QuantSpec::per_layer`]) — the sweep/CLI-override path.
    pub fn with_uniform(
        backend: &'a dyn Backend,
        spec: QuantSpec,
    ) -> Calibrator<'a> {
        let specs = spec.per_layer(backend.manifest().nq());
        Calibrator { backend, specs }
    }

    /// Explicit per-layer specs (length is checked at `calibrate`).
    pub fn with_specs(
        backend: &'a dyn Backend,
        specs: Vec<QuantSpec>,
    ) -> Calibrator<'a> {
        Calibrator { backend, specs }
    }

    /// The resolved per-layer specs this calibrator will run with.
    pub fn specs(&self) -> &[QuantSpec] {
        &self.specs
    }

    /// Serial calibration: stream `n_batches`, then fit + hardware-
    /// project every layer's codebook.
    pub fn calibrate(
        &self,
        data: &ModelData,
        n_batches: usize,
    ) -> Result<CalibrationResult> {
        self.calibrate_sharded(data, n_batches, 1)
    }

    /// Shard-parallel calibration: `shards` scoped threads each stream a
    /// contiguous slice of the batches through a [`Backend::replicate`]
    /// clone; estimator states merge associatively, so the codebooks are
    /// bit-identical to `shards = 1`.
    pub fn calibrate_sharded(
        &self,
        data: &ModelData,
        n_batches: usize,
        shards: usize,
    ) -> Result<CalibrationResult> {
        let m = self.backend.manifest();
        let nq = m.nq();
        ensure!(
            self.specs.len() == nq,
            "{} quant specs for {} q-layers",
            self.specs.len(),
            nq
        );
        for (i, spec) in self.specs.iter().enumerate() {
            spec.validate(m.max_levels).with_context(|| {
                format!("q-layer '{}' quant spec", m.qlayers[i].name)
            })?;
        }
        ensure!(n_batches >= 1, "calibration needs at least one batch");
        ensure!(
            n_batches * m.batch <= data.n_calib(),
            "need {} calib samples, have {}",
            n_batches * m.batch,
            data.n_calib()
        );
        let shards = shards.clamp(1, n_batches);

        let mut states: Vec<ShardState> = if shards == 1 {
            vec![run_shard(self.backend, &self.specs, data, 0..n_batches)?]
        } else {
            let mut replicas = Vec::with_capacity(shards);
            for _ in 0..shards {
                replicas.push(self.backend.replicate().context(
                    "sharded calibration needs a replicable backend \
                     (run with shards = 1 instead)",
                )?);
            }
            let ranges = split_ranges(n_batches, shards);
            let specs = &self.specs;
            std::thread::scope(|scope| {
                let handles: Vec<_> = replicas
                    .into_iter()
                    .zip(ranges)
                    .map(|(be, range)| {
                        scope.spawn(move || {
                            run_shard(be.as_ref(), specs, data, range)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("calibration shard panicked"))
                    .collect::<Result<Vec<_>>>()
            })?
        };

        let mut root = states.remove(0);
        for st in states {
            root.absorb(st)?;
        }

        let layer_names: Vec<String> =
            m.qlayers.iter().map(|q| q.name.clone()).collect();
        let (nl_books, tile_books, programmed) = finish_codebooks(
            &self.specs,
            &root.estimators,
            &root.tile_max,
            &layer_names,
            m.max_levels,
        )?;
        Ok(CalibrationResult {
            nl_books,
            tile_books,
            programmed,
            batches: n_batches,
            shards,
            samples_seen: root.samples_seen,
            specs: self.specs.clone(),
            sketches: root.sketches,
        })
    }

    /// Pool all calibration activations per layer (for the MSE figures,
    /// which compare fitters on identical sample sets).
    pub fn collect_samples(
        &self,
        data: &ModelData,
        n_batches: usize,
    ) -> Result<Vec<Vec<f64>>> {
        let m = self.backend.manifest();
        let mut pooled: Vec<Vec<f64>> = vec![Vec::new(); m.nq()];
        for b in 0..n_batches {
            let xb = ModelData::batch(&data.x_calib, b, m.batch);
            let out = self.backend.run_collect(xb)?;
            for (p, s) in pooled.iter_mut().zip(out.samples) {
                p.extend(s);
            }
        }
        Ok(pooled)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_cover_contiguously() {
        for (n, shards) in [(8usize, 3usize), (16, 4), (5, 8), (1, 1)] {
            let ranges = split_ranges(n, shards.min(n));
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "gap between shards");
            }
        }
    }
}
