//! Calibration orchestration: streams batches through the model's
//! `collect` entry point (any [`Backend`]), feeds every quantized layer's
//! activation subsample to its own Algorithm 1 calibrator (or a baseline
//! fitter), and programs the resulting codebooks — the per-layer,
//! data-dependent quantization the prior NL-ADC hardware (fixed profiles)
//! could not do.

use anyhow::{ensure, Result};

use crate::backend::{Backend, ProgrammedCodebooks};
use crate::data::dataset::ModelData;
use crate::quant::bs_kmq::BsKmqCalibrator;
use crate::quant::codebook::{Codebook, MAX_LEVELS};
use crate::quant::Method;

/// Per-tile conversion resolution: the reconfigurable ADC's maximum (7
/// bit linear) — intermediate partial sums keep full hardware precision
/// while the layer output uses the low-bit NL codebook.
pub const TILE_BITS: u32 = 7;

pub struct CalibrationResult {
    /// per-layer NL codebooks (hardware-projected)
    pub nl_books: Vec<Codebook>,
    /// per-layer 7-bit linear tile codebooks
    pub tile_books: Vec<Codebook>,
    /// stacked tensors ready for the deployed forward
    pub programmed: ProgrammedCodebooks,
    /// calibration batches consumed
    pub batches: usize,
    /// per-layer sample counts observed
    pub samples_seen: Vec<usize>,
}

pub struct Calibrator<'a> {
    backend: &'a dyn Backend,
    pub method: Method,
    pub bits: u32,
}

impl<'a> Calibrator<'a> {
    pub fn new(backend: &'a dyn Backend, method: Method, bits: u32) -> Self {
        Calibrator {
            backend,
            method,
            bits,
        }
    }

    /// Stream `n_batches` of calibration data (Algorithm 1 stage 1), then
    /// fit + hardware-project every layer's codebook (stage 2).
    pub fn calibrate(
        &self,
        data: &ModelData,
        n_batches: usize,
    ) -> Result<CalibrationResult> {
        let m = self.backend.manifest();
        let nq = m.nq();
        let batch = m.batch;
        ensure!(
            n_batches * batch <= data.n_calib(),
            "need {} calib samples, have {}",
            n_batches * batch,
            data.n_calib()
        );
        let mut bs_calibs: Vec<BsKmqCalibrator> =
            (0..nq).map(|i| BsKmqCalibrator::new(0.005, 200_000, i as u64)).collect();
        let mut pooled: Vec<Vec<f64>> = vec![Vec::new(); nq];
        let mut tile_max = vec![0f64; nq];
        let mut samples_seen = vec![0usize; nq];

        for b in 0..n_batches {
            let xb = ModelData::batch(&data.x_calib, b, batch);
            let out = self.backend.run_collect(xb)?;
            for i in 0..nq {
                samples_seen[i] += out.samples[i].len();
                match self.method {
                    Method::BsKmq => bs_calibs[i].observe(&out.samples[i]),
                    _ => pooled[i].extend(&out.samples[i]),
                }
                tile_max[i] = tile_max[i].max(out.tile_max[i]);
            }
        }

        let mut nl_books = Vec::with_capacity(nq);
        let mut tile_books = Vec::with_capacity(nq);
        for i in 0..nq {
            let centers = match self.method {
                Method::BsKmq => bs_calibs[i].finish(self.bits, i as u64)?,
                m => m.fit(&pooled[i], self.bits),
            };
            nl_books.push(
                Codebook::from_centers(&centers).project_to_hardware(self.bits),
            );
            // per-tile linear conversion over the observed partial range
            let r = tile_max[i].max(1e-6);
            tile_books.push(Codebook::linear(-r, r, TILE_BITS));
        }
        let programmed =
            ProgrammedCodebooks::stack(&nl_books, &tile_books, MAX_LEVELS)?;
        Ok(CalibrationResult {
            nl_books,
            tile_books,
            programmed,
            batches: n_batches,
            samples_seen,
        })
    }

    /// Pool all calibration activations per layer (for the MSE figures,
    /// which compare fitters on identical sample sets).
    pub fn collect_samples(
        &self,
        data: &ModelData,
        n_batches: usize,
    ) -> Result<Vec<Vec<f64>>> {
        let m = self.backend.manifest();
        let mut pooled: Vec<Vec<f64>> = vec![Vec::new(); m.nq()];
        for b in 0..n_batches {
            let xb = ModelData::batch(&data.x_calib, b, m.batch);
            let out = self.backend.run_collect(xb)?;
            for (p, s) in pooled.iter_mut().zip(out.samples) {
                p.extend(s);
            }
        }
        Ok(pooled)
    }
}
