//! Post-training-quantization evaluation: runs the test split through the
//! deployed quantized forward of any [`Backend`] with programmed
//! codebooks, optional ADC noise (Fig. 6/7) and optional weight
//! quantization (Fig. 6), and reports accuracy against the exported
//! labels.

use anyhow::{ensure, Result};

use crate::backend::{Backend, ProgrammedCodebooks};
use crate::data::dataset::ModelData;
use crate::quant::weights::quantize_tensor;
use crate::quant::QuantSpec;

#[derive(Clone, Debug)]
pub struct PtqResult {
    pub accuracy: f64,
    pub batches: usize,
    pub samples: usize,
}

pub struct PtqEvaluator<'a> {
    backend: &'a dyn Backend,
}

impl<'a> PtqEvaluator<'a> {
    pub fn new(backend: &'a dyn Backend) -> Self {
        PtqEvaluator { backend }
    }

    /// Accuracy over `n_batches` test batches through qfwd.
    pub fn evaluate(
        &self,
        data: &ModelData,
        books: &ProgrammedCodebooks,
        noise_std: f32,
        n_batches: usize,
        seed: u32,
    ) -> Result<PtqResult> {
        let m = self.backend.manifest();
        let batch = m.batch;
        let classes = m.num_classes;
        let n_batches = n_batches.min(data.n_test() / batch);
        let mut correct = 0usize;
        let mut total = 0usize;
        for b in 0..n_batches {
            let xb = ModelData::batch(&data.x_test, b, batch);
            let logits =
                self.backend
                    .run_qfwd(xb, books, noise_std, seed.wrapping_add(b as u32))?;
            for i in 0..batch {
                let row = &logits[i * classes..(i + 1) * classes];
                let pred = argmax(row);
                if pred == data.y_test[b * batch + i] {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(PtqResult {
            accuracy: correct as f64 / total.max(1) as f64,
            batches: n_batches,
            samples: total,
        })
    }

    /// A backend clone with linearly quantized q-layer weights (Fig. 6).
    pub fn quantize_weights(&self, w_bits: u32) -> Result<Box<dyn Backend>> {
        let mut weights = self.backend.weights().to_vec();
        for i in self.backend.qweight_indices() {
            weights[i] = quantize_tensor(&weights[i], w_bits);
        }
        self.backend.with_weights(weights)
    }

    /// A backend clone with *per-layer* weight quantization: each
    /// q-layer whose spec carries `weight_bits` gets its matrix
    /// quantized to that width, the rest keep the trained floats — the
    /// mixed-precision deployments (the paper's 6/2/3b system point) as
    /// one artifact.
    pub fn quantize_weights_spec(
        &self,
        specs: &[QuantSpec],
    ) -> Result<Box<dyn Backend>> {
        let m = self.backend.manifest();
        ensure!(
            specs.len() == m.nq(),
            "{} quant specs for {} q-layers",
            specs.len(),
            m.nq()
        );
        let qidx = self.backend.qweight_indices();
        ensure!(
            qidx.len() == m.nq(),
            "backend exposes {} q-weight tensors for {} q-layers",
            qidx.len(),
            m.nq()
        );
        let mut weights = self.backend.weights().to_vec();
        for (i, spec) in specs.iter().enumerate() {
            if let Some(w_bits) = spec.weight_bits {
                weights[qidx[i]] = quantize_tensor(&weights[qidx[i]], w_bits);
            }
        }
        self.backend.with_weights(weights)
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-5.0, -9.0]), 0);
        assert_eq!(argmax(&[0.0]), 0);
    }
}
