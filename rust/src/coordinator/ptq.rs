//! Post-training-quantization evaluation: runs the test split through the
//! deployed `qfwd` graph with programmed codebooks, optional ADC noise
//! (Fig. 6/7) and optional weight quantization (Fig. 6), and reports
//! accuracy against the exported labels.

use anyhow::Result;

use crate::data::dataset::ModelData;
use crate::quant::weights::quantize_tensor;
use crate::runtime::model::{ModelRuntime, ProgrammedCodebooks};

#[derive(Clone, Debug)]
pub struct PtqResult {
    pub accuracy: f64,
    pub batches: usize,
    pub samples: usize,
}

pub struct PtqEvaluator<'a> {
    runtime: &'a ModelRuntime,
}

impl<'a> PtqEvaluator<'a> {
    pub fn new(runtime: &'a ModelRuntime) -> Self {
        PtqEvaluator { runtime }
    }

    /// Accuracy over `n_batches` test batches through qfwd.
    pub fn evaluate(
        &self,
        data: &ModelData,
        books: &ProgrammedCodebooks,
        noise_std: f32,
        n_batches: usize,
        seed: u32,
    ) -> Result<PtqResult> {
        let m = &self.runtime.manifest;
        let batch = m.batch;
        let classes = m.num_classes;
        let n_batches = n_batches.min(data.n_test() / batch);
        let mut correct = 0usize;
        let mut total = 0usize;
        for b in 0..n_batches {
            let xb = ModelData::batch(&data.x_test, b, batch);
            let logits =
                self.runtime
                    .run_qfwd(xb, books, noise_std, seed.wrapping_add(b as u32))?;
            for i in 0..batch {
                let row = &logits[i * classes..(i + 1) * classes];
                let pred = argmax(row);
                if pred == data.y_test[b * batch + i] {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(PtqResult {
            accuracy: correct as f64 / total.max(1) as f64,
            batches: n_batches,
            samples: total,
        })
    }

    /// A runtime clone with linearly quantized q-layer weights (Fig. 6).
    pub fn quantize_weights(&self, w_bits: u32) -> Result<ModelRuntime> {
        let mut weights = self.runtime.weights().to_vec();
        for i in self.runtime.qweight_indices() {
            weights[i] = quantize_tensor(&weights[i], w_bits);
        }
        self.runtime.with_weights(weights)
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-5.0, -9.0]), 0);
        assert_eq!(argmax(&[0.0]), 0);
    }
}
