//! TCP serving fronts over a [`ModelRegistry`] (DESIGN.md §13).
//!
//! Two interchangeable fronts speak the same line protocol — one line
//! `[model:]f1,f2,...` in, one line of logits (or `error: ...`) out,
//! plus the `stats` / `stats --text` / `metrics` commands:
//!
//! * [`FrontKind::Threaded`] — the historical thread-per-connection
//!   accept loop.  Simple, blocking, and kept as the oracle: the
//!   agreement test (`rust/tests/serving_front.rs`) pins the event
//!   front's replies byte-identical to it.
//! * [`FrontKind::Event`] — a nonblocking epoll event loop (linux)
//!   multiplexing thousands of connections onto one thread.  Requests
//!   are submitted with a completion-queue reply route; replies come
//!   back through a self-pipe wakeup and are written in request order
//!   per connection (the protocol is pipelined: a client may send many
//!   lines before reading any reply).
//!
//! Both fronts build every reply through the same [`classify`] /
//! [`format_reply`] helpers, so protocol bytes are identical by
//! construction; both set `TCP_NODELAY` on accepted sockets (the
//! line-oriented protocol writes one small reply per request, which
//! Nagle would otherwise delay).  The front never blocks on the pool:
//! admission control and deadline shedding guarantee every submitted
//! request gets exactly one reply.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::pool::{
    ModelRegistry, PoolClient, Reply, REPLY_GRACE,
};
use crate::obs::prometheus::PromWriter;
use crate::obs::registry::{Counter, Gauge, MetricsRegistry};

/// Which serving front multiplexes the TCP connections.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrontKind {
    /// nonblocking epoll event loop (linux only)
    Event,
    /// one thread per connection (the historical front, kept as the
    /// byte-identity oracle)
    Threaded,
}

impl FrontKind {
    pub fn parse(s: &str) -> Result<FrontKind> {
        match s {
            "event" => Ok(FrontKind::Event),
            "threaded" | "thread" => Ok(FrontKind::Threaded),
            other => bail!("unknown front '{other}' (event|threaded)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FrontKind::Event => "event",
            FrontKind::Threaded => "threaded",
        }
    }

    /// The event front where epoll exists, the threaded front elsewhere.
    pub fn default_for_platform() -> FrontKind {
        if cfg!(target_os = "linux") {
            FrontKind::Event
        } else {
            FrontKind::Threaded
        }
    }
}

/// State both fronts share: the routed registry plus front-level
/// telemetry (connection gauge/counter rendered on the `metrics` page).
struct FrontShared {
    registry: Arc<ModelRegistry>,
    metrics: Arc<MetricsRegistry>,
    conns: AtomicU64,
    conn_gauge: Arc<Gauge>,
    accepted: Arc<Counter>,
    stop: AtomicBool,
}

impl FrontShared {
    fn conn_opened(&self) {
        let n = self.conns.fetch_add(1, Ordering::SeqCst) + 1;
        self.conn_gauge.set(n as f64);
    }

    fn conn_closed(&self) {
        let n = self.conns.fetch_sub(1, Ordering::SeqCst) - 1;
        self.conn_gauge.set(n as f64);
    }

    fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

/// A running TCP front.  Dropping (or [`ServeFront::stop`]) shuts the
/// accept/event loop down and joins its thread; the registry and its
/// pools stay up — fronts are replaceable, pools are the server.
pub struct ServeFront {
    kind: FrontKind,
    addr: SocketAddr,
    shared: Arc<FrontShared>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
}

impl ServeFront {
    /// Start serving `listener`'s connections against `registry` on a
    /// background thread.  The event front requires linux epoll; asking
    /// for it elsewhere is an error (pick
    /// [`FrontKind::default_for_platform`] when in doubt).
    pub fn spawn(
        registry: Arc<ModelRegistry>,
        listener: TcpListener,
        kind: FrontKind,
    ) -> Result<ServeFront> {
        #[cfg(not(target_os = "linux"))]
        {
            if matches!(kind, FrontKind::Event) {
                bail!("the event front needs linux epoll; use --front threaded");
            }
        }
        let addr = listener.local_addr()?;
        let metrics = Arc::new(MetricsRegistry::new());
        let conn_gauge = metrics.gauge("bskmq_connections");
        let accepted = metrics.counter("bskmq_connections_accepted_total");
        let shared = Arc::new(FrontShared {
            registry,
            metrics,
            conns: AtomicU64::new(0),
            conn_gauge,
            accepted,
            stop: AtomicBool::new(false),
        });
        let sh = shared.clone();
        let handle = match kind {
            FrontKind::Threaded => {
                std::thread::spawn(move || threaded_loop(&sh, listener))
            }
            FrontKind::Event => {
                std::thread::spawn(move || event_front_entry(&sh, listener))
            }
        };
        Ok(ServeFront {
            kind,
            addr,
            shared,
            handle: Some(handle),
        })
    }

    pub fn kind(&self) -> FrontKind {
        self.kind
    }

    /// The bound address (useful with port 0 in tests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Front-level metrics (connection gauge, accepted counter); also
    /// rendered on the `metrics` protocol page.
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.shared.metrics
    }

    /// Currently open connections.
    pub fn connections(&self) -> u64 {
        self.shared.conns.load(Ordering::SeqCst)
    }

    /// Signal the loop to stop, then join it.  Idempotent; also runs on
    /// Drop.  Open connections are torn down, in-flight requests still
    /// get served by the pools (their replies just have nowhere to go).
    pub fn stop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => eprintln!("{} front failed: {e:#}", self.kind.name()),
                Err(_) => eprintln!("{} front panicked", self.kind.name()),
            }
        }
    }

    /// Block until the front exits (it only exits on [`ServeFront::stop`]
    /// or a fatal loop error).
    pub fn join(&mut self) -> Result<()> {
        match self.handle.take() {
            Some(h) => match h.join() {
                Ok(r) => r,
                Err(_) => bail!("{} front panicked", self.kind.name()),
            },
            None => Ok(()),
        }
    }
}

impl Drop for ServeFront {
    fn drop(&mut self) {
        self.stop();
    }
}

/// What one protocol line asks for.
enum Action {
    /// empty line: no reply
    Nothing,
    /// a complete reply, ready to write (stats/metrics/errors)
    Text(String),
    /// submit `x` to pool index `idx`
    Infer(usize, Vec<f32>),
}

/// Parse one trimmed protocol line.  Every reply byte either front
/// writes for a given line originates here or in [`format_reply`], which
/// is what makes the two fronts byte-identical by construction.
fn classify(sh: &FrontShared, t: &str) -> Action {
    if t.is_empty() {
        return Action::Nothing;
    }
    if t == "stats" {
        return Action::Text(format!("{}\n", sh.registry.stats_json()));
    }
    if t == "stats --text" {
        return Action::Text(format!(
            "{}\n",
            sh.registry.summary().replace('\n', " | ")
        ));
    }
    if t == "metrics" {
        // Prometheus text exposition 0.0.4, terminated by a blank line
        // so line-oriented clients know where the page ends
        return Action::Text(format!("{}\n", metrics_page(sh)));
    }
    // route by `model:` prefix; bare lines go to the default pool
    let (idx, payload) = match t.split_once(':') {
        Some((name, rest)) => {
            match sh.registry.pools().iter().position(|p| p.model == name) {
                Some(i) => (i, rest),
                None => {
                    return Action::Text(format!(
                        "error: unknown model '{name}' (serving: {})\n",
                        sh.registry.models().join(",")
                    ));
                }
            }
        }
        None => (0, t),
    };
    let parsed: std::result::Result<Vec<f32>, _> = payload
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().parse::<f32>())
        .collect();
    match parsed {
        Ok(x) => Action::Infer(idx, x),
        Err(e) => Action::Text(format!("error: parsing input floats: {e}\n")),
    }
}

/// Format one pool reply as protocol bytes.
fn format_reply(r: &Reply) -> String {
    match r {
        Ok(logits) => {
            let s: Vec<String> =
                logits.iter().map(|v| format!("{v:.6}")).collect();
            format!("{}\n", s.join(","))
        }
        Err(e) => format!("error: {e}\n"),
    }
}

/// A submit refused before admission (wrong size, queue full, closed).
fn format_submit_error(e: &anyhow::Error) -> String {
    format!("error: {e:#}\n")
}

/// The `metrics` page: every pool's series plus the front's own
/// connection telemetry, through one writer.
fn metrics_page(sh: &FrontShared) -> String {
    let mut w = PromWriter::new();
    for p in sh.registry.pools() {
        p.render_prometheus(&mut w);
    }
    sh.metrics.render(&mut w);
    w.finish()
}

// ---------------------------------------------------------------------------
// Threaded front (the oracle)
// ---------------------------------------------------------------------------

/// Accept loop: one thread per connection.  Nonblocking accept with a
/// short sleep so the stop flag is observed.
fn threaded_loop(sh: &Arc<FrontShared>, listener: TcpListener) -> Result<()> {
    listener.set_nonblocking(true)?;
    let clients: Vec<PoolClient> =
        sh.registry.pools().iter().map(|p| p.client()).collect();
    std::thread::scope(|scope| {
        loop {
            if sh.stopping() {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    sh.accepted.inc();
                    let sh = sh.clone();
                    let clients = &clients;
                    scope.spawn(move || {
                        sh.conn_opened();
                        if let Err(e) = threaded_conn(&sh, clients, stream) {
                            eprintln!("client connection error: {e}");
                        }
                        sh.conn_closed();
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    eprintln!("accept failed: {e}");
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
    });
    Ok(())
}

/// One blocking client session.  Reads use a short timeout so the
/// session also winds down when the front stops.
fn threaded_conn(
    sh: &FrontShared,
    clients: &[PoolClient],
    stream: TcpStream,
) -> Result<()> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    'session: loop {
        line.clear();
        // assemble one full line, tolerating read timeouts (partial
        // reads accumulate in `line` across retries)
        loop {
            match reader.read_line(&mut line) {
                Ok(0) => break 'session,
                Ok(_) => break,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if sh.stopping() {
                        break 'session;
                    }
                }
                Err(e) => return Err(e.into()),
            }
        }
        match classify(sh, line.trim()) {
            Action::Nothing => {}
            Action::Text(s) => out.write_all(s.as_bytes())?,
            Action::Infer(idx, x) => {
                let c = &clients[idx];
                match c.submit_deadline(x, c.deadline()) {
                    Ok(rx) => {
                        let s = match rx.recv_timeout(c.deadline() + REPLY_GRACE)
                        {
                            Ok(r) => format_reply(&r),
                            Err(_) => {
                                "error: request dropped or timed out\n"
                                    .to_string()
                            }
                        };
                        out.write_all(s.as_bytes())?;
                    }
                    Err(e) => {
                        out.write_all(format_submit_error(&e).as_bytes())?
                    }
                }
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Event front (linux epoll)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
fn event_front_entry(sh: &Arc<FrontShared>, listener: TcpListener) -> Result<()> {
    event::run(sh, listener)
}

#[cfg(not(target_os = "linux"))]
fn event_front_entry(
    _sh: &Arc<FrontShared>,
    _listener: TcpListener,
) -> Result<()> {
    bail!("the event front needs linux epoll")
}

#[cfg(target_os = "linux")]
mod event {
    //! The epoll event loop.  No external crates: std already links
    //! libc, so the four epoll symbols are declared directly.
    //!
    //! Life of a request: readable socket → buffered bytes split into
    //! lines → [`classify`] → `submit_to` with a completion token
    //! (slot | generation | sequence) → worker replies into the
    //! [`CompletionQueue`], firing the self-pipe → the loop drains
    //! completions, fills each connection's in-order pending queue, and
    //! flushes.  Replies are written strictly in request order per
    //! connection; a closed connection bumps its slot generation so
    //! late completions for it are dropped instead of crossing wires.

    use std::collections::VecDeque;
    use std::io::{ErrorKind, Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::{AsRawFd, RawFd};
    use std::os::unix::net::UnixStream;
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    use anyhow::{ensure, Result};

    use super::{
        classify, format_reply, format_submit_error, Action, FrontShared,
    };
    use crate::coordinator::pool::{CompletionQueue, PoolClient, ReplyTo};

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;

    /// x86_64's epoll_event is packed (no padding between the fields);
    /// other architectures use the natural layout.  Fields must be read
    /// by value, never by reference.
    #[cfg(target_arch = "x86_64")]
    #[repr(C, packed)]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    #[cfg(not(target_arch = "x86_64"))]
    #[repr(C)]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(
            epfd: i32,
            op: i32,
            fd: i32,
            event: *mut EpollEvent,
        ) -> i32;
        fn epoll_wait(
            epfd: i32,
            events: *mut EpollEvent,
            maxevents: i32,
            timeout: i32,
        ) -> i32;
        fn close(fd: i32) -> i32;
    }

    /// Owned epoll instance.
    struct Epoll {
        fd: i32,
    }

    impl Epoll {
        fn new() -> Result<Epoll> {
            let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            ensure!(
                fd >= 0,
                "epoll_create1 failed: {}",
                std::io::Error::last_os_error()
            );
            Ok(Epoll { fd })
        }

        fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> Result<()> {
            let mut ev = EpollEvent {
                events,
                data: token,
            };
            let rc = unsafe { epoll_ctl(self.fd, op, fd, &mut ev) };
            ensure!(
                rc == 0,
                "epoll_ctl failed: {}",
                std::io::Error::last_os_error()
            );
            Ok(())
        }

        fn del(&self, fd: RawFd) {
            let mut ev = EpollEvent { events: 0, data: 0 };
            unsafe { epoll_ctl(self.fd, EPOLL_CTL_DEL, fd, &mut ev) };
        }

        /// Wait up to `timeout_ms`; EINTR reads as zero events.
        fn wait(&self, out: &mut [EpollEvent], timeout_ms: i32) -> usize {
            let rc = unsafe {
                epoll_wait(
                    self.fd,
                    out.as_mut_ptr(),
                    out.len() as i32,
                    timeout_ms,
                )
            };
            if rc < 0 {
                0
            } else {
                rc as usize
            }
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            unsafe { close(self.fd) };
        }
    }

    const TOK_LISTENER: u64 = u64::MAX;
    const TOK_WAKE: u64 = u64::MAX - 1;

    /// At most this many in-flight + completed-unflushed requests per
    /// connection; past it the protocol answers with an error line.
    const MAX_PENDING_PER_CONN: usize = 4096;
    /// A single protocol line longer than this closes the connection.
    const MAX_LINE_BYTES: usize = 1 << 20;

    /// Completion tokens: slot (24 bits) | generation (16) | seq (24).
    fn conn_token(slot: usize, gen: u16) -> u64 {
        ((slot as u64) << 40) | ((gen as u64) << 24)
    }

    fn completion_token(slot: usize, gen: u16, seq: u32) -> u64 {
        conn_token(slot, gen) | (seq as u64 & 0xFF_FFFF)
    }

    fn token_slot(tok: u64) -> usize {
        (tok >> 40) as usize
    }

    fn token_gen(tok: u64) -> u16 {
        ((tok >> 24) & 0xFFFF) as u16
    }

    fn token_seq(tok: u64) -> u32 {
        (tok & 0xFF_FFFF) as u32
    }

    /// One request slot in a connection's in-order reply queue.
    struct Pending {
        seq: u32,
        /// `None` while the pool is working; the formatted reply once
        /// it is ready to write
        done: Option<String>,
    }

    struct Conn {
        stream: TcpStream,
        rbuf: Vec<u8>,
        wbuf: Vec<u8>,
        wpos: usize,
        /// replies in request order; only the front is ever written
        pending: VecDeque<Pending>,
        next_seq: u32,
        want_write: bool,
        registered_out: bool,
        peer_closed: bool,
    }

    impl Conn {
        fn new(stream: TcpStream) -> Conn {
            Conn {
                stream,
                rbuf: Vec::new(),
                wbuf: Vec::new(),
                wpos: 0,
                pending: VecDeque::new(),
                next_seq: 0,
                want_write: false,
                registered_out: false,
                peer_closed: false,
            }
        }

        fn alloc_seq(&mut self) -> u32 {
            let s = self.next_seq;
            self.next_seq = (self.next_seq + 1) & 0xFF_FFFF;
            s
        }
    }

    /// Connection slot: the generation survives the connection so stale
    /// completion tokens from a closed session are recognized.
    struct Slot {
        gen: u16,
        conn: Option<Conn>,
    }

    pub(super) fn run(
        sh: &Arc<FrontShared>,
        listener: TcpListener,
    ) -> Result<()> {
        listener.set_nonblocking(true)?;
        let ep = Epoll::new()?;
        // self-pipe: workers completing requests write one byte to wake
        // epoll_wait; the loop drains the pipe and the completion queue
        let (wake_rx, wake_tx) = UnixStream::pair()?;
        wake_rx.set_nonblocking(true)?;
        wake_tx.set_nonblocking(true)?;
        ep.ctl(
            EPOLL_CTL_ADD,
            listener.as_raw_fd(),
            EPOLLIN,
            TOK_LISTENER,
        )?;
        ep.ctl(EPOLL_CTL_ADD, wake_rx.as_raw_fd(), EPOLLIN, TOK_WAKE)?;
        let cq = CompletionQueue::new(Box::new(move || {
            let _ = (&wake_tx).write(&[1u8]);
        }));
        let clients: Vec<PoolClient> =
            sh.registry.pools().iter().map(|p| p.client()).collect();

        let mut events = vec![EpollEvent { events: 0, data: 0 }; 256];
        let mut slots: Vec<Slot> = Vec::new();
        let mut free: Vec<usize> = Vec::new();
        let mut touched: Vec<usize> = Vec::new();
        loop {
            if sh.stopping() {
                break;
            }
            let n = ep.wait(&mut events, 100);
            touched.clear();
            for e in &events[..n] {
                // packed struct: copy the fields out, never reference
                let ev = e.events;
                let tok = e.data;
                match tok {
                    TOK_LISTENER => {
                        accept_all(sh, &listener, &ep, &mut slots, &mut free)
                    }
                    TOK_WAKE => {
                        let mut b = [0u8; 64];
                        while let Ok(k) = (&wake_rx).read(&mut b) {
                            if k == 0 {
                                break;
                            }
                        }
                    }
                    _ => {
                        let slot = token_slot(tok);
                        if slot >= slots.len()
                            || slots[slot].gen != token_gen(tok)
                        {
                            continue; // stale event for a closed conn
                        }
                        if ev & (EPOLLIN | EPOLLERR | EPOLLHUP | EPOLLRDHUP)
                            != 0
                        {
                            let gen = slots[slot].gen;
                            if let Some(conn) = slots[slot].conn.as_mut() {
                                read_and_dispatch(
                                    conn, &clients, sh, &cq, slot, gen,
                                );
                            }
                        }
                        // EPOLLOUT needs no special handling: maintain()
                        // below flushes every touched connection
                        touched.push(slot);
                    }
                }
            }
            // drain completions unconditionally (not only on a wake
            // event): immune to any lost-wakeup interleaving
            for (tok, reply) in cq.drain() {
                let slot = token_slot(tok);
                let Some(s) = slots.get_mut(slot) else { continue };
                if s.gen != token_gen(tok) {
                    continue; // the conn this belonged to is gone
                }
                let Some(conn) = s.conn.as_mut() else { continue };
                let seq = token_seq(tok);
                if let Some(p) = conn
                    .pending
                    .iter_mut()
                    .find(|p| p.seq == seq && p.done.is_none())
                {
                    p.done = Some(format_reply(&reply));
                }
                touched.push(slot);
            }
            touched.sort_unstable();
            touched.dedup();
            for i in 0..touched.len() {
                maintain(&ep, &mut slots, &mut free, touched[i], sh);
            }
        }
        // teardown: close every live connection (pools keep running)
        for (slot, s) in slots.iter_mut().enumerate() {
            if let Some(conn) = s.conn.take() {
                ep.del(conn.stream.as_raw_fd());
                s.gen = s.gen.wrapping_add(1);
                free.push(slot);
                sh.conn_closed();
            }
        }
        Ok(())
    }

    fn accept_all(
        sh: &Arc<FrontShared>,
        listener: &TcpListener,
        ep: &Epoll,
        slots: &mut Vec<Slot>,
        free: &mut Vec<usize>,
    ) {
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nodelay(true);
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    sh.accepted.inc();
                    let slot = free.pop().unwrap_or_else(|| {
                        slots.push(Slot { gen: 0, conn: None });
                        slots.len() - 1
                    });
                    let gen = slots[slot].gen;
                    let tok = conn_token(slot, gen);
                    if ep
                        .ctl(
                            EPOLL_CTL_ADD,
                            stream.as_raw_fd(),
                            EPOLLIN | EPOLLRDHUP,
                            tok,
                        )
                        .is_err()
                    {
                        free.push(slot);
                        continue;
                    }
                    slots[slot].conn = Some(Conn::new(stream));
                    sh.conn_opened();
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) => {
                    eprintln!("accept failed: {e}");
                    break;
                }
            }
        }
    }

    /// Drain the socket, split complete lines, classify and submit.
    fn read_and_dispatch(
        conn: &mut Conn,
        clients: &[PoolClient],
        sh: &FrontShared,
        cq: &Arc<CompletionQueue>,
        slot: usize,
        gen: u16,
    ) {
        let mut buf = [0u8; 4096];
        loop {
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    conn.peer_closed = true;
                    break;
                }
                Ok(k) => conn.rbuf.extend_from_slice(&buf[..k]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    conn.peer_closed = true;
                    break;
                }
            }
        }
        while let Some(pos) = conn.rbuf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = conn.rbuf.drain(..=pos).collect();
            let text = String::from_utf8_lossy(&line);
            dispatch_line(conn, text.trim(), clients, sh, cq, slot, gen);
        }
        if conn.rbuf.len() > MAX_LINE_BYTES {
            // unbounded line: refuse rather than buffer forever
            let seq = conn.alloc_seq();
            conn.pending.push_back(Pending {
                seq,
                done: Some("error: line too long\n".to_string()),
            });
            conn.rbuf.clear();
            conn.peer_closed = true;
        }
    }

    fn dispatch_line(
        conn: &mut Conn,
        t: &str,
        clients: &[PoolClient],
        sh: &FrontShared,
        cq: &Arc<CompletionQueue>,
        slot: usize,
        gen: u16,
    ) {
        match classify(sh, t) {
            Action::Nothing => {}
            Action::Text(s) => {
                let seq = conn.alloc_seq();
                conn.pending.push_back(Pending { seq, done: Some(s) });
            }
            Action::Infer(idx, x) => {
                if conn.pending.len() >= MAX_PENDING_PER_CONN {
                    let seq = conn.alloc_seq();
                    conn.pending.push_back(Pending {
                        seq,
                        done: Some(
                            "error: too many pipelined requests\n".to_string(),
                        ),
                    });
                    return;
                }
                let seq = conn.alloc_seq();
                let c = &clients[idx];
                let reply = ReplyTo::Completion {
                    cq: cq.clone(),
                    token: completion_token(slot, gen, seq),
                };
                match c.submit_to(x, c.deadline(), reply) {
                    Ok(()) => {
                        conn.pending.push_back(Pending { seq, done: None })
                    }
                    Err(e) => conn.pending.push_back(Pending {
                        seq,
                        done: Some(format_submit_error(&e)),
                    }),
                }
            }
        }
    }

    /// Move completed in-order replies into the write buffer and write
    /// as much as the socket takes.
    fn flush(conn: &mut Conn) -> std::io::Result<()> {
        while let Some(front) = conn.pending.front_mut() {
            match front.done.take() {
                Some(s) => {
                    conn.wbuf.extend_from_slice(s.as_bytes());
                    conn.pending.pop_front();
                }
                None => break,
            }
        }
        while conn.wpos < conn.wbuf.len() {
            match conn.stream.write(&conn.wbuf[conn.wpos..]) {
                Ok(0) => {
                    return Err(std::io::Error::from(ErrorKind::WriteZero))
                }
                Ok(k) => conn.wpos += k,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        if conn.wpos >= conn.wbuf.len() {
            conn.wbuf.clear();
            conn.wpos = 0;
            conn.want_write = false;
        } else {
            conn.want_write = true;
        }
        Ok(())
    }

    /// Post-event housekeeping for one slot: flush, adjust EPOLLOUT
    /// interest, tear the connection down when finished or failed.
    fn maintain(
        ep: &Epoll,
        slots: &mut [Slot],
        free: &mut Vec<usize>,
        slot: usize,
        sh: &FrontShared,
    ) {
        let close_now = {
            let s = &mut slots[slot];
            let gen = s.gen;
            let Some(conn) = s.conn.as_mut() else { return };
            let dead = flush(conn).is_err()
                || (conn.peer_closed
                    && conn.pending.is_empty()
                    && conn.wbuf.is_empty());
            if !dead && conn.want_write != conn.registered_out {
                let mask = if conn.want_write {
                    EPOLLIN | EPOLLRDHUP | EPOLLOUT
                } else {
                    EPOLLIN | EPOLLRDHUP
                };
                let tok = conn_token(slot, gen);
                if ep
                    .ctl(EPOLL_CTL_MOD, conn.stream.as_raw_fd(), mask, tok)
                    .is_ok()
                {
                    conn.registered_out = conn.want_write;
                }
            }
            dead
        };
        if close_now {
            let s = &mut slots[slot];
            if let Some(conn) = s.conn.take() {
                ep.del(conn.stream.as_raw_fd());
            }
            // a new generation invalidates completion tokens still in
            // flight for the closed session
            s.gen = s.gen.wrapping_add(1);
            free.push(slot);
            sh.conn_closed();
        }
    }
}
