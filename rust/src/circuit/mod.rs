//! Behavioral circuit simulation of the dual-9T SRAM macro (§2.2-2.3).
//!
//! Stands in for the paper's 65 nm SPICE testbench (DESIGN.md §5): the
//! mechanisms that produce Fig. 7's statistics — per-device mismatch,
//! process corners, replica biasing, zero-crossing calibration, sense-amp
//! offset — are modeled behaviorally and Monte-Carlo'd; voltages are
//! expressed in MAC-value units (1 ramp cell = the paper's minimum step
//! of 10).

pub mod bitcell;
pub mod corners;
pub mod montecarlo;
pub mod ramp;
pub mod sense_amp;

pub use bitcell::{DualNineT, TernaryWeight};
pub use corners::{Corner, CornerParams};
pub use montecarlo::{ConversionStats, MonteCarlo, MonteCarloConfig};
pub use ramp::RampGenerator;
pub use sense_amp::SenseAmp;

/// MAC units per ramp cell: Fig. 7 states "the minimum step size of the
/// NL-ADC is 10".
pub const MAC_UNITS_PER_CELL: f64 = 10.0;

/// Crossbar geometry of the paper's macro.
pub const ROWS: usize = 256;
pub const COLS: usize = 128;
/// Zero-crossing calibration consumes 4 bitcells, leaving 252 (§2.3).
pub const CALIB_CELLS: usize = 4;
pub const USABLE_CELLS: usize = ROWS - CALIB_CELLS;
