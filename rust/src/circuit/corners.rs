//! Process corners (Fig. 7: TT / FF / SS).
//!
//! Each corner scales two things in the behavioral model:
//! * `drive` — absolute cell drive strength (slow devices discharge the
//!   bitline less per pulse).  With replica biasing the ramp and MAC
//!   columns share this factor, so it cancels in the comparison; without
//!   it, the factor shows up as a gain error (the ablation the paper's
//!   "due to replica biasing" sentence implies).
//! * `mismatch` — relative device-to-device variation.  Slow-slow devices
//!   operate at lower overdrive and suffer relatively more mismatch; the
//!   1.2x factor reproduces the paper's sigma(SS)/sigma(TT).

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Corner {
    TT,
    FF,
    SS,
}

#[derive(Clone, Copy, Debug)]
pub struct CornerParams {
    /// absolute drive-strength factor vs TT
    pub drive: f64,
    /// mismatch scale vs TT
    pub mismatch: f64,
}

impl Corner {
    pub const ALL: [Corner; 3] = [Corner::SS, Corner::TT, Corner::FF];

    pub fn name(&self) -> &'static str {
        match self {
            Corner::TT => "TT",
            Corner::FF => "FF",
            Corner::SS => "SS",
        }
    }

    pub fn params(&self) -> CornerParams {
        match self {
            Corner::TT => CornerParams {
                drive: 1.0,
                mismatch: 1.0,
            },
            Corner::FF => CornerParams {
                drive: 1.15,
                mismatch: 0.95,
            },
            Corner::SS => CornerParams {
                drive: 0.85,
                mismatch: 1.2,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corner_ordering() {
        let tt = Corner::TT.params();
        let ff = Corner::FF.params();
        let ss = Corner::SS.params();
        assert!(ff.drive > tt.drive && tt.drive > ss.drive);
        assert!(ss.mismatch > tt.mismatch && tt.mismatch >= ff.mismatch);
        // the paper's headline ratio
        assert!((ss.mismatch / tt.mismatch - 1.2).abs() < 1e-12);
    }
}
