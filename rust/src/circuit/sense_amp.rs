//! Double-differential sense amplifier (from [14], shared-reference
//! scheme): compares the held V_MAC on the bitline capacitors against the
//! global ramp V_ADC.  Behavioral model: a fabrication-time input offset
//! plus per-comparison thermal noise.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SenseAmp {
    /// input-referred offset, MAC units (fixed per instance)
    pub offset: f64,
    /// per-comparison thermal noise sigma, MAC units
    pub thermal_sigma: f64,
}

impl SenseAmp {
    pub fn fabricate(
        offset_sigma: f64,
        thermal_sigma: f64,
        mismatch_scale: f64,
        rng: &mut Rng,
    ) -> Self {
        SenseAmp {
            offset: rng.normal(0.0, offset_sigma * mismatch_scale),
            thermal_sigma: thermal_sigma * mismatch_scale,
        }
    }

    /// One comparison: true iff V_MAC (plus offset & noise) >= V_ADC.
    pub fn compare(&self, v_mac: f64, v_adc: f64, rng: &mut Rng) -> bool {
        let noise = if self.thermal_sigma > 0.0 {
            rng.normal(0.0, self.thermal_sigma)
        } else {
            0.0
        };
        v_mac + self.offset + noise >= v_adc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noiseless_compare_is_exact() {
        let sa = SenseAmp {
            offset: 0.0,
            thermal_sigma: 0.0,
        };
        let mut rng = Rng::new(0);
        assert!(sa.compare(1.0, 0.5, &mut rng));
        assert!(!sa.compare(0.4, 0.5, &mut rng));
    }

    #[test]
    fn offset_shifts_threshold() {
        let sa = SenseAmp {
            offset: 1.0,
            thermal_sigma: 0.0,
        };
        let mut rng = Rng::new(0);
        assert!(sa.compare(0.0, 0.5, &mut rng)); // 0 + 1 >= 0.5
    }

    #[test]
    fn thermal_noise_flips_marginal_decisions() {
        let sa = SenseAmp {
            offset: 0.0,
            thermal_sigma: 1.0,
        };
        let mut rng = Rng::new(4);
        let mut trues = 0;
        for _ in 0..2000 {
            if sa.compare(0.0, 0.0, &mut rng) {
                trues += 1;
            }
        }
        // marginal input: decisions split roughly evenly
        assert!((800..1200).contains(&trues), "trues={trues}");
    }
}
