//! Monte-Carlo conversion experiment — the behavioral stand-in for the
//! paper's SPICE study (Fig. 7): fabricate column instances (ramp + sense
//! amp with device mismatch), convert random MAC values, and fit a
//! Gaussian to the analog conversion error in MAC units.
//!
//! Calibration anchors (TT, 6-bit input / 4-bit output, min step 10):
//! error ~ N(0.21, 1.07); sigma(SS)/sigma(TT) ~ 1.2 thanks to replica
//! biasing — the corner drive factor rides on both the MAC array and the
//! ramp replica cells and cancels in the comparison, so only the
//! mismatch scaling survives.  With `replica_bias = false` (ablation) the
//! ramp is generated from a nominal reference while the MAC voltage
//! scales with the corner drive, producing a gain error.

use crate::circuit::corners::Corner;
use crate::circuit::ramp::RampGenerator;
use crate::circuit::sense_amp::SenseAmp;
use crate::circuit::MAC_UNITS_PER_CELL;
use crate::util::rng::Rng;
use crate::util::stats;

#[derive(Clone, Debug)]
pub struct MonteCarloConfig {
    /// relative per-cell drive mismatch (sigma)
    pub sigma_cell: f64,
    /// SA input-referred offset sigma, MAC units
    pub sa_offset_sigma: f64,
    /// SA per-comparison thermal noise sigma, MAC units
    pub sa_thermal_sigma: f64,
    /// systematic residue of zero-crossing calibration, MAC units
    pub calib_residual: f64,
    /// replica biasing on (paper) or off (ablation)
    pub replica_bias: bool,
    /// fabricated column instances
    pub instances: usize,
    /// conversions per instance
    pub conversions: usize,
}

impl Default for MonteCarloConfig {
    fn default() -> Self {
        MonteCarloConfig {
            sigma_cell: 0.02,
            sa_offset_sigma: 0.55,
            sa_thermal_sigma: 0.45,
            calib_residual: 0.21,
            replica_bias: true,
            instances: 64,
            conversions: 512,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ConversionStats {
    pub corner: Corner,
    /// Gaussian fit of the analog conversion error, MAC units
    pub mu: f64,
    pub sigma: f64,
    /// fraction of conversions whose output code differed from ideal
    pub code_error_rate: f64,
    /// mean |code error| in codebook steps when a code error happens
    pub mean_code_error_steps: f64,
    pub samples: usize,
}

pub struct MonteCarlo {
    pub cfg: MonteCarloConfig,
}

impl MonteCarlo {
    pub fn new(cfg: MonteCarloConfig) -> Self {
        MonteCarlo { cfg }
    }

    /// Run the Fig. 7 experiment at one corner for a reference ladder
    /// given as integer cell steps (e.g. a 4-bit NL codebook's 16 steps).
    pub fn run(&self, corner: Corner, steps: &[usize], seed: u64) -> ConversionStats {
        let p = corner.params();
        let mut rng = Rng::new(seed ^ 0xC0FF_EE00);
        let total_cells: usize = steps.iter().sum();
        let span = total_cells as f64 * MAC_UNITS_PER_CELL;
        let base = -0.5 * span; // bipolar MAC range, ramp starts negative

        // ideal ladder for the same steps
        let mut ideal = Vec::with_capacity(steps.len());
        {
            let mut v = base;
            for &n in steps {
                ideal.push(v);
                v += n as f64 * MAC_UNITS_PER_CELL;
            }
        }

        let mut analog_errors = Vec::new();
        let mut code_errors = 0usize;
        let mut code_error_mag = 0usize;
        for inst in 0..self.cfg.instances {
            // Replica bias: ramp cells see the same corner drive as the
            // MAC array -> the factor cancels; model both sides at
            // nominal drive with mismatch only.  Ablation: ramp nominal,
            // MAC voltage carries the drive factor.
            let ramp = RampGenerator::fabricate(
                self.cfg.sigma_cell,
                p.mismatch,
                1.0,
                self.cfg.calib_residual,
                &mut rng,
            );
            let sa = SenseAmp::fabricate(
                self.cfg.sa_offset_sigma,
                self.cfg.sa_thermal_sigma,
                p.mismatch,
                &mut Rng::new(seed.wrapping_add(1) ^ ((inst as u64) << 17)),
            );
            let refs = ramp.generate(base, steps);
            for _ in 0..self.cfg.conversions {
                let v_ideal = rng.range(base, base + span);
                let v_eff = if self.cfg.replica_bias {
                    v_ideal
                } else {
                    v_ideal * p.drive
                };
                // thermometer conversion against the actual ladder (the
                // 128 SAs share the ramp; one column modeled here)
                let mut code = 0usize;
                for (i, &r) in refs.iter().enumerate() {
                    if sa.compare(v_eff, r, &mut rng) {
                        code = i;
                    }
                }
                let ideal_code =
                    ideal.iter().rposition(|&r| v_ideal >= r).unwrap_or(0);
                // analog error: effective threshold shift at the landing
                // code = SA offset + thermal noise of the decisive
                // comparison + calibration residue & local ramp deviation
                // (refs[code] - ideal[code]) + gain error when replica
                // bias is off
                let gain_err = if self.cfg.replica_bias {
                    0.0
                } else {
                    (p.drive - 1.0) * v_ideal
                };
                let analog_err = sa.offset
                    + rng.normal(0.0, sa.thermal_sigma)
                    + (refs[code] - ideal[code])
                    + gain_err;
                analog_errors.push(analog_err);
                if code != ideal_code {
                    code_errors += 1;
                    code_error_mag += code.abs_diff(ideal_code);
                }
            }
        }
        let (mu, sigma) = stats::gaussian_fit(&analog_errors);
        ConversionStats {
            corner,
            mu,
            sigma,
            code_error_rate: code_errors as f64 / analog_errors.len() as f64,
            mean_code_error_steps: if code_errors > 0 {
                code_error_mag as f64 / code_errors as f64
            } else {
                0.0
            },
            samples: analog_errors.len(),
        }
    }

    /// Run all three corners (Fig. 7's three panels).
    pub fn run_corners(&self, steps: &[usize], seed: u64) -> Vec<ConversionStats> {
        Corner::ALL
            .iter()
            .map(|&c| self.run(c, steps, seed))
            .collect()
    }
}

/// A 4-bit NL ladder within the paper's 32-cell budget (16 steps, denser
/// near zero like a BS-KMQ codebook); min step = 1 cell = 10 MAC units.
pub fn default_4bit_steps() -> Vec<usize> {
    vec![1, 1, 1, 1, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 4, 6]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tt_stats_match_paper_anchor() {
        let mc = MonteCarlo::new(MonteCarloConfig::default());
        let s = mc.run(Corner::TT, &default_4bit_steps(), 42);
        assert!((s.mu - 0.21).abs() < 0.2, "mu {} vs paper 0.21", s.mu);
        assert!(
            (s.sigma - 1.07).abs() < 0.35,
            "sigma {} vs paper 1.07",
            s.sigma
        );
    }

    #[test]
    fn ss_sigma_ratio_about_1p2() {
        let mc = MonteCarlo::new(MonteCarloConfig::default());
        let tt = mc.run(Corner::TT, &default_4bit_steps(), 7);
        let ss = mc.run(Corner::SS, &default_4bit_steps(), 7);
        let ratio = ss.sigma / tt.sigma;
        assert!(
            (1.05..1.4).contains(&ratio),
            "sigma ratio {ratio} should be ~1.2"
        );
    }

    #[test]
    fn replica_bias_ablation_hurts_off_corners() {
        let cfg_off = MonteCarloConfig {
            replica_bias: false,
            ..Default::default()
        };
        let steps = default_4bit_steps();
        let on = MonteCarlo::new(MonteCarloConfig::default())
            .run(Corner::SS, &steps, 3);
        let off = MonteCarlo::new(cfg_off).run(Corner::SS, &steps, 3);
        // without replica biasing the SS gain error dominates
        assert!(
            off.sigma > 1.5 * on.sigma,
            "off sigma {} should dwarf on sigma {}",
            off.sigma,
            on.sigma
        );
    }

    #[test]
    fn code_errors_are_rare_and_small() {
        let mc = MonteCarlo::new(MonteCarloConfig::default());
        let s = mc.run(Corner::TT, &default_4bit_steps(), 11);
        assert!(s.code_error_rate < 0.3, "rate {}", s.code_error_rate);
        assert!(s.mean_code_error_steps <= 1.5);
    }
}
