//! NL-ADC reference (ramp) generation (§2.3, Fig. 3(a)).
//!
//! The ramp is built from the same replica dual-9T bitcells as the MAC
//! array: RWL- cells pull the initial voltage V_initcalib negative, then
//! each conversion step enables `n_i` RWL+ cells (the programmable step
//! size), so the reference ladder is `V_init + dv * cumsum(n_i)` with
//! per-cell mismatch riding on every step.  Zero-crossing calibration
//! trims V_init with the 4 dedicated calibration cells, leaving a small
//! residual offset.

use crate::circuit::bitcell::{DualNineT, TernaryWeight};
use crate::circuit::{CALIB_CELLS, MAC_UNITS_PER_CELL, USABLE_CELLS};
use crate::util::rng::Rng;

/// One fabricated ramp-generation column instance.
pub struct RampGenerator {
    /// replica cells used for ramp steps (up to 252)
    cells: Vec<DualNineT>,
    /// residual offset after zero-crossing calibration, MAC units
    pub residual_offset: f64,
    /// corner drive factor applied to the ramp (cancels under replica bias)
    pub drive: f64,
}

impl RampGenerator {
    /// Fabricate: per-cell mismatch ~ N(0, sigma_cell * mismatch_scale);
    /// zero-crossing calibration leaves `calib_residual` (MAC units) of
    /// systematic offset plus a small random trim error.
    pub fn fabricate(
        sigma_cell: f64,
        mismatch_scale: f64,
        drive: f64,
        calib_residual: f64,
        rng: &mut Rng,
    ) -> Self {
        let cells = (0..USABLE_CELLS)
            .map(|_| {
                DualNineT::fabricate(
                    TernaryWeight::Plus,
                    sigma_cell,
                    mismatch_scale,
                    rng,
                )
            })
            .collect();
        // the 4 calibration cells trim V_init in 1-cell granularity; the
        // leftover is a sub-cell systematic residue + trim noise
        let residual_offset = calib_residual
            + rng.normal(0.0, 0.05 * MAC_UNITS_PER_CELL * mismatch_scale);
        RampGenerator {
            cells,
            residual_offset,
            drive,
        }
    }

    /// Generate the actual reference ladder for integer step sizes
    /// `steps[i]` (bitcells enabled at conversion step i).  `ideal_base`
    /// is the programmed V_initcalib (MAC units).  Returns one actual
    /// reference voltage per step (length = steps.len()).
    pub fn generate(&self, ideal_base: f64, steps: &[usize]) -> Vec<f64> {
        let total: usize = steps.iter().sum();
        assert!(
            total <= self.cells.len(),
            "ramp needs {total} cells, only {} usable (budget 252)",
            self.cells.len()
        );
        let mut refs = Vec::with_capacity(steps.len());
        let mut v = ideal_base * self.drive + self.residual_offset;
        let mut cell_idx = 0;
        for &n in steps {
            refs.push(v);
            let mut dv = 0.0;
            for c in &self.cells[cell_idx..cell_idx + n] {
                dv += MAC_UNITS_PER_CELL
                    * self.drive
                    * (1.0 + c.mismatch);
            }
            cell_idx += n;
            v += dv;
        }
        refs
    }

    /// Cells available for ramp generation (252 of 256; §2.3).
    pub fn capacity(&self) -> usize {
        self.cells.len()
    }
}

/// §2.3 cell accounting: an NL ramp at `bits` needs 2^(bits+1) cells, a
/// linear ramp needs 2^bits; both plus the 4 calibration cells.
pub fn ramp_cells_nl(bits: u32) -> usize {
    (1usize << (bits + 1)) + CALIB_CELLS
}

pub fn ramp_cells_linear(bits: u32) -> usize {
    (1usize << bits) + CALIB_CELLS
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ideal_ramp() -> RampGenerator {
        RampGenerator {
            cells: vec![
                DualNineT {
                    weight: TernaryWeight::Plus,
                    mismatch: 0.0,
                };
                USABLE_CELLS
            ],
            residual_offset: 0.0,
            drive: 1.0,
        }
    }

    #[test]
    fn ideal_ladder_matches_cumsum() {
        let r = ideal_ramp();
        let refs = r.generate(-20.0, &[1, 2, 4, 1]);
        assert_eq!(refs, vec![-20.0, -10.0, 10.0, 50.0]);
    }

    #[test]
    fn capacity_is_252() {
        assert_eq!(ideal_ramp().capacity(), 252);
    }

    #[test]
    #[should_panic(expected = "ramp needs")]
    fn over_budget_panics() {
        let r = ideal_ramp();
        r.generate(0.0, &[200, 100]);
    }

    #[test]
    fn paper_cell_accounting() {
        // 4-bit NL: "only 32 bitcells are required (excluding the four
        // calibration bitcells)"; linear needs 16.
        assert_eq!(ramp_cells_nl(4) - CALIB_CELLS, 32);
        assert_eq!(ramp_cells_linear(4) - CALIB_CELLS, 16);
        // max resolution 7 bits fits the 252 usable cells + 4 calib
        assert!(ramp_cells_nl(7) - CALIB_CELLS <= USABLE_CELLS + CALIB_CELLS);
    }

    #[test]
    fn mismatch_perturbs_ladder() {
        let mut rng = Rng::new(9);
        let r = RampGenerator::fabricate(0.02, 1.0, 1.0, 2.1, &mut rng);
        let refs = r.generate(0.0, &[2, 2, 2]);
        // base offset present, steps near 20 but not exact
        assert!((refs[0] - 2.1).abs() < 3.0);
        let step = refs[1] - refs[0];
        assert!((step - 20.0).abs() < 2.0 && step != 20.0);
    }
}
