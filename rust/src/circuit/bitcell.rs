//! Dual-9T SRAM bitcell behavioral model (Fig. 2(b)).
//!
//! The 6T core stores a ternary weight; the decoupled 6-NMOS read path
//! performs ternary multiplication: RWL+ (positive input) or RWL-
//! (negative input) gates a discharge of RBLL/RBLR depending on the
//! stored weight.  A zero weight creates no discharge path (the energy
//! argument of §2.2).  The multiplication result is the differential
//! voltage V = V_RBLR - V_RBLL, expressed here in MAC units per pulse.

use crate::util::rng::Rng;

/// Ternary weight state, encoded as (V_L, V_R) in the silicon cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TernaryWeight {
    Minus, // V_L=L, V_R=H
    Zero,  // V_L=L, V_R=L
    Plus,  // V_L=H, V_R=L
}

impl TernaryWeight {
    pub fn value(&self) -> i32 {
        match self {
            TernaryWeight::Minus => -1,
            TernaryWeight::Zero => 0,
            TernaryWeight::Plus => 1,
        }
    }

    pub fn from_value(v: i32) -> Self {
        match v.signum() {
            -1 => TernaryWeight::Minus,
            0 => TernaryWeight::Zero,
            _ => TernaryWeight::Plus,
        }
    }
}

/// One dual-9T cell instance with its (fixed at fabrication) mismatch.
#[derive(Clone, Debug)]
pub struct DualNineT {
    pub weight: TernaryWeight,
    /// relative drive mismatch epsilon_i, drawn once per instance
    pub mismatch: f64,
}

impl DualNineT {
    /// Fabricate a cell: mismatch ~ N(0, sigma_cell * corner.mismatch).
    pub fn fabricate(
        weight: TernaryWeight,
        sigma_cell: f64,
        mismatch_scale: f64,
        rng: &mut Rng,
    ) -> Self {
        DualNineT {
            weight,
            mismatch: rng.normal(0.0, sigma_cell * mismatch_scale),
        }
    }

    /// Differential bitline contribution of `pulses` input pulses with the
    /// given polarity, in MAC units (1 pulse * weight 1 = 1 MAC unit at
    /// nominal drive).  `drive` is the corner's absolute factor.
    pub fn discharge(&self, pulses: u32, positive_input: bool, drive: f64) -> f64 {
        let w = self.weight.value() as f64;
        if w == 0.0 || pulses == 0 {
            return 0.0; // no discharge path: zero weight costs nothing
        }
        let x = if positive_input { 1.0 } else { -1.0 };
        w * x * pulses as f64 * drive * (1.0 + self.mismatch)
    }

    /// Whether this cell consumes bitline discharge energy for an input.
    pub fn draws_energy(&self, pulses: u32) -> bool {
        self.weight != TernaryWeight::Zero && pulses > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(w: i32) -> DualNineT {
        DualNineT {
            weight: TernaryWeight::from_value(w),
            mismatch: 0.0,
        }
    }

    #[test]
    fn ternary_multiplication_table() {
        // (weight, input polarity) -> sign of differential voltage
        for &(w, pos, want) in &[
            (1, true, 1.0),
            (1, false, -1.0),
            (-1, true, -1.0),
            (-1, false, 1.0),
            (0, true, 0.0),
            (0, false, 0.0),
        ] {
            assert_eq!(cell(w).discharge(1, pos, 1.0), want, "w={w} pos={pos}");
        }
    }

    #[test]
    fn pulses_scale_linearly() {
        assert_eq!(cell(1).discharge(5, true, 1.0), 5.0);
        assert_eq!(cell(-1).discharge(3, true, 2.0), -6.0);
    }

    #[test]
    fn zero_weight_draws_no_energy() {
        assert!(!cell(0).draws_energy(7));
        assert!(cell(1).draws_energy(7));
        assert!(!cell(1).draws_energy(0));
    }

    #[test]
    fn mismatch_perturbs_drive() {
        let mut rng = Rng::new(1);
        let c = DualNineT::fabricate(TernaryWeight::Plus, 0.02, 1.0, &mut rng);
        let d = c.discharge(1, true, 1.0);
        assert!((d - 1.0).abs() < 0.2 && d != 1.0);
    }
}
