//! Quantization-health telemetry for the digitization step.
//!
//! BS-KMQ's whole premise is distribution shape: ReLU/clamping piles
//! activation mass onto boundary values, and the fitted NL codebook
//! leans its levels into that mass.  This module watches the same
//! signal on *live* traffic, per quantized layer:
//!
//! * **level occupancy** — how many activations landed in each codebook
//!   level (the noiseless floor-ADC mapping of the pre-conversion
//!   value);
//! * **saturation rate** — the share of mass in the boundary bins
//!   (level 0 and level `L-1`), i.e. clipping pressure at either end of
//!   the reference ladder;
//! * a **live [`ValueSketch`]** fed by strided sampling of
//!   pre-conversion activations, diffable against the sketch captured
//!   at calibration time — the drift signal online recalibration
//!   (ROADMAP item 3) will act on.
//!
//! Hooked into the graph executor between `add_bias_relu_into` and
//! `nl_convert_into`, so it sees exactly the values the NL-ADC is about
//! to digitize.  Counters are atomics (occupancy is bucketed locally
//! then added once per level), and sketch inserts take one lock per
//! observed slice — cheap enough to leave on in serving.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

use crate::obs::prometheus::{escape_label, PromWriter};
use crate::quant::codebook::Codebook;
use crate::quant::sketch::ValueSketch;
use crate::util::stats::quantile_sorted;

/// Calibration-time sketch capacity: small enough to clone per replica,
/// big enough that decile estimates are stable.
pub const CALIB_SKETCH_CAP: usize = 2048;
/// Shared salt so live and calibration sketches hash identically.
pub const CALIB_SKETCH_SALT: u64 = 0x51ac_ba5e;
/// Default live-sketch window: once a layer's live sketch has absorbed
/// this many sampled values it restarts empty, so the drift signal
/// tracks *recent* traffic instead of the whole process lifetime (a
/// lifetime sketch would dilute a late shift — and never decay after a
/// codebook hot-swap).  Large enough that decile estimates are stable
/// long before a restart.
pub const DEFAULT_LIVE_WINDOW: u64 = 1 << 20;

/// Fresh sketch with the health-telemetry parameters (used by the
/// calibrator so its sketches stay merge-compatible with live ones).
pub fn health_sketch() -> ValueSketch {
    ValueSketch::new(CALIB_SKETCH_CAP, CALIB_SKETCH_SALT)
}

/// The swappable part of a layer's telemetry: everything derived from
/// the codebook generation currently being served.  Replaced wholesale
/// by [`QuantHealth::rebaseline`] on a codebook hot-swap.
struct LayerBaseline {
    levels: usize,
    /// Unpadded NL reference ladder in f32 — the same precision the
    /// executor compares against, so the noiseless level mapping here
    /// agrees bit-for-bit with a zero-noise forward.
    refs: Vec<f32>,
    occupancy: Vec<AtomicU64>,
    calib: Option<ValueSketch>,
}

struct LayerHealth {
    name: String,
    base: RwLock<LayerBaseline>,
    /// Cumulative across rebaselines (total telemetry coverage).
    observed: AtomicU64,
    live: Mutex<ValueSketch>,
    /// Position of the next value in this layer's activation stream
    /// (drives strided sketch sampling).
    cursor: AtomicU64,
}

/// Pool-wide telemetry over every quantized layer.  Shared via `Arc`
/// across replicas (cloning a `NativeBackend` keeps the same
/// `QuantHealth`), so occupancy aggregates across the whole pool.
pub struct QuantHealth {
    layers: Vec<LayerHealth>,
    sample_every: u64,
    /// Live-sketch restart threshold (sampled values per layer); 0
    /// disables windowing (lifetime sketch, the pre-§15 behavior).
    live_window: AtomicU64,
    /// Times [`QuantHealth::rebaseline`] ran (0 = still on the
    /// calibration-time baseline).
    rebaselines: AtomicU64,
}

impl QuantHealth {
    /// `names`/`nl_books` run parallel over the quantized layers;
    /// `calib_sketches`, when given, must be the calibration-time
    /// sketches in the same order.  `sample_every == 0` disables live
    /// sketching (occupancy stays on).
    pub fn new(
        names: &[String],
        nl_books: &[Codebook],
        calib_sketches: Option<&[ValueSketch]>,
        sample_every: u64,
    ) -> QuantHealth {
        assert_eq!(names.len(), nl_books.len());
        if let Some(cs) = calib_sketches {
            assert_eq!(cs.len(), nl_books.len());
        }
        let layers = names
            .iter()
            .zip(nl_books)
            .enumerate()
            .map(|(i, (name, cb))| LayerHealth {
                name: name.clone(),
                base: RwLock::new(LayerBaseline {
                    levels: cb.levels(),
                    refs: cb.refs.iter().map(|&r| r as f32).collect(),
                    occupancy: (0..cb.levels())
                        .map(|_| AtomicU64::new(0))
                        .collect(),
                    calib: calib_sketches.map(|cs| cs[i].clone()),
                }),
                observed: AtomicU64::new(0),
                live: Mutex::new(health_sketch()),
                cursor: AtomicU64::new(0),
            })
            .collect();
        QuantHealth {
            layers,
            sample_every,
            live_window: AtomicU64::new(DEFAULT_LIVE_WINDOW),
            rebaselines: AtomicU64::new(0),
        }
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn layer_name(&self, q: usize) -> &str {
        &self.layers[q].name
    }

    /// Override the live-sketch window ([`DEFAULT_LIVE_WINDOW`] at
    /// construction; 0 restores the lifetime-sketch behavior).
    pub fn set_live_window(&self, window: u64) {
        self.live_window.store(window, Ordering::Relaxed);
    }

    /// Times the baseline was replaced by a codebook hot-swap.
    pub fn rebaselines(&self) -> u64 {
        self.rebaselines.load(Ordering::SeqCst)
    }

    /// Replace every layer's baseline with freshly fitted codebooks (and
    /// optionally the sketches they were fitted on), restarting the live
    /// sketches and occupancy counters — called on a codebook hot-swap
    /// so post-swap drift is measured against the *new* books on *new*
    /// traffic, never against retired state.  `observed` totals stay
    /// cumulative.
    pub fn rebaseline(
        &self,
        nl_books: &[Codebook],
        calib_sketches: Option<&[ValueSketch]>,
    ) {
        assert_eq!(nl_books.len(), self.layers.len());
        if let Some(cs) = calib_sketches {
            assert_eq!(cs.len(), self.layers.len());
        }
        for (i, layer) in self.layers.iter().enumerate() {
            let cb = &nl_books[i];
            {
                let mut base = layer.base.write().unwrap();
                *base = LayerBaseline {
                    levels: cb.levels(),
                    refs: cb.refs.iter().map(|&r| r as f32).collect(),
                    occupancy: (0..cb.levels())
                        .map(|_| AtomicU64::new(0))
                        .collect(),
                    calib: calib_sketches.map(|cs| cs[i].clone()),
                };
            }
            *layer.live.lock().unwrap() = health_sketch();
            layer.cursor.store(0, Ordering::Relaxed);
        }
        self.rebaselines.fetch_add(1, Ordering::SeqCst);
    }

    /// Record one slice of pre-conversion activations for layer `q`.
    pub fn observe(&self, q: usize, pre: &[f32]) {
        let layer = &self.layers[q];
        if pre.is_empty() {
            return;
        }
        let base = layer.base.read().unwrap();
        // noiseless floor-ADC level per value, bucketed locally so the
        // shared counters see one add per level, not one per element
        let mut local = vec![0u64; base.levels];
        for &v in pre {
            let cnt = base.refs.partition_point(|&r| r <= v);
            let idx = cnt.saturating_sub(1).min(base.levels - 1);
            local[idx] += 1;
        }
        for (slot, &c) in base.occupancy.iter().zip(&local) {
            if c > 0 {
                slot.fetch_add(c, Ordering::Relaxed);
            }
        }
        drop(base);
        layer.observed.fetch_add(pre.len() as u64, Ordering::Relaxed);

        if self.sample_every > 0 {
            let start =
                layer.cursor.fetch_add(pre.len() as u64, Ordering::Relaxed);
            let k = self.sample_every;
            let mut idx = (k - start % k) % k;
            if idx < pre.len() as u64 {
                let window = self.live_window.load(Ordering::Relaxed);
                let mut sk = layer.live.lock().unwrap();
                // windowed restart: a full sketch begins a fresh one, so
                // deciles always describe the most recent window
                if window > 0 && sk.n_seen() >= window {
                    *sk = health_sketch();
                }
                while (idx as usize) < pre.len() {
                    sk.insert(pre[idx as usize] as f64);
                    idx += k;
                }
            }
        }
    }

    /// Per-level hit counts for layer `q` (since the last rebaseline).
    pub fn occupancy(&self, q: usize) -> Vec<u64> {
        self.layers[q]
            .base
            .read()
            .unwrap()
            .occupancy
            .iter()
            .map(|c| c.load(Ordering::SeqCst))
            .collect()
    }

    /// Total activations observed for layer `q`.
    pub fn observed(&self, q: usize) -> u64 {
        self.layers[q].observed.load(Ordering::SeqCst)
    }

    /// (low, high) boundary-bin rates for layer `q`; zeros before any
    /// traffic.
    pub fn saturation(&self, q: usize) -> (f64, f64) {
        let occ = self.occupancy(q);
        let total: u64 = occ.iter().sum();
        if total == 0 {
            return (0.0, 0.0);
        }
        let low = occ[0] as f64 / total as f64;
        let high = occ[occ.len() - 1] as f64 / total as f64;
        (low, high)
    }

    /// Copy of the live sketch for layer `q`.
    pub fn live_sketch(&self, q: usize) -> ValueSketch {
        self.layers[q].live.lock().unwrap().clone()
    }

    /// Live-vs-calibration drift for layer `q`: mean absolute decile
    /// displacement, normalized by the calibration distribution's
    /// q10–q90 spread.  `None` until both sketches hold samples (or when
    /// no calibration sketch was attached).
    pub fn divergence(&self, q: usize) -> Option<f64> {
        let layer = &self.layers[q];
        let base = layer.base.read().unwrap();
        let calib = base.calib.as_ref()?;
        if calib.n_seen() == 0 {
            return None;
        }
        let live = layer.live.lock().unwrap();
        if live.n_seen() == 0 {
            return None;
        }
        let a = calib.expand();
        let b = live.expand();
        drop(live);
        drop(base);
        if a.is_empty() || b.is_empty() {
            return None;
        }
        let spread =
            (quantile_sorted(&a, 0.9) - quantile_sorted(&a, 0.1)).abs() + 1e-9;
        let mut acc = 0.0;
        for i in 1..10 {
            let t = i as f64 / 10.0;
            acc += (quantile_sorted(&a, t) - quantile_sorted(&b, t)).abs();
        }
        Some(acc / 9.0 / spread)
    }

    /// Render every layer's series under the given model label.
    pub fn render(&self, w: &mut PromWriter, model: &str) {
        let model = escape_label(model);
        for (q, layer) in self.layers.iter().enumerate() {
            let lname = escape_label(&layer.name);
            let base = format!("model=\"{model}\",layer=\"{lname}\"");
            w.family(
                "bskmq_level_occupancy_total",
                "counter",
                "activations digitized into each codebook level",
            );
            for (lvl, c) in self.occupancy(q).iter().enumerate() {
                w.raw_sample(
                    "bskmq_level_occupancy_total",
                    &format!("{base},level=\"{lvl}\""),
                    *c as f64,
                );
            }
            let (low, high) = self.saturation(q);
            w.family(
                "bskmq_saturation_rate",
                "gauge",
                "share of activations in the boundary codebook bins",
            );
            w.raw_sample(
                "bskmq_saturation_rate",
                &format!("{base},bin=\"low\""),
                low,
            );
            w.raw_sample(
                "bskmq_saturation_rate",
                &format!("{base},bin=\"high\""),
                high,
            );
            w.family(
                "bskmq_activations_observed_total",
                "counter",
                "pre-conversion activations seen by health telemetry",
            );
            w.raw_sample(
                "bskmq_activations_observed_total",
                &base,
                self.observed(q) as f64,
            );
            if let Some(d) = self.divergence(q) {
                w.family(
                    "bskmq_sketch_divergence",
                    "gauge",
                    "normalized decile drift of live vs calibration \
                     activations",
                );
                w.raw_sample("bskmq_sketch_divergence", &base, d);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_layer_health(sample_every: u64) -> QuantHealth {
        let books = vec![
            Codebook::from_centers(&[0.0, 1.0, 2.0, 3.0]),
            Codebook::from_centers(&[-1.0, 0.0, 1.0]),
        ];
        QuantHealth::new(
            &["a".to_string(), "b".to_string()],
            &books,
            None,
            sample_every,
        )
    }

    #[test]
    fn occupancy_matches_floor_adc() {
        let h = two_layer_health(0);
        // refs of layer 0: [0.0, 0.5, 1.5, 2.5]
        h.observe(0, &[-5.0, 0.0, 0.4, 0.5, 2.0, 99.0]);
        assert_eq!(h.occupancy(0), vec![3, 1, 1, 1]);
        assert_eq!(h.observed(0), 6);
        let (low, high) = h.saturation(0);
        assert!((low - 0.5).abs() < 1e-12);
        assert!((high - 1.0 / 6.0).abs() < 1e-12);
        // untouched layer stays at zero
        assert_eq!(h.occupancy(1), vec![0, 0, 0]);
        assert_eq!(h.saturation(1), (0.0, 0.0));
    }

    #[test]
    fn strided_sketch_sampling() {
        let h = two_layer_health(2);
        let xs: Vec<f32> = (0..10).map(|i| i as f32).collect();
        h.observe(0, &xs);
        // positions 0,2,4,6,8 sampled
        assert_eq!(h.live_sketch(0).n_seen(), 5);
        h.observe(0, &xs[..3]);
        // stream positions 10,12 sampled
        assert_eq!(h.live_sketch(0).n_seen(), 7);
    }

    #[test]
    fn divergence_present_only_with_calibration() {
        let books = vec![Codebook::from_centers(&[0.0, 1.0])];
        let mut calib = health_sketch();
        for i in 0..100 {
            calib.insert(i as f64 / 100.0);
        }
        let h = QuantHealth::new(
            &["a".to_string()],
            &books,
            Some(&[calib]),
            1,
        );
        assert_eq!(h.divergence(0), None, "no live traffic yet");
        let near: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        h.observe(0, &near);
        let base = h.divergence(0).unwrap();
        assert!(base < 0.1, "matched distribution drifted: {base}");
        let far: Vec<f32> = (0..400).map(|i| 5.0 + i as f32 / 100.0).collect();
        h.observe(0, &far);
        let shifted = h.divergence(0).unwrap();
        assert!(
            shifted > base + 0.5,
            "shifted traffic must move divergence: {base} -> {shifted}"
        );
    }

    /// After a rebaseline the drift signal restarts: new refs drive
    /// occupancy, the live sketch is empty, and divergence is measured
    /// against the new calibration sketch only.
    #[test]
    fn rebaseline_restarts_drift_against_new_books() {
        let books = vec![Codebook::from_centers(&[0.0, 1.0])];
        let mut calib = health_sketch();
        for i in 0..100 {
            calib.insert(i as f64 / 100.0);
        }
        let h = QuantHealth::new(
            &["a".to_string()],
            &books,
            Some(std::slice::from_ref(&calib)),
            1,
        );
        // drive far-off traffic: lifetime drift goes large
        let far: Vec<f32> = (0..400).map(|i| 5.0 + i as f32 / 100.0).collect();
        h.observe(0, &far);
        assert!(h.divergence(0).unwrap() > 1.0);
        assert!(h.occupancy(0).iter().sum::<u64>() > 0);
        let seen_before = h.observed(0);

        // hot-swap: new books fitted on the shifted traffic, baseline =
        // a sketch of that traffic
        let new_books = vec![Codebook::from_centers(&[5.0, 9.0])];
        let mut new_calib = health_sketch();
        for &v in &far {
            new_calib.insert(v as f64);
        }
        h.rebaseline(&new_books, Some(std::slice::from_ref(&new_calib)));
        assert_eq!(h.rebaselines(), 1);
        // live sketch restarted: no divergence until fresh traffic
        assert_eq!(h.divergence(0), None);
        assert_eq!(h.occupancy(0), vec![0, 0], "occupancy restarts");
        assert_eq!(h.observed(0), seen_before, "observed stays cumulative");

        // post-swap traffic matching the new baseline: drift stays low
        // (without the rebaseline the lifetime sketch would keep the old
        // mass and the signal would never decay)
        h.observe(0, &far);
        let post = h.divergence(0).unwrap();
        assert!(post < 0.1, "post-swap matched traffic drifted: {post}");
        let (low, _) = h.saturation(0);
        assert!(low > 0.0, "new refs classify the shifted values");
    }

    /// The live sketch is a moving window: once `live_window` sampled
    /// values accumulate it restarts, so an early distribution no longer
    /// pins the deciles late in the process lifetime.
    #[test]
    fn live_sketch_windows_instead_of_accumulating_forever() {
        let h = two_layer_health(1);
        h.set_live_window(8);
        let xs: Vec<f32> = (0..8).map(|i| i as f32).collect();
        h.observe(0, &xs);
        assert_eq!(h.live_sketch(0).n_seen(), 8);
        // the window is full: the next observe restarts the sketch
        h.observe(0, &xs[..3]);
        assert_eq!(h.live_sketch(0).n_seen(), 3);
        // window 0 = lifetime accumulation (pre-§15 behavior)
        h.set_live_window(0);
        for _ in 0..10 {
            h.observe(0, &xs);
        }
        assert_eq!(h.live_sketch(0).n_seen(), 83);
    }
}
