//! End-to-end observability for the BS-KMQ runtime.
//!
//! Four pieces, layered from generic to paper-specific:
//!
//! * [`registry`] — lock-free counters/gauges/fixed-bucket histograms
//!   with snapshot-and-merge semantics, shared via `Arc` across replica
//!   workers;
//! * [`trace`] — one span per admitted request (intake → queue wait →
//!   batch assembly → forward with per-op breakdown → reply), emitted
//!   sampled to a JSONL sink;
//! * [`quant_health`] — per-qlayer codebook level occupancy, boundary
//!   saturation rates, and a live-vs-calibration activation sketch
//!   diff: the boundary-accumulation signal BS-KMQ is built around,
//!   observed on live traffic;
//! * [`prometheus`] + [`bench_report`] — exposition: the `metrics` TCP
//!   command renders Prometheus text, and `bskmq bench` writes the
//!   committed `BENCH_<shortrev>.json` perf trajectory.
//!
//! See DESIGN.md §11 for the architecture.

pub mod bench_report;
pub mod prometheus;
pub mod quant_health;
pub mod registry;
pub mod trace;

pub use bench_report::{BenchReport, ExecBench, ModelBench, ServingPoint};
pub use prometheus::PromWriter;
pub use quant_health::QuantHealth;
pub use registry::{Counter, Gauge, Histogram, HistogramSnapshot, MetricsRegistry};
pub use trace::{RequestTracer, Span, TraceSink};
