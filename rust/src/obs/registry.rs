//! Lock-free metrics registry shared across replica workers.
//!
//! Hot-path instruments ([`Counter`], [`Gauge`], [`Histogram`]) are plain
//! atomics behind `Arc` handles: registration takes a `Mutex` once, but
//! every increment/observe afterwards is a single atomic RMW with no
//! allocation.  Histograms use fixed bucket bounds chosen at registration
//! and a fixed-point (×1000) atomic sum so that concurrent observation
//! followed by [`Histogram::snapshot`] is deterministic: N threads each
//! recording the same multiset always produce the identical snapshot.
//! Snapshots [`HistogramSnapshot::merge`] associatively, which is what
//! lets per-replica registries fold into a pool-level view.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use super::prometheus::PromWriter;

/// Fixed-point scale for histogram sums (1e-3 resolution).
const SUM_SCALE: f64 = 1000.0;

/// Monotonic atomic counter.
#[derive(Debug, Default)]
pub struct Counter {
    n: AtomicU64,
}

impl Counter {
    pub fn new() -> Counter {
        Counter { n: AtomicU64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        self.n.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, k: u64) {
        self.n.fetch_add(k, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.n.load(Ordering::SeqCst)
    }
}

/// Last-write-wins gauge (f64 stored as bits).
#[derive(Debug)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge { bits: AtomicU64::new(0f64.to_bits()) }
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::SeqCst))
    }
}

/// Fixed-bucket histogram: `bounds` are the inclusive upper bounds of the
/// first `bounds.len()` buckets; one overflow bucket follows.  The sum is
/// kept in fixed point so concurrent `observe` calls commute exactly.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    sum_scaled: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    /// `bounds` must be strictly increasing and non-empty.
    pub fn new(bounds: &[f64]) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one bound");
        for w in bounds.windows(2) {
            assert!(w[0] < w[1], "histogram bounds must increase");
        }
        let buckets = (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            bounds: bounds.to_vec(),
            buckets,
            sum_scaled: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Default latency bounds in milliseconds (sub-ms to 10 s).
    pub fn latency_ms_bounds() -> Vec<f64> {
        vec![
            0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
            500.0, 1000.0, 2500.0, 10_000.0,
        ]
    }

    #[inline]
    pub fn observe(&self, v: f64) {
        // first bucket whose bound is >= v; equal values land low so the
        // mapping is a pure function of the value.
        let i = self.bounds.partition_point(|&b| b < v);
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        let scaled = (v.max(0.0) * SUM_SCALE).round() as u64;
        self.sum_scaled.fetch_add(scaled, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::SeqCst)
    }

    /// Point-in-time copy; deterministic once all writers are quiescent.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            bounds: self.bounds.clone(),
            counts: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::SeqCst))
                .collect(),
            sum_scaled: self.sum_scaled.load(Ordering::SeqCst),
            count: self.count.load(Ordering::SeqCst),
        }
    }
}

/// Immutable histogram state; merging is associative and commutative.
#[derive(Clone, Debug, PartialEq)]
pub struct HistogramSnapshot {
    pub bounds: Vec<f64>,
    pub counts: Vec<u64>,
    pub sum_scaled: u64,
    pub count: u64,
}

impl HistogramSnapshot {
    pub fn merge(&mut self, other: &HistogramSnapshot) -> Result<()> {
        ensure!(
            self.bounds == other.bounds,
            "merging histograms with different bucket bounds"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum_scaled += other.sum_scaled;
        self.count += other.count;
        Ok(())
    }

    pub fn sum(&self) -> f64 {
        self.sum_scaled as f64 / SUM_SCALE
    }

    /// Render as a Prometheus histogram family.
    pub fn render(&self, w: &mut PromWriter, name: &str, labels: &str) {
        w.family(name, "histogram", "");
        let mut cum = 0u64;
        for (i, &b) in self.bounds.iter().enumerate() {
            cum += self.counts[i];
            let le = format_bound(b);
            let l = join_labels(labels, &format!("le=\"{le}\""));
            w.raw_sample(&format!("{name}_bucket"), &l, cum as f64);
        }
        cum += self.counts[self.bounds.len()];
        let l = join_labels(labels, "le=\"+Inf\"");
        w.raw_sample(&format!("{name}_bucket"), &l, cum as f64);
        w.raw_sample(&format!("{name}_sum"), labels, self.sum());
        w.raw_sample(&format!("{name}_count"), labels, self.count as f64);
    }
}

fn format_bound(b: f64) -> String {
    if b == b.trunc() && b.abs() < 1e15 {
        format!("{}", b as i64)
    } else {
        format!("{b}")
    }
}

fn join_labels(a: &str, b: &str) -> String {
    if a.is_empty() {
        b.to_string()
    } else {
        format!("{a},{b}")
    }
}

/// A registered instrument.
#[derive(Clone)]
pub enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Name → instrument map.  Registration is idempotent: asking twice for
/// the same name returns the same underlying instrument, so replicas can
/// all register their shared series without coordination.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<BTreeMap<String, Metric>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry { inner: Mutex::new(BTreeMap::new()) }
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.inner.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())))
        {
            Metric::Counter(c) => c.clone(),
            _ => panic!("metric '{name}' already registered as non-counter"),
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.inner.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new())))
        {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("metric '{name}' already registered as non-gauge"),
        }
    }

    pub fn histogram(&self, name: &str, bounds: &[f64]) -> Arc<Histogram> {
        let mut m = self.inner.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new(bounds))))
        {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("metric '{name}' already registered as non-histogram"),
        }
    }

    /// Registered metric names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.inner.lock().unwrap().keys().cloned().collect()
    }

    /// Render every registered instrument into `w`.  Names may carry an
    /// embedded label set (`name{labels}`), split here so families with
    /// many label combinations render under one family header.
    pub fn render(&self, w: &mut PromWriter) {
        let m = self.inner.lock().unwrap();
        for (full, metric) in m.iter() {
            let (name, labels) = split_name_labels(full);
            match metric {
                Metric::Counter(c) => {
                    w.family(name, "counter", "");
                    w.raw_sample(name, labels, c.get() as f64);
                }
                Metric::Gauge(g) => {
                    w.family(name, "gauge", "");
                    w.raw_sample(name, labels, g.get());
                }
                Metric::Histogram(h) => {
                    h.snapshot().render(w, name, labels);
                }
            }
        }
    }
}

/// Split `name{a="b"}` into (`name`, `a="b"`); plain names get no labels.
fn split_name_labels(full: &str) -> (&str, &str) {
    match (full.find('{'), full.rfind('}')) {
        (Some(o), Some(c)) if c > o => (&full[..o], &full[o + 1..c]),
        _ => (full, ""),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = MetricsRegistry::new();
        let c = r.counter("reqs");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // idempotent registration returns the same instrument
        assert_eq!(r.counter("reqs").get(), 5);
        let g = r.gauge("temp");
        g.set(3.25);
        assert_eq!(r.gauge("temp").get(), 3.25);
        assert_eq!(r.names(), vec!["reqs".to_string(), "temp".to_string()]);
    }

    #[test]
    fn histogram_buckets_and_sum() {
        let h = Histogram::new(&[1.0, 2.0]);
        for v in [0.5, 1.0, 1.5, 2.5] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.counts, vec![2, 1, 1]);
        assert_eq!(s.count, 4);
        assert!((s.sum() - 5.5).abs() < 1e-9);
    }

    #[test]
    fn snapshot_merge_requires_matching_bounds() {
        let a = Histogram::new(&[1.0]);
        let b = Histogram::new(&[2.0]);
        a.observe(0.5);
        b.observe(0.5);
        let mut sa = a.snapshot();
        assert!(sa.merge(&b.snapshot()).is_err());
        let c = Histogram::new(&[1.0]);
        c.observe(3.0);
        sa.merge(&c.snapshot()).unwrap();
        assert_eq!(sa.counts, vec![1, 1]);
    }

    #[test]
    fn split_labels() {
        assert_eq!(split_name_labels("a"), ("a", ""));
        assert_eq!(
            split_name_labels("a{x=\"y\"}"),
            ("a", "x=\"y\"")
        );
    }
}
