//! Prometheus text exposition (format version 0.0.4).
//!
//! No client library is vendored, so this is a minimal writer: one
//! `# HELP` / `# TYPE` header per family (emitted once even when many
//! label combinations sample into it), then plain `name{labels} value`
//! lines.  Consumers are the `metrics` protocol command on the serving
//! TCP front and the obs tests.

use std::collections::BTreeSet;

/// Accumulates exposition text.
#[derive(Default)]
pub struct PromWriter {
    buf: String,
    seen: BTreeSet<String>,
}

impl PromWriter {
    pub fn new() -> PromWriter {
        PromWriter::default()
    }

    /// Emit the `# HELP`/`# TYPE` header for `name` once.
    pub fn family(&mut self, name: &str, kind: &str, help: &str) {
        if self.seen.insert(name.to_string()) {
            if !help.is_empty() {
                self.buf.push_str(&format!("# HELP {name} {help}\n"));
            }
            self.buf.push_str(&format!("# TYPE {name} {kind}\n"));
        }
    }

    /// Emit one sample, declaring the family as a gauge if it has not
    /// been declared yet.
    pub fn sample(&mut self, name: &str, labels: &str, value: f64) {
        self.family(name, "gauge", "");
        self.raw_sample(name, labels, value);
    }

    /// Emit one sample line without touching family headers (for series
    /// like `_bucket` that live under an already-declared family).
    pub fn raw_sample(&mut self, name: &str, labels: &str, value: f64) {
        if labels.is_empty() {
            self.buf.push_str(&format!("{name} {}\n", format_value(value)));
        } else {
            self.buf.push_str(&format!(
                "{name}{{{labels}}} {}\n",
                format_value(value)
            ));
        }
    }

    pub fn finish(self) -> String {
        self.buf
    }
}

/// Escape a label *value* per the exposition format.
pub fn escape_label(v: &str) -> String {
    let mut s = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => s.push_str("\\\\"),
            '"' => s.push_str("\\\""),
            '\n' => s.push_str("\\n"),
            _ => s.push(c),
        }
    }
    s
}

fn format_value(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_once_per_family() {
        let mut w = PromWriter::new();
        w.family("x_total", "counter", "things");
        w.raw_sample("x_total", "m=\"a\"", 1.0);
        w.family("x_total", "counter", "things");
        w.raw_sample("x_total", "m=\"b\"", 2.5);
        let out = w.finish();
        assert_eq!(out.matches("# TYPE x_total counter").count(), 1);
        assert!(out.contains("x_total{m=\"a\"} 1\n"));
        assert!(out.contains("x_total{m=\"b\"} 2.5\n"));
    }

    #[test]
    fn escaping() {
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
