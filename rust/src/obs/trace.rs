//! Request-lifecycle tracing.
//!
//! Every *admitted* request opens exactly one span at submit time and
//! closes it after its reply is sent; rejected submissions never open a
//! span.  Closed spans are serialized as one JSON object per line
//! (JSONL) into a [`TraceSink`], but only a sampled subset is actually
//! emitted (`sample_every`), so steady-state serving does no tracing
//! allocation beyond the span struct the worker already builds for the
//! batch it timed.
//!
//! The open/closed counters are the invariant the tests pin: after a
//! pool shuts down, `opened == closed` — no span leaks, no double close.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

/// One closed request span.  Durations are microseconds; `ops` carries
/// the sampled per-op forward breakdown when profiling was on for the
/// batch this request rode in (empty otherwise).
#[derive(Clone, Debug)]
pub struct Span {
    pub id: u64,
    pub model: String,
    pub replica: u32,
    pub batch_n: usize,
    pub queue_us: u64,
    pub forward_us: u64,
    pub reply_us: u64,
    pub ops: Vec<(String, u64)>,
}

impl Span {
    /// Serialize as one JSONL line (keys are fixed, values numeric or
    /// escaped strings — parseable by `util::json`).
    pub fn to_json_line(&self) -> String {
        let mut s = String::with_capacity(128);
        s.push_str(&format!(
            "{{\"id\":{},\"model\":\"{}\",\"replica\":{},\"batch_n\":{},\
             \"queue_us\":{},\"forward_us\":{},\"reply_us\":{},\"ops\":[",
            self.id,
            escape_json(&self.model),
            self.replica,
            self.batch_n,
            self.queue_us,
            self.forward_us,
            self.reply_us
        ));
        for (i, (name, ns)) in self.ops.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"op\":\"{}\",\"ns\":{}}}",
                escape_json(name),
                ns
            ));
        }
        s.push_str("]}");
        s
    }
}

pub(crate) fn escape_json(v: &str) -> String {
    let mut s = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => s.push_str("\\\\"),
            '"' => s.push_str("\\\""),
            '\n' => s.push_str("\\n"),
            '\r' => s.push_str("\\r"),
            '\t' => s.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                s.push_str(&format!("\\u{:04x}", c as u32))
            }
            _ => s.push(c),
        }
    }
    s
}

enum SinkInner {
    File(Mutex<BufWriter<File>>),
    Memory(Mutex<Vec<String>>),
}

/// Destination for emitted span lines: an append-only JSONL file for
/// production, or an in-memory buffer for tests and the bench harness.
pub struct TraceSink {
    inner: SinkInner,
    written: AtomicU64,
}

impl TraceSink {
    pub fn file(path: &Path) -> Result<Arc<TraceSink>> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("mkdir {}", parent.display()))?;
            }
        }
        let f = File::create(path)
            .with_context(|| format!("create trace file {}", path.display()))?;
        Ok(Arc::new(TraceSink {
            inner: SinkInner::File(Mutex::new(BufWriter::new(f))),
            written: AtomicU64::new(0),
        }))
    }

    pub fn memory() -> Arc<TraceSink> {
        Arc::new(TraceSink {
            inner: SinkInner::Memory(Mutex::new(Vec::new())),
            written: AtomicU64::new(0),
        })
    }

    pub fn emit(&self, line: &str) {
        match &self.inner {
            SinkInner::File(w) => {
                let mut w = w.lock().unwrap();
                let _ = writeln!(w, "{line}");
                let _ = w.flush();
            }
            SinkInner::Memory(v) => v.lock().unwrap().push(line.to_string()),
        }
        self.written.fetch_add(1, Ordering::Relaxed);
    }

    /// Lines captured so far (memory sinks only; empty for file sinks).
    pub fn lines(&self) -> Vec<String> {
        match &self.inner {
            SinkInner::File(_) => Vec::new(),
            SinkInner::Memory(v) => v.lock().unwrap().clone(),
        }
    }

    pub fn written(&self) -> u64 {
        self.written.load(Ordering::SeqCst)
    }
}

/// Per-pool span bookkeeping.  `open` hands out ids at admission;
/// `close` is called by the worker after the reply send.  Emission is
/// sampled by id (`id % sample_every == 0`) so the emitted subset is
/// deterministic under any thread interleaving.
pub struct RequestTracer {
    model: String,
    sample_every: u64,
    next_id: AtomicU64,
    opened: AtomicU64,
    closed: AtomicU64,
    emitted: AtomicU64,
    sink: Option<Arc<TraceSink>>,
}

impl RequestTracer {
    /// `sample_every == 0` disables emission entirely (spans are still
    /// counted, keeping the completeness invariant observable).
    pub fn new(
        model: &str,
        sample_every: u64,
        sink: Option<Arc<TraceSink>>,
    ) -> Arc<RequestTracer> {
        Arc::new(RequestTracer {
            model: model.to_string(),
            sample_every,
            next_id: AtomicU64::new(0),
            opened: AtomicU64::new(0),
            closed: AtomicU64::new(0),
            emitted: AtomicU64::new(0),
            sink,
        })
    }

    /// Open a span for an admitted request; returns its id.
    pub fn open(&self) -> u64 {
        self.opened.fetch_add(1, Ordering::Relaxed);
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Roll back an [`RequestTracer::open`] whose request was refused
    /// admission: the span never existed as far as completeness
    /// accounting (`opened == closed`) is concerned.
    pub fn cancel(&self, _id: u64) {
        self.opened.fetch_sub(1, Ordering::Relaxed);
    }

    /// Whether span `id` would be emitted — lets workers skip building
    /// the op-name vector for unsampled spans.
    pub fn sampled(&self, id: u64) -> bool {
        self.sample_every > 0
            && self.sink.is_some()
            && id % self.sample_every == 0
    }

    /// Close span `id`.  `build` is only invoked when the span is
    /// sampled, so unsampled closes stay allocation-free.
    pub fn close<F: FnOnce() -> Span>(&self, id: u64, build: F) {
        self.closed.fetch_add(1, Ordering::Relaxed);
        if self.sampled(id) {
            let mut span = build();
            span.id = id;
            span.model = self.model.clone();
            if let Some(sink) = &self.sink {
                sink.emit(&span.to_json_line());
                self.emitted.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    pub fn opened(&self) -> u64 {
        self.opened.load(Ordering::SeqCst)
    }

    pub fn closed(&self) -> u64 {
        self.closed.load(Ordering::SeqCst)
    }

    pub fn emitted(&self) -> u64 {
        self.emitted.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn blank_span() -> Span {
        Span {
            id: 0,
            model: String::new(),
            replica: 1,
            batch_n: 4,
            queue_us: 10,
            forward_us: 200,
            reply_us: 3,
            ops: vec![("d0:dense".into(), 1234)],
        }
    }

    #[test]
    fn span_line_parses_as_json() {
        let mut span = blank_span();
        span.model = "res\"net".into();
        let j = Json::parse(&span.to_json_line()).unwrap();
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "res\"net");
        assert_eq!(j.get("queue_us").unwrap().as_usize().unwrap(), 10);
        let ops = j.get("ops").unwrap().as_arr().unwrap();
        assert_eq!(ops[0].get("op").unwrap().as_str().unwrap(), "d0:dense");
    }

    #[test]
    fn sampling_and_counters() {
        let sink = TraceSink::memory();
        let t = RequestTracer::new("m", 2, Some(sink.clone()));
        for _ in 0..6 {
            let id = t.open();
            t.close(id, blank_span);
        }
        assert_eq!(t.opened(), 6);
        assert_eq!(t.closed(), 6);
        assert_eq!(t.emitted(), 3, "ids 0,2,4 sampled");
        assert_eq!(sink.lines().len(), 3);
        for line in sink.lines() {
            Json::parse(&line).unwrap();
        }
    }

    #[test]
    fn sample_every_zero_emits_nothing() {
        let sink = TraceSink::memory();
        let t = RequestTracer::new("m", 0, Some(sink.clone()));
        let id = t.open();
        t.close(id, blank_span);
        assert_eq!(t.closed(), 1);
        assert_eq!(t.emitted(), 0);
        assert!(sink.lines().is_empty());
    }
}
