//! BENCH_*.json: the committed perf-trajectory artifact.
//!
//! `bskmq bench` runs a standard workload per topology and writes
//! `BENCH_<shortrev>.json` at the repo root so performance is tracked
//! in-repo alongside the code (ROADMAP item 1).  This module owns the
//! schema — the struct, its hand-written serializer (no serde offline),
//! and a validator the CI smoke runs against freshly emitted files.
//! The workload orchestration itself lives in `main.rs`.

use std::path::{Path, PathBuf};
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::Json;

/// Bump when the BENCH json layout changes.
/// v2: adds the `serving` section (closed-loop load-harness points:
/// latency percentiles, throughput, and shed rate vs offered load).
/// v3: adds the `exec` section (executor-pool vs scoped-spawn qfwd
/// timings, per-op ns, thread-budget config) and records
/// `replicas`/`exec_threads` on every serving point so load numbers are
/// comparable across machines.
/// v4: records hot-swap telemetry on every serving point
/// (`swaps`/`swap_ns`/`inflight_at_swap`) so the recalibration
/// swap-under-load phase is tracked in the trajectory.
pub const BENCH_SCHEMA_VERSION: u64 = 4;

/// Per-topology measurements.
#[derive(Clone, Debug, Default)]
pub struct ModelBench {
    pub model: String,
    pub batch: usize,
    /// Quantized forwards per second (one forward = one batch).
    pub forwards_per_sec: f64,
    /// Mean wall time of one quantized batch forward.
    pub qfwd_batch_ns: u64,
    /// Calibration throughput: samples absorbed per second.
    pub calib_samples_per_sec: f64,
    pub serve_p50_ms: f64,
    pub serve_p99_ms: f64,
    pub serve_p999_ms: f64,
    pub serve_requests: u64,
    pub serve_rejected: u64,
    /// Queue-wait percentiles from the same serving run.
    pub queue_p50_ms: f64,
    pub queue_p99_ms: f64,
    /// Mean nanoseconds per op from `run_qfwd_profiled`.
    pub per_op_ns: Vec<(String, u64)>,
}

/// One closed-loop load-harness measurement: `offered` concurrent
/// clients driving `requests` requests against a pool, every request
/// accounted for as completed, shed (deadline overload), rejected
/// (admission), or errored.
#[derive(Clone, Debug, Default)]
pub struct ServingPoint {
    /// which sweep this point belongs to (e.g. "ladder", "overload")
    pub phase: String,
    pub model: String,
    /// offered load: closed-loop client threads
    pub offered: usize,
    pub requests: u64,
    pub completed: u64,
    pub shed: u64,
    pub rejected: u64,
    pub errors: u64,
    pub wall_s: f64,
    /// completed requests per second of wall time
    pub throughput_rps: f64,
    /// latency percentiles over *admitted completed* requests
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    /// the per-request deadline the point ran with
    pub deadline_ms: f64,
    /// replicas live when the point ran (0 when the harness ran inline
    /// without a pool)
    pub replicas: usize,
    /// global executor thread budget (`BSKMQ_THREADS`) the point ran with
    pub exec_threads: usize,
    /// codebook hot-swaps completed while the point ran (schema v4)
    pub swaps: u64,
    /// wall nanos of the last refit + swap during the point (0 = none)
    pub swap_ns: u64,
    /// pool queue depth at the last swap instant (requests in flight
    /// while the generation changed under them)
    pub inflight_at_swap: u64,
}

impl ServingPoint {
    /// Fraction of offered requests shed past their deadline.
    pub fn shed_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.shed as f64 / self.requests as f64
        }
    }
}

/// One executor measurement (schema v3): the same quantized forward
/// timed on the legacy per-op scoped-spawn path and through the
/// persistent executor pool with the cached `LayerPlan`, under a stated
/// thread budget.  `speedup` > 1 means the pool path is faster.
#[derive(Clone, Debug, Default)]
pub struct ExecBench {
    pub model: String,
    pub batch: usize,
    /// thread budget the measurement ran under (`BSKMQ_THREADS`)
    pub exec_threads: usize,
    /// parked workers in the persistent pool (budget - 1; the submitter
    /// is the remaining thread)
    pub pool_workers: usize,
    /// mean ns of one quantized batch forward, per-op scoped spawn
    pub spawn_qfwd_ns: u64,
    /// mean ns of one quantized batch forward, pool + cached plan
    pub pool_qfwd_ns: u64,
    /// spawn_qfwd_ns / pool_qfwd_ns
    pub speedup: f64,
    /// pool-path per-op mean ns from `run_qfwd_profiled`
    pub per_op_ns: Vec<(String, u64)>,
}

/// The whole report.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub schema: u64,
    pub shortrev: String,
    pub generated_unix: u64,
    pub quick: bool,
    /// `false` marks hand-seeded placeholder numbers (no benchmark run
    /// backs them); CI regenerates with `measured: true`.
    pub measured: bool,
    pub host_threads: usize,
    pub note: String,
    pub models: Vec<ModelBench>,
    /// closed-loop load-harness points (schema v2)
    pub serving: Vec<ServingPoint>,
    /// executor-pool vs scoped-spawn measurements (schema v3)
    pub exec: Vec<ExecBench>,
}

impl BenchReport {
    pub fn new(shortrev: &str, quick: bool) -> BenchReport {
        BenchReport {
            schema: BENCH_SCHEMA_VERSION,
            shortrev: shortrev.to_string(),
            generated_unix: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
            quick,
            measured: true,
            host_threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            note: String::new(),
            models: Vec::new(),
            serving: Vec::new(),
            exec: Vec::new(),
        }
    }

    /// `BENCH_<shortrev>.json`.
    pub fn filename(&self) -> String {
        format!("BENCH_{}.json", self.shortrev)
    }

    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(1024);
        s.push_str("{\n");
        s.push_str(&format!("  \"schema\": {},\n", self.schema));
        s.push_str(&format!(
            "  \"shortrev\": \"{}\",\n",
            esc(&self.shortrev)
        ));
        s.push_str(&format!(
            "  \"generated_unix\": {},\n",
            self.generated_unix
        ));
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"measured\": {},\n", self.measured));
        s.push_str(&format!("  \"host_threads\": {},\n", self.host_threads));
        s.push_str(&format!("  \"note\": \"{}\",\n", esc(&self.note)));
        s.push_str("  \"models\": [\n");
        for (i, m) in self.models.iter().enumerate() {
            s.push_str("    {\n");
            s.push_str(&format!("      \"model\": \"{}\",\n", esc(&m.model)));
            s.push_str(&format!("      \"batch\": {},\n", m.batch));
            s.push_str(&format!(
                "      \"forwards_per_sec\": {},\n",
                num(m.forwards_per_sec)
            ));
            s.push_str(&format!(
                "      \"qfwd_batch_ns\": {},\n",
                m.qfwd_batch_ns
            ));
            s.push_str(&format!(
                "      \"calib_samples_per_sec\": {},\n",
                num(m.calib_samples_per_sec)
            ));
            s.push_str(&format!(
                "      \"serve_p50_ms\": {},\n",
                num(m.serve_p50_ms)
            ));
            s.push_str(&format!(
                "      \"serve_p99_ms\": {},\n",
                num(m.serve_p99_ms)
            ));
            s.push_str(&format!(
                "      \"serve_p999_ms\": {},\n",
                num(m.serve_p999_ms)
            ));
            s.push_str(&format!(
                "      \"serve_requests\": {},\n",
                m.serve_requests
            ));
            s.push_str(&format!(
                "      \"serve_rejected\": {},\n",
                m.serve_rejected
            ));
            s.push_str(&format!(
                "      \"queue_p50_ms\": {},\n",
                num(m.queue_p50_ms)
            ));
            s.push_str(&format!(
                "      \"queue_p99_ms\": {},\n",
                num(m.queue_p99_ms)
            ));
            s.push_str("      \"per_op_ns\": [");
            for (j, (op, ns)) in m.per_op_ns.iter().enumerate() {
                if j > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!(
                    "{{\"op\": \"{}\", \"ns\": {}}}",
                    esc(op),
                    ns
                ));
            }
            s.push_str("]\n");
            s.push_str("    }");
            s.push_str(if i + 1 < self.models.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ],\n");
        s.push_str("  \"serving\": [\n");
        for (i, p) in self.serving.iter().enumerate() {
            s.push_str("    {\n");
            s.push_str(&format!("      \"phase\": \"{}\",\n", esc(&p.phase)));
            s.push_str(&format!("      \"model\": \"{}\",\n", esc(&p.model)));
            s.push_str(&format!("      \"offered\": {},\n", p.offered));
            s.push_str(&format!("      \"requests\": {},\n", p.requests));
            s.push_str(&format!("      \"completed\": {},\n", p.completed));
            s.push_str(&format!("      \"shed\": {},\n", p.shed));
            s.push_str(&format!("      \"rejected\": {},\n", p.rejected));
            s.push_str(&format!("      \"errors\": {},\n", p.errors));
            s.push_str(&format!("      \"wall_s\": {},\n", num(p.wall_s)));
            s.push_str(&format!(
                "      \"throughput_rps\": {},\n",
                num(p.throughput_rps)
            ));
            s.push_str(&format!(
                "      \"shed_rate\": {},\n",
                num(p.shed_rate())
            ));
            s.push_str(&format!("      \"p50_ms\": {},\n", num(p.p50_ms)));
            s.push_str(&format!("      \"p99_ms\": {},\n", num(p.p99_ms)));
            s.push_str(&format!("      \"p999_ms\": {},\n", num(p.p999_ms)));
            s.push_str(&format!(
                "      \"deadline_ms\": {},\n",
                num(p.deadline_ms)
            ));
            s.push_str(&format!("      \"replicas\": {},\n", p.replicas));
            s.push_str(&format!(
                "      \"exec_threads\": {},\n",
                p.exec_threads
            ));
            s.push_str(&format!("      \"swaps\": {},\n", p.swaps));
            s.push_str(&format!("      \"swap_ns\": {},\n", p.swap_ns));
            s.push_str(&format!(
                "      \"inflight_at_swap\": {}\n",
                p.inflight_at_swap
            ));
            s.push_str("    }");
            s.push_str(if i + 1 < self.serving.len() {
                ",\n"
            } else {
                "\n"
            });
        }
        s.push_str("  ],\n");
        s.push_str("  \"exec\": [\n");
        for (i, e) in self.exec.iter().enumerate() {
            s.push_str("    {\n");
            s.push_str(&format!("      \"model\": \"{}\",\n", esc(&e.model)));
            s.push_str(&format!("      \"batch\": {},\n", e.batch));
            s.push_str(&format!(
                "      \"exec_threads\": {},\n",
                e.exec_threads
            ));
            s.push_str(&format!(
                "      \"pool_workers\": {},\n",
                e.pool_workers
            ));
            s.push_str(&format!(
                "      \"spawn_qfwd_ns\": {},\n",
                e.spawn_qfwd_ns
            ));
            s.push_str(&format!(
                "      \"pool_qfwd_ns\": {},\n",
                e.pool_qfwd_ns
            ));
            s.push_str(&format!("      \"speedup\": {},\n", num(e.speedup)));
            s.push_str("      \"per_op_ns\": [");
            for (j, (op, ns)) in e.per_op_ns.iter().enumerate() {
                if j > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!(
                    "{{\"op\": \"{}\", \"ns\": {}}}",
                    esc(op),
                    ns
                ));
            }
            s.push_str("]\n");
            s.push_str("    }");
            s.push_str(if i + 1 < self.exec.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Serialize, write to `dir`, re-parse and validate the bytes on
    /// disk.  Returns the written path.
    ///
    /// Refuses `measured: false` reports: a placeholder that looks like
    /// a trajectory point poisons the perf history (the committed
    /// `BENCH_c7ee675.json` seed was exactly that).  Callers that
    /// genuinely want a placeholder must say so via
    /// [`BenchReport::write_placeholder`] (`bskmq bench
    /// --allow-placeholder`).
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        ensure!(
            self.measured,
            "refusing to write a placeholder BENCH report \
             (measured: false); pass --allow-placeholder to force"
        );
        self.write_unchecked(dir)
    }

    /// [`BenchReport::write`] without the `measured: true` guard — the
    /// explicit escape hatch for seeding a placeholder point.
    pub fn write_placeholder(&self, dir: &Path) -> Result<PathBuf> {
        self.write_unchecked(dir)
    }

    fn write_unchecked(&self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(self.filename());
        let text = self.to_json();
        std::fs::write(&path, &text)
            .with_context(|| format!("write {}", path.display()))?;
        let parsed = Json::parse(&text).context("BENCH json does not parse")?;
        validate(&parsed).context("BENCH json fails its own schema")?;
        Ok(path)
    }
}

fn esc(v: &str) -> String {
    let mut s = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => s.push_str("\\\\"),
            '"' => s.push_str("\\\""),
            '\n' => s.push_str("\\n"),
            _ => s.push(c),
        }
    }
    s
}

/// JSON has no NaN/Inf; clamp them to 0 rather than emit garbage.
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

/// Schema check for a parsed BENCH report.
pub fn validate(j: &Json) -> Result<()> {
    let schema = j.get("schema")?.as_f64()? as u64;
    ensure!(
        schema == BENCH_SCHEMA_VERSION,
        "unknown BENCH schema {schema} (expected {BENCH_SCHEMA_VERSION})"
    );
    let rev = j.get("shortrev")?.as_str()?;
    ensure!(!rev.is_empty(), "empty shortrev");
    j.get("generated_unix")?.as_f64()?;
    j.get("quick")?.as_bool()?;
    j.get("measured")?.as_bool()?;
    j.get("host_threads")?.as_f64()?;
    j.get("note")?.as_str()?;
    let models = j.get("models")?.as_arr()?;
    for m in models {
        let name = m.get("model")?.as_str()?;
        ensure!(!name.is_empty(), "model entry without a name");
        for key in [
            "batch",
            "forwards_per_sec",
            "qfwd_batch_ns",
            "calib_samples_per_sec",
            "serve_p50_ms",
            "serve_p99_ms",
            "serve_p999_ms",
            "serve_requests",
            "serve_rejected",
            "queue_p50_ms",
            "queue_p99_ms",
        ] {
            let v = m.get(key)?.as_f64()?;
            ensure!(
                v.is_finite() && v >= 0.0,
                "{name}.{key} is not a non-negative number"
            );
        }
        for op in m.get("per_op_ns")?.as_arr()? {
            ensure!(!op.get("op")?.as_str()?.is_empty(), "unnamed op");
            op.get("ns")?.as_f64()?;
        }
    }
    let serving = j.get("serving")?.as_arr()?;
    for p in serving {
        let phase = p.get("phase")?.as_str()?;
        ensure!(!phase.is_empty(), "serving point without a phase");
        ensure!(
            !p.get("model")?.as_str()?.is_empty(),
            "serving point without a model"
        );
        for key in [
            "offered",
            "requests",
            "completed",
            "shed",
            "rejected",
            "errors",
            "wall_s",
            "throughput_rps",
            "shed_rate",
            "p50_ms",
            "p99_ms",
            "p999_ms",
            "deadline_ms",
            "replicas",
            "exec_threads",
            "swaps",
            "swap_ns",
            "inflight_at_swap",
        ] {
            let v = p.get(key)?.as_f64()?;
            ensure!(
                v.is_finite() && v >= 0.0,
                "serving[{phase}].{key} is not a non-negative number"
            );
        }
        // accounting identity: every offered request ends exactly one way
        let total = p.get("requests")?.as_f64()?;
        let parts = p.get("completed")?.as_f64()?
            + p.get("shed")?.as_f64()?
            + p.get("rejected")?.as_f64()?
            + p.get("errors")?.as_f64()?;
        ensure!(
            (total - parts).abs() < 0.5,
            "serving[{phase}]: completed+shed+rejected+errors != requests"
        );
    }
    let exec = j.get("exec")?.as_arr()?;
    for e in exec {
        let name = e.get("model")?.as_str()?;
        ensure!(!name.is_empty(), "exec entry without a model");
        for key in [
            "batch",
            "exec_threads",
            "pool_workers",
            "spawn_qfwd_ns",
            "pool_qfwd_ns",
            "speedup",
        ] {
            let v = e.get(key)?.as_f64()?;
            ensure!(
                v.is_finite() && v >= 0.0,
                "exec[{name}].{key} is not a non-negative number"
            );
        }
        for op in e.get("per_op_ns")?.as_arr()? {
            ensure!(!op.get("op")?.as_str()?.is_empty(), "unnamed op");
            op.get("ns")?.as_f64()?;
        }
    }
    Ok(())
}

/// Short git revision of HEAD, or "local" when git is unavailable (the
/// artifact must still be writable from an exported tree).
pub fn short_rev() -> String {
    let out = std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output();
    match out {
        Ok(o) if o.status.success() => {
            let s = String::from_utf8_lossy(&o.stdout).trim().to_string();
            if s.is_empty() {
                "local".to_string()
            } else {
                s
            }
        }
        _ => "local".to_string(),
    }
}

/// Find committed BENCH_*.json files under `dir` (for trajectory tools).
pub fn list_reports(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    if let Ok(entries) = std::fs::read_dir(dir) {
        for e in entries.flatten() {
            let name = e.file_name().to_string_lossy().to_string();
            if name.starts_with("BENCH_") && name.ends_with(".json") {
                out.push(e.path());
            }
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> BenchReport {
        let mut r = BenchReport::new("abc1234", true);
        r.models.push(ModelBench {
            model: "resnet".into(),
            batch: 4,
            forwards_per_sec: 1234.5,
            qfwd_batch_ns: 810_000,
            calib_samples_per_sec: 9000.0,
            serve_p50_ms: 1.2,
            serve_p99_ms: 4.5,
            serve_p999_ms: 9.0,
            serve_requests: 512,
            serve_rejected: 3,
            queue_p50_ms: 0.2,
            queue_p99_ms: 1.1,
            per_op_ns: vec![("conv0:conv".into(), 400_000)],
        });
        r.serving.push(ServingPoint {
            phase: "ladder".into(),
            model: "resnet".into(),
            offered: 32,
            requests: 1000,
            completed: 990,
            shed: 8,
            rejected: 2,
            errors: 0,
            wall_s: 2.5,
            throughput_rps: 396.0,
            p50_ms: 1.0,
            p99_ms: 4.0,
            p999_ms: 8.0,
            deadline_ms: 250.0,
            replicas: 2,
            exec_threads: 8,
            swaps: 1,
            swap_ns: 2_000_000,
            inflight_at_swap: 12,
        });
        r.exec.push(ExecBench {
            model: "resnet".into(),
            batch: 4,
            exec_threads: 8,
            pool_workers: 7,
            spawn_qfwd_ns: 900_000,
            pool_qfwd_ns: 750_000,
            speedup: 1.2,
            per_op_ns: vec![("conv0:conv".into(), 380_000)],
        });
        r
    }

    #[test]
    fn roundtrip_and_validate() {
        let r = sample_report();
        let j = Json::parse(&r.to_json()).unwrap();
        validate(&j).unwrap();
        let models = j.get("models").unwrap().as_arr().unwrap();
        assert_eq!(
            models[0].get("model").unwrap().as_str().unwrap(),
            "resnet"
        );
        assert_eq!(
            models[0].get("qfwd_batch_ns").unwrap().as_usize().unwrap(),
            810_000
        );
        assert_eq!(r.filename(), "BENCH_abc1234.json");
    }

    #[test]
    fn validate_rejects_corruption() {
        let r = sample_report();
        let good = r.to_json();
        let bad = good.replace("\"schema\": 4", "\"schema\": 99");
        assert!(validate(&Json::parse(&bad).unwrap()).is_err());
        let bad = good.replace("\"serve_p50_ms\": 1.2", "\"serve_p50_ms\": -1");
        assert!(validate(&Json::parse(&bad).unwrap()).is_err());
        let bad = good.replace("\"spawn_qfwd_ns\": 900000", "\"spawn_qfwd_ns\": -1");
        assert!(validate(&Json::parse(&bad).unwrap()).is_err());
        let bad = good.replace("\"shortrev\": \"abc1234\",", "");
        assert!(validate(&Json::parse(&bad).unwrap()).is_err());
        // serving accounting identity is part of the schema
        let bad = good.replace("\"completed\": 990", "\"completed\": 500");
        assert!(validate(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn write_and_list() {
        let dir = std::env::temp_dir().join("bskmq_bench_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = sample_report().write(&dir).unwrap();
        assert!(path.exists());
        let found = list_reports(&dir);
        assert_eq!(found, vec![path]);
    }

    #[test]
    fn write_refuses_unmeasured_placeholders() {
        let dir = std::env::temp_dir().join("bskmq_bench_placeholder_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut r = sample_report();
        r.measured = false;
        let err = r.write(&dir).unwrap_err();
        assert!(err.to_string().contains("placeholder"), "{err}");
        assert!(list_reports(&dir).is_empty(), "no file may land");
        // the explicit escape hatch still works (and still validates)
        let path = r.write_placeholder(&dir).unwrap();
        assert!(path.exists());
    }
}
