//! Multi-bit weight mapping (§3.2): parallel bitcell connections.
//!
//! The magnitude bits of a w-bit weight map to parallel groups of 1, 2,
//! 4, ... identical bitcells (7 cells for a 4-bit weight); the sign is
//! free through the symmetric dual-9T left/right paths.  A 256x128 macro
//! therefore stores fewer *weights* per row as precision grows.

use crate::macro_model::COLS;
use crate::quant::weights::bitcells_per_weight;

/// Distinct weights stored per crossbar row at a precision.
pub fn weight_columns(w_bits: u32) -> usize {
    COLS / bitcells_per_weight(w_bits)
}

/// Cells activated for one weight value (energy accounting): the parallel
/// groups corresponding to set magnitude bits.
pub fn active_cells(weight_level: i32, w_bits: u32) -> usize {
    let mag = weight_level.unsigned_abs() as usize;
    let max_mag = (1usize << (w_bits - 1)) - 1;
    assert!(mag <= max_mag, "level {weight_level} out of {w_bits}-bit range");
    mag // groups of 1,2,4.. cells: total active cells == magnitude
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn column_counts() {
        assert_eq!(weight_columns(2), 128); // ternary: 1 cell per weight
        assert_eq!(weight_columns(3), 42);
        assert_eq!(weight_columns(4), 18);
    }

    #[test]
    fn active_cells_equal_magnitude() {
        assert_eq!(active_cells(0, 4), 0);
        assert_eq!(active_cells(5, 4), 5);
        assert_eq!(active_cells(-7, 4), 7);
    }

    #[test]
    #[should_panic]
    fn out_of_range_level_panics() {
        active_cells(8, 4);
    }
}
