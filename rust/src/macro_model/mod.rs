//! Macro-level energy / area / latency model of the 256x128 dual-9T IMC
//! macro with the in-memory NL-ADC (§3.2, Fig. 8).
//!
//! Stands in for the paper's SPICE-derived numbers; the per-component
//! constants are *anchored* to the published figures — total area
//! 0.248 mm^2, NL-ADC = 3.3 % of the MAC array area, 246 TOPS/W and
//! 0.55 TOPS/mm^2 at 6-bit input / 2-bit weight / 4-bit output, ~30 %
//! ADC energy increase vs a same-resolution linear IM ADC — and every
//! other configuration is obtained by the scaling laws of the
//! architecture (PWM input cycles = 2^in_bits, ramp steps and cells per
//! §2.3, parallel bitcells per weight per §3.2).

pub mod area;
pub mod energy;
pub mod weights;

pub use area::{AreaBreakdown, MacroArea};
pub use energy::{EnergyBreakdown, MacroConfig, MacroEnergy};
pub use weights::weight_columns;

/// Crossbar geometry (rows x columns).
pub const ROWS: usize = 256;
pub const COLS: usize = 128;
/// Clock of both the PWM-input and IMA domains (MHz).
pub const FREQ_MHZ: f64 = 200.0;
