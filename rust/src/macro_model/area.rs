//! Area model (Fig. 8(b)): bitcell-level floorplan of the macro.
//!
//! Anchors from the paper: dual-9T bitcell = 3.6 um x 1.9 um (65 nm),
//! total macro 0.248 mm^2, the 128 IM NL-ADCs cost only 3.3 % of the MAC
//! array area (vs 23 % for the NL ramp ADC of [15] and 17 % for the SAR
//! ADC of [17]), and the conventional initial-ramp generator that the
//! dual-9T design eliminates would have cost ~50 % of the ADC core.

use crate::circuit::CALIB_CELLS;
use crate::macro_model::{COLS, ROWS};

/// um^2 of one dual-9T bitcell (3.6 x 1.9 um, §2.2).
pub const BITCELL_UM2: f64 = 3.6 * 1.9;
/// Total macro area anchor (mm^2).
pub const MACRO_MM2: f64 = 0.248;

#[derive(Clone, Debug)]
pub struct AreaBreakdown {
    pub mac_array_mm2: f64,
    pub nl_adc_mm2: f64,
    pub drivers_mm2: f64,
    pub sa_buffers_mm2: f64,
    pub rcnt_mm2: f64,
    pub control_mm2: f64,
}

impl AreaBreakdown {
    pub fn total(&self) -> f64 {
        self.mac_array_mm2
            + self.nl_adc_mm2
            + self.drivers_mm2
            + self.sa_buffers_mm2
            + self.rcnt_mm2
            + self.control_mm2
    }

    /// The paper's headline overhead metric: NL-ADC area / MAC array area.
    pub fn adc_overhead_ratio(&self) -> f64 {
        self.nl_adc_mm2 / self.mac_array_mm2
    }
}

pub struct MacroArea;

impl MacroArea {
    /// Floorplan of the proposed macro.  The single 256x1 replica column
    /// (+ calibration cells) is the whole NL-ADC reference generator; the
    /// SAs/buffers are shared with normal readout, and a share of them is
    /// attributed to the ADC function to match the paper's 3.3 % figure.
    pub fn proposed() -> AreaBreakdown {
        let mac_array = ROWS as f64 * COLS as f64 * BITCELL_UM2 * 1e-6; // mm^2
        // reference column: 256 replica cells incl. 4 calibration cells
        let ref_column = (ROWS + CALIB_CELLS) as f64 * BITCELL_UM2 * 1e-6;
        // ADC-attributed comparator/buffer share (fits the 3.3 % anchor)
        let adc_sa_share = mac_array * 0.033 - ref_column;
        let nl_adc = ref_column + adc_sa_share.max(0.0);
        // remaining periphery split per Fig. 8(b) proportions
        let periphery = MACRO_MM2 - mac_array - nl_adc;
        AreaBreakdown {
            mac_array_mm2: mac_array,
            nl_adc_mm2: nl_adc,
            drivers_mm2: periphery * 0.38,
            sa_buffers_mm2: periphery * 0.34,
            rcnt_mm2: periphery * 0.18,
            control_mm2: periphery * 0.10,
        }
    }

    /// Prior NL ramp ADC of [15]: 23 % of the MAC array area (and its
    /// separate initial-ramp array costs ~50 % of the ADC core, §2.3).
    pub fn prior_nl_ramp() -> AreaBreakdown {
        let mut a = Self::proposed();
        a.nl_adc_mm2 = a.mac_array_mm2 * 0.23;
        a
    }

    /// Prior linear SAR ADC of [17]: 17 % of the MAC array area.
    pub fn prior_sar() -> AreaBreakdown {
        let mut a = Self::proposed();
        a.nl_adc_mm2 = a.mac_array_mm2 * 0.17;
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_matches_anchor() {
        let a = MacroArea::proposed();
        assert!(
            (a.total() - MACRO_MM2).abs() < 1e-9,
            "total {} vs anchor {}",
            a.total(),
            MACRO_MM2
        );
    }

    #[test]
    fn adc_overhead_is_3p3_percent() {
        let a = MacroArea::proposed();
        assert!((a.adc_overhead_ratio() - 0.033).abs() < 2e-3);
    }

    #[test]
    fn improvement_factors_vs_prior() {
        let ours = MacroArea::proposed().adc_overhead_ratio();
        let ramp = MacroArea::prior_nl_ramp().adc_overhead_ratio();
        let sar = MacroArea::prior_sar().adc_overhead_ratio();
        // paper: 7x vs the NL ramp ADC [15], 5.2x vs the SAR ADC [17]
        assert!((ramp / ours - 7.0).abs() < 0.8, "ramp ratio {}", ramp / ours);
        assert!((sar / ours - 5.2).abs() < 0.6, "sar ratio {}", sar / ours);
    }

    #[test]
    fn bitcell_area_is_65nm_cell() {
        assert!((BITCELL_UM2 - 6.84).abs() < 1e-12);
    }
}
