//! Energy / throughput model (Fig. 8(a)) with per-component constants
//! anchored to the paper's 246 TOPS/W at 6-bit input, 2-bit weight,
//! 4-bit output and the Fig. 8(a) breakdown shares (NL-ADC and drivers
//! dominate).  Scaling laws:
//!
//! * drivers  ~ rows x PWM cycles (2^in_bits)
//! * array    ~ active cells x PWM cycles
//! * ADC      ~ SA comparisons (cols x 2^out_bits) + ramp cell-cycles
//!              (the NL ramp holds ~2x the cells of a linear ramp ->
//!              the paper's ~30 % ADC energy increase)
//! * buffers/RCNT ~ cols x 2^out_bits;  control ~ total cycles

use crate::macro_model::weights::weight_columns;
use crate::macro_model::{COLS, FREQ_MHZ, ROWS};

// --- calibrated constants (fJ unless noted) -------------------------------
const E_DRIVER_ROW_CYCLE: f64 = 4.877; // fJ per row driver per PWM cycle
const E_CELL_CYCLE: f64 = 0.0254; // fJ per active cell per PWM cycle
const E_SA_COMPARE: f64 = 23.1; // fJ per SA comparison
const E_RAMP_CELL_CYCLE: f64 = 158.6; // fJ per enabled ramp cell-cycle
const E_BUF_CYCLE: f64 = 11.7; // fJ per buffer per conversion step
const E_RCNT_CYCLE: f64 = 6.5; // fJ per counter per conversion step
const E_CTRL_CYCLE: f64 = 100.0; // fJ per macro cycle (control/clock)
/// pipeline / handover overhead cycles per pass (anchors 0.55 TOPS/mm^2)
const OVERHEAD_CYCLES: f64 = 16.0;
/// average input activity (fraction of PWM cycles driving the rows)
const ACTIVITY: f64 = 0.5;

/// One macro operating point.
#[derive(Clone, Copy, Debug)]
pub struct MacroConfig {
    pub in_bits: u32,
    pub w_bits: u32,
    pub out_bits: u32,
    /// nonlinear (BS-KMQ) ramp vs plain linear ramp
    pub nl_adc: bool,
}

impl MacroConfig {
    /// The paper's macro evaluation point (Fig. 8): 6/2/4, NL.
    pub fn paper_macro() -> Self {
        MacroConfig { in_bits: 6, w_bits: 2, out_bits: 4, nl_adc: true }
    }

    /// The paper's system evaluation point (Table 1): 6/2/3, NL.
    pub fn paper_system() -> Self {
        MacroConfig { in_bits: 6, w_bits: 2, out_bits: 3, nl_adc: true }
    }
}

#[derive(Clone, Debug)]
pub struct EnergyBreakdown {
    /// picojoules per macro pass
    pub drivers_pj: f64,
    pub array_pj: f64,
    pub adc_pj: f64,
    pub sa_buffers_pj: f64,
    pub rcnt_pj: f64,
    pub control_pj: f64,
}

impl EnergyBreakdown {
    pub fn total_pj(&self) -> f64 {
        self.drivers_pj + self.array_pj + self.adc_pj + self.sa_buffers_pj
            + self.rcnt_pj + self.control_pj
    }

    pub fn shares(&self) -> Vec<(&'static str, f64)> {
        let t = self.total_pj();
        vec![
            ("nl_adc", self.adc_pj / t),
            ("drivers", self.drivers_pj / t),
            ("array", self.array_pj / t),
            ("sa_buffers", self.sa_buffers_pj / t),
            ("rcnt", self.rcnt_pj / t),
            ("control", self.control_pj / t),
        ]
    }
}

pub struct MacroEnergy;

impl MacroEnergy {
    /// Energy of one full macro pass (all rows MAC'd, all columns
    /// converted once).
    pub fn per_pass(cfg: MacroConfig) -> EnergyBreakdown {
        let pwm = (1u64 << cfg.in_bits) as f64;
        let steps = (1u64 << cfg.out_bits) as f64;
        let drivers = E_DRIVER_ROW_CYCLE * ROWS as f64 * pwm * ACTIVITY * 2.0;
        let array =
            E_CELL_CYCLE * (ROWS * COLS) as f64 * pwm * ACTIVITY * 2.0;
        // ramp cell-cycles: enabled cells accumulate over the sweep;
        // sum_i cum_i ~ total_cells * steps / 2
        let ramp_cells = if cfg.nl_adc { 2.0 * steps } else { steps };
        let ramp_cell_cycles = ramp_cells * steps / 2.0;
        let adc = E_SA_COMPARE * COLS as f64 * steps
            + E_RAMP_CELL_CYCLE * ramp_cell_cycles;
        let sa_buffers = E_BUF_CYCLE * COLS as f64 * steps;
        let rcnt = E_RCNT_CYCLE * COLS as f64 * steps;
        let cycles = pwm + steps + OVERHEAD_CYCLES;
        let control = E_CTRL_CYCLE * cycles;
        EnergyBreakdown {
            drivers_pj: drivers / 1e3,
            array_pj: array / 1e3,
            adc_pj: adc / 1e3,
            sa_buffers_pj: sa_buffers / 1e3,
            rcnt_pj: rcnt / 1e3,
            control_pj: control / 1e3,
        }
    }

    /// MAC+accumulate operations per pass (2 ops per stored weight x rows).
    pub fn ops_per_pass(cfg: MacroConfig) -> f64 {
        2.0 * ROWS as f64 * weight_columns(cfg.w_bits) as f64
    }

    /// Seconds per pass.
    pub fn pass_seconds(cfg: MacroConfig) -> f64 {
        let cycles =
            (1u64 << cfg.in_bits) as f64 + (1u64 << cfg.out_bits) as f64
                + OVERHEAD_CYCLES;
        cycles / (FREQ_MHZ * 1e6)
    }

    /// TOPS/W at an operating point.
    pub fn tops_per_watt(cfg: MacroConfig) -> f64 {
        let ops = Self::ops_per_pass(cfg);
        let e_j = Self::per_pass(cfg).total_pj() * 1e-12;
        ops / e_j / 1e12
    }

    /// Peak TOPS of one macro.
    pub fn tops(cfg: MacroConfig) -> f64 {
        Self::ops_per_pass(cfg) / Self::pass_seconds(cfg) / 1e12
    }

    /// TOPS per mm^2 (uses the Fig. 8(b) floorplan).
    pub fn tops_per_mm2(cfg: MacroConfig) -> f64 {
        Self::tops(cfg) / super::area::MACRO_MM2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_246_topsw_and_0p55_topsmm2() {
        let cfg = MacroConfig::paper_macro();
        let tw = MacroEnergy::tops_per_watt(cfg);
        assert!((tw - 246.0).abs() < 25.0, "TOPS/W {tw} vs anchor 246");
        let tmm = MacroEnergy::tops_per_mm2(cfg);
        assert!((tmm - 0.55).abs() < 0.06, "TOPS/mm2 {tmm} vs anchor 0.55");
    }

    #[test]
    fn nl_adc_costs_about_30_percent_more() {
        let nl = MacroEnergy::per_pass(MacroConfig::paper_macro());
        let lin = MacroEnergy::per_pass(MacroConfig {
            nl_adc: false,
            ..MacroConfig::paper_macro()
        });
        let ratio = nl.adc_pj / lin.adc_pj;
        assert!(
            (1.2..1.45).contains(&ratio),
            "NL/linear ADC energy ratio {ratio} (paper ~1.3)"
        );
    }

    #[test]
    fn adc_and_drivers_dominate() {
        let e = MacroEnergy::per_pass(MacroConfig::paper_macro());
        let shares = e.shares();
        let adc = shares[0].1;
        let drv = shares[1].1;
        assert!(adc > 0.25 && drv > 0.2, "adc {adc} drivers {drv}");
        assert!(adc + drv > 0.5);
    }

    #[test]
    fn lower_out_bits_cut_adc_energy() {
        let e4 = MacroEnergy::per_pass(MacroConfig::paper_macro());
        let e3 = MacroEnergy::per_pass(MacroConfig::paper_system());
        assert!(e3.adc_pj < 0.6 * e4.adc_pj);
        assert!(e3.total_pj() < e4.total_pj());
    }

    #[test]
    fn higher_weight_bits_reduce_efficiency() {
        let t2 = MacroEnergy::tops_per_watt(MacroConfig::paper_macro());
        let t4 = MacroEnergy::tops_per_watt(MacroConfig {
            w_bits: 4,
            ..MacroConfig::paper_macro()
        });
        assert!(t4 < t2 / 3.0, "t2 {t2} t4 {t4}");
    }
}
