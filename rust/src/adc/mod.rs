//! The reconfigurable (1-7 bit) in-memory nonlinear ADC (§2.3, Fig. 3).

pub mod nl_adc;
pub mod thermometer;

pub use nl_adc::{NlAdc, NlAdcConfig};
pub use thermometer::{binary_to_thermometer, thermometer_to_binary};
