//! The reconfigurable in-memory NL-ADC (§2.3): programs a BS-KMQ codebook
//! into integer bitcell counts per ramp step, converts held MAC voltages
//! by sweeping the shared ramp through the 128 column sense amps, and
//! accounts for the §2.3 bitcell budget (2^(b+1) cells NL vs 2^b linear,
//! 4 calibration cells, 7-bit maximum).

use anyhow::{ensure, Result};

use crate::adc::thermometer::thermometer_to_binary;
use crate::circuit::ramp::{ramp_cells_linear, ramp_cells_nl};
use crate::circuit::USABLE_CELLS;
use crate::quant::codebook::Codebook;

#[derive(Clone, Debug)]
pub struct NlAdcConfig {
    pub bits: u32,
    /// integer bitcells per conversion step (len = 2^bits - 1 transitions
    /// after the base reference)
    pub steps: Vec<usize>,
    /// programmed base reference (V_initcalib target), MAC units
    pub base: f64,
    /// MAC units represented by one ramp cell after input scaling
    pub cell_units: f64,
}

impl NlAdcConfig {
    /// Program a hardware-projected codebook into ramp cell counts.
    /// The codebook must already be on the integer-cell grid
    /// (`Codebook::project_to_hardware`); cell_units is recovered from
    /// the codebook's minimum step.
    pub fn from_codebook(cb: &Codebook, bits: u32) -> Result<NlAdcConfig> {
        ensure!((1..=7).contains(&bits), "bits in [1,7]");
        ensure!(cb.levels() == 1 << bits, "codebook levels != 2^bits");
        // Recover the ramp cell grid: the projected reference steps are
        // exact integer multiples of the cell voltage, so their float
        // GCD is (a multiple of) it — using min_step alone drifts when
        // no step is exactly one cell.
        let diffs: Vec<f64> = cb.refs.windows(2).map(|w| w[1] - w[0]).collect();
        let cell_units = float_gcd(&diffs);
        let steps: Vec<usize> = diffs
            .iter()
            .map(|&d| ((d / cell_units).round()).max(1.0) as usize)
            .collect();
        let total: usize = steps.iter().sum();
        ensure!(
            total <= USABLE_CELLS,
            "codebook needs {total} ramp cells > {USABLE_CELLS} usable"
        );
        Ok(NlAdcConfig {
            bits,
            steps,
            base: cb.refs[0],
            cell_units,
        })
    }

    /// The reference ladder this configuration realizes (ideal cells):
    /// `base` plus one entry per step — 2^bits references in total.
    pub fn ladder(&self) -> Vec<f64> {
        let mut v = self.base;
        let mut out = Vec::with_capacity(self.steps.len() + 1);
        out.push(v);
        for &n in &self.steps {
            v += n as f64 * self.cell_units;
            out.push(v);
        }
        out
    }

    /// Total ramp bitcells consumed (excluding the 4 calibration cells).
    pub fn cells_used(&self) -> usize {
        self.steps.iter().sum()
    }
}

/// The IM NL-ADC: ideal-cell conversion path (the circuit-level
/// non-idealities live in `circuit::montecarlo`).
pub struct NlAdc {
    pub cfg: NlAdcConfig,
    ladder: Vec<f64>,
}

impl NlAdc {
    pub fn new(cfg: NlAdcConfig) -> Self {
        let ladder = cfg.ladder();
        NlAdc { cfg, ladder }
    }

    /// Convert one held MAC voltage: ramp sweep -> thermometer -> RCNT.
    pub fn convert(&self, v_mac: f64) -> usize {
        let therm: Vec<bool> =
            self.ladder.iter().map(|&r| v_mac >= r).collect();
        thermometer_to_binary(&therm).saturating_sub(1)
    }

    /// Convert a whole column batch (the 128 SAs share one ramp sweep).
    pub fn convert_column(&self, v_macs: &[f64]) -> Vec<usize> {
        v_macs.iter().map(|&v| self.convert(v)).collect()
    }

    /// Reference ladder (for tests and the Fig. 7 harness).
    pub fn ladder(&self) -> &[f64] {
        &self.ladder
    }
}

/// Float GCD (Euclid with tolerance) of positive step sizes — recovers
/// the integer-cell grid of a hardware-projected reference ladder.
fn float_gcd(xs: &[f64]) -> f64 {
    let mut g = 0.0f64;
    for &x in xs {
        if x <= 0.0 {
            continue;
        }
        let mut a = g.max(x);
        let mut b = g.min(x);
        if b == 0.0 {
            g = a;
            continue;
        }
        let tol = 1e-6 * a.max(1e-12);
        while b > tol {
            let r = a % b;
            a = b;
            b = r;
        }
        g = a;
    }
    if g > 0.0 {
        g
    } else {
        1.0
    }
}

/// §2.3 overhead accounting: NL vs linear ramp bitcells at a resolution.
pub fn nl_vs_linear_cells(bits: u32) -> (usize, usize) {
    (ramp_cells_nl(bits), ramp_cells_linear(bits))
}

/// Maximum reconfigurable resolution given the 252 usable cells: 7 bits
/// (2^7 - 1 = 127 ramp steps of at least one cell each fit; 8 bits would
/// need 255 > 252).
pub fn max_resolution() -> u32 {
    let mut b = 1u32;
    while b < 8 && (1usize << (b + 1)) - 1 <= USABLE_CELLS {
        b += 1;
    }
    b.min(7)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Method;
    use crate::util::rng::Rng;

    fn relu_samples(n: usize) -> Vec<f64> {
        let mut rng = Rng::new(5);
        (0..n).map(|_| rng.normal(3.0, 10.0).max(0.0)).collect()
    }

    #[test]
    fn convert_matches_codebook_quantize() {
        let xs = relu_samples(20_000);
        let cb = Method::BsKmq.fit_hw(&xs, 4, 0);
        let adc = NlAdc::new(NlAdcConfig::from_codebook(&cb, 4).unwrap());
        let mut rng = Rng::new(6);
        for _ in 0..2000 {
            let v = rng.range(-5.0, 60.0);
            let code = adc.convert(v);
            let q_adc = cb.centers[code];
            let q_cb = cb.quantize(v);
            // ladders agree to the integer-cell grid
            assert!(
                (q_adc - q_cb).abs() <= cb.min_step() + 1e-9,
                "v={v} adc={q_adc} cb={q_cb}"
            );
        }
    }

    #[test]
    fn reconfigurable_1_to_7_bits() {
        assert_eq!(max_resolution(), 7);
        let xs = relu_samples(5_000);
        for bits in 1..=7 {
            let cb = Method::BsKmq.fit_hw(&xs, bits, 0);
            let cfg = NlAdcConfig::from_codebook(&cb, bits).unwrap();
            assert!(cfg.cells_used() <= USABLE_CELLS, "bits={bits}");
            assert_eq!(cfg.ladder().len(), 1 << bits);
        }
    }

    #[test]
    fn cell_overhead_vs_linear() {
        let (nl, lin) = nl_vs_linear_cells(4);
        // paper: 32 + 4 calib vs 16 + 4 calib
        assert_eq!(nl, 36);
        assert_eq!(lin, 20);
    }

    #[test]
    fn column_conversion_shares_ramp() {
        let xs = relu_samples(5_000);
        let cb = Method::Linear.fit_hw(&xs, 3, 0);
        let adc = NlAdc::new(NlAdcConfig::from_codebook(&cb, 3).unwrap());
        let vs = [0.0, 5.0, 10.0, 40.0];
        let codes = adc.convert_column(&vs);
        assert_eq!(codes.len(), 4);
        assert!(codes.windows(2).all(|w| w[0] <= w[1]));
    }
}
