//! Thermometer -> binary conversion: the ripple counters (RCNTs) that
//! digitize the 128 sense-amp outputs as the shared ramp sweeps (§2.2).
//!
//! During conversion, each SA output is high while V_MAC >= V_ADC; the
//! ripple counter simply counts the high cycles, so the output code is
//! the number of set bits in a (valid) thermometer word.

/// Count a thermometer word into its binary code.  Non-monotone words
/// (bubble errors from SA metastability) are still counted — exactly what
/// a ripple counter does in silicon, making single bubbles cost 1 LSB.
pub fn thermometer_to_binary(bits: &[bool]) -> usize {
    bits.iter().filter(|&&b| b).count()
}

/// Ideal thermometer word for a code (testing/golden vectors).
pub fn binary_to_thermometer(code: usize, levels: usize) -> Vec<bool> {
    (0..levels).map(|i| i < code).collect()
}

/// Whether a word is a valid (monotone) thermometer code.
pub fn is_monotone(bits: &[bool]) -> bool {
    let mut seen_low = false;
    for &b in bits {
        if b && seen_low {
            return false;
        }
        if !b {
            seen_low = true;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_codes() {
        for levels in [4usize, 16, 128] {
            for code in 0..=levels {
                let t = binary_to_thermometer(code, levels);
                assert!(is_monotone(&t));
                assert_eq!(thermometer_to_binary(&t), code);
            }
        }
    }

    #[test]
    fn bubble_costs_one_lsb() {
        // 1 1 0 1 0 0: a bubble at position 2
        let w = [true, true, false, true, false, false];
        assert!(!is_monotone(&w));
        assert_eq!(thermometer_to_binary(&w), 3);
    }
}
