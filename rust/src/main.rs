//! BS-KMQ leader binary: experiment harnesses, the end-to-end pipeline
//! and the batched inference server (TCP front).
//!
//! Usage:
//!   bskmq exp <fig1|fig4|fig5|fig6|fig7|fig8|table1|all>
//!   bskmq calibrate <model> <bits>    # print per-layer codebooks
//!   bskmq serve [--addr 127.0.0.1:7878] [--model resnet] [--bits 3]
//!   bskmq info                        # artifacts + platform summary

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;

use anyhow::{Context, Result};

use bskmq::coordinator::calibrate::Calibrator;
use bskmq::coordinator::server::InferenceServer;
use bskmq::data::dataset::ModelData;
use bskmq::quant::Method;
use bskmq::runtime::engine::Engine;
use bskmq::runtime::model::ModelRuntime;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("exp") => {
            let id = args.get(1).map(String::as_str).unwrap_or("all");
            bskmq::experiments::run(id)
        }
        Some("calibrate") => {
            let model = args.get(1).map(String::as_str).unwrap_or("resnet");
            let bits: u32 = args
                .get(2)
                .map(|s| s.parse())
                .transpose()
                .context("bits must be an integer")?
                .unwrap_or(3);
            calibrate(model, bits)
        }
        Some("serve") => serve(args),
        Some("info") => info(),
        _ => {
            eprintln!(
                "usage: bskmq <exp|calibrate|serve|info> [...]\n\
                 \x20 exp <fig1|fig4|fig5|fig6|fig7|fig8|table1|all>\n\
                 \x20 calibrate <model> <bits>\n\
                 \x20 serve [--addr A] [--model M] [--bits B]\n\
                 \x20 info"
            );
            Ok(())
        }
    }
}

fn calibrate(model: &str, bits: u32) -> Result<()> {
    let engine = Engine::cpu()?;
    let artifacts = bskmq::artifacts_dir();
    let runtime = ModelRuntime::load(&engine, &artifacts, model)?;
    let data = ModelData::load(&artifacts, model)?;
    let calib = Calibrator::new(&runtime, Method::BsKmq, bits)
        .calibrate(&data, 8)?;
    println!("calibrated {model} at {bits}b over {} batches", calib.batches);
    for (i, (book, q)) in calib
        .nl_books
        .iter()
        .zip(&runtime.manifest.qlayers)
        .enumerate()
    {
        println!(
            "  layer {:>2} {:<10} K={:<4} centers[0..4] = {:?}",
            i,
            q.name,
            q.k,
            &book.centers[..4.min(book.centers.len())]
        );
    }
    Ok(())
}

fn serve(args: &[String]) -> Result<()> {
    let mut addr = "127.0.0.1:7878".to_string();
    let mut model = "resnet".to_string();
    let mut bits = 3u32;
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--addr" => {
                addr = args.get(i + 1).context("--addr value")?.clone();
                i += 2;
            }
            "--model" => {
                model = args.get(i + 1).context("--model value")?.clone();
                i += 2;
            }
            "--bits" => {
                bits = args.get(i + 1).context("--bits value")?.parse()?;
                i += 2;
            }
            other => anyhow::bail!("unknown serve flag '{other}'"),
        }
    }
    let server = InferenceServer::start(
        bskmq::artifacts_dir(),
        model.clone(),
        Method::BsKmq,
        bits,
        0.0,
        8,
    )?;
    let listener = TcpListener::bind(&addr)?;
    println!("serving {model} ({bits}b BS-KMQ) on {addr}");
    println!("protocol: one line of comma-separated input floats -> one line of logits");
    for stream in listener.incoming() {
        let stream = stream?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut out = stream;
        let mut line = String::new();
        while {
            line.clear();
            reader.read_line(&mut line)? > 0
        } {
            let x: Vec<f32> = line
                .trim()
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse::<f32>())
                .collect::<std::result::Result<_, _>>()
                .context("parsing input floats")?;
            match server.infer(x) {
                Ok(logits) => {
                    let s: Vec<String> =
                        logits.iter().map(|v| format!("{v:.6}")).collect();
                    writeln!(out, "{}", s.join(","))?;
                }
                Err(e) => writeln!(out, "error: {e}")?,
            }
        }
        println!("client done; stats: {}", server.stats.summary());
    }
    Ok(())
}

fn info() -> Result<()> {
    let artifacts = bskmq::artifacts_dir();
    println!("artifacts dir: {}", artifacts.display());
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    for model in ["resnet", "vgg", "inception", "distilbert"] {
        match ModelRuntime::load(&engine, &artifacts, model) {
            Ok(rt) => println!(
                "  {model:<11} nq={:<3} batch={} input={:?}",
                rt.manifest.nq(),
                rt.manifest.batch,
                rt.manifest.input_shape
            ),
            Err(e) => println!("  {model:<11} UNAVAILABLE: {e}"),
        }
    }
    Ok(())
}
