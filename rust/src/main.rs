//! BS-KMQ leader binary: experiment harnesses, the end-to-end pipeline
//! and the replica-pool inference server (TCP front).
//!
//! Usage:
//!   bskmq exp <fig1|fig4|fig5|fig6|fig7|fig8|table1|backends|all>
//!   bskmq calibrate <model> [--spec [model=]S] [--layer name=S]
//!                   [--shards N] [--eval-batches N] [--backend B]
//!       # calibrate (optionally shard-parallel), print per-layer
//!       # codebooks, then run the PTQ evaluation end-to-end.  Spec
//!       # strings are `[method:]TILE/WEIGHT/ACT` or `[method:]ACT`
//!       # (weight `-` = float), e.g. `--spec resnet=6/2/3`; layers
//!       # without overrides keep the manifest's per-layer specs.
//!   bskmq serve [--addr 127.0.0.1:7878] [--models resnet,vgg]
//!               [--spec S] [--backend auto|native|xla] [--replicas N]
//!               [--max-replicas N] [--shards N] [--queue-depth N]
//!               [--request-deadline-ms N] [--front event|threaded]
//!               [--calib-batches N] [--trace FILE] [--trace-sample N]
//!               [--profile-every N] [--no-quant-health]
//!               [--exec-threads N] [--recalib] [--recalib-sample N]
//!               [--drift-threshold X]
//!   bskmq bench [--quick] [--models M1,M2] [--out DIR]
//!               [--allow-placeholder]
//!       # run the standard perf workload per model and write
//!       # BENCH_<shortrev>.json (schema: src/obs/bench_report.rs);
//!       # refuses `measured: false` output unless --allow-placeholder
//!   bskmq synth <dir> [--seed N]      # write synthetic artifacts (5 models)
//!   bskmq graph <manifest.json>       # validate + dump a layer graph
//!   bskmq info                        # artifacts + backend summary
//!
//! The execution backend defaults to `auto` (XLA when compiled in and
//! loadable, the native integer IMC engine otherwise); `BSKMQ_BACKEND`
//! sets the process-wide default.  `--replicas` spawns that many worker
//! replicas per model (native backends share one weight set via `Arc`);
//! `--max-replicas` > `--replicas` turns on queue-depth-driven
//! autoscaling between the two bounds.  `--queue-depth` bounds each
//! model's intake queue — a full queue rejects requests with an error
//! line instead of buffering them — and `--request-deadline-ms` is the
//! per-request shed horizon: requests still queued past it get an
//! explicit overload reply instead of service.  `--front` picks the TCP
//! front (epoll event loop by default on linux, thread-per-connection
//! otherwise).  `--shards` streams calibration batches over that many
//! threads (codebooks stay bit-identical to serial).  `--recalib` turns
//! on online shadow recalibration (DESIGN.md §15): every
//! `--recalib-sample`th request's input feeds a shadow calibration
//! window, and once live sketch drift exceeds `--drift-threshold` the
//! controller refits the codebooks and hot-swaps them with zero
//! downtime (each reply is served entirely under one codebook
//! generation).

use std::net::TcpListener;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use bskmq::backend::{Backend, BackendKind};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::coordinator::front::{FrontKind, ServeFront};
use bskmq::coordinator::loadgen::{
    closed_loop, closed_loop_phased, scaled_inputs, TrafficPhase,
};
use bskmq::coordinator::ptq::PtqEvaluator;
use bskmq::coordinator::pool::{ModelPool, ModelRegistry, PoolConfig};
use bskmq::coordinator::recalib::RecalibConfig;
use bskmq::data::dataset::ModelData;
use bskmq::obs::bench_report::{
    short_rev, BenchReport, ExecBench, ModelBench, ServingPoint,
};
use bskmq::quant::QuantSpec;
use bskmq::util::stats::rate;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("exp") => {
            let id = args.get(1).map(String::as_str).unwrap_or("all");
            bskmq::experiments::run(id)
        }
        Some("calibrate") => calibrate(args),
        Some("serve") => serve(args),
        Some("bench") => bench(args),
        Some("synth") => synth(args),
        Some("graph") => {
            let path = args.get(1).context(
                "usage: bskmq graph <manifest.json>",
            )?;
            graph_dump(std::path::Path::new(path))
        }
        Some("info") => info(),
        _ => {
            eprintln!(
                "usage: bskmq <exp|calibrate|serve|bench|synth|graph|info> [...]\n\
                 \x20 exp <fig1|fig4|fig5|fig6|fig7|fig8|table1|backends|all>\n\
                 \x20 calibrate <model> [--spec [model=]S] [--layer name=S]\n\
                 \x20           [--shards N] [--eval-batches N] [--backend B]\n\
                 \x20           (S = [method:]TILE/WEIGHT/ACT or ACT, e.g. 6/2/3)\n\
                 \x20 serve [--addr A] [--models M1,M2] [--spec S] [--backend B]\n\
                 \x20       [--replicas N] [--max-replicas N] [--shards N]\n\
                 \x20       [--queue-depth N] [--request-deadline-ms N]\n\
                 \x20       [--front event|threaded] [--calib-batches N]\n\
                 \x20       [--trace FILE] [--trace-sample N]\n\
                 \x20       [--profile-every N] [--no-quant-health]\n\
                 \x20       [--exec-threads N] [--recalib]\n\
                 \x20       [--recalib-sample N] [--drift-threshold X]\n\
                 \x20 bench [--quick] [--models M1,M2] [--out DIR]\n\
                 \x20       [--allow-placeholder]\n\
                 \x20 synth <dir> [--seed N]\n\
                 \x20 graph <manifest.json>\n\
                 \x20 info"
            );
            Ok(())
        }
    }
}

/// `bskmq synth <dir> [--seed N]`: write the synthetic artifact set;
/// the seed threads into every generator, so identical invocations
/// produce bit-identical artifacts (reproducible test fixtures).
fn synth(args: &[String]) -> Result<()> {
    let dir = args.get(1).filter(|s| !s.starts_with("--")).context(
        "usage: bskmq synth <dir> [--seed N] (refuses to guess where to write)",
    )?;
    let mut seed = 42u64;
    let mut i = 2;
    while i < args.len() {
        match args[i].as_str() {
            "--seed" => {
                seed = args
                    .get(i + 1)
                    .context("--seed value")?
                    .parse()
                    .context("--seed must be an unsigned integer")?;
                i += 2;
            }
            other => anyhow::bail!("unknown synth flag '{other}'"),
        }
    }
    bskmq::data::synth::write_all(std::path::Path::new(dir), seed)?;
    println!(
        "wrote synthetic artifacts (seed {seed}) for {} into {dir}",
        bskmq::data::synth::MODELS.join("/")
    );
    println!("serve them with: BSKMQ_ARTIFACTS={dir} bskmq serve ...");
    Ok(())
}

/// `bskmq graph <manifest.json>`: compile (validate) the manifest's
/// layer graph and dump the resolved op list — the smoke test for
/// hand-written manifests before anything is served.
fn graph_dump(path: &std::path::Path) -> Result<()> {
    use bskmq::backend::native::graph::GraphProgram;
    let manifest = bskmq::io::manifest::Manifest::load(path)?;
    let program = GraphProgram::compile(&manifest).with_context(|| {
        format!("validating layer graph of model '{}'", manifest.model)
    })?;
    println!(
        "model {}: input {:?} -> {} classes, {} q-layers",
        manifest.model,
        manifest.input_shape,
        manifest.num_classes,
        manifest.nq()
    );
    for (i, op) in program.summary(&manifest).iter().enumerate() {
        let q = op
            .qlayer
            .as_ref()
            .map(|q| format!("  qlayer {q}"))
            .unwrap_or_default();
        println!(
            "  {i:>3} {:<10} {:<12} [{}] -> {} : {}{q}",
            op.kind,
            op.name,
            op.inputs.join(", "),
            op.output,
            op.out_shape,
        );
    }
    println!(
        "graph OK: {} ops, {} value edges on {} arena slots",
        program.n_ops(),
        program.n_values(),
        program.n_slots()
    );
    Ok(())
}

/// `bskmq calibrate`: resolve per-layer specs (manifest + overrides),
/// calibrate (optionally shard-parallel), print the programmed
/// codebooks, then run the PTQ evaluation — the calibrate → PTQ half of
/// the pipeline; `bskmq serve --spec` is the serving half.
fn calibrate(args: &[String]) -> Result<()> {
    let model = args
        .get(1)
        .filter(|s| !s.starts_with("--"))
        .map(String::as_str)
        .unwrap_or("resnet")
        .to_string();
    let mut kind = BackendKind::from_env();
    let mut spec_arg: Option<String> = None;
    let mut layer_args: Vec<String> = Vec::new();
    let mut shards = 1usize;
    let mut eval_batches = 4usize;
    let mut i = if args.len() > 1 && !args[1].starts_with("--") { 2 } else { 1 };
    while i < args.len() {
        match args[i].as_str() {
            "--spec" => {
                spec_arg = Some(args.get(i + 1).context("--spec value")?.clone());
                i += 2;
            }
            "--layer" => {
                layer_args
                    .push(args.get(i + 1).context("--layer value")?.clone());
                i += 2;
            }
            "--shards" => {
                shards = args.get(i + 1).context("--shards value")?.parse()?;
                i += 2;
            }
            "--eval-batches" => {
                eval_batches =
                    args.get(i + 1).context("--eval-batches value")?.parse()?;
                i += 2;
            }
            "--backend" => {
                kind = BackendKind::parse(
                    args.get(i + 1).context("--backend value")?,
                )?;
                i += 2;
            }
            // pre-QuantSpec compatibility: a bare bit count = uniform ACT
            bits if bits.parse::<u32>().is_ok() => {
                spec_arg = Some(bits.to_string());
                i += 1;
            }
            other => anyhow::bail!("unknown calibrate flag '{other}'"),
        }
    }

    let artifacts = bskmq::artifacts_dir();
    let backend = bskmq::backend::load(kind, &artifacts, &model)?;
    let m = backend.manifest();

    // specs: manifest defaults, then the uniform --spec override, then
    // per-layer --layer overrides
    let mut specs = m.layer_specs();
    if let Some(sarg) = &spec_arg {
        let body = match sarg.split_once('=') {
            Some((named, rest)) => {
                ensure!(
                    named == model,
                    "--spec names model '{named}' but calibrating '{model}'"
                );
                rest
            }
            None => sarg.as_str(),
        };
        for spec in &mut specs {
            *spec = QuantSpec::parse(body, spec)?;
        }
    }
    for larg in &layer_args {
        let (lname, body) = larg
            .split_once('=')
            .context("--layer wants name=SPEC")?;
        let li = m
            .qlayers
            .iter()
            .position(|q| q.name == lname)
            .with_context(|| format!("no q-layer '{lname}' in {model}"))?;
        specs[li] = QuantSpec::parse(body, &specs[li])?;
    }

    let data = ModelData::load(&artifacts, &model)?;
    // deployment order: program the weights the specs ask for FIRST,
    // then run Algorithm 1 once on the deployed macro — the printed
    // codebooks are exactly the ones the PTQ number below used
    let has_wq = specs.iter().any(|s| s.weight_bits.is_some());
    let qlayers = m.qlayers.clone();
    let engine = backend.name();
    let deployed: Box<dyn Backend> = if has_wq {
        PtqEvaluator::new(backend.as_ref()).quantize_weights_spec(&specs)?
    } else {
        backend
    };
    let calib = Calibrator::with_specs(deployed.as_ref(), specs.clone())
        .calibrate_sharded(&data, 8, shards)?;
    println!(
        "calibrated {model}{} over {} batches x {} shard(s) ({engine} backend)",
        if has_wq { " (weight-quantized)" } else { "" },
        calib.batches,
        calib.shards,
    );
    for (i, (book, q)) in calib.nl_books.iter().zip(&qlayers).enumerate() {
        println!(
            "  layer {:>2} {:<10} K={:<4} [{}] centers[0..4] = {:?}",
            i,
            q.name,
            q.k,
            specs[i].summary(),
            &book.centers[..4.min(book.centers.len())]
        );
    }
    let r = PtqEvaluator::new(deployed.as_ref()).evaluate(
        &data,
        &calib.programmed,
        0.0,
        eval_batches,
        7,
    )?;
    println!(
        "PTQ accuracy: {:.3} over {} test samples",
        r.accuracy, r.samples
    );
    Ok(())
}

fn serve(args: &[String]) -> Result<()> {
    let mut addr = "127.0.0.1:7878".to_string();
    let mut models: Vec<String> = vec!["resnet".to_string()];
    let mut front_kind = FrontKind::default_for_platform();
    let mut cfg = PoolConfig {
        backend: BackendKind::from_env(),
        ..PoolConfig::default()
    };
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--addr" => {
                addr = args.get(i + 1).context("--addr value")?.clone();
                i += 2;
            }
            "--model" | "--models" => {
                models = args
                    .get(i + 1)
                    .context("--models value")?
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(str::to_string)
                    .collect();
                i += 2;
            }
            "--spec" => {
                let base = cfg.spec.unwrap_or_default();
                cfg.spec = Some(QuantSpec::parse(
                    args.get(i + 1).context("--spec value")?,
                    &base,
                )?);
                i += 2;
            }
            // pre-QuantSpec compatibility: uniform ACT bit override
            "--bits" => {
                let base = cfg.spec.unwrap_or_default();
                cfg.spec = Some(QuantSpec {
                    act_bits: args
                        .get(i + 1)
                        .context("--bits value")?
                        .parse()?,
                    ..base
                });
                i += 2;
            }
            "--shards" => {
                cfg.calib_shards = args
                    .get(i + 1)
                    .context("--shards value")?
                    .parse()?;
                i += 2;
            }
            "--backend" => {
                cfg.backend = BackendKind::parse(
                    args.get(i + 1).context("--backend value")?,
                )?;
                i += 2;
            }
            "--replicas" => {
                cfg.replicas = args
                    .get(i + 1)
                    .context("--replicas value")?
                    .parse()?;
                i += 2;
            }
            "--queue-depth" => {
                cfg.queue_depth = args
                    .get(i + 1)
                    .context("--queue-depth value")?
                    .parse()?;
                i += 2;
            }
            "--max-replicas" => {
                cfg.max_replicas = args
                    .get(i + 1)
                    .context("--max-replicas value")?
                    .parse()?;
                i += 2;
            }
            "--request-deadline-ms" => {
                let ms: u64 = args
                    .get(i + 1)
                    .context("--request-deadline-ms value")?
                    .parse()?;
                ensure!(ms > 0, "--request-deadline-ms must be positive");
                cfg.request_deadline = std::time::Duration::from_millis(ms);
                i += 2;
            }
            "--front" => {
                front_kind = FrontKind::parse(
                    args.get(i + 1).context("--front value")?,
                )?;
                i += 2;
            }
            "--calib-batches" => {
                cfg.calib_batches = args
                    .get(i + 1)
                    .context("--calib-batches value")?
                    .parse()?;
                i += 2;
            }
            "--trace" => {
                cfg.obs.trace_path = Some(std::path::PathBuf::from(
                    args.get(i + 1).context("--trace value")?,
                ));
                if cfg.obs.trace_sample_every == 0 {
                    cfg.obs.trace_sample_every = 1;
                }
                i += 2;
            }
            "--trace-sample" => {
                cfg.obs.trace_sample_every = args
                    .get(i + 1)
                    .context("--trace-sample value")?
                    .parse()?;
                i += 2;
            }
            "--profile-every" => {
                cfg.obs.profile_every = args
                    .get(i + 1)
                    .context("--profile-every value")?
                    .parse()?;
                i += 2;
            }
            "--no-quant-health" => {
                cfg.obs.quant_health = false;
                i += 1;
            }
            // online shadow recalibration (DESIGN.md §15)
            "--recalib" => {
                cfg.recalib.get_or_insert_with(RecalibConfig::default);
                i += 1;
            }
            "--recalib-sample" => {
                let rc =
                    cfg.recalib.get_or_insert_with(RecalibConfig::default);
                rc.sample_every = args
                    .get(i + 1)
                    .context("--recalib-sample value")?
                    .parse()?;
                i += 2;
            }
            "--drift-threshold" => {
                let rc =
                    cfg.recalib.get_or_insert_with(RecalibConfig::default);
                rc.drift_threshold = args
                    .get(i + 1)
                    .context("--drift-threshold value")?
                    .parse()?;
                i += 2;
            }
            // global executor thread budget shared by ALL replicas of
            // ALL models (DESIGN.md §14) — overrides BSKMQ_THREADS; must
            // land before the first forward instantiates the pool
            "--exec-threads" => {
                let n: usize = args
                    .get(i + 1)
                    .context("--exec-threads value")?
                    .parse()?;
                ensure!(n > 0, "--exec-threads must be positive");
                bskmq::backend::native::ops::set_thread_override(Some(n));
                i += 2;
            }
            other => anyhow::bail!("unknown serve flag '{other}'"),
        }
    }
    let registry = Arc::new(ModelRegistry::start(
        &bskmq::artifacts_dir(),
        &models,
        &cfg,
    )?);
    let listener = TcpListener::bind(&addr)?;
    let spec_desc = match &cfg.spec {
        Some(s) => s.summary(),
        None => "manifest per-layer specs".to_string(),
    };
    let replica_desc = if cfg.max_replicas > cfg.replicas {
        format!("{}..{} replica(s)/model", cfg.replicas, cfg.max_replicas)
    } else {
        format!("{} replica(s)/model", cfg.replicas)
    };
    println!(
        "serving {} ({spec_desc}, {replica_desc}, queue depth {}, deadline \
         {} ms, {} front) on {addr}",
        registry.models().join("+"),
        cfg.queue_depth,
        cfg.request_deadline.as_millis(),
        front_kind.name(),
    );
    if let Some(rc) = &cfg.recalib {
        println!(
            "recalibration: shadow-sampling every {} request(s), drift \
             threshold {}, min window {} samples",
            rc.sample_every, rc.drift_threshold, rc.min_observations,
        );
    }
    println!(
        "protocol: one line `[model:]f1,f2,...` -> one line of logits; \
         `stats` -> pool stats as JSON (`stats --text` for the human \
         summary); `metrics` -> Prometheus text; default model is {}",
        registry.default_pool().model
    );
    // the front multiplexes connections onto the replica pools; the pool
    // (admission control + deadline shedding) is the concurrency
    // limiter, not the accept path
    let mut front = ServeFront::spawn(registry.clone(), listener, front_kind)?;
    front.join()
}

/// `bskmq bench [--quick] [--models M1,M2] [--out DIR]`: run the
/// standard perf workload per model — calibration throughput, quantized
/// forward latency with a per-op breakdown, the executor-pool vs
/// scoped-spawn comparison (schema v3 `exec` section), and a short
/// closed-loop serving run — then write `BENCH_<shortrev>.json` into
/// `--out` (default: current directory).  `--quick` shrinks every phase
/// for CI smoke runs.
fn bench(args: &[String]) -> Result<()> {
    let mut quick = false;
    let mut allow_placeholder = false;
    let mut out_dir = std::path::PathBuf::from(".");
    let mut models: Option<Vec<String>> = None;
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--quick" => {
                quick = true;
                i += 1;
            }
            "--allow-placeholder" => {
                allow_placeholder = true;
                i += 1;
            }
            "--models" => {
                models = Some(
                    args.get(i + 1)
                        .context("--models value")?
                        .split(',')
                        .filter(|s| !s.is_empty())
                        .map(str::to_string)
                        .collect(),
                );
                i += 2;
            }
            "--out" => {
                out_dir = std::path::PathBuf::from(
                    args.get(i + 1).context("--out value")?,
                );
                i += 2;
            }
            other => anyhow::bail!("unknown bench flag '{other}'"),
        }
    }
    let artifacts = bskmq::data::synth::ensure_artifacts()?;
    let models = models.unwrap_or_else(|| {
        if quick {
            vec!["resnet".to_string()]
        } else {
            bskmq::data::synth::MODELS
                .iter()
                .map(|s| s.to_string())
                .collect()
        }
    });
    let mut report = BenchReport::new(&short_rev(), quick);
    for model in &models {
        println!("benchmarking {model} ...");
        let (mb, eb) = bench_model(&artifacts, model, quick)?;
        report.models.push(mb);
        report.exec.push(eb);
    }
    // closed-loop serving sweep on the lead model: throughput/latency vs
    // offered load plus a deliberate overload point (the `serving` section)
    if let Some(lead) = models.first() {
        println!("load sweep on {lead} ...");
        report.serving = bench_serving(&artifacts, lead, quick)?;
    }
    // `write` refuses `measured: false` placeholder reports; the flag
    // is the deliberate escape hatch for seeding one
    let path = if allow_placeholder {
        report.write_placeholder(&out_dir)?
    } else {
        report.write(&out_dir)?
    };
    for m in &report.models {
        println!(
            "  {:<11} qfwd {:>9} ns/batch ({:>8.1} fwd/s)  calib {:>8.0} \
             samples/s  serve p50 {:.2}ms p99 {:.2}ms p999 {:.2}ms \
             ({} requests, {} rejected)",
            m.model,
            m.qfwd_batch_ns,
            m.forwards_per_sec,
            m.calib_samples_per_sec,
            m.serve_p50_ms,
            m.serve_p99_ms,
            m.serve_p999_ms,
            m.serve_requests,
            m.serve_rejected,
        );
    }
    for p in &report.serving {
        println!(
            "  serving[{:<8}] offered {:>4}: {:>8.0} req/s  p50 {:.2}ms \
             p99 {:.2}ms p999 {:.2}ms  shed {:.1}% of {} requests",
            p.phase,
            p.offered,
            p.throughput_rps,
            p.p50_ms,
            p.p99_ms,
            p.p999_ms,
            p.shed_rate() * 100.0,
            p.requests,
        );
    }
    for e in &report.exec {
        println!(
            "  exec[{:<11}] spawn {:>9} ns/batch  pool {:>9} ns/batch  \
             speedup {:.2}x  ({} threads, {} pool workers)",
            e.model,
            e.spawn_qfwd_ns,
            e.pool_qfwd_ns,
            e.speedup,
            e.exec_threads,
            e.pool_workers,
        );
    }
    println!("wrote {}", path.display());
    Ok(())
}

/// The serving section of the BENCH report: a ladder of offered loads
/// against a fixed replica pool (throughput and tail latency as
/// concurrency grows), then an overload point on a deliberately starved
/// pool with a tight deadline — the claim being measured is that
/// admitted requests stay fast while the excess is shed.
fn bench_serving(
    artifacts: &std::path::Path,
    model: &str,
    quick: bool,
) -> Result<Vec<ServingPoint>> {
    use std::time::Duration;

    let be = bskmq::backend::load(BackendKind::Native, artifacts, model)?;
    let in_elems = be.manifest().input_elems();
    drop(be);
    let data = ModelData::load(artifacts, model)?;
    let base = ModelData::batch(&data.x_test, 0, 1).to_vec();
    // a cycle of slightly-varied inputs so batches are not byte-identical
    let inputs: Vec<Vec<f32>> = (0..64)
        .map(|k| {
            let mut xi = base[..in_elems].to_vec();
            xi[0] += k as f32 * 1e-6;
            xi
        })
        .collect();

    let calib_batches = if quick { 2 } else { 8 };
    let per_point: u64 = if quick { 2_000 } else { 20_000 };
    let deadline = Duration::from_millis(250);
    let cfg = PoolConfig {
        backend: BackendKind::Native,
        calib_batches,
        replicas: if quick { 2 } else { 4 },
        queue_depth: 4096,
        request_deadline: deadline,
        ..PoolConfig::default()
    };
    let mut pool =
        ModelPool::start(artifacts.to_path_buf(), model.to_string(), &cfg)?;
    let client = pool.client();
    let ladder: &[usize] =
        if quick { &[1, 8, 32] } else { &[1, 8, 32, 128] };
    let mut points = Vec::new();
    for &offered in ladder {
        points.push(closed_loop(
            &client, &inputs, model, "ladder", offered, per_point, deadline,
        ));
    }
    pool.shutdown();

    // overload: one replica, tight deadline, 64 closed-loop clients
    let deadline = Duration::from_millis(5);
    let cfg = PoolConfig {
        backend: BackendKind::Native,
        calib_batches,
        replicas: 1,
        queue_depth: 4096,
        request_deadline: deadline,
        ..cfg
    };
    let mut pool =
        ModelPool::start(artifacts.to_path_buf(), model.to_string(), &cfg)?;
    let client = pool.client();
    points.push(closed_loop(
        &client, &inputs, model, "overload", 64, per_point, deadline,
    ));
    pool.shutdown();

    // swap-under-load: the shadow recalibration controller live, driven
    // by a nonstationary program (matched traffic, then the same inputs
    // scaled 4x so every activation decile moves past the drift
    // threshold mid-run).  The point records the hot-swaps that landed,
    // the last refit+swap wall time, and the queue depth at the swap
    // instant.  Measurement-only: a very short run may end before the
    // controller fires, recording zero swaps rather than failing.
    let deadline = Duration::from_millis(250);
    let cfg = PoolConfig {
        backend: BackendKind::Native,
        calib_batches,
        replicas: 2,
        queue_depth: 4096,
        request_deadline: deadline,
        recalib: Some(RecalibConfig {
            sample_every: 1,
            drift_threshold: 0.2,
            min_observations: 64,
            check_interval: Duration::from_millis(10),
            ..RecalibConfig::default()
        }),
        ..PoolConfig::default()
    };
    let mut pool =
        ModelPool::start(artifacts.to_path_buf(), model.to_string(), &cfg)?;
    let client = pool.client();
    let half = (per_point / 2).max(1);
    let mut point = closed_loop_phased(
        &client,
        &[
            TrafficPhase {
                inputs: inputs.clone(),
                requests: half,
            },
            TrafficPhase {
                inputs: scaled_inputs(&inputs, 4.0),
                requests: half,
            },
        ],
        model,
        "recalib",
        32,
        deadline,
    );
    if let Some(r) = pool.recalib() {
        point.swaps = r.stats.swaps.load(Ordering::SeqCst);
        point.swap_ns = r.stats.last_refit_ns.load(Ordering::SeqCst);
        point.inflight_at_swap =
            r.stats.inflight_at_swap.load(Ordering::SeqCst);
    }
    pool.shutdown();
    points.push(point);
    Ok(points)
}

/// One model's bench pass (native backend: the measured engine must not
/// depend on optional features).  Also returns the schema-v3 executor
/// measurement: the identical quantized forward timed through the
/// persistent pool (warm `LayerPlan` cache) and again with
/// `force_spawn` pinning the legacy per-op scoped-spawn path.
fn bench_model(
    artifacts: &std::path::Path,
    model: &str,
    quick: bool,
) -> Result<(ModelBench, ExecBench)> {
    use bskmq::util::bench::{bench_cfg, black_box};
    use std::time::{Duration, Instant};

    let be = bskmq::backend::load(BackendKind::Native, artifacts, model)?;
    let (batch, in_elems) = {
        let m = be.manifest();
        (m.batch, m.input_elems())
    };
    let data = ModelData::load(artifacts, model)?;

    // calibration throughput (samples absorbed per second, end to end)
    let calib_batches = if quick { 2 } else { 8 };
    let t0 = Instant::now();
    let calib = Calibrator::with_specs(
        be.as_ref(),
        be.manifest().layer_specs(),
    )
    .calibrate_sharded(&data, calib_batches, 1)?;
    let calib_samples_per_sec =
        rate((calib.batches * batch) as f64, t0.elapsed().as_secs_f64());

    // quantized forward latency (one compiled batch per iteration)
    let x = ModelData::batch(&data.x_test, 0, batch).to_vec();
    let (warmup, budget, min_iters) = if quick {
        (Duration::from_millis(20), Duration::from_millis(80), 3)
    } else {
        (Duration::from_millis(150), Duration::from_millis(600), 10)
    };
    let r = bench_cfg(
        &format!("{model}:qfwd"),
        warmup,
        budget,
        min_iters,
        &mut || {
            black_box(be.run_qfwd(&x, &calib.programmed, 0.0, 7).unwrap());
        },
    );
    let qfwd_batch_ns = r.mean_ns();
    let forwards_per_sec = r.per_sec();

    // per-op breakdown: mean nanoseconds over a few profiled runs
    let prof_iters: u64 = if quick { 2 } else { 8 };
    let mut per_op: Vec<(String, u64)> = Vec::new();
    for _ in 0..prof_iters {
        let (_, timings) =
            be.run_qfwd_profiled(&x, &calib.programmed, 0.0, 7)?;
        for t in timings {
            let ns = t.nanos as u64;
            match per_op.iter_mut().find(|(n, _)| *n == t.name) {
                Some((_, acc)) => *acc += ns,
                None => per_op.push((t.name, ns)),
            }
        }
    }
    for (_, ns) in &mut per_op {
        *ns /= prof_iters;
    }

    // executor section: the qfwd timing above ran through the persistent
    // pool with the cached plan (the default path); re-time the same
    // forward with the pool disabled via force_spawn so the speedup is
    // apples-to-apples on this host
    let exec = {
        use bskmq::backend::native::{exec_pool, ops};
        exec_pool::force_spawn(true);
        let rs = bench_cfg(
            &format!("{model}:qfwd-spawn"),
            warmup,
            budget,
            min_iters,
            &mut || {
                black_box(
                    be.run_qfwd(&x, &calib.programmed, 0.0, 7).unwrap(),
                );
            },
        );
        exec_pool::force_spawn(false);
        let spawn_qfwd_ns = rs.mean_ns();
        let (_, pool_workers, _, _) = exec_pool::snapshot();
        ExecBench {
            model: model.to_string(),
            batch,
            exec_threads: ops::num_threads(),
            pool_workers,
            spawn_qfwd_ns,
            pool_qfwd_ns: qfwd_batch_ns,
            speedup: if qfwd_batch_ns > 0 {
                spawn_qfwd_ns as f64 / qfwd_batch_ns as f64
            } else {
                0.0
            },
            per_op_ns: per_op.clone(),
        }
    };

    // short closed-loop serving run against a 2-replica pool
    let cfg = PoolConfig {
        backend: BackendKind::Native,
        calib_batches,
        replicas: 2,
        ..PoolConfig::default()
    };
    let mut pool =
        ModelPool::start(artifacts.to_path_buf(), model.to_string(), &cfg)?;
    let client = pool.client();
    let total: usize = if quick { 64 } else { 512 };
    let wave = 16usize;
    let mut sent = 0usize;
    while sent < total {
        let n = wave.min(total - sent);
        let mut rxs = Vec::with_capacity(n);
        for k in 0..n {
            let mut xi = x[..in_elems].to_vec();
            // vary inputs slightly so waves are not byte-identical
            xi[0] += (sent + k) as f32 * 1e-6;
            rxs.push(client.submit(xi)?);
        }
        for rx in rxs {
            let _ = rx.recv_timeout(Duration::from_secs(30));
        }
        sent += n;
    }
    let lat = pool.stats.percentiles_ms(&[0.5, 0.99, 0.999]);
    let qw = pool.stats.queue_percentiles_ms(&[0.5, 0.99]);
    let mb = ModelBench {
        model: model.to_string(),
        batch,
        forwards_per_sec,
        qfwd_batch_ns,
        calib_samples_per_sec,
        serve_p50_ms: lat[0],
        serve_p99_ms: lat[1],
        serve_p999_ms: lat[2],
        serve_requests: pool.stats.requests.load(Ordering::Relaxed),
        serve_rejected: pool.rejected(),
        queue_p50_ms: qw[0],
        queue_p99_ms: qw[1],
        per_op_ns: per_op,
    };
    pool.shutdown();
    Ok((mb, exec))
}

fn info() -> Result<()> {
    let artifacts = bskmq::artifacts_dir();
    println!("artifacts dir: {}", artifacts.display());
    println!(
        "compiled backends: native{}",
        if cfg!(feature = "xla") { " + xla" } else { "" }
    );
    for model in bskmq::data::synth::MODELS {
        print!("  {model:<11}");
        match bskmq::backend::load(BackendKind::Native, &artifacts, model) {
            Ok(b) => {
                let m = b.manifest();
                print!(
                    " native[nq={} batch={} input={:?}]",
                    m.nq(),
                    m.batch,
                    m.input_shape
                );
            }
            Err(e) => print!(" native[UNAVAILABLE: {e}]"),
        }
        #[cfg(feature = "xla")]
        match bskmq::backend::load(BackendKind::Xla, &artifacts, model) {
            Ok(_) => print!(" xla[ok]"),
            Err(e) => print!(" xla[UNAVAILABLE: {e}]"),
        }
        println!();
    }
    Ok(())
}
