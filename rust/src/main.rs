//! BS-KMQ leader binary: experiment harnesses, the end-to-end pipeline
//! and the batched inference server (TCP front).
//!
//! Usage:
//!   bskmq exp <fig1|fig4|fig5|fig6|fig7|fig8|table1|backends|all>
//!   bskmq calibrate <model> <bits> [--backend B]   # print per-layer codebooks
//!   bskmq serve [--addr 127.0.0.1:7878] [--model resnet] [--bits 3]
//!               [--backend auto|native|xla]
//!   bskmq info                        # artifacts + backend summary
//!
//! The execution backend defaults to `auto` (XLA when compiled in and
//! loadable, the native integer IMC engine otherwise); `BSKMQ_BACKEND`
//! sets the process-wide default.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;

use anyhow::{Context, Result};

use bskmq::backend::{Backend, BackendKind};
use bskmq::coordinator::calibrate::Calibrator;
use bskmq::coordinator::server::InferenceServer;
use bskmq::data::dataset::ModelData;
use bskmq::quant::Method;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("exp") => {
            let id = args.get(1).map(String::as_str).unwrap_or("all");
            bskmq::experiments::run(id)
        }
        Some("calibrate") => {
            let model = args.get(1).map(String::as_str).unwrap_or("resnet");
            let bits: u32 = args
                .get(2)
                .filter(|s| !s.starts_with("--"))
                .map(|s| s.parse())
                .transpose()
                .context("bits must be an integer")?
                .unwrap_or(3);
            calibrate(model, bits, parse_backend_flag(args)?)
        }
        Some("serve") => serve(args),
        Some("info") => info(),
        _ => {
            eprintln!(
                "usage: bskmq <exp|calibrate|serve|info> [...]\n\
                 \x20 exp <fig1|fig4|fig5|fig6|fig7|fig8|table1|backends|all>\n\
                 \x20 calibrate <model> <bits> [--backend B]\n\
                 \x20 serve [--addr A] [--model M] [--bits B] [--backend B]\n\
                 \x20 info"
            );
            Ok(())
        }
    }
}

/// `--backend <kind>` anywhere in the args, else the env/auto default.
fn parse_backend_flag(args: &[String]) -> Result<BackendKind> {
    for i in 0..args.len() {
        if args[i] == "--backend" {
            let v = args.get(i + 1).context("--backend value")?;
            return BackendKind::parse(v);
        }
    }
    Ok(BackendKind::from_env())
}

fn calibrate(model: &str, bits: u32, kind: BackendKind) -> Result<()> {
    let artifacts = bskmq::artifacts_dir();
    let backend = bskmq::backend::load(kind, &artifacts, model)?;
    let data = ModelData::load(&artifacts, model)?;
    let calib = Calibrator::new(backend.as_ref(), Method::BsKmq, bits)
        .calibrate(&data, 8)?;
    println!(
        "calibrated {model} at {bits}b over {} batches ({} backend)",
        calib.batches,
        backend.name()
    );
    for (i, (book, q)) in calib
        .nl_books
        .iter()
        .zip(&backend.manifest().qlayers)
        .enumerate()
    {
        println!(
            "  layer {:>2} {:<10} K={:<4} centers[0..4] = {:?}",
            i,
            q.name,
            q.k,
            &book.centers[..4.min(book.centers.len())]
        );
    }
    Ok(())
}

fn serve(args: &[String]) -> Result<()> {
    let mut addr = "127.0.0.1:7878".to_string();
    let mut model = "resnet".to_string();
    let mut bits = 3u32;
    let mut kind = BackendKind::from_env();
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--addr" => {
                addr = args.get(i + 1).context("--addr value")?.clone();
                i += 2;
            }
            "--model" => {
                model = args.get(i + 1).context("--model value")?.clone();
                i += 2;
            }
            "--bits" => {
                bits = args.get(i + 1).context("--bits value")?.parse()?;
                i += 2;
            }
            "--backend" => {
                kind = BackendKind::parse(
                    args.get(i + 1).context("--backend value")?,
                )?;
                i += 2;
            }
            other => anyhow::bail!("unknown serve flag '{other}'"),
        }
    }
    let server = InferenceServer::start(
        bskmq::artifacts_dir(),
        model.clone(),
        kind,
        Method::BsKmq,
        bits,
        0.0,
        8,
    )?;
    let listener = TcpListener::bind(&addr)?;
    println!(
        "serving {model} ({bits}b BS-KMQ, {} backend) on {addr}",
        kind.name()
    );
    println!("protocol: one line of comma-separated input floats -> one line of logits");
    for stream in listener.incoming() {
        // one misbehaving client must not take the server down: per-line
        // errors answer on the wire, connection errors just end it
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("accept failed: {e}");
                continue;
            }
        };
        if let Err(e) = handle_client(&server, stream) {
            eprintln!("client connection error: {e}");
        }
        println!("client done; stats: {}", server.stats.summary());
    }
    Ok(())
}

/// One TCP client session: lines of comma-separated floats in, lines of
/// logits (or `error: ...`) out.  Returns Err only on connection IO.
fn handle_client(
    server: &InferenceServer,
    stream: std::net::TcpStream,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    while {
        line.clear();
        reader.read_line(&mut line)? > 0
    } {
        let parsed: std::result::Result<Vec<f32>, _> = line
            .trim()
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse::<f32>())
            .collect();
        let x = match parsed {
            Ok(x) => x,
            Err(e) => {
                writeln!(out, "error: parsing input floats: {e}")?;
                continue;
            }
        };
        match server.infer(x) {
            Ok(logits) => {
                let s: Vec<String> =
                    logits.iter().map(|v| format!("{v:.6}")).collect();
                writeln!(out, "{}", s.join(","))?;
            }
            Err(e) => writeln!(out, "error: {e}")?,
        }
    }
    Ok(())
}

fn info() -> Result<()> {
    let artifacts = bskmq::artifacts_dir();
    println!("artifacts dir: {}", artifacts.display());
    println!(
        "compiled backends: native{}",
        if cfg!(feature = "xla") { " + xla" } else { "" }
    );
    for model in ["resnet", "vgg", "inception", "distilbert"] {
        print!("  {model:<11}");
        match bskmq::backend::load(BackendKind::Native, &artifacts, model) {
            Ok(b) => {
                let m = b.manifest();
                print!(
                    " native[nq={} batch={} input={:?}]",
                    m.nq(),
                    m.batch,
                    m.input_shape
                );
            }
            Err(e) => print!(" native[UNAVAILABLE: {e}]"),
        }
        #[cfg(feature = "xla")]
        match bskmq::backend::load(BackendKind::Xla, &artifacts, model) {
            Ok(_) => print!(" xla[ok]"),
            Err(e) => print!(" xla[UNAVAILABLE: {e}]"),
        }
        println!();
    }
    Ok(())
}
