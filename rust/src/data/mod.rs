//! Workload data: loads the exported synthetic datasets (the exact
//! streams the models were trained on, written by aot.py) and generates
//! pure-Rust synthetic activation distributions for the quantizer
//! benchmarks and circuit workloads.

pub mod activations;
pub mod dataset;
pub mod synth;

pub use activations::{relu_activations, signed_activations, ActivationProfile};
pub use dataset::ModelData;
