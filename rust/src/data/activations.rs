//! Synthetic activation distributions for quantizer benchmarks: the
//! distribution families the paper's figures study — ReLU zero spikes,
//! clamp pile-ups, heavy signed tails (transformer projections).

use crate::util::rng::Rng;

/// Named activation profile (used by the fig1/fig4 benches as a
/// controlled complement to the real collected activations).
#[derive(Clone, Copy, Debug)]
pub enum ActivationProfile {
    /// post Conv-BN-ReLU: ~40-55 % exact zeros + half-Gaussian body
    ReluConv,
    /// ReLU + hardware clamp pile-up at the range top
    ReluClamped,
    /// signed, heavy-tailed attention projection (Fig. 4)
    AttentionSigned,
}

/// ReLU-family samples with optional lognormal outlier tail.
pub fn relu_activations(
    n: usize,
    mean: f64,
    std: f64,
    outlier_frac: f64,
    seed: u64,
) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut xs: Vec<f64> =
        (0..n).map(|_| rng.normal(mean, std).max(0.0)).collect();
    let n_out = (n as f64 * outlier_frac) as usize;
    for _ in 0..n_out {
        let i = rng.below(n);
        xs[i] = rng.normal(1.2, 0.8).exp();
    }
    xs
}

/// Signed heavy-tailed samples (Student-t-ish via Gaussian mixtures).
pub fn signed_activations(n: usize, std: f64, tail_frac: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            if rng.uniform() < tail_frac {
                rng.normal(0.0, std * 6.0)
            } else {
                rng.normal(0.0, std)
            }
        })
        .collect()
}

impl ActivationProfile {
    pub fn sample(&self, n: usize, seed: u64) -> Vec<f64> {
        match self {
            ActivationProfile::ReluConv => {
                relu_activations(n, 0.1, 1.0, 0.004, seed)
            }
            ActivationProfile::ReluClamped => {
                let clamp = 2.2;
                relu_activations(n, 0.3, 1.0, 0.0, seed)
                    .into_iter()
                    .map(|x| x.min(clamp))
                    .collect()
            }
            ActivationProfile::AttentionSigned => {
                signed_activations(n, 1.0, 0.02, seed)
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ActivationProfile::ReluConv => "relu_conv",
            ActivationProfile::ReluClamped => "relu_clamped",
            ActivationProfile::AttentionSigned => "attention_signed",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_has_zero_spike() {
        let xs = relu_activations(20_000, 0.1, 1.0, 0.0, 1);
        let zeros = xs.iter().filter(|&&x| x == 0.0).count();
        assert!(zeros > 6_000, "zero spike too small: {zeros}");
        assert!(xs.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn clamped_profile_piles_up() {
        let xs = ActivationProfile::ReluClamped.sample(20_000, 2);
        let at_clamp = xs.iter().filter(|&&x| x >= 2.2).count();
        assert!(at_clamp > 300, "clamp pile-up missing: {at_clamp}");
    }

    #[test]
    fn signed_tail_is_heavy() {
        let xs = ActivationProfile::AttentionSigned.sample(50_000, 3);
        let sd = crate::util::stats::std(&xs);
        let beyond_4sd =
            xs.iter().filter(|&&x| x.abs() > 4.0 * sd).count() as f64
                / xs.len() as f64;
        // a Gaussian would have ~6e-5 beyond 4 sigma
        assert!(beyond_4sd > 3e-4, "tail not heavy: {beyond_4sd}");
    }
}
