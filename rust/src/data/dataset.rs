//! Loader for `<model>_data.bin`: the calibration and test splits of the
//! synthetic tasks, exported at AOT time so the Rust pipeline evaluates
//! exactly the distribution the models were trained on.

use std::path::Path;

use anyhow::Result;

use crate::io::weights::load_tensors;
use crate::tensor::Tensor;

pub struct ModelData {
    /// calibration inputs [n_calib, ...input_shape]
    pub x_calib: Tensor,
    /// test inputs [n_test, ...input_shape]
    pub x_test: Tensor,
    /// test labels [n_test] (stored as f32 class indices)
    pub y_test: Vec<usize>,
}

impl ModelData {
    pub fn load(artifacts: &Path, model: &str) -> Result<ModelData> {
        let tm = load_tensors(artifacts.join(format!("{model}_data.bin")))?;
        let x_calib = tm.get("x_calib")?.clone();
        let x_test = tm.get("x_test")?.clone();
        let y_test = tm
            .get("y_test")?
            .data
            .iter()
            .map(|&v| v as usize)
            .collect();
        Ok(ModelData {
            x_calib,
            x_test,
            y_test,
        })
    }

    pub fn n_calib(&self) -> usize {
        self.x_calib.shape[0]
    }

    pub fn n_test(&self) -> usize {
        self.x_test.shape[0]
    }

    /// Batch `i` of `batch` samples from a split (row-major slice).
    pub fn batch<'a>(x: &'a Tensor, i: usize, batch: usize) -> &'a [f32] {
        let stride: usize = x.shape[1..].iter().product();
        &x.data[i * batch * stride..(i + 1) * batch * stride]
    }
}
