//! Pure-Rust synthetic artifact writer: a self-consistent manifest +
//! weights container + data splits for every synthetic topology, with
//! no Python and no HLO lowering.  This is what the native-backend tests,
//! the concurrency soak suite and the serving benches run on when the
//! real `make artifacts` outputs are absent — the shapes are miniature,
//! and every manifest carries the layer-graph IR (`graph` section built
//! by `nn::graphs`) the native backend executes, so the full pipeline
//! (collect -> Algorithm 1 -> qfwd -> replica pool) exercises the same
//! code paths as the trained artifacts.  The `mixer` topology exists
//! *only* as manifest data — no per-model Rust was ever written for it.

use std::path::Path;

use anyhow::{bail, Result};

use crate::io::manifest::{quant_spec_json, GraphDef};
use crate::io::weights::save_tensors;
use crate::nn::graphs;
use crate::quant::QuantSpec;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Batch size baked into every synthetic manifest.
pub const BATCH: usize = 4;
/// Classifier width of every synthetic model.
pub const CLASSES: usize = 10;
/// Per-layer activation subsample length (collect layout).
pub const SPL: usize = 4096;
/// Calibration split size (supports up to 8 calibration batches).
pub const N_CALIB: usize = 8 * BATCH;
/// Test split size.
pub const N_TEST: usize = 4 * BATCH;
/// Vocabulary of the synthetic distilbert task.
pub const BERT_VOCAB: usize = 32;
/// Sequence length of the synthetic distilbert task.
pub const BERT_SEQ: usize = 6;
/// Attention head count of the synthetic distilbert encoder.
pub const BERT_HEADS: usize = 4;

/// Every synthetic topology, in the order `write_all` emits them.
pub const MODELS: [&str; 5] =
    ["resnet", "vgg", "inception", "distilbert", "mixer"];

/// The mixture input family used by the property/fuzz tests: zero spike +
/// gaussian body + occasional far outliers, with random parameters per
/// call — the activation shape BS-KMQ is designed around.
pub fn mixture_samples(rng: &mut Rng, n: usize) -> Vec<f64> {
    let spike_frac = rng.uniform() * 0.6;
    let mu = rng.range(-2.0, 2.0);
    let sigma = rng.range(0.1, 3.0);
    let relu = rng.uniform() < 0.5;
    (0..n)
        .map(|_| {
            let v = if rng.uniform() < spike_frac {
                0.0
            } else if rng.uniform() < 0.01 {
                rng.normal(mu, sigma * 8.0)
            } else {
                rng.normal(mu, sigma)
            };
            if relu {
                v.max(0.0)
            } else {
                v
            }
        })
        .collect()
}

/// One quantized MAC layer of a synthetic topology: (name, k, n, relu).
type QSpec = (&'static str, usize, usize, bool);

/// resnet-mini layer table (manifest order = `nn::graphs::resnet_mini`
/// consumption order).
const RESNET: [QSpec; 7] = [
    ("conv0", 27, 16, true),
    ("b1c1", 144, 16, true),
    ("b1c2", 144, 16, false),
    ("b2c1", 144, 32, true),
    ("b2c2", 288, 32, false),
    ("b2sc", 16, 32, false),
    ("fc", 32, CLASSES, false),
];

/// vgg-mini: five 3x3 conv-relu layers (pool after conv1/conv3/conv4,
/// the `nn::graphs::vgg_mini` pool pattern), flatten at 2x2x16, two
/// dense layers.
const VGG: [QSpec; 7] = [
    ("conv0", 27, 8, true),
    ("conv1", 72, 8, true),
    ("conv2", 72, 16, true),
    ("conv3", 144, 16, true),
    ("conv4", 144, 16, true),
    ("fc1", 64, 32, true),
    ("fc2", 32, CLASSES, false),
];

/// inception-mini: stem + two 3-branch blocks (concat 4+8+4 -> 16 then
/// 8+8+8 -> 24 channels) + classifier, in `nn::graphs::inception_mini`
/// order (b0, b1a, b1b, pp per block).
const INCEPTION: [QSpec; 10] = [
    ("stem", 27, 8, true),
    ("i1b0", 8, 4, true),
    ("i1b1a", 8, 4, true),
    ("i1b1b", 36, 8, true),
    ("i1pp", 8, 4, true),
    ("i2b0", 16, 8, true),
    ("i2b1a", 16, 4, true),
    ("i2b1b", 36, 8, true),
    ("i2pp", 16, 8, true),
    ("fc", 24, CLASSES, false),
];

/// distilbert-mini: one encoder layer (q/k/v/o at d=8, ff 8->16->8) plus
/// the classifier; digital embedding/positional/layernorm params ride in
/// `weight_args` after the q-layer pairs.
const DISTILBERT: [QSpec; 7] = [
    ("l0_q", 8, 8, false),
    ("l0_k", 8, 8, false),
    ("l0_v", 8, 8, false),
    ("l0_o", 8, 8, false),
    ("l0_ff1", 8, 16, true),
    ("l0_ff2", 16, 8, false),
    ("cls", 8, CLASSES, false),
];

/// mixer-mini: the never-hardcoded fifth topology — 2x2 stride-2 patch
/// embed (12 = 2*2*3 inputs), a channel-mixing MLP with a residual over
/// the 64 patch tokens, layernorm, mean pooling, classifier.
const MIXER: [QSpec; 4] = [
    ("patch", 12, 8, false),
    ("mix1", 8, 16, true),
    ("mix2", 16, 8, false),
    ("cls", 8, CLASSES, false),
];

struct Topology {
    qlayers: &'static [QSpec],
    input_shape: &'static [usize],
    /// extra non-MAC parameters: (name, shape)
    digital: Vec<(String, Vec<usize>)>,
    /// inputs are token ids rather than images
    tokens: bool,
    /// the layer-graph IR embedded in the manifest
    graph: GraphDef,
}

fn topology(model: &str) -> Result<Topology> {
    let t = match model {
        "resnet" => Topology {
            qlayers: &RESNET,
            input_shape: &[16, 16, 3],
            digital: Vec::new(),
            tokens: false,
            graph: graphs::resnet_mini(),
        },
        "vgg" => Topology {
            qlayers: &VGG,
            input_shape: &[16, 16, 3],
            digital: Vec::new(),
            tokens: false,
            graph: graphs::vgg_mini(&[false, true, false, true, true]),
        },
        "inception" => Topology {
            qlayers: &INCEPTION,
            input_shape: &[16, 16, 3],
            digital: Vec::new(),
            tokens: false,
            graph: graphs::inception_mini(2),
        },
        "distilbert" => {
            let d = DISTILBERT[0].2; // d_model = first projection width
            Topology {
                qlayers: &DISTILBERT,
                input_shape: &[BERT_SEQ],
                digital: vec![
                    ("d_embed".into(), vec![BERT_VOCAB, d]),
                    ("d_pos".into(), vec![BERT_SEQ, d]),
                    ("d_l0_ln1_gamma".into(), vec![d]),
                    ("d_l0_ln1_beta".into(), vec![d]),
                    ("d_l0_ln2_gamma".into(), vec![d]),
                    ("d_l0_ln2_beta".into(), vec![d]),
                ],
                tokens: true,
                graph: graphs::distilbert_mini(1, BERT_HEADS),
            }
        }
        "mixer" => {
            let d = MIXER[0].2; // token width = patch-embed output
            Topology {
                qlayers: &MIXER,
                input_shape: &[16, 16, 3],
                digital: vec![
                    ("d_ln_gamma".into(), vec![d]),
                    ("d_ln_beta".into(), vec![d]),
                ],
                tokens: false,
                graph: graphs::mixer_mini(),
            }
        }
        other => bail!("no synthetic topology for model '{other}'"),
    };
    Ok(t)
}

/// Write a self-consistent synthetic artifact set (`<model>_manifest.json`,
/// `<model>_weights.bin`, `<model>_data.bin`) for one model into `dir`.
/// Deterministic: same model + same `seed` -> bit-identical artifacts.
pub fn write_model(dir: &Path, model: &str, seed: u64) -> Result<()> {
    let topo = topology(model)?;
    std::fs::create_dir_all(dir)?;
    let mut rng = Rng::new(seed ^ 0x5EED_A171);

    // --- weights container: he-init mats, small random biases, digital
    // params (layernorm scales at 1, shifts at 0, embeddings gaussian)
    let mut tensors: Vec<(String, Tensor)> = Vec::new();
    let mut weight_args: Vec<String> = Vec::new();
    for (i, (name, k, n, _relu)) in topo.qlayers.iter().enumerate() {
        let scale = (2.0 / *k as f64).sqrt();
        let w: Vec<f32> = (0..k * n)
            .map(|_| (rng.gaussian() * scale) as f32)
            .collect();
        let b: Vec<f32> =
            (0..*n).map(|_| (rng.gaussian() * 0.05) as f32).collect();
        let wname = format!("q{i:02}_{name}_w");
        let bname = format!("q{i:02}_{name}_b");
        weight_args
            .push(format!(r#"{{"name": "{wname}", "shape": [{k}, {n}]}}"#));
        weight_args.push(format!(r#"{{"name": "{bname}", "shape": [{n}]}}"#));
        tensors.push((wname, Tensor::new(vec![*k, *n], w)?));
        tensors.push((bname, Tensor::new(vec![*n], b)?));
    }
    for (name, shape) in &topo.digital {
        let len: usize = shape.iter().product();
        let data: Vec<f32> = if name.contains("gamma") {
            vec![1.0; len]
        } else if name.contains("beta") {
            vec![0.0; len]
        } else {
            (0..len).map(|_| (rng.gaussian() * 0.5) as f32).collect()
        };
        let dims: Vec<String> = shape.iter().map(|d| d.to_string()).collect();
        weight_args.push(format!(
            r#"{{"name": "{name}", "shape": [{}]}}"#,
            dims.join(", ")
        ));
        tensors.push((name.clone(), Tensor::new(shape.clone(), data)?));
    }
    let refs: Vec<(&str, &Tensor)> =
        tensors.iter().map(|(n, t)| (n.as_str(), t)).collect();
    save_tensors(dir.join(format!("{model}_weights.bin")), &refs)?;

    // --- manifest (same JSON layout aot.py writes)
    let nq = topo.qlayers.len();
    let logits_len = BATCH * CLASSES;
    // per-layer QuantSpec entries: the paper's fine-tuned NL-ADC levels
    // are per-network (3/3/4/4b for resnet/vgg/inception/distilbert), so
    // the manifest — not the CLI — carries each layer's precision
    let act_bits = paper_act_bits(model);
    let qlayers_json: Vec<String> = topo
        .qlayers
        .iter()
        .enumerate()
        .map(|(i, (name, k, n, relu))| {
            let spec = QuantSpec {
                act_bits,
                ..QuantSpec::default_for_layer(i)
            };
            format!(
                r#"{{"name": "{name}", "k": {k}, "n": {n}, "relu": {relu}, "quant": {}}}"#,
                quant_spec_json(&spec)
            )
        })
        .collect();
    let shape_json: Vec<String> =
        topo.input_shape.iter().map(|d| d.to_string()).collect();
    let manifest = format!(
        r#"{{
  "model": "{model}",
  "batch": {BATCH},
  "input_shape": [{}],
  "input_dtype": "f32",
  "num_classes": {CLASSES},
  "max_levels": 128,
  "qlayers": [{}],
  "weight_args": [{}],
  "collect": {{
    "out_len": {},
    "logits_len": {logits_len},
    "samples_per_layer": {SPL},
    "tilemax_offset": {}
  }},
  "artifacts": {{
    "collect": "{model}_collect.hlo.txt",
    "qfwd": "{model}_qfwd.hlo.txt"
  }},
  "graph": {}
}}"#,
        shape_json.join(", "),
        qlayers_json.join(","),
        weight_args.join(","),
        logits_len + nq * SPL + nq,
        logits_len + nq * SPL,
        topo.graph.to_json(),
    );
    std::fs::write(dir.join(format!("{model}_manifest.json")), manifest)?;

    // --- data splits: smooth-ish random images, or token-id sequences
    let elems: usize = topo.input_shape.iter().product();
    let gen = |rng: &mut Rng, n: usize| -> Vec<f32> {
        (0..n * elems)
            .map(|_| {
                if topo.tokens {
                    rng.below(BERT_VOCAB) as f32
                } else {
                    (rng.gaussian() * 0.6) as f32
                }
            })
            .collect()
    };
    let mut shape = vec![N_CALIB];
    shape.extend_from_slice(topo.input_shape);
    let x_calib = Tensor::new(shape, gen(&mut rng, N_CALIB))?;
    let mut shape = vec![N_TEST];
    shape.extend_from_slice(topo.input_shape);
    let x_test = Tensor::new(shape, gen(&mut rng, N_TEST))?;
    let y: Vec<f32> = (0..N_TEST).map(|_| rng.below(CLASSES) as f32).collect();
    let y_test = Tensor::new(vec![N_TEST], y)?;
    save_tensors(
        dir.join(format!("{model}_data.bin")),
        &[
            ("x_calib", &x_calib),
            ("x_test", &x_test),
            ("y_test", &y_test),
        ],
    )?;
    Ok(())
}

/// The paper's fine-tuned NL-ADC resolution per network (3/3/4/4b for
/// the four paper topologies; the mixer rides at the default 3).
pub fn paper_act_bits(model: &str) -> u32 {
    match model {
        "inception" | "distilbert" => 4,
        _ => 3,
    }
}

/// Write synthetic artifacts for every supported topology into `dir`.
pub fn write_all(dir: &Path, seed: u64) -> Result<()> {
    for model in MODELS {
        write_model(dir, model, seed)?;
    }
    Ok(())
}

/// The trained artifacts directory when present *and graph-bearing*,
/// otherwise a synthetic set written under the system temp dir — the
/// examples/benches fallback, so they run in any checkout without
/// Python.  Pre-IR artifact sets (manifests without a `graph` section)
/// fall back to synthetic too: the native backend executes only the
/// layer-graph IR.
pub fn ensure_artifacts() -> Result<std::path::PathBuf> {
    let dir = crate::artifacts_dir();
    let manifest = dir.join("resnet_manifest.json");
    if manifest.exists() {
        // present but corrupt must fail loudly, not silently fall back
        let m = crate::io::manifest::Manifest::load(&manifest)?;
        if m.graph.is_some() {
            return Ok(dir);
        }
        eprintln!(
            "artifacts in {} predate the layer-graph IR (no `graph` \
             section); using a synthetic set instead",
            dir.display()
        );
    }
    let dir = std::env::temp_dir().join("bskmq_synth_artifacts");
    write_all(&dir, 42)?;
    Ok(dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{load, Backend, BackendKind};
    use crate::data::dataset::ModelData;

    #[test]
    fn all_topologies_load_and_forward() {
        let dir =
            std::env::temp_dir().join("bskmq_synth_smoke");
        let _ = std::fs::remove_dir_all(&dir);
        write_all(&dir, 7).unwrap();
        for model in MODELS {
            let be = load(BackendKind::Native, &dir, model).unwrap();
            let data = ModelData::load(&dir, model).unwrap();
            let m = be.manifest();
            assert_eq!(m.batch, BATCH, "{model}");
            let out = be
                .run_collect(ModelData::batch(&data.x_calib, 0, m.batch))
                .unwrap();
            assert_eq!(out.logits.len(), BATCH * CLASSES, "{model}");
            assert!(
                out.logits.iter().all(|v| v.is_finite()),
                "{model} produced non-finite logits"
            );
        }
    }
}
