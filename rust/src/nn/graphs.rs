//! Layer-graph IR builders: programmatic construction of the manifest
//! `graph` section the native backend executes.
//!
//! `data::synth` embeds these graphs (via [`GraphDef::to_json`]) in the
//! synthetic manifests; nothing here fixes tensor sizes — a graph only
//! names q-layers, weight args and value edges, and the shape/k-n
//! consistency against a concrete manifest is checked at load time by
//! `backend::native::graph::GraphProgram::compile`.  The five mini
//! topologies below mirror the q-layer tables in `data::synth`; new
//! workloads need only a manifest, not new Rust.

use crate::io::manifest::{GraphDef, GraphOpDef};

/// Incremental [`GraphDef`] construction; one method per op kind.
pub struct GraphBuilder {
    input: String,
    ops: Vec<GraphOpDef>,
}

impl GraphBuilder {
    pub fn new(input: &str) -> GraphBuilder {
        GraphBuilder {
            input: input.to_string(),
            ops: Vec::new(),
        }
    }

    /// Quantized conv (im2col + tiled MAC); node name = q-layer name.
    pub fn conv(
        &mut self,
        qlayer: &str,
        input: &str,
        output: &str,
        kernel: usize,
        stride: usize,
        pad: &str,
    ) -> &mut Self {
        let mut op = GraphOpDef::new("conv", qlayer, &[input], output);
        op.qlayer = Some(qlayer.to_string());
        op.kernel = Some(kernel);
        op.stride = Some(stride);
        op.pad = Some(pad.to_string());
        self.ops.push(op);
        self
    }

    /// Quantized dense MAC; node name = q-layer name.
    pub fn dense(&mut self, qlayer: &str, input: &str, output: &str) -> &mut Self {
        let mut op = GraphOpDef::new("dense", qlayer, &[input], output);
        op.qlayer = Some(qlayer.to_string());
        self.ops.push(op);
        self
    }

    pub fn maxpool2(&mut self, name: &str, input: &str, output: &str) -> &mut Self {
        self.ops
            .push(GraphOpDef::new("maxpool2", name, &[input], output));
        self
    }

    pub fn avgpool3(&mut self, name: &str, input: &str, output: &str) -> &mut Self {
        self.ops
            .push(GraphOpDef::new("avgpool3", name, &[input], output));
        self
    }

    /// Global average pool: NHWC -> `[1, c]` per sample.
    pub fn gap(&mut self, name: &str, input: &str, output: &str) -> &mut Self {
        self.ops.push(GraphOpDef::new("gap", name, &[input], output));
        self
    }

    /// NHWC -> `[1, h*w*c]` per sample (the CNN classifier-head layout).
    pub fn flatten(&mut self, name: &str, input: &str, output: &str) -> &mut Self {
        self.ops
            .push(GraphOpDef::new("flatten", name, &[input], output));
        self
    }

    /// NHWC -> `[h*w, c]` per sample (patches-as-tokens reinterpret).
    pub fn tokens(&mut self, name: &str, input: &str, output: &str) -> &mut Self {
        self.ops
            .push(GraphOpDef::new("tokens", name, &[input], output));
        self
    }

    /// Channel concatenation of equal-spatial feature maps.
    pub fn concat(&mut self, name: &str, inputs: &[&str], output: &str) -> &mut Self {
        self.ops
            .push(GraphOpDef::new("concat", name, inputs, output));
        self
    }

    /// Residual add, optionally with a folded ReLU.
    pub fn add(
        &mut self,
        name: &str,
        a: &str,
        b: &str,
        output: &str,
        relu: bool,
    ) -> &mut Self {
        let mut op = GraphOpDef::new("add", name, &[a, b], output);
        op.relu = Some(relu);
        self.ops.push(op);
        self
    }

    /// Standalone elementwise ReLU fold.
    pub fn relu(&mut self, name: &str, input: &str, output: &str) -> &mut Self {
        self.ops.push(GraphOpDef::new("relu", name, &[input], output));
        self
    }

    /// Row-wise layer norm with named scale/shift weight args.
    pub fn layernorm(
        &mut self,
        name: &str,
        input: &str,
        output: &str,
        gamma: &str,
        beta: &str,
    ) -> &mut Self {
        let mut op = GraphOpDef::new("layernorm", name, &[input], output);
        op.gamma = Some(gamma.to_string());
        op.beta = Some(beta.to_string());
        self.ops.push(op);
        self
    }

    /// Digital multi-head attention over Q/K/V value edges.
    pub fn attention(
        &mut self,
        name: &str,
        q: &str,
        k: &str,
        v: &str,
        output: &str,
        heads: usize,
    ) -> &mut Self {
        let mut op = GraphOpDef::new("attention", name, &[q, k, v], output);
        op.heads = Some(heads);
        self.ops.push(op);
        self
    }

    /// Token-id embedding + positional add from named weight args.
    pub fn embed(
        &mut self,
        name: &str,
        input: &str,
        output: &str,
        table: &str,
        pos: &str,
    ) -> &mut Self {
        let mut op = GraphOpDef::new("embed", name, &[input], output);
        op.table = Some(table.to_string());
        op.pos = Some(pos.to_string());
        self.ops.push(op);
        self
    }

    /// Mean over the sequence axis: `[t, d]` -> `[1, d]` per sample.
    pub fn meanseq(&mut self, name: &str, input: &str, output: &str) -> &mut Self {
        self.ops
            .push(GraphOpDef::new("meanseq", name, &[input], output));
        self
    }

    pub fn finish(self, output: &str) -> GraphDef {
        GraphDef {
            input: self.input,
            output: output.to_string(),
            ops: self.ops,
        }
    }
}

/// resnet-mini: stem, one identity block, one strided projection block,
/// GAP, linear classifier.  Residual adds + ReLUs are digital.
pub fn resnet_mini() -> GraphDef {
    let mut g = GraphBuilder::new("x");
    g.conv("conv0", "x", "y0", 3, 1, "same")
        .conv("b1c1", "y0", "h1", 3, 1, "same")
        .conv("b1c2", "h1", "h2", 3, 1, "same")
        .add("res1", "y0", "h2", "y1", true)
        .conv("b2c1", "y1", "h3", 3, 2, "same")
        .conv("b2c2", "h3", "h4", 3, 1, "same")
        .conv("b2sc", "y1", "h5", 1, 2, "same")
        .add("res2", "h4", "h5", "y2", true)
        .gap("gap", "y2", "p")
        .dense("fc", "p", "logits");
    g.finish("logits")
}

/// vgg-mini: conv-relu stack with max pools after the layers flagged in
/// `pool_after`, flatten, two dense classifier layers.
pub fn vgg_mini(pool_after: &[bool]) -> GraphDef {
    let mut g = GraphBuilder::new("x");
    let mut cur = "x".to_string();
    for (i, &pool) in pool_after.iter().enumerate() {
        let conv_out = format!("c{i}");
        g.conv(&format!("conv{i}"), &cur, &conv_out, 3, 1, "same");
        cur = conv_out;
        if pool {
            let pool_out = format!("m{i}");
            g.maxpool2(&format!("pool{i}"), &cur, &pool_out);
            cur = pool_out;
        }
    }
    g.flatten("flat", &cur, "f")
        .dense("fc1", "f", "d1")
        .dense("fc2", "d1", "logits");
    g.finish("logits")
}

/// inception-mini: stem + max-pool, `blocks` three-branch modules
/// (1x1 | 1x1->3x3 | avg-pool->1x1, channel-concatenated), GAP, fc.
pub fn inception_mini(blocks: usize) -> GraphDef {
    let mut g = GraphBuilder::new("x");
    g.conv("stem", "x", "s0", 3, 1, "same").maxpool2("pool", "s0", "y0");
    let mut cur = "y0".to_string();
    for b in 1..=blocks {
        let (b0, t, b1, pp, b2, cat) = (
            format!("i{b}e0"),
            format!("i{b}t"),
            format!("i{b}e1"),
            format!("i{b}pool"),
            format!("i{b}e2"),
            format!("y{b}"),
        );
        g.conv(&format!("i{b}b0"), &cur, &b0, 1, 1, "same")
            .conv(&format!("i{b}b1a"), &cur, &t, 1, 1, "same")
            .conv(&format!("i{b}b1b"), &t, &b1, 3, 1, "same")
            .avgpool3(&format!("i{b}avg"), &cur, &pp)
            .conv(&format!("i{b}pp"), &pp, &b2, 1, 1, "same")
            .concat(&format!("i{b}cat"), &[&b0, &b1, &b2], &cat);
        cur = cat;
    }
    g.gap("gap", &cur, "p").dense("fc", "p", "logits");
    g.finish("logits")
}

/// distilbert-mini: embedding + position add, `n_layers` post-LN encoder
/// layers (quantized Q/K/V/O/FF projections, digital attention +
/// layernorm), mean pooling, classifier.
pub fn distilbert_mini(n_layers: usize, heads: usize) -> GraphDef {
    let mut g = GraphBuilder::new("x");
    g.embed("embed", "x", "h0", "d_embed", "d_pos");
    let mut cur = "h0".to_string();
    for l in 0..n_layers {
        let pre = format!("l{l}");
        g.dense(&format!("{pre}_q"), &cur, &format!("{pre}.q"))
            .dense(&format!("{pre}_k"), &cur, &format!("{pre}.k"))
            .dense(&format!("{pre}_v"), &cur, &format!("{pre}.v"))
            .attention(
                &format!("{pre}_att"),
                &format!("{pre}.q"),
                &format!("{pre}.k"),
                &format!("{pre}.v"),
                &format!("{pre}.a"),
                heads,
            )
            .dense(&format!("{pre}_o"), &format!("{pre}.a"), &format!("{pre}.o"))
            .add(
                &format!("{pre}_res1"),
                &cur,
                &format!("{pre}.o"),
                &format!("{pre}.s1"),
                false,
            )
            .layernorm(
                &format!("{pre}_ln1"),
                &format!("{pre}.s1"),
                &format!("{pre}.h1"),
                &format!("d_{pre}_ln1_gamma"),
                &format!("d_{pre}_ln1_beta"),
            )
            .dense(&format!("{pre}_ff1"), &format!("{pre}.h1"), &format!("{pre}.f1"))
            .dense(&format!("{pre}_ff2"), &format!("{pre}.f1"), &format!("{pre}.f2"))
            .add(
                &format!("{pre}_res2"),
                &format!("{pre}.h1"),
                &format!("{pre}.f2"),
                &format!("{pre}.s2"),
                false,
            )
            .layernorm(
                &format!("{pre}_ln2"),
                &format!("{pre}.s2"),
                &format!("h{}", l + 1),
                &format!("d_{pre}_ln2_gamma"),
                &format!("d_{pre}_ln2_beta"),
            );
        cur = format!("h{}", l + 1);
    }
    g.meanseq("pool", &cur, "pooled").dense("cls", "pooled", "logits");
    g.finish("logits")
}

/// mixer-mini: the fifth, never-hardcoded topology — a small
/// MLP-Mixer-style graph (patch-embed conv, patches-as-tokens, per-token
/// channel-mixing MLP with a residual, layernorm, mean pooling,
/// classifier).  It exists only as manifest data; no per-model Rust ever
/// existed for it.
pub fn mixer_mini() -> GraphDef {
    let mut g = GraphBuilder::new("x");
    g.conv("patch", "x", "pe", 2, 2, "valid")
        .tokens("tok", "pe", "t0")
        .dense("mix1", "t0", "m1")
        .dense("mix2", "m1", "m2")
        .add("res", "t0", "m2", "r", false)
        .relu("act", "r", "ra")
        .layernorm("ln", "ra", "n", "d_ln_gamma", "d_ln_beta")
        .meanseq("pool", "n", "pooled")
        .dense("cls", "pooled", "logits");
    g.finish("logits")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::manifest::parse_graph_str;

    #[test]
    fn builders_roundtrip_and_cover_the_vocabulary() {
        for (g, n_ops) in [
            (resnet_mini(), 10),
            (vgg_mini(&[false, true, false, true, true]), 11),
            (inception_mini(2), 16),
            (distilbert_mini(1, 4), 14),
            (mixer_mini(), 9),
        ] {
            assert_eq!(g.ops.len(), n_ops);
            assert_eq!(g.input, "x");
            assert_eq!(g.output, "logits");
            let back = parse_graph_str(&g.to_json()).unwrap();
            assert_eq!(back.ops.len(), g.ops.len());
            for (a, b) in g.ops.iter().zip(&back.ops) {
                assert_eq!(a.op, b.op);
                assert_eq!(a.name, b.name);
                assert_eq!(a.inputs, b.inputs);
                assert_eq!(a.output, b.output);
                assert_eq!(a.qlayer, b.qlayer);
            }
        }
    }

    #[test]
    fn distilbert_graph_consumes_qlayers_in_manifest_order() {
        let g = distilbert_mini(2, 4);
        let used: Vec<String> =
            g.ops.iter().filter_map(|o| o.qlayer.clone()).collect();
        assert_eq!(
            used,
            vec![
                "l0_q", "l0_k", "l0_v", "l0_o", "l0_ff1", "l0_ff2", "l1_q",
                "l1_k", "l1_v", "l1_o", "l1_ff1", "l1_ff2", "cls"
            ]
        );
    }
}
