//! Network descriptors: layer shapes of the paper's four evaluation
//! models at *paper scale* (for the system-level cost simulation of
//! Table 1) and of the mini models (for cross-checks against the AOT
//! manifests).

pub mod zoo;

pub use zoo::{distilbert, inception_v3, resnet18_cifar, vgg16_cifar, Layer, Network};
