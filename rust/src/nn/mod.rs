//! Network descriptors: layer shapes of the paper's four evaluation
//! models at *paper scale* (for the system-level cost simulation of
//! Table 1), and the layer-graph IR builders that emit the manifest
//! `graph` sections the native backend executes.

pub mod graphs;
pub mod zoo;

pub use graphs::GraphBuilder;
pub use zoo::{distilbert, inception_v3, resnet18_cifar, vgg16_cifar, Layer, Network};
