//! Paper-scale network shape descriptors.
//!
//! Only the MAC structure matters for the system simulation: each layer
//! contributes a weight matrix (K = receptive field, N = output features)
//! and an output count (MAC rows per inference).

/// One MAC layer as mapped onto IMC crossbars.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    /// contraction size (kh*kw*cin for convs, din for dense)
    pub k: usize,
    /// output features
    pub n: usize,
    /// output positions per inference (oh*ow for convs, tokens or 1)
    pub positions: usize,
}

impl Layer {
    pub fn conv(name: &str, cin: usize, cout: usize, ksz: usize,
                oh: usize, ow: usize) -> Layer {
        Layer {
            name: name.into(),
            k: ksz * ksz * cin,
            n: cout,
            positions: oh * ow,
        }
    }

    pub fn dense(name: &str, din: usize, dout: usize, positions: usize) -> Layer {
        Layer {
            name: name.into(),
            k: din,
            n: dout,
            positions,
        }
    }

    /// MAC operations per inference (x2 for multiply+accumulate).
    pub fn ops(&self) -> f64 {
        2.0 * (self.k * self.n * self.positions) as f64
    }
}

#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Network {
    pub fn total_ops(&self) -> f64 {
        self.layers.iter().map(Layer::ops).sum()
    }

    pub fn total_weights(&self) -> usize {
        self.layers.iter().map(|l| l.k * l.n).sum()
    }
}

/// ResNet-18 (CIFAR-10 variant, 32x32 input) — the Table 1 workload.
pub fn resnet18_cifar() -> Network {
    let mut layers = vec![Layer::conv("conv1", 3, 64, 3, 32, 32)];
    let stages: [(usize, usize, usize); 4] =
        [(64, 32, 0), (128, 16, 1), (256, 8, 1), (512, 4, 1)];
    let mut cin = 64;
    for (si, &(c, hw, strided)) in stages.iter().enumerate() {
        for b in 0..2 {
            let in_c = if b == 0 { cin } else { c };
            layers.push(Layer::conv(
                &format!("s{si}b{b}c1"), in_c, c, 3, hw, hw));
            layers.push(Layer::conv(
                &format!("s{si}b{b}c2"), c, c, 3, hw, hw));
            if b == 0 && strided == 1 {
                layers.push(Layer::conv(
                    &format!("s{si}sc"), in_c, c, 1, hw, hw));
            }
        }
        cin = c;
    }
    layers.push(Layer::dense("fc", 512, 10, 1));
    Network {
        name: "resnet18".into(),
        layers,
    }
}

/// VGG-16 (CIFAR-100 variant).
pub fn vgg16_cifar() -> Network {
    let cfg: [(usize, usize, usize); 13] = [
        (3, 64, 32), (64, 64, 32),
        (64, 128, 16), (128, 128, 16),
        (128, 256, 8), (256, 256, 8), (256, 256, 8),
        (256, 512, 4), (512, 512, 4), (512, 512, 4),
        (512, 512, 2), (512, 512, 2), (512, 512, 2),
    ];
    let mut layers: Vec<Layer> = cfg
        .iter()
        .enumerate()
        .map(|(i, &(cin, cout, hw))| {
            Layer::conv(&format!("conv{}", i + 1), cin, cout, 3, hw, hw)
        })
        .collect();
    layers.push(Layer::dense("fc1", 512, 512, 1));
    layers.push(Layer::dense("fc2", 512, 100, 1));
    Network {
        name: "vgg16".into(),
        layers,
    }
}

/// Inception-V3 (Tiny-ImageNet, 64x64 input) — coarse per-block shapes.
pub fn inception_v3() -> Network {
    let mut layers = vec![
        Layer::conv("stem1", 3, 32, 3, 32, 32),
        Layer::conv("stem2", 32, 64, 3, 32, 32),
        Layer::conv("stem3", 64, 80, 1, 16, 16),
        Layer::conv("stem4", 80, 192, 3, 16, 16),
    ];
    // 3 inception-A style blocks at 16x16 / 288 ch
    let mut cin = 192;
    for b in 0..3 {
        for (bi, &(k, cout)) in
            [(1, 64), (1, 48), (5, 64), (1, 64), (3, 96), (1, 64)]
                .iter()
                .enumerate()
        {
            layers.push(Layer::conv(
                &format!("a{b}_{bi}"), cin.min(288), cout, k, 16, 16));
        }
        cin = 288;
    }
    // reduction + 2 inception-C style blocks at 8x8
    layers.push(Layer::conv("red", 288, 384, 3, 8, 8));
    for b in 0..2 {
        for (bi, &(k, cout)) in
            [(1, 320), (1, 384), (3, 384), (1, 448), (3, 384)]
                .iter()
                .enumerate()
        {
            layers.push(Layer::conv(
                &format!("c{b}_{bi}"), 768, cout, k, 8, 8));
        }
    }
    layers.push(Layer::dense("fc", 2048, 200, 1));
    Network {
        name: "inception_v3".into(),
        layers,
    }
}

/// DistilBERT-base (seq len 128): 6 layers, d=768, ff=3072.
pub fn distilbert() -> Network {
    let t = 128;
    let d = 768;
    let ff = 3072;
    let mut layers = Vec::new();
    for l in 0..6 {
        for p in ["q", "k", "v", "o"] {
            layers.push(Layer::dense(&format!("l{l}_{p}"), d, d, t));
        }
        layers.push(Layer::dense(&format!("l{l}_ff1"), d, ff, t));
        layers.push(Layer::dense(&format!("l{l}_ff2"), ff, d, t));
    }
    layers.push(Layer::dense("qa", d, 2, t));
    Network {
        name: "distilbert".into(),
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_shape_sanity() {
        let net = resnet18_cifar();
        // 1 stem + 4 stages x (2 blocks x 2 convs) + 3 shortcuts + fc
        assert_eq!(net.layers.len(), 1 + 16 + 3 + 1);
        // CIFAR ResNet-18: ~11M params, ~0.56 GMACs -> ~1.1 Gops
        let w = net.total_weights() as f64;
        assert!((1.0e7..1.3e7).contains(&w), "weights {w}");
        let ops = net.total_ops();
        assert!((0.9e9..1.4e9).contains(&ops), "ops {ops}");
    }

    #[test]
    fn vgg16_has_more_weights_than_resnet18() {
        // on CIFAR inputs VGG-16 has more *weights* (big dense stacks)
        // while ResNet-18 has more ops (larger early feature maps)
        assert!(vgg16_cifar().total_weights() > resnet18_cifar().total_weights());
        assert!(resnet18_cifar().total_ops() > vgg16_cifar().total_ops());
    }

    #[test]
    fn distilbert_param_count() {
        let net = distilbert();
        // ~42M MAC weights in the 6 encoder layers
        let w = net.total_weights() as f64;
        assert!((3.5e7..5.0e7).contains(&w), "weights {w}");
    }
}
