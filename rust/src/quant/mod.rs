//! Quantizer suite: BS-KMQ (paper Algorithm 1) + the four baselines of
//! Fig. 1, the floor-ADC codebook machinery (Eq. 2) and the §2.3 hardware
//! projection.  Mirrors `python/compile/quantlib/`; golden-vector tests in
//! `rust/tests/quant_parity.rs` pin the two implementations together.

pub mod bs_kmq;
pub mod cdf;
pub mod codebook;
pub mod kmeans;
pub mod linear;
pub mod lloyd_max;
pub mod weights;

pub use bs_kmq::{fit_bs_kmq, BsKmqCalibrator};
pub use cdf::fit_cdf;
pub use codebook::{Codebook, MAX_LEVELS};
pub use kmeans::{fit_kmeans, kmeans_1d};
pub use linear::fit_linear;
pub use lloyd_max::fit_lloyd_max;
pub use weights::quantize_weights_linear;

/// The five quantization methods evaluated in Fig. 1 / Fig. 4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Linear,
    LloydMax,
    Cdf,
    KMeans,
    BsKmq,
}

impl Method {
    pub const ALL: [Method; 5] = [
        Method::Linear,
        Method::LloydMax,
        Method::Cdf,
        Method::KMeans,
        Method::BsKmq,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Linear => "linear",
            Method::LloydMax => "lloyd_max",
            Method::Cdf => "cdf",
            Method::KMeans => "kmeans",
            Method::BsKmq => "bs_kmq",
        }
    }

    /// Fit `2^bits` centers on `samples` (sorted ascending output).
    pub fn fit(&self, samples: &[f64], bits: u32) -> Vec<f64> {
        match self {
            Method::Linear => fit_linear(samples, bits),
            Method::LloydMax => fit_lloyd_max(samples, bits),
            Method::Cdf => fit_cdf(samples, bits),
            Method::KMeans => fit_kmeans(samples, bits, 0),
            Method::BsKmq => fit_bs_kmq(samples, bits),
        }
    }

    /// Fit and project onto the IM NL-ADC grid — the deployed codebook.
    pub fn fit_hw(&self, samples: &[f64], bits: u32) -> Codebook {
        let centers = self.fit(samples, bits);
        Codebook::from_centers(&centers).project_to_hardware(bits)
    }
}
