//! Quantizer suite: BS-KMQ (paper Algorithm 1) + the four baselines of
//! Fig. 1, the floor-ADC codebook machinery (Eq. 2) and the §2.3 hardware
//! projection — behind the streaming mergeable [`QuantEstimator`] trait
//! the calibration pipeline consumes, configured per layer by
//! [`QuantSpec`].  Mirrors `python/compile/quantlib/`; the invariants are
//! pinned by `rust/tests/quant_properties.rs` (codebook/fitter
//! properties) and `rust/tests/quant_spec.rs` (estimator merge laws,
//! spec plumbing, sharded-calibration equivalence).

pub mod bs_kmq;
pub mod cdf;
pub mod codebook;
pub mod estimator;
pub mod kmeans;
pub mod linear;
pub mod lloyd_max;
pub mod sketch;
pub mod spec;
pub mod weights;

pub use bs_kmq::{fit_bs_kmq, BsKmqCalibrator};
pub use cdf::fit_cdf;
pub use codebook::{Codebook, MAX_LEVELS};
pub use estimator::{estimator_for, QuantEstimator};
pub use kmeans::{fit_kmeans, kmeans_1d};
pub use linear::fit_linear;
pub use lloyd_max::fit_lloyd_max;
pub use sketch::ValueSketch;
pub use spec::{Method, QuantSpec};
pub use weights::quantize_weights_linear;
