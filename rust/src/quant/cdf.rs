//! CDF (equal-probability) quantizer baseline [11]: centers at mid-cell
//! quantiles.  On ReLU activations the zero spike collapses many quantiles
//! onto the same value — the degeneracy the paper calls out; duplicates
//! are nudged just enough to keep the reference ladder strictly sorted.

use crate::util::stats::quantile_sorted;

/// `2^bits` equal-probability-mass centers (mid-cell quantiles).
pub fn fit_cdf(samples: &[f64], bits: u32) -> Vec<f64> {
    assert!((1..=7).contains(&bits), "bits in [1,7]");
    assert!(!samples.is_empty(), "empty sample set");
    let k = 1usize << bits;
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let absmax = v
        .iter()
        .fold(1.0f64, |m, x| m.max(x.abs()));
    let eps = 1e-12 + 1e-9 * absmax;
    let mut centers: Vec<f64> = (0..k)
        .map(|i| quantile_sorted(&v, (i as f64 + 0.5) / k as f64))
        .collect();
    for i in 1..k {
        if centers[i] <= centers[i - 1] {
            centers[i] = centers[i - 1] + eps;
        }
    }
    centers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_mass_on_uniform() {
        let xs: Vec<f64> = (0..10_000).map(|i| i as f64 / 10_000.0).collect();
        let c = fit_cdf(&xs, 2);
        let want = [0.125, 0.375, 0.625, 0.875];
        for (a, b) in c.iter().zip(want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_spike_degenerates_but_stays_sorted() {
        let mut xs = vec![0.0; 9_000];
        xs.extend((0..1_000).map(|i| 1.0 + i as f64 / 1_000.0));
        let c = fit_cdf(&xs, 3);
        // strictly increasing despite 90% identical samples
        assert!(c.windows(2).all(|w| w[0] < w[1]));
        // most centers collapsed near the spike - the paper's failure mode
        assert!(c[5] < 1e-3, "expected collapse, got {:?}", c);
    }
}
