//! Per-layer quantization configuration: the [`QuantSpec`] every q-layer
//! carries (in the manifest's `qlayers` entries, on the CLI, and through
//! the calibration/PTQ/serving pipeline) plus the [`Method`] identifier
//! naming one of the five fitters.
//!
//! The paper's headline configurations are *mixed precision* — 3/3/4/4b
//! NL-ADC levels across the four networks after fine-tuning, and the
//! 6/2/3b (tile/weight/activation) ResNet-18 system point of Table 1 —
//! so precision is a per-layer artifact here, not a CLI global.  The CLI
//! spelling is `[method:]TILE/WEIGHT/ACT` (weight `-` = keep float) or a
//! bare `ACT` bit count, e.g. `6/2/3` or `bs_kmq:6/-/3` or `4`.

use anyhow::{bail, ensure, Context, Result};

use crate::quant::bs_kmq::{fit_bs_kmq_cfg, DEFAULT_ALPHA};
use crate::quant::cdf::fit_cdf;
use crate::quant::codebook::Codebook;
use crate::quant::kmeans::fit_kmeans;
use crate::quant::linear::fit_linear;
use crate::quant::lloyd_max::fit_lloyd_max;

/// Identifier of one of the five quantization methods evaluated in
/// Fig. 1 / Fig. 4.  This is a *name*: fitting goes through the
/// streaming [`crate::quant::QuantEstimator`] trait (calibration) or the
/// one-shot wrappers below (figures, benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Linear,
    LloydMax,
    Cdf,
    KMeans,
    BsKmq,
}

impl Method {
    pub const ALL: [Method; 5] = [
        Method::Linear,
        Method::LloydMax,
        Method::Cdf,
        Method::KMeans,
        Method::BsKmq,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Linear => "linear",
            Method::LloydMax => "lloyd_max",
            Method::Cdf => "cdf",
            Method::KMeans => "kmeans",
            Method::BsKmq => "bs_kmq",
        }
    }

    /// Inverse of [`Method::name`] (manifest `quant.method`, CLI specs).
    pub fn parse(s: &str) -> Result<Method> {
        match s {
            "linear" => Ok(Method::Linear),
            "lloyd_max" => Ok(Method::LloydMax),
            "cdf" => Ok(Method::Cdf),
            "kmeans" => Ok(Method::KMeans),
            "bs_kmq" => Ok(Method::BsKmq),
            other => bail!(
                "unknown quantization method '{other}' \
                 (linear|lloyd_max|cdf|kmeans|bs_kmq)"
            ),
        }
    }

    /// One-shot fit of `2^bits` centers (sorted ascending).  `seed`
    /// drives every stochastic stage (k-means++ init, BS-KMQ reservoir),
    /// so results are reproducible by configuration, never by accident.
    pub fn fit(&self, samples: &[f64], bits: u32, seed: u64) -> Vec<f64> {
        match self {
            Method::Linear => fit_linear(samples, bits),
            Method::LloydMax => fit_lloyd_max(samples, bits),
            Method::Cdf => fit_cdf(samples, bits),
            Method::KMeans => fit_kmeans(samples, bits, seed),
            Method::BsKmq => {
                fit_bs_kmq_cfg(samples, bits, DEFAULT_ALPHA, 8, seed)
            }
        }
    }

    /// Fit and project onto the IM NL-ADC grid — the deployed codebook.
    pub fn fit_hw(&self, samples: &[f64], bits: u32, seed: u64) -> Codebook {
        let centers = self.fit(samples, bits, seed);
        Codebook::from_centers(&centers).project_to_hardware(bits)
    }
}

/// Per-layer quantization configuration.
///
/// Carried in the manifest's `qlayers[i].quant` entries, resolved by
/// [`crate::io::manifest::Manifest::layer_specs`] (absent entries get
/// [`QuantSpec::default_for_layer`], which reproduces the historical
/// uniform BS-KMQ/3-bit behavior), validated against the manifest's
/// `max_levels` by `GraphProgram::compile`, and consumed by the
/// calibrator, the PTQ evaluator and the serving pools.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantSpec {
    /// which fitter programs this layer's NL-ADC codebook
    pub method: Method,
    /// layer-output NL-ADC resolution (the paper's per-network 3/3/4/4b)
    pub act_bits: u32,
    /// linear weight quantization; `None` keeps the trained float weights
    pub weight_bits: Option<u32>,
    /// per-tile partial-sum conversion resolution (linear codebook)
    pub tile_bits: u32,
    /// Algorithm 1 tail-trim fraction
    pub alpha: f64,
    /// seed of every stochastic fitting stage for this layer
    pub seed: u64,
}

impl Default for QuantSpec {
    fn default() -> QuantSpec {
        QuantSpec {
            method: Method::BsKmq,
            act_bits: 3,
            weight_bits: None,
            tile_bits: 7,
            alpha: DEFAULT_ALPHA,
            seed: 0,
        }
    }
}

impl QuantSpec {
    /// A default spec with the given method and NL-ADC resolution.
    pub fn new(method: Method, act_bits: u32) -> QuantSpec {
        QuantSpec {
            method,
            act_bits,
            ..QuantSpec::default()
        }
    }

    /// This spec re-seeded for q-layer `layer`: uniform configurations
    /// still give every layer its own fitting seed (`seed + layer`),
    /// matching the historical per-layer seeding of the calibrator.
    pub fn for_layer(&self, layer: usize) -> QuantSpec {
        QuantSpec {
            seed: self.seed.wrapping_add(layer as u64),
            ..*self
        }
    }

    /// The spec a manifest without per-layer entries resolves to for
    /// q-layer `layer` — exactly the pre-QuantSpec pipeline defaults.
    pub fn default_for_layer(layer: usize) -> QuantSpec {
        QuantSpec::default().for_layer(layer)
    }

    /// Expand a uniform spec into the per-layer vector an `nq`-layer
    /// model consumes (each layer re-seeded via [`QuantSpec::for_layer`]).
    pub fn per_layer(&self, nq: usize) -> Vec<QuantSpec> {
        (0..nq).map(|i| self.for_layer(i)).collect()
    }

    /// Range/consistency checks against a manifest's `max_levels`.
    pub fn validate(&self, max_levels: usize) -> Result<()> {
        ensure!(
            (1..=7).contains(&self.act_bits),
            "act_bits must be in [1, 7], got {}",
            self.act_bits
        );
        ensure!(
            (1..=7).contains(&self.tile_bits),
            "tile_bits must be in [1, 7], got {}",
            self.tile_bits
        );
        ensure!(
            (1usize << self.act_bits) <= max_levels,
            "act_bits {} needs {} levels but the manifest caps max_levels \
             at {max_levels}",
            self.act_bits,
            1usize << self.act_bits
        );
        ensure!(
            (1usize << self.tile_bits) <= max_levels,
            "tile_bits {} needs {} levels but the manifest caps max_levels \
             at {max_levels}",
            self.tile_bits,
            1usize << self.tile_bits
        );
        if let Some(w) = self.weight_bits {
            ensure!(
                (2..=8).contains(&w),
                "weight_bits must be in [2, 8], got {w}"
            );
        }
        ensure!(
            (0.0..0.5).contains(&self.alpha),
            "alpha must be in [0, 0.5), got {}",
            self.alpha
        );
        Ok(())
    }

    /// Parse a CLI spec string over `base` (unmentioned fields keep the
    /// base's values): `[method:]TILE/WEIGHT/ACT` or `[method:]ACT`,
    /// with weight `-`/`none`/`float` meaning "keep float weights".
    pub fn parse(s: &str, base: &QuantSpec) -> Result<QuantSpec> {
        let mut spec = *base;
        let body = match s.split_once(':') {
            Some((m, rest)) => {
                spec.method = Method::parse(m)?;
                rest
            }
            None => s,
        };
        let parse_bits = |part: &str, what: &str| -> Result<u32> {
            part.parse::<u32>()
                .with_context(|| format!("spec '{s}': {what} bits '{part}'"))
        };
        let parts: Vec<&str> = body.split('/').collect();
        match parts.as_slice() {
            [a] => spec.act_bits = parse_bits(a, "activation")?,
            [t, w, a] => {
                spec.tile_bits = parse_bits(t, "tile")?;
                spec.weight_bits = match *w {
                    "-" | "none" | "float" => None,
                    w => Some(parse_bits(w, "weight")?),
                };
                spec.act_bits = parse_bits(a, "activation")?;
            }
            _ => bail!(
                "spec '{s}' is neither ACT nor TILE/WEIGHT/ACT \
                 (e.g. '3', '6/2/3', 'bs_kmq:6/-/3')"
            ),
        }
        Ok(spec)
    }

    /// Compact human-readable form, `method tT/wW/aA`.
    pub fn summary(&self) -> String {
        let w = match self.weight_bits {
            Some(w) => w.to_string(),
            None => "-".to_string(),
        };
        format!(
            "{} t{}/w{}/a{}",
            self.method.name(),
            self.tile_bits,
            w,
            self.act_bits
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_names_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("median").is_err());
    }

    #[test]
    fn default_matches_historical_pipeline() {
        let d = QuantSpec::default();
        assert_eq!(d.method, Method::BsKmq);
        assert_eq!(d.act_bits, 3);
        assert_eq!(d.tile_bits, 7);
        assert_eq!(d.weight_bits, None);
        assert_eq!(d.alpha, DEFAULT_ALPHA);
        // per-layer seeding = layer index, like the old calibrator
        assert_eq!(QuantSpec::default_for_layer(5).seed, 5);
    }

    #[test]
    fn parse_full_and_short_forms() {
        let base = QuantSpec::default();
        let s = QuantSpec::parse("6/2/3", &base).unwrap();
        assert_eq!((s.tile_bits, s.weight_bits, s.act_bits), (6, Some(2), 3));
        assert_eq!(s.method, Method::BsKmq);

        let s = QuantSpec::parse("linear:6/-/4", &base).unwrap();
        assert_eq!(s.method, Method::Linear);
        assert_eq!((s.tile_bits, s.weight_bits, s.act_bits), (6, None, 4));

        let s = QuantSpec::parse("5", &base).unwrap();
        assert_eq!(s.act_bits, 5);
        assert_eq!(s.tile_bits, base.tile_bits);

        assert!(QuantSpec::parse("6/2", &base).is_err());
        assert!(QuantSpec::parse("median:3", &base).is_err());
        assert!(QuantSpec::parse("a/b/c", &base).is_err());
    }

    #[test]
    fn validate_catches_out_of_range() {
        let mut s = QuantSpec::default();
        assert!(s.validate(128).is_ok());
        s.act_bits = 8;
        assert!(s.validate(128).is_err());
        s.act_bits = 7;
        assert!(s.validate(64).is_err(), "2^7 levels > max_levels 64");
        s.act_bits = 3;
        s.weight_bits = Some(1);
        assert!(s.validate(128).is_err());
        s.weight_bits = Some(2);
        s.alpha = 0.5;
        assert!(s.validate(128).is_err());
    }

    #[test]
    fn fit_seed_flows_into_kmeans() {
        // two seeds must be *able* to differ (k-means++ init) while the
        // same seed is reproducible — the old API hardcoded seed 0
        let xs: Vec<f64> = (0..5000)
            .map(|i| ((i * 37) % 101) as f64 / 7.0)
            .collect();
        let a = Method::KMeans.fit(&xs, 4, 1);
        let b = Method::KMeans.fit(&xs, 4, 1);
        assert_eq!(a, b, "same seed must reproduce");
    }
}
