//! Codebook: centers <-> floor-ADC references (paper Eq. 2) plus the §2.3
//! hardware projection onto the IM NL-ADC's integer-bitcell ramp grid.

use anyhow::{ensure, Result};

/// 7-bit NL-ADC -> at most 128 levels (the macro's maximum resolution).
pub const MAX_LEVELS: usize = 128;

/// A fitted quantizer: sorted centers + derived reference ladder.
#[derive(Clone, Debug, PartialEq)]
pub struct Codebook {
    pub centers: Vec<f64>,
    pub refs: Vec<f64>,
}

impl Codebook {
    /// Eq. 2: `R_0 = C_0`, `R_i = (C_{i-1} + C_i) / 2` — emulates
    /// nearest-center rounding on a floor-type ADC.
    pub fn from_centers(centers: &[f64]) -> Codebook {
        let mut c = centers.to_vec();
        c.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut refs = Vec::with_capacity(c.len());
        refs.push(c[0]);
        for i in 1..c.len() {
            refs.push(0.5 * (c[i - 1] + c[i]));
        }
        Codebook { centers: c, refs }
    }

    pub fn levels(&self) -> usize {
        self.centers.len()
    }

    /// Floor-ADC conversion: index of largest reference <= x.
    #[inline]
    pub fn index_of(&self, x: f64) -> usize {
        // refs is sorted; binary search for the rightmost ref <= x
        match self
            .refs
            .binary_search_by(|r| r.partial_cmp(&x).unwrap())
        {
            Ok(mut i) => {
                // land on the last of an equal run
                while i + 1 < self.refs.len() && self.refs[i + 1] == x {
                    i += 1;
                }
                i
            }
            Err(0) => 0,
            Err(i) => i - 1,
        }
    }

    /// Quantize one value to its nearest center (via the reference ladder).
    #[inline]
    pub fn quantize(&self, x: f64) -> f64 {
        self.centers[self.index_of(x)]
    }

    /// Quantize a slice.
    pub fn quantize_all(&self, xs: &[f64]) -> Vec<f64> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Mean squared quantization error on samples.
    pub fn mse(&self, xs: &[f64]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter()
            .map(|&x| {
                let q = self.quantize(x);
                (x - q) * (x - q)
            })
            .sum::<f64>()
            / xs.len() as f64
    }

    /// Smallest positive reference step — the ADC LSB (noise unit, Fig. 7).
    pub fn min_step(&self) -> f64 {
        let mut m = f64::INFINITY;
        for w in self.refs.windows(2) {
            let d = w[1] - w[0];
            if d > 0.0 && d < m {
                m = d;
            }
        }
        if m.is_finite() {
            m
        } else {
            1.0
        }
    }

    /// Ramp bitcell budget at a resolution (§2.3): the paper's 4-bit
    /// NL-ADC uses 32 cells (vs 16 linear); budget(b) = 2^(b+1), capped
    /// at the 252 usable cells of the 256-cell column (4 are calibration
    /// cells), which is what limits the maximum resolution to 7 bits.
    pub fn cell_budget(bits: u32) -> Result<usize> {
        ensure!((1..=7).contains(&bits), "bits must be in [1,7], got {bits}");
        Ok((1usize << (bits + 1)).min(252))
    }

    /// §2.3 / Fig. 3: project onto the realizable grid — integer bitcells
    /// per ramp step (>=1, total <= budget) and `out_bits`-wide digital
    /// centers.  Mirrors `quantlib.codebook.project_to_hardware`.
    pub fn project_to_hardware(&self, bits: u32) -> Codebook {
        self.project_to_hardware_out(bits, 6)
    }

    pub fn project_to_hardware_out(&self, bits: u32, out_bits: u32) -> Codebook {
        let k = self.centers.len();
        let budget = Self::cell_budget(bits).expect("bits in range") as i64;
        let span = self.refs[k - 1] - self.refs[0];
        if span <= 0.0 || k < 2 {
            return self.clone();
        }
        let dv = span / budget as f64; // one ramp cell's increment
        let mut n: Vec<i64> = self
            .refs
            .windows(2)
            .map(|w| (((w[1] - w[0]) / dv).round() as i64).max(1))
            .collect();
        // enforce the budget by shaving the widest steps first
        while n.iter().sum::<i64>() > budget {
            let imax = (0..n.len()).max_by_key(|&i| n[i]).unwrap();
            n[imax] -= 1;
        }
        let mut hw_refs = Vec::with_capacity(k);
        hw_refs.push(self.refs[0]);
        let mut acc = 0i64;
        for &ni in &n {
            acc += ni;
            hw_refs.push(self.refs[0] + dv * acc as f64);
        }
        hw_refs.truncate(k);
        // digital center grid: sub-cell resolution dv / 2^(out_bits-bits)
        let grid = dv / (1u32 << out_bits.saturating_sub(bits)).max(1) as f64;
        let mut hw_centers: Vec<f64> = self
            .centers
            .iter()
            .map(|c| (c / grid).round() * grid)
            .collect();
        for i in 1..k {
            if hw_centers[i] < hw_centers[i - 1] {
                hw_centers[i] = hw_centers[i - 1];
            }
        }
        // references must stay the Eq.-2 ladder of the *projected* ramp
        Codebook {
            centers: hw_centers,
            refs: hw_refs,
        }
    }

    /// Pad to `levels` slots for the fixed-shape AOT graphs: padding refs
    /// are +inf (never selected), padding centers repeat the last center.
    pub fn padded(&self, levels: usize) -> (Vec<f32>, Vec<f32>) {
        assert!(self.levels() <= levels, "codebook exceeds capacity");
        let mut refs = vec![f32::INFINITY; levels];
        let mut centers = vec![*self.centers.last().unwrap() as f32; levels];
        for (i, (&r, &c)) in self.refs.iter().zip(&self.centers).enumerate() {
            refs[i] = r as f32;
            centers[i] = c as f32;
        }
        (refs, centers)
    }

    /// Linear codebook over [lo, hi] — the per-tile high-resolution
    /// conversion and the Fig. 1 "linear [14]" baseline.
    pub fn linear(lo: f64, hi: f64, bits: u32) -> Codebook {
        let k = 1usize << bits;
        let hi = if hi > lo { hi } else { lo + 1e-8 };
        let step = (hi - lo) / (k - 1) as f64;
        let centers: Vec<f64> =
            (0..k).map(|i| lo + step * i as f64).collect();
        Codebook::from_centers(&centers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's worked 3-bit example (§2.1).
    #[test]
    fn paper_example_references() {
        let centers = [0.0, 0.125, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0];
        let cb = Codebook::from_centers(&centers);
        let expect = [0.0, 0.0625, 0.1875, 0.375, 0.75, 1.5, 3.0, 6.0];
        for (r, e) in cb.refs.iter().zip(expect) {
            assert!((r - e).abs() < 1e-12, "{r} vs {e}");
        }
        // "An input of 0.05 falls below R1 and maps to C0 = 0"
        assert_eq!(cb.quantize(0.05), 0.0);
        // "an input of 0.07 lies between R1 and R2 and maps to C1 = 0.125"
        assert_eq!(cb.quantize(0.07), 0.125);
    }

    #[test]
    fn quantize_is_nearest_center() {
        let cb = Codebook::from_centers(&[-1.0, 0.0, 2.0, 5.0]);
        for &(x, want) in &[(-9.0, -1.0), (-0.51, -1.0), (-0.49, 0.0),
                            (0.99, 0.0), (1.01, 2.0), (3.49, 2.0),
                            (3.51, 5.0), (99.0, 5.0)] {
            assert_eq!(cb.quantize(x), want, "x={x}");
        }
    }

    #[test]
    fn min_step_and_budget() {
        let cb = Codebook::from_centers(&[0.0, 1.0, 3.0, 7.0]);
        assert!((cb.min_step() - 0.5).abs() < 1e-12);
        assert_eq!(Codebook::cell_budget(4).unwrap(), 32);
        assert!(Codebook::cell_budget(0).is_err());
        assert!(Codebook::cell_budget(8).is_err());
    }

    #[test]
    fn hardware_projection_respects_budget() {
        // extreme step ratio: tiny steps near 0, huge tail step
        let centers = [0.0, 1e-4, 2e-4, 3e-4, 0.5, 1.0, 50.0, 100.0];
        let cb = Codebook::from_centers(&centers).project_to_hardware(3);
        assert_eq!(cb.levels(), 8);
        let span = cb.refs[7] - cb.refs[0];
        let dv = span_ideal(&centers) / 16.0;
        // every step is at least one cell and the total fits the budget
        let total: f64 = cb.refs.windows(2).map(|w| w[1] - w[0]).sum();
        assert!(total <= span_ideal(&centers) + 1e-9);
        for w in cb.refs.windows(2) {
            assert!(w[1] - w[0] >= dv * 0.999, "step below one cell");
        }
        let _ = span;
    }

    fn span_ideal(centers: &[f64]) -> f64 {
        let cb = Codebook::from_centers(centers);
        cb.refs[cb.refs.len() - 1] - cb.refs[0]
    }

    #[test]
    fn linear_codebook_uniform() {
        let cb = Codebook::linear(0.0, 7.0, 3);
        assert_eq!(cb.levels(), 8);
        for (i, c) in cb.centers.iter().enumerate() {
            assert!((c - i as f64).abs() < 1e-12);
        }
    }

    /// Constant-input calibration: every fitter must stay finite and
    /// quantize back to (numerically) the constant, and the hardware
    /// projection must not blow up on a zero-span ladder.
    #[test]
    fn constant_input_calibration_is_stable() {
        let xs = vec![3.7f64; 5_000];
        for m in crate::quant::Method::ALL {
            for bits in [1u32, 3] {
                let cb = m.fit_hw(&xs, bits, 0);
                assert_eq!(cb.levels(), 1 << bits, "{} {bits}b", m.name());
                assert!(
                    cb.centers.iter().all(|c| c.is_finite()),
                    "{}: non-finite centers {:?}",
                    m.name(),
                    cb.centers
                );
                assert!(
                    cb.refs.windows(2).all(|w| w[0] <= w[1]),
                    "{}: refs not sorted",
                    m.name()
                );
                let q = cb.quantize(3.7);
                assert!(
                    (q - 3.7).abs() < 1e-3,
                    "{}: constant 3.7 quantized to {q}",
                    m.name()
                );
            }
        }
    }

    /// Duplicated centers (k-means empty clusters pad by repeating) must
    /// survive the hardware projection: every ramp step stays >= one cell
    /// (so refs become strictly increasing), the cell budget holds, and
    /// centers stay monotone.
    #[test]
    fn projection_handles_empty_cluster_duplicates() {
        let centers = [0.0, 0.0, 0.0, 1.0, 2.0, 2.0, 3.0, 5.0];
        let ideal = Codebook::from_centers(&centers);
        let span = ideal.refs[7] - ideal.refs[0];
        let cb = ideal.project_to_hardware(3);
        assert_eq!(cb.levels(), 8);
        let budget = Codebook::cell_budget(3).unwrap() as f64;
        let dv = span / budget;
        for w in cb.refs.windows(2) {
            assert!(w[1] - w[0] >= dv * 0.999, "step collapsed: {:?}", cb.refs);
        }
        let total: f64 = cb.refs.windows(2).map(|w| w[1] - w[0]).sum();
        assert!(total <= span + 1e-9, "budget exceeded: {total} > {span}");
        assert!(cb.centers.windows(2).all(|w| w[0] <= w[1]));
    }

    /// Floor-ADC semantics at exact reference values: an input equal to a
    /// boundary midpoint belongs to the *upper* cell (x >= R_i), and an
    /// equal-run of references resolves to the last of the run.
    #[test]
    fn index_of_exact_boundary_midpoints() {
        let cb = Codebook::from_centers(&[-1.0, 0.0, 2.0, 5.0]);
        // refs = [-1.0, -0.5, 1.0, 3.5]
        assert_eq!(cb.index_of(-1.0), 0); // base reference
        assert_eq!(cb.index_of(-0.5), 1); // exact midpoint -> upper cell
        assert_eq!(cb.index_of(1.0), 2);
        assert_eq!(cb.index_of(3.5), 3);
        assert_eq!(cb.index_of(-100.0), 0); // below base clamps to 0
        assert_eq!(cb.quantize(-0.5), 0.0);
        // duplicated references (degenerate centers) pick the run's end
        let dup = Codebook::from_centers(&[0.0, 0.0, 2.0]);
        assert_eq!(dup.refs, vec![0.0, 0.0, 1.0]);
        assert_eq!(dup.index_of(0.0), 1);
        assert_eq!(dup.quantize(0.0), 0.0);
    }

    #[test]
    fn padded_semantics() {
        let cb = Codebook::from_centers(&[0.0, 1.0]);
        let (refs, centers) = cb.padded(4);
        assert_eq!(refs[0], 0.0);
        assert_eq!(refs[1], 0.5);
        assert!(refs[2].is_infinite() && refs[3].is_infinite());
        assert_eq!(centers, vec![0.0, 1.0, 1.0, 1.0]);
    }
}
