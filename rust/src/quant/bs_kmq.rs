//! Boundary Suppressed K-Means Quantization — paper Algorithm 1, as a
//! streaming **mergeable** calibrator.
//!
//! Per observed batch (Algorithm 1 lines 5-17): trim the extreme `alpha`
//! tails, record the trimmed min/max, keep the interior samples.  At
//! `finish` (lines 18-23): replay the per-batch records in global stream
//! order — EMA the trimmed ranges into [g_min, g_max] (Eq. 1), fill the
//! bounded sample buffer — then clamp to [g_min, g_max], *remove*
//! samples saturating at either bound (ReLU zero spike / clamp pile-up),
//! k-means the interior into 2^b - 2 centers, and re-attach g_min/g_max
//! as the outermost centers.
//!
//! Deferring the order-sensitive EMA/buffer accumulation to a replay
//! over *indexed* batch records is what makes the calibrator mergeable
//! (the [`crate::quant::QuantEstimator`] contract): shards record
//! disjoint batch-index slices ([`BsKmqCalibrator::seek`]), `merge`
//! unions the records, and the replay is a pure function of the union —
//! so 1, 4 or 16 shards produce bit-identical codebooks, each identical
//! to the historical sequential calibrator (exactly so for batches
//! within the [`DEFAULT_MAX_BUFFER`] fit bound; larger batches are
//! deterministically thinned at `observe`, where the old code sampled
//! once from its live reservoir).  The L3 coordinator's counterpart of
//! `python/compile/quantlib/bs_kmq.py`.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::quant::kmeans::kmeans_1d;
use crate::util::rng::Rng;

pub const DEFAULT_ALPHA: f64 = 0.005;
pub const EMA_KEEP: f64 = 0.9;
pub const EMA_NEW: f64 = 0.1;
/// Fit-buffer bound (samples): the replayed buffer that feeds k-means
/// is capped here, and any single batch retaining more than this is
/// deterministically thinned at `observe`.  NOTE: unlike the
/// pre-mergeable calibrator, the cap does NOT bound total retention —
/// exact EMA replay needs every batch's record until `finish`, so
/// memory grows with the number of observed batches (~`max_buffer`
/// worst case per batch, `samples_per_layer` in practice).  Calibration
/// runs are tens of batches; for unbounded streams, calibrate in
/// bounded rounds.
pub const DEFAULT_MAX_BUFFER: usize = 200_000;

/// One observed batch's Algorithm-1 summary (trimmed range + interior).
#[derive(Clone, Debug)]
struct ObservedBatch {
    b_min: f64,
    b_max: f64,
    interior: Vec<f64>,
    /// raw batch length (before trimming), for diagnostics
    seen: usize,
}

/// Streaming mergeable implementation of Algorithm 1.
pub struct BsKmqCalibrator {
    alpha: f64,
    max_buffer: usize,
    seed: u64,
    /// per-batch records keyed by global stream index
    batches: BTreeMap<u64, ObservedBatch>,
    next_index: u64,
}

impl Default for BsKmqCalibrator {
    fn default() -> Self {
        Self::new(DEFAULT_ALPHA, DEFAULT_MAX_BUFFER, 0)
    }
}

impl BsKmqCalibrator {
    pub fn new(alpha: f64, max_buffer: usize, seed: u64) -> Self {
        assert!((0.0..0.5).contains(&alpha), "alpha in [0, 0.5)");
        BsKmqCalibrator {
            alpha,
            max_buffer,
            seed,
            batches: BTreeMap::new(),
            next_index: 0,
        }
    }

    /// Algorithm 1 lines 5-17: trim tails, record the batch summary at
    /// the current stream index.
    pub fn observe(&mut self, batch: &[f64]) {
        if batch.is_empty() {
            return;
        }
        // one sort serves both tail quantiles (perf: was two full
        // sort-based quantile() calls per batch — EXPERIMENTS.md §Perf)
        let mut sorted = batch.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p_low = crate::util::stats::quantile_sorted(&sorted, self.alpha);
        let p_high =
            crate::util::stats::quantile_sorted(&sorted, 1.0 - self.alpha);
        let mut interior: Vec<f64> = batch
            .iter()
            .copied()
            .filter(|&a| a >= p_low && a <= p_high)
            .collect();
        if interior.is_empty() {
            interior = batch.to_vec();
        }
        let b_min = interior.iter().copied().fold(f64::INFINITY, f64::min);
        let b_max = interior.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let idx = self.next_index;
        self.next_index += 1;
        // a single batch larger than the fit buffer is thinned here,
        // deterministically in (seed, index) — a pure function of the
        // record, so shard/order invariance is preserved
        if interior.len() > self.max_buffer {
            let mut rng =
                Rng::new(self.seed ^ crate::util::rng::mix64(idx));
            interior = rng.sample(&interior, self.max_buffer);
        }
        let clash = self.batches.insert(
            idx,
            ObservedBatch {
                b_min,
                b_max,
                interior,
                seen: batch.len(),
            },
        );
        assert!(
            clash.is_none(),
            "stream index {idx} observed twice (seek/merge misuse)"
        );
    }

    /// Position the stream cursor at a global batch index (shard drivers
    /// call this once before streaming their contiguous batch slice).
    pub fn seek(&mut self, batch_index: u64) {
        self.next_index = batch_index;
    }

    /// Fold another shard's records into this calibrator.  The shards
    /// must have been configured identically and observed disjoint
    /// stream indices.
    pub fn merge(&mut self, other: &BsKmqCalibrator) -> Result<()> {
        ensure!(
            self.alpha == other.alpha
                && self.max_buffer == other.max_buffer
                && self.seed == other.seed,
            "merging incompatible BS-KMQ calibrators \
             (alpha/buffer/seed differ)"
        );
        for (idx, ob) in &other.batches {
            ensure!(
                !self.batches.contains_key(idx),
                "merge collision: batch index {idx} observed by both shards"
            );
            self.batches.insert(*idx, ob.clone());
        }
        self.next_index = self.next_index.max(other.next_index);
        Ok(())
    }

    /// Batches recorded so far (across all merged shards).
    pub fn batches_seen(&self) -> usize {
        self.batches.len()
    }

    /// Raw samples observed so far (before trimming).
    pub fn n_observed(&self) -> usize {
        self.batches.values().map(|b| b.seen).sum()
    }

    /// Replay the indexed batch records in stream order: EMA the trimmed
    /// ranges (Eq. 1) and fill the bounded buffer exactly as the
    /// sequential algorithm did.
    fn replay(&self) -> Result<(f64, f64, Vec<f64>)> {
        let mut g_min: Option<f64> = None;
        let mut g_max: Option<f64> = None;
        let mut buffer: Vec<f64> = Vec::new();
        let mut rng = Rng::new(self.seed);
        for ob in self.batches.values() {
            match (g_min, g_max) {
                (None, _) | (_, None) => {
                    g_min = Some(ob.b_min);
                    g_max = Some(ob.b_max);
                }
                (Some(lo), Some(hi)) => {
                    g_min = Some(EMA_KEEP * lo + EMA_NEW * ob.b_min);
                    g_max = Some(EMA_KEEP * hi + EMA_NEW * ob.b_max);
                }
            }
            // bounded buffering (reservoir-ish, matches the python side)
            if buffer.len() + ob.interior.len() > self.max_buffer {
                let keep = self.max_buffer.saturating_sub(buffer.len());
                if keep == 0 {
                    continue;
                }
                buffer.extend(rng.sample(&ob.interior, keep));
            } else {
                buffer.extend_from_slice(&ob.interior);
            }
        }
        match (g_min, g_max) {
            (Some(a), Some(b)) => Ok((a, b, buffer)),
            _ => anyhow::bail!("finish() before any observe()"),
        }
    }

    /// Algorithm 1 lines 18-23: boundary-suppressed clustering on the
    /// replayed state; sorted `2^bits` centers.
    pub fn finish_centers(&self, bits: u32) -> Result<Vec<f64>> {
        ensure!((1..=7).contains(&bits), "bits in [1,7], got {bits}");
        let (g_min, g_max, buffer) = self.replay()?;
        let g_max = if g_max > g_min { g_max } else { g_min + 1e-8 };
        let k_interior = (1usize << bits) - 2;
        if k_interior == 0 {
            return Ok(vec![g_min, g_max]); // 1-bit: just the bounds
        }
        // clamp, then REMOVE boundary-saturating samples
        let interior: Vec<f64> = buffer
            .iter()
            .map(|&s| s.clamp(g_min, g_max))
            .filter(|&s| s > g_min && s < g_max)
            .collect();
        let mut cq = if interior.len() < k_interior {
            even_interior(g_min, g_max, k_interior)
        } else {
            let mut c = kmeans_1d(&interior, k_interior, 50, self.seed);
            if c.len() < k_interior {
                let pad = even_interior(g_min, g_max, k_interior - c.len());
                c.extend(pad);
                c.sort_by(|a, b| a.partial_cmp(b).unwrap());
            }
            c
        };
        let mut centers = Vec::with_capacity(k_interior + 2);
        centers.push(g_min);
        centers.append(&mut cq);
        centers.push(g_max);
        centers.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok(centers)
    }
}

fn even_interior(lo: f64, hi: f64, k: usize) -> Vec<f64> {
    let step = (hi - lo) / (k + 1) as f64;
    (1..=k).map(|i| lo + step * i as f64).collect()
}

/// One-shot convenience wrapper: splits `samples` into 8 batches.
pub fn fit_bs_kmq(samples: &[f64], bits: u32) -> Vec<f64> {
    fit_bs_kmq_cfg(samples, bits, DEFAULT_ALPHA, 8, 0)
}

pub fn fit_bs_kmq_cfg(
    samples: &[f64],
    bits: u32,
    alpha: f64,
    batches: usize,
    seed: u64,
) -> Vec<f64> {
    assert!(!samples.is_empty(), "empty sample set");
    let mut calib = BsKmqCalibrator::new(alpha, DEFAULT_MAX_BUFFER, seed);
    let bs = batches.clamp(1, samples.len());
    let chunk = samples.len().div_ceil(bs);
    for c in samples.chunks(chunk) {
        calib.observe(c);
    }
    calib.finish_centers(bits).expect("observed at least one batch")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::Codebook;
    use crate::util::rng::Rng;

    fn relu_gaussian(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal(0.3, 1.0).max(0.0)).collect()
    }

    #[test]
    fn includes_bounds_as_centers() {
        let xs = relu_gaussian(50_000, 1);
        let c = fit_bs_kmq(&xs, 3);
        assert_eq!(c.len(), 8);
        // g_min for ReLU data is ~0 and is the first center
        assert!(c[0].abs() < 1e-6, "g_min {}", c[0]);
        assert!(c.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn range_robust_to_outliers() {
        let mut xs = relu_gaussian(50_000, 2);
        // inject 0.2% giant outliers, spread across calibration batches
        for i in 0..100 {
            xs[i * 499] = 1e4;
        }
        let c = fit_bs_kmq(&xs, 4);
        // the EMA'd, trimmed range must ignore the 1e4 spikes
        assert!(
            *c.last().unwrap() < 100.0,
            "g_max exploded: {}",
            c.last().unwrap()
        );
    }

    #[test]
    fn streaming_matches_oneshot_shape() {
        let xs = relu_gaussian(8_000, 3);
        let mut calib = BsKmqCalibrator::default();
        for c in xs.chunks(1000) {
            calib.observe(c);
        }
        let centers = calib.finish_centers(3).unwrap();
        assert_eq!(centers.len(), 8);
        assert_eq!(calib.batches_seen(), 8);
        assert_eq!(calib.n_observed(), 8_000);
    }

    #[test]
    fn one_bit_is_just_bounds() {
        let xs = relu_gaussian(1_000, 4);
        let c = fit_bs_kmq(&xs, 1);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn finish_before_observe_errors() {
        let calib = BsKmqCalibrator::default();
        assert!(calib.finish_centers(3).is_err());
    }

    /// The mergeable contract: splitting the same batch stream over
    /// shards (with seeked indices) and merging in any order reproduces
    /// the sequential calibrator bit for bit.
    #[test]
    fn sharded_merge_is_bit_identical_to_sequential() {
        let xs = relu_gaussian(16_000, 5);
        let batches: Vec<&[f64]> = xs.chunks(1000).collect(); // 16 batches

        let mut seq = BsKmqCalibrator::default();
        for b in &batches {
            seq.observe(b);
        }
        let want = seq.finish_centers(3).unwrap();

        for shards in [2usize, 4, 8] {
            let per = batches.len() / shards;
            let mut parts: Vec<BsKmqCalibrator> = (0..shards)
                .map(|s| {
                    let mut c = BsKmqCalibrator::default();
                    c.seek((s * per) as u64);
                    for b in &batches[s * per..(s + 1) * per] {
                        c.observe(b);
                    }
                    c
                })
                .collect();
            // merge in a scrambled order: root is the *last* shard
            let mut root = parts.pop().unwrap();
            while let Some(p) = parts.pop() {
                root.merge(&p).unwrap();
            }
            let got = root.finish_centers(3).unwrap();
            let as_bits = |v: &[f64]| -> Vec<u64> {
                v.iter().map(|x| x.to_bits()).collect()
            };
            assert_eq!(
                as_bits(&got),
                as_bits(&want),
                "{shards} shards diverged from sequential"
            );
        }
    }

    #[test]
    fn merge_rejects_index_collisions_and_mismatched_params() {
        let xs = relu_gaussian(2_000, 6);
        let mut a = BsKmqCalibrator::default();
        a.observe(&xs[..1000]);
        let mut b = BsKmqCalibrator::default();
        b.observe(&xs[1000..]); // same index 0 as `a`
        assert!(a.merge(&b).is_err(), "overlapping stream indices");
        let c = BsKmqCalibrator::new(0.01, DEFAULT_MAX_BUFFER, 0);
        assert!(a.merge(&c).is_err(), "alpha mismatch");
    }

    /// The headline property (Fig. 1 mechanism): under the hardware
    /// projection, BS-KMQ beats the baselines on ReLU-spiked, outlier-
    /// tailed activations (averaged over seeds — individual k-means++
    /// draws can get lucky).
    #[test]
    fn wins_under_hardware_projection() {
        let bits = 3;
        let mut wins = 0;
        let trials = 5;
        for seed in 0..trials {
            let mut rng = Rng::new(700 + seed);
            // heavy ReLU spike (~50% zeros) + lognormal outlier tail
            let mut xs: Vec<f64> = (0..40_000)
                .map(|_| rng.normal(0.0, 1.0).max(0.0))
                .collect();
            for _ in 0..200 {
                let i = rng.below(xs.len());
                xs[i] = rng.normal(1.5, 0.9).exp();
            }
            let bs =
                crate::quant::Method::BsKmq.fit_hw(&xs, bits, 0).mse(&xs);
            let all_beat = [
                crate::quant::Method::Linear,
                crate::quant::Method::Cdf,
                crate::quant::Method::KMeans,
                crate::quant::Method::LloydMax,
            ]
            .iter()
            .all(|m| bs < m.fit_hw(&xs, bits, 0).mse(&xs));
            if all_beat {
                wins += 1;
            }
        }
        assert!(
            wins * 2 > trials,
            "bs_kmq won only {wins}/{trials} seeds under hw projection"
        );
    }
}
