//! Boundary Suppressed K-Means Quantization — paper Algorithm 1.
//!
//! Streaming calibrator: per batch, trim the extreme `alpha` tails, EMA
//! the trimmed min/max into the global range (Eq. 1), buffer the interior
//! samples; at finish, clamp to [g_min, g_max], *remove* samples
//! saturating at either bound (ReLU zero spike / clamp pile-up), k-means
//! the interior into 2^b - 2 centers, and re-attach g_min/g_max as the
//! outermost centers.  This is the L3 coordinator's counterpart of
//! `python/compile/quantlib/bs_kmq.py`.

use anyhow::{ensure, Result};

use crate::quant::kmeans::kmeans_1d;
use crate::util::rng::Rng;


pub const DEFAULT_ALPHA: f64 = 0.005;
pub const EMA_KEEP: f64 = 0.9;
pub const EMA_NEW: f64 = 0.1;

/// Streaming implementation of Algorithm 1.
pub struct BsKmqCalibrator {
    alpha: f64,
    pub g_min: Option<f64>,
    pub g_max: Option<f64>,
    buffer: Vec<f64>,
    max_buffer: usize,
    rng: Rng,
    pub batches_seen: usize,
}

impl Default for BsKmqCalibrator {
    fn default() -> Self {
        Self::new(DEFAULT_ALPHA, 200_000, 0)
    }
}

impl BsKmqCalibrator {
    pub fn new(alpha: f64, max_buffer: usize, seed: u64) -> Self {
        assert!((0.0..0.5).contains(&alpha), "alpha in [0, 0.5)");
        BsKmqCalibrator {
            alpha,
            g_min: None,
            g_max: None,
            buffer: Vec::new(),
            max_buffer,
            rng: Rng::new(seed),
            batches_seen: 0,
        }
    }

    /// Algorithm 1 lines 5-17: trim tails, EMA the range, buffer interior.
    pub fn observe(&mut self, batch: &[f64]) {
        if batch.is_empty() {
            return;
        }
        // one sort serves both tail quantiles (perf: was two full
        // sort-based quantile() calls per batch — EXPERIMENTS.md §Perf)
        let mut sorted = batch.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p_low = crate::util::stats::quantile_sorted(&sorted, self.alpha);
        let p_high =
            crate::util::stats::quantile_sorted(&sorted, 1.0 - self.alpha);
        let mut cent: Vec<f64> = batch
            .iter()
            .copied()
            .filter(|&a| a >= p_low && a <= p_high)
            .collect();
        if cent.is_empty() {
            cent = batch.to_vec();
        }
        let b_min = cent.iter().copied().fold(f64::INFINITY, f64::min);
        let b_max = cent.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        match (self.g_min, self.g_max) {
            (None, _) | (_, None) => {
                self.g_min = Some(b_min);
                self.g_max = Some(b_max);
            }
            (Some(gmin), Some(gmax)) => {
                self.g_min = Some(EMA_KEEP * gmin + EMA_NEW * b_min);
                self.g_max = Some(EMA_KEEP * gmax + EMA_NEW * b_max);
            }
        }
        self.batches_seen += 1;
        // bounded buffering (reservoir-ish, matches the python side)
        if self.buffer.len() + cent.len() > self.max_buffer {
            let keep = self.max_buffer.saturating_sub(self.buffer.len());
            if keep == 0 {
                return;
            }
            cent = self.rng.sample(&cent, keep);
        }
        self.buffer.extend_from_slice(&cent);
    }

    /// Algorithm 1 lines 18-23: boundary-suppressed clustering.
    pub fn finish(&self, bits: u32, seed: u64) -> Result<Vec<f64>> {
        ensure!((1..=7).contains(&bits), "bits in [1,7], got {bits}");
        let (g_min, g_max) = match (self.g_min, self.g_max) {
            (Some(a), Some(b)) => (a, b),
            _ => anyhow::bail!("finish() before any observe()"),
        };
        let g_max = if g_max > g_min { g_max } else { g_min + 1e-8 };
        let k_interior = (1usize << bits) - 2;
        if k_interior == 0 {
            return Ok(vec![g_min, g_max]); // 1-bit: just the bounds
        }
        // clamp, then REMOVE boundary-saturating samples
        let interior: Vec<f64> = self
            .buffer
            .iter()
            .map(|&s| s.clamp(g_min, g_max))
            .filter(|&s| s > g_min && s < g_max)
            .collect();
        let mut cq = if interior.len() < k_interior {
            even_interior(g_min, g_max, k_interior)
        } else {
            let mut c = kmeans_1d(&interior, k_interior, 50, seed);
            if c.len() < k_interior {
                let pad = even_interior(g_min, g_max, k_interior - c.len());
                c.extend(pad);
                c.sort_by(|a, b| a.partial_cmp(b).unwrap());
            }
            c
        };
        let mut centers = Vec::with_capacity(k_interior + 2);
        centers.push(g_min);
        centers.append(&mut cq);
        centers.push(g_max);
        centers.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok(centers)
    }
}

fn even_interior(lo: f64, hi: f64, k: usize) -> Vec<f64> {
    let step = (hi - lo) / (k + 1) as f64;
    (1..=k).map(|i| lo + step * i as f64).collect()
}

/// One-shot convenience wrapper: splits `samples` into 8 batches.
pub fn fit_bs_kmq(samples: &[f64], bits: u32) -> Vec<f64> {
    fit_bs_kmq_cfg(samples, bits, DEFAULT_ALPHA, 8, 0)
}

pub fn fit_bs_kmq_cfg(
    samples: &[f64],
    bits: u32,
    alpha: f64,
    batches: usize,
    seed: u64,
) -> Vec<f64> {
    assert!(!samples.is_empty(), "empty sample set");
    let mut calib = BsKmqCalibrator::new(alpha, 200_000, seed);
    let bs = batches.clamp(1, samples.len());
    let chunk = samples.len().div_ceil(bs);
    for c in samples.chunks(chunk) {
        calib.observe(c);
    }
    calib.finish(bits, seed).expect("observed at least one batch")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::Codebook;
    use crate::util::rng::Rng;

    fn relu_gaussian(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal(0.3, 1.0).max(0.0)).collect()
    }

    #[test]
    fn includes_bounds_as_centers() {
        let xs = relu_gaussian(50_000, 1);
        let c = fit_bs_kmq(&xs, 3);
        assert_eq!(c.len(), 8);
        // g_min for ReLU data is ~0 and is the first center
        assert!(c[0].abs() < 1e-6, "g_min {}", c[0]);
        assert!(c.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn range_robust_to_outliers() {
        let mut xs = relu_gaussian(50_000, 2);
        // inject 0.2% giant outliers, spread across calibration batches
        for i in 0..100 {
            xs[i * 499] = 1e4;
        }
        let c = fit_bs_kmq(&xs, 4);
        // the EMA'd, trimmed range must ignore the 1e4 spikes
        assert!(
            *c.last().unwrap() < 100.0,
            "g_max exploded: {}",
            c.last().unwrap()
        );
    }

    #[test]
    fn streaming_matches_oneshot_shape() {
        let xs = relu_gaussian(8_000, 3);
        let mut calib = BsKmqCalibrator::default();
        for c in xs.chunks(1000) {
            calib.observe(c);
        }
        let centers = calib.finish(3, 0).unwrap();
        assert_eq!(centers.len(), 8);
        assert_eq!(calib.batches_seen, 8);
    }

    #[test]
    fn one_bit_is_just_bounds() {
        let xs = relu_gaussian(1_000, 4);
        let c = fit_bs_kmq(&xs, 1);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn finish_before_observe_errors() {
        let calib = BsKmqCalibrator::default();
        assert!(calib.finish(3, 0).is_err());
    }

    /// The headline property (Fig. 1 mechanism): under the hardware
    /// projection, BS-KMQ beats the baselines on ReLU-spiked, outlier-
    /// tailed activations (averaged over seeds — individual k-means++
    /// draws can get lucky).
    #[test]
    fn wins_under_hardware_projection() {
        let bits = 3;
        let mut wins = 0;
        let trials = 5;
        for seed in 0..trials {
            let mut rng = Rng::new(700 + seed);
            // heavy ReLU spike (~50% zeros) + lognormal outlier tail
            let mut xs: Vec<f64> = (0..40_000)
                .map(|_| rng.normal(0.0, 1.0).max(0.0))
                .collect();
            for _ in 0..200 {
                let i = rng.below(xs.len());
                xs[i] = rng.normal(1.5, 0.9).exp();
            }
            let bs = crate::quant::Method::BsKmq.fit_hw(&xs, bits).mse(&xs);
            let all_beat = [
                crate::quant::Method::Linear,
                crate::quant::Method::Cdf,
                crate::quant::Method::KMeans,
                crate::quant::Method::LloydMax,
            ]
            .iter()
            .all(|m| bs < m.fit_hw(&xs, bits).mse(&xs));
            if all_beat {
                wins += 1;
            }
        }
        assert!(
            wins * 2 > trials,
            "bs_kmq won only {wins}/{trials} seeds under hw projection"
        );
    }
}
