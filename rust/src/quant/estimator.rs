//! The streaming, mergeable quantizer trait behind the calibration
//! pipeline.
//!
//! A [`QuantEstimator`] accumulates activation statistics batch by batch
//! (`observe`), folds in another shard's state (`merge`), and fits the
//! final codebook (`finish`) — the object-safe replacement for the old
//! buffer-everything-then-dispatch-on-`Method` calibration path.  All
//! five methods implement it, which is what makes shard-parallel
//! calibration possible: N threads each stream a contiguous slice of the
//! calibration batches through their own estimator, and the states merge
//! associatively.
//!
//! ## Contract (the merge laws)
//!
//! 1. **Chunking invariance** — observing a sample multiset in any batch
//!    chunking yields the same `finish` result as observing it in one
//!    call (for [`crate::quant::BsKmqCalibrator`], whose Algorithm 1 is
//!    defined *per batch*, the per-batch chunking is part of the input:
//!    the law holds per identical batch sequences).
//! 2. **Merge = union** — `a.merge(&b)` makes `a` equivalent to a single
//!    estimator that observed both shards' streams.  Merging is
//!    order-insensitive and shard-count-invariant: 1, 4 or 16 shards
//!    over the same batches produce **bit-identical** codebooks.
//! 3. **Seeded determinism** — all randomness derives from the spec's
//!    seed; same spec + same data ⇒ same codebook, always.
//!
//! Order-sensitive state (BS-KMQ's per-batch EMA range) satisfies law 2
//! by recording *indexed* per-batch summaries and replaying them in
//! global stream order at `finish`; shard drivers position their
//! estimators with [`QuantEstimator::seek`] before observing.

use std::any::Any;

use anyhow::{anyhow, ensure, Result};

use crate::quant::bs_kmq::BsKmqCalibrator;
use crate::quant::cdf::fit_cdf;
use crate::quant::codebook::Codebook;
use crate::quant::kmeans::fit_kmeans;
use crate::quant::linear::fit_linear_range;
use crate::quant::lloyd_max::fit_lloyd_max;
use crate::quant::sketch::{DEFAULT_SKETCH_CAP, ValueSketch};
use crate::quant::spec::{Method, QuantSpec};

/// Streaming mergeable codebook estimator (see module docs for the
/// observe/merge/finish laws).  Object-safe: the calibrator holds one
/// `Box<dyn QuantEstimator>` per q-layer and never names a method.
pub trait QuantEstimator: Send {
    /// Which method this estimator fits.
    fn method(&self) -> Method;

    /// Stream one calibration batch into the running state.
    fn observe(&mut self, batch: &[f64]);

    /// Position the stream cursor at a global batch index (shard
    /// drivers call this once with their slice's first index, so merged
    /// states replay in true stream order).  Estimators whose fit is
    /// order-free ignore it.
    fn seek(&mut self, _batch_index: u64) {}

    /// Fold another shard's state into this one.  Fails on mismatched
    /// estimator types or fitting parameters.
    fn merge(&mut self, other: &dyn QuantEstimator) -> Result<()>;

    /// Fit the `2^bits`-level codebook from the accumulated state (the
    /// ideal codebook; callers apply the §2.3 hardware projection).
    fn finish(&self, bits: u32) -> Result<Codebook>;

    /// Total samples observed so far (diagnostics).
    fn n_observed(&self) -> usize;

    /// Downcast hook for [`QuantEstimator::merge`].
    fn as_any(&self) -> &dyn Any;
}

/// Build the estimator a [`QuantSpec`] asks for.
pub fn estimator_for(spec: &QuantSpec) -> Box<dyn QuantEstimator> {
    match spec.method {
        Method::Linear => Box::new(LinearEstimator::new()),
        Method::BsKmq => Box::new(BsKmqCalibrator::new(
            spec.alpha,
            crate::quant::bs_kmq::DEFAULT_MAX_BUFFER,
            spec.seed,
        )),
        Method::Cdf | Method::LloydMax | Method::KMeans => {
            Box::new(SketchEstimator::new(spec.method, spec.seed))
        }
    }
}

fn downcast<'a, T: 'static>(
    other: &'a dyn QuantEstimator,
    into: Method,
) -> Result<&'a T> {
    other.as_any().downcast_ref::<T>().ok_or_else(|| {
        anyhow!(
            "cannot merge a {} estimator into a {} estimator",
            other.method().name(),
            into.name()
        )
    })
}

/// Linear (uniform min-max) estimator: exact O(1) moment state — the
/// observed min/max are associative, so merging is trivially exact.
#[derive(Clone, Debug)]
pub struct LinearEstimator {
    lo: f64,
    hi: f64,
    seen: usize,
}

impl LinearEstimator {
    pub fn new() -> LinearEstimator {
        LinearEstimator {
            lo: f64::INFINITY,
            hi: f64::NEG_INFINITY,
            seen: 0,
        }
    }
}

impl Default for LinearEstimator {
    fn default() -> Self {
        Self::new()
    }
}

impl QuantEstimator for LinearEstimator {
    fn method(&self) -> Method {
        Method::Linear
    }

    fn observe(&mut self, batch: &[f64]) {
        for &x in batch {
            self.lo = self.lo.min(x);
            self.hi = self.hi.max(x);
        }
        self.seen += batch.len();
    }

    fn merge(&mut self, other: &dyn QuantEstimator) -> Result<()> {
        let other: &LinearEstimator = downcast(other, self.method())?;
        self.lo = self.lo.min(other.lo);
        self.hi = self.hi.max(other.hi);
        self.seen += other.seen;
        Ok(())
    }

    fn finish(&self, bits: u32) -> Result<Codebook> {
        ensure!((1..=7).contains(&bits), "bits in [1,7], got {bits}");
        ensure!(self.seen > 0, "finish() before any observe()");
        Ok(Codebook::from_centers(&fit_linear_range(
            self.lo, self.hi, bits,
        )))
    }

    fn n_observed(&self) -> usize {
        self.seen
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// Sketch-backed estimator for the CDF / Lloyd-Max / k-means baselines:
/// a mergeable bottom-k [`ValueSketch`] retains a bounded, deterministic
/// subsample of the activation multiset; `finish` expands it in
/// canonical (value-sorted) order and runs the one-shot fitter.  While
/// the stream stays within the sketch capacity this is bit-equal to
/// fitting the full buffered sample set.
pub struct SketchEstimator {
    method: Method,
    seed: u64,
    sketch: ValueSketch,
}

impl SketchEstimator {
    pub fn new(method: Method, seed: u64) -> SketchEstimator {
        assert!(
            matches!(method, Method::Cdf | Method::LloydMax | Method::KMeans),
            "SketchEstimator serves cdf/lloyd_max/kmeans, not {}",
            method.name()
        );
        SketchEstimator {
            method,
            seed,
            sketch: ValueSketch::new(DEFAULT_SKETCH_CAP, seed),
        }
    }
}

impl QuantEstimator for SketchEstimator {
    fn method(&self) -> Method {
        self.method
    }

    fn observe(&mut self, batch: &[f64]) {
        for &x in batch {
            self.sketch.insert(x);
        }
    }

    fn merge(&mut self, other: &dyn QuantEstimator) -> Result<()> {
        let other: &SketchEstimator = downcast(other, self.method)?;
        ensure!(
            self.method == other.method,
            "cannot merge a {} estimator into a {} estimator",
            other.method.name(),
            self.method.name()
        );
        ensure!(
            self.seed == other.seed,
            "merging {} estimators with different seeds ({} vs {})",
            self.method.name(),
            self.seed,
            other.seed
        );
        self.sketch.merge(&other.sketch)
    }

    fn finish(&self, bits: u32) -> Result<Codebook> {
        ensure!((1..=7).contains(&bits), "bits in [1,7], got {bits}");
        let xs = self.sketch.expand();
        ensure!(!xs.is_empty(), "finish() before any observe()");
        let centers = match self.method {
            Method::Cdf => fit_cdf(&xs, bits),
            Method::LloydMax => fit_lloyd_max(&xs, bits),
            Method::KMeans => fit_kmeans(&xs, bits, self.seed),
            _ => unreachable!("constructor rejects other methods"),
        };
        Ok(Codebook::from_centers(&centers))
    }

    fn n_observed(&self) -> usize {
        self.sketch.n_seen() as usize
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

impl QuantEstimator for BsKmqCalibrator {
    fn method(&self) -> Method {
        Method::BsKmq
    }

    fn observe(&mut self, batch: &[f64]) {
        BsKmqCalibrator::observe(self, batch)
    }

    fn seek(&mut self, batch_index: u64) {
        BsKmqCalibrator::seek(self, batch_index)
    }

    fn merge(&mut self, other: &dyn QuantEstimator) -> Result<()> {
        let other: &BsKmqCalibrator = downcast(other, Method::BsKmq)?;
        BsKmqCalibrator::merge(self, other)
    }

    fn finish(&self, bits: u32) -> Result<Codebook> {
        Ok(Codebook::from_centers(&self.finish_centers(bits)?))
    }

    fn n_observed(&self) -> usize {
        BsKmqCalibrator::n_observed(self)
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimator_for_dispatches_every_method() {
        for m in Method::ALL {
            let spec = QuantSpec::new(m, 3);
            let est = estimator_for(&spec);
            assert_eq!(est.method(), m);
        }
    }

    #[test]
    fn linear_streaming_equals_buffered() {
        let xs: Vec<f64> = (0..3000).map(|i| (i as f64).sin() * 4.0).collect();
        let mut est = LinearEstimator::new();
        for c in xs.chunks(137) {
            est.observe(c);
        }
        let want = Codebook::from_centers(&crate::quant::linear::fit_linear(
            &xs, 3,
        ));
        assert_eq!(est.finish(3).unwrap(), want);
    }

    #[test]
    fn merge_rejects_cross_method() {
        let mut lin = LinearEstimator::new();
        lin.observe(&[1.0]);
        let mut cdf = SketchEstimator::new(Method::Cdf, 0);
        cdf.observe(&[1.0]);
        assert!(lin.merge(&cdf).is_err());
        assert!(cdf.merge(&lin).is_err());
        let km0 = SketchEstimator::new(Method::KMeans, 0);
        let mut km1 = SketchEstimator::new(Method::KMeans, 1);
        assert!(km1.merge(&km0).is_err(), "seed mismatch must fail");
    }

    #[test]
    fn finish_before_observe_errors() {
        for m in Method::ALL {
            let est = estimator_for(&QuantSpec::new(m, 3));
            assert!(est.finish(3).is_err(), "{}", m.name());
        }
    }
}
