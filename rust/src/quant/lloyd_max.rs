//! Lloyd-Max quantizer baseline [2]: alternating boundary/centroid
//! optimization on a histogram density estimate (512 bins), uniform init —
//! the classic recipe, with its characteristic tail sensitivity (empty
//! outer cells pin centroids to the tail region).

const BINS: usize = 512;

/// Fit `2^bits` Lloyd-Max centroids on a histogram density estimate.
pub fn fit_lloyd_max(samples: &[f64], bits: u32) -> Vec<f64> {
    fit_lloyd_max_iters(samples, bits, 60)
}

pub fn fit_lloyd_max_iters(samples: &[f64], bits: u32, iters: usize) -> Vec<f64> {
    assert!((1..=7).contains(&bits), "bits in [1,7]");
    assert!(!samples.is_empty(), "empty sample set");
    let k = 1usize << bits;
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in samples {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if hi <= lo {
        return vec![lo; k];
    }
    // histogram approximation of the pdf
    let w = (hi - lo) / BINS as f64;
    let mut hist = vec![0f64; BINS];
    for &x in samples {
        let i = (((x - lo) / w) as usize).min(BINS - 1);
        hist[i] += 1.0;
    }
    let mids: Vec<f64> = (0..BINS)
        .map(|i| lo + w * (i as f64 + 0.5))
        .collect();

    let step = (hi - lo) / (k - 1) as f64;
    let mut centers: Vec<f64> = (0..k).map(|i| lo + step * i as f64).collect();
    for _ in 0..iters {
        // boundaries at midpoints, centroid = conditional mean per cell
        let mut sums = vec![0f64; k];
        let mut wts = vec![0f64; k];
        let mut cell = 0usize;
        for (m, h) in mids.iter().zip(&hist) {
            while cell + 1 < k
                && *m > 0.5 * (centers[cell] + centers[cell + 1])
            {
                cell += 1;
            }
            sums[cell] += m * h;
            wts[cell] += h;
        }
        let mut moved = 0f64;
        for i in 0..k {
            if wts[i] > 0.0 {
                let c = sums[i] / wts[i];
                moved = moved.max((c - centers[i]).abs());
                centers[i] = c;
            }
        }
        centers.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if moved < 1e-9 {
            break;
        }
    }
    centers
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::Codebook;
    use crate::util::rng::Rng;

    #[test]
    fn beats_linear_on_nonuniform_data() {
        let mut rng = Rng::new(5);
        let xs: Vec<f64> = (0..20_000)
            .map(|_| rng.gaussian().max(0.0)) // ReLU-like
            .collect();
        let lm = Codebook::from_centers(&fit_lloyd_max(&xs, 3));
        let lin = Codebook::from_centers(
            &crate::quant::linear::fit_linear(&xs, 3),
        );
        assert!(lm.mse(&xs) < lin.mse(&xs));
    }

    #[test]
    fn centers_sorted_and_sized() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sqrt()).collect();
        let c = fit_lloyd_max(&xs, 4);
        assert_eq!(c.len(), 16);
        assert!(c.windows(2).all(|w| w[0] <= w[1]));
    }
}
