//! Standard 1-D k-means quantizer baseline [13]: k-means++ seeding +
//! Lloyd iterations over the raw, untrimmed samples (no boundary
//! suppression — the ReLU zero spike and clamp tails pull centroids
//! toward the distribution edges, the instability BS-KMQ fixes).

use crate::util::rng::Rng;

const MAX_FIT_SAMPLES: usize = 20_000;

fn kmeanspp_init(x: &[f64], k: usize, rng: &mut Rng) -> Vec<f64> {
    let mut centers = Vec::with_capacity(k);
    centers.push(x[rng.below(x.len())]);
    let mut d2: Vec<f64> = x
        .iter()
        .map(|&v| (v - centers[0]) * (v - centers[0]))
        .collect();
    while centers.len() < k {
        let total: f64 = d2.iter().sum();
        if total <= 0.0 {
            centers.push(x[rng.below(x.len())]);
            continue;
        }
        let mut target = rng.uniform() * total;
        let mut pick = x.len() - 1;
        for (i, &d) in d2.iter().enumerate() {
            target -= d;
            if target <= 0.0 {
                pick = i;
                break;
            }
        }
        let c = x[pick];
        centers.push(c);
        for (i, &v) in x.iter().enumerate() {
            let nd = (v - c) * (v - c);
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }
    centers.sort_by(|a, b| a.partial_cmp(b).unwrap());
    centers
}

/// Lloyd's algorithm in 1-D: sorted centroids, O(n log k) assignment.
pub fn kmeans_1d(samples: &[f64], k: usize, iters: usize, seed: u64) -> Vec<f64> {
    assert!(!samples.is_empty(), "kmeans on empty sample set");
    let mut rng = Rng::new(seed);
    let x: Vec<f64> = if samples.len() > MAX_FIT_SAMPLES {
        rng.sample(samples, MAX_FIT_SAMPLES)
    } else {
        samples.to_vec()
    };
    let distinct = {
        let mut v = x.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v.dedup();
        v.len()
    };
    let k = k.min(distinct.max(1));
    let mut centers = kmeanspp_init(&x, k, &mut rng);
    let mut sums = vec![0f64; k];
    let mut counts = vec![0usize; k];
    let mut bounds = vec![0f64; k.saturating_sub(1)];
    for _ in 0..iters {
        sums.iter_mut().for_each(|s| *s = 0.0);
        counts.iter_mut().for_each(|c| *c = 0);
        for (b, w) in bounds.iter_mut().zip(centers.windows(2)) {
            *b = 0.5 * (w[0] + w[1]);
        }
        for &v in &x {
            // binary search over boundary midpoints (perf: was an O(k)
            // scan — see EXPERIMENTS.md §Perf)
            let cell = bounds.partition_point(|&b| b < v);
            sums[cell] += v;
            counts[cell] += 1;
        }
        let mut moved = 0f64;
        for i in 0..k {
            if counts[i] > 0 {
                let c = sums[i] / counts[i] as f64;
                moved = moved.max((c - centers[i]).abs());
                centers[i] = c;
            }
        }
        centers.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if moved < 1e-10 {
            break;
        }
    }
    centers
}

/// `2^bits` standard k-means centers over the raw sample set.
pub fn fit_kmeans(samples: &[f64], bits: u32, seed: u64) -> Vec<f64> {
    assert!((1..=7).contains(&bits), "bits in [1,7]");
    let k = 1usize << bits;
    let mut centers = kmeans_1d(samples, k, 50, seed);
    while centers.len() < k {
        centers.push(*centers.last().unwrap()); // degenerate data
    }
    centers
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::Codebook;
    use crate::util::rng::Rng;

    #[test]
    fn recovers_well_separated_clusters() {
        let mut rng = Rng::new(11);
        let mut xs = Vec::new();
        for &mu in &[0.0, 10.0, 20.0, 30.0] {
            for _ in 0..500 {
                xs.push(rng.normal(mu, 0.1));
            }
        }
        let c = kmeans_1d(&xs, 4, 50, 0);
        for (got, want) in c.iter().zip([0.0, 10.0, 20.0, 30.0]) {
            assert!((got - want).abs() < 0.5, "{got} vs {want}");
        }
    }

    #[test]
    fn near_optimal_mse_in_1d() {
        let mut rng = Rng::new(13);
        let xs: Vec<f64> = (0..10_000).map(|_| rng.gaussian()).collect();
        let km = Codebook::from_centers(&fit_kmeans(&xs, 3, 0));
        let lin = Codebook::from_centers(
            &crate::quant::linear::fit_linear(&xs, 3),
        );
        assert!(km.mse(&xs) < lin.mse(&xs));
    }

    #[test]
    fn pads_degenerate_data() {
        let c = fit_kmeans(&[1.0, 1.0, 1.0], 2, 0);
        assert_eq!(c.len(), 4);
    }
}
