//! Mergeable bounded-memory sample sketch for the streaming baseline
//! estimators.
//!
//! A [`ValueSketch`] retains the `cap` distinct sample values with the
//! smallest deterministic hash keys (`mix64(value_bits ^ salt)`),
//! together with their exact multiplicities — a bottom-k sketch over the
//! *distinct-value* set.  Because the key is a pure function of the
//! value, the retained state is a pure function of the observed
//! **multiset**: feeding the same samples in any order, in any chunking,
//! across any number of merged shards, produces bit-identical sketches.
//! (Once a value's key exceeds the bottom-k threshold anywhere it
//! exceeds it globally — thresholds only tighten as more distinct values
//! arrive — so survivors' counts are never corrupted by eviction.)
//!
//! Memory is `O(cap)` entries regardless of stream length; while the
//! stream has at most `cap` distinct values the sketch is lossless and
//! [`ValueSketch::expand`] reproduces the exact sorted multiset — the
//! regime where the streaming estimators are bit-equal to their
//! buffer-everything ancestors.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::util::rng::mix64;

/// Default retained distinct-value capacity (matches the old BS-KMQ
/// buffer bound).
pub const DEFAULT_SKETCH_CAP: usize = 200_000;

/// Expansion bound: [`ValueSketch::expand`] emits at most this many
/// samples, proportionally downscaling counts beyond it.
pub const EXPAND_CAP: usize = 1 << 20;

/// Order- and shard-invariant bounded multiset sketch.
#[derive(Clone, Debug)]
pub struct ValueSketch {
    cap: usize,
    salt: u64,
    /// (hash key, value bits) -> exact multiplicity
    entries: BTreeMap<(u64, u64), u64>,
    /// total samples observed (including evicted ones)
    seen: u64,
    /// whether any entry was ever evicted (sketch no longer lossless)
    evicted: bool,
}

impl ValueSketch {
    pub fn new(cap: usize, salt: u64) -> ValueSketch {
        assert!(cap >= 1, "sketch capacity must be >= 1");
        ValueSketch {
            cap,
            salt,
            entries: BTreeMap::new(),
            seen: 0,
            evicted: false,
        }
    }

    /// Observe one sample.
    pub fn insert(&mut self, v: f64) {
        self.seen += 1;
        let bits = v.to_bits();
        let key = (mix64(bits ^ self.salt), bits);
        *self.entries.entry(key).or_insert(0) += 1;
        if self.entries.len() > self.cap {
            let last = *self.entries.keys().next_back().unwrap();
            self.entries.remove(&last);
            self.evicted = true;
        }
    }

    /// Fold another shard's sketch into this one (associative and
    /// commutative: the result depends only on the union multiset).
    pub fn merge(&mut self, other: &ValueSketch) -> Result<()> {
        ensure!(
            self.cap == other.cap && self.salt == other.salt,
            "merging incompatible sketches (cap {} vs {}, salt {:#x} vs \
             {:#x})",
            self.cap,
            other.cap,
            self.salt,
            other.salt
        );
        for (k, c) in &other.entries {
            *self.entries.entry(*k).or_insert(0) += c;
        }
        while self.entries.len() > self.cap {
            let last = *self.entries.keys().next_back().unwrap();
            self.entries.remove(&last);
            self.evicted = true;
        }
        self.seen += other.seen;
        self.evicted |= other.evicted;
        Ok(())
    }

    /// Distinct values currently retained.
    pub fn n_distinct(&self) -> usize {
        self.entries.len()
    }

    /// Total samples observed (including any evicted).
    pub fn n_seen(&self) -> u64 {
        self.seen
    }

    /// `true` while the sketch still holds the exact observed multiset.
    pub fn lossless(&self) -> bool {
        !self.evicted
    }

    /// The retained multiset, expanded value-sorted (canonical order, so
    /// downstream fitters see a deterministic sequence).  Beyond
    /// [`EXPAND_CAP`] total retained samples, counts are proportionally
    /// downscaled (each surviving value keeps at least one sample).
    pub fn expand(&self) -> Vec<f64> {
        let mut pairs: Vec<(f64, u64)> = self
            .entries
            .iter()
            .map(|(&(_, bits), &c)| (f64::from_bits(bits), c))
            .collect();
        pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
        let total: u64 = pairs.iter().map(|p| p.1).sum();
        let mut out = Vec::with_capacity((total as usize).min(EXPAND_CAP));
        for (v, c) in pairs {
            let k = if total as usize <= EXPAND_CAP {
                c
            } else {
                ((c as u128 * EXPAND_CAP as u128) / total as u128).max(1)
                    as u64
            };
            out.resize(out.len() + k as usize, v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lossless_under_cap_and_order_invariant() {
        let xs: Vec<f64> =
            (0..500).map(|i| ((i * 7) % 97) as f64 * 0.25).collect();
        let mut fwd = ValueSketch::new(1000, 9);
        let mut rev = ValueSketch::new(1000, 9);
        for &v in &xs {
            fwd.insert(v);
        }
        for &v in xs.iter().rev() {
            rev.insert(v);
        }
        assert!(fwd.lossless());
        let mut want = xs.clone();
        want.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(fwd.expand(), want);
        assert_eq!(rev.expand(), want, "expansion must be order-invariant");
    }

    #[test]
    fn merge_equals_single_stream() {
        let xs: Vec<f64> = (0..4000).map(|i| (i % 331) as f64 * 0.5).collect();
        let mut whole = ValueSketch::new(100, 3);
        for &v in &xs {
            whole.insert(v);
        }
        // 4 shards, merged in a scrambled order
        let mut shards: Vec<ValueSketch> =
            (0..4).map(|_| ValueSketch::new(100, 3)).collect();
        for (i, &v) in xs.iter().enumerate() {
            shards[i % 4].insert(v);
        }
        let mut merged = shards.pop().unwrap();
        for s in [shards.pop().unwrap(), shards.remove(0), shards.remove(0)] {
            merged.merge(&s).unwrap();
        }
        assert!(!whole.lossless(), "331 distinct > cap 100 must evict");
        assert_eq!(whole.expand(), merged.expand());
        assert_eq!(whole.n_seen(), merged.n_seen());
    }

    #[test]
    fn merge_rejects_mismatched_params() {
        let mut a = ValueSketch::new(10, 1);
        let b = ValueSketch::new(10, 2);
        assert!(a.merge(&b).is_err());
        let c = ValueSketch::new(11, 1);
        assert!(a.merge(&c).is_err());
    }

    #[test]
    fn expand_caps_giant_multiplicities() {
        let mut s = ValueSketch::new(8, 0);
        for _ in 0..(EXPAND_CAP as u64 + 10_000) {
            s.insert(1.5);
        }
        s.insert(2.5);
        let xs = s.expand();
        assert!(xs.len() <= EXPAND_CAP + 8);
        assert!(xs.contains(&2.5), "rare value must keep >= 1 sample");
    }
}
