//! Weight quantization (Fig. 6): the paper keeps weights on a *linear*
//! symmetric grid (ranges are fixed after training) at 2/3/4/4 bits for
//! ResNet-18 / VGG-16 / Inception-V3 / DistilBERT.  In hardware a w-bit
//! weight is realised by parallel bitcell connections (1/2/4 cells per
//! magnitude bit, sign via the dual 9T paths — §3.2), so the digital grid
//! below is exactly what the macro can store.

use crate::tensor::Tensor;

/// Symmetric linear weight quantization to `bits` (including sign).
/// 2-bit -> levels {-1, 0, +1} * delta (the native ternary cell).
pub fn quantize_weights_linear(w: &[f32], bits: u32) -> Vec<f32> {
    assert!((2..=8).contains(&bits), "weight bits in [2,8]");
    let absmax = w.iter().fold(0f32, |m, x| m.max(x.abs()));
    if absmax == 0.0 {
        return w.to_vec();
    }
    let qmax = ((1i32 << (bits - 1)) - 1) as f32; // e.g. 1 for 2-bit
    let delta = absmax / qmax;
    w.iter()
        .map(|&x| (x / delta).round().clamp(-qmax, qmax) * delta)
        .collect()
}

/// Quantize a weight tensor.  2-D `[K, N]` matrices (the q-layer mats)
/// are quantized **per output column**: each crossbar column carries its
/// own scale in the macro (the column's reference/DAC scaling), which is
/// essential after BN folding spreads per-channel magnitudes over orders
/// of magnitude.  Other ranks fall back to per-tensor.
pub fn quantize_tensor(w: &Tensor, bits: u32) -> Tensor {
    if w.shape.len() == 2 {
        let (k, n) = (w.shape[0], w.shape[1]);
        let mut data = w.data.clone();
        for col in 0..n {
            let mut absmax = 0f32;
            for row in 0..k {
                absmax = absmax.max(data[row * n + col].abs());
            }
            if absmax == 0.0 {
                continue;
            }
            let qmax = ((1i32 << (bits - 1)) - 1) as f32;
            let delta = absmax / qmax;
            for row in 0..k {
                let v = &mut data[row * n + col];
                *v = (*v / delta).round().clamp(-qmax, qmax) * delta;
            }
        }
        return Tensor {
            shape: w.shape.clone(),
            data,
        };
    }
    Tensor {
        shape: w.shape.clone(),
        data: quantize_weights_linear(&w.data, bits),
    }
}

/// Mean squared weight quantization error (diagnostics for Fig. 6).
pub fn weight_mse(w: &[f32], bits: u32) -> f64 {
    let q = quantize_weights_linear(w, bits);
    w.iter()
        .zip(&q)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / w.len().max(1) as f64
}

/// Number of bitcells per weight at a precision (§3.2 parallel scheme):
/// magnitude bits map to 1+2+4+... parallel cells; sign is free (dual 9T).
pub fn bitcells_per_weight(bits: u32) -> usize {
    assert!((2..=8).contains(&bits));
    (1usize << (bits - 1)) - 1 // e.g. 4-bit -> 7 cells (1+2+4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_bit_is_ternary() {
        let w = [0.9f32, -0.2, 0.1, -1.0, 0.5];
        let q = quantize_weights_linear(&w, 2);
        let delta = 1.0;
        for v in &q {
            let lv = v / delta;
            assert!(
                (lv - lv.round()).abs() < 1e-6 && lv.abs() <= 1.0,
                "non-ternary level {lv}"
            );
        }
    }

    #[test]
    fn higher_bits_lower_error() {
        let w: Vec<f32> = (0..1000).map(|i| ((i * 37) % 97) as f32 / 97.0 - 0.5).collect();
        let e2 = weight_mse(&w, 2);
        let e4 = weight_mse(&w, 4);
        let e8 = weight_mse(&w, 8);
        assert!(e2 > e4 && e4 > e8, "{e2} {e4} {e8}");
    }

    #[test]
    fn cell_counts_match_paper() {
        // "a 4-bit weight ... parallel connections of 1, 2, and 4 identical
        // bitcell structures (7 cells per 4-bit weight)"
        assert_eq!(bitcells_per_weight(4), 7);
        assert_eq!(bitcells_per_weight(2), 1);
    }

    #[test]
    fn zero_tensor_unchanged() {
        let q = quantize_weights_linear(&[0.0; 8], 3);
        assert_eq!(q, vec![0.0; 8]);
    }
}
