//! Linear (uniform min-max) quantizer — the paper's baseline [14]: equal
//! reference steps, no adaptation to the activation distribution.

/// Evenly spaced `2^bits` centers over the observed [min, max].
pub fn fit_linear(samples: &[f64], bits: u32) -> Vec<f64> {
    assert!((1..=7).contains(&bits), "bits in [1,7]");
    assert!(!samples.is_empty(), "empty sample set");
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in samples {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    fit_linear_range(lo, hi, bits)
}

/// Evenly spaced centers over an explicit range.
pub fn fit_linear_range(lo: f64, hi: f64, bits: u32) -> Vec<f64> {
    let k = 1usize << bits;
    let hi = if hi > lo { hi } else { lo + 1e-8 };
    let step = (hi - lo) / (k - 1) as f64;
    (0..k).map(|i| lo + step * i as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_min_max() {
        let c = fit_linear(&[-2.0, 0.0, 6.0], 2);
        let want = [-2.0, -2.0 + 8.0 / 3.0, -2.0 + 16.0 / 3.0, 6.0];
        for (a, b) in c.iter().zip(want) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn degenerate_range() {
        let c = fit_linear(&[3.0, 3.0], 1);
        assert_eq!(c.len(), 2);
        assert!(c[1] > c[0]);
    }
}
