//! Small statistics helpers shared by the quantizers, circuit Monte-Carlo
//! and experiment harnesses.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// `q`-quantile (linear interpolation) of unsorted data.  Non-finite
/// samples (a NaN latency from a cold `rate`, an overflowed counter)
/// are dropped rather than poisoning the sort; all-non-finite input
/// yields 0.0 — the metrics path must never panic mid-serve.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    quantile_sorted(&v, q)
}

/// `q`-quantile of data already sorted ascending.
pub fn quantile_sorted(v: &[f64], q: f64) -> f64 {
    let n = v.len();
    if n == 1 {
        return v[0];
    }
    let pos = q.clamp(0.0, 1.0) * (n - 1) as f64;
    let i = pos.floor() as usize;
    let frac = pos - i as f64;
    if i + 1 < n {
        v[i] * (1.0 - frac) + v[i + 1] * frac
    } else {
        v[n - 1]
    }
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        / a.len() as f64
}

/// Skewness-free Gaussian fit: returns (mu, sigma).
pub fn gaussian_fit(xs: &[f64]) -> (f64, f64) {
    (mean(xs), std(xs))
}

/// Histogram over [lo, hi] with `bins` buckets; returns counts.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    if hi <= lo {
        return h;
    }
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x < lo || x > hi {
            continue;
        }
        let i = (((x - lo) / w) as usize).min(bins - 1);
        h[i] += 1;
    }
    h
}

/// Guarded rate: `num / den`, or 0 when the denominator is zero or not
/// finite (throughput and saturation-rate reporting never divide by a
/// cold counter).
pub fn rate(num: f64, den: f64) -> f64 {
    if den.is_finite() && den > 0.0 {
        num / den
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_quantile() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((quantile(&xs, 1.0) - 4.0).abs() < 1e-12);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_survives_nan_and_infinities() {
        // a single NaN used to panic the partial_cmp sort mid-serve
        let xs = [3.0, f64::NAN, 1.0, 2.0, f64::INFINITY, 4.0];
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile(&xs, 1.0) - 4.0).abs() < 1e-12);
        // all-non-finite input degrades to 0.0 instead of panicking
        assert_eq!(quantile(&[f64::NAN, f64::NEG_INFINITY], 0.5), 0.0);
    }

    #[test]
    fn mse_basic() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 4.0]), 2.0);
    }

    #[test]
    fn rate_guards_zero_denominator() {
        assert_eq!(rate(5.0, 0.0), 0.0);
        assert_eq!(rate(5.0, f64::NAN), 0.0);
        assert!((rate(5.0, 2.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let h = histogram(&[0.1, 0.2, 0.9], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 1]);
    }
}
