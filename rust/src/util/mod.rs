//! Dependency-free utilities: PRNG, statistics, a minimal JSON parser and
//! a micro-benchmark harness (this build environment is offline; only the
//! `anyhow` crate — plus `xla` behind the `xla` feature — is vendored, so
//! rand/serde/criterion/rayon substitutes live here).

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
