//! Dependency-free utilities: PRNG, statistics, a minimal JSON parser and
//! a micro-benchmark harness (this build environment is offline; only the
//! `xla` + `anyhow` crates are vendored, so rand/serde/criterion substitutes
//! live here).

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
