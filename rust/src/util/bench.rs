//! Micro-benchmark harness (criterion is not vendored offline).
//!
//! `cargo bench` binaries use [`Bencher`] for timed hot paths and plain
//! printing for the paper-table regeneration harnesses.  Reports min /
//! median / mean over timed iterations after a warmup phase.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters  min {:>12?}  median {:>12?}  mean {:>12?}",
            self.name, self.iters, self.min, self.median, self.mean
        );
    }

    /// Mean wall time in integer nanoseconds (BENCH json unit).
    pub fn mean_ns(&self) -> u64 {
        self.mean.as_nanos().min(u64::MAX as u128) as u64
    }

    /// Iterations per second implied by the mean (0 when unmeasured).
    pub fn per_sec(&self) -> f64 {
        let ns = self.mean.as_nanos() as f64;
        if ns > 0.0 {
            1e9 / ns
        } else {
            0.0
        }
    }

    /// Throughput line given work items per iteration.
    pub fn print_throughput(&self, items: f64, unit: &str) {
        let per_sec = items / self.mean.as_secs_f64();
        println!(
            "{:<44} mean {:>12?}  {:>14.1} {unit}/s",
            self.name, self.mean, per_sec
        );
    }
}

/// Run `f` repeatedly: warm up for `warmup`, then time iterations until
/// `budget` elapses (at least `min_iters`).
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_cfg(name, Duration::from_millis(200), Duration::from_secs(1), 10, &mut f)
}

pub fn bench_cfg<F: FnMut()>(
    name: &str,
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
    f: &mut F,
) -> BenchResult {
    let start = Instant::now();
    while start.elapsed() < warmup {
        f();
    }
    let mut samples = Vec::new();
    let timed = Instant::now();
    while timed.elapsed() < budget || samples.len() < min_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() > 100_000 {
            break;
        }
    }
    samples.sort();
    let iters = samples.len();
    let min = samples[0];
    let median = samples[iters / 2];
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchResult {
        name: name.to_string(),
        iters,
        min,
        median,
        mean,
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs() {
        let r = bench_cfg(
            "noop",
            Duration::from_millis(1),
            Duration::from_millis(5),
            5,
            &mut || {
                black_box(1 + 1);
            },
        );
        assert!(r.iters >= 5);
        assert!(r.min <= r.median && r.median <= r.mean * 2);
    }
}
