//! Minimal JSON parser for the AOT manifests (serde is not vendored in
//! this offline environment).  Supports the full JSON value grammar; no
//! serialization beyond what the experiment harnesses need.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser {
            b: src.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad \\u"))?,
                            );
                        }
                        _ => bail!("bad escape"),
                    }
                }
                _ => {
                    // copy raw UTF-8 bytes
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let src = r#"{"model": "resnet", "batch": 32,
            "qlayers": [{"name": "conv0", "k": 27, "relu": true}],
            "nested": {"a": [1, 2.5, -3e2], "b": null}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "resnet");
        assert_eq!(j.get("batch").unwrap().as_usize().unwrap(), 32);
        let ql = j.get("qlayers").unwrap().as_arr().unwrap();
        assert!(ql[0].get("relu").unwrap().as_bool().unwrap());
        let a = j.get("nested").unwrap().get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[2].as_f64().unwrap(), -300.0);
    }

    #[test]
    fn parse_strings_with_escapes() {
        let j = Json::parse(r#"{"s": "a\nb\"cA"}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str().unwrap(), "a\nb\"cA");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
    }
}
