//! SplitMix64-seeded xoshiro256++ PRNG with Box-Muller Gaussians.
//!
//! Deterministic, seedable, and fast enough for the Monte-Carlo circuit
//! simulation (Fig. 7 draws millions of device-mismatch samples).

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller output
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stateless SplitMix64 finalizer: a high-quality 64-bit mixing function.
/// Used as the deterministic sampling key of the mergeable value sketch
/// (`quant::sketch`) — the same input always maps to the same key, which
/// is what makes bottom-k selection order- and shard-invariant.
pub fn mix64(x: u64) -> u64 {
    let mut state = x;
    splitmix64(&mut state)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            gauss_spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s0
            .wrapping_add(s3)
            .rotate_left(23)
            .wrapping_add(s0);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u1 in (0,1] to keep ln finite
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * th.sin());
        r * th.cos()
    }

    /// Normal with given mean and std.
    #[inline]
    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.gaussian()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` elements without replacement (k <= xs.len()).
    pub fn sample<T: Copy>(&mut self, xs: &[T], k: usize) -> Vec<T> {
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.into_iter().map(|i| xs[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            s += g;
            s2 += g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_without_replacement() {
        let mut r = Rng::new(1);
        let xs: Vec<i32> = (0..50).collect();
        let mut got = r.sample(&xs, 20);
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 20);
    }
}
