//! Fig. 6: quantization effects and ADC-noise impact on accuracy.
//!
//! Three bars per model: activation quantization only (PTQ at the
//! paper's per-model bits), + linear weight quantization (2/3/4/4 bit),
//! + ADC conversion noise injected at the circuit-sim-derived TT level
//! (N(0.21, 1.07) MAC units at min step 10 -> sigma ~ 0.107 LSB).

use anyhow::Result;

use crate::backend::Backend;
use crate::circuit::montecarlo::{default_4bit_steps, MonteCarlo, MonteCarloConfig};
use crate::circuit::{Corner, MAC_UNITS_PER_CELL};
use crate::coordinator::calibrate::Calibrator;
use crate::coordinator::ptq::PtqEvaluator;
use crate::data::dataset::ModelData;
use crate::experiments::ExpContext;
use crate::quant::{Method, QuantSpec};

/// (model, activation bits, weight bits) — the paper's Fig. 6 settings.
/// The paper uses 2/3/4/4-bit weights on 10M+-param models; the minis
/// (~20k params) sit ~2 bits left of the paper's redundancy cliff, so the
/// iso-accuracy points are 4/4/4/4 (measured sweep in EXPERIMENTS.md) —
/// the *trend* (small loss, noise adds little, deeper nets hurt more) is
/// what Fig. 6 establishes.
pub const SETTINGS: [(&str, u32, u32); 4] = [
    ("resnet", 3, 4),
    ("vgg", 3, 4),
    ("inception", 4, 4),
    ("distilbert", 4, 4),
];
const EVAL_BATCHES: usize = 4;

pub struct Fig6Row {
    pub model: String,
    pub acc_act_quant: f64,
    pub acc_plus_wquant: f64,
    pub acc_plus_noise: f64,
}

pub fn run(ctx: &ExpContext) -> Result<Vec<Fig6Row>> {
    println!("== Fig.6: weight quantization + ADC noise impact ==");
    // derive the injected noise sigma from the circuit simulation at TT
    let mc = MonteCarlo::new(MonteCarloConfig::default());
    let tt = mc.run(Corner::TT, &default_4bit_steps(), 42);
    let sigma_lsb = (tt.sigma / MAC_UNITS_PER_CELL) as f32;
    println!(
        "   circuit-sim TT error N({:.2}, {:.2}) MAC units -> sigma {:.3} LSB",
        tt.mu, tt.sigma, sigma_lsb
    );
    let mut rows = Vec::new();
    for (model, bits, wbits) in SETTINGS {
        let backend = ctx.backend(model)?;
        let data = ModelData::load(&ctx.artifacts, model)?;
        // one per-layer spec set expresses the whole Fig. 6 deployment
        // point: NL-ADC act bits + linear weight bits
        let spec = QuantSpec {
            weight_bits: Some(wbits),
            ..QuantSpec::new(Method::BsKmq, bits)
        };
        let act_only = Calibrator::with_uniform(
            backend.as_ref(),
            QuantSpec::new(Method::BsKmq, bits),
        );
        let calib = act_only.calibrate(&data, 8)?;

        let ev = PtqEvaluator::new(backend.as_ref());
        let a0 = ev
            .evaluate(&data, &calib.programmed, 0.0, EVAL_BATCHES, 3)?
            .accuracy;
        // + weight quantization; deployment order: recalibrate the NL-ADC
        // codebooks on the quantized-weight hardware (Algorithm 1 runs on
        // the deployed macro, not on a float simulator)
        let wq_specs = spec.per_layer(backend.manifest().nq());
        let wq_backend = ev.quantize_weights_spec(&wq_specs)?;
        let wq_books = Calibrator::with_specs(wq_backend.as_ref(), wq_specs)
            .calibrate(&data, 8)?;
        let evw = PtqEvaluator::new(wq_backend.as_ref());
        let a1 = evw
            .evaluate(&data, &wq_books.programmed, 0.0, EVAL_BATCHES, 3)?
            .accuracy;
        // + ADC noise at the TT level
        let a2 = evw
            .evaluate(&data, &wq_books.programmed, sigma_lsb, EVAL_BATCHES, 3)?
            .accuracy;
        println!(
            "   {model:<11} act@{bits}b {:.3} | +w@{wbits}b {:.3} ({:+.2} pts) | +noise {:.3} ({:+.2} pts)",
            a0,
            a1,
            (a1 - a0) * 100.0,
            a2,
            (a2 - a1) * 100.0
        );
        rows.push(Fig6Row {
            model: model.into(),
            acc_act_quant: a0,
            acc_plus_wquant: a1,
            acc_plus_noise: a2,
        });
    }
    Ok(rows)
}
