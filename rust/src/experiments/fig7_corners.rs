//! Fig. 7: NL-ADC output vs theoretical MAC across process corners
//! (SS/TT/FF), 6-bit input / 4-bit output, minimum step 10.  Prints the
//! Gaussian fit per corner plus the replica-bias ablation.

use anyhow::Result;

use crate::circuit::montecarlo::{default_4bit_steps, MonteCarlo, MonteCarloConfig};
use crate::circuit::Corner;

pub struct Fig7Row {
    pub corner: &'static str,
    pub mu: f64,
    pub sigma: f64,
    pub code_error_rate: f64,
}

pub fn run() -> Result<Vec<Fig7Row>> {
    println!("== Fig.7: conversion error across process corners (4-bit, min step 10) ==");
    let steps = default_4bit_steps();
    let mc = MonteCarlo::new(MonteCarloConfig::default());
    let stats = mc.run_corners(&steps, 42);
    let mut rows = Vec::new();
    let mut tt_sigma = 1.0;
    for s in &stats {
        if s.corner == Corner::TT {
            tt_sigma = s.sigma;
        }
    }
    for s in &stats {
        println!(
            "   {:<3} error ~ N({:+.2}, {:.2})  sigma/sigma(TT) = {:.2}   code-error rate {:.3}",
            s.corner.name(),
            s.mu,
            s.sigma,
            s.sigma / tt_sigma,
            s.code_error_rate
        );
        rows.push(Fig7Row {
            corner: s.corner.name(),
            mu: s.mu,
            sigma: s.sigma,
            code_error_rate: s.code_error_rate,
        });
    }
    println!("   paper anchors: TT ~ N(0.21, 1.07), sigma(SS)/sigma(TT) ~ 1.2");

    // replica-bias ablation (the mechanism behind the robustness claim)
    let ab = MonteCarlo::new(MonteCarloConfig {
        replica_bias: false,
        ..Default::default()
    });
    let ss_off = ab.run(Corner::SS, &steps, 42);
    let ss_on = stats.iter().find(|s| s.corner == Corner::SS).unwrap();
    println!(
        "   ablation, replica bias OFF @SS: sigma {:.2} ({}x worse) — the design's robustness source",
        ss_off.sigma,
        (ss_off.sigma / ss_on.sigma).round() as i64
    );
    Ok(rows)
}
