//! Fig. 1 / Fig. 4: MSE of the five quantizers on real collected
//! activations — Fig. 1 uses the first Conv-BN-ReLU block of ResNet
//! (3-bit), Fig. 4 the first attention query projection of DistilBERT
//! (4-bit).  All codebooks are evaluated after the §2.3 hardware
//! projection (the deployed form).

use anyhow::Result;

use crate::backend::Backend;
use crate::coordinator::calibrate::Calibrator;
use crate::data::dataset::ModelData;
use crate::experiments::ExpContext;
use crate::quant::Method;

pub struct MseRow {
    pub method: &'static str,
    pub mse: f64,
}

pub fn run(ctx: &ExpContext, model: &str, bits: u32) -> Result<Vec<MseRow>> {
    let fig = if model == "resnet" { "Fig.1" } else { "Fig.4" };
    println!("== {fig}: {bits}-bit quantizer MSE on {model} layer-0 activations ==");
    let backend = ctx.backend(model)?;
    let data = ModelData::load(&ctx.artifacts, model)?;
    let calib = Calibrator::from_manifest(backend.as_ref());
    let samples = calib.collect_samples(&data, 8)?;
    let layer0 = &samples[0];
    println!(
        "   layer '{}': {} samples, range [{:.3}, {:.3}]",
        backend.manifest().qlayers[0].name,
        layer0.len(),
        layer0.iter().cloned().fold(f64::INFINITY, f64::min),
        layer0.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    );
    let rows = mse_rows(layer0, bits);
    print_rows(&rows);
    Ok(rows)
}

/// Fit all five methods on one sample set and evaluate deployed MSE.
pub fn mse_rows(samples: &[f64], bits: u32) -> Vec<MseRow> {
    Method::ALL
        .iter()
        .map(|m| MseRow {
            method: m.name(),
            mse: m.fit_hw(samples, bits, 0).mse(samples),
        })
        .collect()
}

fn print_rows(rows: &[MseRow]) {
    let bs = rows
        .iter()
        .find(|r| r.method == "bs_kmq")
        .map(|r| r.mse)
        .unwrap_or(f64::NAN);
    for r in rows {
        let ratio = r.mse / bs;
        println!(
            "   {:<10} MSE {:>12.6}   ({:>5.2}x vs BS-KMQ)",
            r.method, r.mse, ratio
        );
    }
}
