//! Experiment harnesses: one per paper table/figure (DESIGN.md §3).
//! Each prints the same rows/series the paper reports and returns the
//! numbers for EXPERIMENTS.md.  `run_all` regenerates everything.

pub mod backends_agree;
pub mod fig1_mse;
pub mod fig5_ptq;
pub mod fig6_noise;
pub mod fig7_corners;
pub mod fig8_macro;
pub mod table1_system;

use std::path::PathBuf;

use anyhow::Result;

use crate::backend::{Backend, BackendKind};

/// Shared context: the artifacts directory + the selected execution
/// backend (env `BSKMQ_BACKEND`, default auto).
pub struct ExpContext {
    pub artifacts: PathBuf,
    pub kind: BackendKind,
}

impl ExpContext {
    pub fn new() -> Result<ExpContext> {
        Ok(ExpContext {
            artifacts: crate::artifacts_dir(),
            kind: BackendKind::from_env(),
        })
    }

    /// Load the selected backend for one model.
    pub fn backend(&self, model: &str) -> Result<Box<dyn Backend>> {
        crate::backend::load(self.kind, &self.artifacts, model)
    }
}

/// Run one experiment by id ("fig1", "fig4", "fig5", "fig6", "fig7",
/// "fig8", "table1", "backends" or "all").
pub fn run(id: &str) -> Result<()> {
    match id {
        "fig1" => {
            let ctx = ExpContext::new()?;
            fig1_mse::run(&ctx, "resnet", 3)?;
        }
        "fig4" => {
            let ctx = ExpContext::new()?;
            fig1_mse::run(&ctx, "distilbert", 4)?;
        }
        "fig5" => {
            let ctx = ExpContext::new()?;
            fig5_ptq::run(&ctx)?;
        }
        "fig6" => {
            let ctx = ExpContext::new()?;
            fig6_noise::run(&ctx)?;
        }
        "fig7" => {
            fig7_corners::run()?;
        }
        "fig8" => fig8_macro::run()?,
        "table1" => {
            let ctx = ExpContext::new()?;
            table1_system::run(&ctx)?;
        }
        "backends" => {
            let ctx = ExpContext::new()?;
            backends_agree::run(&ctx)?;
        }
        "all" => {
            let ctx = ExpContext::new()?;
            fig1_mse::run(&ctx, "resnet", 3)?;
            fig1_mse::run(&ctx, "distilbert", 4)?;
            fig5_ptq::run(&ctx)?;
            fig6_noise::run(&ctx)?;
            fig7_corners::run()?;
            fig8_macro::run()?;
            table1_system::run(&ctx)?;
            backends_agree::run(&ctx)?;
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' \
             (fig1|fig4|fig5|fig6|fig7|fig8|table1|backends|all)"
        ),
    }
    Ok(())
}
