//! Experiment harnesses: one per paper table/figure (DESIGN.md §3).
//! Each prints the same rows/series the paper reports and returns the
//! numbers for EXPERIMENTS.md.  `run_all` regenerates everything.

pub mod fig1_mse;
pub mod fig5_ptq;
pub mod fig6_noise;
pub mod fig7_corners;
pub mod fig8_macro;
pub mod table1_system;

use std::path::PathBuf;

use anyhow::Result;

use crate::runtime::engine::Engine;

/// Shared context: one PJRT engine + the artifacts directory.
pub struct ExpContext {
    pub engine: Engine,
    pub artifacts: PathBuf,
}

impl ExpContext {
    pub fn new() -> Result<ExpContext> {
        Ok(ExpContext {
            engine: Engine::cpu()?,
            artifacts: crate::artifacts_dir(),
        })
    }
}

/// Run one experiment by id ("fig1", "fig4", "fig5", "fig6", "fig7",
/// "fig8", "table1" or "all").
pub fn run(id: &str) -> Result<()> {
    match id {
        "fig1" => {
            let ctx = ExpContext::new()?;
            fig1_mse::run(&ctx, "resnet", 3)?;
        }
        "fig4" => {
            let ctx = ExpContext::new()?;
            fig1_mse::run(&ctx, "distilbert", 4)?;
        }
        "fig5" => {
            let ctx = ExpContext::new()?;
            fig5_ptq::run(&ctx)?;
        }
        "fig6" => {
            let ctx = ExpContext::new()?;
            fig6_noise::run(&ctx)?;
        }
        "fig7" => {
            fig7_corners::run()?;
        }
        "fig8" => fig8_macro::run()?,
        "table1" => table1_system::run()?,
        "all" => {
            let ctx = ExpContext::new()?;
            fig1_mse::run(&ctx, "resnet", 3)?;
            fig1_mse::run(&ctx, "distilbert", 4)?;
            fig5_ptq::run(&ctx)?;
            fig6_noise::run(&ctx)?;
            fig7_corners::run()?;
            fig8_macro::run()?;
            table1_system::run()?;
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (fig1|fig4|fig5|fig6|fig7|fig8|table1|all)"
        ),
    }
    Ok(())
}
