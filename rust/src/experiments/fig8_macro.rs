//! Fig. 8: macro energy and area breakdowns (6/4-bit I/O, 2-bit weight)
//! plus the headline macro metrics and ADC-overhead comparison.

use anyhow::Result;

use crate::macro_model::{AreaBreakdown, MacroArea, MacroConfig, MacroEnergy};

pub fn run() -> Result<()> {
    let cfg = MacroConfig::paper_macro();
    println!("== Fig.8(a): macro energy breakdown (6-bit in, 2-bit w, 4-bit out) ==");
    let e = MacroEnergy::per_pass(cfg);
    for (name, share) in e.shares() {
        println!("   {:<11} {:>5.1}%", name, share * 100.0);
    }
    println!("   total {:.1} pJ per macro pass", e.total_pj());
    println!(
        "   macro: {:.0} TOPS/W (paper 246), {:.2} TOPS/mm^2 (paper 0.55)",
        MacroEnergy::tops_per_watt(cfg),
        MacroEnergy::tops_per_mm2(cfg)
    );
    let lin = MacroEnergy::per_pass(MacroConfig { nl_adc: false, ..cfg });
    println!(
        "   NL vs linear IM ADC energy: {:.2}x (paper ~1.3x)",
        e.adc_pj / lin.adc_pj
    );

    println!("== Fig.8(b): macro area breakdown (total 0.248 mm^2) ==");
    let a = MacroArea::proposed();
    print_area(&a);
    println!(
        "   ADC overhead (NL-ADC/MAC array): {:.1}% — vs 23% NL ramp [15] ({:.1}x), 17% SAR [17] ({:.1}x)",
        a.adc_overhead_ratio() * 100.0,
        MacroArea::prior_nl_ramp().adc_overhead_ratio() / a.adc_overhead_ratio(),
        MacroArea::prior_sar().adc_overhead_ratio() / a.adc_overhead_ratio()
    );
    Ok(())
}

fn print_area(a: &AreaBreakdown) {
    let t = a.total();
    for (name, v) in [
        ("mac_array", a.mac_array_mm2),
        ("nl_adc", a.nl_adc_mm2),
        ("drivers", a.drivers_mm2),
        ("sa_buffers", a.sa_buffers_mm2),
        ("rcnt", a.rcnt_mm2),
        ("control", a.control_mm2),
    ] {
        println!("   {:<11} {:>7.4} mm^2  ({:>4.1}%)", name, v, v / t * 100.0);
    }
}
