//! Backend agreement harness: calibrates once, then runs the same test
//! batch with the same programmed codebooks through the native integer
//! backend and (when compiled in) the XLA engine, reporting logit-level
//! agreement.  Residual differences come from float summation order
//! crossing a floor-ADC reference — i.e. at most codebook quantization
//! tolerance per conversion.

use anyhow::Result;

use crate::backend::{self, Backend, BackendKind};
use crate::coordinator::calibrate::Calibrator;
use crate::coordinator::ptq::argmax;
use crate::data::dataset::ModelData;
use crate::experiments::ExpContext;
use crate::quant::{Method, QuantSpec};

pub const MODELS: [&str; 4] = ["resnet", "vgg", "inception", "distilbert"];

/// Per-model agreement statistics (native vs reference logits).
pub struct AgreeRow {
    pub model: String,
    /// fraction of exactly equal logits
    pub exact: f64,
    /// fraction of matching per-sample argmax decisions
    pub argmax_match: f64,
    pub max_abs_diff: f64,
}

pub fn run(ctx: &ExpContext) -> Result<Vec<AgreeRow>> {
    println!("== Backend agreement: native integer IMC vs XLA qfwd ==");
    #[allow(unused_mut)] // pushed only when the xla feature is compiled in
    let mut rows = Vec::new();
    for model in MODELS {
        let native =
            match backend::load(BackendKind::Native, &ctx.artifacts, model) {
                Ok(b) => b,
                Err(e) => {
                    println!("   {model:<11} SKIP (native load: {e:#})");
                    continue;
                }
            };
        let data = ModelData::load(&ctx.artifacts, model)?;
        let calib = Calibrator::with_uniform(
            native.as_ref(),
            QuantSpec::new(Method::BsKmq, 3),
        )
        .calibrate(&data, 4)?;
        let m = native.manifest();
        let xb = ModelData::batch(&data.x_test, 0, m.batch);
        let nat = native.run_qfwd(xb, &calib.programmed, 0.0, 7)?;
        anyhow::ensure!(
            nat.iter().all(|v| v.is_finite()),
            "{model}: native logits not finite"
        );

        #[cfg(feature = "xla")]
        {
            let xla_be =
                match backend::load(BackendKind::Xla, &ctx.artifacts, model) {
                    Ok(b) => b,
                    Err(e) => {
                        println!("   {model:<11} native ok; xla SKIP ({e:#})");
                        continue;
                    }
                };
            let ref_logits = xla_be.run_qfwd(xb, &calib.programmed, 0.0, 7)?;
            let row = compare(model, &nat, &ref_logits, m.batch, m.num_classes);
            println!(
                "   {model:<11} exact {:.1}%  argmax {:.1}%  max|diff| {:.4}",
                row.exact * 100.0,
                row.argmax_match * 100.0,
                row.max_abs_diff
            );
            rows.push(row);
        }
        #[cfg(not(feature = "xla"))]
        {
            println!(
                "   {model:<11} native ok ({} logits finite; build with \
                 --features xla for the cross-backend diff)",
                nat.len()
            );
        }
    }
    Ok(rows)
}

/// Logit-level agreement between two backends' outputs.
pub fn compare(
    model: &str,
    a: &[f32],
    b: &[f32],
    batch: usize,
    classes: usize,
) -> AgreeRow {
    assert_eq!(a.len(), b.len(), "logit length mismatch");
    let exact = a
        .iter()
        .zip(b)
        .filter(|(x, y)| x == y)
        .count() as f64
        / a.len() as f64;
    let mut max_abs_diff = 0f64;
    for (x, y) in a.iter().zip(b) {
        max_abs_diff = max_abs_diff.max((x - y).abs() as f64);
    }
    let mut agree = 0usize;
    for i in 0..batch {
        let ra = &a[i * classes..(i + 1) * classes];
        let rb = &b[i * classes..(i + 1) * classes];
        if argmax(ra) == argmax(rb) {
            agree += 1;
        }
    }
    AgreeRow {
        model: model.into(),
        exact,
        argmax_match: agree as f64 / batch as f64,
        max_abs_diff,
    }
}
