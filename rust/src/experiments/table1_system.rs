//! Table 1: system-level comparison — our simulated ResNet-18 (6/2/3b)
//! accelerator vs the three published IMC designs, with the paper's
//! speedup / energy-efficiency headline ratios.

use anyhow::Result;

use crate::arch::accelerator::{Accelerator, SystemConfig};
use crate::arch::baselines::baseline_designs;
use crate::nn::zoo::resnet18_cifar;

pub fn run() -> Result<()> {
    println!("== Table 1: comparison with state-of-the-art IMC designs ==");
    let net = resnet18_cifar();
    let acc = Accelerator::new(SystemConfig::paper_system());
    let ours = acc.simulate(&net);

    println!(
        "{:<14} {:>6} {:>7} {:>9} {:>7} {:>10} {:>12}",
        "design", "tech", "ADC", "network", "TOPS", "TOPS/W", "acc loss %"
    );
    for d in baseline_designs() {
        println!(
            "{:<14} {:>4}nm {:>7} {:>9} {:>7} {:>10} {:>12.2}",
            d.label,
            d.tech_nm,
            d.adc_type,
            d.network,
            d.tops.map(|t| format!("{t:.2}")).unwrap_or("-".into()),
            format!("{:.2}-{:.2}", d.tops_per_watt.0, d.tops_per_watt.1),
            d.acc_loss_pct
        );
    }
    println!(
        "{:<14} {:>4}nm {:>7} {:>9} {:>7.2} {:>10.1} {:>12.2}",
        "Ours (sim)", 65, "IM NL", "ResNet-18", ours.tops, ours.tops_per_watt, 1.0
    );
    println!(
        "   latency {:.3} ms/inference, {:.0} inf/s, energy {:.1} uJ (macro {:.1} + periphery {:.1})",
        ours.latency_ms,
        ours.inferences_per_sec,
        ours.total_energy_uj,
        ours.macro_energy_uj,
        ours.periphery_energy_uj
    );

    // headline ratios
    let designs = baseline_designs();
    // speedup vs the *fastest* reported baseline (the paper's 4x compares
    // against TCASI'24's 0.52 TOPS, not the slowest design)
    let speedup = designs
        .iter()
        .filter_map(|d| d.tops)
        .fold(0.0f64, f64::max)
        .recip()
        * ours.tops;
    let eff = designs
        .iter()
        .map(|d| ours.tops_per_watt / d.tops_per_watt.1)
        .fold(0.0f64, f64::max);
    println!(
        "   headline: up to {:.1}x speedup (paper 4x), up to {:.0}x energy efficiency (paper 24x)",
        speedup, eff
    );
    Ok(())
}
