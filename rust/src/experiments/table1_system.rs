//! Table 1: system-level comparison — our simulated ResNet-18 (6/2/3b)
//! accelerator vs the three published IMC designs, with the paper's
//! speedup / energy-efficiency headline ratios, plus the *measured*
//! 6/2/3b (tile/weight/activation) PTQ point driven through the
//! QuantSpec pipeline on the resnet artifact.

use anyhow::Result;

use crate::arch::accelerator::{Accelerator, SystemConfig};
use crate::arch::baselines::baseline_designs;
use crate::backend::Backend;
use crate::coordinator::calibrate::Calibrator;
use crate::coordinator::ptq::PtqEvaluator;
use crate::data::dataset::ModelData;
use crate::experiments::ExpContext;
use crate::nn::zoo::resnet18_cifar;
use crate::quant::QuantSpec;

pub fn run(ctx: &ExpContext) -> Result<()> {
    println!("== Table 1: comparison with state-of-the-art IMC designs ==");
    let net = resnet18_cifar();
    let acc = Accelerator::new(SystemConfig::paper_system());
    let ours = acc.simulate(&net);

    println!(
        "{:<14} {:>6} {:>7} {:>9} {:>7} {:>10} {:>12}",
        "design", "tech", "ADC", "network", "TOPS", "TOPS/W", "acc loss %"
    );
    for d in baseline_designs() {
        println!(
            "{:<14} {:>4}nm {:>7} {:>9} {:>7} {:>10} {:>12.2}",
            d.label,
            d.tech_nm,
            d.adc_type,
            d.network,
            d.tops.map(|t| format!("{t:.2}")).unwrap_or("-".into()),
            format!("{:.2}-{:.2}", d.tops_per_watt.0, d.tops_per_watt.1),
            d.acc_loss_pct
        );
    }
    println!(
        "{:<14} {:>4}nm {:>7} {:>9} {:>7.2} {:>10.1} {:>12.2}",
        "Ours (sim)", 65, "IM NL", "ResNet-18", ours.tops, ours.tops_per_watt, 1.0
    );
    println!(
        "   latency {:.3} ms/inference, {:.0} inf/s, energy {:.1} uJ (macro {:.1} + periphery {:.1})",
        ours.latency_ms,
        ours.inferences_per_sec,
        ours.total_energy_uj,
        ours.macro_energy_uj,
        ours.periphery_energy_uj
    );

    // headline ratios
    let designs = baseline_designs();
    // speedup vs the *fastest* reported baseline (the paper's 4x compares
    // against TCASI'24's 0.52 TOPS, not the slowest design)
    let speedup = designs
        .iter()
        .filter_map(|d| d.tops)
        .fold(0.0f64, f64::max)
        .recip()
        * ours.tops;
    let eff = designs
        .iter()
        .map(|d| ours.tops_per_watt / d.tops_per_watt.1)
        .fold(0.0f64, f64::max);
    println!(
        "   headline: up to {:.1}x speedup (paper 4x), up to {:.0}x energy efficiency (paper 24x)",
        speedup, eff
    );

    // the same 6/2/3b system point, *measured*: tile 6b / weight 2b /
    // activation 3b per-layer specs through calibrate -> PTQ on the
    // resnet artifact (skips gracefully when no artifacts are present —
    // the analytic rows above never need them)
    match measured_system_point(ctx) {
        Ok((acc, acc_float, samples)) => println!(
            "   measured 6/2/3b PTQ on the resnet artifact: acc {acc:.3} \
             (float ref {acc_float:.3}, {samples} samples)"
        ),
        Err(e) => println!("   measured 6/2/3b PTQ point skipped: {e:#}"),
    }
    Ok(())
}

/// Drive the paper's 6/2/3b (tile/weight/act) config end-to-end through
/// the QuantSpec pipeline: per-layer specs -> weight programming ->
/// Algorithm 1 on the deployed macro -> PTQ accuracy.
fn measured_system_point(ctx: &ExpContext) -> Result<(f64, f64, usize)> {
    let backend = ctx.backend("resnet")?;
    let data = ModelData::load(&ctx.artifacts, "resnet")?;
    let spec = QuantSpec {
        tile_bits: 6,
        weight_bits: Some(2),
        act_bits: 3,
        ..QuantSpec::default()
    };
    let specs = spec.per_layer(backend.manifest().nq());
    let deployed =
        PtqEvaluator::new(backend.as_ref()).quantize_weights_spec(&specs)?;
    let books = Calibrator::with_specs(deployed.as_ref(), specs)
        .calibrate(&data, 8)?;
    let r = PtqEvaluator::new(deployed.as_ref())
        .evaluate(&data, &books.programmed, 0.0, 4, 1)?;
    // float reference: 7-bit linear codebooks on the float weights
    let float_books = Calibrator::with_uniform(
        backend.as_ref(),
        QuantSpec::new(crate::quant::Method::Linear, 7),
    )
    .calibrate(&data, 8)?;
    let rf = PtqEvaluator::new(backend.as_ref())
        .evaluate(&data, &float_books.programmed, 0.0, 4, 1)?;
    Ok((r.accuracy, rf.accuracy, r.samples))
}
