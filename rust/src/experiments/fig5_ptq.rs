//! Fig. 5: PTQ accuracy of linear vs BS-KMQ over the ADC bit sweep for
//! all four models, plus the float baseline (BL) and the build-time
//! fine-tuning (FT) results recorded by train.py.

use anyhow::Result;

use crate::coordinator::calibrate::Calibrator;
use crate::coordinator::ptq::PtqEvaluator;
use crate::data::dataset::ModelData;
use crate::experiments::ExpContext;
use crate::quant::{Method, QuantSpec};
use crate::util::json::Json;

pub const MODELS: [&str; 4] = ["resnet", "vgg", "inception", "distilbert"];
pub const BIT_SWEEP: [u32; 4] = [2, 3, 4, 5];
/// test batches per point (32 samples each)
const EVAL_BATCHES: usize = 4;
const CALIB_BATCHES: usize = 8;

pub struct Fig5Row {
    pub model: String,
    pub bits: u32,
    pub acc_linear: f64,
    pub acc_bskmq: f64,
}

pub fn run(ctx: &ExpContext) -> Result<Vec<Fig5Row>> {
    println!("== Fig.5: PTQ accuracy, linear vs BS-KMQ (BL = float) ==");
    let train_results = load_train_results(ctx)?;
    let mut rows = Vec::new();
    for model in MODELS {
        let backend = ctx.backend(model)?;
        let data = ModelData::load(&ctx.artifacts, model)?;
        let ev = PtqEvaluator::new(backend.as_ref());
        let bl = train_results
            .get(model)
            .and_then(|m| m.get("float_acc").ok().and_then(|v| v.as_f64().ok()))
            .unwrap_or(f64::NAN);
        println!("-- {model} (BL float acc {:.3}) --", bl);
        for bits in BIT_SWEEP {
            let mut accs = [0.0f64; 2];
            for (i, method) in [Method::Linear, Method::BsKmq].iter().enumerate() {
                let calib = Calibrator::with_uniform(
                    backend.as_ref(),
                    QuantSpec::new(*method, bits),
                )
                .calibrate(&data, CALIB_BATCHES)?;
                let r = ev.evaluate(&data, &calib.programmed, 0.0,
                                    EVAL_BATCHES, 7)?;
                accs[i] = r.accuracy;
            }
            println!(
                "   {bits}b: linear {:.3}  bs_kmq {:.3}  (gap {:+.1} pts)",
                accs[0],
                accs[1],
                (accs[1] - accs[0]) * 100.0
            );
            rows.push(Fig5Row {
                model: model.into(),
                bits,
                acc_linear: accs[0],
                acc_bskmq: accs[1],
            });
        }
        // the paper's fine-tuned mixed-precision point (3/3/4/4b across
        // the networks) lives in the manifest's per-layer specs — drive
        // it through the same API instead of a re-implemented loop
        let paper = Calibrator::from_manifest(backend.as_ref());
        let spec_desc = paper.specs()[0].summary();
        let calib = paper.calibrate(&data, CALIB_BATCHES)?;
        let r = ev.evaluate(&data, &calib.programmed, 0.0, EVAL_BATCHES, 7)?;
        println!("   manifest spec ({spec_desc}): acc {:.3}", r.accuracy);
        if let Some(m) = train_results.get(model) {
            let g = |k: &str| {
                m.get(k)
                    .ok()
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(f64::NAN)
            };
            println!(
                "   FT@{}b (build-time QAT): linear {:.3}  bs_kmq {:.3}",
                g("paper_bits") as u32,
                g("ft_linear"),
                g("ft_bs_kmq")
            );
        }
    }
    Ok(rows)
}

fn load_train_results(
    ctx: &ExpContext,
) -> Result<std::collections::BTreeMap<String, Json>> {
    let src =
        std::fs::read_to_string(ctx.artifacts.join("train_results.json"))?;
    match Json::parse(&src)? {
        Json::Obj(m) => Ok(m.into_iter().collect()),
        _ => anyhow::bail!("train_results.json is not an object"),
    }
}
