//! Typed view over the per-model AOT manifest JSON written by aot.py and
//! `data::synth` — including the declarative layer-graph IR (`graph`
//! section) the native backend executes.  This module only *parses*; all
//! semantic validation (acyclicity, shape inference, q-layer/weight
//! cross-checks) lives in `backend::native::graph`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::quant::{Method, QuantSpec};
use crate::util::json::Json;

/// One quantized MAC layer (conv im2col'd or dense) of a model.
#[derive(Clone, Debug)]
pub struct QLayer {
    pub name: String,
    /// contraction size — determines the number of 256-row crossbar tiles
    pub k: usize,
    /// output features (crossbar columns)
    pub n: usize,
    /// ReLU'd activations (non-negative codebook) vs signed
    pub relu: bool,
    /// per-layer quantization spec (`quant` entry); `None` resolves to
    /// [`QuantSpec::default_for_layer`] via [`Manifest::layer_specs`]
    pub spec: Option<QuantSpec>,
}

/// One weight argument of the AOT graphs, in call order.
#[derive(Clone, Debug)]
pub struct WeightArg {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One op of the layer-graph IR, as written in the manifest: a typed node
/// with named value edges (`inputs` -> `output`) plus the attributes its
/// kind needs.  Unknown kinds and inconsistent attributes are rejected at
/// load time by `backend::native::graph::GraphProgram::compile`.
#[derive(Clone, Debug)]
pub struct GraphOpDef {
    /// op kind ("conv", "dense", "add", "attention", ...)
    pub op: String,
    /// node name, used in error messages and timing breakdowns
    pub name: String,
    /// value edges consumed
    pub inputs: Vec<String>,
    /// value edge produced
    pub output: String,
    /// q-layer consumed (conv/dense)
    pub qlayer: Option<String>,
    /// square kernel size (conv)
    pub kernel: Option<usize>,
    /// spatial stride (conv)
    pub stride: Option<usize>,
    /// "same" or "valid" padding (conv)
    pub pad: Option<String>,
    /// fold a ReLU into the op (add)
    pub relu: Option<bool>,
    /// head count (attention)
    pub heads: Option<usize>,
    /// scale / shift weight-arg names (layernorm)
    pub gamma: Option<String>,
    pub beta: Option<String>,
    /// embedding-table / positional weight-arg names (embed)
    pub table: Option<String>,
    pub pos: Option<String>,
}

/// The manifest's `graph` section: a topologically-ordered op list over
/// named value edges, rooted at `input` and read out at `output`.
#[derive(Clone, Debug)]
pub struct GraphDef {
    /// name of the model-input value edge
    pub input: String,
    /// name of the logits value edge
    pub output: String,
    pub ops: Vec<GraphOpDef>,
}

impl GraphOpDef {
    /// An op with only the universal fields set; builders fill in the
    /// kind-specific attributes.
    pub fn new(op: &str, name: &str, inputs: &[&str], output: &str) -> Self {
        GraphOpDef {
            op: op.to_string(),
            name: name.to_string(),
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            output: output.to_string(),
            qlayer: None,
            kernel: None,
            stride: None,
            pad: None,
            relu: None,
            heads: None,
            gamma: None,
            beta: None,
            table: None,
            pos: None,
        }
    }

    fn to_json(&self) -> String {
        let mut fields = vec![
            format!(r#""op": {}"#, json_str(&self.op)),
            format!(r#""name": {}"#, json_str(&self.name)),
            format!(
                r#""in": [{}]"#,
                self.inputs
                    .iter()
                    .map(|s| json_str(s))
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
            format!(r#""out": {}"#, json_str(&self.output)),
        ];
        let mut push_str = |key: &str, v: &Option<String>| {
            if let Some(s) = v {
                fields.push(format!(r#""{key}": {}"#, json_str(s)));
            }
        };
        push_str("qlayer", &self.qlayer);
        push_str("pad", &self.pad);
        push_str("gamma", &self.gamma);
        push_str("beta", &self.beta);
        push_str("table", &self.table);
        push_str("pos", &self.pos);
        if let Some(k) = self.kernel {
            fields.push(format!(r#""kernel": {k}"#));
        }
        if let Some(s) = self.stride {
            fields.push(format!(r#""stride": {s}"#));
        }
        if let Some(h) = self.heads {
            fields.push(format!(r#""heads": {h}"#));
        }
        if let Some(r) = self.relu {
            fields.push(format!(r#""relu": {r}"#));
        }
        format!("{{{}}}", fields.join(", "))
    }
}

/// A JSON string literal (quoted, with `"`/`\`/control escapes).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

impl GraphDef {
    /// Serialize back to the manifest's `graph` JSON object (the inverse
    /// of the `parse_graph` path; `data::synth` embeds this text).
    pub fn to_json(&self) -> String {
        let ops: Vec<String> =
            self.ops.iter().map(|o| format!("    {}", o.to_json())).collect();
        format!(
            "{{\n  \"input\": {},\n  \"output\": {},\n  \"ops\": [\n{}\n  ]\n}}",
            json_str(&self.input),
            json_str(&self.output),
            ops.join(",\n")
        )
    }
}

/// Parsed `<model>_manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub input_is_int: bool,
    pub num_classes: usize,
    pub max_levels: usize,
    pub qlayers: Vec<QLayer>,
    pub weight_args: Vec<WeightArg>,
    pub collect_out_len: usize,
    pub collect_logits_len: usize,
    pub samples_per_layer: usize,
    pub tilemax_offset: usize,
    pub collect_hlo: String,
    pub qfwd_hlo: String,
    pub qfwd_b1_hlo: Option<String>,
    /// layer-graph IR; required by the native backend, ignored by XLA
    pub graph: Option<GraphDef>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let path = path.as_ref();
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json_str(&src)
            .with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse a manifest from its JSON text (tests, the `graph` CLI).
    pub fn from_json_str(src: &str) -> Result<Manifest> {
        let j = Json::parse(src)?;

        let qlayers = j
            .get("qlayers")?
            .as_arr()?
            .iter()
            .map(|q| {
                let name = q.get("name")?.as_str()?.to_string();
                let spec = match q.get("quant") {
                    Ok(qs) => Some(parse_quant_spec(qs).with_context(
                        || format!("q-layer '{name}': `quant` entry"),
                    )?),
                    Err(_) => None,
                };
                Ok(QLayer {
                    name,
                    k: q.get("k")?.as_usize()?,
                    n: q.get("n")?.as_usize()?,
                    relu: q.get("relu")?.as_bool()?,
                    spec,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let weight_args = j
            .get("weight_args")?
            .as_arr()?
            .iter()
            .map(|w| {
                Ok(WeightArg {
                    name: w.get("name")?.as_str()?.to_string(),
                    shape: w
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let collect = j.get("collect")?;
        let arts = j.get("artifacts")?;
        Ok(Manifest {
            model: j.get("model")?.as_str()?.to_string(),
            batch: j.get("batch")?.as_usize()?,
            input_shape: j
                .get("input_shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?,
            input_is_int: j.get("input_dtype")?.as_str()? == "i32",
            num_classes: j.get("num_classes")?.as_usize()?,
            max_levels: j.get("max_levels")?.as_usize()?,
            qlayers,
            weight_args,
            collect_out_len: collect.get("out_len")?.as_usize()?,
            collect_logits_len: collect.get("logits_len")?.as_usize()?,
            samples_per_layer: collect.get("samples_per_layer")?.as_usize()?,
            tilemax_offset: collect.get("tilemax_offset")?.as_usize()?,
            collect_hlo: arts.get("collect")?.as_str()?.to_string(),
            qfwd_hlo: arts.get("qfwd")?.as_str()?.to_string(),
            qfwd_b1_hlo: arts
                .get("qfwd_b1")
                .ok()
                .map(|s| s.as_str().map(str::to_string))
                .transpose()?,
            graph: j
                .get("graph")
                .ok()
                .map(parse_graph)
                .transpose()
                .context("parsing `graph` section")?,
        })
    }

    /// Number of quantized layers.
    pub fn nq(&self) -> usize {
        self.qlayers.len()
    }

    /// Per-sample input element count.
    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// The resolved per-layer quantization specs: each q-layer's `quant`
    /// entry when present, [`QuantSpec::default_for_layer`] otherwise —
    /// so manifests predating the QuantSpec schema calibrate exactly as
    /// the historical uniform pipeline did.
    pub fn layer_specs(&self) -> Vec<QuantSpec> {
        self.qlayers
            .iter()
            .enumerate()
            .map(|(i, q)| q.spec.unwrap_or_else(|| QuantSpec::default_for_layer(i)))
            .collect()
    }
}

/// Parse a q-layer's `quant` JSON object into a [`QuantSpec`].
/// `method`, `act_bits` and `tile_bits` are required; `weight_bits`,
/// `alpha` and `seed` are optional (defaulting to float weights,
/// Algorithm 1's trim fraction, and seed 0).  Out-of-range integers are
/// rejected loudly, never wrapped.
fn parse_quant_spec(o: &Json) -> Result<QuantSpec> {
    let bits = |key: &str, v: usize| -> Result<u32> {
        u32::try_from(v)
            .map_err(|_| anyhow::anyhow!("`{key}` {v} does not fit in u32"))
    };
    let mut spec = QuantSpec {
        method: Method::parse(o.get("method")?.as_str()?)?,
        act_bits: bits("act_bits", o.get("act_bits")?.as_usize()?)?,
        tile_bits: bits("tile_bits", o.get("tile_bits")?.as_usize()?)?,
        ..QuantSpec::default()
    };
    if let Some(a) = opt_f64(o, "alpha")? {
        spec.alpha = a;
    }
    if let Some(s) = opt_usize(o, "seed")? {
        spec.seed = s as u64;
    }
    spec.weight_bits = opt_usize(o, "weight_bits")?
        .map(|w| bits("weight_bits", w))
        .transpose()?;
    Ok(spec)
}

/// Serialize a [`QuantSpec`] as a q-layer `quant` JSON object (the
/// inverse of the parse above; `data::synth` embeds this text).
pub fn quant_spec_json(s: &QuantSpec) -> String {
    let mut fields = vec![
        format!(r#""method": "{}""#, s.method.name()),
        format!(r#""act_bits": {}"#, s.act_bits),
        format!(r#""tile_bits": {}"#, s.tile_bits),
        format!(r#""alpha": {}"#, s.alpha),
        format!(r#""seed": {}"#, s.seed),
    ];
    if let Some(w) = s.weight_bits {
        fields.push(format!(r#""weight_bits": {w}"#));
    }
    format!("{{{}}}", fields.join(", "))
}

fn opt_str(o: &Json, key: &str) -> Result<Option<String>> {
    match o.get(key) {
        Ok(v) => Ok(Some(v.as_str()?.to_string())),
        Err(_) => Ok(None),
    }
}

fn opt_usize(o: &Json, key: &str) -> Result<Option<usize>> {
    match o.get(key) {
        Ok(v) => Ok(Some(v.as_usize()?)),
        Err(_) => Ok(None),
    }
}

fn opt_bool(o: &Json, key: &str) -> Result<Option<bool>> {
    match o.get(key) {
        Ok(v) => Ok(Some(v.as_bool()?)),
        Err(_) => Ok(None),
    }
}

fn opt_f64(o: &Json, key: &str) -> Result<Option<f64>> {
    match o.get(key) {
        Ok(v) => Ok(Some(v.as_f64()?)),
        Err(_) => Ok(None),
    }
}

/// Parse a standalone `graph` JSON object (tests, round-trip checks).
pub fn parse_graph_str(src: &str) -> Result<GraphDef> {
    parse_graph(&Json::parse(src)?)
}

fn parse_graph(g: &Json) -> Result<GraphDef> {
    let ops = g
        .get("ops")?
        .as_arr()?
        .iter()
        .enumerate()
        .map(|(i, o)| {
            let parse = || -> Result<GraphOpDef> {
                Ok(GraphOpDef {
                    op: o.get("op")?.as_str()?.to_string(),
                    name: o.get("name")?.as_str()?.to_string(),
                    inputs: o
                        .get("in")?
                        .as_arr()?
                        .iter()
                        .map(|s| Ok(s.as_str()?.to_string()))
                        .collect::<Result<Vec<_>>>()?,
                    output: o.get("out")?.as_str()?.to_string(),
                    qlayer: opt_str(o, "qlayer")?,
                    kernel: opt_usize(o, "kernel")?,
                    stride: opt_usize(o, "stride")?,
                    pad: opt_str(o, "pad")?,
                    relu: opt_bool(o, "relu")?,
                    heads: opt_usize(o, "heads")?,
                    gamma: opt_str(o, "gamma")?,
                    beta: opt_str(o, "beta")?,
                    table: opt_str(o, "table")?,
                    pos: opt_str(o, "pos")?,
                })
            };
            parse().with_context(|| {
                // name the op when it has a name, its index otherwise
                match o.get("name").ok().and_then(|n| n.as_str().ok()) {
                    Some(n) => format!("graph op #{i} ('{n}')"),
                    None => format!("graph op #{i}"),
                }
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(GraphDef {
        input: g.get("input")?.as_str()?.to_string(),
        output: g.get("output")?.as_str()?.to_string(),
        ops,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quant_spec_roundtrips_and_defaults() {
        let spec = QuantSpec {
            method: Method::KMeans,
            act_bits: 4,
            weight_bits: Some(2),
            tile_bits: 6,
            alpha: 0.01,
            seed: 9,
        };
        let json = format!(
            r#"{{"name": "d0", "k": 4, "n": 5, "relu": true,
                 "quant": {}}}"#,
            quant_spec_json(&spec)
        );
        let parsed = Json::parse(&json).unwrap();
        let back = parse_quant_spec(parsed.get("quant").unwrap()).unwrap();
        assert_eq!(back, spec);

        // optional fields default: float weights, default alpha, seed 0
        let minimal = Json::parse(
            r#"{"method": "bs_kmq", "act_bits": 3, "tile_bits": 7}"#,
        )
        .unwrap();
        let spec = parse_quant_spec(&minimal).unwrap();
        assert_eq!(spec, QuantSpec::default());
        // unknown method is a parse error, not a silent default
        let bad = Json::parse(
            r#"{"method": "median", "act_bits": 3, "tile_bits": 7}"#,
        )
        .unwrap();
        assert!(parse_quant_spec(&bad).is_err());
        // out-of-range integers are rejected, never wrapped (4294967299
        // would silently truncate to 3 under an `as u32` cast)
        let wrap = Json::parse(
            r#"{"method": "bs_kmq", "act_bits": 4294967299, "tile_bits": 7}"#,
        )
        .unwrap();
        assert!(parse_quant_spec(&wrap).is_err());
    }

    #[test]
    fn manifest_without_quant_entries_resolves_defaults() {
        let m = Manifest::from_json_str(
            r#"{
  "model": "chain",
  "batch": 2,
  "input_shape": [4],
  "input_dtype": "f32",
  "num_classes": 3,
  "max_levels": 128,
  "qlayers": [
    {"name": "d0", "k": 4, "n": 5, "relu": true},
    {"name": "d1", "k": 5, "n": 3, "relu": false,
     "quant": {"method": "linear", "act_bits": 5, "tile_bits": 6}}
  ],
  "weight_args": [],
  "collect": {
    "out_len": 0, "logits_len": 6,
    "samples_per_layer": 8, "tilemax_offset": 0
  },
  "artifacts": {"collect": "none", "qfwd": "none"}
}"#,
        )
        .unwrap();
        assert_eq!(m.qlayers[0].spec, None);
        let specs = m.layer_specs();
        assert_eq!(specs[0], QuantSpec::default_for_layer(0));
        assert_eq!(specs[1].method, Method::Linear);
        assert_eq!(specs[1].act_bits, 5);
        assert_eq!(specs[1].tile_bits, 6);
    }

    #[test]
    fn graph_roundtrips_through_json() {
        let mut conv = GraphOpDef::new("conv", "conv0", &["x"], "y0");
        conv.qlayer = Some("conv0".into());
        conv.kernel = Some(3);
        conv.stride = Some(1);
        conv.pad = Some("same".into());
        let mut add = GraphOpDef::new("add", "res", &["y0", "y1"], "y2");
        add.relu = Some(true);
        let g = GraphDef {
            input: "x".into(),
            output: "y2".into(),
            ops: vec![conv, add],
        };
        let back = parse_graph_str(&g.to_json()).unwrap();
        assert_eq!(back.input, "x");
        assert_eq!(back.output, "y2");
        assert_eq!(back.ops.len(), 2);
        assert_eq!(back.ops[0].op, "conv");
        assert_eq!(back.ops[0].qlayer.as_deref(), Some("conv0"));
        assert_eq!(back.ops[0].kernel, Some(3));
        assert_eq!(back.ops[0].pad.as_deref(), Some("same"));
        assert_eq!(back.ops[1].inputs, vec!["y0", "y1"]);
        assert_eq!(back.ops[1].relu, Some(true));
        assert_eq!(back.ops[1].heads, None);
    }
}
