//! Typed view over the per-model AOT manifest JSON written by aot.py.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One quantized MAC layer (conv im2col'd or dense) of a model.
#[derive(Clone, Debug)]
pub struct QLayer {
    pub name: String,
    /// contraction size — determines the number of 256-row crossbar tiles
    pub k: usize,
    /// output features (crossbar columns)
    pub n: usize,
    /// ReLU'd activations (non-negative codebook) vs signed
    pub relu: bool,
}

/// One weight argument of the AOT graphs, in call order.
#[derive(Clone, Debug)]
pub struct WeightArg {
    pub name: String,
    pub shape: Vec<usize>,
}

/// Parsed `<model>_manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub input_is_int: bool,
    pub num_classes: usize,
    pub max_levels: usize,
    pub qlayers: Vec<QLayer>,
    pub weight_args: Vec<WeightArg>,
    pub collect_out_len: usize,
    pub collect_logits_len: usize,
    pub samples_per_layer: usize,
    pub tilemax_offset: usize,
    pub collect_hlo: String,
    pub qfwd_hlo: String,
    pub qfwd_b1_hlo: Option<String>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let path = path.as_ref();
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&src)
            .with_context(|| format!("parsing {}", path.display()))?;

        let qlayers = j
            .get("qlayers")?
            .as_arr()?
            .iter()
            .map(|q| {
                Ok(QLayer {
                    name: q.get("name")?.as_str()?.to_string(),
                    k: q.get("k")?.as_usize()?,
                    n: q.get("n")?.as_usize()?,
                    relu: q.get("relu")?.as_bool()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let weight_args = j
            .get("weight_args")?
            .as_arr()?
            .iter()
            .map(|w| {
                Ok(WeightArg {
                    name: w.get("name")?.as_str()?.to_string(),
                    shape: w
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let collect = j.get("collect")?;
        let arts = j.get("artifacts")?;
        Ok(Manifest {
            model: j.get("model")?.as_str()?.to_string(),
            batch: j.get("batch")?.as_usize()?,
            input_shape: j
                .get("input_shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?,
            input_is_int: j.get("input_dtype")?.as_str()? == "i32",
            num_classes: j.get("num_classes")?.as_usize()?,
            max_levels: j.get("max_levels")?.as_usize()?,
            qlayers,
            weight_args,
            collect_out_len: collect.get("out_len")?.as_usize()?,
            collect_logits_len: collect.get("logits_len")?.as_usize()?,
            samples_per_layer: collect.get("samples_per_layer")?.as_usize()?,
            tilemax_offset: collect.get("tilemax_offset")?.as_usize()?,
            collect_hlo: arts.get("collect")?.as_str()?.to_string(),
            qfwd_hlo: arts.get("qfwd")?.as_str()?.to_string(),
            qfwd_b1_hlo: arts
                .get("qfwd_b1")
                .ok()
                .map(|s| s.as_str().map(str::to_string))
                .transpose()?,
        })
    }

    /// Number of quantized layers.
    pub fn nq(&self) -> usize {
        self.qlayers.len()
    }

    /// Per-sample input element count.
    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }
}
