//! Artifact I/O: the flat tensor container written by
//! `python/compile/weights_io.py` and the per-model AOT manifests.

pub mod manifest;
pub mod weights;

pub use manifest::{Manifest, QLayer};
pub use weights::{load_tensors, TensorMap};
