//! Reader/writer for the flat tensor container (see
//! python/compile/weights_io.py).
//!
//! Layout (little-endian): magic u32 "BSKQ" (0x42534B51), version u32 = 1,
//! count u32, then per tensor: name_len u32, name bytes, ndim u32,
//! dims u32*ndim, f32 data.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

pub const MAGIC: u32 = 0x4253_4B51;
pub const VERSION: u32 = 1;

/// Ordered name -> tensor map (insertion order preserved separately).
pub struct TensorMap {
    pub names: Vec<String>,
    pub map: BTreeMap<String, Tensor>,
}

impl TensorMap {
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .with_context(|| format!("tensor '{name}' missing from container"))
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Load every tensor in a container file.
pub fn load_tensors(path: impl AsRef<Path>) -> Result<TensorMap> {
    let path = path.as_ref();
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?,
    );
    let magic = read_u32(&mut f)?;
    let version = read_u32(&mut f)?;
    if magic != MAGIC || version != VERSION {
        bail!("bad container header {magic:#x} v{version} in {}", path.display());
    }
    let count = read_u32(&mut f)? as usize;
    let mut names = Vec::with_capacity(count);
    let mut map = BTreeMap::new();
    for _ in 0..count {
        let nlen = read_u32(&mut f)? as usize;
        if nlen > 4096 {
            bail!("implausible tensor name length {nlen}");
        }
        let mut nb = vec![0u8; nlen];
        f.read_exact(&mut nb)?;
        let name = String::from_utf8(nb)?;
        let ndim = read_u32(&mut f)? as usize;
        if ndim > 8 {
            bail!("implausible rank {ndim} for '{name}'");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut f)? as usize);
        }
        let n: usize = shape.iter().product();
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)
            .with_context(|| format!("reading data of '{name}'"))?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        map.insert(name.clone(), Tensor::new(shape, data)?);
        names.push(name);
    }
    Ok(TensorMap { names, map })
}

/// Write a container file — the Rust counterpart of
/// `weights_io.save_tensors` (same byte layout), used by the native
/// backend's synthetic-artifact tests and future export tooling.
pub fn save_tensors(
    path: impl AsRef<Path>,
    tensors: &[(&str, &Tensor)],
) -> Result<()> {
    let path = path.as_ref();
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(&MAGIC.to_le_bytes())?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        for &v in &t.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    f.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_container(tensors: &[(&str, Vec<usize>, Vec<f32>)]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend(MAGIC.to_le_bytes());
        b.extend(VERSION.to_le_bytes());
        b.extend((tensors.len() as u32).to_le_bytes());
        for (name, shape, data) in tensors {
            b.extend((name.len() as u32).to_le_bytes());
            b.extend(name.as_bytes());
            b.extend((shape.len() as u32).to_le_bytes());
            for &d in shape {
                b.extend((d as u32).to_le_bytes());
            }
            for &x in data {
                b.extend(x.to_le_bytes());
            }
        }
        b
    }

    #[test]
    fn roundtrip() {
        let bytes = write_container(&[
            ("a", vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
            ("b", vec![3], vec![5.0, 6.0, 7.0]),
        ]);
        let dir = std::env::temp_dir().join("bskmq_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        std::fs::File::create(&path)
            .unwrap()
            .write_all(&bytes)
            .unwrap();
        let tm = load_tensors(&path).unwrap();
        assert_eq!(tm.names, vec!["a", "b"]);
        assert_eq!(tm.get("a").unwrap().shape, vec![2, 2]);
        assert_eq!(tm.get("b").unwrap().data, vec![5.0, 6.0, 7.0]);
        assert!(tm.get("missing").is_err());
    }

    #[test]
    fn save_tensors_roundtrips_through_loader() {
        let a = Tensor::new(vec![2, 3], (0..6).map(|v| v as f32).collect())
            .unwrap();
        let b = Tensor::new(vec![4], vec![9.0, 8.0, 7.0, 6.0]).unwrap();
        let dir = std::env::temp_dir().join("bskmq_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("saved.bin");
        save_tensors(&path, &[("alpha", &a), ("beta", &b)]).unwrap();
        let tm = load_tensors(&path).unwrap();
        assert_eq!(tm.names, vec!["alpha", "beta"]);
        assert_eq!(tm.get("alpha").unwrap(), &a);
        assert_eq!(tm.get("beta").unwrap(), &b);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = write_container(&[]);
        bytes[0] = 0;
        let dir = std::env::temp_dir().join("bskmq_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::File::create(&path)
            .unwrap()
            .write_all(&bytes)
            .unwrap();
        assert!(load_tensors(&path).is_err());
    }
}
