//! Thin wrapper over the `xla` crate: HLO text -> compiled executable ->
//! literal execution.  One [`Engine`] per process (the PJRT CPU client);
//! executables are compiled once and cached by artifact path.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::Result;

use crate::tensor::Tensor;

/// Process-wide PJRT client + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<Executable>>>,
}

/// A compiled HLO module.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

impl Engine {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Engine {
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached by path).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<std::sync::Arc<Executable>> {
        let path = path.as_ref().to_path_buf();
        if let Some(e) = self.cache.lock().unwrap().get(&path) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))?;
        let arc = std::sync::Arc::new(Executable {
            exe,
            path: path.clone(),
        });
        self.cache.lock().unwrap().insert(path, arc.clone());
        Ok(arc)
    }
}

impl Executable {
    /// Execute with f32/i32 literal arguments; returns the flat f32
    /// vector of the single (1-tuple) output.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("execute {}: {e}", self.path.display()))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
        let out = lit
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple result: {e}"))?;
        out.to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("result to_vec: {e}"))
    }
}

/// Build an f32 literal from a tensor.
pub fn literal_f32(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.shape.is_empty() {
        // scalar: reshape to rank 0
        return lit
            .reshape(&[])
            .map_err(|e| anyhow::anyhow!("scalar reshape: {e}"));
    }
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape {:?}: {e}", t.shape))
}

/// Build an i32 literal from f32 class/token values (exact for < 2^24).
pub fn literal_i32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let ints: Vec<i32> = data.iter().map(|&v| v as i32).collect();
    let lit = xla::Literal::vec1(&ints);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape {shape:?}: {e}"))
}

/// Scalar literals for qfwd's noise/seed arguments.
pub fn literal_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn literal_scalar_u32(v: u32) -> xla::Literal {
    xla::Literal::scalar(v)
}
