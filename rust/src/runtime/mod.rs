//! PJRT runtime: loads the HLO-text artifacts lowered by aot.py, compiles
//! them once on the CPU PJRT client, and executes them from the request
//! path.  Python is never involved at runtime.

pub mod engine;
pub mod model;

pub use engine::{Engine, Executable};
pub use model::ModelRuntime;
