//! PJRT runtime (feature `xla`): loads the HLO-text artifacts lowered by
//! aot.py, compiles them once on the CPU PJRT client, and executes them
//! from the request path.  Python is never involved at runtime.
//!
//! Builds without the `xla` feature omit this engine entirely; the
//! [`crate::backend::native`] backend covers the same entry points in
//! pure Rust.  The shared interchange types ([`CollectOut`],
//! [`ProgrammedCodebooks`]) live in [`crate::backend`].

#[cfg(feature = "xla")]
pub mod engine;
#[cfg(feature = "xla")]
pub mod model;

#[cfg(feature = "xla")]
pub use engine::{Engine, Executable};
#[cfg(feature = "xla")]
pub use model::ModelRuntime;

#[cfg(feature = "xla")]
pub use crate::backend::{CollectOut, ProgrammedCodebooks};
