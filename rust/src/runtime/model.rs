//! Per-model runtime: manifest + trained weights + compiled graphs.
//!
//! Owns the weight literals (uploaded once) and exposes the two AOT entry
//! points: `collect` (calibration activations) and `qfwd` (the deployed
//! quantized forward with codebooks, noise sigma and PRNG seed).

use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::backend::{CollectOut, ProgrammedCodebooks};
use crate::io::manifest::Manifest;
use crate::io::weights::load_tensors;
use crate::runtime::engine::{
    literal_f32, literal_i32, literal_scalar_f32, literal_scalar_u32,
    Engine, Executable,
};
use crate::tensor::Tensor;

pub struct ModelRuntime {
    pub manifest: Manifest,
    collect_exe: Arc<Executable>,
    qfwd_exe: Arc<Executable>,
    qfwd_b1_exe: Option<Arc<Executable>>,
    /// weight tensors in graph argument order
    weights: Vec<Tensor>,
}

impl ModelRuntime {
    pub fn load(engine: &Engine, artifacts: &Path, model: &str) -> Result<ModelRuntime> {
        let manifest =
            Manifest::load(artifacts.join(format!("{model}_manifest.json")))?;
        let tm = load_tensors(artifacts.join(format!("{model}_weights.bin")))?;
        let weights = manifest
            .weight_args
            .iter()
            .map(|wa| {
                let t = tm.get(&wa.name)?.clone();
                ensure!(
                    t.shape == wa.shape,
                    "weight '{}' shape {:?} != manifest {:?}",
                    wa.name,
                    t.shape,
                    wa.shape
                );
                Ok(t)
            })
            .collect::<Result<Vec<_>>>()?;
        let collect_exe = engine
            .load(artifacts.join(&manifest.collect_hlo))
            .context("loading collect graph")?;
        let qfwd_exe = engine
            .load(artifacts.join(&manifest.qfwd_hlo))
            .context("loading qfwd graph")?;
        let qfwd_b1_exe = manifest
            .qfwd_b1_hlo
            .as_ref()
            .map(|p| engine.load(artifacts.join(p)))
            .transpose()?;
        Ok(ModelRuntime {
            manifest,
            collect_exe,
            qfwd_exe,
            qfwd_b1_exe,
            weights,
        })
    }

    fn input_literal(&self, x: &[f32], batch: usize) -> Result<xla::Literal> {
        let mut shape = vec![batch];
        shape.extend(&self.manifest.input_shape);
        let n: usize = shape.iter().product();
        ensure!(x.len() == n, "input len {} != {:?}", x.len(), shape);
        if self.manifest.input_is_int {
            literal_i32(x, &shape)
        } else {
            literal_f32(&Tensor::new(shape, x.to_vec())?)
        }
    }

    fn weight_literals(&self) -> Result<Vec<xla::Literal>> {
        self.weights.iter().map(literal_f32).collect()
    }

    /// Run one calibration batch through the collect graph.
    pub fn run_collect(&self, x: &[f32]) -> Result<CollectOut> {
        let m = &self.manifest;
        let mut args = vec![self.input_literal(x, m.batch)?];
        args.extend(self.weight_literals()?);
        let out = self.collect_exe.run(&args)?;
        ensure!(
            out.len() == m.collect_out_len,
            "collect output len {} != manifest {}",
            out.len(),
            m.collect_out_len
        );
        let nq = m.nq();
        let spl = m.samples_per_layer;
        let logits = out[..m.collect_logits_len].to_vec();
        let samples = (0..nq)
            .map(|i| {
                let s = m.collect_logits_len + i * spl;
                out[s..s + spl].iter().map(|&v| v as f64).collect()
            })
            .collect();
        let tile_max = out[m.tilemax_offset..m.tilemax_offset + nq]
            .iter()
            .map(|&v| v as f64)
            .collect();
        Ok(CollectOut {
            logits,
            samples,
            tile_max,
        })
    }

    /// Run the quantized forward on one batch; returns flat logits.
    pub fn run_qfwd(
        &self,
        x: &[f32],
        books: &ProgrammedCodebooks,
        noise_std: f32,
        seed: u32,
    ) -> Result<Vec<f32>> {
        self.run_qfwd_on(&self.qfwd_exe, self.manifest.batch, x, books, noise_std, seed)
    }

    /// Batch-1 serving entry point (resnet only).
    pub fn run_qfwd_b1(
        &self,
        x: &[f32],
        books: &ProgrammedCodebooks,
        noise_std: f32,
        seed: u32,
    ) -> Result<Vec<f32>> {
        let exe = self
            .qfwd_b1_exe
            .as_ref()
            .context("model has no batch-1 qfwd graph")?
            .clone();
        self.run_qfwd_on(&exe, 1, x, books, noise_std, seed)
    }

    pub fn has_b1(&self) -> bool {
        self.qfwd_b1_exe.is_some()
    }

    fn run_qfwd_on(
        &self,
        exe: &Executable,
        batch: usize,
        x: &[f32],
        books: &ProgrammedCodebooks,
        noise_std: f32,
        seed: u32,
    ) -> Result<Vec<f32>> {
        let mut args = vec![
            self.input_literal(x, batch)?,
            literal_f32(&books.nl_refs)?,
            literal_f32(&books.nl_centers)?,
            literal_f32(&books.tile_refs)?,
            literal_f32(&books.tile_centers)?,
            literal_scalar_f32(noise_std),
            literal_scalar_u32(seed),
        ];
        args.extend(self.weight_literals()?);
        exe.run(&args)
    }

    /// Weight tensors in graph order (for Fig. 6 weight quantization the
    /// caller clones + quantizes and uses [`Self::with_weights`]).
    pub fn weights(&self) -> &[Tensor] {
        &self.weights
    }

    /// Replace the weight set (e.g. with quantized weights).
    pub fn with_weights(&self, weights: Vec<Tensor>) -> Result<ModelRuntime> {
        ensure!(weights.len() == self.weights.len(), "weight count mismatch");
        Ok(ModelRuntime {
            manifest: self.manifest.clone(),
            collect_exe: self.collect_exe.clone(),
            qfwd_exe: self.qfwd_exe.clone(),
            qfwd_b1_exe: self.qfwd_b1_exe.clone(),
            weights,
        })
    }

}
