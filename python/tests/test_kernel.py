"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py) — the
CORE correctness signal, swept over shapes/dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quantlib as Q
from compile.kernels.imc_mac import imc_mac_adc
from compile.kernels.nl_quant import nl_quantize
from compile.kernels.ref import (CROSSBAR_ROWS, min_ref_step,
                                 ref_imc_mac_adc, ref_nl_quantize)


def padded_codebook(bits, lo=-20.0, hi=20.0):
    centers = np.linspace(lo, hi, 2 ** bits)
    cb = Q.Codebook.from_centers(centers)
    pc, pr = cb.padded()
    return jnp.asarray(pr), jnp.asarray(pc)


class TestNlQuant:
    def test_matches_ref_basic(self):
        refs, centers = padded_codebook(4)
        x = jnp.asarray(np.random.default_rng(0).normal(0, 10, (16, 8)),
                        jnp.float32)
        np.testing.assert_allclose(
            nl_quantize(x, refs, centers),
            ref_nl_quantize(x, refs, centers))

    def test_below_range_floors_to_first_center(self):
        refs, centers = padded_codebook(3, 0.0, 7.0)
        out = nl_quantize(jnp.asarray([-5.0], jnp.float32), refs, centers)
        assert float(out[0]) == 0.0

    def test_above_range_clamps_to_last_center(self):
        refs, centers = padded_codebook(3, 0.0, 7.0)
        out = nl_quantize(jnp.asarray([99.0], jnp.float32), refs, centers)
        assert float(out[0]) == 7.0

    @settings(max_examples=20, deadline=None)
    @given(
        st.integers(min_value=1, max_value=7),
        st.tuples(st.integers(1, 9), st.integers(1, 33)),
        st.integers(min_value=0, max_value=10_000),
    )
    def test_property_matches_ref(self, bits, shape, seed):
        rng = np.random.default_rng(seed)
        centers = np.sort(rng.normal(0, 5, 2 ** bits))
        centers = np.unique(centers)
        if centers.size < 2:
            return
        cb = Q.Codebook.from_centers(centers)
        pc, pr = cb.padded()
        refs, cents = jnp.asarray(pr), jnp.asarray(pc)
        x = jnp.asarray(rng.normal(0, 8, shape), jnp.float32)
        got = nl_quantize(x, refs, cents)
        want = ref_nl_quantize(x, refs, cents)
        np.testing.assert_allclose(got, want)


class TestImcMac:
    def test_single_tile_matches_ref(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(8, 100)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(100, 6)), jnp.float32)
        refs, centers = padded_codebook(5, -40, 40)
        np.testing.assert_allclose(
            imc_mac_adc(x, w, refs, centers),
            ref_imc_mac_adc(x, w, refs, centers), rtol=1e-6)

    def test_multi_tile_accumulates(self):
        rng = np.random.default_rng(2)
        k = CROSSBAR_ROWS * 2 + 37  # 3 tiles with ragged tail
        x = jnp.asarray(rng.normal(size=(4, k)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, 5)), jnp.float32)
        refs, centers = padded_codebook(6, -60, 60)
        np.testing.assert_allclose(
            imc_mac_adc(x, w, refs, centers),
            ref_imc_mac_adc(x, w, refs, centers), rtol=1e-6)

    def test_noise_is_applied_per_tile(self):
        rng = np.random.default_rng(3)
        k = CROSSBAR_ROWS + 10
        x = jnp.asarray(rng.normal(size=(3, k)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, 4)), jnp.float32)
        refs, centers = padded_codebook(6, -60, 60)
        noise = jnp.asarray(rng.normal(size=(2, 3, 4)) * 5, jnp.float32)
        got = imc_mac_adc(x, w, refs, centers, noise)
        want = ref_imc_mac_adc(x, w, refs, centers, noise)
        np.testing.assert_allclose(got, want, rtol=1e-6)
        # and differs from the noiseless result
        clean = imc_mac_adc(x, w, refs, centers)
        assert not np.allclose(got, clean)

    def test_identity_codebook_approximates_matmul(self):
        """A fine linear codebook over the MAC range ~ plain matmul."""
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(6, 64)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(64, 3)), jnp.float32)
        refs, centers = padded_codebook(7, -30, 30)
        got = imc_mac_adc(x, w, refs, centers)
        want = x @ w
        step = 60.0 / 127
        assert float(jnp.max(jnp.abs(got - want))) <= step

    @settings(max_examples=10, deadline=None)
    @given(
        st.integers(min_value=1, max_value=20),
        st.integers(min_value=1, max_value=600),
        st.integers(min_value=1, max_value=10),
        st.integers(min_value=0, max_value=10_000),
    )
    def test_property_matches_ref_all_shapes(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        refs, centers = padded_codebook(6, -80, 80)
        np.testing.assert_allclose(
            imc_mac_adc(x, w, refs, centers),
            ref_imc_mac_adc(x, w, refs, centers), rtol=1e-5, atol=1e-5)


def test_min_ref_step_ignores_padding():
    refs = jnp.asarray([0.0, 0.5, 2.0, np.inf, np.inf], jnp.float32)
    assert float(min_ref_step(refs)) == 0.5
