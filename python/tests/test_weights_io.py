"""Container round-trip + golden-vector parity with the Rust reader
(rust/src/io/weights.rs reads what weights_io.py writes)."""

import numpy as np
import pytest

from compile import weights_io


def test_roundtrip(tmp_path):
    tensors = [
        ("a", np.arange(6, dtype=np.float32).reshape(2, 3)),
        ("b", np.array(3.5, dtype=np.float32)),
        ("nested/name_w", np.random.default_rng(0)
         .normal(size=(4, 1, 2)).astype(np.float32)),
    ]
    p = tmp_path / "t.bin"
    weights_io.save_tensors(str(p), tensors)
    out = weights_io.load_tensors(str(p))
    assert [n for n, _ in out] == [n for n, _ in tensors]
    for (_, a), (_, b) in zip(tensors, out):
        np.testing.assert_array_equal(np.asarray(a, np.float32), b)


def test_rejects_bad_header(tmp_path):
    p = tmp_path / "bad.bin"
    p.write_bytes(b"\x00" * 16)
    with pytest.raises(ValueError):
        weights_io.load_tensors(str(p))


def test_float64_downcast(tmp_path):
    p = tmp_path / "d.bin"
    weights_io.save_tensors(str(p), [("x", np.array([1.5], np.float64))])
    (_, x), = weights_io.load_tensors(str(p))
    assert x.dtype == np.float32 and x[0] == 1.5
