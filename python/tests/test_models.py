"""L2 model tests: folded-inference parity, collect-mode recording, quant
mode consistency, and the AOT pack plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import data as D
from compile import quantlib as Q
from compile.models import MODELS, common as cm


@pytest.fixture(scope="module", params=list(MODELS))
def model_setup(request):
    name = request.param
    mod = MODELS[name]
    params = mod.init_params(jax.random.PRNGKey(0))
    state = mod.init_state()
    pack = mod.export_pack(params, state)
    x, y = D.dataset_for(name, 0, 8)
    return name, mod, params, state, pack, x, y


class TestForward:
    def test_folded_matches_train_eval(self, model_setup):
        name, mod, params, state, pack, x, _ = model_setup
        lt, _ = mod.forward_train(params, state, jnp.asarray(x), False)
        ctx = cm.QuantCtx(mode="float")
        li = mod.forward_infer(pack, jnp.asarray(x), ctx)
        np.testing.assert_allclose(li, lt, rtol=2e-4, atol=2e-4)

    def test_collect_records_every_qlayer(self, model_setup):
        name, mod, _, _, pack, x, _ = model_setup
        ctx = cm.QuantCtx(mode="collect")
        mod.forward_infer(pack, jnp.asarray(x), ctx)
        assert len(ctx.records) == len(pack.qspecs)
        assert len(ctx.tile_maxes) == len(pack.qspecs)
        for rec, spec in zip(ctx.records, pack.qspecs):
            assert rec.shape == (cm.COLLECT_SAMPLES,)
            if spec.relu:
                assert float(jnp.min(rec)) >= 0.0, spec.name

    def test_quant_mode_with_fine_codebooks_approximates_float(
            self, model_setup):
        name, mod, _, _, pack, x, _ = model_setup
        nq = len(pack.qspecs)
        # collect ranges, build 7-bit codebooks per layer
        ctx = cm.QuantCtx(mode="collect")
        lf = mod.forward_infer(pack, jnp.asarray(x), ctx)
        nl_r, nl_c, t_r, t_c = [], [], [], []
        for i in range(nq):
            s = np.asarray(ctx.records[i])
            lo, hi = float(s.min()), float(s.max())
            cb = Q.Codebook.from_centers(Q.fit_linear(
                np.array([lo, hi + 1e-6]), 7))
            pc, pr = cb.padded()
            nl_r.append(pr), nl_c.append(pc)
            tm = float(ctx.tile_maxes[i]) * 1.5
            tcb = Q.Codebook.from_centers(np.linspace(-tm, tm, 128))
            pc, pr = tcb.padded()
            t_r.append(pr), t_c.append(pc)
        qctx = cm.QuantCtx(
            mode="quant",
            nl_refs=jnp.asarray(np.stack(nl_r)),
            nl_centers=jnp.asarray(np.stack(nl_c)),
            tile_refs=jnp.asarray(np.stack(t_r)),
            tile_centers=jnp.asarray(np.stack(t_c)),
            noise_std=jnp.float32(0.0),
            key=jax.random.PRNGKey(0))
        lq = mod.forward_infer(pack, jnp.asarray(x), qctx)
        assert lq.shape == lf.shape
        # untrained nets have near-degenerate logits, so check relative
        # logit error plus above-chance argmax agreement (chance ~ 1/C)
        rel = float(jnp.linalg.norm(lq - lf) / (jnp.linalg.norm(lf) + 1e-9))
        assert rel < 0.5, f"{name}: relative logit error {rel}"
        agree = float(jnp.mean(jnp.argmax(lq, -1) == jnp.argmax(lf, -1)))
        assert agree >= 0.5, f"{name}: only {agree} argmax agreement"


class TestPackPlumbing:
    def test_weight_arg_layout_roundtrip(self, model_setup):
        name, mod, _, _, pack, _, _ = model_setup
        names, shapes = aot.weight_arg_layout(pack)
        assert len(names) == len(shapes)
        flat = []
        for pair in pack.qweights:
            flat.extend(pair)
        for dname in sorted(pack.digital):
            v = pack.digital[dname]
            if isinstance(v, dict):
                flat.extend(v[f] for f in sorted(v))
            else:
                flat.append(v)
        rebuilt = aot.rebuild_pack(pack, flat)
        for (a, b), (c, d) in zip(pack.qweights, rebuilt.qweights):
            np.testing.assert_array_equal(a, c)
            np.testing.assert_array_equal(b, d)

    def test_qspec_ks_match_weight_shapes(self, model_setup):
        name, mod, _, _, pack, _, _ = model_setup
        for (w, b), spec in zip(pack.qweights, pack.qspecs):
            assert w.shape == (spec.k, spec.n), spec.name
            assert b.shape == (spec.n,)


class TestData:
    def test_task_fixed_across_splits(self):
        x0, y0 = D.make_image_dataset(0, 64)
        x1, y1 = D.make_image_dataset(1, 64)
        # different samples...
        assert not np.allclose(x0, x1)
        # ...but same class templates: per-class means correlate strongly
        m0 = np.stack([x0[y0 == c].mean(0) for c in range(10)
                       if (y0 == c).any() and (y1 == c).any()])
        m1 = np.stack([x1[y1 == c].mean(0) for c in range(10)
                       if (y0 == c).any() and (y1 == c).any()])
        corr = np.corrcoef(m0.ravel(), m1.ravel())[0, 1]
        assert corr > 0.5, f"templates differ across splits: corr={corr}"

    def test_image_outliers_present(self):
        x, _ = D.make_image_dataset(0, 4096)
        scale = np.abs(x).max(axis=(1, 2, 3))
        frac_hot = (scale > 2.0 * np.median(scale)).mean()
        assert 0.002 < frac_hot < 0.05

    def test_token_dataset_shapes(self):
        x, y = D.make_token_dataset(0, 32)
        assert x.shape == (32, 32) and x.dtype == np.int32
        assert x.min() >= 0 and x.max() < 64
        assert y.max() < 6
